"""Pure-jnp reference oracle for the Pallas kernels and the L2 model.

Everything here is straight-line jax.numpy with no Pallas, no tiling and no
cleverness: it is the ground truth that `python/tests/` compares the Pallas
kernels and the AOT'd HLO against.

Math reference (Scetbon & Cuturi 2020, Lemma 1):
    q      = eps^{-1} R^2 / (2 d W0(eps^{-1} R^2 / d))
    rho    = N(0, (q eps / 4) I_d)
    phi(x, u) = (2q)^{d/4} exp(-2 eps^{-1} ||x - u||^2) exp(eps^{-1}||u||^2 / q)
    k(x, y)   = E_{u~rho}[phi(x,u) phi(y,u)] = exp(-||x-y||^2 / eps)
Monte-Carlo with r draws and a 1/sqrt(r) normalisation gives the positive
feature matrices  xi = Phi(X) in R_+^{n x r}  with  K ~= xi @ zeta^T.
"""
from __future__ import annotations

import jax.numpy as jnp


def lambert_w0(z, iters: int = 32):
    """Principal branch of the Lambert W function via Halley iterations.

    Valid for z >= 0 (all uses in Lemma 1 have z > 0). Matches
    scipy.special.lambertw to ~1e-12 on [1e-6, 1e6].
    """
    z = jnp.asarray(z, dtype=jnp.float64 if jnp.asarray(z).dtype == jnp.float64 else jnp.float32)
    # Initial guess: log-based for large z, rational for small z.
    logz = jnp.log(jnp.maximum(z, 1e-30))
    w = jnp.where(z > jnp.e, logz - jnp.log(jnp.maximum(logz, 1e-30)), z / (1.0 + z))
    for _ in range(iters):
        ew = jnp.exp(w)
        f = w * ew - z
        # Halley update.
        w = w - f / (ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0))
    return w


def gaussian_q(eps: float, radius: float, dim: int):
    """The Lemma-1 constant q = eps^{-1}R^2 / (2 d W0(eps^{-1}R^2/d))."""
    z = (radius ** 2) / (eps * dim)
    return (radius ** 2) / (eps * 2.0 * dim * lambert_w0(jnp.asarray(z)))


# Positivity by construction is the paper's point, but exp() underflows f32
# below ~1e-38 and would re-introduce exact zeros into the kernel (and hence
# divisions by zero in Alg. 1). Clamping the log-feature at -80 keeps every
# entry a normal positive float (exp(-80) ~ 1.8e-35) while perturbing no
# value that was representable to begin with. The symmetric ceiling at +80
# (exp(80) ~ 5.5e34) guards the anchor-norm term uu/(eps q) against f32
# overflow for extreme (eps, q) combinations.
LOG_FLOOR = -80.0
LOG_CEIL = 80.0


def sq_dists(x, u):
    """Pairwise squared euclidean distances, (n,d) x (r,d) -> (n,r)."""
    xx = jnp.sum(x * x, axis=1)[:, None]
    uu = jnp.sum(u * u, axis=1)[None, :]
    return xx - 2.0 * x @ u.T + uu


def gaussian_features(x, u, eps: float, q: float):
    """Positive feature matrix Phi in R_+^{n x r} (Lemma 1, 1/sqrt(r) folded in).

    x: (n, d) points; u: (r, d) random anchors drawn from N(0, q*eps/4 I).
    """
    n, d = x.shape
    r = u.shape[0]
    sq = sq_dists(x, u)                      # (n, r)
    uu = jnp.sum(u * u, axis=1)[None, :]     # (1, r)
    log_phi = (d / 4.0) * jnp.log(2.0 * q) \
        - 2.0 * sq / eps + uu / (eps * q) \
        - 0.5 * jnp.log(float(r))
    return jnp.exp(jnp.clip(log_phi, LOG_FLOOR, LOG_CEIL))


def arccos_features(x, u, s: int, kappa: float, sigma: float):
    """Perturbed arc-cosine positive features (Lemma 3).

    Returns (n, r+1): r rectified-projection features plus the constant
    sqrt(kappa) column that makes the kernel bounded away from zero.
    """
    n, d = x.shape
    r = u.shape[0]
    proj = jnp.maximum(x @ u.T, 0.0) ** s                      # (n, r)
    uu = jnp.sum(u * u, axis=1)[None, :]
    scale = (sigma ** (d / 2.0)) * jnp.sqrt(2.0) * jnp.exp(-(uu / 4.0) * (1.0 - 1.0 / sigma ** 2))
    feats = proj * scale / jnp.sqrt(float(r))
    const = jnp.full((n, 1), jnp.sqrt(kappa))
    return jnp.concatenate([feats, const], axis=1)


def gibbs_kernel(x, y, eps: float):
    """Dense Gibbs kernel exp(-||x-y||^2/eps) — the `Sin` baseline."""
    return jnp.exp(-sq_dists(x, y) / eps)


def matvec(a, v):
    """Reference for the Pallas blocked matvec: a @ v."""
    return a @ v


def matvec_t(a, v):
    """Reference for the Pallas blocked transpose-matvec: a.T @ v."""
    return a.T @ v


def factored_apply(phi_x, phi_y, v):
    """K v with K = phi_x @ phi_y^T, computed in O(r(n+m))."""
    return phi_x @ (phi_y.T @ v)


def sinkhorn_dense(kmat, a, b, iters: int):
    """Algorithm 1 on a dense kernel matrix; returns (u, v, w_hat/eps)."""
    u = jnp.ones_like(a)
    v = jnp.ones_like(b)
    for _ in range(iters):
        v = b / (kmat.T @ u)
        u = a / (kmat @ v)
    w_hat = jnp.sum(a * jnp.log(u)) + jnp.sum(b * jnp.log(v))
    return u, v, w_hat


def sinkhorn_factored(phi_x, phi_y, a, b, iters: int):
    """Algorithm 1 with the factored kernel xi^T zeta; O(r(n+m)) per iter."""
    u = jnp.ones_like(a)
    v = jnp.ones_like(b)
    for _ in range(iters):
        v = b / (phi_y @ (phi_x.T @ u))
        u = a / (phi_x @ (phi_y.T @ v))
    w_hat = jnp.sum(a * jnp.log(u)) + jnp.sum(b * jnp.log(v))
    return u, v, w_hat


def rot_value(eps: float, a, b, u, v):
    """Eq. (6): eps * (a^T log u + b^T log v) estimates W_{eps,c}."""
    return eps * (jnp.sum(a * jnp.log(u)) + jnp.sum(b * jnp.log(v)))


def marginal_error(kmat, a, b, u, v):
    """L1 violation of the column marginal, Alg. 1's stopping criterion."""
    return jnp.sum(jnp.abs(v * (kmat.T @ u) - b))


def sinkhorn_divergence_factored(phi_x, phi_y, a, b, eps: float, iters: int):
    """Eq. (2) with three factored transport problems."""
    _, _, w_xy = sinkhorn_factored(phi_x, phi_y, a, b, iters)
    _, _, w_xx = sinkhorn_factored(phi_x, phi_x, a, a, iters)
    _, _, w_yy = sinkhorn_factored(phi_y, phi_y, b, b, iters)
    return eps * (w_xy - 0.5 * (w_xx + w_yy))
