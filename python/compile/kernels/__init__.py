"""L1 Pallas kernels for the linear-time Sinkhorn hot spots."""
from . import factored_apply, gaussian_features, ref  # noqa: F401
