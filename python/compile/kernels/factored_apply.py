"""L1 Pallas kernels: blocked matvec / transpose-matvec and the factored
kernel application  K v = Phi_x (Phi_y^T v)  that makes Sinkhorn linear.

The two matvecs are the entire per-iteration cost of RF-Sinkhorn
(Alg. 1 with K = xi^T zeta): O(r(n+m)) instead of O(nm).

TPU mapping: A is tiled (BLOCK_M rows x BLOCK_K cols); each grid step loads
one VMEM tile and a BLOCK_K slice of v, does a (BLOCK_M, BLOCK_K) x
(BLOCK_K,) contraction on the MXU (expressed as a matmul against a column
vector), and accumulates into the output block across the K grid dimension
— the revolving-accumulator pattern (out_spec constant in k) that keeps the
partial sum resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 512
BLOCK_K = 512


def _ceil_to(v: int, b: int) -> int:
    return -(-v // b) * b


def _matvec_kernel(a_ref, v_ref, o_ref):
    """o[i-block] += A[i-block, k-block] @ v[k-block], accumulated over k."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]                           # (bm, bk)
    v = v_ref[...]                           # (bk, 1)
    o_ref[...] += jnp.dot(a, v, preferred_element_type=jnp.float32)


@jax.jit
def matvec(a, v):
    """a @ v with a (m, k), v (k,) -> (m,), Pallas-tiled."""
    m, k = a.shape
    bm = min(BLOCK_M, _ceil_to(m, 8))
    bk = min(BLOCK_K, _ceil_to(k, 8))
    m_pad, k_pad = _ceil_to(m, bm), _ceil_to(k, bk)
    ap = jnp.pad(a.astype(jnp.float32), ((0, m_pad - m), (0, k_pad - k)))
    vp = jnp.pad(v.astype(jnp.float32), (0, k_pad - k))[:, None]
    out = pl.pallas_call(
        _matvec_kernel,
        grid=(m_pad // bm, k_pad // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bk, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        interpret=True,
    )(ap, vp)
    return out[:m, 0]


@jax.jit
def matvec_t(a, v):
    """a.T @ v with a (m, k), v (m,) -> (k,).

    Implemented by swapping the roles of the two grid axes so the reduction
    runs over row-blocks of A while the output block (a column-block of
    A.T v) stays resident — no materialised transpose.
    """
    m, k = a.shape
    bm = min(BLOCK_M, _ceil_to(m, 8))
    bk = min(BLOCK_K, _ceil_to(k, 8))
    m_pad, k_pad = _ceil_to(m, bm), _ceil_to(k, bk)
    ap = jnp.pad(a.astype(jnp.float32), ((0, m_pad - m), (0, k_pad - k)))
    vp = jnp.pad(v.astype(jnp.float32), (0, m_pad - m))[:, None]

    def kernel(a_ref, v_ref, o_ref):
        j = pl.program_id(1)

        @pl.when(j == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        a_blk = a_ref[...]                   # (bm, bk)
        v_blk = v_ref[...]                   # (bm, 1)
        o_ref[...] += jnp.dot(a_blk.T, v_blk, preferred_element_type=jnp.float32)

    out = pl.pallas_call(
        kernel,
        grid=(k_pad // bk, m_pad // bm),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (j, i)),
            pl.BlockSpec((bm, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bk, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k_pad, 1), jnp.float32),
        interpret=True,
    )(ap, vp)
    return out[:k, 0]


def factored_apply(phi_x, phi_y, v):
    """(Phi_x Phi_y^T) v in O(r(n+m)) — the linear-time Sinkhorn hot path."""
    return matvec(phi_x, matvec_t(phi_y, v))


def factored_apply_t(phi_x, phi_y, u):
    """(Phi_x Phi_y^T)^T u = Phi_y (Phi_x^T u)."""
    return matvec(phi_y, matvec_t(phi_x, u))
