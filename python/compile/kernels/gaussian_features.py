"""L1 Pallas kernel: Lemma-1 positive Gaussian feature matrix.

Computes Phi[i, j] = (2q)^{d/4} exp(-2/eps ||x_i - u_j||^2 + ||u_j||^2/(eps q))
                     / sqrt(r)
for x in R^{n x d} (points) and u in R^{r x d} (anchors drawn from
N(0, q*eps/4 I_d)).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the (n, r) output is tiled
into (BLOCK_N, BLOCK_R) VMEM blocks; the squared distance is expanded as
||x||^2 - 2 x.u + ||u||^2 so the inner contraction `x_block @ u_block.T` is
a (BLOCK_N, d) x (d, BLOCK_R) matmul that maps onto the MXU, while the two
norm vectors are cheap VPU reductions. This is the TPU analogue of the
threadblock-shared-memory tiling a CUDA implementation would use.

NOTE: `interpret=True` everywhere — the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU numbers are estimated analytically in
DESIGN.md §8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block shape chosen so x-block (256 x d), u-block (256 x d), the (256, 256)
# f32 output tile and two norm vectors stay ~1.1 MB for d<=64 — comfortably
# inside 16 MB VMEM with double-buffering headroom.
BLOCK_N = 256
BLOCK_R = 256


def _features_kernel(x_ref, u_ref, o_ref, *, eps: float, q: float, d: int, r: int):
    """One (BLOCK_N, BLOCK_R) tile of the feature matrix."""
    x = x_ref[...]                         # (bn, d)
    u = u_ref[...]                         # (br, d)
    dot = jnp.dot(x, u.T, preferred_element_type=jnp.float32)   # MXU
    xx = jnp.sum(x * x, axis=1)[:, None]
    uu = jnp.sum(u * u, axis=1)[None, :]
    sq = xx - 2.0 * dot + uu
    log_phi = (d / 4.0) * jnp.log(2.0 * q) \
        - (2.0 / eps) * sq + uu / (eps * q) \
        - 0.5 * jnp.log(float(r))
    # Same clamp window as ref.LOG_FLOOR/LOG_CEIL: keeps positivity-by-
    # construction true in f32 (exp(-80) is a normal float, exact 0 is not)
    # and guards the anchor-norm term against overflow at extreme (eps, q).
    o_ref[...] = jnp.exp(jnp.clip(log_phi, -80.0, 80.0))


def _ceil_to(v: int, b: int) -> int:
    return -(-v // b) * b


@functools.partial(jax.jit, static_argnames=("eps", "q"))
def gaussian_features(x, u, *, eps: float, q: float):
    """Tiled positive feature matrix, shape (n, r), all entries > 0.

    Pads n and r up to block multiples, runs the Pallas grid, slices back.
    """
    n, d = x.shape
    r = u.shape[0]
    bn = min(BLOCK_N, _ceil_to(n, 8))
    br = min(BLOCK_R, _ceil_to(r, 8))
    n_pad = _ceil_to(n, bn)
    r_pad = _ceil_to(r, br)
    # Zero-padding x rows is harmless (rows are sliced away); padding u rows
    # with zeros would inject exp(+uu/(eps q)) = 1 columns — also sliced away.
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0)))
    up = jnp.pad(u, ((0, r_pad - r), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_features_kernel, eps=eps, q=q, d=d, r=r),
        grid=(n_pad // bn, r_pad // br),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((br, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, br), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pad, r_pad), jnp.float32),
        interpret=True,
    )(xp.astype(jnp.float32), up.astype(jnp.float32))
    return out[:n, :r]
