"""L2: the paper's compute graphs in JAX, calling the L1 Pallas kernels.

Build-time only — these functions are AOT-lowered by `aot.py` into HLO text
that the Rust runtime loads; Python never runs on the request path.

Graphs provided:
  * `features_graph`        — Lemma-1 positive feature matrix (Pallas inside).
  * `rf_sinkhorn_graph`     — fixed-iteration factored Sinkhorn (Alg. 1 with
                              K = Phi_x Phi_y^T), O(r(n+m)) per iteration.
  * `dense_sinkhorn_graph`  — dense baseline (`Sin`), O(nm) per iteration.
  * `rf_divergence_graph`   — Eq. (2) Sinkhorn divergence, three factored
                              transport problems sharing feature matrices.
  * `critic_grad_graph`     — Prop-3.2 analytic gradient of W w.r.t. the
                              feature matrices (no unrolling through the
                              Sinkhorn loop), for the adversarial-kernel GAN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import factored_apply as fa
from .kernels import gaussian_features as gf

# The Sinkhorn loop body is two factored applies. We keep the loop as a
# lax.scan so the lowered HLO is a compact While op instead of an unrolled
# chain of `iters` matmuls (smaller artifact, same compute).


def _rf_sinkhorn_scan(phi_x, phi_y, a, b, iters: int, use_pallas: bool):
    apply = fa.factored_apply if use_pallas else (lambda px, py, v: px @ (py.T @ v))
    apply_t = fa.factored_apply_t if use_pallas else (lambda px, py, u: py @ (px.T @ u))

    def body(carry, _):
        u, v = carry
        v = b / apply_t(phi_x, phi_y, u)
        u = a / apply(phi_x, phi_y, v)
        return (u, v), None

    u0 = jnp.ones_like(a)
    v0 = jnp.ones_like(b)
    (u, v), _ = jax.lax.scan(body, (u0, v0), None, length=iters)
    return u, v


def features_graph(x, u, *, eps: float, q: float):
    """Positive feature matrix (n, r) — L1 Pallas kernel, jit boundary."""
    return gf.gaussian_features(x, u, eps=eps, q=q)


def rf_sinkhorn_graph(phi_x, phi_y, a, b, *, eps: float, iters: int,
                      use_pallas: bool = True):
    """Returns (u, v, w_hat) with w_hat = eps(a^T log u + b^T log v)."""
    u, v = _rf_sinkhorn_scan(phi_x, phi_y, a, b, iters, use_pallas)
    w_hat = eps * (jnp.sum(a * jnp.log(u)) + jnp.sum(b * jnp.log(v)))
    return u, v, w_hat


def dense_sinkhorn_graph(kmat, a, b, *, eps: float, iters: int):
    """Dense Alg. 1 baseline over an explicit kernel matrix."""

    def body(carry, _):
        u, v = carry
        v = b / (kmat.T @ u)
        u = a / (kmat @ v)
        return (u, v), None

    u0 = jnp.ones_like(a)
    v0 = jnp.ones_like(b)
    (u, v), _ = jax.lax.scan(body, (u0, v0), None, length=iters)
    w_hat = eps * (jnp.sum(a * jnp.log(u)) + jnp.sum(b * jnp.log(v)))
    return u, v, w_hat


def rf_divergence_graph(x, y, anchors, a, b, *, eps: float, q: float,
                        iters: int):
    """Eq. (2): W(mu,nu) - (W(mu,mu) + W(nu,nu))/2, all factored.

    Feature matrices are computed once (Pallas) and shared by the three
    transport problems — the xy, xx and yy kernels reuse Phi_x and Phi_y.
    """
    phi_x = gf.gaussian_features(x, anchors, eps=eps, q=q)
    phi_y = gf.gaussian_features(y, anchors, eps=eps, q=q)
    _, _, w_xy = rf_sinkhorn_graph(phi_x, phi_y, a, b, eps=eps, iters=iters,
                                   use_pallas=False)
    _, _, w_xx = rf_sinkhorn_graph(phi_x, phi_x, a, a, eps=eps, iters=iters,
                                   use_pallas=False)
    _, _, w_yy = rf_sinkhorn_graph(phi_y, phi_y, b, b, eps=eps, iters=iters,
                                   use_pallas=False)
    return w_xy - 0.5 * (w_xx + w_yy)


def critic_grad_graph(phi_x, phi_y, a, b, *, eps: float, iters: int):
    """Prop-3.2 gradient of W_{eps,c_theta} w.r.t. the feature matrices.

    nabla_K G = -eps * u v^T evaluated at the Sinkhorn-output scalings,
    chained onto K = Phi_x Phi_y^T:
        dW/dPhi_x[i, k] = -eps * u_i * (Phi_y^T v)_k
        dW/dPhi_y[j, k] = -eps * v_j * (Phi_x^T u)_k
    No differentiation *through* the loop: duals are treated as constants
    (envelope theorem), which is the paper's memory-efficient strategy.
    """
    u, v = _rf_sinkhorn_scan(phi_x, phi_y, a, b, iters, use_pallas=False)
    u = jax.lax.stop_gradient(u)
    v = jax.lax.stop_gradient(v)
    ky_v = phi_y.T @ v                      # (r,)
    kx_u = phi_x.T @ u                      # (r,)
    g_phi_x = -eps * u[:, None] * ky_v[None, :]
    g_phi_y = -eps * v[:, None] * kx_u[None, :]
    w_hat = eps * (jnp.sum(a * jnp.log(u)) + jnp.sum(b * jnp.log(v)))
    return g_phi_x, g_phi_y, w_hat


def marginal_error_graph(phi_x, phi_y, b, u, v):
    """L1 column-marginal violation for the factored kernel."""
    return jnp.sum(jnp.abs(v * (phi_y @ (phi_x.T @ u)) - b))
