"""AOT pipeline: lower the L2 graphs to HLO **text** artifacts.

HLO text (not `.serialize()`d HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts

Writes one `<name>.hlo.txt` per graph variant plus `manifest.json`
describing parameter shapes/dtypes, output arity and the static constants
(eps, q, iters, seed) baked into each artifact. The Rust
`runtime::Registry` consumes the manifest.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def build_artifacts(out_dir: str, *, quick: bool = False) -> dict:
    """Lower every graph variant; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "xla_extension": "0.5.1", "entries": {}}

    # Variant grid. Kept deliberately small: CPU PJRT compile time per
    # artifact is seconds; the native Rust path covers arbitrary sizes.
    eps_default = 0.5
    radius = 4.0

    def emit(name, lowered, params, outputs, consts):
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "params": params,
            "outputs": outputs,
            "constants": consts,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"  wrote {name}.hlo.txt ({len(text)} chars)")

    sizes = [(256, 64, 2)] if quick else [(256, 64, 2), (1024, 256, 2), (1024, 256, 28)]
    for (n, r, d) in sizes:
        q = float(ref.gaussian_q(eps_default, radius, d))
        name = f"rf_features_n{n}_r{r}_d{d}"
        lowered = jax.jit(
            lambda x, u: model.features_graph(x, u, eps=eps_default, q=q)
        ).lower(_spec(n, d), _spec(r, d))
        emit(name, lowered,
             params=[["x", [n, d]], ["u", [r, d]]],
             outputs=[["phi", [n, r]]],
             consts={"eps": eps_default, "q": q, "radius": radius})

    iters = 20 if quick else 100
    sk_sizes = [(256, 64)] if quick else [(256, 64), (1024, 256), (4096, 512)]
    for (n, r) in sk_sizes:
        name = f"rf_sinkhorn_n{n}_r{r}_it{iters}"
        lowered = jax.jit(
            lambda px, py, a, b: model.rf_sinkhorn_graph(
                px, py, a, b, eps=eps_default, iters=iters, use_pallas=False)
        ).lower(_spec(n, r), _spec(n, r), _spec(n), _spec(n))
        emit(name, lowered,
             params=[["phi_x", [n, r]], ["phi_y", [n, r]], ["a", [n]], ["b", [n]]],
             outputs=[["u", [n]], ["v", [n]], ["w_hat", []]],
             consts={"eps": eps_default, "iters": iters})

    dn = 256 if quick else 1024
    name = f"dense_sinkhorn_n{dn}_it{iters}"
    lowered = jax.jit(
        lambda k, a, b: model.dense_sinkhorn_graph(
            k, a, b, eps=eps_default, iters=iters)
    ).lower(_spec(dn, dn), _spec(dn), _spec(dn))
    emit(name, lowered,
         params=[["kmat", [dn, dn]], ["a", [dn]], ["b", [dn]]],
         outputs=[["u", [dn]], ["v", [dn]], ["w_hat", []]],
         consts={"eps": eps_default, "iters": iters})

    # End-to-end divergence: points in, scalar out (used by the service).
    div_sizes = [(256, 64, 2)] if quick else [(256, 64, 2), (1024, 256, 2)]
    for (n, r, d) in div_sizes:
        q = float(ref.gaussian_q(eps_default, radius, d))
        name = f"rf_divergence_n{n}_r{r}_d{d}_it{iters}"
        lowered = jax.jit(
            lambda x, y, anchors, a, b: model.rf_divergence_graph(
                x, y, anchors, a, b, eps=eps_default, q=q, iters=iters)
        ).lower(_spec(n, d), _spec(n, d), _spec(r, d), _spec(n), _spec(n))
        emit(name, lowered,
             params=[["x", [n, d]], ["y", [n, d]], ["anchors", [r, d]],
                     ["a", [n]], ["b", [n]]],
             outputs=[["divergence", []]],
             consts={"eps": eps_default, "q": q, "iters": iters})

    # GAN critic gradient (Prop 3.2), batch s x features r.
    s, r = (128, 64) if quick else (512, 128)
    gan_iters = 20 if quick else 50
    name = f"critic_grad_s{s}_r{r}_it{gan_iters}"
    lowered = jax.jit(
        lambda px, py, a, b: model.critic_grad_graph(
            px, py, a, b, eps=1.0, iters=gan_iters)
    ).lower(_spec(s, r), _spec(s, r), _spec(s), _spec(s))
    emit(name, lowered,
         params=[["phi_x", [s, r]], ["phi_y", [s, r]], ["a", [s]], ["b", [s]]],
         outputs=[["g_phi_x", [s, r]], ["g_phi_y", [s, r]], ["w_hat", []]],
         consts={"eps": 1.0, "iters": gan_iters})

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(manifest['entries'])} entries)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--quick", action="store_true",
                    help="small variant grid (CI / smoke)")
    args = ap.parse_args()
    print(f"AOT lowering to {args.out} (quick={args.quick})")
    build_artifacts(args.out, quick=args.quick)


if __name__ == "__main__":
    main()
