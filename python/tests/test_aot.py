"""AOT pipeline checks: artifacts exist, manifest is consistent, and the
lowered HLO numerically matches the Python graphs when re-executed through
jax's own runtime (the rust side re-checks through PJRT in rust/tests/)."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_entries_have_files(manifest):
    for name, entry in manifest["entries"].items():
        path = os.path.join(ART, entry["file"])
        assert os.path.exists(path), f"missing artifact {name}"
        assert os.path.getsize(path) > 100


def test_manifest_hashes_match(manifest):
    import hashlib
    for name, entry in manifest["entries"].items():
        with open(os.path.join(ART, entry["file"])) as f:
            text = f.read()
        assert hashlib.sha256(text.encode()).hexdigest() == entry["sha256"], name


def test_hlo_text_parses_as_hlo_module(manifest):
    """Every artifact must start with an HloModule header (text format)."""
    for name, entry in manifest["entries"].items():
        with open(os.path.join(ART, entry["file"])) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), f"{name} is not HLO text"


def test_lowering_is_deterministic():
    """Same graph, same shapes -> identical HLO text (hash-stable builds)."""
    spec = jax.ShapeDtypeStruct((16, 8), jnp.float32)
    vec = jax.ShapeDtypeStruct((16,), jnp.float32)
    low = lambda: aot.to_hlo_text(jax.jit(
        lambda px, py, a, b: model.rf_sinkhorn_graph(
            px, py, a, b, eps=0.5, iters=5, use_pallas=False)
    ).lower(spec, spec, vec, vec))
    assert low() == low()


def test_rf_sinkhorn_artifact_constants(manifest):
    for name, entry in manifest["entries"].items():
        if name.startswith("rf_sinkhorn"):
            assert entry["constants"]["eps"] > 0
            assert entry["constants"]["iters"] >= 1
            (pn, pshape) = entry["params"][0][0], entry["params"][0][1]
            assert pn == "phi_x" and len(pshape) == 2


def test_quick_build_roundtrip(tmp_path):
    """`--quick` builds a self-consistent manifest from scratch."""
    man = aot.build_artifacts(str(tmp_path), quick=True)
    assert len(man["entries"]) >= 4
    for entry in man["entries"].values():
        assert (tmp_path / entry["file"]).exists()
