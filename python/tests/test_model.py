"""L2 correctness: Sinkhorn graphs — factored vs dense equivalence and the
transport invariants the paper's theory relies on."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _problem(seed, n=40, m=36, r=12):
    rng = np.random.default_rng(seed)
    px = rng.uniform(0.2, 1.2, size=(n, r)).astype(np.float32)
    py = rng.uniform(0.2, 1.2, size=(m, r)).astype(np.float32)
    a = rng.uniform(0.5, 1.5, size=n).astype(np.float32)
    b = rng.uniform(0.5, 1.5, size=m).astype(np.float32)
    a /= a.sum()
    b /= b.sum()
    return jnp.array(px), jnp.array(py), jnp.array(a), jnp.array(b)


def test_rf_sinkhorn_matches_dense_on_same_kernel():
    """Alg. 1 over K = Phi_x Phi_y^T must give identical scalings whether
    K is applied densely or through the factors."""
    px, py, a, b = _problem(0)
    kmat = px @ py.T
    u_f, v_f, w_f = model.rf_sinkhorn_graph(px, py, a, b, eps=0.5, iters=60,
                                            use_pallas=False)
    u_d, v_d, w_d = model.dense_sinkhorn_graph(kmat, a, b, eps=0.5, iters=60)
    np.testing.assert_allclose(np.asarray(u_f), np.asarray(u_d), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(v_f), np.asarray(v_d), rtol=1e-4)
    assert abs(float(w_f) - float(w_d)) < 1e-4 * max(1.0, abs(float(w_d)))


def test_rf_sinkhorn_pallas_path_matches_jnp_path():
    px, py, a, b = _problem(1, n=33, m=29, r=8)
    u_p, v_p, w_p = model.rf_sinkhorn_graph(px, py, a, b, eps=0.5, iters=30,
                                            use_pallas=True)
    u_j, v_j, w_j = model.rf_sinkhorn_graph(px, py, a, b, eps=0.5, iters=30,
                                            use_pallas=False)
    np.testing.assert_allclose(np.asarray(u_p), np.asarray(u_j), rtol=2e-4)
    assert abs(float(w_p) - float(w_j)) < 2e-4 * max(1.0, abs(float(w_j)))


@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_sinkhorn_marginals_feasible_after_convergence(seed):
    """After enough iterations diag(u) K diag(v) has marginals (a, b)."""
    px, py, a, b = _problem(seed, n=25, m=25, r=10)
    u, v, _ = model.rf_sinkhorn_graph(px, py, a, b, eps=0.5, iters=300,
                                      use_pallas=False)
    kmat = np.asarray(px @ py.T)
    plan = np.asarray(u)[:, None] * kmat * np.asarray(v)[None, :]
    np.testing.assert_allclose(plan.sum(axis=1), np.asarray(a), atol=1e-4)
    np.testing.assert_allclose(plan.sum(axis=0), np.asarray(b), atol=1e-4)


def test_plan_mass_is_one_after_one_iteration():
    """u^T K v = 1 after even one full Sinkhorn sweep (paper §2)."""
    px, py, a, b = _problem(3)
    u, v, _ = model.rf_sinkhorn_graph(px, py, a, b, eps=0.5, iters=1,
                                      use_pallas=False)
    mass = float(np.asarray(u) @ np.asarray(px @ py.T) @ np.asarray(v))
    assert abs(mass - 1.0) < 1e-5


def test_divergence_of_identical_measures_is_zero():
    rng = np.random.default_rng(5)
    n, r, d = 30, 16, 2
    x = rng.normal(size=(n, d)).astype(np.float32)
    anchors = rng.normal(size=(r, d)).astype(np.float32) * 0.8
    a = np.full(n, 1.0 / n, dtype=np.float32)
    div = float(model.rf_divergence_graph(
        jnp.array(x), jnp.array(x), jnp.array(anchors), jnp.array(a),
        jnp.array(a), eps=0.5, q=2.0, iters=200))
    assert abs(div) < 1e-5


def test_divergence_positive_for_separated_measures():
    rng = np.random.default_rng(6)
    n, r, d = 30, 64, 2
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.normal(size=(n, d)).astype(np.float32) + 3.0
    q = float(ref.gaussian_q(0.5, 5.0, d))
    anchors = (rng.normal(size=(r, d)) * np.sqrt(q * 0.5 / 4)).astype(np.float32)
    a = np.full(n, 1.0 / n, dtype=np.float32)
    div = float(model.rf_divergence_graph(
        jnp.array(x), jnp.array(y), jnp.array(anchors), jnp.array(a),
        jnp.array(a), eps=0.5, q=q, iters=200))
    assert div > 0.1


def test_critic_grad_shapes_and_signs():
    px, py, a, b = _problem(7, n=20, m=20, r=6)
    gx, gy, w = model.critic_grad_graph(px, py, a, b, eps=0.5, iters=50)
    assert gx.shape == px.shape and gy.shape == py.shape
    # Gradient of W wrt K is -eps u v^T < 0 elementwise; chain through
    # positive factors keeps the sign.
    assert (np.asarray(gx) < 0).all()
    assert (np.asarray(gy) < 0).all()


def test_critic_grad_matches_finite_difference():
    """Envelope-theorem gradient vs central finite differences on W(K)."""
    px, py, a, b = _problem(8, n=12, m=12, r=4)
    eps = 0.5
    iters = 800  # near-exact duals so the envelope gradient is accurate

    def w_of(px_, py_):
        _, _, w = model.rf_sinkhorn_graph(px_, py_, a, b, eps=eps,
                                          iters=iters, use_pallas=False)
        return float(w)

    gx, gy, _ = model.critic_grad_graph(px, py, a, b, eps=eps, iters=iters)
    h = 1e-3
    for (i, k) in [(0, 0), (3, 2), (11, 3)]:
        pert = np.zeros_like(np.asarray(px))
        pert[i, k] = h
        num = (w_of(jnp.array(np.asarray(px) + pert), py)
               - w_of(jnp.array(np.asarray(px) - pert), py)) / (2 * h)
        got = float(np.asarray(gx)[i, k])
        assert abs(num - got) < 5e-2 * max(0.1, abs(num)), (num, got)


def test_marginal_error_goes_to_zero():
    px, py, a, b = _problem(9)
    errs = []
    for iters in (1, 10, 100):
        u, v, _ = model.rf_sinkhorn_graph(px, py, a, b, eps=0.5, iters=iters,
                                          use_pallas=False)
        errs.append(float(model.marginal_error_graph(px, py, b, u, v)))
    assert errs[2] < errs[0]
    assert errs[2] < 1e-4
