"""Deeper property sweeps over the L1/L2 stack (hypothesis).

These complement test_kernels.py: instead of fixed tolerances against the
oracle, they assert *structural* invariants of the transport pipeline that
must hold for any shapes/values — positivity, mass conservation, adjoint
identities, scaling equivariances the paper's math relies on.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import factored_apply as fa
from compile.kernels import gaussian_features as gf
from compile.kernels import ref


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# Feature-map structure
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=40),
       st.integers(min_value=1, max_value=40),
       st.integers(min_value=1, max_value=6),
       st.floats(min_value=0.1, max_value=4.0),
       st.floats(min_value=0.5, max_value=6.0),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_features_always_positive_and_finite(n, r, d, eps, q, seed):
    rng = _rng(seed)
    x = (rng.normal(size=(n, d)) * 3).astype(np.float32)
    u = (rng.normal(size=(r, d)) * 2).astype(np.float32)
    phi = np.asarray(gf.gaussian_features(jnp.array(x), jnp.array(u), eps=eps, q=q))
    assert np.isfinite(phi).all()
    assert (phi > 0).all()


@given(st.integers(min_value=2, max_value=30),
       st.integers(min_value=1, max_value=20),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_kernel_symmetry_same_points(n, r, seed):
    """k_theta(x, y) = k_theta(y, x): the factored kernel matrix built from
    one cloud against itself is symmetric."""
    rng = _rng(seed)
    x = rng.normal(size=(n, 2)).astype(np.float32)
    u = rng.normal(size=(r, 2)).astype(np.float32)
    phi = np.asarray(ref.gaussian_features(jnp.array(x), jnp.array(u), 0.5, 2.0))
    k = phi @ phi.T
    np.testing.assert_allclose(k, k.T, rtol=1e-5)
    # Diagonal dominates in the Gibbs sense: k(x,x) >= k(x,y) in expectation
    # is NOT guaranteed per-draw, but PSD is guaranteed structurally.
    eigs = np.linalg.eigvalsh(k.astype(np.float64))
    assert eigs.min() > -1e-5 * max(1.0, eigs.max()), "factored kernel must be PSD"


@given(st.integers(min_value=1, max_value=25),
       st.integers(min_value=1, max_value=25),
       st.integers(min_value=1, max_value=10),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_matvec_linearity(m, k, scale_i, seed):
    """A(av + bw) == a Av + b Aw for the Pallas blocked matvec."""
    rng = _rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    v = rng.normal(size=(k,)).astype(np.float32)
    w = rng.normal(size=(k,)).astype(np.float32)
    alpha = float(scale_i)
    lhs = np.asarray(fa.matvec(jnp.array(a), jnp.array(alpha * v + w)))
    rhs = alpha * np.asarray(fa.matvec(jnp.array(a), jnp.array(v))) + np.asarray(
        fa.matvec(jnp.array(a), jnp.array(w)))
    np.testing.assert_allclose(lhs, rhs, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Sinkhorn invariants
# ---------------------------------------------------------------------------

def _transport_problem(rng, n, m, r):
    px = rng.uniform(0.2, 1.5, size=(n, r)).astype(np.float32)
    py = rng.uniform(0.2, 1.5, size=(m, r)).astype(np.float32)
    a = rng.uniform(0.3, 1.0, size=n).astype(np.float32)
    b = rng.uniform(0.3, 1.0, size=m).astype(np.float32)
    a /= a.sum()
    b /= b.sum()
    return jnp.array(px), jnp.array(py), jnp.array(a), jnp.array(b)


@given(st.integers(min_value=2, max_value=30),
       st.integers(min_value=2, max_value=30),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_scalings_always_positive(n, m, r, seed):
    rng = _rng(seed)
    px, py, a, b = _transport_problem(rng, n, m, r)
    u, v, w = model.rf_sinkhorn_graph(px, py, a, b, eps=0.7, iters=50,
                                      use_pallas=False)
    assert (np.asarray(u) > 0).all(), "positivity by construction"
    assert (np.asarray(v) > 0).all()
    assert np.isfinite(float(w))


@given(st.integers(min_value=3, max_value=20),
       st.floats(min_value=0.5, max_value=4.0),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_kernel_scaling_shifts_objective_by_eps_log_c(n, c, seed):
    """Replacing K by c*K shifts the dual estimate by exactly -eps log c
    (the plan is unchanged: scalings absorb the constant)."""
    rng = _rng(seed)
    px, py, a, b = _transport_problem(rng, n, n, 5)
    eps = 0.5
    _, _, w1 = model.rf_sinkhorn_graph(px, py, a, b, eps=eps, iters=400,
                                       use_pallas=False)
    _, _, w2 = model.rf_sinkhorn_graph(
        px * np.sqrt(c, dtype=np.float32), py * np.sqrt(c, dtype=np.float32),
        a, b, eps=eps, iters=400, use_pallas=False)
    shift = float(w1) - float(w2)
    expect = eps * np.log(c)
    assert abs(shift - expect) < 5e-3 * max(1.0, abs(expect)), (shift, expect)


@given(st.integers(min_value=3, max_value=18),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_permutation_equivariance(n, seed):
    """Permuting the support points permutes the scalings, leaves W."""
    rng = _rng(seed)
    px, py, a, b = _transport_problem(rng, n, n, 4)
    perm = rng.permutation(n)
    u1, v1, w1 = model.rf_sinkhorn_graph(px, py, a, b, eps=0.5, iters=200,
                                         use_pallas=False)
    u2, v2, w2 = model.rf_sinkhorn_graph(
        jnp.array(np.asarray(px)[perm]), py, jnp.array(np.asarray(a)[perm]), b,
        eps=0.5, iters=200, use_pallas=False)
    assert abs(float(w1) - float(w2)) < 1e-4 * max(1.0, abs(float(w1)))
    np.testing.assert_allclose(np.asarray(u2), np.asarray(u1)[perm], rtol=1e-4)


def test_divergence_triangle_of_scales():
    """Wbar grows with separation (sanity of the debiased divergence)."""
    rng = _rng(0)
    n, r, d = 24, 48, 2
    a = np.full(n, 1.0 / n, dtype=np.float32)
    q = float(ref.gaussian_q(0.5, 6.0, d))
    anchors = (rng.normal(size=(r, d)) * np.sqrt(q * 0.5 / 4)).astype(np.float32)
    base = rng.normal(size=(n, d)).astype(np.float32) * 0.3
    prev = -1e-9
    for shift in [0.5, 1.5, 3.0]:
        y = base + np.array([shift, 0.0], dtype=np.float32)
        div = float(model.rf_divergence_graph(
            jnp.array(base), jnp.array(y), jnp.array(anchors), jnp.array(a),
            jnp.array(a), eps=0.5, q=q, iters=300))
        assert div > prev, f"divergence must grow with separation ({div} after {prev})"
        prev = div


# ---------------------------------------------------------------------------
# Gradient structure (Prop 3.2)
# ---------------------------------------------------------------------------

@given(st.integers(min_value=3, max_value=15),
       st.integers(min_value=1, max_value=6),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_critic_grad_nonpositive_elementwise(n, r, seed):
    """-eps u (Phi^T v)^T through positive factors is elementwise <= 0."""
    rng = _rng(seed)
    px, py, a, b = _transport_problem(rng, n, n, r)
    gx, gy, _ = model.critic_grad_graph(px, py, a, b, eps=0.5, iters=100)
    assert (np.asarray(gx) <= 0).all()
    assert (np.asarray(gy) <= 0).all()


def test_critic_grad_scale_with_eps():
    """The envelope gradient scales linearly with eps at fixed duals
    structure (first-order check at two nearby eps)."""
    rng = _rng(3)
    px, py, a, b = _transport_problem(rng, 10, 10, 4)
    gx1, _, _ = model.critic_grad_graph(px, py, a, b, eps=1.0, iters=500)
    gx2, _, _ = model.critic_grad_graph(px, py, a, b, eps=2.0, iters=500)
    # Not exactly 2x (duals change too) but within a factor band.
    ratio = float(np.mean(np.asarray(gx2) / np.asarray(gx1)))
    assert 1.2 < ratio < 3.5, ratio
