"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref.py`).

Hypothesis sweeps shapes (including non-block-multiple and degenerate ones)
and dtypes-adjacent value ranges; every property asserts allclose against
the reference.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import factored_apply as fa
from compile.kernels import gaussian_features as gf
from compile.kernels import ref

RTOL, ATOL = 2e-5, 2e-6


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# Lambert W / q constant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("z,expected", [
    (0.0, 0.0),
    (1.0, 0.5671432904097838),
    (np.e, 1.0),
    (10.0, 1.7455280027406994),
    (1e4, 7.231846038093373),
])
def test_lambert_w0_known_values(z, expected):
    got = float(ref.lambert_w0(jnp.asarray(z, dtype=jnp.float32)))
    assert abs(got - expected) < 5e-5


@given(st.floats(min_value=1e-3, max_value=1e4))
@settings(max_examples=40, deadline=None)
def test_lambert_w0_inverse_property(z):
    w = float(ref.lambert_w0(jnp.asarray(z, dtype=jnp.float32)))
    assert w >= 0.0
    assert abs(w * np.exp(w) - z) < 1e-2 * max(1.0, z)


@given(st.floats(min_value=0.05, max_value=5.0),
       st.floats(min_value=0.5, max_value=8.0),
       st.integers(min_value=1, max_value=64))
@settings(max_examples=30, deadline=None)
def test_gaussian_q_positive_and_monotone_in_radius(eps, radius, dim):
    q = float(ref.gaussian_q(eps, radius, dim))
    q2 = float(ref.gaussian_q(eps, radius * 1.5, dim))
    assert q > 0.0
    assert q2 >= q * 0.999  # q grows with R^2 (W0 grows sublinearly)


# ---------------------------------------------------------------------------
# Gaussian positive features (Lemma 1)
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=70),
       st.integers(min_value=1, max_value=70),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_gaussian_features_matches_ref(n, r, d, seed):
    rng = _rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    u = rng.normal(size=(r, d)).astype(np.float32)
    eps, q = 0.5, 2.0
    got = gf.gaussian_features(jnp.array(x), jnp.array(u), eps=eps, q=q)
    want = ref.gaussian_features(jnp.array(x), jnp.array(u), eps, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


def test_gaussian_features_block_multiple_shapes():
    # Exactly block-aligned shapes exercise the no-padding path.
    rng = _rng(7)
    x = rng.normal(size=(256, 4)).astype(np.float32)
    u = rng.normal(size=(256, 4)).astype(np.float32)
    got = gf.gaussian_features(jnp.array(x), jnp.array(u), eps=1.0, q=3.0)
    want = ref.gaussian_features(jnp.array(x), jnp.array(u), 1.0, 3.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=RTOL, atol=ATOL)


def test_gaussian_features_strictly_positive():
    rng = _rng(11)
    x = rng.normal(size=(33, 3)).astype(np.float32) * 2
    u = rng.normal(size=(17, 3)).astype(np.float32)
    phi = np.asarray(gf.gaussian_features(jnp.array(x), jnp.array(u),
                                          eps=0.3, q=1.7))
    assert (phi > 0).all(), "positivity by construction is the paper's point"


# Tolerances widen as eps shrinks: psi ~ 2(2q)^{d/2} blows up at small
# regularisation (Lemma 1), so the MC ratio variance grows — exactly the
# small-eps failure regime Figures 1/3/5 document.
@pytest.mark.parametrize("eps,tol", [(0.1, 1.0), (0.5, 0.3), (1.0, 0.25), (2.0, 0.2)])
def test_feature_kernel_converges_to_gibbs(eps, tol):
    """Prop 3.1 shape: with many features the ratio k_theta/k -> 1."""
    rng = _rng(13)
    d, r, radius = 2, 8000, 2.0
    q = float(ref.gaussian_q(eps, radius, d))
    u = (rng.normal(size=(r, d)) * np.sqrt(q * eps / 4.0)).astype(np.float32)
    x = rng.uniform(-1, 1, size=(6, d)).astype(np.float32)
    y = rng.uniform(-1, 1, size=(6, d)).astype(np.float32)
    px = np.asarray(ref.gaussian_features(jnp.array(x), jnp.array(u), eps, q))
    py = np.asarray(ref.gaussian_features(jnp.array(y), jnp.array(u), eps, q))
    k_theta = px @ py.T
    k_true = np.asarray(ref.gibbs_kernel(jnp.array(x), jnp.array(y), eps))
    ratio = k_theta / k_true
    assert np.abs(ratio - 1.0).max() < tol
    assert abs(ratio.mean() - 1.0) < tol / 2


# ---------------------------------------------------------------------------
# Arc-cosine features (Lemma 3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [0, 1])
def test_arccos_features_positive_kernel_floor(s):
    rng = _rng(17)
    x = rng.normal(size=(20, 3)).astype(np.float32)
    u = rng.normal(size=(50, 3)).astype(np.float32)
    kappa = 0.1
    phi = np.asarray(ref.arccos_features(jnp.array(x), jnp.array(u), s, kappa, 1.5))
    k = phi @ phi.T
    assert (k >= kappa - 1e-6).all(), "kernel must be bounded below by kappa"


# ---------------------------------------------------------------------------
# Blocked matvec / transpose-matvec / factored apply
# ---------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=130),
       st.integers(min_value=1, max_value=130),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_matvec_matches_ref(m, k, seed):
    rng = _rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    v = rng.normal(size=(k,)).astype(np.float32)
    got = np.asarray(fa.matvec(jnp.array(a), jnp.array(v)))
    np.testing.assert_allclose(got, a @ v, rtol=1e-4, atol=1e-4)


@given(st.integers(min_value=1, max_value=130),
       st.integers(min_value=1, max_value=130),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_matvec_t_matches_ref(m, k, seed):
    rng = _rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    v = rng.normal(size=(m,)).astype(np.float32)
    got = np.asarray(fa.matvec_t(jnp.array(a), jnp.array(v)))
    np.testing.assert_allclose(got, a.T @ v, rtol=1e-4, atol=1e-4)


@given(st.integers(min_value=2, max_value=60),
       st.integers(min_value=2, max_value=60),
       st.integers(min_value=1, max_value=40),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_factored_apply_equals_dense_kernel_apply(n, m, r, seed):
    """The linchpin identity: (Phi_x Phi_y^T) v via factors == dense."""
    rng = _rng(seed)
    px = rng.uniform(0.1, 1.0, size=(n, r)).astype(np.float32)
    py = rng.uniform(0.1, 1.0, size=(m, r)).astype(np.float32)
    v = rng.normal(size=(m,)).astype(np.float32)
    got = np.asarray(fa.factored_apply(jnp.array(px), jnp.array(py), jnp.array(v)))
    want = (px @ py.T) @ v
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_factored_apply_t_is_adjoint():
    rng = _rng(23)
    n, m, r = 31, 45, 9
    px = rng.uniform(0.1, 1.0, size=(n, r)).astype(np.float32)
    py = rng.uniform(0.1, 1.0, size=(m, r)).astype(np.float32)
    u = rng.normal(size=(n,)).astype(np.float32)
    v = rng.normal(size=(m,)).astype(np.float32)
    lhs = float(np.dot(u, np.asarray(fa.factored_apply(jnp.array(px), jnp.array(py), jnp.array(v)))))
    rhs = float(np.dot(v, np.asarray(fa.factored_apply_t(jnp.array(px), jnp.array(py), jnp.array(u)))))
    assert abs(lhs - rhs) < 1e-3 * max(1.0, abs(lhs))
