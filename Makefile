# linear-sinkhorn build entry points.
#
# `make check` is the mechanical gate: build, tests, warning-clean rustdoc,
# formatting. `make artifacts` is the only step that runs python — it AOT-
# lowers the L1/L2 graphs to HLO-text artifacts the Rust runtime loads.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: all build test test-scalar shard-fault shard-soak stream doc doc-test examples fmt fmt-check clippy check artifacts perf bench-smoke clean

all: build

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# The redesigned rustdoc snippets (OtProblem quick tour) are compiled
# doctests; CI gates them in the `examples-doctests` job.
doc-test:
	$(CARGO) test --doc

# Build every example — the migrated examples are part of the public
# surface and CI builds them on every push.
examples:
	$(CARGO) build --examples

# The SIMD core's portable-fallback arm: the full suite with the env
# override pinning scalar kernels (CI runs this as its own job, so both
# dispatch arms stay green).
test-scalar:
	LINEAR_SINKHORN_SIMD=scalar $(CARGO) test -q

# The shard fault-injection suite under both SIMD dispatch arms: the
# sharded scatter/gather solve must stay bitwise identical to the local
# fused solve per arm, under every survivable fault schedule (CI runs
# this as the `shard-fault` job).
shard-fault:
	$(CARGO) test -q --test shard_fault_injection --test wire_format
	LINEAR_SINKHORN_SIMD=scalar $(CARGO) test -q --test shard_fault_injection --test wire_format

# The chaos soak: multi-round kill/flap/rejoin storms, straggler hedging,
# partition windows, overload shed, mid-flight drain and mixed-version
# rejoiners — every answered pair bitwise identical to the local fused
# solve, under both SIMD dispatch arms (CI runs this as the `shard-soak`
# job).
shard-soak:
	$(CARGO) test -q --test shard_chaos_soak
	LINEAR_SINKHORN_SIMD=scalar $(CARGO) test -q --test shard_chaos_soak

# The streaming-session equivalence suite under both SIMD dispatch arms:
# incremental sessions must match from-scratch solves, zero-delta updates
# and thread counts must be bitwise invisible, and sharded session
# serving must answer with the local path's bits (CI runs this as the
# `stream` job).
stream:
	$(CARGO) test -q --test streaming_equivalence
	LINEAR_SINKHORN_SIMD=scalar $(CARGO) test -q --test streaming_equivalence

# Rustdoc with warnings denied: broken intra-doc links fail the build, so
# documentation drift (e.g. a citation of a section that no longer exists)
# is caught here rather than in review.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

# Fatal: the tree is kept rustfmt-conformant (also enforced by CI's
# `cargo fmt --check`); run `make fmt` after editing.
fmt-check:
	$(CARGO) fmt --check

fmt:
	$(CARGO) fmt

# Fatal like CI's clippy job: all targets, warnings denied.
clippy:
	$(CARGO) clippy --all-targets -- -D warnings

check: build test shard-fault shard-soak stream doc doc-test examples fmt-check clippy
	@echo "check: OK"

# AOT-lower the Pallas/JAX graphs to HLO text + manifest. The binary never
# runs python; this is the single build-time python invocation.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

# Parallel-scaling numbers for EXPERIMENTS.md §Parallel scaling.
perf:
	$(CARGO) bench --bench parallel_scaling

# CI's quick bench pass, locally: small sizes, tables appended to
# BENCH_ci.json (JSON lines, one object per table; every table carries a
# "cpu" field naming the SIMD dispatch arm).
bench-smoke:
	BENCH_SMOKE=1 BENCH_JSON=BENCH_ci.json $(CARGO) bench --bench simd_kernels
	BENCH_SMOKE=1 BENCH_JSON=BENCH_ci.json $(CARGO) bench --bench parallel_scaling
	BENCH_SMOKE=1 BENCH_JSON=BENCH_ci.json $(CARGO) bench --bench coordinator_throughput
	BENCH_SMOKE=1 BENCH_JSON=BENCH_ci.json $(CARGO) bench --bench anneal_iterations
	BENCH_SMOKE=1 BENCH_JSON=BENCH_ci.json $(CARGO) bench --bench tradeoff_headtohead
	BENCH_SMOKE=1 BENCH_JSON=BENCH_ci.json $(CARGO) bench --bench streaming_updates

clean:
	$(CARGO) clean
