# linear-sinkhorn build entry points.
#
# `make check` is the mechanical gate: build, tests, warning-clean rustdoc,
# formatting. `make artifacts` is the only step that runs python — it AOT-
# lowers the L1/L2 graphs to HLO-text artifacts the Rust runtime loads.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: all build test doc fmt fmt-check check artifacts perf clean

all: build

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Rustdoc with warnings denied: broken intra-doc links fail the build, so
# documentation drift (e.g. a citation of a section that no longer exists)
# is caught here rather than in review.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

# Fatal: the tree is kept rustfmt-conformant (also enforced by CI's
# `cargo fmt --check`); run `make fmt` after editing.
fmt-check:
	$(CARGO) fmt --check

fmt:
	$(CARGO) fmt

check: build test doc fmt-check
	@echo "check: OK"

# AOT-lower the Pallas/JAX graphs to HLO text + manifest. The binary never
# runs python; this is the single build-time python invocation.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

# Parallel-scaling numbers for EXPERIMENTS.md §Parallel scaling.
perf:
	$(CARGO) bench --bench parallel_scaling

clean:
	$(CARGO) clean
