//! Eps-annealing schedules and the symmetric fixed-point iteration.
//!
//! **Annealing.** Sinkhorn's contraction factor degrades as eps shrinks
//! (iteration complexity scales like 1/eps — Altschuler–Weed–Rigollet,
//! arXiv:1705.09634), so solving at a small target eps from cold duals is
//! the expensive way in. [`EpsSchedule`] describes the standard fix: a
//! geometric ladder of regularisations from roughly the squared support
//! diameter (where Sinkhorn converges in a handful of iterations) down to
//! the target, each rung warm-started from the previous rung's dual
//! potential. The schedule itself is a pure function of two f64 scalars,
//! so every executor — local, batched, or a remote shard worker that
//! received the plan over the wire — derives bit-identical rungs.
//!
//! **Warm starts.** The currency between rungs (and between the plain and
//! log-domain solvers on escalation) is the f64 row dual `alpha` of the
//! a⊗b-relative formulation used by the log-domain solver: the plain
//! solver's scaling is `u_i = a_i exp(alpha_i / eps)`. Both solvers update
//! the column dual first, so only `alpha` needs to travel. [`WarmSolve`]
//! carries a solution together with its final `alpha`.
//!
//! **Symmetric self-solves.** The xx/yy terms of the Sinkhorn divergence
//! are transport problems of a measure against itself; their fixed point
//! is symmetric (u = v up to an irrelevant constant), so a dedicated
//! damped iteration on a *single* dual vector
//! (`f ← 0.5 (f + T(f))`, the classic averaged update) halves the work
//! per iteration and converges monotonically where the alternating
//! two-sided update can oscillate. [`sinkhorn_symmetric`] runs it in f32
//! scalings (`u ← sqrt(u ∘ a / Ku)`, the same update in exp form),
//! [`sinkhorn_symmetric_log`] in f64 duals, and
//! [`sinkhorn_symmetric_stabilized`] glues them with the same
//! escalate-on-divergence contract as
//! [`sinkhorn_stabilized`](super::sinkhorn_stabilized).

use crate::config::SinkhornConfig;
use crate::error::{Error, Result};
use crate::kernels::{KernelOp, LogKernelOp};

use super::logdomain::first_non_finite;
use super::{first_bad, objective, SinkhornSolution};

/// Hard cap on schedule length: a decay pathologically close to 1 must
/// not turn one solve into thousands. 64 geometric rungs at decay 0.5
/// span 19 orders of magnitude — far beyond any representable regime.
pub const MAX_RUNGS: usize = 64;

/// A geometric eps-annealing schedule: start at `eps_start` and multiply
/// by `decay` until the target regularisation is reached.
///
/// The target eps is *not* stored here — it lives in the
/// [`Plan`](crate::api::Plan) / [`SinkhornConfig`] next to this schedule,
/// so the two can never disagree. [`EpsSchedule::rungs`] materialises the
/// ladder for a given target; the last rung is always exactly the target.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpsSchedule {
    /// First (largest) regularisation. The planner picks `4 R^2` where
    /// `R` is the larger support radius — the scale at which the Gibbs
    /// kernel is nearly flat and Sinkhorn converges almost immediately.
    pub eps_start: f64,
    /// Geometric damping factor in (0, 1); 0.5 halves eps per rung.
    pub decay: f64,
}

impl EpsSchedule {
    /// Validating constructor.
    pub fn new(eps_start: f64, decay: f64) -> Result<Self> {
        if !(eps_start.is_finite() && eps_start > 0.0) {
            return Err(Error::Config(format!(
                "eps schedule: eps_start must be positive and finite, got {eps_start}"
            )));
        }
        if !(decay.is_finite() && decay > 0.0 && decay < 1.0) {
            return Err(Error::Config(format!(
                "eps schedule: decay must lie in (0, 1), got {decay}"
            )));
        }
        Ok(EpsSchedule { eps_start, decay })
    }

    /// The eps ladder down to (and ending exactly at) `target`.
    ///
    /// Pure f64 arithmetic on two scalars: every host that holds the same
    /// schedule and target derives the same rungs bit for bit, which is
    /// what lets sharded workers anneal identically to the local solve.
    /// Degenerate inputs (`eps_start <= target`) yield `[target]` — a
    /// single-rung schedule is exactly the direct solve.
    pub fn rungs(&self, target: f64) -> Vec<f64> {
        let mut out = Vec::new();
        let mut e = self.eps_start;
        while e > target && out.len() < MAX_RUNGS - 1 {
            out.push(e);
            e *= self.decay;
        }
        out.push(target);
        out
    }
}

/// A solve result carrying the f64 dual it ended on, so the next rung of
/// an annealing schedule (or a log-domain escalation) can warm-start.
#[derive(Clone, Debug)]
pub struct WarmSolve {
    /// The solution, exactly as the underlying solver reports it.
    pub solution: SinkhornSolution,
    /// Whether the log-domain path produced it (plain-solver escalation).
    pub escalated: bool,
    /// Final a⊗b-relative row dual: `u_i = a_i exp(alpha_i / eps)`.
    pub alpha: Vec<f64>,
}

/// f32 scalings from a warm dual: `u_i = a_i exp(alpha_i / eps)` — the
/// same expression the log-domain solver uses to report its scalings, so
/// plain rungs warm-started from log rungs round-trip consistently.
pub(crate) fn warm_scalings(eps: f64, a: &[f32], alpha: &[f64]) -> Vec<f32> {
    alpha.iter().zip(a).map(|(&al, &ai)| (ai as f64 * (al / eps).exp()) as f32).collect()
}

/// The inverse map, used to snapshot a plain solver's state as a warm
/// dual: `alpha_i = eps (ln u_i - ln a_i)`. Callers only invoke this on
/// scalings that passed the finite-positive check.
pub(crate) fn alpha_from_scalings(eps: f64, a: &[f32], u: &[f32]) -> Vec<f64> {
    u.iter().zip(a).map(|(&ui, &ai)| eps * ((ui as f64).ln() - (ai as f64).ln())).collect()
}

/// Validate a square self-transport setup.
fn check_symmetric<K: ?Sized>(n: usize, m: usize, a: &[f32], _k: &K) -> Result<()> {
    if n != m {
        return Err(Error::Shape(format!(
            "symmetric sinkhorn: kernel {n}x{m} is not square"
        )));
    }
    if a.len() != n {
        return Err(Error::Shape(format!(
            "symmetric sinkhorn: kernel {n}x{n} vs a[{}]",
            a.len()
        )));
    }
    Ok(())
}

/// Symmetric fixed-point Sinkhorn on one dual vector, in f32 scalings.
///
/// For a self-transport problem (square kernel, both marginals `a`) the
/// damped update `u <- sqrt(u ∘ a / Ku)` is the exp-form of the averaged
/// dual iteration `f <- 0.5 (f + T(f))`; one kernel apply per iteration
/// instead of two, one dual vector instead of two. The objective is
/// Eq. (6) with v = u, directly comparable to a two-sided self-solve
/// (whose fixed point differs from the symmetric one only by a constant
/// factor that cancels in the objective).
pub fn sinkhorn_symmetric<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    cfg: &SinkhornConfig,
) -> Result<SinkhornSolution> {
    sinkhorn_symmetric_warm(kernel, a, cfg, None).map(|ws| ws.solution)
}

/// [`sinkhorn_symmetric`] with an optional warm dual, reporting the final
/// dual for annealing chains. Diverged solves error like the plain
/// solver; use [`sinkhorn_symmetric_stabilized_warm`] for escalation.
pub fn sinkhorn_symmetric_warm<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    cfg: &SinkhornConfig,
    warm: Option<&[f64]>,
) -> Result<WarmSolve> {
    match symmetric_core(kernel, a, cfg, warm) {
        SymOutcome::Done(ws) => Ok(ws),
        SymOutcome::Diverged { error, .. } | SymOutcome::Failed(error) => Err(error),
    }
}

/// Outcome of the plain symmetric core: either a finished solve, a
/// divergence carrying the last known-good dual (escalation warm start),
/// or a hard setup error.
enum SymOutcome {
    Done(WarmSolve),
    Diverged { error: Error, alpha: Vec<f64> },
    Failed(Error),
}

fn symmetric_core<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    cfg: &SinkhornConfig,
    warm: Option<&[f64]>,
) -> SymOutcome {
    let (n, m) = (kernel.rows(), kernel.cols());
    if let Err(e) = check_symmetric(n, m, a, kernel) {
        return SymOutcome::Failed(e);
    }
    if let Some(w) = warm {
        if w.len() != n {
            return SymOutcome::Failed(Error::Shape(format!(
                "symmetric sinkhorn: warm dual [{}] vs kernel {n}x{n}",
                w.len()
            )));
        }
    }
    let eps = cfg.epsilon;
    let mut u: Vec<f32> = match warm {
        Some(w) => warm_scalings(eps, a, w),
        None => vec![1.0f32; n],
    };
    let mut ku = vec![0.0f32; n];
    // Last dual that passed a finite-positive check (init: the warm dual
    // itself, or the u = 1 dual), handed to the log-domain escalation.
    let mut last_good: Vec<f64> = match warm {
        Some(w) => w.to_vec(),
        None => alpha_from_scalings(eps, a, &u),
    };

    let check_every = cfg.check_every.max(1);
    let mut iter = 0;
    let mut marginal = f64::INFINITY;
    let mut converged = false;

    while iter < cfg.max_iters {
        // u <- sqrt(u ∘ a / Ku): the 0.5-damped symmetric update.
        kernel.apply_into(&u, &mut ku);
        for i in 0..n {
            u[i] = (u[i] * (a[i] / ku[i])).sqrt();
        }
        iter += 1;

        if iter % check_every == 0 || iter == cfg.max_iters {
            if let Some(bad) = first_bad(&u) {
                return SymOutcome::Diverged {
                    error: Error::SinkhornDiverged {
                        iter,
                        reason: format!(
                            "non-finite or non-positive scaling ({bad}) in symmetric \
                             sinkhorn; kernel {} lost positivity or eps is too small for f32",
                            kernel.label()
                        ),
                    },
                    alpha: last_good,
                };
            }
            last_good = alpha_from_scalings(eps, a, &u);
            // Row marginal of P = diag(u) K diag(u) against a.
            kernel.apply_into(&u, &mut ku);
            marginal = (0..n).map(|i| ((u[i] * ku[i] - a[i]) as f64).abs()).sum();
            if marginal < cfg.tol {
                converged = true;
                break;
            }
        }
    }

    let sol = SinkhornSolution {
        objective: objective(eps, a, a, &u, &u) - eps * kernel.log_scale(),
        v: u.clone(),
        u,
        iterations: iter,
        marginal_error: marginal,
        converged,
    };
    SymOutcome::Done(WarmSolve { solution: sol, escalated: false, alpha: last_good })
}

/// Symmetric fixed-point iteration in the log domain: the averaged dual
/// update `f <- 0.5 (f + T(f))` with
/// `T(f)_i = -eps lse_j(log K_ij + f_j/eps + log a_j)`, matrix-free over
/// any [`LogKernelOp`] — the small-eps-safe arm of the symmetric solve.
pub fn sinkhorn_symmetric_log<K: LogKernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    cfg: &SinkhornConfig,
) -> Result<SinkhornSolution> {
    sinkhorn_symmetric_log_warm(kernel, a, cfg, None).map(|ws| ws.solution)
}

/// [`sinkhorn_symmetric_log`] with an optional warm dual, reporting the
/// final dual for annealing chains.
pub fn sinkhorn_symmetric_log_warm<K: LogKernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    cfg: &SinkhornConfig,
    warm: Option<&[f64]>,
) -> Result<WarmSolve> {
    let (n, m) = kernel.shape();
    check_symmetric(n, m, a, kernel)?;
    if let Some(w) = warm {
        if w.len() != n {
            return Err(Error::Shape(format!(
                "symmetric sinkhorn: warm dual [{}] vs kernel {n}x{n}",
                w.len()
            )));
        }
    }
    let eps = cfg.epsilon;
    let log_a: Vec<f64> = a.iter().map(|&x| (x as f64).ln()).collect();
    let mut f: Vec<f64> = match warm {
        Some(w) => w.to_vec(),
        None => vec![0.0f64; n],
    };
    let mut t_in = vec![0.0f64; n];
    let mut t_out = vec![0.0f64; n];

    let check_every = cfg.check_every.max(1);
    let mut iter = 0;
    let mut marginal = f64::INFINITY;
    let mut converged = false;

    while iter < cfg.max_iters {
        // f <- 0.5 (f + T(f)).
        for i in 0..n {
            t_in[i] = f[i] / eps + log_a[i];
        }
        kernel.apply_log(&t_in, &mut t_out);
        for i in 0..n {
            f[i] = 0.5 * (f[i] - eps * t_out[i]);
        }
        iter += 1;

        if iter % check_every == 0 || iter == cfg.max_iters {
            if let Some(bad) = first_non_finite(&f) {
                return Err(Error::SinkhornDiverged {
                    iter,
                    reason: format!(
                        "non-finite dual potential ({bad}) in symmetric log-domain \
                         sinkhorn on {}; the kernel has an empty (all -inf) row",
                        kernel.describe()
                    ),
                });
            }
            // Row marginal of P_ij = a_i a_j exp((f_i + f_j)/eps + log K_ij).
            for i in 0..n {
                t_in[i] = f[i] / eps + log_a[i];
            }
            kernel.apply_log(&t_in, &mut t_out);
            marginal = 0.0;
            for i in 0..n {
                let row_mass = (t_out[i] + f[i] / eps + log_a[i]).exp();
                marginal += (row_mass - a[i] as f64).abs();
            }
            if marginal < cfg.tol {
                converged = true;
                break;
            }
        }
    }

    // Objective: Eq. (6) with alpha = beta = f plus the entropy offset
    // for both marginals, exactly as the two-sided log solver computes it
    // with a = b.
    let offset: f64 =
        2.0 * eps * a.iter().map(|&ai| (ai as f64) * (ai as f64).ln()).sum::<f64>();
    let obj: f64 =
        2.0 * a.iter().zip(&f).map(|(&ai, &fi)| ai as f64 * fi).sum::<f64>() + offset;
    let u: Vec<f32> =
        f.iter().zip(a).map(|(&x, &ai)| (ai as f64 * (x / eps).exp()) as f32).collect();
    let sol = SinkhornSolution {
        objective: obj,
        v: u.clone(),
        u,
        iterations: iter,
        marginal_error: marginal,
        converged,
    };
    Ok(WarmSolve { solution: sol, escalated: false, alpha: f })
}

/// Symmetric solve with automatic small-eps escalation: run the f32
/// fixed point, and when it reports non-finite scalings under
/// `cfg.stabilize`, continue on the log-domain symmetric iteration warm-
/// started from the last known-good dual — the same contract as
/// [`sinkhorn_stabilized`](super::sinkhorn_stabilized), one dual instead
/// of two.
pub fn sinkhorn_symmetric_stabilized<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    cfg: &SinkhornConfig,
) -> Result<(SinkhornSolution, bool)> {
    sinkhorn_symmetric_stabilized_warm(kernel, a, cfg, None)
        .map(|ws| (ws.solution, ws.escalated))
}

/// [`sinkhorn_symmetric_stabilized`] with warm-start chaining.
pub fn sinkhorn_symmetric_stabilized_warm<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    cfg: &SinkhornConfig,
    warm: Option<&[f64]>,
) -> Result<WarmSolve> {
    match symmetric_core(kernel, a, cfg, warm) {
        SymOutcome::Done(ws) => Ok(ws),
        SymOutcome::Diverged { error, alpha } if cfg.stabilize => match kernel.as_log_kernel() {
            Some(log_kernel) => {
                let mut ws = sinkhorn_symmetric_log_warm(log_kernel, a, cfg, Some(&alpha))?;
                ws.escalated = true;
                Ok(ws)
            }
            None => Err(error),
        },
        SymOutcome::Diverged { error, .. } | SymOutcome::Failed(error) => Err(error),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::features::GaussianFeatureMap;
    use crate::kernels::{DenseKernel, FactoredKernel};
    use crate::linalg::Mat;
    use crate::rng::Rng;
    use crate::sinkhorn::sinkhorn_stabilized;

    fn cfg(eps: f64) -> SinkhornConfig {
        SinkhornConfig {
            epsilon: eps,
            max_iters: 5000,
            tol: 1e-6,
            check_every: 5,
            threads: 1,
            stabilize: false,
            max_batch: 1,
            anneal: None,
            anneal_decay: 0.5,
            symmetric: None,
        }
    }

    #[test]
    fn rungs_descend_geometrically_and_end_at_target() {
        let s = EpsSchedule::new(8.0, 0.5).unwrap();
        let r = s.rungs(1e-1);
        assert_eq!(r.first().copied(), Some(8.0));
        assert_eq!(r.last().copied(), Some(1e-1));
        for w in r.windows(2) {
            assert!(w[1] < w[0], "rungs must strictly descend: {r:?}");
        }
        // 8, 4, 2, 1, 0.5, 0.25, 0.125, 0.1.
        assert_eq!(r.len(), 8);
    }

    #[test]
    fn degenerate_schedule_is_the_direct_solve() {
        let s = EpsSchedule::new(0.05, 0.5).unwrap();
        assert_eq!(s.rungs(0.5), vec![0.5]);
        assert_eq!(s.rungs(0.05), vec![0.05]);
    }

    #[test]
    fn rung_count_is_capped() {
        let s = EpsSchedule::new(1e30, 0.999).unwrap();
        let r = s.rungs(1e-12);
        assert_eq!(r.len(), MAX_RUNGS);
        assert_eq!(r.last().copied(), Some(1e-12));
    }

    #[test]
    fn schedule_validation_rejects_bad_parameters() {
        assert!(EpsSchedule::new(0.0, 0.5).is_err());
        assert!(EpsSchedule::new(f64::NAN, 0.5).is_err());
        assert!(EpsSchedule::new(1.0, 0.0).is_err());
        assert!(EpsSchedule::new(1.0, 1.0).is_err());
        assert!(EpsSchedule::new(1.0, -0.5).is_err());
    }

    #[test]
    fn symmetric_matches_two_sided_self_solve_objective() {
        let mut rng = Rng::seed_from(40);
        let (mu, _) = data::gaussian_blobs(40, &mut rng);
        let k = DenseKernel::from_measures(&mu, &mu, 0.5);
        let sym = sinkhorn_symmetric(&k, &mu.weights, &cfg(0.5)).unwrap();
        let (two, _) = sinkhorn_stabilized(&k, &mu.weights, &mu.weights, &cfg(0.5)).unwrap();
        assert!(sym.converged, "symmetric solve should converge: err {}", sym.marginal_error);
        let rel = (sym.objective - two.objective).abs() / two.objective.abs().max(1.0);
        assert!(rel < 1e-4, "sym {} vs two-sided {} (rel {rel:.2e})", sym.objective, two.objective);
    }

    #[test]
    fn symmetric_log_matches_plain_symmetric_at_moderate_eps() {
        let mut rng = Rng::seed_from(41);
        let (mu, _) = data::gaussian_blobs(30, &mut rng);
        let fm = GaussianFeatureMap::fit(&mu, &mu, 0.5, 64, &mut rng);
        let k = FactoredKernel::from_measures_stabilized(&fm, &mu, &mu);
        let plain = sinkhorn_symmetric(&k, &mu.weights, &cfg(0.5)).unwrap();
        let logd = sinkhorn_symmetric_log(&k, &mu.weights, &cfg(0.5)).unwrap();
        let rel = (plain.objective - logd.objective).abs() / plain.objective.abs().max(1.0);
        assert!(
            rel < 1e-3,
            "plain {} vs log {} (rel {rel:.2e})",
            plain.objective,
            logd.objective
        );
    }

    #[test]
    fn symmetric_stabilized_escalates_on_underflowing_factors() {
        let n = 12;
        let phi = Mat::from_fn(n, 6, |i, k| 1e-30f32 * (1.0 + 0.1 * (((i + 2 * k) % 5) as f32)));
        let k = FactoredKernel::from_factors(phi.clone(), phi);
        let a = vec![1.0 / n as f32; n];
        let c = SinkhornConfig { stabilize: true, ..cfg(1e-3) };
        let (sol, escalated) = sinkhorn_symmetric_stabilized(&k, &a, &c).unwrap();
        assert!(escalated, "underflowing factors must take the log-domain path");
        assert!(sol.objective.is_finite());
        assert!(sol.marginal_error < 1e-3, "err {}", sol.marginal_error);
        // Stabilize off: the typed error surfaces.
        let off = cfg(1e-3);
        let k2 = {
            let phi = Mat::from_fn(n, 6, |i, k| {
                1e-30f32 * (1.0 + 0.1 * (((i + 2 * k) % 5) as f32))
            });
            FactoredKernel::from_factors(phi.clone(), phi)
        };
        assert!(matches!(
            sinkhorn_symmetric(&k2, &a, &off),
            Err(Error::SinkhornDiverged { .. })
        ));
    }

    #[test]
    fn symmetric_rejects_non_square_kernels() {
        let mut rng = Rng::seed_from(42);
        let (mu, nu) = data::gaussian_blobs(10, &mut rng);
        let k = DenseKernel::from_measures(&mu, &nu, 0.5);
        assert!(matches!(
            sinkhorn_symmetric(&k, &mu.weights, &cfg(0.5)),
            Err(Error::Shape(_))
        ));
    }

    #[test]
    fn warm_started_symmetric_finishes_faster() {
        let mut rng = Rng::seed_from(43);
        let (mu, _) = data::gaussian_blobs(40, &mut rng);
        let k = DenseKernel::from_measures(&mu, &mu, 0.3);
        let c = SinkhornConfig { check_every: 1, ..cfg(0.3) };
        let cold = sinkhorn_symmetric_warm(&k, &mu.weights, &c, None).unwrap();
        let warm = sinkhorn_symmetric_warm(&k, &mu.weights, &c, Some(&cold.alpha)).unwrap();
        assert!(
            warm.solution.iterations <= cold.solution.iterations,
            "warm {} vs cold {}",
            warm.solution.iterations,
            cold.solution.iterations
        );
        assert!(warm.solution.iterations <= 2, "restart from the fixed point should be instant");
    }
}
