//! Batched multi-pair Sinkhorn: drive B transport problems that share
//! one kernel as a single column-blocked iteration.
//!
//! A service solving many concurrent divergence requests against the same
//! support (re-weighted histograms, repeated reference-distribution
//! queries, barycenter-style workloads) runs B independent Sinkhorn
//! solves whose per-iteration cost is B pairs of kernel applies. Stacking
//! the scaling vectors into pair-major blocks `U ∈ R^{B×n}`, `V ∈ R^{B×m}`
//! turns those into fused `Φ_x(Φ_y^T V)`-style mat-mat applies
//! ([`KernelOp::apply_batch_into`]) that stream each factor **once** for
//! all B pairs — the "apply K to many vectors at once" batching that makes
//! matrix Sinkhorn fast (Cuturi '13), carried over to the paper's
//! O(r(n+m)) factored kernels at O(r·Σn) per fused apply.
//!
//! ## Sequential-equivalence contract
//!
//! [`solve_batch`] returns **bitwise-identical** potentials, objectives,
//! iteration counts and errors to B separate [`super::sinkhorn`] calls on
//! the same kernel, at every pool size and every batch width. The chain
//! of guarantees: the column-blocked linalg kernels compute each pair row
//! with the same per-row/per-chunk arithmetic as the vector kernels on
//! the same fixed chunk grids ([`crate::linalg`]); the batched kernel
//! applies are therefore bitwise per pair
//! ([`KernelOp::apply_batch_into`]); and this solver mirrors the
//! sequential loop's update, check cadence and stopping logic exactly,
//! with **per-pair convergence masking**: a pair that converges (or
//! diverges) at a check point freezes — its row is compacted out of the
//! working block — without desynchronising the survivors, whose arithmetic
//! is column-independent. Property-tested in
//! `rust/tests/batched_equivalence.rs`.
//!
//! [`solve_batch_log_domain`] repeats the construction one level down for
//! the stabilised log-domain iteration over [`LogKernelOp`], and
//! [`solve_batch_stabilized`] glues the two together per pair the way
//! [`super::sinkhorn_stabilized`] does for one. [`sinkhorn_divergence_batch`]
//! is the B-pair Eq. (2) entry point: 3·B constituent solves as three
//! width-B batched solves, run concurrently on a [`Pool`].

use crate::config::SinkhornConfig;
use crate::error::{Error, Result};
use crate::kernels::{KernelOp, LogKernelOp};
use crate::linalg::Mat;
use crate::runtime::pool::Pool;

use super::logdomain::first_non_finite;
use super::schedule::{alpha_from_scalings, warm_scalings, WarmSolve};
use super::{first_bad, objective, PlainOutcome, SinkhornSolution};

/// Copy the kept rows of a pair-major block into a fresh, smaller block.
fn retain_rows(mat: &Mat, keep: &[usize]) -> Mat {
    let mut out = Mat::zeros(keep.len(), mat.cols());
    for (dst, &src) in keep.iter().enumerate() {
        out.row_mut(dst).copy_from_slice(mat.row(src));
    }
    out
}

/// Keep the rows of a pair-major `Vec<Vec<f64>>` block named by `keep`
/// (strictly increasing), moving the buffers instead of copying them.
fn retain_vecs(xs: Vec<Vec<f64>>, keep: &[usize]) -> Vec<Vec<f64>> {
    let mut slots: Vec<Option<Vec<f64>>> = xs.into_iter().map(Some).collect();
    keep.iter().map(|&row| slots[row].take().expect("kept row")).collect()
}

/// Assemble one pair's solution exactly as the sequential solver does.
#[allow(clippy::too_many_arguments)]
fn finish<K: KernelOp + ?Sized>(
    kernel: &K,
    (a, b): (&[f32], &[f32]),
    cfg: &SinkhornConfig,
    u: &[f32],
    v: &[f32],
    iterations: usize,
    marginal_error: f64,
    converged: bool,
) -> SinkhornSolution {
    SinkhornSolution {
        // `-eps log_scale` compensates stabilised kernels, as in
        // `sinkhorn`.
        objective: objective(cfg.epsilon, a, b, u, v) - cfg.epsilon * kernel.log_scale(),
        u: u.to_vec(),
        v: v.to_vec(),
        iterations,
        marginal_error,
        converged,
    }
}

/// Algorithm 1 over one kernel and B weight pairs, column-blocked.
///
/// Each element of `pairs` is an `(a, b)` marginal pair for the same
/// `kernel`; the result vector is index-aligned with `pairs`. Per pair,
/// the outcome (solution or typed error) is bitwise identical to
/// [`super::sinkhorn`] on that pair alone — see the module docs for the
/// contract. One pair diverging never poisons its batch-mates: its row is
/// frozen with the error and the rest continue.
pub fn solve_batch<K: KernelOp + ?Sized>(
    kernel: &K,
    pairs: &[(&[f32], &[f32])],
    cfg: &SinkhornConfig,
) -> Vec<Result<SinkhornSolution>> {
    solve_batch_core(kernel, pairs, cfg, None).into_iter().map(|o| o.result).collect()
}

/// [`solve_batch`] with optional per-pair warm duals and the final dual
/// reported per pair — the batched rung-to-rung chaining entry point of
/// an [`EpsSchedule`](super::EpsSchedule). `warms`, when given, is
/// index-aligned with `pairs`.
pub fn solve_batch_warm<K: KernelOp + ?Sized>(
    kernel: &K,
    pairs: &[(&[f32], &[f32])],
    cfg: &SinkhornConfig,
    warms: Option<&[Vec<f64>]>,
) -> Vec<Result<WarmSolve>> {
    solve_batch_core(kernel, pairs, cfg, warms)
        .into_iter()
        .map(|o| o.result.map(|solution| WarmSolve { solution, escalated: false, alpha: o.alpha }))
        .collect()
}

fn solve_batch_core<K: KernelOp + ?Sized>(
    kernel: &K,
    pairs: &[(&[f32], &[f32])],
    cfg: &SinkhornConfig,
    warms: Option<&[Vec<f64>]>,
) -> Vec<PlainOutcome> {
    let (n, m) = (kernel.rows(), kernel.cols());
    if let Some(ws) = warms {
        assert_eq!(ws.len(), pairs.len(), "solve_batch: warms must align with pairs");
    }
    let mut slots: Vec<Option<PlainOutcome>> = (0..pairs.len()).map(|_| None).collect();
    // `live[row]` = index into `pairs` occupying row `row` of the
    // column-blocked state; finished rows are compacted away.
    let mut live: Vec<usize> = Vec::new();
    for (p, &(a, b)) in pairs.iter().enumerate() {
        if a.len() != n || b.len() != m {
            slots[p] = Some(PlainOutcome {
                result: Err(Error::Shape(format!(
                    "sinkhorn: kernel {}x{} vs a[{}], b[{}]",
                    n,
                    m,
                    a.len(),
                    b.len()
                ))),
                alpha: Vec::new(),
            });
        } else if warms.is_some_and(|ws| ws[p].len() != n) {
            slots[p] = Some(PlainOutcome {
                result: Err(Error::Shape(format!(
                    "sinkhorn: warm dual [{}] vs kernel {n}x{m}",
                    warms.expect("checked")[p].len()
                ))),
                alpha: Vec::new(),
            });
        } else {
            live.push(p);
        }
    }

    let mut us = Mat::ones(live.len(), n);
    let mut vs = Mat::ones(live.len(), m);
    // Warm rows replace the all-ones init with the same expression the
    // sequential warm solver uses — bitwise per pair.
    if let Some(ws) = warms {
        for (row, &p) in live.iter().enumerate() {
            us.row_mut(row).copy_from_slice(&warm_scalings(cfg.epsilon, pairs[p].0, &ws[p]));
        }
    }
    let mut kv = Mat::zeros(live.len(), n);
    let mut ktu = Mat::zeros(live.len(), m);
    let mut marginals = vec![f64::INFINITY; live.len()];
    // Per-row last dual that passed a checkpoint, mirroring the
    // sequential core (escalation warm starts).
    let mut last_goods: Vec<Vec<f64>> = live
        .iter()
        .enumerate()
        .map(|(row, &p)| match warms {
            Some(ws) => ws[p].clone(),
            None => alpha_from_scalings(cfg.epsilon, pairs[p].0, us.row(row)),
        })
        .collect();

    let check_every = cfg.check_every.max(1);
    let mut iter = 0;

    while iter < cfg.max_iters && !live.is_empty() {
        // v <- b / K^T u, all live pairs in one fused apply.
        kernel.apply_batch_t_into(&us, &mut ktu);
        for (row, &p) in live.iter().enumerate() {
            let b = pairs[p].1;
            for ((v, &k), &bj) in vs.row_mut(row).iter_mut().zip(ktu.row(row)).zip(b) {
                *v = bj / k;
            }
        }
        // u <- a / K v.
        kernel.apply_batch_into(&vs, &mut kv);
        for (row, &p) in live.iter().enumerate() {
            let a = pairs[p].0;
            for ((u, &k), &ai) in us.row_mut(row).iter_mut().zip(kv.row(row)).zip(a) {
                *u = ai / k;
            }
        }
        iter += 1;

        if iter % check_every == 0 || iter == cfg.max_iters {
            // Divergence check on the scalings, pair by pair; surviving
            // rows refresh their last-good dual like the sequential core.
            for (row, &p) in live.iter().enumerate() {
                if let Some(bad) = first_bad(us.row(row)).or_else(|| first_bad(vs.row(row))) {
                    slots[p] = Some(PlainOutcome {
                        result: Err(Error::SinkhornDiverged {
                            iter,
                            reason: format!(
                                "non-finite or non-positive scaling ({bad}); kernel {} lost \
                                 positivity or eps is too small for f32",
                                kernel.label()
                            ),
                        }),
                        alpha: std::mem::take(&mut last_goods[row]),
                    });
                } else {
                    last_goods[row] = alpha_from_scalings(cfg.epsilon, pairs[p].0, us.row(row));
                }
            }
            // Marginal errors: one fused transposed apply serves every
            // live pair (rows of just-errored pairs are computed and
            // discarded — column independence keeps the rest exact).
            kernel.apply_batch_t_into(&us, &mut ktu);
            for (row, &p) in live.iter().enumerate() {
                if slots[p].is_some() {
                    continue;
                }
                let b = pairs[p].1;
                let marginal: f64 = vs
                    .row(row)
                    .iter()
                    .zip(ktu.row(row))
                    .zip(b)
                    .map(|((&vj, &kj), &bj)| ((vj * kj - bj) as f64).abs())
                    .sum();
                marginals[row] = marginal;
                if marginal < cfg.tol {
                    slots[p] = Some(PlainOutcome {
                        result: Ok(finish(
                            kernel,
                            pairs[p],
                            cfg,
                            us.row(row),
                            vs.row(row),
                            iter,
                            marginal,
                            true,
                        )),
                        alpha: std::mem::take(&mut last_goods[row]),
                    });
                }
            }
            // Freeze finished pairs: compact their rows out of the block.
            if live.iter().any(|&p| slots[p].is_some()) {
                let keep: Vec<usize> =
                    (0..live.len()).filter(|&row| slots[live[row]].is_none()).collect();
                us = retain_rows(&us, &keep);
                vs = retain_rows(&vs, &keep);
                kv = Mat::zeros(keep.len(), n);
                ktu = Mat::zeros(keep.len(), m);
                marginals = keep.iter().map(|&row| marginals[row]).collect();
                last_goods = retain_vecs(last_goods, &keep);
                live = keep.iter().map(|&row| live[row]).collect();
            }
        }
    }

    // Pairs still live at the iteration cap exit un-converged, mirroring
    // the sequential loop's fall-through.
    for (row, &p) in live.iter().enumerate() {
        slots[p] = Some(PlainOutcome {
            result: Ok(finish(
                kernel,
                pairs[p],
                cfg,
                us.row(row),
                vs.row(row),
                iter,
                marginals[row],
                false,
            )),
            alpha: std::mem::take(&mut last_goods[row]),
        });
    }

    slots.into_iter().map(|s| s.expect("every pair resolved")).collect()
}

/// Assemble one pair's log-domain solution exactly as
/// [`super::sinkhorn_log_domain`] does.
fn finish_log(
    (a, b): (&[f32], &[f32]),
    eps: f64,
    alpha: &[f64],
    beta: &[f64],
    iterations: usize,
    marginal_error: f64,
    converged: bool,
) -> SinkhornSolution {
    // Entropy offset converting the a⊗b-relative duals to Eq. (6)'s
    // objective — see the sequential solver for the derivation.
    let offset: f64 = eps
        * (a.iter().map(|&ai| (ai as f64) * (ai as f64).ln()).sum::<f64>()
            + b.iter().map(|&bi| (bi as f64) * (bi as f64).ln()).sum::<f64>());
    let objective: f64 = a.iter().zip(alpha).map(|(&ai, &al)| ai as f64 * al).sum::<f64>()
        + b.iter().zip(beta).map(|(&bi, &be)| bi as f64 * be).sum::<f64>()
        + offset;
    SinkhornSolution {
        u: alpha.iter().zip(a).map(|(&x, &ai)| (ai as f64 * (x / eps).exp()) as f32).collect(),
        v: beta.iter().zip(b).map(|(&x, &bi)| (bi as f64 * (x / eps).exp()) as f32).collect(),
        objective,
        iterations,
        marginal_error,
        converged,
    }
}

/// Log-domain Sinkhorn over one log-space kernel and B weight pairs,
/// column-blocked — the stabilised counterpart of [`solve_batch`], with
/// the same per-pair masking and the same bitwise equivalence to B
/// sequential [`super::sinkhorn_log_domain`] calls.
pub fn solve_batch_log_domain<K: LogKernelOp + ?Sized>(
    kernel: &K,
    pairs: &[(&[f32], &[f32])],
    cfg: &SinkhornConfig,
) -> Vec<Result<SinkhornSolution>> {
    solve_batch_log_domain_warm(kernel, pairs, cfg, None)
        .into_iter()
        .map(|r| r.map(|ws| ws.solution))
        .collect()
}

/// [`solve_batch_log_domain`] with optional per-pair warm duals and the
/// final f64 dual reported per pair (the escalation/annealing currency —
/// see [`sinkhorn_log_domain_warm`](super::sinkhorn_log_domain_warm)).
/// `warms`, when given, is index-aligned with `pairs`.
pub fn solve_batch_log_domain_warm<K: LogKernelOp + ?Sized>(
    kernel: &K,
    pairs: &[(&[f32], &[f32])],
    cfg: &SinkhornConfig,
    warms: Option<&[Vec<f64>]>,
) -> Vec<Result<WarmSolve>> {
    let (n, m) = kernel.shape();
    let eps = cfg.epsilon;
    if let Some(ws) = warms {
        assert_eq!(ws.len(), pairs.len(), "solve_batch_log_domain: warms must align with pairs");
    }
    let mut slots: Vec<Option<Result<WarmSolve>>> = (0..pairs.len()).map(|_| None).collect();
    let mut live: Vec<usize> = Vec::new();
    for (p, &(a, b)) in pairs.iter().enumerate() {
        if a.len() != n || b.len() != m {
            slots[p] = Some(Err(Error::Shape(format!(
                "log-domain sinkhorn: kernel {n}x{m} vs a[{}], b[{}]",
                a.len(),
                b.len()
            ))));
        } else if warms.is_some_and(|ws| ws[p].len() != n) {
            slots[p] = Some(Err(Error::Shape(format!(
                "log-domain sinkhorn: warm dual [{}] vs kernel {n}x{m}",
                warms.expect("checked")[p].len()
            ))));
        } else {
            live.push(p);
        }
    }

    let bsize = live.len();
    let mut log_as: Vec<Vec<f64>> =
        live.iter().map(|&p| pairs[p].0.iter().map(|&x| (x as f64).ln()).collect()).collect();
    let mut log_bs: Vec<Vec<f64>> =
        live.iter().map(|&p| pairs[p].1.iter().map(|&x| (x as f64).ln()).collect()).collect();
    let mut alphas: Vec<Vec<f64>> = live
        .iter()
        .map(|&p| match warms {
            Some(ws) => ws[p].clone(),
            None => vec![0.0f64; n],
        })
        .collect();
    let mut betas: Vec<Vec<f64>> = (0..bsize).map(|_| vec![0.0f64; m]).collect();
    let mut row_ins: Vec<Vec<f64>> = (0..bsize).map(|_| vec![0.0f64; n]).collect();
    let mut col_ins: Vec<Vec<f64>> = (0..bsize).map(|_| vec![0.0f64; m]).collect();
    let mut row_outs: Vec<Vec<f64>> = (0..bsize).map(|_| vec![0.0f64; n]).collect();
    let mut col_outs: Vec<Vec<f64>> = (0..bsize).map(|_| vec![0.0f64; m]).collect();
    let mut marginals = vec![f64::INFINITY; bsize];
    // `live_rows[row]` = index into `pairs`; the f64 state vectors above
    // are compacted in lockstep with it.
    let mut live_rows = live;

    let check_every = cfg.check_every.max(1);
    let mut iter = 0;

    while iter < cfg.max_iters && !live_rows.is_empty() {
        // beta update: beta_j = -eps logsumexp_i(log K_ij + alpha_i/eps + log a_i).
        for row in 0..live_rows.len() {
            for ((ri, &al), &la) in
                row_ins[row].iter_mut().zip(&alphas[row]).zip(&log_as[row])
            {
                *ri = al / eps + la;
            }
        }
        kernel.apply_log_batch_t(&row_ins, &mut col_outs);
        for row in 0..live_rows.len() {
            for (be, &co) in betas[row].iter_mut().zip(&col_outs[row]) {
                *be = -eps * co;
            }
        }
        // alpha update.
        for row in 0..live_rows.len() {
            for ((ci, &be), &lb) in
                col_ins[row].iter_mut().zip(&betas[row]).zip(&log_bs[row])
            {
                *ci = be / eps + lb;
            }
        }
        kernel.apply_log_batch(&col_ins, &mut row_outs);
        for row in 0..live_rows.len() {
            for (al, &ro) in alphas[row].iter_mut().zip(&row_outs[row]) {
                *al = -eps * ro;
            }
        }
        iter += 1;

        if iter % check_every == 0 || iter == cfg.max_iters {
            for (row, &p) in live_rows.iter().enumerate() {
                if let Some(bad) =
                    first_non_finite(&alphas[row]).or_else(|| first_non_finite(&betas[row]))
                {
                    slots[p] = Some(Err(Error::SinkhornDiverged {
                        iter,
                        reason: format!(
                            "non-finite dual potential ({bad}) in log-domain sinkhorn on {}; \
                             the kernel has an empty (all -inf) row or column",
                            kernel.describe()
                        ),
                    }));
                }
            }
            // Column marginals, fused across live pairs.
            for row in 0..live_rows.len() {
                for ((ri, &al), &la) in
                    row_ins[row].iter_mut().zip(&alphas[row]).zip(&log_as[row])
                {
                    *ri = al / eps + la;
                }
            }
            kernel.apply_log_batch_t(&row_ins, &mut col_outs);
            for (row, &p) in live_rows.iter().enumerate() {
                if slots[p].is_some() {
                    continue;
                }
                let b = pairs[p].1;
                let mut marginal = 0.0;
                for ((&co, &be), (&lb, &bj)) in col_outs[row]
                    .iter()
                    .zip(&betas[row])
                    .zip(log_bs[row].iter().zip(b))
                {
                    let col_mass = (co + be / eps + lb).exp();
                    marginal += (col_mass - bj as f64).abs();
                }
                marginals[row] = marginal;
                if marginal < cfg.tol {
                    let solution =
                        finish_log(pairs[p], eps, &alphas[row], &betas[row], iter, marginal, true);
                    slots[p] = Some(Ok(WarmSolve {
                        solution,
                        escalated: false,
                        alpha: std::mem::take(&mut alphas[row]),
                    }));
                }
            }
            // Compact finished rows out of every state vector.
            if live_rows.iter().any(|&p| slots[p].is_some()) {
                let keep: Vec<usize> =
                    (0..live_rows.len()).filter(|&row| slots[live_rows[row]].is_none()).collect();
                alphas = retain_vecs(alphas, &keep);
                betas = retain_vecs(betas, &keep);
                row_ins = retain_vecs(row_ins, &keep);
                col_ins = retain_vecs(col_ins, &keep);
                row_outs = retain_vecs(row_outs, &keep);
                col_outs = retain_vecs(col_outs, &keep);
                log_as = retain_vecs(log_as, &keep);
                log_bs = retain_vecs(log_bs, &keep);
                marginals = keep.iter().map(|&row| marginals[row]).collect();
                live_rows = keep.iter().map(|&row| live_rows[row]).collect();
            }
        }
    }

    for (row, &p) in live_rows.iter().enumerate() {
        let solution =
            finish_log(pairs[p], eps, &alphas[row], &betas[row], iter, marginals[row], false);
        slots[p] = Some(Ok(WarmSolve {
            solution,
            escalated: false,
            alpha: std::mem::take(&mut alphas[row]),
        }));
    }

    slots.into_iter().map(|s| s.expect("every pair resolved")).collect()
}

/// [`solve_batch`] with automatic small-eps escalation, per pair: pairs
/// whose plain solve reports [`Error::SinkhornDiverged`] are re-solved —
/// together, as one batched log-domain solve — through the kernel's
/// [`KernelOp::as_log_kernel`] view when `cfg.stabilize` is set. The
/// boolean in each result is the per-pair "the log-domain path was taken"
/// flag, exactly as [`super::sinkhorn_stabilized`] reports it; kernels
/// without a log view keep their original error, and non-diverged
/// batch-mates are untouched by an escalation.
pub fn solve_batch_stabilized<K: KernelOp + ?Sized>(
    kernel: &K,
    pairs: &[(&[f32], &[f32])],
    cfg: &SinkhornConfig,
) -> Vec<Result<(SinkhornSolution, bool)>> {
    solve_batch_stabilized_warm(kernel, pairs, cfg, None)
        .into_iter()
        .map(|r| r.map(|ws| (ws.solution, ws.escalated)))
        .collect()
}

/// [`solve_batch_stabilized`] with warm-start chaining: optional per-pair
/// warm duals in, final per-pair duals out. Escalated pairs warm-start
/// the batched log-domain solve from their last checkpoint-good plain
/// dual, exactly as [`sinkhorn_stabilized_warm`](super::sinkhorn_stabilized_warm)
/// does one pair at a time — the bitwise lockstep the batched-equivalence
/// suite pins.
pub fn solve_batch_stabilized_warm<K: KernelOp + ?Sized>(
    kernel: &K,
    pairs: &[(&[f32], &[f32])],
    cfg: &SinkhornConfig,
    warms: Option<&[Vec<f64>]>,
) -> Vec<Result<WarmSolve>> {
    let plain = solve_batch_core(kernel, pairs, cfg, warms);
    let mut out: Vec<Option<Result<WarmSolve>>> = (0..pairs.len()).map(|_| None).collect();
    let mut escalate: Vec<usize> = Vec::new();
    let mut esc_warms: Vec<Vec<f64>> = Vec::new();
    for (p, o) in plain.into_iter().enumerate() {
        match o.result {
            Ok(solution) => {
                out[p] = Some(Ok(WarmSolve { solution, escalated: false, alpha: o.alpha }))
            }
            Err(Error::SinkhornDiverged { iter, reason }) if cfg.stabilize => {
                if kernel.as_log_kernel().is_some() {
                    escalate.push(p);
                    esc_warms.push(o.alpha);
                } else {
                    out[p] = Some(Err(Error::SinkhornDiverged { iter, reason }));
                }
            }
            Err(e) => out[p] = Some(Err(e)),
        }
    }
    if !escalate.is_empty() {
        let log_kernel = kernel.as_log_kernel().expect("escalation implies a log view");
        let esc_pairs: Vec<(&[f32], &[f32])> = escalate.iter().map(|&p| pairs[p]).collect();
        for (i, res) in
            solve_batch_log_domain_warm(log_kernel, &esc_pairs, cfg, Some(&esc_warms))
                .into_iter()
                .enumerate()
        {
            out[escalate[i]] = Some(res.map(|mut ws| {
                ws.escalated = true;
                ws
            }));
        }
    }
    out.into_iter().map(|o| o.expect("every pair resolved")).collect()
}

/// Eq. (2) for B weight pairs sharing one support triple: the debiased
/// divergence `W_k(a_k, b_k) - (W_k(a_k, a_k) + W_k(b_k, b_k))/2` for
/// every pair, from **three width-B batched solves** (3·B constituent
/// transport problems) instead of 3·B vector solves. The three batched
/// solves run concurrently on a [`Pool`] when `cfg.threads` allows, like
/// [`super::sinkhorn_divergence`]; per pair, errors surface with the same
/// xy → xx → yy priority, and results are bitwise identical to B separate
/// `sinkhorn_divergence` calls at any thread count.
pub fn sinkhorn_divergence_batch<K: KernelOp + Sync + ?Sized>(
    k_xy: &K,
    k_xx: &K,
    k_yy: &K,
    pairs: &[(&[f32], &[f32])],
    cfg: &SinkhornConfig,
) -> Vec<Result<f64>> {
    let pool = Pool::new_capped(cfg.threads, 3);
    let xx_pairs: Vec<(&[f32], &[f32])> = pairs.iter().map(|&(a, _)| (a, a)).collect();
    let yy_pairs: Vec<(&[f32], &[f32])> = pairs.iter().map(|&(_, b)| (b, b)).collect();
    let (r_xy, r_xx, r_yy) = pool.join3(
        || solve_batch_stabilized(k_xy, pairs, cfg),
        || solve_batch_stabilized(k_xx, &xx_pairs, cfg),
        || solve_batch_stabilized(k_yy, &yy_pairs, cfg),
    );
    r_xy.into_iter()
        .zip(r_xx)
        .zip(r_yy)
        .map(|((xy, xx), yy)| {
            let (xy, _) = xy?;
            let (xx, _) = xx?;
            let (yy, _) = yy?;
            Ok(xy.objective - 0.5 * (xx.objective + yy.objective))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::features::GaussianFeatureMap;
    use crate::kernels::{DenseKernel, FactoredKernel};
    use crate::rng::Rng;
    use crate::sinkhorn::{sinkhorn, sinkhorn_log_domain, sinkhorn_stabilized};

    fn cfg(eps: f64) -> SinkhornConfig {
        SinkhornConfig {
            epsilon: eps,
            max_iters: 500,
            tol: 1e-5,
            check_every: 5,
            threads: 1,
            stabilize: false,
            max_batch: 8,
            anneal: None,
            anneal_decay: 0.5,
            symmetric: None,
        }
    }

    /// B positive weight vectors of length n with different skews, each
    /// summing to one — mixed convergence speeds for masking coverage.
    fn weight_family(n: usize, b: usize) -> Vec<Vec<f32>> {
        (0..b)
            .map(|k| {
                let raw: Vec<f64> = (0..n)
                    .map(|i| 1.0 + ((i * (k + 2) + k) % 7) as f64 * (0.2 + k as f64 * 0.3))
                    .collect();
                let total: f64 = raw.iter().sum();
                raw.iter().map(|&x| (x / total) as f32).collect()
            })
            .collect()
    }

    #[test]
    fn empty_batch_is_empty() {
        let mut rng = Rng::seed_from(0);
        let (mu, nu) = data::gaussian_blobs(10, &mut rng);
        let k = DenseKernel::from_measures(&mu, &nu, 0.5);
        assert!(solve_batch(&k, &[], &cfg(0.5)).is_empty());
    }

    #[test]
    fn shape_mismatch_flags_only_the_bad_pair() {
        let mut rng = Rng::seed_from(1);
        let (mu, nu) = data::gaussian_blobs(12, &mut rng);
        let k = DenseKernel::from_measures(&mu, &nu, 0.5);
        let bad = vec![0.5f32; 3];
        let pairs: Vec<(&[f32], &[f32])> = vec![
            (&mu.weights, &nu.weights),
            (&bad, &nu.weights),
            (&mu.weights, &nu.weights),
        ];
        let res = solve_batch(&k, &pairs, &cfg(0.5));
        assert!(res[0].is_ok());
        assert!(matches!(res[1], Err(Error::Shape(_))));
        assert!(res[2].is_ok());
    }

    #[test]
    fn batched_matches_sequential_bitwise_on_dense() {
        // The default (per-pair loop) batched applies: the solver logic
        // itself must already be exactly the sequential loop.
        let mut rng = Rng::seed_from(2);
        let (mu, nu) = data::gaussian_blobs(30, &mut rng);
        let k = DenseKernel::from_measures(&mu, &nu, 0.5);
        let ws_a = weight_family(mu.len(), 3);
        let ws_b = weight_family(nu.len(), 3);
        let pairs: Vec<(&[f32], &[f32])> =
            ws_a.iter().zip(&ws_b).map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let batched = solve_batch(&k, &pairs, &cfg(0.5));
        for (p, &(a, b)) in pairs.iter().enumerate() {
            let solo = sinkhorn(&k, a, b, &cfg(0.5)).unwrap();
            let got = batched[p].as_ref().unwrap();
            assert_eq!(got.objective.to_bits(), solo.objective.to_bits(), "pair {p}");
            assert_eq!(got.iterations, solo.iterations, "pair {p}");
            assert_eq!(got.converged, solo.converged, "pair {p}");
        }
    }

    #[test]
    fn masking_freezes_converged_pairs_without_desync() {
        // Pairs with different skews converge at different check points;
        // each must report exactly its own sequential iteration count.
        let mut rng = Rng::seed_from(3);
        let (mu, nu) = data::gaussian_blobs(25, &mut rng);
        let map = GaussianFeatureMap::fit(&mu, &nu, 0.5, 64, &mut rng);
        let k = FactoredKernel::from_measures(&map, &mu, &nu);
        let ws_a = weight_family(mu.len(), 4);
        let ws_b = weight_family(nu.len(), 4);
        let pairs: Vec<(&[f32], &[f32])> =
            ws_a.iter().zip(&ws_b).map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let c = SinkhornConfig { tol: 1e-4, max_iters: 3000, check_every: 1, ..cfg(0.5) };
        let batched = solve_batch(&k, &pairs, &c);
        let mut iters: Vec<usize> = Vec::new();
        for (p, &(a, b)) in pairs.iter().enumerate() {
            let solo = sinkhorn(&k, a, b, &c).unwrap();
            let got = batched[p].as_ref().unwrap();
            assert_eq!(got.iterations, solo.iterations, "pair {p}");
            assert_eq!(got.objective.to_bits(), solo.objective.to_bits(), "pair {p}");
            assert!(got.converged, "pair {p} should converge");
            iters.push(got.iterations);
        }
        iters.dedup();
        assert!(iters.len() > 1, "weight family too uniform to exercise masking: {iters:?}");
    }

    #[test]
    fn log_domain_batched_matches_sequential_bitwise() {
        let mut rng = Rng::seed_from(4);
        let (mu, nu) = data::gaussian_blobs(15, &mut rng);
        let eps = 1e-2;
        let map = GaussianFeatureMap::fit(&mu, &nu, eps, 24, &mut rng);
        let k = FactoredKernel::from_measures_stabilized(&map, &mu, &nu);
        let ws_a = weight_family(mu.len(), 3);
        let ws_b = weight_family(nu.len(), 3);
        let pairs: Vec<(&[f32], &[f32])> =
            ws_a.iter().zip(&ws_b).map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let c = SinkhornConfig { max_iters: 80, ..cfg(eps) };
        let batched = solve_batch_log_domain(&k, &pairs, &c);
        for (p, &(a, b)) in pairs.iter().enumerate() {
            let solo = sinkhorn_log_domain(&k, a, b, &c).unwrap();
            let got = batched[p].as_ref().unwrap();
            assert_eq!(got.objective.to_bits(), solo.objective.to_bits(), "pair {p}");
            assert_eq!(got.iterations, solo.iterations, "pair {p}");
            assert_eq!(got.marginal_error.to_bits(), solo.marginal_error.to_bits(), "pair {p}");
        }
    }

    #[test]
    fn stabilized_escalates_per_pair_like_sequential() {
        // Underflowing factors: every pair diverges in plain f32 and
        // escalates; flags and objectives must match the sequential
        // stabilised path bit for bit.
        let (n, m) = (12, 10);
        let phi_x = Mat::from_fn(n, 6, |i, k| 1e-30f32 * (1.0 + 0.1 * (((i + 2 * k) % 5) as f32)));
        let phi_y = Mat::from_fn(m, 6, |j, k| 1e-30f32 * (1.0 + 0.1 * (((2 * j + k) % 7) as f32)));
        let k = FactoredKernel::from_factors(phi_x, phi_y);
        let ws_a = weight_family(n, 2);
        let ws_b = weight_family(m, 2);
        let pairs: Vec<(&[f32], &[f32])> =
            ws_a.iter().zip(&ws_b).map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let c = SinkhornConfig { stabilize: true, ..cfg(1e-3) };
        let batched = solve_batch_stabilized(&k, &pairs, &c);
        for (p, &(a, b)) in pairs.iter().enumerate() {
            let (solo, solo_st) = sinkhorn_stabilized(&k, a, b, &c).unwrap();
            let (got, got_st) = batched[p].as_ref().unwrap();
            assert!(*got_st && solo_st, "pair {p}: both paths must escalate");
            assert_eq!(got.objective.to_bits(), solo.objective.to_bits(), "pair {p}");
        }
        // With stabilisation off the typed error surfaces per pair.
        let off = SinkhornConfig { stabilize: false, ..cfg(1e-3) };
        let res = solve_batch_stabilized(&k, &pairs, &off);
        assert!(res.iter().all(|r| matches!(r, Err(Error::SinkhornDiverged { .. }))));
    }

    #[test]
    fn divergence_batch_matches_scalar_divergence() {
        let mut rng = Rng::seed_from(5);
        let (mu, nu) = data::gaussian_blobs(20, &mut rng);
        let map = GaussianFeatureMap::fit(&mu, &nu, 0.5, 64, &mut rng);
        let k_xy = FactoredKernel::from_measures(&map, &mu, &nu);
        let k_xx = FactoredKernel::from_measures(&map, &mu, &mu);
        let k_yy = FactoredKernel::from_measures(&map, &nu, &nu);
        let ws_a = weight_family(mu.len(), 3);
        let ws_b = weight_family(nu.len(), 3);
        let pairs: Vec<(&[f32], &[f32])> =
            ws_a.iter().zip(&ws_b).map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let c = cfg(0.5);
        let batched = sinkhorn_divergence_batch(&k_xy, &k_xx, &k_yy, &pairs, &c);
        for (p, &(a, b)) in pairs.iter().enumerate() {
            let solo =
                crate::sinkhorn::sinkhorn_divergence(&k_xy, &k_xx, &k_yy, a, b, &c).unwrap();
            let got = *batched[p].as_ref().unwrap();
            assert_eq!(got.to_bits(), solo.to_bits(), "pair {p}: {got} vs {solo}");
        }
    }
}
