//! Algorithm 2: accelerated Sinkhorn (Guminov et al. '19, as restated in
//! the paper's Appendix A.2 / Remark 2).
//!
//! Maximises the smooth dual reformulation (Eq. 32)
//!
//!   F(eta1, eta2) = eps [ <eta1, a> + <eta2, b> - log <e^{eta1}, K e^{eta2}> ]
//!
//! by accelerated *alternating* maximisation: at each step, take the exact
//! block maximisation (a log-form Sinkhorn half-step) on the block with the
//! larger partial-gradient norm, combined with a Nesterov momentum sequence
//! and adaptive Lipschitz backtracking. Everything touches K only through
//! `apply`/`apply_t`, so it runs on factored kernels at O(r(n+m)) per step
//! (the Remark-2 combination).

use crate::config::SinkhornConfig;
use crate::error::{Error, Result};
use crate::kernels::KernelOp;

/// Output of the accelerated solver.
#[derive(Clone, Debug)]
pub struct AccelSolution {
    /// Dual point eta1 (length n) — alpha/eps in the paper's scaling.
    pub eta1: Vec<f64>,
    /// Dual point eta2 (length m).
    pub eta2: Vec<f64>,
    /// F(eta1, eta2): converges to W_{eps,c} + eps from below.
    pub objective: f64,
    pub iterations: usize,
    pub converged: bool,
    /// Final gradient norm (optimality measure).
    pub grad_norm: f64,
}

struct Evaluator<'a, K: KernelOp + ?Sized> {
    kernel: &'a K,
    a: &'a [f32],
    b: &'a [f32],
    eps: f64,
    // Scratch.
    eu: Vec<f32>,
    ev: Vec<f32>,
    ku: Vec<f32>,
    kv: Vec<f32>,
}

impl<'a, K: KernelOp + ?Sized> Evaluator<'a, K> {
    fn new(kernel: &'a K, a: &'a [f32], b: &'a [f32], eps: f64) -> Self {
        let (n, m) = (kernel.rows(), kernel.cols());
        Evaluator {
            kernel,
            a,
            b,
            eps,
            eu: vec![0.0; n],
            ev: vec![0.0; m],
            ku: vec![0.0; m],
            kv: vec![0.0; n],
        }
    }

    /// Shift-stabilised exponentials of the dual point.
    fn exps(&mut self, eta1: &[f64], eta2: &[f64]) -> (f64, f64) {
        let s1 = eta1.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let s2 = eta2.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        for (o, &e) in self.eu.iter_mut().zip(eta1) {
            *o = (e - s1).exp() as f32;
        }
        for (o, &e) in self.ev.iter_mut().zip(eta2) {
            *o = (e - s2).exp() as f32;
        }
        (s1, s2)
    }

    /// F value and the normalised plan marginals (p_row, p_col).
    fn eval(&mut self, eta1: &[f64], eta2: &[f64]) -> (f64, Vec<f64>, Vec<f64>) {
        let (s1, s2) = self.exps(eta1, eta2);
        self.kernel.apply_into(&self.ev, &mut self.kv); // K e^{eta2}
        self.kernel.apply_t_into(&self.eu, &mut self.ku); // K^T e^{eta1}
        let z: f64 = self
            .eu
            .iter()
            .zip(&self.kv)
            .map(|(&u, &k)| u as f64 * k as f64)
            .sum();
        let log_z = z.ln() + s1 + s2;
        let lin: f64 = eta1.iter().zip(self.a).map(|(&e, &w)| e * w as f64).sum::<f64>()
            + eta2.iter().zip(self.b).map(|(&e, &w)| e * w as f64).sum::<f64>();
        let f = self.eps * (lin - log_z);
        // Marginals of the normalised plan.
        let p_row: Vec<f64> = self
            .eu
            .iter()
            .zip(&self.kv)
            .map(|(&u, &k)| (u as f64 * k as f64) / z)
            .collect();
        let p_col: Vec<f64> = self
            .ev
            .iter()
            .zip(&self.ku)
            .map(|(&v, &k)| (v as f64 * k as f64) / z)
            .collect();
        (f, p_row, p_col)
    }

    /// Exact block maximisation over eta1 (log-form Sinkhorn half-step):
    /// eta1_i <- log a_i - log (K e^{eta2})_i (up to an additive constant,
    /// which F is invariant to).
    fn block_max_eta1(&mut self, eta2: &[f64], out: &mut [f64]) {
        let s2 = eta2.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        for (o, &e) in self.ev.iter_mut().zip(eta2) {
            *o = (e - s2).exp() as f32;
        }
        self.kernel.apply_into(&self.ev, &mut self.kv);
        for (i, o) in out.iter_mut().enumerate() {
            *o = (self.a[i] as f64).ln() - (self.kv[i] as f64).ln() - s2;
        }
    }

    fn block_max_eta2(&mut self, eta1: &[f64], out: &mut [f64]) {
        let s1 = eta1.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        for (o, &e) in self.eu.iter_mut().zip(eta1) {
            *o = (e - s1).exp() as f32;
        }
        self.kernel.apply_t_into(&self.eu, &mut self.ku);
        for (j, o) in out.iter_mut().enumerate() {
            *o = (self.b[j] as f64).ln() - (self.ku[j] as f64).ln() - s1;
        }
    }
}

/// Accelerated Sinkhorn (Alg. 2). Stops when the dual gradient norm falls
/// below `cfg.tol` or `cfg.max_iters` outer iterations elapse.
pub fn sinkhorn_accelerated<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    b: &[f32],
    cfg: &SinkhornConfig,
) -> Result<AccelSolution> {
    let (n, m) = (kernel.rows(), kernel.cols());
    if a.len() != n || b.len() != m {
        return Err(Error::Shape(format!(
            "accelerated sinkhorn: kernel {n}x{m} vs a[{}], b[{}]",
            a.len(),
            b.len()
        )));
    }
    let eps = cfg.epsilon;
    let mut ev = Evaluator::new(kernel, a, b, eps);

    // eta = current iterate, zeta = momentum point, lambda = lookahead.
    let mut eta1 = vec![0.0f64; n];
    let mut eta2 = vec![0.0f64; m];
    let mut zeta1 = vec![0.0f64; n];
    let mut zeta2 = vec![0.0f64; m];
    let mut lam1 = vec![0.0f64; n];
    let mut lam2 = vec![0.0f64; m];

    // Adaptive Lipschitz estimate (phi = -F is L-smooth with L <= 2/eps).
    let mut lk = 1.0 / eps;
    let mut a_seq = 0.0f64; // sum of step weights A_k

    let mut converged = false;
    let mut stalled = false;
    let mut grad_norm = f64::INFINITY;
    let mut iters = 0;

    for k in 0..cfg.max_iters {
        iters = k + 1;
        let mut l_next = lk / 2.0;
        loop {
            // Step weight from the accelerated scheme.
            let a_next = 1.0 / (2.0 * l_next)
                + (1.0 / (4.0 * l_next * l_next) + a_seq * lk / l_next).sqrt();
            let tau = a_next / (a_seq + a_next);

            // Lookahead point.
            for i in 0..n {
                lam1[i] = tau * zeta1[i] + (1.0 - tau) * eta1[i];
            }
            for j in 0..m {
                lam2[j] = tau * zeta2[j] + (1.0 - tau) * eta2[j];
            }

            // Gradient of F at lambda: eps (a - p_row, b - p_col).
            let (f_lam, p_row, p_col) = ev.eval(&lam1, &lam2);
            let g1: Vec<f64> = a.iter().zip(&p_row).map(|(&w, &p)| eps * (w as f64 - p)).collect();
            let g2: Vec<f64> = b.iter().zip(&p_col).map(|(&w, &p)| eps * (w as f64 - p)).collect();
            let n1: f64 = g1.iter().map(|x| x * x).sum();
            let n2: f64 = g2.iter().map(|x| x * x).sum();
            grad_norm = (n1 + n2).sqrt();
            if grad_norm < cfg.tol {
                eta1.copy_from_slice(&lam1);
                eta2.copy_from_slice(&lam2);
                converged = true;
                break;
            }

            // Exact maximisation on the block with larger gradient norm.
            let mut cand1 = lam1.clone();
            let mut cand2 = lam2.clone();
            if n1 >= n2 {
                ev.block_max_eta1(&lam2, &mut cand1);
            } else {
                ev.block_max_eta2(&lam1, &mut cand2);
            }
            let (f_cand, _, _) = ev.eval(&cand1, &cand2);

            // Backtracking condition (maximisation form):
            // F(eta+) >= F(lambda) + ||grad||^2 / (2 L), with a relative
            // slack so f32 kernel-apply noise near the optimum cannot make
            // the line search loop forever on sub-precision differences.
            let slack = 1e-10 * f_lam.abs().max(1.0);
            if f_cand >= f_lam + (n1 + n2) / (2.0 * l_next) - slack {
                // Accept: momentum update zeta += a_next * grad F(lambda).
                for i in 0..n {
                    zeta1[i] += a_next * g1[i];
                }
                for j in 0..m {
                    zeta2[j] += a_next * g2[j];
                }
                eta1 = cand1;
                eta2 = cand2;
                a_seq += a_next;
                lk = l_next;
                break;
            }
            l_next *= 2.0;
            if l_next > 1e9 {
                // L exceeded any plausible smoothness constant: the
                // remaining gap is below working precision. Accept the
                // current lookahead as converged rather than erroring.
                eta1.copy_from_slice(&lam1);
                eta2.copy_from_slice(&lam2);
                converged = grad_norm < cfg.tol * 100.0;
                stalled = true;
                break;
            }
        }
        if converged || stalled {
            break;
        }
        if !eta1.iter().chain(eta2.iter()).all(|x| x.is_finite()) {
            return Err(Error::SinkhornDiverged {
                iter: k,
                reason: "non-finite dual point in accelerated sinkhorn".into(),
            });
        }
    }

    let (f_final, _, _) = ev.eval(&eta1, &eta2);
    // Same stabilised-kernel compensation as Alg. 1 (log z shifts by
    // log_scale, so F shifts by -eps log_scale).
    let objective = f_final - eps * kernel.log_scale();
    Ok(AccelSolution { eta1, eta2, objective, iterations: iters, converged, grad_norm })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SinkhornConfig;
    use crate::data;
    use crate::features::GaussianFeatureMap;
    use crate::kernels::{DenseKernel, FactoredKernel};
    use crate::rng::Rng;
    use crate::sinkhorn::sinkhorn;

    fn cfg(eps: f64, tol: f64) -> SinkhornConfig {
        SinkhornConfig {
            epsilon: eps,
            max_iters: 2000,
            tol,
            check_every: 1,
            threads: 1,
            stabilize: false,
            max_batch: 1,
            anneal: None,
            anneal_decay: 0.5,
            symmetric: None,
        }
    }

    #[test]
    fn reaches_same_objective_as_alg1() {
        let mut rng = Rng::seed_from(0);
        let (mu, nu) = data::gaussian_blobs(40, &mut rng);
        let eps = 0.5;
        let k = DenseKernel::from_measures(&mu, &nu, eps);
        let plain = sinkhorn(&k, &mu.weights, &nu.weights, &cfg(eps, 1e-7)).unwrap();
        let accel = sinkhorn_accelerated(&k, &mu.weights, &nu.weights, &cfg(eps, 1e-7)).unwrap();
        // F converges to W + eps*0? — F(eta*) = eps(<eta1,a>+<eta2,b> - log u^T K v)
        // equals the Eq.-5 dual value; compare against Alg.1's objective.
        assert!(
            (accel.objective - plain.objective).abs() < 2e-3 * plain.objective.abs().max(1.0),
            "accel {} plain {}",
            accel.objective,
            plain.objective
        );
    }

    #[test]
    fn works_on_factored_kernel() {
        let mut rng = Rng::seed_from(1);
        let (mu, nu) = data::gaussian_blobs(50, &mut rng);
        let eps = 0.5;
        let fm = GaussianFeatureMap::fit(&mu, &nu, eps, 128, &mut rng);
        let fk = FactoredKernel::from_measures(&fm, &mu, &nu);
        let plain = sinkhorn(&fk, &mu.weights, &nu.weights, &cfg(eps, 1e-7)).unwrap();
        let accel =
            sinkhorn_accelerated(&fk, &mu.weights, &nu.weights, &cfg(eps, 1e-7)).unwrap();
        assert!(
            (accel.objective - plain.objective).abs() < 2e-3 * plain.objective.abs().max(1.0)
        );
    }

    #[test]
    fn converges_flag_and_gradient() {
        let mut rng = Rng::seed_from(2);
        let (mu, nu) = data::gaussian_blobs(20, &mut rng);
        let k = DenseKernel::from_measures(&mu, &nu, 1.0);
        let sol = sinkhorn_accelerated(&k, &mu.weights, &nu.weights, &cfg(1.0, 1e-6)).unwrap();
        assert!(sol.converged);
        assert!(sol.grad_norm < 1e-6);
    }

    #[test]
    fn objective_monotone_ish_under_more_iters() {
        let mut rng = Rng::seed_from(3);
        let (mu, nu) = data::gaussian_blobs(25, &mut rng);
        let k = DenseKernel::from_measures(&mu, &nu, 0.2);
        let short = SinkhornConfig { max_iters: 3, ..cfg(0.2, 0.0) };
        let long = SinkhornConfig { max_iters: 200, ..cfg(0.2, 0.0) };
        let s = sinkhorn_accelerated(&k, &mu.weights, &nu.weights, &short).unwrap();
        let l = sinkhorn_accelerated(&k, &mu.weights, &nu.weights, &long).unwrap();
        assert!(l.objective >= s.objective - 1e-9, "long {} short {}", l.objective, s.objective);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut rng = Rng::seed_from(4);
        let (mu, nu) = data::gaussian_blobs(10, &mut rng);
        let k = DenseKernel::from_measures(&mu, &nu, 0.5);
        assert!(sinkhorn_accelerated(&k, &[0.5, 0.5], &nu.weights, &cfg(0.5, 1e-6)).is_err());
    }
}
