//! Sinkhorn solvers — Alg. 1 (matrix-free over any [`KernelOp`]), the
//! log-domain stabilised variant (matrix-free over any
//! [`LogKernelOp`](crate::kernels::LogKernelOp)), the accelerated Alg. 2,
//! and the Eq. (2) Sinkhorn divergence.
//!
//! Because Alg. 1 only touches the kernel through `apply`/`apply_t`, the
//! *same* loop runs the dense `Sin` baseline at O(nm)/iter and the paper's
//! `RF` factored kernel at O(r(n+m))/iter — the complexity claim is in the
//! operator, not in specialised solver code. The log-domain solver repeats
//! the trick one level down: its updates only touch the kernel through
//! `apply_log`/`apply_log_t`, so small-eps stabilisation is *also* linear
//! time on factored kernels. [`sinkhorn_stabilized`] glues the two
//! together: run Alg. 1, and when it reports non-finite scalings escalate
//! to the log-domain iteration (gated by `SinkhornConfig::stabilize`).
//!
//! The [`batch`] module scales the same loops across *pairs*: B transport
//! problems sharing one kernel iterate as column-blocked scaling matrices
//! with fused mat-mat kernel applies ([`solve_batch`],
//! [`sinkhorn_divergence_batch`]) — bitwise identical to B sequential
//! solves, per pair, at any thread count.
//!
//! All of these solvers inherit their numeric contract from the SIMD
//! core underneath ([`crate::linalg::simd`]): kernel applies dispatch at
//! runtime between an AVX2+FMA arm and the portable scalar arm, results
//! are bitwise thread-count-deterministic *per arm*, and the arms agree
//! to the documented kernel tolerances (~1e-5 relative on f32 applies,
//! ~1e-12 on the f64 log-domain reductions) — force
//! `LINEAR_SINKHORN_SIMD=scalar` to pin solver output across machines
//! (EXPERIMENTS.md §Perf, "SIMD core").
//!
//! **Status since PR 5:** this module is the *reference layer*. The
//! blessed public entry point is the planned API
//! ([`crate::api::OtProblem`] → [`crate::api::Plan`]), whose executor
//! routes through these functions bitwise-unchanged
//! (`rust/tests/api_equivalence.rs`); the free functions are no longer
//! re-exported by the main prelude — import them via
//! [`crate::prelude::legacy`] (README.md §Migration maps each entry
//! point to its builder form).

mod accelerated;
mod batch;
mod exact;
mod flow;
mod logdomain;
mod schedule;

pub use accelerated::{sinkhorn_accelerated, AccelSolution};
pub use batch::{
    sinkhorn_divergence_batch, solve_batch, solve_batch_log_domain, solve_batch_log_domain_warm,
    solve_batch_stabilized, solve_batch_stabilized_warm, solve_batch_warm,
};
pub use exact::{exact_ot_uniform, hungarian};
pub use flow::{divergence_grad_locations, gradient_flow_step, FlowEval};
pub use logdomain::{sinkhorn_log_domain, sinkhorn_log_domain_warm, sq_euclidean_cost};
pub use schedule::{
    sinkhorn_symmetric, sinkhorn_symmetric_log, sinkhorn_symmetric_log_warm,
    sinkhorn_symmetric_stabilized, sinkhorn_symmetric_stabilized_warm, sinkhorn_symmetric_warm,
    EpsSchedule, WarmSolve, MAX_RUNGS,
};

use crate::config::SinkhornConfig;
use crate::error::{Error, Result};
use crate::kernels::KernelOp;
use crate::linalg;
use crate::runtime::pool::Pool;

/// Output of a Sinkhorn solve.
#[derive(Clone, Debug)]
pub struct SinkhornSolution {
    /// Row scaling u (length n).
    pub u: Vec<f32>,
    /// Column scaling v (length m).
    pub v: Vec<f32>,
    /// The Eq. (6) objective estimate: eps (a^T log u + b^T log v).
    pub objective: f64,
    /// Iterations executed.
    pub iterations: usize,
    /// Final L1 marginal error ||v o K^T u - b||_1.
    pub marginal_error: f64,
    /// Whether the tolerance was reached before `max_iters`.
    pub converged: bool,
}

impl SinkhornSolution {
    /// Dual potentials alpha = eps log u, beta = eps log v.
    pub fn duals(&self, eps: f64) -> (Vec<f32>, Vec<f32>) {
        let a = self.u.iter().map(|&x| (eps * (x as f64).ln()) as f32).collect();
        let b = self.v.iter().map(|&x| (eps * (x as f64).ln()) as f32).collect();
        (a, b)
    }
}

/// Eq. (6): eps (a^T log u + b^T log v), in f64 for stability.
pub fn objective(eps: f64, a: &[f32], b: &[f32], u: &[f32], v: &[f32]) -> f64 {
    let sa: f64 = a.iter().zip(u).map(|(&ai, &ui)| ai as f64 * (ui as f64).ln()).sum();
    let sb: f64 = b.iter().zip(v).map(|(&bi, &vi)| bi as f64 * (vi as f64).ln()).sum();
    eps * (sa + sb)
}

/// Algorithm 1 over any kernel operator.
///
/// Repeats `v <- b / K^T u`, `u <- a / K v` until the L1 marginal error
/// drops below `cfg.tol` (checked every `cfg.check_every` iterations) or
/// `cfg.max_iters` is hit. Errors with [`Error::SinkhornDiverged`] when a
/// scaling goes non-finite or non-positive — the failure mode of
/// non-positivity-safe kernels (Nyström at small eps).
pub fn sinkhorn<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    b: &[f32],
    cfg: &SinkhornConfig,
) -> Result<SinkhornSolution> {
    sinkhorn_core(kernel, a, b, cfg, None).result
}

/// Alg. 1 with an optional warm dual and the final dual reported back —
/// the rung-to-rung chaining entry point of an [`EpsSchedule`]. The
/// warm dual is the a⊗b-relative row potential (see [`WarmSolve`]); with
/// `warm = None` this is exactly [`sinkhorn`].
pub fn sinkhorn_warm<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    b: &[f32],
    cfg: &SinkhornConfig,
    warm: Option<&[f64]>,
) -> Result<WarmSolve> {
    let out = sinkhorn_core(kernel, a, b, cfg, warm);
    out.result.map(|solution| WarmSolve { solution, escalated: false, alpha: out.alpha })
}

/// Outcome of the plain core: the sequential result plus the dual the
/// solve ended on — the final dual on success, the dual from the last
/// checkpoint that passed the finite-positive check on divergence (which
/// is what the log-domain escalation warm-starts from).
pub(crate) struct PlainOutcome {
    pub(crate) result: Result<SinkhornSolution>,
    pub(crate) alpha: Vec<f64>,
}

pub(crate) fn sinkhorn_core<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    b: &[f32],
    cfg: &SinkhornConfig,
    warm: Option<&[f64]>,
) -> PlainOutcome {
    let fail = |e: Error| PlainOutcome { result: Err(e), alpha: Vec::new() };
    let (n, m) = (kernel.rows(), kernel.cols());
    if a.len() != n || b.len() != m {
        return fail(Error::Shape(format!(
            "sinkhorn: kernel {}x{} vs a[{}], b[{}]",
            n,
            m,
            a.len(),
            b.len()
        )));
    }
    if let Some(w) = warm {
        if w.len() != n {
            return fail(Error::Shape(format!(
                "sinkhorn: warm dual [{}] vs kernel {n}x{m}",
                w.len()
            )));
        }
    }
    let mut u: Vec<f32> = match warm {
        Some(w) => schedule::warm_scalings(cfg.epsilon, a, w),
        None => vec![1.0f32; n],
    };
    let mut v = vec![1.0f32; m];
    // Preallocated work buffers — the loop is allocation-free.
    let mut kv = vec![0.0f32; n];
    let mut ktu = vec![0.0f32; m];
    // Last dual that passed a checkpoint (init: the warm dual itself, or
    // the dual of u = 1) — kept in f64 so escalation never restarts cold.
    let mut last_good: Vec<f64> = match warm {
        Some(w) => w.to_vec(),
        None => schedule::alpha_from_scalings(cfg.epsilon, a, &u),
    };

    let check_every = cfg.check_every.max(1);
    let mut iter = 0;
    let mut marginal = f64::INFINITY;
    let mut converged = false;

    while iter < cfg.max_iters {
        // v <- b / K^T u
        kernel.apply_t_into(&u, &mut ktu);
        for j in 0..m {
            v[j] = b[j] / ktu[j];
        }
        // u <- a / K v
        kernel.apply_into(&v, &mut kv);
        for i in 0..n {
            u[i] = a[i] / kv[i];
        }
        iter += 1;

        if iter % check_every == 0 || iter == cfg.max_iters {
            // Divergence check on the scalings themselves.
            if let Some(bad) = first_bad(&u).or_else(|| first_bad(&v)) {
                return PlainOutcome {
                    result: Err(Error::SinkhornDiverged {
                        iter,
                        reason: format!(
                            "non-finite or non-positive scaling ({bad}); kernel {} lost \
                             positivity or eps is too small for f32",
                            kernel.label()
                        ),
                    }),
                    alpha: last_good,
                };
            }
            last_good = schedule::alpha_from_scalings(cfg.epsilon, a, &u);
            // Marginal error ||v o K^T u - b||_1.
            kernel.apply_t_into(&u, &mut ktu);
            marginal = (0..m)
                .map(|j| ((v[j] * ktu[j] - b[j]) as f64).abs())
                .sum();
            if marginal < cfg.tol {
                converged = true;
                break;
            }
        }
    }

    PlainOutcome {
        result: Ok(SinkhornSolution {
            // `-eps log_scale` compensates stabilised kernels (K_true = c K):
            // scaling K by c shifts the dual estimate by -eps log c.
            objective: objective(cfg.epsilon, a, b, &u, &v) - cfg.epsilon * kernel.log_scale(),
            u,
            v,
            iterations: iter,
            marginal_error: marginal,
            converged,
        }),
        alpha: last_good,
    }
}

pub(crate) fn first_bad(xs: &[f32]) -> Option<String> {
    for (i, &x) in xs.iter().enumerate() {
        if !x.is_finite() || x <= 0.0 {
            return Some(format!("index {i} = {x}"));
        }
    }
    None
}

/// Alg. 1 with automatic small-eps escalation: when the plain iteration
/// reports non-finite scalings ([`Error::SinkhornDiverged`]) and
/// `cfg.stabilize` is set, continue on the matrix-free log-domain solver
/// ([`sinkhorn_log_domain_warm`]) through the kernel's
/// [`KernelOp::as_log_kernel`] view, **warm-started from the last dual
/// that passed a checkpoint** — the plain iterations done before the
/// blow-up are no longer thrown away. Returns the solution plus whether
/// the stabilised path was taken (the coordinator exports that as the
/// `service.stabilized_solves` metric).
///
/// Kernels without a usable log-domain view propagate the original
/// divergence error — escalation never masks a genuinely broken kernel.
/// (Nyström gates its clamped signed log view off at runtime exactly
/// when clamping would distort the apply, so its broken-positivity
/// regime lands here.)
pub fn sinkhorn_stabilized<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    b: &[f32],
    cfg: &SinkhornConfig,
) -> Result<(SinkhornSolution, bool)> {
    sinkhorn_stabilized_warm(kernel, a, b, cfg, None).map(|ws| (ws.solution, ws.escalated))
}

/// [`sinkhorn_stabilized`] with warm-start chaining: the annealed
/// executor's per-rung work-horse for the auto-escalate domain.
pub fn sinkhorn_stabilized_warm<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    b: &[f32],
    cfg: &SinkhornConfig,
    warm: Option<&[f64]>,
) -> Result<WarmSolve> {
    let out = sinkhorn_core(kernel, a, b, cfg, warm);
    match out.result {
        Ok(solution) => Ok(WarmSolve { solution, escalated: false, alpha: out.alpha }),
        Err(Error::SinkhornDiverged { iter, reason }) if cfg.stabilize => {
            match kernel.as_log_kernel() {
                Some(log_kernel) => {
                    let mut ws =
                        sinkhorn_log_domain_warm(log_kernel, a, b, cfg, Some(&out.alpha))?;
                    ws.escalated = true;
                    Ok(ws)
                }
                None => Err(Error::SinkhornDiverged { iter, reason }),
            }
        }
        Err(e) => Err(e),
    }
}

/// Eq. (2): the debiased Sinkhorn divergence
/// `W(mu,nu) - (W(mu,mu) + W(nu,nu))/2` from three transport solves.
///
/// The three problems are independent, so when `cfg.threads > 1` they run
/// concurrently on a [`Pool`] (`0` = auto-size to the machine; the pool
/// is capped at 3 — one worker per transport problem). Each solve is
/// deterministic on its own kernel, so the result is identical for every
/// thread count; errors are reported with the same priority as the
/// historical sequential path (xy, then xx, then yy). Each solve runs
/// through [`sinkhorn_stabilized`], so small-eps divergences escalate to
/// the log-domain path when `cfg.stabilize` is set.
///
/// When `cfg.symmetric` is `Some(true)` the xx/yy self-terms run the
/// dedicated one-dual symmetric fixed point
/// ([`sinkhorn_symmetric_stabilized`]) instead of full two-sided solves —
/// half the kernel applies per self-iteration, with the same objective up
/// to solver tolerance (the fixed points differ by a constant that
/// cancels). `None`/`Some(false)` keeps the historical two-sided path;
/// the planned API resolves `None` per plan (`symmetric_self_solves`).
pub fn sinkhorn_divergence<K: KernelOp + Sync + ?Sized>(
    k_xy: &K,
    k_xx: &K,
    k_yy: &K,
    a: &[f32],
    b: &[f32],
    cfg: &SinkhornConfig,
) -> Result<f64> {
    let pool = Pool::new_capped(cfg.threads, 3);
    if cfg.symmetric == Some(true) {
        let (r_xy, r_xx, r_yy) = pool.join3(
            || sinkhorn_stabilized(k_xy, a, b, cfg),
            || sinkhorn_symmetric_stabilized(k_xx, a, cfg),
            || sinkhorn_symmetric_stabilized(k_yy, b, cfg),
        );
        return Ok(r_xy?.0.objective - 0.5 * (r_xx?.0.objective + r_yy?.0.objective));
    }
    let (r_xy, r_xx, r_yy) = pool.join3(
        || sinkhorn_stabilized(k_xy, a, b, cfg),
        || sinkhorn_stabilized(k_xx, a, a, cfg),
        || sinkhorn_stabilized(k_yy, b, b, cfg),
    );
    Ok(r_xy?.0.objective - 0.5 * (r_xx?.0.objective + r_yy?.0.objective))
}

/// The transport plan `P = diag(u) K diag(v)` materialised (tests / small
/// problems only).
pub fn transport_plan<K: KernelOp + ?Sized>(
    kernel: &K,
    sol: &SinkhornSolution,
) -> crate::linalg::Mat {
    let (n, m) = (kernel.rows(), kernel.cols());
    let mut plan = crate::linalg::Mat::zeros(n, m);
    // Column j of P is u o (K e_j v_j).
    let mut e = vec![0.0f32; m];
    let mut col = vec![0.0f32; n];
    for j in 0..m {
        e[j] = 1.0;
        kernel.apply_into(&e, &mut col);
        e[j] = 0.0;
        for i in 0..n {
            plan[(i, j)] = sol.u[i] * col[i] * sol.v[j];
        }
    }
    plan
}

/// Relative deviation used in Figures 1/3/5:
/// `D = 100 (ROT - ROT_hat)/|ROT| + 100` (100 = exact).
pub fn deviation_score(ground_truth: f64, estimate: f64) -> f64 {
    100.0 * (ground_truth - estimate) / ground_truth.abs() + 100.0
}

/// The canonical tight-tolerance solver profile behind every "ground
/// truth" ROT value (the paper's `Sin` converged hard): single thread,
/// plain domain, 20k iterations, 1e-6 L1 tolerance. Shared by
/// [`ground_truth_rot`] and the planned API's
/// [`OtProblem::ground_truth`](crate::api::OtProblem::ground_truth) so
/// the constants live in exactly one place.
pub fn ground_truth_config(eps: f64) -> SinkhornConfig {
    SinkhornConfig {
        epsilon: eps,
        max_iters: 20_000,
        tol: 1e-6,
        check_every: 20,
        threads: 1,
        stabilize: false,
        max_batch: 1,
        // Ground truth is always the direct, two-sided solve: no
        // annealing schedule, no symmetric shortcut.
        anneal: Some(false),
        anneal_decay: 0.5,
        symmetric: Some(false),
    }
}

/// Converged dense Sinkhorn used as the "ground truth" ROT value in the
/// tradeoff figures (the paper's `Sin` with a tight tolerance).
pub fn ground_truth_rot<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    b: &[f32],
    eps: f64,
) -> Result<f64> {
    Ok(sinkhorn(kernel, a, b, &ground_truth_config(eps))?.objective)
}

/// L1 marginal feasibility of a solution (diagnostic).
pub fn marginal_errors<K: KernelOp + ?Sized>(
    kernel: &K,
    sol: &SinkhornSolution,
    a: &[f32],
    b: &[f32],
) -> (f64, f64) {
    let ku = kernel.apply_t(&sol.u);
    let col: Vec<f32> = sol.v.iter().zip(&ku).map(|(&vj, &k)| vj * k).collect();
    let kv = kernel.apply(&sol.v);
    let row: Vec<f32> = sol.u.iter().zip(&kv).map(|(&ui, &k)| ui * k).collect();
    (linalg::l1_diff(&row, a) as f64, linalg::l1_diff(&col, b) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SinkhornConfig;
    use crate::data::{self, Measure};
    use crate::features::GaussianFeatureMap;
    use crate::kernels::{DenseKernel, FactoredKernel, NystromKernel};
    use crate::linalg::Mat;
    use crate::rng::Rng;

    fn cfg(eps: f64) -> SinkhornConfig {
        SinkhornConfig {
            epsilon: eps,
            max_iters: 5000,
            tol: 1e-5,
            check_every: 5,
            threads: 1,
            stabilize: false,
            max_batch: 1,
            anneal: None,
            anneal_decay: 0.5,
            symmetric: None,
        }
    }

    fn uniform(n: usize) -> Vec<f32> {
        vec![1.0 / n as f32; n]
    }

    #[test]
    fn converges_on_small_dense_problem() {
        let mut rng = Rng::seed_from(0);
        let (mu, nu) = data::gaussian_blobs(50, &mut rng);
        let k = DenseKernel::from_measures(&mu, &nu, 0.5);
        let sol = sinkhorn(&k, &mu.weights, &nu.weights, &cfg(0.5)).unwrap();
        assert!(sol.converged, "did not converge: err {}", sol.marginal_error);
        assert!(sol.marginal_error < 1e-5);
    }

    #[test]
    fn marginals_feasible_at_convergence() {
        let mut rng = Rng::seed_from(1);
        let (mu, nu) = data::gaussian_blobs(40, &mut rng);
        let k = DenseKernel::from_measures(&mu, &nu, 1.0);
        let sol = sinkhorn(&k, &mu.weights, &nu.weights, &cfg(1.0)).unwrap();
        let (row_err, col_err) = marginal_errors(&k, &sol, &mu.weights, &nu.weights);
        assert!(row_err < 1e-4, "row err {row_err}");
        assert!(col_err < 1e-4, "col err {col_err}");
    }

    #[test]
    fn plan_mass_is_one() {
        let mut rng = Rng::seed_from(2);
        let (mu, nu) = data::gaussian_blobs(20, &mut rng);
        let k = DenseKernel::from_measures(&mu, &nu, 0.5);
        let sol = sinkhorn(&k, &mu.weights, &nu.weights, &cfg(0.5)).unwrap();
        let plan = transport_plan(&k, &sol);
        let mass: f64 = plan.data().iter().map(|&x| x as f64).sum();
        assert!((mass - 1.0).abs() < 1e-4, "mass {mass}");
        assert!(plan.min_entry() >= 0.0);
    }

    #[test]
    fn factored_and_dense_agree_on_same_kernel() {
        // Run Alg. 1 on K given as factors and as a materialised matrix:
        // identical fixed point.
        let mut rng = Rng::seed_from(3);
        let (mu, nu) = data::gaussian_blobs(60, &mut rng);
        let fm = GaussianFeatureMap::fit(&mu, &nu, 0.5, 64, &mut rng);
        let fk = FactoredKernel::from_measures(&fm, &mu, &nu);
        let dk = DenseKernel::from_matrix(fk.to_dense(), 0.5);
        let s1 = sinkhorn(&fk, &mu.weights, &nu.weights, &cfg(0.5)).unwrap();
        let s2 = sinkhorn(&dk, &mu.weights, &nu.weights, &cfg(0.5)).unwrap();
        assert!(
            (s1.objective - s2.objective).abs() < 1e-4 * s2.objective.abs().max(1.0),
            "{} vs {}",
            s1.objective,
            s2.objective
        );
    }

    #[test]
    fn rf_estimate_close_to_ground_truth_moderate_eps() {
        // The headline behaviour: RF with enough features approximates the
        // true ROT value (deviation score near 100).
        let mut rng = Rng::seed_from(4);
        let (mu, nu) = data::gaussian_blobs(150, &mut rng);
        let eps = 1.0;
        let dense = DenseKernel::from_measures(&mu, &nu, eps);
        let truth = ground_truth_rot(&dense, &mu.weights, &nu.weights, eps).unwrap();
        let fm = GaussianFeatureMap::fit(&mu, &nu, eps, 1500, &mut rng);
        let fk = FactoredKernel::from_measures(&fm, &mu, &nu);
        let est = sinkhorn(&fk, &mu.weights, &nu.weights, &cfg(eps)).unwrap().objective;
        let dev = deviation_score(truth, est);
        assert!((dev - 100.0).abs() < 5.0, "deviation {dev} (truth {truth}, est {est})");
    }

    #[test]
    fn identical_measures_have_near_zero_divergence() {
        let mut rng = Rng::seed_from(5);
        let (mu, _) = data::gaussian_blobs(30, &mut rng);
        let fm = GaussianFeatureMap::fit(&mu, &mu, 0.5, 128, &mut rng);
        let k = FactoredKernel::from_measures(&fm, &mu, &mu);
        let kxx = FactoredKernel::from_measures(&fm, &mu, &mu);
        let kyy = FactoredKernel::from_measures(&fm, &mu, &mu);
        let d =
            sinkhorn_divergence(&k, &kxx, &kyy, &mu.weights, &mu.weights, &cfg(0.5)).unwrap();
        assert!(d.abs() < 1e-6, "divergence {d}");
    }

    #[test]
    fn divergence_positive_and_monotone_in_separation() {
        let mut rng = Rng::seed_from(6);
        let n = 40;
        let mk = |shift: f32, rng: &mut Rng| {
            Measure::uniform(Mat::from_fn(n, 2, |_, j| {
                rng.normal_f32() * 0.5 + if j == 0 { shift } else { 0.0 }
            }))
        };
        let mu = mk(0.0, &mut rng);
        let nu1 = mk(1.0, &mut rng);
        let nu2 = mk(3.0, &mut rng);
        let eps = 0.5;
        let div = |mu: &Measure, nu: &Measure, rng: &mut Rng| {
            let fm = GaussianFeatureMap::fit(mu, nu, eps, 1000, rng);
            let kxy = FactoredKernel::from_measures(&fm, mu, nu);
            let kxx = FactoredKernel::from_measures(&fm, mu, mu);
            let kyy = FactoredKernel::from_measures(&fm, nu, nu);
            sinkhorn_divergence(&kxy, &kxx, &kyy, &mu.weights, &nu.weights, &cfg(eps)).unwrap()
        };
        let d1 = div(&mu, &nu1, &mut rng);
        let d2 = div(&mu, &nu2, &mut rng);
        assert!(d1 > 0.0, "d1 {d1}");
        assert!(d2 > d1, "d2 {d2} should exceed d1 {d1}");
    }

    #[test]
    fn nystrom_small_eps_fails_loudly() {
        // The contrast the paper draws: Nyström at small eps breaks
        // Sinkhorn; the solver reports it as a typed error instead of NaN.
        let mut rng = Rng::seed_from(7);
        let (mu, nu) = data::gaussian_blobs(80, &mut rng);
        let nk = NystromKernel::from_measures(&mu, &nu, 0.01, 8, &mut rng);
        let res = sinkhorn(&nk, &mu.weights, &nu.weights, &cfg(0.01));
        assert!(res.is_err(), "expected divergence, got {:?}", res.map(|s| s.objective));
    }

    #[test]
    fn shape_mismatch_is_error() {
        let mut rng = Rng::seed_from(8);
        let (mu, nu) = data::gaussian_blobs(10, &mut rng);
        let k = DenseKernel::from_measures(&mu, &nu, 0.5);
        let bad = vec![0.1f32; 7];
        assert!(matches!(
            sinkhorn(&k, &bad, &nu.weights, &cfg(0.5)),
            Err(Error::Shape(_))
        ));
    }

    #[test]
    fn deviation_score_exact_is_100() {
        assert!((deviation_score(2.5, 2.5) - 100.0).abs() < 1e-12);
        assert!(deviation_score(2.5, 2.0) > 100.0); // underestimate
        assert!(deviation_score(2.5, 3.0) < 100.0); // overestimate
    }

    #[test]
    fn duals_recover_objective() {
        let mut rng = Rng::seed_from(9);
        let (mu, nu) = data::gaussian_blobs(25, &mut rng);
        let eps = 0.5;
        let k = DenseKernel::from_measures(&mu, &nu, eps);
        let sol = sinkhorn(&k, &mu.weights, &nu.weights, &cfg(eps)).unwrap();
        let (alpha, beta) = sol.duals(eps);
        let w: f64 = mu
            .weights
            .iter()
            .zip(&alpha)
            .map(|(&ai, &al)| ai as f64 * al as f64)
            .sum::<f64>()
            + nu.weights.iter().zip(&beta).map(|(&bi, &be)| bi as f64 * be as f64).sum::<f64>();
        assert!((w - sol.objective).abs() < 1e-5 * sol.objective.abs().max(1.0));
    }

    #[test]
    fn more_iterations_never_hurt_marginal_error() {
        let mut rng = Rng::seed_from(10);
        let (mu, nu) = data::gaussian_blobs(30, &mut rng);
        let k = DenseKernel::from_measures(&mu, &nu, 0.3);
        let few = SinkhornConfig { max_iters: 3, tol: 0.0, check_every: 1, ..cfg(0.3) };
        let many = SinkhornConfig { max_iters: 300, tol: 0.0, check_every: 1, ..cfg(0.3) };
        let e1 = sinkhorn(&k, &mu.weights, &nu.weights, &few).unwrap().marginal_error;
        let e2 = sinkhorn(&k, &mu.weights, &nu.weights, &many).unwrap().marginal_error;
        assert!(e2 <= e1 * 1.01, "e1={e1} e2={e2}");
    }

    #[test]
    fn uniform_helper() {
        assert_eq!(uniform(4), vec![0.25; 4]);
    }

    /// A factored kernel whose f32 applies *provably* produce non-finite
    /// scalings: every factor entry sits near 1e-30, so every product in
    /// `Phi_x (Phi_y^T v)` is ~1e-60 — far below the smallest f32
    /// subnormal (~1.4e-45) — and flushes to exact zero. `K^T u` is then
    /// identically zero and Alg. 1's very first update divides by it.
    /// This is the real small-eps mechanism (raw Gibbs values below f32
    /// range), made deterministic; the log-domain view of the same kernel
    /// works in f64 on the logs (~-69 per factor) and is perfectly
    /// conditioned. Mild entry variation keeps the problem non-trivial.
    fn underflowing_kernel(n: usize, m: usize, r: usize) -> FactoredKernel {
        let phi_x = crate::linalg::Mat::from_fn(n, r, |i, k| {
            1e-30f32 * (1.0 + 0.1 * (((i + 2 * k) % 5) as f32))
        });
        let phi_y = crate::linalg::Mat::from_fn(m, r, |j, k| {
            1e-30f32 * (1.0 + 0.1 * (((2 * j + k) % 7) as f32))
        });
        FactoredKernel::from_factors(phi_x, phi_y)
    }

    #[test]
    fn escalation_setup_diverges() {
        let (n, m) = (12, 10);
        let k_xy = underflowing_kernel(n, m, 6);
        let res = sinkhorn(&k_xy, &uniform(n), &uniform(m), &cfg(1e-3));
        match res {
            Err(Error::SinkhornDiverged { .. }) => {}
            other => panic!(
                "expected plain f32 Alg. 1 to diverge on underflowing factors, got {:?}",
                other.map(|s| s.objective)
            ),
        }
    }

    #[test]
    fn sinkhorn_stabilized_escalates_and_reports_it() {
        let (n, m) = (12, 10);
        let k_xy = underflowing_kernel(n, m, 6);
        let cfg_tiny = SinkhornConfig { stabilize: true, ..cfg(1e-3) };
        let (sol, stabilized) =
            sinkhorn_stabilized(&k_xy, &uniform(n), &uniform(m), &cfg_tiny).unwrap();
        assert!(stabilized, "the log-domain path must have been taken");
        assert!(sol.objective.is_finite());
        assert!(sol.marginal_error < 1e-3, "err {}", sol.marginal_error);
        // At moderate eps on healthy factors nothing escalates and the
        // flag stays false.
        let mut rng = Rng::seed_from(22);
        let (mu2, nu2) = data::gaussian_blobs(25, &mut rng);
        let fm = GaussianFeatureMap::fit(&mu2, &nu2, 0.5, 64, &mut rng);
        let k = FactoredKernel::from_measures_stabilized(&fm, &mu2, &nu2);
        let cfg_mid = SinkhornConfig { stabilize: true, ..cfg(0.5) };
        let (_, stabilized) =
            sinkhorn_stabilized(&k, &mu2.weights, &nu2.weights, &cfg_mid).unwrap();
        assert!(!stabilized);
    }

    #[test]
    fn divergence_escalates_when_stabilize_on_and_errors_when_off() {
        let n = 12;
        let k_xy = underflowing_kernel(n, n, 6);
        let k_xx = underflowing_kernel(n, n, 6);
        let k_yy = underflowing_kernel(n, n, 6);
        let w = uniform(n);

        let off = cfg(1e-3);
        let err = sinkhorn_divergence(&k_xy, &k_xx, &k_yy, &w, &w, &off);
        assert!(err.is_err(), "stabilize=false must surface the divergence error");

        let on = SinkhornConfig { stabilize: true, ..cfg(1e-3) };
        let d = sinkhorn_divergence(&k_xy, &k_xx, &k_yy, &w, &w, &on)
            .expect("escalated divergence");
        assert!(d.is_finite());
    }

    #[test]
    fn stabilized_does_not_mask_kernels_without_log_view() {
        // Nyström gates its clamped signed log view off whenever
        // clamping would distort the apply — which is exactly the
        // broken-positivity small-eps regime. So even with stabilize
        // on, escalation finds no log view to land in and the
        // divergence stays a typed error instead of converging on a
        // silently-wrong kernel.
        let mut rng = Rng::seed_from(24);
        let (mu, nu) = data::gaussian_blobs(80, &mut rng);
        let nk = NystromKernel::from_measures(&mu, &nu, 0.01, 8, &mut rng);
        let cfg = SinkhornConfig { stabilize: true, ..cfg(0.01) };
        let res = sinkhorn_stabilized(&nk, &mu.weights, &nu.weights, &cfg);
        assert!(matches!(res, Err(Error::SinkhornDiverged { .. })));
    }
}
