//! Sinkhorn-divergence gradient flow on point locations (Prop 3.2's
//! `∇_X W = -eps (∂ξ/∂X)^T u (ζ v)^T` chained through the Lemma-1 feature
//! map) — the "fully differentiable in the inputs" capability the paper
//! contrasts against Nyström (whose data-dependent approximation is not
//! differentiable at the input locations).
//!
//! [`divergence_grad_locations`] returns ∇_X Wbar(mu(X), nu) for the
//! debiased divergence (Eq. 2), treating the Sinkhorn duals as constants
//! (envelope theorem). [`gradient_flow_step`] takes one explicit-Euler step
//! of the flow X <- X - lr ∇_X Wbar.

use crate::config::SinkhornConfig;
use crate::data::Measure;
use crate::error::Result;
use crate::features::{FeatureMap, GaussianFeatureMap};
use crate::kernels::FactoredKernel;
use crate::linalg::{self, Mat};

use super::{sinkhorn, SinkhornSolution};

/// Upstream gradient w.r.t. Phi_x for one transport problem:
/// `dW/dPhi_x[i, k] = -eps u_i (Phi_y^T v)_k`.
fn upstream_left(eps: f64, sol: &SinkhornSolution, phi_y: &Mat) -> Mat {
    let kyv = linalg::matvec_t(phi_y, &sol.v);
    let mut m = Mat::zeros(sol.u.len(), kyv.len());
    for (i, &ui) in sol.u.iter().enumerate() {
        let row = m.row_mut(i);
        for (cell, &k) in row.iter_mut().zip(&kyv) {
            *cell = (-eps as f32) * ui * k;
        }
    }
    m
}

/// For the self-problem W(mu, mu), Phi_x appears on both sides; the two
/// contributions add: `-eps [u (Phi^T v)^T + v (Phi^T u)^T]`.
fn upstream_both(eps: f64, sol: &SinkhornSolution, phi: &Mat) -> Mat {
    let mut g = upstream_left(eps, sol, phi);
    let kxu = linalg::matvec_t(phi, &sol.u);
    for (j, &vj) in sol.v.iter().enumerate() {
        let row = g.row_mut(j);
        for (cell, &k) in row.iter_mut().zip(&kxu) {
            *cell += (-eps as f32) * vj * k;
        }
    }
    g
}

/// Result of one divergence-with-gradient evaluation.
#[derive(Debug)]
pub struct FlowEval {
    /// Wbar(mu, nu) (Eq. 2).
    pub divergence: f64,
    /// ∇_X Wbar, shape (n, d).
    pub grad: Mat,
}

/// Evaluate the debiased divergence and its location gradient for the
/// source measure `mu` (weights fixed, uniform flow on the support).
pub fn divergence_grad_locations(
    map: &GaussianFeatureMap,
    mu: &Measure,
    nu: &Measure,
    cfg: &SinkhornConfig,
) -> Result<FlowEval> {
    let eps = cfg.epsilon;
    let phi_x = map.feature_matrix(&mu.points);
    let phi_y = map.feature_matrix(&nu.points);
    let k_xy = FactoredKernel::from_factors(phi_x.clone(), phi_y.clone());
    let k_xx = FactoredKernel::from_factors(phi_x.clone(), phi_x.clone());
    let k_yy = FactoredKernel::from_factors(phi_y.clone(), phi_y.clone());
    let s_xy = sinkhorn(&k_xy, &mu.weights, &nu.weights, cfg)?;
    let s_xx = sinkhorn(&k_xx, &mu.weights, &mu.weights, cfg)?;
    // W(nu, nu) does not depend on X; only its value enters the divergence.
    let s_yy = sinkhorn(&k_yy, &nu.weights, &nu.weights, cfg)?;
    let divergence = s_xy.objective - 0.5 * (s_xx.objective + s_yy.objective);

    // d Wbar / d Phi_x = upstream(xy) - 0.5 * upstream_both(xx).
    let mut up = upstream_left(eps, &s_xy, &phi_y);
    let both = upstream_both(eps, &s_xx, &phi_x);
    for (dst, &src) in up.data_mut().iter_mut().zip(both.data()) {
        *dst -= 0.5 * src;
    }
    let grad = map.grad_points(&mu.points, &phi_x, &up);
    Ok(FlowEval { divergence, grad })
}

/// One explicit-Euler flow step: `X <- X - lr * ∇_X Wbar`. Returns the
/// divergence *before* the step.
pub fn gradient_flow_step(
    map: &GaussianFeatureMap,
    mu: &mut Measure,
    nu: &Measure,
    cfg: &SinkhornConfig,
    lr: f32,
) -> Result<f64> {
    let eval = divergence_grad_locations(map, mu, nu, cfg)?;
    for (x, &g) in mu.points.data_mut().iter_mut().zip(eval.grad.data()) {
        *x -= lr * g;
    }
    Ok(eval.divergence)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::rng::Rng;

    fn cfg(eps: f64) -> SinkhornConfig {
        SinkhornConfig {
            epsilon: eps,
            max_iters: 2000,
            tol: 1e-6,
            check_every: 10,
            threads: 1,
            stabilize: false,
            max_batch: 1,
            anneal: None,
            anneal_decay: 0.5,
            symmetric: None,
        }
    }

    #[test]
    fn location_gradient_matches_finite_difference() {
        let mut rng = Rng::seed_from(0);
        let n = 12;
        let mu = data::gaussian_cloud(n, 2, 0.0, 0.5, &mut rng);
        let nu = data::gaussian_cloud(n, 2, 1.0, 0.5, &mut rng);
        let eps = 0.8;
        let map = GaussianFeatureMap::fit(&mu, &nu, eps, 512, &mut rng);
        let eval = divergence_grad_locations(&map, &mu, &nu, &cfg(eps)).unwrap();

        let div_of = |mu: &data::Measure| -> f64 {
            divergence_grad_locations(&map, mu, &nu, &cfg(eps)).unwrap().divergence
        };
        let h = 5e-3;
        for &(i, c) in &[(0usize, 0usize), (5, 1), (11, 0)] {
            let mut mp = mu.clone();
            mp.points[(i, c)] += h;
            let up = div_of(&mp);
            mp.points[(i, c)] -= 2.0 * h;
            let dn = div_of(&mp);
            let num = (up - dn) / (2.0 * h as f64);
            let ana = eval.grad[(i, c)] as f64;
            assert!(
                (num - ana).abs() < 0.1 * num.abs().max(0.05),
                "point {i} coord {c}: fd {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn flow_decreases_divergence() {
        let mut rng = Rng::seed_from(1);
        let n = 40;
        let mut mu = data::gaussian_cloud(n, 2, 0.0, 0.3, &mut rng);
        let nu = data::gaussian_cloud(n, 2, 2.0, 0.3, &mut rng);
        let eps = 0.5;
        let map = GaussianFeatureMap::new(eps, 5.0, 2, 800, &mut rng);
        let mut last = f64::INFINITY;
        let mut first = None;
        for _ in 0..30 {
            let d = gradient_flow_step(&map, &mut mu, &nu, &cfg(eps), 0.5).unwrap();
            if first.is_none() {
                first = Some(d);
            }
            last = d;
        }
        let first = first.unwrap();
        assert!(
            last < first * 0.5,
            "flow should at least halve the divergence: {first} -> {last}"
        );
    }

    #[test]
    fn grad_points_zero_for_matched_clouds() {
        // mu == nu with identical weights: Wbar = 0 is a minimum, gradient
        // ~ 0 (up to the MC noise of shared features, which cancels exactly
        // here because phi_x == phi_y).
        let mut rng = Rng::seed_from(2);
        let mu = data::gaussian_cloud(15, 2, 0.0, 0.5, &mut rng);
        let map = GaussianFeatureMap::fit(&mu, &mu, 0.5, 256, &mut rng);
        let eval = divergence_grad_locations(&map, &mu, &mu, &cfg(0.5)).unwrap();
        assert!(eval.divergence.abs() < 1e-6);
        let gmax = eval.grad.data().iter().fold(0.0f32, |m, &g| m.max(g.abs()));
        assert!(gmax < 1e-3, "gradient at the optimum should vanish, got {gmax}");
    }
}
