//! Log-domain stabilised Sinkhorn, matrix-free over any [`LogKernelOp`].
//!
//! At very small eps the scalings u, v overflow/underflow f32 (and even
//! f64). The classic fix iterates on the dual potentials directly:
//!
//!   alpha_i <- -eps logsumexp_j(log K_ij + beta_j/eps + log b_j)   (row)
//!   beta_j  <- -eps logsumexp_i(log K_ij + alpha_i/eps + log a_i)  (col)
//!
//! each update a row/col logsumexp of `log K + input` — exactly the
//! [`LogKernelOp`] contract. The dense baseline streams `-cost/eps` at
//! O(nm)/update; the paper's factored kernel nests the logsumexp through
//! its log factors at **O(r(n+m))/update and memory**, never
//! materialising an n×m matrix, so stabilisation keeps the linear-time
//! claim intact. [`sinkhorn_divergence`](super::sinkhorn_divergence) and
//! the coordinator escalate here automatically when plain Alg. 1 reports
//! non-finite scalings (`sinkhorn.stabilize`); the tradeoff benches use
//! the dense instance as the small-eps ground truth. The eps sweep in
//! EXPERIMENTS.md §Stabilisation records where each path lives.
//!
//! The per-entry f64 `exp` that prices every update runs on the SIMD
//! core's dispatched kernels: the AVX2+FMA arm evaluates it through the
//! ≤ 2 ulp vectorised polynomial (`special/vexp.rs`), the scalar arm
//! through libm — per-arm thread-count determinism and the solver's
//! numeric contract are unchanged (EXPERIMENTS.md §Perf, "SIMD core").

use crate::config::SinkhornConfig;
use crate::error::{Error, Result};
use crate::kernels::LogKernelOp;
use crate::linalg::Mat;

use super::schedule::WarmSolve;
use super::SinkhornSolution;

/// Log-domain Sinkhorn over any log-space kernel operator.
///
/// The returned duals are those of the kernel the operator represents
/// (for stabilised factored kernels: the *true* kernel, so no
/// `log_scale` correction applies — the objective is directly comparable
/// to a dense solve of the same kernel). The f32 scalings in the
/// solution are `u_i = a_i exp(alpha_i / eps)` and may saturate f32 at
/// extreme eps; the objective itself is computed from the f64 duals.
pub fn sinkhorn_log_domain<K: LogKernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    b: &[f32],
    cfg: &SinkhornConfig,
) -> Result<SinkhornSolution> {
    sinkhorn_log_domain_warm(kernel, a, b, cfg, None).map(|ws| ws.solution)
}

/// [`sinkhorn_log_domain`] with an optional warm dual and the final f64
/// dual reported back. The f64 dual is the escalation/annealing currency
/// — extracting it from the solution's f32 scalings would saturate at
/// the small eps this solver exists for, so it travels directly. With
/// `warm = None` (or a zero dual) this is exactly the cold solve.
pub fn sinkhorn_log_domain_warm<K: LogKernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    b: &[f32],
    cfg: &SinkhornConfig,
    warm: Option<&[f64]>,
) -> Result<WarmSolve> {
    let (n, m) = kernel.shape();
    if a.len() != n || b.len() != m {
        return Err(Error::Shape(format!(
            "log-domain sinkhorn: kernel {n}x{m} vs a[{}], b[{}]",
            a.len(),
            b.len()
        )));
    }
    if let Some(w) = warm {
        if w.len() != n {
            return Err(Error::Shape(format!(
                "log-domain sinkhorn: warm dual [{}] vs kernel {n}x{m}",
                w.len()
            )));
        }
    }
    let eps = cfg.epsilon;
    let log_a: Vec<f64> = a.iter().map(|&x| (x as f64).ln()).collect();
    let log_b: Vec<f64> = b.iter().map(|&x| (x as f64).ln()).collect();
    let mut alpha: Vec<f64> = match warm {
        Some(w) => w.to_vec(),
        None => vec![0.0f64; n],
    };
    let mut beta = vec![0.0f64; m];

    let check_every = cfg.check_every.max(1);
    let mut iter = 0;
    let mut marginal = f64::INFINITY;
    let mut converged = false;

    // Preallocated operator inputs/outputs — the loop is allocation-free
    // apart from whatever the operator itself scratches (O(r) for the
    // factored kernel).
    let mut row_in = vec![0.0f64; n];
    let mut col_in = vec![0.0f64; m];
    let mut row_out = vec![0.0f64; n];
    let mut col_out = vec![0.0f64; m];

    while iter < cfg.max_iters {
        // beta update: beta_j = -eps logsumexp_i(log K_ij + alpha_i/eps + log a_i).
        for i in 0..n {
            row_in[i] = alpha[i] / eps + log_a[i];
        }
        kernel.apply_log_t(&row_in, &mut col_out);
        for j in 0..m {
            beta[j] = -eps * col_out[j];
        }
        // alpha update.
        for j in 0..m {
            col_in[j] = beta[j] / eps + log_b[j];
        }
        kernel.apply_log(&col_in, &mut row_out);
        for i in 0..n {
            alpha[i] = -eps * row_out[i];
        }
        iter += 1;

        if iter % check_every == 0 || iter == cfg.max_iters {
            if let Some(bad) = first_non_finite(&alpha).or_else(|| first_non_finite(&beta)) {
                return Err(Error::SinkhornDiverged {
                    iter,
                    reason: format!(
                        "non-finite dual potential ({bad}) in log-domain sinkhorn on {}; the \
                         kernel has an empty (all -inf) row or column",
                        kernel.describe()
                    ),
                });
            }
            // Column marginal of P_ij = exp((alpha_i + beta_j)/eps + log K_ij
            // + log a_i + log b_j): reuse the operator with the fresh alpha.
            for i in 0..n {
                row_in[i] = alpha[i] / eps + log_a[i];
            }
            kernel.apply_log_t(&row_in, &mut col_out);
            marginal = 0.0;
            for j in 0..m {
                let col_mass = (col_out[j] + beta[j] / eps + log_b[j]).exp();
                marginal += (col_mass - b[j] as f64).abs();
            }
            if marginal < cfg.tol {
                converged = true;
                break;
            }
        }
    }

    // Objective via duals. These (alpha, beta) are the duals of the
    // a⊗b-relative formulation (the plan is P_ij = a_i b_j
    // exp((alpha_i + beta_j)/eps + log K_ij)), i.e. the kernel-form
    // scalings are u_i = a_i e^{alpha_i/eps}. Converting to Eq. (6)'s
    // eps(a^T log u + b^T log v) adds the entropy offset
    // eps (a^T log a + b^T log b).
    let offset: f64 = eps
        * (a.iter().map(|&ai| (ai as f64) * (ai as f64).ln()).sum::<f64>()
            + b.iter().map(|&bi| (bi as f64) * (bi as f64).ln()).sum::<f64>());
    let objective: f64 = a.iter().zip(&alpha).map(|(&ai, &al)| ai as f64 * al).sum::<f64>()
        + b.iter().zip(&beta).map(|(&bi, &be)| bi as f64 * be).sum::<f64>()
        + offset;

    let solution = SinkhornSolution {
        u: alpha
            .iter()
            .zip(a)
            .map(|(&x, &ai)| (ai as f64 * (x / eps).exp()) as f32)
            .collect(),
        v: beta
            .iter()
            .zip(b)
            .map(|(&x, &bi)| (bi as f64 * (x / eps).exp()) as f32)
            .collect(),
        objective,
        iterations: iter,
        marginal_error: marginal,
        converged,
    };
    Ok(WarmSolve { solution, escalated: false, alpha })
}

pub(crate) fn first_non_finite(xs: &[f64]) -> Option<String> {
    xs.iter()
        .enumerate()
        .find(|(_, x)| !x.is_finite())
        .map(|(i, x)| format!("index {i} = {x}"))
}

/// Squared-Euclidean cost matrix helper for the log-domain path.
pub fn sq_euclidean_cost(x: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.cols(), y.cols());
    Mat::from_fn(x.rows(), y.rows(), |i, j| {
        x.row(i)
            .iter()
            .zip(y.row(j))
            .map(|(&a, &b)| (a - b) * (a - b))
            .sum()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::features::{FeatureMap, GaussianFeatureMap};
    use crate::kernels::{CostMatrixLogKernel, DenseKernel, FactoredKernel};
    use crate::rng::Rng;
    use crate::sinkhorn::sinkhorn;

    fn cfg(eps: f64) -> SinkhornConfig {
        SinkhornConfig {
            epsilon: eps,
            max_iters: 3000,
            tol: 1e-6,
            check_every: 10,
            threads: 1,
            stabilize: false,
            max_batch: 1,
            anneal: None,
            anneal_decay: 0.5,
            symmetric: None,
        }
    }

    #[test]
    fn matches_plain_sinkhorn_at_moderate_eps() {
        let mut rng = Rng::seed_from(0);
        let (mu, nu) = data::gaussian_blobs(30, &mut rng);
        let eps = 0.5;
        let cost = sq_euclidean_cost(&mu.points, &nu.points);
        let plain = sinkhorn(
            &DenseKernel::from_measures(&mu, &nu, eps),
            &mu.weights,
            &nu.weights,
            &cfg(eps),
        )
        .unwrap();
        let logd = sinkhorn_log_domain(
            &CostMatrixLogKernel::new(&cost, eps),
            &mu.weights,
            &nu.weights,
            &cfg(eps),
        )
        .unwrap();
        assert!(
            (plain.objective - logd.objective).abs() < 1e-3 * plain.objective.abs().max(1.0),
            "plain {} logdomain {}",
            plain.objective,
            logd.objective
        );
    }

    #[test]
    fn dense_kernel_and_cost_adapter_agree() {
        // DenseKernel's log view and the borrowed-cost adapter are the
        // same operator; the solver must not care which it gets.
        let mut rng = Rng::seed_from(4);
        let (mu, nu) = data::gaussian_blobs(20, &mut rng);
        let eps = 0.05;
        let dk = DenseKernel::from_measures(&mu, &nu, eps);
        let via_kernel =
            sinkhorn_log_domain(&dk, &mu.weights, &nu.weights, &cfg(eps)).unwrap();
        let via_cost = sinkhorn_log_domain(
            &CostMatrixLogKernel::new(dk.cost(), eps),
            &mu.weights,
            &nu.weights,
            &cfg(eps),
        )
        .unwrap();
        assert_eq!(via_kernel.objective.to_bits(), via_cost.objective.to_bits());
    }

    #[test]
    fn survives_tiny_eps_where_plain_fails_or_stalls() {
        // eps so small the plain kernel underflows rows: log-domain still
        // converges to a finite objective.
        let mut rng = Rng::seed_from(1);
        let (mu, nu) = data::gaussian_blobs(25, &mut rng);
        let eps = 0.002;
        let cost = sq_euclidean_cost(&mu.points, &nu.points);
        let logd = sinkhorn_log_domain(
            &CostMatrixLogKernel::new(&cost, eps),
            &mu.weights,
            &nu.weights,
            &cfg(eps),
        )
        .unwrap();
        assert!(logd.objective.is_finite());
        assert!(logd.marginal_error < 1e-3, "err {}", logd.marginal_error);
        // As eps -> 0 the entropic OT value approaches the unregularised
        // OT cost, which is at least the squared distance between means.
        assert!(logd.objective > 0.0);
    }

    #[test]
    fn factored_matches_dense_log_domain_at_small_eps() {
        // The acceptance property of the matrix-free refactor: on the
        // *same* RF kernel, the O(r(n+m)) factored log-domain solve and a
        // dense log-domain solve over the materialised RF cost agree to
        // 1e-4 relative — at an eps (1e-3 scale) where the f32 factor
        // representation is floored and plain Alg. 1 is at best solving
        // the wrong (clamped) kernel (see EXPERIMENTS.md §Stabilisation;
        // the guaranteed-divergence regime is pinned by
        // escalation_setup_diverges in sinkhorn/mod.rs).
        let mut rng = Rng::seed_from(2);
        let (mu, nu) = data::gaussian_blobs(20, &mut rng);
        let eps = 1e-3;
        let map = GaussianFeatureMap::fit(&mu, &nu, eps, 32, &mut rng);
        let lx = map.log_feature_matrix(&mu.points);
        let ly = map.log_feature_matrix(&nu.points);
        let fk = FactoredKernel::from_log_factors(lx.clone(), ly.clone());

        // Materialise the RF cost C_ij = -eps logsumexp_k(lx_ik + ly_jk)
        // in f64, then hand it to the dense path.
        let (n, r) = lx.shape();
        let m = ly.rows();
        let cost = Mat::from_fn(n, m, |i, j| {
            let mx = (0..r)
                .map(|k| lx[(i, k)] as f64 + ly[(j, k)] as f64)
                .fold(f64::NEG_INFINITY, f64::max);
            let s: f64 = (0..r).map(|k| (lx[(i, k)] as f64 + ly[(j, k)] as f64 - mx).exp()).sum();
            (-eps * (mx + s.ln())) as f32
        });

        let factored =
            sinkhorn_log_domain(&fk, &mu.weights, &nu.weights, &cfg(eps)).unwrap();
        let dense = sinkhorn_log_domain(
            &CostMatrixLogKernel::new(&cost, eps),
            &mu.weights,
            &nu.weights,
            &cfg(eps),
        )
        .unwrap();
        assert!(factored.objective.is_finite() && dense.objective.is_finite());
        let rel = (factored.objective - dense.objective).abs() / dense.objective.abs().max(1.0);
        assert!(
            rel < 1e-4,
            "factored {} vs dense {} (rel {rel:.2e})",
            factored.objective,
            dense.objective
        );
        // Full convergence at eps = 1e-3 is slow (the contraction factor
        // approaches 1 as eps -> 0); the stabilised path must at least be
        // finite and near-feasible where plain f32 Alg. 1 cannot run at all.
        assert!(factored.marginal_error < 5e-2, "err {}", factored.marginal_error);
    }

    #[test]
    fn factored_matches_dense_log_domain_at_moderate_eps() {
        // Same agreement away from the extreme regime, on fitted
        // stabilised factors end to end.
        let mut rng = Rng::seed_from(3);
        let (mu, nu) = data::gaussian_blobs(30, &mut rng);
        let eps = 0.5;
        let map = GaussianFeatureMap::fit(&mu, &nu, eps, 64, &mut rng);
        let fk = FactoredKernel::from_measures_stabilized(&map, &mu, &nu);
        let factored =
            sinkhorn_log_domain(&fk, &mu.weights, &nu.weights, &cfg(eps)).unwrap();
        // Plain Alg. 1 works here; the log-domain result must agree with
        // it on the same kernel (log_scale-corrected by sinkhorn()).
        let plain = sinkhorn(&fk, &mu.weights, &nu.weights, &cfg(eps)).unwrap();
        assert!(
            (factored.objective - plain.objective).abs()
                < 1e-3 * plain.objective.abs().max(1.0),
            "log-domain {} vs plain {}",
            factored.objective,
            plain.objective
        );
    }

    #[test]
    fn converges_flag_set() {
        let mut rng = Rng::seed_from(2);
        let (mu, nu) = data::gaussian_blobs(15, &mut rng);
        let cost = sq_euclidean_cost(&mu.points, &nu.points);
        let sol = sinkhorn_log_domain(
            &CostMatrixLogKernel::new(&cost, 0.1),
            &mu.weights,
            &nu.weights,
            &cfg(0.1),
        )
        .unwrap();
        assert!(sol.converged);
    }

    #[test]
    fn cost_matrix_is_symmetric_for_same_cloud() {
        let mut rng = Rng::seed_from(3);
        let (mu, _) = data::gaussian_blobs(10, &mut rng);
        let c = sq_euclidean_cost(&mu.points, &mu.points);
        for i in 0..10 {
            assert_eq!(c[(i, i)], 0.0);
            for j in 0..10 {
                assert!((c[(i, j)] - c[(j, i)]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let c = Mat::zeros(3, 4);
        let k = CostMatrixLogKernel::new(&c, 0.5);
        assert!(sinkhorn_log_domain(&k, &[0.5, 0.5], &[0.25; 4], &cfg(0.5)).is_err());
    }
}
