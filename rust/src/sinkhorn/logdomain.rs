//! Log-domain stabilised Sinkhorn (dense cost matrices only).
//!
//! At very small eps the scalings u, v overflow/underflow f32 (and even
//! f64). The classic fix iterates on the dual potentials directly:
//!
//!   alpha_i <- -eps log sum_j exp((beta_j - C_ij)/eps + log b_j)   (row)
//!   beta_j  <- -eps log sum_i exp((alpha_i - C_ij)/eps + log a_i)  (col)
//!
//! each update a row/col logsumexp over C. This requires the *cost matrix*
//! (not just a kernel operator), so it exists only for the dense baseline:
//! the RF kernel has no materialised C — the paper's method instead relies
//! on positivity and moderate eps. We document that asymmetry here and in
//! DESIGN.md; the tradeoff benches use this as the small-eps ground truth.

use crate::config::SinkhornConfig;
use crate::error::{Error, Result};
use crate::linalg::Mat;

use super::SinkhornSolution;

/// Log-domain Sinkhorn over an explicit cost matrix.
pub fn sinkhorn_log_domain(
    cost: &Mat,
    a: &[f32],
    b: &[f32],
    cfg: &SinkhornConfig,
) -> Result<SinkhornSolution> {
    let (n, m) = cost.shape();
    if a.len() != n || b.len() != m {
        return Err(Error::Shape(format!(
            "log-domain sinkhorn: cost {n}x{m} vs a[{}], b[{}]",
            a.len(),
            b.len()
        )));
    }
    let eps = cfg.epsilon;
    let log_a: Vec<f64> = a.iter().map(|&x| (x as f64).ln()).collect();
    let log_b: Vec<f64> = b.iter().map(|&x| (x as f64).ln()).collect();
    let mut alpha = vec![0.0f64; n];
    let mut beta = vec![0.0f64; m];

    let check_every = cfg.check_every.max(1);
    let mut iter = 0;
    let mut marginal = f64::INFINITY;
    let mut converged = false;

    // Scratch row buffer for the logsumexp reductions.
    let mut buf = vec![0.0f64; n.max(m)];

    while iter < cfg.max_iters {
        // beta update: beta_j = -eps logsumexp_i((alpha_i - C_ij)/eps + log a_i).
        for j in 0..m {
            for i in 0..n {
                buf[i] = (alpha[i] - cost[(i, j)] as f64) / eps + log_a[i];
            }
            beta[j] = -eps * logsumexp64(&buf[..n]);
        }
        // alpha update.
        for i in 0..n {
            let crow = cost.row(i);
            for j in 0..m {
                buf[j] = (beta[j] - crow[j] as f64) / eps + log_b[j];
            }
            alpha[i] = -eps * logsumexp64(&buf[..m]);
        }
        iter += 1;

        if iter % check_every == 0 || iter == cfg.max_iters {
            // Column marginal error of P_ij = exp((alpha_i + beta_j - C_ij)/eps + log a_i + log b_j).
            marginal = 0.0;
            for j in 0..m {
                for i in 0..n {
                    buf[i] =
                        (alpha[i] + beta[j] - cost[(i, j)] as f64) / eps + log_a[i] + log_b[j];
                }
                let col_mass = logsumexp64(&buf[..n]).exp();
                marginal += (col_mass - b[j] as f64).abs();
            }
            if marginal < cfg.tol {
                converged = true;
                break;
            }
        }
    }

    // Objective via duals. These (alpha, beta) are the duals of the
    // a⊗b-relative formulation (the plan is P_ij = a_i b_j
    // exp((alpha_i + beta_j - C_ij)/eps)), i.e. the kernel-form scalings
    // are u_i = a_i e^{alpha_i/eps}. Converting to Eq. (6)'s
    // eps(a^T log u + b^T log v) adds the entropy offset
    // eps (a^T log a + b^T log b).
    let offset: f64 = eps
        * (a.iter().map(|&ai| (ai as f64) * (ai as f64).ln()).sum::<f64>()
            + b.iter().map(|&bi| (bi as f64) * (bi as f64).ln()).sum::<f64>());
    let objective: f64 = a.iter().zip(&alpha).map(|(&ai, &al)| ai as f64 * al).sum::<f64>()
        + b.iter().zip(&beta).map(|(&bi, &be)| bi as f64 * be).sum::<f64>()
        + offset;

    Ok(SinkhornSolution {
        u: alpha
            .iter()
            .zip(a)
            .map(|(&x, &ai)| (ai as f64 * (x / eps).exp()) as f32)
            .collect(),
        v: beta
            .iter()
            .zip(b)
            .map(|(&x, &bi)| (bi as f64 * (x / eps).exp()) as f32)
            .collect(),
        objective,
        iterations: iter,
        marginal_error: marginal,
        converged,
    })
}

fn logsumexp64(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    m + xs.iter().map(|&x| (x - m).exp()).sum::<f64>().ln()
}

/// Squared-Euclidean cost matrix helper for the log-domain path.
pub fn sq_euclidean_cost(x: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.cols(), y.cols());
    Mat::from_fn(x.rows(), y.rows(), |i, j| {
        x.row(i)
            .iter()
            .zip(y.row(j))
            .map(|(&a, &b)| (a - b) * (a - b))
            .sum()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::kernels::DenseKernel;
    use crate::rng::Rng;
    use crate::sinkhorn::sinkhorn;

    fn cfg(eps: f64) -> SinkhornConfig {
        SinkhornConfig { epsilon: eps, max_iters: 3000, tol: 1e-6, check_every: 10, threads: 1 }
    }

    #[test]
    fn matches_plain_sinkhorn_at_moderate_eps() {
        let mut rng = Rng::seed_from(0);
        let (mu, nu) = data::gaussian_blobs(30, &mut rng);
        let eps = 0.5;
        let cost = sq_euclidean_cost(&mu.points, &nu.points);
        let plain = sinkhorn(
            &DenseKernel::from_measures(&mu, &nu, eps),
            &mu.weights,
            &nu.weights,
            &cfg(eps),
        )
        .unwrap();
        let logd = sinkhorn_log_domain(&cost, &mu.weights, &nu.weights, &cfg(eps)).unwrap();
        assert!(
            (plain.objective - logd.objective).abs() < 1e-3 * plain.objective.abs().max(1.0),
            "plain {} logdomain {}",
            plain.objective,
            logd.objective
        );
    }

    #[test]
    fn survives_tiny_eps_where_plain_fails_or_stalls() {
        // eps so small the plain kernel underflows rows: log-domain still
        // converges to a finite objective.
        let mut rng = Rng::seed_from(1);
        let (mu, nu) = data::gaussian_blobs(25, &mut rng);
        let eps = 0.002;
        let cost = sq_euclidean_cost(&mu.points, &nu.points);
        let logd = sinkhorn_log_domain(&cost, &mu.weights, &nu.weights, &cfg(eps)).unwrap();
        assert!(logd.objective.is_finite());
        assert!(logd.marginal_error < 1e-3, "err {}", logd.marginal_error);
        // As eps -> 0 the entropic OT value approaches the unregularised
        // OT cost, which is at least the squared distance between means.
        assert!(logd.objective > 0.0);
    }

    #[test]
    fn converges_flag_set() {
        let mut rng = Rng::seed_from(2);
        let (mu, nu) = data::gaussian_blobs(15, &mut rng);
        let cost = sq_euclidean_cost(&mu.points, &nu.points);
        let sol = sinkhorn_log_domain(&cost, &mu.weights, &nu.weights, &cfg(0.1)).unwrap();
        assert!(sol.converged);
    }

    #[test]
    fn cost_matrix_is_symmetric_for_same_cloud() {
        let mut rng = Rng::seed_from(3);
        let (mu, _) = data::gaussian_blobs(10, &mut rng);
        let c = sq_euclidean_cost(&mu.points, &mu.points);
        for i in 0..10 {
            assert_eq!(c[(i, i)], 0.0);
            for j in 0..10 {
                assert!((c[(i, j)] - c[(j, i)]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let c = Mat::zeros(3, 4);
        assert!(sinkhorn_log_domain(&c, &[0.5, 0.5], &[0.25; 4], &cfg(0.5)).is_err());
    }
}
