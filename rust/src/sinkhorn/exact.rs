//! Exact (unregularised) OT for *test oracles only*: the Hungarian
//! algorithm on square cost matrices with uniform weights, O(n^3).
//!
//! Used to validate the eps -> 0 limit of the entropic solvers: for
//! uniform measures of equal size, OT is an assignment problem and
//! `W_eps -> OT_cost` as eps shrinks (up to the entropy offset).

use crate::linalg::Mat;

/// Minimum-cost perfect matching on a square cost matrix (Jonker–Volgenant
/// style shortest augmenting paths). Returns (assignment, total cost),
/// where `assignment[i] = j` matches row i to column j.
pub fn hungarian(cost: &Mat) -> (Vec<usize>, f64) {
    let n = cost.rows();
    assert_eq!(cost.cols(), n, "hungarian: square matrices only");
    // Potentials and matching, 1-indexed internally (classic formulation).
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[j] = row matched to column j (0 = none)
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![f64::INFINITY; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = f64::INFINITY;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost[(i0 - 1, j - 1)] as f64 - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        // Augment along the path.
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut assignment = vec![0usize; n];
    let mut total = 0.0f64;
    for j in 1..=n {
        if p[j] > 0 {
            assignment[p[j] - 1] = j - 1;
            total += cost[(p[j] - 1, j - 1)] as f64;
        }
    }
    (assignment, total)
}

/// Exact OT cost between two uniform measures of equal size:
/// (1/n) * min-cost perfect matching.
pub fn exact_ot_uniform(cost: &Mat) -> f64 {
    let (_, total) = hungarian(cost);
    total / cost.rows() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SinkhornConfig;
    use crate::data;
    use crate::kernels::CostMatrixLogKernel;
    use crate::rng::Rng;
    use crate::sinkhorn::{sinkhorn_log_domain, sq_euclidean_cost};

    #[test]
    fn hungarian_identity_matrix() {
        // Cost = 1 - I: optimal matching is the diagonal, cost 0.
        let n = 5;
        let c = Mat::from_fn(n, n, |i, j| if i == j { 0.0 } else { 1.0 });
        let (assign, total) = hungarian(&c);
        assert_eq!(assign, vec![0, 1, 2, 3, 4]);
        assert_eq!(total, 0.0);
    }

    #[test]
    fn hungarian_known_3x3() {
        // Classic example: optimal = 1+2+2 = 5? verify by brute force.
        let c = Mat::from_rows(&[vec![4.0, 1.0, 3.0], vec![2.0, 0.0, 5.0], vec![3.0, 2.0, 2.0]]);
        let (_, total) = hungarian(&c);
        // Brute force over all 6 permutations.
        let perms = [[0, 1, 2], [0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]];
        let best = perms
            .iter()
            .map(|p| (0..3).map(|i| c[(i, p[i])] as f64).sum::<f64>())
            .fold(f64::INFINITY, f64::min);
        assert!((total - best).abs() < 1e-9, "hungarian {total} vs brute {best}");
    }

    #[test]
    fn hungarian_matches_brute_force_random() {
        let mut rng = Rng::seed_from(0);
        for n in [2usize, 3, 4, 5] {
            for _ in 0..5 {
                let c = Mat::from_fn(n, n, |_, _| rng.uniform() as f32 * 10.0);
                let (assign, total) = hungarian(&c);
                // Assignment must be a permutation.
                let mut seen = assign.clone();
                seen.sort_unstable();
                assert_eq!(seen, (0..n).collect::<Vec<_>>());
                // Brute force.
                let best = permutations(n)
                    .into_iter()
                    .map(|p| (0..n).map(|i| c[(i, p[i])] as f64).sum::<f64>())
                    .fold(f64::INFINITY, f64::min);
                assert!((total - best).abs() < 1e-6, "n={n}: {total} vs {best}");
            }
        }
    }

    fn permutations(n: usize) -> Vec<Vec<usize>> {
        if n == 1 {
            return vec![vec![0]];
        }
        let mut out = Vec::new();
        for p in permutations(n - 1) {
            for pos in 0..=p.len() {
                let mut q: Vec<usize> = p.clone();
                q.insert(pos, n - 1);
                out.push(q);
            }
        }
        out
    }

    #[test]
    fn entropic_ot_approaches_exact_as_eps_shrinks() {
        // The eps->0 limit: log-domain Sinkhorn cost -> assignment cost.
        let mut rng = Rng::seed_from(1);
        let (mu, nu) = data::gaussian_blobs(16, &mut rng);
        let cost = sq_euclidean_cost(&mu.points, &nu.points);
        let exact = exact_ot_uniform(&cost);
        let mut prev_gap = f64::INFINITY;
        for eps in [0.5, 0.1, 0.02] {
            let cfg = SinkhornConfig {
                epsilon: eps,
                max_iters: 20_000,
                tol: 1e-8,
                check_every: 50,
                threads: 1,
                stabilize: false,
                max_batch: 1,
                anneal: None,
                anneal_decay: 0.5,
                symmetric: None,
            };
            let log_kernel = CostMatrixLogKernel::new(&cost, eps);
            let sol =
                sinkhorn_log_domain(&log_kernel, &mu.weights, &nu.weights, &cfg).unwrap();
            let gap = (sol.objective - exact).abs();
            assert!(gap <= prev_gap * 1.10, "gap should shrink with eps: {gap} vs {prev_gap}");
            prev_gap = gap;
        }
        assert!(prev_gap < 0.1 * exact.abs().max(0.1), "final gap {prev_gap} vs exact {exact}");
    }
}
