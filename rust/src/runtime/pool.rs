//! Intra-solve parallel execution layer: a **persistent channel-fed
//! worker pool** on `std::thread` (the offline crate set has no rayon),
//! shared by the row-chunked matvec and logsumexp variants in
//! [`crate::linalg`], the parallel feature evaluation in
//! [`crate::features`], and the concurrent three-problem divergence solve
//! in [`crate::sinkhorn::sinkhorn_divergence`].
//!
//! ## Design
//!
//! A [`Pool`] is a cheap cloneable handle to a set of **live worker
//! threads** spawned once at construction and fed through an mpsc
//! channel. Earlier revisions spawned scoped threads per parallel region
//! (twice per Sinkhorn iteration when pooled); the persistent pool pays
//! the spawn cost once, so a region dispatch is a channel send plus a
//! condvar wait — microseconds against the tens-of-microseconds scoped
//! spawn, which matters exactly at small n where per-region work is short
//! (EXPERIMENTS.md §Parallel scaling has the measured comparison; the
//! `parallel_scaling` bench's spawn-overhead case reproduces it).
//!
//! Tasks may still borrow the caller's matrices and output buffers
//! directly, without `'static` bounds: a parallel *region* places its
//! task queue on the caller's stack, hands the workers type-erased
//! pointers to it, and — crucially — **blocks until every handed-out
//! pointer has been consumed and signalled** before returning, so no
//! worker can observe the region after it is gone. The caller itself
//! participates in draining the queue, which both removes one spawn from
//! the critical path and guarantees progress even when all workers are
//! busy with other regions.
//!
//! One rule follows from the blocking hand-shake: **a region task must
//! not dispatch a new region onto the same pool** (tasks are leaf
//! compute in this crate: matvec chunks, feature rows, logsumexp chunks,
//! or whole solves whose inner matvecs run on a *different* pool
//! instance). Nesting across distinct pools — e.g. the divergence-level
//! pool vs a kernel's matvec pool — is fine and is exactly how the
//! coordinator composes them.
//!
//! ## Determinism / accuracy contract
//!
//! The pool itself never touches floating-point data, and the kernels
//! built on it are written so that **results are independent of the thread
//! count**: work is cut on a fixed chunk grid (not a thread-count-derived
//! one) and reductions run over chunks in index order on a single thread
//! (see [`crate::linalg::matvec_t_into_pooled`]). `Pool::new(1)` and
//! `Pool::new(8)` therefore produce bitwise-identical outputs, which is
//! what lets the service flip `solver_threads` in production without
//! changing any numerical result — and what the property tests in
//! `rust/tests/parallel_equivalence.rs` assert.
//!
//! A thread count of `0` means "auto": resolve to
//! [`std::thread::available_parallelism`] at construction.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Handle to a persistent worker pool. Cloning shares the same workers;
/// dropping the last clone shuts them down. Serial pools (`threads == 1`)
/// hold no threads at all and run every region inline.
#[derive(Clone, Debug)]
pub struct Pool {
    threads: usize,
    inner: Option<Arc<PoolInner>>,
}

impl Default for Pool {
    /// The default pool is serial — parallelism is strictly opt-in so that
    /// library users and tests keep the historical single-thread
    /// behaviour unless they ask otherwise.
    fn default() -> Self {
        Pool::serial()
    }
}

impl Pool {
    /// A pool that may use up to `threads` workers; `0` resolves to the
    /// machine's available parallelism. `threads - 1` OS threads are
    /// spawned immediately (the caller of each region is the remaining
    /// worker) and live until the last handle is dropped.
    pub fn new(threads: usize) -> Pool {
        let resolved = if threads == 0 { available_threads() } else { threads };
        if resolved <= 1 {
            return Pool::serial();
        }
        Pool { threads: resolved, inner: Some(Arc::new(PoolInner::spawn(resolved - 1))) }
    }

    /// [`Pool::new`] with the auto-resolved thread count capped at `cap` —
    /// for regions with a known maximum parallelism (e.g. the three
    /// transport problems of a divergence), so `threads = 0` doesn't
    /// spawn machine-width workers that can never be used.
    pub fn new_capped(threads: usize, cap: usize) -> Pool {
        let resolved = if threads == 0 { available_threads() } else { threads };
        Pool::new(resolved.min(cap.max(1)))
    }

    /// The serial pool: every region runs inline on the caller's thread.
    pub fn serial() -> Pool {
        Pool { threads: 1, inner: None }
    }

    /// A pool sized to the machine (`available_parallelism`).
    pub fn auto() -> Pool {
        Pool::new(0)
    }

    /// The resolved worker count (always ≥ 1, counting the region caller).
    pub fn threads(&self) -> usize {
        self.threads.max(1)
    }

    /// Run every task in `tasks`, using up to `threads()` executors (the
    /// calling thread plus persistent workers) draining a shared queue.
    /// Tasks may borrow caller state: the region blocks until all workers
    /// that were handed the region have finished with it. Order of
    /// *execution* across workers is unspecified; callers needing
    /// deterministic results must make tasks independent (disjoint
    /// outputs) — see the module docs. Tasks must not dispatch new
    /// regions onto this same pool (see the module docs).
    ///
    /// Panics in a task propagate to the caller after the region drains.
    pub fn run_tasks<T, F>(&self, tasks: Vec<T>, f: F)
    where
        T: Send,
        F: Fn(T) + Sync,
    {
        let helpers = match &self.inner {
            Some(_) if self.threads > 1 => (self.threads - 1).min(tasks.len()),
            _ => 0,
        };
        if helpers == 0 || tasks.len() <= 1 {
            for task in tasks {
                f(task);
            }
            return;
        }
        let region = Region {
            queue: Mutex::new(tasks.into_iter()),
            f,
            done: Mutex::new(0),
            all_done: Condvar::new(),
            panic: Mutex::new(None),
        };
        let inner = self.inner.as_ref().expect("helpers > 0 implies live workers");
        let sent = inner.send_participants(
            &region as *const Region<T, F> as *const (),
            participate_erased::<T, F>,
            helpers,
        );
        // The caller drains too: progress is guaranteed even when every
        // worker is busy with other regions.
        region.participate();
        region.wait_for(sent + 1);
        if let Some(payload) = region.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Run three independent closures, concurrently when the pool allows
    /// it (used for the three transport problems of the Sinkhorn
    /// divergence, which share no state). Serial pools run them in order
    /// on the caller's thread.
    pub fn join3<FA, FB, FC, RA, RB, RC>(&self, fa: FA, fb: FB, fc: FC) -> (RA, RB, RC)
    where
        FA: FnOnce() -> RA + Send,
        FB: FnOnce() -> RB + Send,
        FC: FnOnce() -> RC + Send,
        RA: Send,
        RB: Send,
        RC: Send,
    {
        if self.threads() <= 1 || self.inner.is_none() {
            return (fa(), fb(), fc());
        }
        let (sa, sb, sc) = (Mutex::new(None), Mutex::new(None), Mutex::new(None));
        {
            let (ra, rb, rc) = (&sa, &sb, &sc);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(move || *ra.lock().unwrap() = Some(fa())),
                Box::new(move || *rb.lock().unwrap() = Some(fb())),
                Box::new(move || *rc.lock().unwrap() = Some(fc())),
            ];
            self.run_tasks(tasks, |task| task());
        }
        (
            sa.into_inner().unwrap().expect("join3 task a ran"),
            sb.into_inner().unwrap().expect("join3 task b ran"),
            sc.into_inner().unwrap().expect("join3 task c ran"),
        )
    }
}

/// The machine's available parallelism (≥ 1; 1 when detection fails).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A type-erased pointer to a live [`Region`] on some caller's stack,
/// paired with the monomorphised drain function. Sound because the
/// region's owner blocks until every envelope recipient signals done.
struct Envelope {
    region: *const (),
    run: unsafe fn(*const ()),
}

// The pointer is only dereferenced behind the region hand-shake.
unsafe impl Send for Envelope {}

/// Shared workers + intake channel; dropped when the last [`Pool`] clone
/// goes away, which disconnects the channel and joins the workers.
struct PoolInner {
    tx: Mutex<Option<Sender<Envelope>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl std::fmt::Debug for PoolInner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let n = self.workers.lock().map(|w| w.len()).unwrap_or(0);
        write!(f, "PoolInner({n} workers)")
    }
}

impl PoolInner {
    fn spawn(workers: usize) -> PoolInner {
        let (tx, rx) = channel::<Envelope>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("ls-pool-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn pool worker")
            })
            .collect();
        PoolInner { tx: Mutex::new(Some(tx)), workers: Mutex::new(handles) }
    }

    /// Hand `want` participation envelopes to the workers; returns how
    /// many were actually sent (the caller must wait for exactly that many
    /// completions on top of its own).
    fn send_participants(
        &self,
        region: *const (),
        run: unsafe fn(*const ()),
        want: usize,
    ) -> usize {
        let guard = self.tx.lock().unwrap();
        let Some(tx) = guard.as_ref() else { return 0 };
        let mut sent = 0;
        for _ in 0..want {
            if tx.send(Envelope { region, run }).is_err() {
                break;
            }
            sent += 1;
        }
        sent
    }
}

impl Drop for PoolInner {
    fn drop(&mut self) {
        // Disconnect the channel so parked workers wake and exit, then
        // join them so no pool thread outlives the last handle.
        drop(self.tx.lock().unwrap().take());
        for handle in self.workers.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Envelope>>>) {
    loop {
        // Hold the receiver lock only for the blocking recv itself.
        let envelope = match rx.lock().unwrap().recv() {
            Ok(e) => e,
            Err(_) => return, // pool dropped
        };
        // Safety: the region owner blocks until this call signals done.
        unsafe { (envelope.run)(envelope.region) }
    }
}

/// One parallel region: a task queue on the caller's stack plus the
/// completion hand-shake workers signal through.
struct Region<T, F> {
    queue: Mutex<std::vec::IntoIter<T>>,
    f: F,
    done: Mutex<usize>,
    all_done: Condvar,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl<T: Send, F: Fn(T) + Sync> Region<T, F> {
    /// Drain the queue until empty, then signal completion. The signal is
    /// raised while holding the `done` lock, so the region owner cannot
    /// observe completion (and free the region) before this participant
    /// has stopped touching it.
    fn participate(&self) {
        let result = catch_unwind(AssertUnwindSafe(|| loop {
            let task = self.queue.lock().unwrap().next();
            match task {
                Some(t) => (self.f)(t),
                None => break,
            }
        }));
        if let Err(payload) = result {
            let mut slot = self.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(payload);
            }
        }
        let mut done = self.done.lock().unwrap();
        *done += 1;
        self.all_done.notify_all();
    }

    /// Block until `participants` completions have been signalled.
    fn wait_for(&self, participants: usize) {
        let mut done = self.done.lock().unwrap();
        while *done < participants {
            done = self.all_done.wait(done).unwrap();
        }
    }
}

/// Monomorphised participation entry point handed to workers.
///
/// Safety: `ptr` must point at a live `Region<T, F>` whose owner waits
/// for this participant's done signal before freeing it.
unsafe fn participate_erased<T: Send, F: Fn(T) + Sync>(ptr: *const ()) {
    (*(ptr as *const Region<T, F>)).participate();
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn zero_resolves_to_auto() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::auto().threads(), available_threads());
        assert_eq!(Pool::serial().threads(), 1);
        assert_eq!(Pool::default().threads(), 1);
    }

    #[test]
    fn new_capped_bounds_auto_and_explicit_counts() {
        assert_eq!(Pool::new_capped(0, 3).threads(), available_threads().min(3));
        assert_eq!(Pool::new_capped(8, 3).threads(), 3);
        assert_eq!(Pool::new_capped(2, 3).threads(), 2);
        assert_eq!(Pool::new_capped(1, 0).threads(), 1);
    }

    #[test]
    fn run_tasks_executes_every_task_once() {
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let n = 100usize;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run_tasks((0..n).collect::<Vec<usize>>(), |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "threads={threads}");
        }
    }

    #[test]
    fn run_tasks_can_fill_disjoint_chunks() {
        let pool = Pool::new(4);
        let mut out = vec![0u32; 64];
        let tasks: Vec<(usize, &mut [u32])> = out.chunks_mut(16).enumerate().collect();
        pool.run_tasks(tasks, |(c, chunk)| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (c * 16 + i) as u32;
            }
        });
        assert_eq!(out, (0..64).collect::<Vec<u32>>());
    }

    #[test]
    fn workers_persist_across_regions() {
        // Many short regions on one pool: the workers are spawned once and
        // reused, and every region still runs to completion.
        let pool = Pool::new(4);
        for round in 0..200usize {
            let hits = AtomicUsize::new(0);
            pool.run_tasks((0..8).collect::<Vec<usize>>(), |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
            assert_eq!(hits.load(Ordering::Relaxed), 8, "round {round}");
        }
    }

    #[test]
    fn clones_share_workers_and_outlive_originals() {
        let clone = {
            let pool = Pool::new(3);
            pool.clone()
        };
        let hits = AtomicUsize::new(0);
        clone.run_tasks((0..16).collect::<Vec<usize>>(), |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn concurrent_regions_from_different_threads() {
        // The service shape: several threads dispatching regions onto one
        // shared pool at once.
        let pool = Pool::new(4);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = pool.clone();
                s.spawn(move || {
                    for _ in 0..50 {
                        let hits = AtomicUsize::new(0);
                        pool.run_tasks((0..8).collect::<Vec<usize>>(), |_| {
                            hits.fetch_add(1, Ordering::Relaxed);
                        });
                        assert_eq!(hits.load(Ordering::Relaxed), 8);
                    }
                });
            }
        });
    }

    #[test]
    fn join3_returns_all_results() {
        for threads in [1usize, 2, 4] {
            let pool = Pool::new(threads);
            let (a, b, c) = pool.join3(|| 1 + 1, || "x".to_string(), || vec![3u8; 3]);
            assert_eq!(a, 2);
            assert_eq!(b, "x");
            assert_eq!(c, vec![3, 3, 3]);
        }
    }

    #[test]
    fn task_panic_propagates_after_drain() {
        let pool = Pool::new(4);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run_tasks((0..32).collect::<Vec<usize>>(), |i| {
                if i == 7 {
                    panic!("task 7 exploded");
                }
            });
        }));
        assert!(result.is_err(), "panic must reach the region caller");
        // The pool is still usable afterwards.
        let hits = AtomicUsize::new(0);
        pool.run_tasks((0..8).collect::<Vec<usize>>(), |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn run_tasks_empty_is_noop() {
        Pool::new(4).run_tasks(Vec::<usize>::new(), |_| panic!("no tasks"));
    }

    #[test]
    fn single_task_runs_inline() {
        // One task never pays the dispatch hand-shake.
        let pool = Pool::new(8);
        let hits = AtomicUsize::new(0);
        pool.run_tasks(vec![0usize], |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }
}
