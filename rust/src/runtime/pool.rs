//! Intra-solve parallel execution layer: a small scoped worker pool on
//! `std::thread` (the offline crate set has no rayon), shared by the
//! row-chunked matvec variants in [`crate::linalg`], the parallel feature
//! evaluation in [`crate::features`], and the concurrent three-problem
//! divergence solve in [`crate::sinkhorn::sinkhorn_divergence`].
//!
//! ## Design
//!
//! A [`Pool`] is a *policy*, not a set of live threads: it records how many
//! workers a parallel region may use, and each region spawns that many
//! scoped threads (`std::thread::scope`) that drain a shared task queue.
//! Scoped spawning keeps the API free of `'static` bounds — tasks may
//! borrow the caller's matrices and output buffers directly — at the cost
//! of a few tens of microseconds of spawn overhead per region, which is
//! noise against the millisecond-scale matvecs it parallelises (see
//! EXPERIMENTS.md §Parallel scaling).
//!
//! ## Determinism / accuracy contract
//!
//! The pool itself never touches floating-point data, and the kernels
//! built on it are written so that **results are independent of the thread
//! count**: work is cut on a fixed chunk grid (not a thread-count-derived
//! one) and reductions run over chunks in index order on a single thread
//! (see [`crate::linalg::matvec_t_into_pooled`]). `Pool::new(1)` and
//! `Pool::new(8)` therefore produce bitwise-identical outputs, which is
//! what lets the service flip `solver_threads` in production without
//! changing any numerical result — and what the property tests in
//! `rust/tests/parallel_equivalence.rs` assert.
//!
//! A thread count of `0` means "auto": resolve to
//! [`std::thread::available_parallelism`] at construction.

use std::sync::Mutex;

/// Worker-count policy for parallel regions. Copyable and cheap; embed it
/// in kernels/configs freely.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pool {
    threads: usize,
}

impl Default for Pool {
    /// The default pool is serial — parallelism is strictly opt-in so that
    /// library users and tests keep the historical single-thread
    /// behaviour unless they ask otherwise.
    fn default() -> Self {
        Pool::serial()
    }
}

impl Pool {
    /// A pool that may use up to `threads` workers; `0` resolves to the
    /// machine's available parallelism.
    pub fn new(threads: usize) -> Pool {
        Pool { threads: if threads == 0 { available_threads() } else { threads } }
    }

    /// The serial pool: every region runs inline on the caller's thread.
    pub fn serial() -> Pool {
        Pool { threads: 1 }
    }

    /// A pool sized to the machine (`available_parallelism`).
    pub fn auto() -> Pool {
        Pool::new(0)
    }

    /// The resolved worker count (always ≥ 1).
    pub fn threads(&self) -> usize {
        self.threads.max(1)
    }

    /// Run every task in `tasks`, using up to `threads()` scoped workers
    /// draining a shared queue. Tasks may borrow caller state: the region
    /// joins all workers before returning. Order of *execution* across
    /// workers is unspecified; callers needing deterministic results must
    /// make tasks independent (disjoint outputs) — see the module docs.
    ///
    /// Panics in a task propagate to the caller after all workers join.
    pub fn run_tasks<T, F>(&self, tasks: Vec<T>, f: F)
    where
        T: Send,
        F: Fn(T) + Sync,
    {
        let workers = self.threads().min(tasks.len());
        if workers <= 1 {
            for task in tasks {
                f(task);
            }
            return;
        }
        let queue = Mutex::new(tasks.into_iter());
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let task = {
                        let mut q = queue.lock().unwrap();
                        q.next()
                    };
                    match task {
                        Some(t) => f(t),
                        None => break,
                    }
                });
            }
        });
    }

    /// Run three independent closures, concurrently when the pool allows
    /// it (used for the three transport problems of the Sinkhorn
    /// divergence, which share no state). Serial pools run them in order
    /// on the caller's thread.
    pub fn join3<FA, FB, FC, RA, RB, RC>(&self, fa: FA, fb: FB, fc: FC) -> (RA, RB, RC)
    where
        FA: FnOnce() -> RA,
        FB: FnOnce() -> RB,
        FC: FnOnce() -> RC,
        FA: Send,
        FB: Send,
        FC: Send,
        RA: Send,
        RB: Send,
        RC: Send,
    {
        match self.threads() {
            0 | 1 => (fa(), fb(), fc()),
            // Honor a 2-thread budget: one spawned worker, two closures
            // on the caller's thread.
            2 => std::thread::scope(|s| {
                let hc = s.spawn(fc);
                let ra = fa();
                let rb = fb();
                let rc = hc.join().unwrap_or_else(|p| std::panic::resume_unwind(p));
                (ra, rb, rc)
            }),
            _ => std::thread::scope(|s| {
                let hb = s.spawn(fb);
                let hc = s.spawn(fc);
                let ra = fa();
                let rb = hb.join().unwrap_or_else(|p| std::panic::resume_unwind(p));
                let rc = hc.join().unwrap_or_else(|p| std::panic::resume_unwind(p));
                (ra, rb, rc)
            }),
        }
    }
}

/// The machine's available parallelism (≥ 1; 1 when detection fails).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn zero_resolves_to_auto() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::auto().threads(), available_threads());
        assert_eq!(Pool::serial().threads(), 1);
        assert_eq!(Pool::default().threads(), 1);
    }

    #[test]
    fn run_tasks_executes_every_task_once() {
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let n = 100usize;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run_tasks((0..n).collect::<Vec<usize>>(), |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "threads={threads}");
        }
    }

    #[test]
    fn run_tasks_can_fill_disjoint_chunks() {
        let pool = Pool::new(4);
        let mut out = vec![0u32; 64];
        let tasks: Vec<(usize, &mut [u32])> = out.chunks_mut(16).enumerate().collect();
        pool.run_tasks(tasks, |(c, chunk)| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = (c * 16 + i) as u32;
            }
        });
        assert_eq!(out, (0..64).collect::<Vec<u32>>());
    }

    #[test]
    fn join3_returns_all_results() {
        for threads in [1usize, 4] {
            let pool = Pool::new(threads);
            let (a, b, c) = pool.join3(|| 1 + 1, || "x".to_string(), || vec![3u8; 3]);
            assert_eq!(a, 2);
            assert_eq!(b, "x");
            assert_eq!(c, vec![3, 3, 3]);
        }
    }

    #[test]
    fn run_tasks_empty_is_noop() {
        Pool::new(4).run_tasks(Vec::<usize>::new(), |_| panic!("no tasks"));
    }
}
