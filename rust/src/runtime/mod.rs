//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot path.
//!
//! The interchange format is **HLO text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax ≥ 0.5 emits HloModuleProto with 64-bit
//! instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.
//!
//! * [`Registry`] — parses `artifacts/manifest.json` (shapes, constants,
//!   hashes) written by the AOT pipeline.
//! * [`Engine`] — a PJRT CPU client plus a compile cache: each artifact is
//!   compiled once and re-executed many times.
//! * [`pool`] — the intra-solve parallel execution layer (scoped worker
//!   pool) used by the native hot paths; see EXPERIMENTS.md §Parallel
//!   scaling for its measured effect.
//! * [`wire`] — the shard layer's binary-column wire format (JSON header
//!   + little-endian f32/f64 payloads, exact round trip); task/result
//!   envelopes live in [`crate::api::envelope`].

mod json;
pub mod pool;
pub mod wire;

pub use json::{Json, JsonError};
pub use pool::Pool;
pub use wire::{WireCol, WireDoc};

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::linalg::Mat;

/// Metadata for one AOT artifact (one lowered HLO module).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    /// Parameter (name, shape) pairs, in call order.
    pub params: Vec<(String, Vec<usize>)>,
    /// Output (name, shape) pairs (the module returns a tuple).
    pub outputs: Vec<(String, Vec<usize>)>,
    /// Static constants baked into the artifact (eps, q, iters, …).
    pub constants: BTreeMap<String, f64>,
    pub sha256: String,
}

/// Parsed artifact manifest.
#[derive(Debug, Default)]
pub struct Registry {
    pub entries: BTreeMap<String, ArtifactMeta>,
    pub dir: PathBuf,
}

impl Registry {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let json = Json::parse(&text).map_err(|e| Error::Artifact(format!("manifest: {e}")))?;
        let entries_json = json
            .get("entries")
            .and_then(Json::as_obj)
            .ok_or_else(|| Error::Artifact("manifest missing `entries`".into()))?;
        let mut entries = BTreeMap::new();
        for (name, entry) in entries_json {
            let get_str = |k: &str| -> Result<String> {
                entry
                    .get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| Error::Artifact(format!("{name}: missing `{k}`")))
            };
            let parse_sig = |k: &str| -> Result<Vec<(String, Vec<usize>)>> {
                entry
                    .get(k)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| Error::Artifact(format!("{name}: missing `{k}`")))?
                    .iter()
                    .map(|p| {
                        let pair = p.as_arr().ok_or_else(|| {
                            Error::Artifact(format!("{name}: bad {k} entry"))
                        })?;
                        let pname = pair[0]
                            .as_str()
                            .ok_or_else(|| Error::Artifact(format!("{name}: bad param name")))?;
                        let shape = pair[1]
                            .as_arr()
                            .ok_or_else(|| Error::Artifact(format!("{name}: bad shape")))?
                            .iter()
                            .map(|d| {
                                d.as_usize().ok_or_else(|| {
                                    Error::Artifact(format!("{name}: bad dim"))
                                })
                            })
                            .collect::<Result<Vec<usize>>>()?;
                        Ok((pname.to_string(), shape))
                    })
                    .collect()
            };
            let constants = entry
                .get("constants")
                .and_then(Json::as_obj)
                .map(|m| {
                    m.iter()
                        .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                        .collect()
                })
                .unwrap_or_default();
            entries.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: dir.join(get_str("file")?),
                    params: parse_sig("params")?,
                    outputs: parse_sig("outputs")?,
                    constants,
                    sha256: get_str("sha256")?,
                },
            );
        }
        Ok(Registry { entries, dir })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.entries.get(name).ok_or_else(|| {
            Error::Artifact(format!(
                "artifact `{name}` not in manifest (have: {})",
                self.entries.keys().cloned().collect::<Vec<_>>().join(", ")
            ))
        })
    }

    /// Find an artifact by prefix (e.g. "rf_sinkhorn_n1024") — convenience
    /// for size-gridded names.
    pub fn find_prefix(&self, prefix: &str) -> Option<&ArtifactMeta> {
        self.entries.values().find(|m| m.name.starts_with(prefix))
    }
}

/// A compiled executable plus its metadata.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with positional literal arguments; returns the decomposed
    /// output tuple (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.meta.params.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} args, got {}",
                self.meta.name,
                self.meta.params.len(),
                args.len()
            )));
        }
        let result = self.exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Run and convert every output to a f32 vector.
    pub fn run_f32(&self, args: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.run(args)?.into_iter().map(|l| Ok(l.to_vec::<f32>()?)).collect()
    }
}

/// PJRT CPU client with a per-artifact compile cache.
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

// The PJRT CPU client and loaded executables are internally synchronised;
// the raw pointers in the xla crate wrappers are what blocks auto-Send.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached by name).
    pub fn load(&self, meta: &ArtifactMeta) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(&meta.name) {
            return Ok(e.clone());
        }
        let path = meta.file.to_str().ok_or_else(|| Error::Artifact("non-utf8 path".into()))?;
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let arc = std::sync::Arc::new(Executable { meta: meta.clone(), exe });
        self.cache.lock().unwrap().insert(meta.name.clone(), arc.clone());
        Ok(arc)
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// Convert a row-major matrix to a 2-D f32 literal.
pub fn mat_to_literal(m: &Mat) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(m.data()).reshape(&[m.rows() as i64, m.cols() as i64])?)
}

/// Convert a slice to a 1-D f32 literal.
pub fn vec_to_literal(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Convert a literal back to a matrix with the given shape.
pub fn literal_to_mat(l: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let data = l.to_vec::<f32>()?;
    if data.len() != rows * cols {
        return Err(Error::Shape(format!(
            "literal has {} elements, expected {rows}x{cols}",
            data.len()
        )));
    }
    Ok(Mat::from_vec(rows, cols, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("ls-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": "hlo-text", "entries": {
                "toy": {"file": "toy.hlo.txt",
                         "params": [["x", [2, 3]], ["v", [3]]],
                         "outputs": [["y", [2]]],
                         "constants": {"eps": 0.5, "iters": 10},
                         "sha256": "deadbeef"}}}"#,
        )
        .unwrap();
        let reg = Registry::load(&dir).unwrap();
        let meta = reg.get("toy").unwrap();
        assert_eq!(meta.params, vec![("x".into(), vec![2, 3]), ("v".into(), vec![3])]);
        assert_eq!(meta.outputs[0].1, vec![2]);
        assert_eq!(meta.constants["eps"], 0.5);
        assert!(reg.find_prefix("to").is_some());
        assert!(reg.get("missing").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn registry_missing_dir_is_artifact_error() {
        let err = Registry::load("/nonexistent/dir").unwrap_err();
        assert!(matches!(err, Error::Artifact(_)));
    }

    #[test]
    fn literal_roundtrip_matrix() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let l = mat_to_literal(&m).unwrap();
        let back = literal_to_mat(&l, 2, 2).unwrap();
        assert_eq!(back.data(), m.data());
        assert!(literal_to_mat(&l, 3, 2).is_err());
    }
}
