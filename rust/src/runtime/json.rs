//! Minimal JSON parser (objects, arrays, strings, numbers, bools, null) —
//! just enough to read `artifacts/manifest.json` offline (no serde_json).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialise to a JSON string. Deterministic: object keys come out in
    /// `BTreeMap` order and floats use Rust's shortest-round-trip
    /// `Display`, so `parse(encode(v)) == v` bit for bit on every finite
    /// number (the wire format's header round trip relies on this).
    /// Non-finite numbers have no JSON form and encode as `null`.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.encode_into(&mut out);
        out
    }

    fn encode_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    // `Display` prints integral floats without a dot
                    // ("42"), still a valid JSON number.
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => encode_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.encode_into(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    encode_str(k, out);
                    out.push(':');
                    v.encode_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn encode_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, m: &str) -> JsonError {
        JsonError { offset: self.pos, message: m.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected token")),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => out.push(c as char),
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit()
                || c == b'.'
                || c == b'e'
                || c == b'E'
                || c == b'+'
                || c == b'-'
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
        assert!(j.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn as_usize_rejects_fractions() {
        assert_eq!(Json::parse("3").unwrap().as_usize(), Some(3));
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_usize(), None);
    }

    #[test]
    fn encode_round_trips_exactly() {
        let doc = r#"{"a":[1,2.5,{"b":"c"}],"d":{},"e":true,"f":null,"g":"x\"y\\z\n"}"#;
        let v = Json::parse(doc).unwrap();
        let enc = v.encode();
        assert_eq!(Json::parse(&enc).unwrap(), v);
        // Deterministic: encoding twice gives the same bytes.
        assert_eq!(enc, v.encode());
    }

    #[test]
    fn encode_floats_shortest_round_trip() {
        for bits in [0.1f64.to_bits(), (1.0f64 / 3.0).to_bits(), f64::MIN_POSITIVE.to_bits()] {
            let x = f64::from_bits(bits);
            let back = Json::parse(&Json::Num(x).encode()).unwrap();
            assert_eq!(back.as_f64().unwrap().to_bits(), bits);
        }
        assert_eq!(Json::Num(f64::NAN).encode(), "null");
        assert_eq!(Json::Num(42.0).encode(), "42");
    }

    #[test]
    fn encode_escapes_control_chars() {
        let v = Json::Str("a\u{1}b".into());
        assert_eq!(v.encode(), "\"a\\u0001b\"");
        assert_eq!(Json::parse(&v.encode()).unwrap(), v);
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n\t\"a\" : 1 , \"b\" : [ ] }\r\n").unwrap();
        assert_eq!(j.get("a").unwrap().as_f64(), Some(1.0));
    }
}
