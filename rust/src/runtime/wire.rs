//! Compact measure/weight wire format: a JSON header plus raw
//! little-endian binary columns.
//!
//! A frame is
//!
//! ```text
//! +-------+-------------+----------------+------------------------+
//! | LSW1  | header len  | header (JSON)  | column payloads, back  |
//! | magic | u32 LE      | ASCII, len B   | to back, LE bytes      |
//! +-------+-------------+----------------+------------------------+
//! ```
//!
//! The header records the format version, free-form metadata, and the
//! column directory `[{name, dtype, len}, …]` in payload order; the
//! payload is the raw `to_le_bytes` concatenation of every column. The
//! round trip is **exact**: floats travel as their bit patterns, so NaN
//! payloads, subnormals and signed zeros all survive (`rust/tests/
//! wire_format.rs` property-tests this), while the textual header only
//! carries integers and short strings.
//!
//! Decoding is strict and typed: bad magic, truncated or oversized
//! headers, unknown dtypes, duplicate column names and any mismatch
//! between the declared directory and the actual payload length surface
//! as [`Error::Wire`] — never a panic, never a silently-wrong column.
//! This is the transport substrate of the shard layer
//! ([`crate::shard`]): task and result envelopes ([`crate::api::envelope`])
//! are `WireDoc`s, as are its heartbeat frames.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::runtime::Json;

/// Frame magic: "LSW1" = linear-sinkhorn wire v1.
pub const WIRE_MAGIC: [u8; 4] = *b"LSW1";

/// Shard control-frame kinds (`meta["kind"]` values). Data frames use
/// `"task"` / `"result"` / `"reject"` (see [`crate::api::envelope`]);
/// these are the membership/lifecycle frames the coordinator and
/// workers exchange around them.
pub mod kinds {
    /// Coordinator → worker liveness probe; also carries `group_id`.
    pub const PING: &str = "ping";
    /// Worker → coordinator liveness reply; carries `worker_id`.
    pub const PONG: &str = "pong";
    /// Rejoin/connect handshake, both directions: carries `plan_v`
    /// (decimal [`crate::api::PLAN_FORMAT_MAJOR`]) so mixed-version
    /// fleets fail typed instead of mis-decoding tasks.
    pub const HELLO: &str = "hello";
    /// Coordinator → worker: stop after in-flight work and exit cleanly.
    pub const DRAIN: &str = "drain";
    /// Worker → coordinator: drain observed, exiting.
    pub const DRAIN_ACK: &str = "drain-ack";
    /// Coordinator → worker: exit immediately (legacy hard stop).
    pub const SHUTDOWN: &str = "shutdown";
    /// Worker → coordinator: one streaming-session solve result (the
    /// session analogue of a `"result"` frame — carries the updated
    /// x-side dual back so the coordinator can warm-start the next
    /// query; see [`crate::api::SessionResultEnvelope`]).
    pub const SESSION_RESULT: &str = "session_result";
    /// Coordinator → worker: a streaming session closed; drop any
    /// resident support state for it. Carries `session.id`.
    pub const SESSION_CLOSE: &str = "session_close";
}

/// Hard cap on the declared header length (1 MiB). A corrupt length
/// prefix must produce a typed error, not a giant allocation.
pub const MAX_HEADER_LEN: usize = 1 << 20;

/// One binary column: a named, typed vector of scalars.
#[derive(Clone, Debug, PartialEq)]
pub enum WireCol {
    F32(Vec<f32>),
    F64(Vec<f64>),
}

impl WireCol {
    fn dtype(&self) -> &'static str {
        match self {
            WireCol::F32(_) => "f32",
            WireCol::F64(_) => "f64",
        }
    }

    fn len(&self) -> usize {
        match self {
            WireCol::F32(v) => v.len(),
            WireCol::F64(v) => v.len(),
        }
    }

    fn byte_len(&self) -> usize {
        match self {
            WireCol::F32(v) => v.len() * 4,
            WireCol::F64(v) => v.len() * 8,
        }
    }

    fn write(&self, out: &mut Vec<u8>) {
        match self {
            WireCol::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            WireCol::F64(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    fn read(dtype: &str, len: usize, bytes: &[u8]) -> Result<WireCol> {
        match dtype {
            "f32" => Ok(WireCol::F32(
                bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            )),
            "f64" => Ok(WireCol::F64(
                bytes.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect(),
            )),
            other => Err(Error::Wire(format!("unknown column dtype `{other}` (len {len})"))),
        }
    }
}

/// A decoded (or under-construction) wire frame: metadata plus named
/// binary columns in insertion order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireDoc {
    /// Free-form JSON metadata (kept ASCII by convention — the header
    /// parser is ASCII-only).
    pub meta: BTreeMap<String, Json>,
    cols: Vec<(String, WireCol)>,
}

impl WireDoc {
    pub fn new() -> WireDoc {
        WireDoc::default()
    }

    /// Convenience constructor with a `kind` tag — the shard transport
    /// dispatches on `meta["kind"]`.
    pub fn with_kind(kind: &str) -> WireDoc {
        let mut doc = WireDoc::new();
        doc.set_str("kind", kind);
        doc
    }

    /// Build a [`kinds::HELLO`] handshake frame advertising a plan
    /// format version. Sent by the coordinator on (re)connect and echoed
    /// by the worker; a version mismatch fails the rejoin typed.
    pub fn hello(plan_major: u64) -> WireDoc {
        let mut doc = WireDoc::with_kind(kinds::HELLO);
        doc.set_u64("plan_v", plan_major);
        doc
    }

    // ---------------------------------------------------------------- meta

    pub fn set_str(&mut self, key: &str, value: &str) {
        self.meta.insert(key.to_string(), Json::Str(value.to_string()));
    }

    pub fn set_num(&mut self, key: &str, value: f64) {
        self.meta.insert(key.to_string(), Json::Num(value));
    }

    /// Store a `u64` losslessly (JSON numbers are f64; large ids/seeds go
    /// as decimal strings, like [`crate::api::Plan::to_json`]'s seed).
    pub fn set_u64(&mut self, key: &str, value: u64) {
        self.meta.insert(key.to_string(), Json::Str(value.to_string()));
    }

    pub fn set_json(&mut self, key: &str, value: Json) {
        self.meta.insert(key.to_string(), value);
    }

    pub fn kind(&self) -> &str {
        self.meta.get("kind").and_then(Json::as_str).unwrap_or("")
    }

    pub fn get_str(&self, key: &str) -> Result<&str> {
        self.meta
            .get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Wire(format!("missing string meta `{key}`")))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Wire(format!("missing integer meta `{key}`")))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64> {
        self.meta
            .get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| Error::Wire(format!("missing number meta `{key}`")))
    }

    pub fn get_u64(&self, key: &str) -> Result<u64> {
        self.get_str(key)?
            .parse::<u64>()
            .map_err(|_| Error::Wire(format!("meta `{key}` is not a decimal u64")))
    }

    // ------------------------------------------------------------- columns

    /// Append an f32 column. Duplicate names are a typed error — a frame
    /// with two same-named columns has an ambiguous directory.
    pub fn push_f32(&mut self, name: &str, data: &[f32]) -> Result<()> {
        self.push_col(name, WireCol::F32(data.to_vec()))
    }

    pub fn push_f64(&mut self, name: &str, data: &[f64]) -> Result<()> {
        self.push_col(name, WireCol::F64(data.to_vec()))
    }

    fn push_col(&mut self, name: &str, col: WireCol) -> Result<()> {
        if self.cols.iter().any(|(n, _)| n == name) {
            return Err(Error::Wire(format!("duplicate column `{name}`")));
        }
        self.cols.push((name.to_string(), col));
        Ok(())
    }

    pub fn has_col(&self, name: &str) -> bool {
        self.cols.iter().any(|(n, _)| n == name)
    }

    pub fn col_names(&self) -> impl Iterator<Item = &str> {
        self.cols.iter().map(|(n, _)| n.as_str())
    }

    pub fn f32s(&self, name: &str) -> Result<&[f32]> {
        match self.cols.iter().find(|(n, _)| n == name) {
            Some((_, WireCol::F32(v))) => Ok(v),
            Some((_, other)) => {
                Err(Error::Wire(format!("column `{name}` is {}, expected f32", other.dtype())))
            }
            None => Err(Error::Wire(format!("missing column `{name}`"))),
        }
    }

    pub fn f64s(&self, name: &str) -> Result<&[f64]> {
        match self.cols.iter().find(|(n, _)| n == name) {
            Some((_, WireCol::F64(v))) => Ok(v),
            Some((_, other)) => {
                Err(Error::Wire(format!("column `{name}` is {}, expected f64", other.dtype())))
            }
            None => Err(Error::Wire(format!("missing column `{name}`"))),
        }
    }

    // ------------------------------------------------------------ framing

    /// Encode to a self-delimiting frame (see the module docs for the
    /// layout).
    pub fn encode(&self) -> Vec<u8> {
        let mut dir = Vec::with_capacity(self.cols.len());
        for (name, col) in &self.cols {
            let mut entry = BTreeMap::new();
            entry.insert("name".to_string(), Json::Str(name.clone()));
            entry.insert("dtype".to_string(), Json::Str(col.dtype().to_string()));
            entry.insert("len".to_string(), Json::Num(col.len() as f64));
            dir.push(Json::Obj(entry));
        }
        let mut header = BTreeMap::new();
        header.insert("v".to_string(), Json::Num(1.0));
        header.insert("meta".to_string(), Json::Obj(self.meta.clone()));
        header.insert("cols".to_string(), Json::Arr(dir));
        let header_bytes = Json::Obj(header).encode().into_bytes();

        let payload_len: usize = self.cols.iter().map(|(_, c)| c.byte_len()).sum();
        let mut out = Vec::with_capacity(8 + header_bytes.len() + payload_len);
        out.extend_from_slice(&WIRE_MAGIC);
        out.extend_from_slice(&(header_bytes.len() as u32).to_le_bytes());
        out.extend_from_slice(&header_bytes);
        for (_, col) in &self.cols {
            col.write(&mut out);
        }
        out
    }

    /// Decode a frame produced by [`WireDoc::encode`]. Every malformation
    /// is a typed [`Error::Wire`]; the payload must match the directory
    /// *exactly* (no trailing bytes, no short columns).
    pub fn decode(bytes: &[u8]) -> Result<WireDoc> {
        if bytes.len() < 8 {
            return Err(Error::Wire(format!("frame too short ({} bytes)", bytes.len())));
        }
        if bytes[..4] != WIRE_MAGIC {
            return Err(Error::Wire(format!(
                "bad magic {:02x}{:02x}{:02x}{:02x} (expected \"LSW1\")",
                bytes[0], bytes[1], bytes[2], bytes[3]
            )));
        }
        let header_len = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        if header_len > MAX_HEADER_LEN {
            return Err(Error::Wire(format!("header length {header_len} exceeds cap")));
        }
        if bytes.len() < 8 + header_len {
            return Err(Error::Wire(format!(
                "truncated header: declares {header_len} bytes, frame has {}",
                bytes.len() - 8
            )));
        }
        let header_text = std::str::from_utf8(&bytes[8..8 + header_len])
            .map_err(|_| Error::Wire("header is not UTF-8".into()))?;
        let header =
            Json::parse(header_text).map_err(|e| Error::Wire(format!("header json: {e}")))?;
        match header.get("v").and_then(Json::as_usize) {
            Some(1) => {}
            Some(v) => return Err(Error::Wire(format!("unsupported wire version {v}"))),
            None => return Err(Error::Wire("header missing version".into())),
        }
        let meta = header
            .get("meta")
            .and_then(Json::as_obj)
            .cloned()
            .ok_or_else(|| Error::Wire("header missing `meta` object".into()))?;
        let dir = header
            .get("cols")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Wire("header missing `cols` directory".into()))?;

        let mut doc = WireDoc { meta, cols: Vec::with_capacity(dir.len()) };
        let payload = &bytes[8 + header_len..];
        let mut offset = 0usize;
        for entry in dir {
            let name = entry
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Wire("column entry missing `name`".into()))?;
            let dtype = entry
                .get("dtype")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Wire(format!("column `{name}` missing `dtype`")))?;
            let len = entry
                .get("len")
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Wire(format!("column `{name}` missing `len`")))?;
            let width = match dtype {
                "f32" => 4usize,
                "f64" => 8usize,
                other => {
                    return Err(Error::Wire(format!("unknown column dtype `{other}`")));
                }
            };
            let byte_len = len
                .checked_mul(width)
                .ok_or_else(|| Error::Wire(format!("column `{name}` length overflows")))?;
            let end = offset
                .checked_add(byte_len)
                .filter(|&e| e <= payload.len())
                .ok_or_else(|| {
                    Error::Wire(format!(
                        "payload length mismatch: column `{name}` needs {byte_len} bytes at \
                         offset {offset}, payload has {}",
                        payload.len()
                    ))
                })?;
            doc.push_col(name, WireCol::read(dtype, len, &payload[offset..end])?)?;
            offset = end;
        }
        if offset != payload.len() {
            return Err(Error::Wire(format!(
                "payload length mismatch: directory covers {offset} bytes, payload has {}",
                payload.len()
            )));
        }
        Ok(doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_meta_and_columns() {
        let mut doc = WireDoc::with_kind("task");
        doc.set_u64("id", u64::MAX);
        doc.set_num("eps", 0.1);
        doc.push_f32("w", &[1.0, -0.0, f32::MIN_POSITIVE]).unwrap();
        doc.push_f64("obj", &[1.0 / 3.0]).unwrap();
        let back = WireDoc::decode(&doc.encode()).unwrap();
        assert_eq!(back.kind(), "task");
        assert_eq!(back.get_u64("id").unwrap(), u64::MAX);
        assert_eq!(back.get_f64("eps").unwrap().to_bits(), 0.1f64.to_bits());
        let w = back.f32s("w").unwrap();
        assert_eq!(w[1].to_bits(), (-0.0f32).to_bits(), "signed zero survives");
        assert_eq!(back.f64s("obj").unwrap()[0].to_bits(), (1.0f64 / 3.0).to_bits());
        assert_eq!(back, doc);
    }

    #[test]
    fn empty_doc_and_empty_columns_round_trip() {
        let mut doc = WireDoc::new();
        doc.push_f32("empty", &[]).unwrap();
        let back = WireDoc::decode(&doc.encode()).unwrap();
        assert_eq!(back.f32s("empty").unwrap().len(), 0);
        assert_eq!(WireDoc::decode(&WireDoc::new().encode()).unwrap(), WireDoc::new());
    }

    #[test]
    fn duplicate_columns_rejected() {
        let mut doc = WireDoc::new();
        doc.push_f32("w", &[1.0]).unwrap();
        assert!(matches!(doc.push_f64("w", &[1.0]), Err(Error::Wire(_))));
    }

    #[test]
    fn truncation_and_tampering_are_typed_errors() {
        let mut doc = WireDoc::new();
        doc.push_f32("w", &[1.0, 2.0, 3.0]).unwrap();
        let frame = doc.encode();
        // Truncate the payload.
        assert!(matches!(WireDoc::decode(&frame[..frame.len() - 1]), Err(Error::Wire(_))));
        // Extra trailing bytes.
        let mut long = frame.clone();
        long.push(0);
        assert!(matches!(WireDoc::decode(&long), Err(Error::Wire(_))));
        // Bad magic.
        let mut bad = frame.clone();
        bad[0] = b'X';
        assert!(matches!(WireDoc::decode(&bad), Err(Error::Wire(_))));
        // Corrupt header bytes.
        let mut garbled = frame;
        garbled[10] ^= 0xFF;
        assert!(matches!(WireDoc::decode(&garbled), Err(Error::Wire(_))));
        // Too short for even the prefix.
        assert!(matches!(WireDoc::decode(&[0, 1, 2]), Err(Error::Wire(_))));
    }

    #[test]
    fn oversized_header_length_rejected_without_allocation() {
        let mut frame = Vec::new();
        frame.extend_from_slice(&WIRE_MAGIC);
        frame.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(WireDoc::decode(&frame), Err(Error::Wire(_))));
    }

    #[test]
    fn wrong_dtype_access_is_typed() {
        let mut doc = WireDoc::new();
        doc.push_f32("w", &[1.0]).unwrap();
        assert!(matches!(doc.f64s("w"), Err(Error::Wire(_))));
        assert!(matches!(doc.f32s("missing"), Err(Error::Wire(_))));
    }
}
