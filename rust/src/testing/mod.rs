//! Mini property-testing harness (the offline crate set has no proptest).
//!
//! [`property`] runs a closure over `cases` seeded RNG draws; on failure it
//! *shrinks by seed replay* — it reports the failing seed so the case is
//! exactly reproducible (`PROP_SEED=<seed>` re-runs a single case).
//! Generators live on [`Gen`], a thin wrapper over [`crate::rng::Rng`].

use crate::linalg::Mat;
use crate::rng::Rng;

/// Value generator for property tests.
pub struct Gen {
    pub rng: Rng,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen { rng: Rng::seed_from(seed) }
    }

    /// Integer in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.uniform_usize(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    /// Strictly positive vector summing to 1 (a probability histogram).
    pub fn simplex(&mut self, n: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..n).map(|_| self.rng.uniform_in(0.05, 1.0) as f32).collect();
        let s: f32 = v.iter().sum();
        v.iter_mut().for_each(|x| *x /= s);
        v
    }

    /// Strictly positive matrix with entries in [lo, hi].
    pub fn positive_mat(&mut self, rows: usize, cols: usize, lo: f32, hi: f32) -> Mat {
        assert!(lo > 0.0 && hi > lo);
        Mat::from_fn(rows, cols, |_, _| self.rng.uniform_in(lo as f64, hi as f64) as f32)
    }

    /// Gaussian point cloud.
    pub fn cloud(&mut self, n: usize, d: usize, std: f32) -> Mat {
        Mat::from_fn(n, d, |_, _| self.rng.normal_f32() * std)
    }
}

/// Run `f` over `cases` generated inputs. Panics with the failing seed on
/// the first failure. If env `PROP_SEED` is set, runs only that seed.
pub fn property(name: &str, cases: usize, mut f: impl FnMut(&mut Gen)) {
    if let Ok(seed_str) = std::env::var("PROP_SEED") {
        let seed: u64 = seed_str.parse().expect("PROP_SEED must be a u64");
        let mut g = Gen::new(seed);
        f(&mut g);
        return;
    }
    for case in 0..cases {
        // Deterministic per-case seed derived from the property name so
        // adding tests elsewhere never shifts this property's cases.
        let seed = fnv1a(name) ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property `{name}` failed at case {case} (re-run with PROP_SEED={seed}):\n{msg}"
            );
        }
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simplex_sums_to_one_and_positive() {
        property("simplex", 50, |g| {
            let n = g.usize_in(1, 100);
            let s = g.simplex(n);
            let total: f32 = s.iter().sum();
            assert!((total - 1.0).abs() < 1e-4);
            assert!(s.iter().all(|&x| x > 0.0));
        });
    }

    #[test]
    fn positive_mat_in_range() {
        property("positive_mat", 20, |g| {
            let rows = g.usize_in(1, 10);
            let cols = g.usize_in(1, 10);
            let m = g.positive_mat(rows, cols, 0.5, 2.0);
            assert!(m.min_entry() >= 0.5 && m.max_entry() <= 2.0);
        });
    }

    #[test]
    fn property_seeds_are_deterministic() {
        let mut first = Vec::new();
        property("det", 5, |g| first.push(g.rng.next_u64()));
        let mut second = Vec::new();
        property("det", 5, |g| second.push(g.rng.next_u64()));
        assert_eq!(first, second);
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed")]
    fn failures_report_seed() {
        property("always_fails", 3, |_| panic!("boom"));
    }
}
