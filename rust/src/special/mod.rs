//! Special functions substrate.
//!
//! Lemma 1 needs the Lambert W function for
//! `q = eps^{-1} R^2 / (2 d W0(eps^{-1} R^2 / d))`; the synthetic data
//! generators and test oracles use `erf` / `log_gamma`. The [`vexp`]
//! submodule holds the SIMD core's vectorised `exp`/`ln` (documented
//! ≤ 2 ulp contract) behind the log-domain Sinkhorn hot path.

pub mod vexp;

/// Principal branch W0 of the Lambert W function for `z >= 0`.
///
/// Halley iterations from a log-based initial guess; converges to ~1e-14
/// in < 8 iterations over the range used by Lemma 1 (z in [1e-6, 1e8]).
pub fn lambert_w0(z: f64) -> f64 {
    assert!(z >= 0.0 && z.is_finite(), "lambert_w0: domain is z >= 0, got {z}");
    if z == 0.0 {
        return 0.0;
    }
    // Initial guess.
    let mut w = if z > std::f64::consts::E {
        let l = z.ln();
        l - l.ln()
    } else {
        // Series-ish rational guess, good on (0, e].
        z / (1.0 + z)
    };
    for _ in 0..32 {
        let ew = w.exp();
        let f = w * ew - z;
        let denom = ew * (w + 1.0) - (w + 2.0) * f / (2.0 * w + 2.0);
        let step = f / denom;
        w -= step;
        if step.abs() < 1e-14 * w.abs().max(1e-14) {
            break;
        }
    }
    w
}

/// The Lemma-1 scale constant `q(eps, R, d)`.
///
/// Larger `q` means fatter feature tails: the bound `psi = 2 (2q)^{d/2}` on
/// the ratio `phi phi / k` (and hence the required number of random
/// features, Thm 3.1) grows with it.
pub fn gaussian_q(eps: f64, radius: f64, dim: usize) -> f64 {
    assert!(eps > 0.0 && radius > 0.0 && dim > 0);
    let z = radius * radius / (eps * dim as f64);
    radius * radius / (eps * 2.0 * dim as f64 * lambert_w0(z))
}

/// The Lemma-1 anchor distribution's standard deviation: sigma^2 = q eps/4.
pub fn gaussian_sigma(eps: f64, radius: f64, dim: usize) -> f64 {
    (gaussian_q(eps, radius, dim) * eps / 4.0).sqrt()
}

/// Error function, Abramowitz–Stegun 7.1.26 rational approximation
/// (|err| < 1.5e-7, plenty for data generation and tests).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Natural log of the gamma function (Lanczos, g=7, n=9).
pub fn log_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    assert!(x > 0.0, "log_gamma: domain is x > 0");
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - log_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambert_w0_known_values() {
        // (z, W0(z)) references from scipy.special.lambertw.
        let cases = [
            (0.0, 0.0),
            (1.0, 0.5671432904097838),
            (std::f64::consts::E, 1.0),
            (10.0, 1.7455280027406994),
            (100.0, 3.3856301402900502),
            (1e4, 7.231846038093373),
            // W(1e8): w e^w = 1e8 with w = 15.6689967...
            (1e8, 15.668996715450962),
        ];
        for (z, want) in cases {
            let got = lambert_w0(z);
            assert!((got - want).abs() < 1e-10, "W0({z}) = {got}, want {want}");
        }
    }

    #[test]
    fn lambert_w0_inverse_property() {
        for i in 0..200 {
            let z = 1e-5 * (1.12f64).powi(i);
            let w = lambert_w0(z);
            assert!((w * w.exp() - z).abs() < 1e-9 * z.max(1.0), "z={z}");
        }
    }

    #[test]
    #[should_panic]
    fn lambert_w0_rejects_negative() {
        lambert_w0(-0.5);
    }

    #[test]
    fn gaussian_q_matches_python_oracle() {
        // Cross-checked against python ref.gaussian_q (eps=0.5, R=3, d=2).
        let q = gaussian_q(0.5, 3.0, 2);
        assert!((q - 2.680140) < 1e-3, "q = {q}");
        assert!(q > 0.0);
    }

    #[test]
    fn gaussian_q_grows_with_radius() {
        let q1 = gaussian_q(0.5, 1.0, 4);
        let q2 = gaussian_q(0.5, 4.0, 4);
        assert!(q2 > q1);
    }

    #[test]
    fn gaussian_q_at_least_one_lambert_regime() {
        // For small z, W0(z) ~ z so q ~ R^2/(2 eps d z) = 0.5 — q is bounded
        // below by ~0.5 in the small-radius regime.
        let q = gaussian_q(10.0, 0.1, 8);
        assert!(q > 0.45 && q < 0.60, "q = {q}");
    }

    #[test]
    fn erf_reference_values() {
        let cases = [(0.0, 0.0), (0.5, 0.5204998778), (1.0, 0.8427007929), (2.0, 0.9953222650)];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 2e-7, "erf({x})");
        }
        assert!((erf(-1.0) + 0.8427007929).abs() < 2e-7);
    }

    #[test]
    fn log_gamma_factorials() {
        // Gamma(n) = (n-1)!
        let mut fact = 1.0f64;
        for n in 1..15u32 {
            if n > 1 {
                fact *= (n - 1) as f64;
            }
            assert!((log_gamma(n as f64) - fact.ln()).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn log_gamma_half() {
        // Gamma(1/2) = sqrt(pi).
        assert!((log_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-9);
    }
}
