//! Vectorised elementwise `exp` / `ln` — the transcendental layer of the
//! SIMD core (EXPERIMENTS.md §Perf, "SIMD core").
//!
//! The log-domain Sinkhorn path pays one f64 `exp` per kernel entry per
//! update (`lse_matvec*` / `lse_matmat*` in [`crate::linalg`], and the
//! nested-logsumexp applies of the factored kernel), plus a `ln` per
//! output column in the transposed reduction's finish. This module
//! replaces those libm calls on the AVX2+FMA dispatch arm with 4-lane
//! polynomial evaluations (`exp4` / `ln4`, Cephes `exp`/`log` rational
//! approximations carried over verbatim to `__m256d` — `exp4` inside
//! `lse_row`/`lse_accum_rows`, `ln4` inside `lse_finish`), and exposes
//! safe slice front-ends ([`vexp_at`], [`vln_at`],
//! [`exp_clamped_f32_at`]) for the scalar-vs-SIMD agreement tests and
//! the feature-map exponentials.
//!
//! ## Accuracy contract
//!
//! * **`exp`**: relative error ≤ 2 ulp on `[-708.39, 709.4]`. Arguments
//!   below `-708.39` return `+0.0` (results that would be subnormal
//!   flush to zero — the shifted logsumexp feeds arguments `≤ 0` whose
//!   dominant term is `exp(0) = 1`, so a dropped `1e-308` straggler is
//!   far below f64 rounding of the sum); arguments above `~709.4`
//!   return `+inf` (true overflow is at `709.78`; the window in between
//!   overflows one `exp2` step early). `exp(0) = 1` exactly; `-inf → 0`,
//!   `+inf → +inf`, `NaN → NaN`.
//! * **`ln`**: relative error ≤ 2 ulp of the result over the full
//!   positive range, including subnormal inputs (rescaled by `2^54`
//!   before reduction) and inputs near 1 (the reduction `m = x - 1` is
//!   exact there, so the relative contract survives the zero crossing).
//!   `ln(1) = 0` exactly; `0 → -inf`, negative and `NaN → NaN`,
//!   `+inf → +inf`.
//!
//! The **scalar arm is libm** (`f64::exp` / `f64::ln`), kept verbatim so
//! forcing `LINEAR_SINKHORN_SIMD=scalar` reproduces the pre-SIMD
//! numbers bitwise. Cross-arm agreement is therefore bounded by the sum
//! of both contracts (≲ 3 ulp) — asserted in the tests below and relied
//! on by the documented scalar-vs-SIMD tolerances in
//! `rust/tests/parallel_equivalence.rs`.

use crate::linalg::simd::SimdLevel;

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

// --- Cephes `exp` constants (shortest round-trip f64 spellings). ---
#[cfg(target_arch = "x86_64")]
const EXP_P0: f64 = 0.000_126_177_193_074_810_58;
#[cfg(target_arch = "x86_64")]
const EXP_P1: f64 = 0.030_299_440_770_744_195;
#[cfg(target_arch = "x86_64")]
const EXP_P2: f64 = 1.0;
#[cfg(target_arch = "x86_64")]
const EXP_Q0: f64 = 3.001_985_051_386_644_6e-6;
#[cfg(target_arch = "x86_64")]
const EXP_Q1: f64 = 0.002_524_483_403_496_841;
#[cfg(target_arch = "x86_64")]
const EXP_Q2: f64 = 0.227_265_548_208_155_03;
#[cfg(target_arch = "x86_64")]
const EXP_Q3: f64 = 2.0;
/// `ln 2` split hi/lo for an exact argument reduction.
#[cfg(target_arch = "x86_64")]
const LN2_HI: f64 = 0.693_145_751_953_125;
#[cfg(target_arch = "x86_64")]
const LN2_LO: f64 = 1.428_606_820_309_417_3e-6;
/// Overflow / flush-to-zero cutoffs (Cephes MAXLOG / MINLOG).
#[cfg(target_arch = "x86_64")]
const EXP_HI: f64 = 709.782_712_893_384;
#[cfg(target_arch = "x86_64")]
const EXP_LO: f64 = -708.396_418_532_264_1;

// --- Cephes `log` constants. ---
#[cfg(target_arch = "x86_64")]
const LOG_P: [f64; 6] = [
    0.000_101_875_663_804_580_93,
    0.497_494_994_976_747,
    4.705_791_198_788_817,
    14.498_922_534_161_093,
    17.936_867_850_781_983,
    7.708_387_337_558_854,
];
#[cfg(target_arch = "x86_64")]
const LOG_Q: [f64; 5] = [
    11.287_358_718_916_746,
    45.227_914_583_753_225,
    82.987_526_691_277_67,
    71.154_475_061_856_39,
    23.125_162_012_676_533,
];
/// `ln 2` split for the log reconstruction (coarse + correction).
#[cfg(target_arch = "x86_64")]
const LOG_LN2_COARSE: f64 = 0.693_359_375;
#[cfg(target_arch = "x86_64")]
const LOG_LN2_CORR: f64 = 0.000_212_194_440_054_690_57;
#[cfg(target_arch = "x86_64")]
const TWO_54: f64 = 18_014_398_509_481_984.0; // 2^54, exact

/// 4-lane `exp` (see the module accuracy contract).
///
/// # Safety
///
/// Requires AVX2 + FMA; callers must have verified
/// [`crate::linalg::simd::avx2_available`] (or hold a
/// [`SimdLevel::Avx2Fma`] produced by the runtime dispatcher).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn exp4(x: __m256d) -> __m256d {
    // n = floor(x * log2(e) + 1/2): the power-of-two exponent.
    let n = _mm256_floor_pd(_mm256_fmadd_pd(
        x,
        _mm256_set1_pd(std::f64::consts::LOG2_E),
        _mm256_set1_pd(0.5),
    ));
    // r = x - n ln2, reduced with a split constant so r is nearly exact.
    let mut r = _mm256_fnmadd_pd(n, _mm256_set1_pd(LN2_HI), x);
    r = _mm256_fnmadd_pd(n, _mm256_set1_pd(LN2_LO), r);
    let rr = _mm256_mul_pd(r, r);
    // exp(r) = 1 + 2 r P(r^2) / (Q(r^2) - r P(r^2)), |r| <= ln2/2.
    let mut p = _mm256_set1_pd(EXP_P0);
    p = _mm256_fmadd_pd(p, rr, _mm256_set1_pd(EXP_P1));
    p = _mm256_fmadd_pd(p, rr, _mm256_set1_pd(EXP_P2));
    let px = _mm256_mul_pd(r, p);
    let mut q = _mm256_set1_pd(EXP_Q0);
    q = _mm256_fmadd_pd(q, rr, _mm256_set1_pd(EXP_Q1));
    q = _mm256_fmadd_pd(q, rr, _mm256_set1_pd(EXP_Q2));
    q = _mm256_fmadd_pd(q, rr, _mm256_set1_pd(EXP_Q3));
    let e = _mm256_div_pd(px, _mm256_sub_pd(q, px));
    let y = _mm256_fmadd_pd(e, _mm256_set1_pd(2.0), _mm256_set1_pd(1.0));
    // Scale by 2^n through the exponent bits: n is clamped to [-1022,
    // 1024] by the EXP_LO/EXP_HI masks below, so `(n + 1023) << 52` is a
    // valid (or deliberately infinite) exponent field.
    let n64 = _mm256_cvtepi32_epi64(_mm256_cvtpd_epi32(n));
    let bias = _mm256_add_epi64(n64, _mm256_set1_epi64x(1023));
    let pow = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(bias));
    let mut out = _mm256_mul_pd(y, pow);
    // Special cases, applied last so they win over the garbage the core
    // computes for out-of-range lanes.
    let lo = _mm256_cmp_pd::<_CMP_LT_OQ>(x, _mm256_set1_pd(EXP_LO));
    out = _mm256_blendv_pd(out, _mm256_setzero_pd(), lo);
    let hi = _mm256_cmp_pd::<_CMP_GT_OQ>(x, _mm256_set1_pd(EXP_HI));
    out = _mm256_blendv_pd(out, _mm256_set1_pd(f64::INFINITY), hi);
    let nan = _mm256_cmp_pd::<_CMP_UNORD_Q>(x, x);
    _mm256_blendv_pd(out, x, nan)
}

/// 4-lane `ln` (see the module accuracy contract).
///
/// # Safety
///
/// Same requirement as [`exp4`]: AVX2 + FMA must be available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
pub(crate) unsafe fn ln4(x: __m256d) -> __m256d {
    let one = _mm256_set1_pd(1.0);
    // Rescale subnormal inputs into the normal range (x * 2^54, e -= 54);
    // lanes with x <= 0 also match but are overwritten by the masks below.
    let tiny = _mm256_cmp_pd::<_CMP_LT_OQ>(x, _mm256_set1_pd(f64::MIN_POSITIVE));
    let xs = _mm256_blendv_pd(x, _mm256_mul_pd(x, _mm256_set1_pd(TWO_54)), tiny);
    let e_adj = _mm256_and_pd(tiny, _mm256_set1_pd(54.0));
    // frexp: biased exponent -> e, mantissa -> m in [1/2, 1).
    let bits = _mm256_castpd_si256(xs);
    let expo = _mm256_and_si256(_mm256_srli_epi64::<52>(bits), _mm256_set1_epi64x(0x7ff));
    let packed = _mm256_permutevar8x32_epi32(expo, _mm256_setr_epi32(0, 2, 4, 6, 0, 0, 0, 0));
    let mut e = _mm256_cvtepi32_pd(_mm256_castsi256_si128(packed));
    e = _mm256_sub_pd(e, _mm256_set1_pd(1022.0));
    e = _mm256_sub_pd(e, e_adj);
    let mant = _mm256_or_si256(
        _mm256_and_si256(bits, _mm256_set1_epi64x(0x000F_FFFF_FFFF_FFFF)),
        _mm256_set1_epi64x(0x3FE0_0000_0000_0000),
    );
    let m = _mm256_castsi256_pd(mant);
    // If m < 1/sqrt(2): e -= 1 and m = 2m - 1, else m = m - 1 (both
    // subtractions are exact — Sterbenz — which is what keeps ln accurate
    // through its zero at x = 1).
    let small = _mm256_cmp_pd::<_CMP_LT_OQ>(m, _mm256_set1_pd(std::f64::consts::FRAC_1_SQRT_2));
    e = _mm256_sub_pd(e, _mm256_and_pd(small, one));
    let m = _mm256_blendv_pd(_mm256_sub_pd(m, one), _mm256_sub_pd(_mm256_add_pd(m, m), one), small);
    let z = _mm256_mul_pd(m, m);
    // y = m z P(m)/Q(m) (Q monic of degree 5).
    let mut p = _mm256_set1_pd(LOG_P[0]);
    p = _mm256_fmadd_pd(p, m, _mm256_set1_pd(LOG_P[1]));
    p = _mm256_fmadd_pd(p, m, _mm256_set1_pd(LOG_P[2]));
    p = _mm256_fmadd_pd(p, m, _mm256_set1_pd(LOG_P[3]));
    p = _mm256_fmadd_pd(p, m, _mm256_set1_pd(LOG_P[4]));
    p = _mm256_fmadd_pd(p, m, _mm256_set1_pd(LOG_P[5]));
    let mut q = _mm256_add_pd(m, _mm256_set1_pd(LOG_Q[0]));
    q = _mm256_fmadd_pd(q, m, _mm256_set1_pd(LOG_Q[1]));
    q = _mm256_fmadd_pd(q, m, _mm256_set1_pd(LOG_Q[2]));
    q = _mm256_fmadd_pd(q, m, _mm256_set1_pd(LOG_Q[3]));
    q = _mm256_fmadd_pd(q, m, _mm256_set1_pd(LOG_Q[4]));
    let mut y = _mm256_mul_pd(_mm256_mul_pd(m, z), _mm256_div_pd(p, q));
    y = _mm256_fnmadd_pd(e, _mm256_set1_pd(LOG_LN2_CORR), y);
    y = _mm256_fnmadd_pd(_mm256_set1_pd(0.5), z, y);
    let mut out = _mm256_add_pd(m, y);
    out = _mm256_fmadd_pd(e, _mm256_set1_pd(LOG_LN2_COARSE), out);
    // Special cases: +inf -> +inf, ±0 -> -inf, negative / NaN -> NaN.
    let inf = _mm256_set1_pd(f64::INFINITY);
    let is_inf = _mm256_cmp_pd::<_CMP_EQ_OQ>(x, inf);
    out = _mm256_blendv_pd(out, inf, is_inf);
    let is_zero = _mm256_cmp_pd::<_CMP_EQ_OQ>(x, _mm256_setzero_pd());
    out = _mm256_blendv_pd(out, _mm256_set1_pd(f64::NEG_INFINITY), is_zero);
    let bad = _mm256_cmp_pd::<_CMP_NGE_UQ>(x, _mm256_setzero_pd());
    _mm256_blendv_pd(out, _mm256_set1_pd(f64::NAN), bad)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn vexp_avx2(xs: &mut [f64]) {
    let n = xs.len();
    let p = xs.as_mut_ptr();
    let mut i = 0;
    while i + 4 <= n {
        _mm256_storeu_pd(p.add(i), exp4(_mm256_loadu_pd(p.add(i))));
        i += 4;
    }
    if i < n {
        // Tail through the same polynomial via a padded register, so the
        // AVX2 arm's per-element contract is uniform across lengths.
        let mut buf = [0.0f64; 4];
        buf[..n - i].copy_from_slice(&xs[i..]);
        let out = exp4(_mm256_loadu_pd(buf.as_ptr()));
        _mm256_storeu_pd(buf.as_mut_ptr(), out);
        xs[i..].copy_from_slice(&buf[..n - i]);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn vln_avx2(xs: &mut [f64]) {
    let n = xs.len();
    let p = xs.as_mut_ptr();
    let mut i = 0;
    while i + 4 <= n {
        _mm256_storeu_pd(p.add(i), ln4(_mm256_loadu_pd(p.add(i))));
        i += 4;
    }
    if i < n {
        let mut buf = [1.0f64; 4];
        buf[..n - i].copy_from_slice(&xs[i..]);
        let out = ln4(_mm256_loadu_pd(buf.as_ptr()));
        _mm256_storeu_pd(buf.as_mut_ptr(), out);
        xs[i..].copy_from_slice(&buf[..n - i]);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn exp_clamped_f32_avx2(xs: &mut [f32], lo: f32, hi: f32) {
    let n = xs.len();
    let p = xs.as_mut_ptr();
    let lo8 = _mm256_set1_ps(lo);
    let hi8 = _mm256_set1_ps(hi);
    let mut i = 0;
    while i + 8 <= n {
        let x0 = _mm256_loadu_ps(p.add(i));
        let v = _mm256_min_ps(_mm256_max_ps(x0, lo8), hi8);
        let e_lo = _mm256_cvtpd_ps(exp4(_mm256_cvtps_pd(_mm256_castps256_ps128(v))));
        let e_hi = _mm256_cvtpd_ps(exp4(_mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v))));
        let mut out = _mm256_set_m128(e_hi, e_lo);
        // max_ps/min_ps drop NaN lanes to the clamp bound; propagate NaN
        // like the scalar arm (`clamp(..).exp()` of NaN is NaN) so
        // non-finite feature parameters fail loudly on both arms.
        let nan = _mm256_cmp_ps::<_CMP_UNORD_Q>(x0, x0);
        out = _mm256_blendv_ps(out, x0, nan);
        _mm256_storeu_ps(p.add(i), out);
        i += 8;
    }
    while i < n {
        *p.add(i) = (*p.add(i)).clamp(lo, hi).exp();
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
fn vexp_avx2_call(xs: &mut [f64]) {
    // SAFETY: callers hold a sanitised `SimdLevel::Avx2Fma`, which only
    // exists after runtime detection (`SimdLevel::sanitize`).
    unsafe { vexp_avx2(xs) }
}

#[cfg(not(target_arch = "x86_64"))]
fn vexp_avx2_call(xs: &mut [f64]) {
    vexp_scalar(xs)
}

#[cfg(target_arch = "x86_64")]
fn vln_avx2_call(xs: &mut [f64]) {
    // SAFETY: as in `vexp_avx2_call`.
    unsafe { vln_avx2(xs) }
}

#[cfg(not(target_arch = "x86_64"))]
fn vln_avx2_call(xs: &mut [f64]) {
    vln_scalar(xs)
}

#[cfg(target_arch = "x86_64")]
fn exp_clamped_f32_avx2_call(xs: &mut [f32], lo: f32, hi: f32) {
    // SAFETY: as in `vexp_avx2_call`.
    unsafe { exp_clamped_f32_avx2(xs, lo, hi) }
}

#[cfg(not(target_arch = "x86_64"))]
fn exp_clamped_f32_avx2_call(xs: &mut [f32], lo: f32, hi: f32) {
    exp_clamped_f32_scalar(xs, lo, hi)
}

fn vexp_scalar(xs: &mut [f64]) {
    for v in xs.iter_mut() {
        *v = v.exp();
    }
}

fn vln_scalar(xs: &mut [f64]) {
    for v in xs.iter_mut() {
        *v = v.ln();
    }
}

fn exp_clamped_f32_scalar(xs: &mut [f32], lo: f32, hi: f32) {
    for v in xs.iter_mut() {
        *v = v.clamp(lo, hi).exp();
    }
}

/// Elementwise `exp` in place on the given dispatch arm (scalar = libm,
/// AVX2 = the 4-lane `exp4` polynomial; see the module accuracy
/// contract).
pub fn vexp_at(level: SimdLevel, xs: &mut [f64]) {
    match level.sanitize() {
        SimdLevel::Scalar => vexp_scalar(xs),
        SimdLevel::Avx2Fma => vexp_avx2_call(xs),
    }
}

/// Elementwise `ln` in place on the given dispatch arm.
pub fn vln_at(level: SimdLevel, xs: &mut [f64]) {
    match level.sanitize() {
        SimdLevel::Scalar => vln_scalar(xs),
        SimdLevel::Avx2Fma => vln_avx2_call(xs),
    }
}

/// Elementwise `x -> exp(clamp(x, lo, hi))` in place on f32 — the
/// feature-map exponential (`phi = exp(log phi)` under the
/// `LOG_FLOOR`/`LOG_CEIL` guards). The AVX2 arm clamps 8 lanes and runs
/// `exp4` on two f64 half-registers; the f64→f32 rounding keeps the
/// result within 1 f32 ulp of the libm scalar arm.
pub fn exp_clamped_f32_at(level: SimdLevel, xs: &mut [f32], lo: f32, hi: f32) {
    match level.sanitize() {
        SimdLevel::Scalar => exp_clamped_f32_scalar(xs, lo, hi),
        SimdLevel::Avx2Fma => exp_clamped_f32_avx2_call(xs, lo, hi),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::simd::avx2_available;

    /// 3 libm-relative ulp: the documented ≤2 ulp contract plus libm's
    /// own rounding of the reference value.
    fn assert_close(got: f64, want: f64, ctx: &str) {
        if want.is_nan() {
            assert!(got.is_nan(), "{ctx}: got {got}, want NaN");
            return;
        }
        if !want.is_finite() {
            assert_eq!(got, want, "{ctx}");
            return;
        }
        let tol = 3.0 * f64::EPSILON * want.abs().max(f64::MIN_POSITIVE);
        assert!((got - want).abs() <= tol, "{ctx}: got {got:e}, want {want:e}");
    }

    fn exp_inputs() -> Vec<f64> {
        let mut xs = vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            0.5,
            -0.5,
            1e-12,
            -1e-12,
            20.0,
            -20.0,
            303.7,
            -303.7,
            700.0,
            -700.0,
            708.0,
            -708.0,
            f64::NEG_INFINITY,
            f64::INFINITY,
            f64::NAN,
            -1e9,
            1e9,
        ];
        for i in 0..400 {
            xs.push(-690.0 + i as f64 * 3.47);
        }
        xs
    }

    #[test]
    fn scalar_vexp_is_libm() {
        let mut xs = exp_inputs();
        let want: Vec<f64> = xs.iter().map(|v| v.exp()).collect();
        vexp_at(SimdLevel::Scalar, &mut xs);
        for (g, w) in xs.iter().zip(&want) {
            assert!(g.to_bits() == w.to_bits() || (g.is_nan() && w.is_nan()));
        }
    }

    #[test]
    fn avx2_vexp_matches_libm_to_contract() {
        if !avx2_available() {
            return;
        }
        let mut xs = exp_inputs();
        let inputs = xs.clone();
        vexp_at(SimdLevel::Avx2Fma, &mut xs);
        for (&x, &got) in inputs.iter().zip(&xs) {
            if x > 709.4 && x.is_finite() {
                // Early-overflow window: +inf is the documented result.
                assert_eq!(got, f64::INFINITY, "exp({x})");
                continue;
            }
            let want = x.exp();
            if want != 0.0 && want < f64::MIN_POSITIVE {
                // Subnormal results flush to zero (documented).
                assert!(got == 0.0 || got.is_finite(), "exp({x}) = {got:e}");
                continue;
            }
            assert_close(got, want, &format!("exp({x})"));
        }
    }

    #[test]
    fn avx2_vexp_exact_anchors() {
        if !avx2_available() {
            return;
        }
        let mut xs = vec![0.0f64, f64::NEG_INFINITY];
        vexp_at(SimdLevel::Avx2Fma, &mut xs);
        assert_eq!(xs[0], 1.0, "exp(0) must be exactly 1");
        assert_eq!(xs[1], 0.0, "exp(-inf) must be exactly 0");
    }

    fn ln_inputs() -> Vec<f64> {
        let mut xs = vec![
            1.0,
            0.5,
            2.0,
            1.0 + 1e-8,
            1.0 - 1e-8,
            std::f64::consts::E,
            1e-300,
            1e-310, // subnormal
            1e300,
            f64::MAX,
            f64::MIN_POSITIVE,
            0.0,
            -0.0,
            -1.0,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
        ];
        for i in 1..400 {
            xs.push(i as f64 * 0.731);
            xs.push((i as f64 * 0.731).recip());
        }
        xs
    }

    #[test]
    fn avx2_vln_matches_libm_to_contract() {
        if !avx2_available() {
            return;
        }
        let mut xs = ln_inputs();
        let inputs = xs.clone();
        vln_at(SimdLevel::Avx2Fma, &mut xs);
        for (&x, &got) in inputs.iter().zip(&xs) {
            let want = x.ln();
            assert_close(got, want, &format!("ln({x:e})"));
        }
    }

    #[test]
    fn avx2_vln_exact_anchors() {
        if !avx2_available() {
            return;
        }
        let mut xs = vec![1.0f64, 0.0, f64::INFINITY];
        vln_at(SimdLevel::Avx2Fma, &mut xs);
        assert_eq!(xs[0], 0.0, "ln(1) must be exactly 0");
        assert_eq!(xs[1], f64::NEG_INFINITY);
        assert_eq!(xs[2], f64::INFINITY);
    }

    #[test]
    fn slice_tails_are_covered() {
        // Lengths that are not lane multiples exercise the padded tail.
        for len in [0usize, 1, 2, 3, 5, 7, 9] {
            let mut xs: Vec<f64> = (0..len).map(|i| -(i as f64) * 0.3).collect();
            let want: Vec<f64> = xs.iter().map(|v| v.exp()).collect();
            vexp_at(crate::linalg::simd::active_level(), &mut xs);
            for (i, (g, w)) in xs.iter().zip(&want).enumerate() {
                assert_close(*g, *w, &format!("len {len} idx {i}"));
            }
        }
    }

    #[test]
    fn exp_clamped_f32_respects_clamp_on_both_arms() {
        let raw: Vec<f32> = (0..37).map(|i| -100.0 + i as f32 * 7.3).collect();
        for level in [SimdLevel::Scalar, SimdLevel::Avx2Fma] {
            let mut xs = raw.clone();
            exp_clamped_f32_at(level, &mut xs, -80.0, 80.0);
            for (&x, &got) in raw.iter().zip(&xs) {
                let want = x.clamp(-80.0, 80.0).exp();
                // Both arms are within ~1 f32 ulp of the true value
                // (libm vs exp4-rounded-to-f32); allow ~3 ulp of slack.
                let rel = ((got as f64) - (want as f64)).abs() / (want as f64);
                assert!(rel <= 4e-7, "exp_clamped({x}) = {got:e}, want {want:e}");
                assert!(got > 0.0 && got.is_finite());
            }
        }
    }
}
