//! Adam optimiser over flat parameter vectors.

/// Adam with bias correction (Kingma & Ba).
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(num_params: usize, lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; num_params],
            v: vec![0.0; num_params],
            t: 0,
        }
    }

    /// One update step: `params -= lr * m_hat / (sqrt(v_hat) + eps)`.
    /// For *ascent* (the critic's max step) pass the negated gradient.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), self.m.len(), "Adam: parameter count changed");
        assert_eq!(params.len(), grads.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i] as f64;
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let m_hat = self.m[i] / b1t;
            let v_hat = self.v[i] / b2t;
            params[i] -= (self.lr * m_hat / (v_hat.sqrt() + self.eps)) as f32;
        }
    }

    pub fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimises_quadratic() {
        // f(x) = ||x - target||^2.
        let target = [3.0f32, -2.0, 0.5];
        let mut x = [0.0f32; 3];
        let mut adam = Adam::new(3, 0.05);
        for _ in 0..2000 {
            let g: Vec<f32> = x.iter().zip(&target).map(|(&xi, &t)| 2.0 * (xi - t)).collect();
            adam.step(&mut x, &g);
        }
        for (xi, t) in x.iter().zip(&target) {
            assert!((xi - t).abs() < 1e-2, "{x:?}");
        }
    }

    #[test]
    fn first_step_size_is_lr() {
        // With bias correction the first update has magnitude ~lr.
        let mut x = [0.0f32];
        let mut adam = Adam::new(1, 0.1);
        adam.step(&mut x, &[123.0]);
        assert!((x[0].abs() - 0.1).abs() < 1e-3, "{}", x[0]);
    }

    #[test]
    #[should_panic]
    fn size_mismatch_panics() {
        let mut adam = Adam::new(2, 0.1);
        let mut x = [0.0f32; 3];
        adam.step(&mut x, &[1.0; 3]);
    }

    #[test]
    fn reset_clears_state() {
        let mut adam = Adam::new(1, 0.1);
        let mut x = [0.0f32];
        adam.step(&mut x, &[1.0]);
        adam.reset();
        assert_eq!(adam.t, 0);
        assert_eq!(adam.m[0], 0.0);
    }
}
