//! Adversarial-kernel OT-GAN (paper §4, objective Eq. 18).
//!
//! Components:
//! * [`Mlp`] — minimal dense network (the generator `g_rho` and the
//!   embedding `f_gamma` are both MLPs, as in the paper which reuses the
//!   DCGAN-ish architectures of [36, 46]; dense layers here since the
//!   offline stack has no conv substrate and the *system* claim — linear
//!   Sinkhorn enables big batches — is architecture-independent).
//! * [`Adam`] — Adam optimiser.
//! * [`GanTrainer`] — alternating min–max training of
//!   `min_rho max_{gamma,theta} (1/B) sum_b Wbar_{eps, c_theta o h_gamma}`,
//!   with the Prop-3.2 envelope gradient through the Sinkhorn duals
//!   (no unrolling — the paper's memory-efficient strategy).

mod checkpoint;
mod mlp;
mod optim;
mod trainer;

pub use checkpoint::Checkpoint;
pub use mlp::{Act, Mlp, MlpGrads};
pub use optim::Adam;
pub use trainer::{GanTrainer, StepReport};
