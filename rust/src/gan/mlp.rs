//! Minimal dense network with manual backprop — the generator and the
//! embedding network of the GAN. Layers: affine + activation
//! (tanh | relu | linear | sigmoid on the output for images).

use crate::linalg::Mat;
use crate::rng::Rng;

/// Activation per layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Act {
    Linear,
    Relu,
    Tanh,
    Sigmoid,
}

impl Act {
    fn f(&self, x: f32) -> f32 {
        match self {
            Act::Linear => x,
            Act::Relu => x.max(0.0),
            Act::Tanh => x.tanh(),
            Act::Sigmoid => 1.0 / (1.0 + (-x).exp()),
        }
    }

    /// Derivative expressed through the *output* y = f(x).
    fn df_from_y(&self, y: f32) -> f32 {
        match self {
            Act::Linear => 1.0,
            Act::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Act::Tanh => 1.0 - y * y,
            Act::Sigmoid => y * (1.0 - y),
        }
    }
}

/// One dense layer.
#[derive(Clone, Debug)]
struct Layer {
    /// (out, in).
    w: Mat,
    b: Vec<f32>,
    act: Act,
}

/// A dense MLP with manual forward/backward.
#[derive(Clone, Debug)]
pub struct Mlp {
    layers: Vec<Layer>,
}

/// Cached activations from a forward pass (needed for backward).
pub struct Tape {
    /// Activations per layer, index 0 = input batch (n, d_in).
    acts: Vec<Mat>,
}

impl Mlp {
    /// Build with Xavier-ish init. `dims = [in, h1, ..., out]`,
    /// `acts.len() == dims.len() - 1`.
    pub fn new(dims: &[usize], acts: &[Act], rng: &mut Rng) -> Self {
        assert!(dims.len() >= 2 && acts.len() == dims.len() - 1);
        let layers = dims
            .windows(2)
            .zip(acts)
            .map(|(d, &act)| {
                let std = (2.0 / (d[0] + d[1]) as f64).sqrt();
                Layer {
                    w: Mat::from_fn(d[1], d[0], |_, _| rng.normal_scaled(0.0, std) as f32),
                    b: vec![0.0; d[1]],
                    act,
                }
            })
            .collect();
        Mlp { layers }
    }

    pub fn in_dim(&self) -> usize {
        self.layers[0].w.cols()
    }

    pub fn out_dim(&self) -> usize {
        self.layers.last().unwrap().w.rows()
    }

    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.rows() * l.w.cols() + l.b.len()).sum()
    }

    /// Forward a batch (n, in) -> (n, out), recording the tape.
    pub fn forward(&self, x: &Mat) -> (Mat, Tape) {
        assert_eq!(x.cols(), self.in_dim());
        let mut acts = Vec::with_capacity(self.layers.len() + 1);
        acts.push(x.clone());
        let mut cur = x.clone();
        for layer in &self.layers {
            let n = cur.rows();
            let mut next = Mat::zeros(n, layer.w.rows());
            for i in 0..n {
                let xi = cur.row(i);
                let row = next.row_mut(i);
                for (j, out) in row.iter_mut().enumerate() {
                    let dot: f32 =
                        xi.iter().zip(layer.w.row(j)).map(|(&a, &b)| a * b).sum();
                    *out = layer.act.f(dot + layer.b[j]);
                }
            }
            acts.push(next.clone());
            cur = next;
        }
        (cur, Tape { acts })
    }

    /// Backward: given dL/d output (n, out), accumulate parameter grads and
    /// return dL/d input (n, in).
    pub fn backward(&self, tape: &Tape, upstream: &Mat, grads: &mut MlpGrads) -> Mat {
        assert_eq!(grads.layers.len(), self.layers.len());
        let mut delta = upstream.clone();
        for (li, layer) in self.layers.iter().enumerate().rev() {
            let y = &tape.acts[li + 1];
            let x = &tape.acts[li];
            let n = y.rows();
            // delta := dL/d preactivation.
            for i in 0..n {
                let yr = y.row(i);
                let dr = delta.row_mut(i);
                for (d, &yv) in dr.iter_mut().zip(yr) {
                    *d *= layer.act.df_from_y(yv);
                }
            }
            // Parameter grads.
            let g = &mut grads.layers[li];
            for i in 0..n {
                let xi = x.row(i);
                let di = delta.row(i);
                for (j, &dj) in di.iter().enumerate() {
                    if dj == 0.0 {
                        continue;
                    }
                    g.b[j] += dj;
                    let gw = g.w.row_mut(j);
                    for (gv, &xv) in gw.iter_mut().zip(xi) {
                        *gv += dj * xv;
                    }
                }
            }
            // Input grad for the next (previous) layer.
            if li > 0 {
                let mut prev = Mat::zeros(n, layer.w.cols());
                for i in 0..n {
                    let di = delta.row(i);
                    let pr = prev.row_mut(i);
                    for (j, &dj) in di.iter().enumerate() {
                        if dj == 0.0 {
                            continue;
                        }
                        let wr = layer.w.row(j);
                        for (pv, &wv) in pr.iter_mut().zip(wr) {
                            *pv += dj * wv;
                        }
                    }
                }
                delta = prev;
            } else {
                // dL/d input of the whole net.
                let mut dinput = Mat::zeros(n, layer.w.cols());
                for i in 0..n {
                    let di = delta.row(i);
                    let pr = dinput.row_mut(i);
                    for (j, &dj) in di.iter().enumerate() {
                        if dj == 0.0 {
                            continue;
                        }
                        let wr = layer.w.row(j);
                        for (pv, &wv) in pr.iter_mut().zip(wr) {
                            *pv += dj * wv;
                        }
                    }
                }
                return dinput;
            }
        }
        unreachable!()
    }

    /// Zeroed gradient accumulator matching this net.
    pub fn zero_grads(&self) -> MlpGrads {
        MlpGrads {
            layers: self
                .layers
                .iter()
                .map(|l| LayerGrads {
                    w: Mat::zeros(l.w.rows(), l.w.cols()),
                    b: vec![0.0; l.b.len()],
                })
                .collect(),
        }
    }

    pub fn params_flat(&self) -> Vec<f32> {
        let mut p = Vec::with_capacity(self.num_params());
        for l in &self.layers {
            p.extend_from_slice(l.w.data());
            p.extend_from_slice(&l.b);
        }
        p
    }

    pub fn set_params_flat(&mut self, p: &[f32]) {
        assert_eq!(p.len(), self.num_params());
        let mut off = 0;
        for l in &mut self.layers {
            let nw = l.w.rows() * l.w.cols();
            l.w.data_mut().copy_from_slice(&p[off..off + nw]);
            off += nw;
            let nb = l.b.len();
            l.b.copy_from_slice(&p[off..off + nb]);
            off += nb;
        }
    }
}

/// Gradient accumulator for an [`Mlp`].
pub struct MlpGrads {
    layers: Vec<LayerGrads>,
}

struct LayerGrads {
    w: Mat,
    b: Vec<f32>,
}

impl MlpGrads {
    pub fn flat(&self) -> Vec<f32> {
        let mut g = Vec::new();
        for l in &self.layers {
            g.extend_from_slice(l.w.data());
            g.extend_from_slice(&l.b);
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::seed_from(0);
        let net = Mlp::new(&[4, 8, 3], &[Act::Tanh, Act::Linear], &mut rng);
        let x = Mat::from_fn(5, 4, |_, _| rng.normal_f32());
        let (y, _) = net.forward(&x);
        assert_eq!(y.shape(), (5, 3));
        assert_eq!(net.num_params(), 4 * 8 + 8 + 8 * 3 + 3);
    }

    #[test]
    fn sigmoid_output_in_unit_interval() {
        let mut rng = Rng::seed_from(1);
        let net = Mlp::new(&[2, 6, 4], &[Act::Relu, Act::Sigmoid], &mut rng);
        let x = Mat::from_fn(10, 2, |_, _| rng.normal_f32() * 5.0);
        let (y, _) = net.forward(&x);
        for &v in y.data() {
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn backward_matches_finite_difference() {
        let mut rng = Rng::seed_from(2);
        let mut net = Mlp::new(&[3, 5, 2], &[Act::Tanh, Act::Linear], &mut rng);
        let x = Mat::from_fn(4, 3, |_, _| rng.normal_f32());
        // Loss = sum of outputs weighted by fixed coefficients.
        let coef = Mat::from_fn(4, 2, |_, _| rng.normal_f32());
        let loss = |net: &Mlp| -> f64 {
            let (y, _) = net.forward(&x);
            y.data().iter().zip(coef.data()).map(|(&a, &c)| (a * c) as f64).sum()
        };
        let (y, tape) = net.forward(&x);
        assert_eq!(y.shape(), coef.shape());
        let mut grads = net.zero_grads();
        let dinput = net.backward(&tape, &coef, &mut grads);
        let flat = grads.flat();
        let base = net.params_flat();
        let h = 1e-3;
        for &idx in &[0usize, 7, 14, 19, net.num_params() - 1] {
            let mut p = base.clone();
            p[idx] += h;
            net.set_params_flat(&p);
            let up = loss(&net);
            p[idx] -= 2.0 * h;
            net.set_params_flat(&p);
            let dn = loss(&net);
            net.set_params_flat(&base);
            let num = (up - dn) / (2.0 * h as f64);
            let ana = flat[idx] as f64;
            assert!((num - ana).abs() < 2e-2 * num.abs().max(0.05), "param {idx}: {num} vs {ana}");
        }
        // Input gradient check on one coordinate.
        let mut x2 = x.clone();
        x2[(1, 2)] += h;
        let (y2, _) = net.forward(&x2);
        let up: f64 = y2.data().iter().zip(coef.data()).map(|(&a, &c)| (a * c) as f64).sum();
        x2[(1, 2)] -= 2.0 * h;
        let (y3, _) = net.forward(&x2);
        let dn: f64 = y3.data().iter().zip(coef.data()).map(|(&a, &c)| (a * c) as f64).sum();
        let num = (up - dn) / (2.0 * h as f64);
        assert!((num - dinput[(1, 2)] as f64).abs() < 2e-2 * num.abs().max(0.05));
    }

    #[test]
    fn params_roundtrip() {
        let mut rng = Rng::seed_from(3);
        let mut net = Mlp::new(&[2, 3, 2], &[Act::Relu, Act::Linear], &mut rng);
        let p: Vec<f32> = (0..net.num_params()).map(|i| i as f32 * 0.1).collect();
        net.set_params_flat(&p);
        assert_eq!(net.params_flat(), p);
    }
}
