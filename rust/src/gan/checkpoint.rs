//! Checkpointing for the GAN trainer: a small self-describing binary
//! format (magic, version, named f32 sections) so long runs can resume
//! and the Table-1 probe can evaluate saved kernels.
//!
//! Layout (little-endian):
//!   magic  "LSKG"          4 bytes
//!   version u32            (currently 1)
//!   n_sections u32
//!   per section: name_len u32, name bytes, data_len u32, f32 data
//! A trailing CRC-free design keeps it dependency-free; corruption is
//! caught by the magic/length checks and the parameter-count asserts on
//! load.

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};

const MAGIC: &[u8; 4] = b"LSKG";
const VERSION: u32 = 1;

/// A named collection of f32 parameter sections.
#[derive(Debug, Default, PartialEq)]
pub struct Checkpoint {
    pub sections: Vec<(String, Vec<f32>)>,
}

impl Checkpoint {
    pub fn add(&mut self, name: &str, data: Vec<f32>) {
        self.sections.push((name.to_string(), data));
    }

    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| d.as_slice())
            .ok_or_else(|| Error::Config(format!("checkpoint missing section `{name}`")))
    }

    /// Serialise to a file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(MAGIC)?;
        f.write_all(&VERSION.to_le_bytes())?;
        f.write_all(&(self.sections.len() as u32).to_le_bytes())?;
        for (name, data) in &self.sections {
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(data.len() as u32).to_le_bytes())?;
            // Bulk-write the f32 payload.
            let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    /// Deserialise from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(&path)?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Config(format!(
                "{}: not a linear-sinkhorn checkpoint",
                path.as_ref().display()
            )));
        }
        let mut u32buf = [0u8; 4];
        f.read_exact(&mut u32buf)?;
        let version = u32::from_le_bytes(u32buf);
        if version != VERSION {
            return Err(Error::Config(format!("unsupported checkpoint version {version}")));
        }
        f.read_exact(&mut u32buf)?;
        let n_sections = u32::from_le_bytes(u32buf) as usize;
        if n_sections > 1_000 {
            return Err(Error::Config("checkpoint section count implausible".into()));
        }
        let mut ckpt = Checkpoint::default();
        for _ in 0..n_sections {
            f.read_exact(&mut u32buf)?;
            let name_len = u32::from_le_bytes(u32buf) as usize;
            if name_len > 4096 {
                return Err(Error::Config("checkpoint name length implausible".into()));
            }
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name)
                .map_err(|_| Error::Config("checkpoint name not utf8".into()))?;
            f.read_exact(&mut u32buf)?;
            let data_len = u32::from_le_bytes(u32buf) as usize;
            let mut bytes = vec![0u8; data_len * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            ckpt.sections.push((name, data));
        }
        Ok(ckpt)
    }
}

impl super::GanTrainer {
    /// Save generator / embedding / feature-map parameters.
    pub fn save_checkpoint(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut c = Checkpoint::default();
        c.add("generator", self.generator.params_flat());
        c.add("embed", self.embed.params_flat());
        c.add("features", self.feat.params_flat());
        c.save(path)
    }

    /// Restore parameters saved by [`Self::save_checkpoint`]. Optimiser
    /// moments are reset (a fresh Adam warmup), matching common practice.
    pub fn load_checkpoint(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let c = Checkpoint::load(path)?;
        let g = c.get("generator")?;
        if g.len() != self.generator.num_params() {
            return Err(Error::Config(format!(
                "generator parameter count mismatch: checkpoint {} vs model {}",
                g.len(),
                self.generator.num_params()
            )));
        }
        self.generator.set_params_flat(g);
        let e = c.get("embed")?;
        if e.len() != self.embed.num_params() {
            return Err(Error::Config("embed parameter count mismatch".into()));
        }
        self.embed.set_params_flat(e);
        let f = c.get("features")?;
        if f.len() != self.feat.num_params() {
            return Err(Error::Config("feature-map parameter count mismatch".into()));
        }
        self.feat.set_params_flat(f);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GanConfig;
    use crate::gan::GanTrainer;
    use crate::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("ls-ckpt-{}-{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_sections() {
        let mut c = Checkpoint::default();
        c.add("a", vec![1.0, -2.5, 3.25]);
        c.add("b", vec![0.0; 100]);
        let path = tmp("roundtrip");
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(c, back);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_magic_rejected() {
        let path = tmp("badmagic");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn trainer_checkpoint_roundtrip_generates_identically() {
        let mut rng = Rng::seed_from(0);
        let cfg = GanConfig {
            batch_size: 8,
            num_features: 8,
            latent_dim: 3,
            embed_dim: 3,
            ..Default::default()
        };
        let mut t1 = GanTrainer::new(9, cfg.clone(), &mut rng);
        let path = tmp("trainer");
        t1.save_checkpoint(&path).unwrap();

        let mut rng2 = Rng::seed_from(99); // different init
        let mut t2 = GanTrainer::new(9, cfg, &mut rng2);
        t2.load_checkpoint(&path).unwrap();
        assert_eq!(t1.generator.params_flat(), t2.generator.params_flat());
        assert_eq!(t1.embed.params_flat(), t2.embed.params_flat());
        assert_eq!(t1.feat.params_flat(), t2.feat.params_flat());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mismatched_architecture_rejected() {
        let mut rng = Rng::seed_from(1);
        let cfg = GanConfig {
            batch_size: 8,
            num_features: 8,
            latent_dim: 3,
            embed_dim: 3,
            ..Default::default()
        };
        let t1 = GanTrainer::new(9, cfg.clone(), &mut rng);
        let path = tmp("mismatch");
        t1.save_checkpoint(&path).unwrap();
        let bigger = GanConfig { num_features: 16, ..cfg };
        let mut t2 = GanTrainer::new(9, bigger, &mut rng);
        assert!(t2.load_checkpoint(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_section_is_error() {
        let mut c = Checkpoint::default();
        c.add("only", vec![1.0]);
        assert!(c.get("missing").is_err());
        assert!(c.get("only").is_ok());
    }
}
