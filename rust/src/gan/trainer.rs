//! The alternating min–max trainer for objective Eq. (18):
//!
//!   min_rho  max_{gamma, theta}  (1/B) sum_b  Wbar_{eps, c_theta o h_gamma}
//!                                  (g_rho# zeta_b, P_X_b)
//!
//! Every divergence evaluation is *linear in the batch size* because the
//! kernel of `c_theta o h_gamma` factorises:
//! `k(x, y) = <phi_theta(f_gamma(x)), phi_theta(f_gamma(y))>`.
//! Gradients use the Prop-3.2 envelope formula through the Sinkhorn-output
//! duals — no unrolling, O(s r) memory.

use crate::api::{OtProblem, Solution};
use crate::config::{GanConfig, SinkhornConfig};
use crate::error::Result;
use crate::features::{FeatureMap, LearnedFeatureMap};
use crate::linalg::{self, Mat};
use crate::rng::Rng;

use super::mlp::{Act, Mlp};
use super::optim::Adam;

/// Per-step training report.
#[derive(Clone, Debug)]
pub struct StepReport {
    pub step: usize,
    /// The minibatch Sinkhorn divergence (the GAN loss).
    pub divergence: f64,
    /// The three raw transport objectives (xy, xx, yy).
    pub w_xy: f64,
    pub w_xx: f64,
    pub w_yy: f64,
    /// Sinkhorn iterations spent in this step (all three solves).
    pub sinkhorn_iters: usize,
}

/// Adversarial-kernel OT-GAN trainer.
pub struct GanTrainer {
    pub cfg: GanConfig,
    /// Generator g_rho: latent -> data space (sigmoid output).
    pub generator: Mlp,
    /// Embedding f_gamma: data -> R^e.
    pub embed: Mlp,
    /// Positive feature map phi_theta: R^e -> (R_+^*)^r.
    pub feat: LearnedFeatureMap,
    opt_gen: Adam,
    opt_embed: Adam,
    opt_feat: Adam,
    rng: Rng,
    data_dim: usize,
    skcfg: SinkhornConfig,
}

impl GanTrainer {
    pub fn new(data_dim: usize, cfg: GanConfig, seed_rng: &mut Rng) -> Self {
        let mut rng = seed_rng.fork(cfg.seed);
        let generator = Mlp::new(
            &[cfg.latent_dim, 64, 64, data_dim],
            &[Act::Relu, Act::Relu, Act::Sigmoid],
            &mut rng,
        );
        let embed = Mlp::new(
            &[data_dim, 64, cfg.embed_dim],
            &[Act::Relu, Act::Tanh],
            &mut rng,
        );
        let feat = LearnedFeatureMap::new(cfg.embed_dim, cfg.num_features, &mut rng);
        let skcfg = SinkhornConfig {
            epsilon: cfg.epsilon,
            max_iters: cfg.sinkhorn_iters,
            tol: 1e-7,
            check_every: cfg.sinkhorn_iters.max(1),
            threads: 1,
            stabilize: false,
            max_batch: 1,
            anneal: None,
            anneal_decay: 0.5,
            symmetric: None,
        };
        GanTrainer {
            opt_gen: Adam::new(generator.num_params(), cfg.lr),
            opt_embed: Adam::new(embed.num_params(), cfg.lr),
            opt_feat: Adam::new(feat.num_params(), cfg.lr),
            generator,
            embed,
            feat,
            rng,
            data_dim,
            skcfg,
            cfg,
        }
    }

    /// Sample a latent batch.
    pub fn sample_noise(&mut self, s: usize) -> Mat {
        Mat::from_fn(s, self.cfg.latent_dim, |_, _| self.rng.normal_f32())
    }

    /// Generate a batch of samples (no tape; for evaluation).
    pub fn generate(&mut self, s: usize) -> Mat {
        let z = self.sample_noise(s);
        self.generator.forward(&z).0
    }

    /// The minibatch Sinkhorn divergence between generated and real data
    /// (evaluation only, no gradients).
    pub fn divergence(&mut self, real: &Mat) -> Result<f64> {
        let fake = self.generate(real.rows());
        self.divergence_inner(&fake, real)
    }

    /// One full training step: `critic_steps` ascent steps on (gamma,
    /// theta), then one descent step on rho. Returns the report of the
    /// generator step.
    pub fn train_step(&mut self, step: usize, real: &Mat) -> Result<StepReport> {
        assert_eq!(real.cols(), self.data_dim);
        for _ in 0..self.cfg.critic_steps {
            self.inner_step(real, true)?;
        }
        let rep = self.inner_step(real, false)?;
        Ok(StepReport { step, ..rep })
    }

    /// Shared critic/generator step.
    fn inner_step(&mut self, real: &Mat, critic: bool) -> Result<StepReport> {
        let s = real.rows();
        let z = self.sample_noise(s);
        let (fake, tape_gen) = self.generator.forward(&z);

        // Embeddings with tapes.
        let (za, tape_a) = self.embed.forward(&fake);
        let (zb, tape_b) = self.embed.forward(real);
        let phi_a = self.feat.feature_matrix(&za);
        let phi_b = self.feat.feature_matrix(&zb);
        let wa = vec![1.0f32 / s as f32; s];

        // Three factored transport problems through the planned API: the
        // learned factors are the kernel (`from_factors`), so the plan is
        // factored/plain and execution is bitwise the three `sinkhorn`
        // calls this trainer used to hand-wire.
        let report = OtProblem::from_factors(&phi_a, &phi_b)
            .config(&self.skcfg)
            .weights(&wa, &wa)
            .divergence()?;
        let (s_xy, s_xx, s_yy) = (&report.xy, &report.xx, &report.yy);
        let div = report.divergence;
        let iters = report.iterations();

        // Envelope upstream gradients w.r.t. the feature matrices.
        // d Wbar / d phi_a = G(phi_a|xy) - 0.5 * G_both(phi_a|xx)
        // d Wbar / d phi_b = G(phi_b|xy) - 0.5 * G_both(phi_b|yy)
        let eps = self.cfg.epsilon;
        let mut up_a = envelope_grad_left(eps, s_xy, &phi_b);
        add_scaled(&mut up_a, &envelope_grad_both(eps, s_xx, &phi_a), -0.5);
        let mut up_b = envelope_grad_right(eps, s_xy, &phi_a);
        add_scaled(&mut up_b, &envelope_grad_both(eps, s_yy, &phi_b), -0.5);

        if critic {
            // Ascent on (gamma, theta): maximise the divergence.
            // theta grads.
            let mut gw = Mat::zeros(self.feat.w.rows(), self.feat.w.cols());
            let mut gb = vec![0.0f32; self.feat.b.len()];
            self.feat.accumulate_grad(&za, &phi_a, &up_a, &mut gw, &mut gb);
            self.feat.accumulate_grad(&zb, &phi_b, &up_b, &mut gw, &mut gb);
            // gamma grads: backprop the embedding gradients.
            let dza = self.feat.backprop_input(&za, &phi_a, &up_a);
            let dzb = self.feat.backprop_input(&zb, &phi_b, &up_b);
            let mut eg = self.embed.zero_grads();
            self.embed.backward(&tape_a, &dza, &mut eg);
            self.embed.backward(&tape_b, &dzb, &mut eg);

            // Negate for ascent (Adam minimises).
            let mut theta_flat = gw.data().to_vec();
            theta_flat.extend_from_slice(&gb);
            theta_flat.iter_mut().for_each(|x| *x = -*x);
            let mut theta = self.feat.params_flat();
            self.opt_feat.step(&mut theta, &theta_flat);
            self.feat.set_params_flat(&theta);

            let mut gamma_grads = eg.flat();
            gamma_grads.iter_mut().for_each(|x| *x = -*x);
            let mut gamma = self.embed.params_flat();
            self.opt_embed.step(&mut gamma, &gamma_grads);
            self.embed.set_params_flat(&gamma);
        } else {
            // Descent on rho, flowing through the fake samples only.
            let dza = self.feat.backprop_input(&za, &phi_a, &up_a);
            let mut eg = self.embed.zero_grads(); // discarded (gamma frozen here)
            let dfake = self.embed.backward(&tape_a, &dza, &mut eg);
            let mut gg = self.generator.zero_grads();
            self.generator.backward(&tape_gen, &dfake, &mut gg);
            let mut rho = self.generator.params_flat();
            self.opt_gen.step(&mut rho, &gg.flat());
            self.generator.set_params_flat(&rho);
        }

        Ok(StepReport {
            step: 0,
            divergence: div,
            w_xy: s_xy.objective,
            w_xx: s_xx.objective,
            w_yy: s_yy.objective,
            sinkhorn_iters: iters,
        })
    }

    /// Table-1 style probe: mean learned kernel value between two sample
    /// batches (rows of `x` vs rows of `y`), using the *current* adversarial
    /// kernel k_theta(f_gamma(x), f_gamma(y)).
    pub fn mean_kernel(&self, x: &Mat, y: &Mat) -> f64 {
        let (zx, _) = self.embed.forward(x);
        let (zy, _) = self.embed.forward(y);
        let px = self.feat.feature_matrix(&zx);
        let py = self.feat.feature_matrix(&zy);
        let mut total = 0.0f64;
        for i in 0..px.rows() {
            for j in 0..py.rows() {
                total += linalg::dot(px.row(i), py.row(j)) as f64;
            }
        }
        total / (px.rows() * py.rows()) as f64
    }

    fn divergence_inner(&mut self, fake: &Mat, real: &Mat) -> Result<f64> {
        let s = real.rows();
        let (za, _) = self.embed.forward(fake);
        let (zb, _) = self.embed.forward(real);
        let phi_a = self.feat.feature_matrix(&za);
        let phi_b = self.feat.feature_matrix(&zb);
        let wa = vec![1.0f32 / s as f32; s];
        let report = OtProblem::from_factors(&phi_a, &phi_b)
            .config(&self.skcfg)
            .weights(&wa, &wa)
            .divergence()?;
        Ok(report.divergence)
    }
}

/// Prop 3.2 chained to the left factor: dW/dPhi_x[i,k] = -eps u_i (Phi_y^T v)_k.
fn envelope_grad_left(eps: f64, sol: &Solution, phi_y: &Mat) -> Mat {
    let kyv = linalg::matvec_t(phi_y, &sol.v);
    outer_scaled(-eps as f32, &sol.u, &kyv)
}

/// Right factor: dW/dPhi_y[j,k] = -eps v_j (Phi_x^T u)_k.
fn envelope_grad_right(eps: f64, sol: &Solution, phi_x: &Mat) -> Mat {
    let kxu = linalg::matvec_t(phi_x, &sol.u);
    outer_scaled(-eps as f32, &sol.v, &kxu)
}

/// Self-transport (xx): Phi appears on both sides, contributions add.
fn envelope_grad_both(eps: f64, sol: &Solution, phi: &Mat) -> Mat {
    let mut g = envelope_grad_left(eps, sol, phi);
    let r = envelope_grad_right(eps, sol, phi);
    add_scaled(&mut g, &r, 1.0);
    g
}

fn outer_scaled(scale: f32, u: &[f32], w: &[f32]) -> Mat {
    let mut m = Mat::zeros(u.len(), w.len());
    for (i, &ui) in u.iter().enumerate() {
        let row = m.row_mut(i);
        for (cell, &wk) in row.iter_mut().zip(w) {
            *cell = scale * ui * wk;
        }
    }
    m
}

fn add_scaled(dst: &mut Mat, src: &Mat, scale: f32) {
    assert_eq!(dst.shape(), src.shape());
    for (d, &s) in dst.data_mut().iter_mut().zip(src.data()) {
        *d += scale * s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    fn small_cfg() -> GanConfig {
        GanConfig {
            batch_size: 32,
            num_features: 16,
            latent_dim: 4,
            embed_dim: 4,
            epsilon: 1.0,
            sinkhorn_iters: 30,
            critic_steps: 1,
            steps: 10,
            lr: 2e-3,
            seed: 7,
        }
    }

    #[test]
    fn generator_output_shape_and_range() {
        let mut rng = Rng::seed_from(0);
        let mut t = GanTrainer::new(8, small_cfg(), &mut rng);
        let x = t.generate(5);
        assert_eq!(x.shape(), (5, 8));
        for &v in x.data() {
            assert!((0.0..=1.0).contains(&v), "sigmoid output out of range");
        }
    }

    #[test]
    fn train_step_runs_and_reports() {
        let mut rng = Rng::seed_from(1);
        let mut t = GanTrainer::new(16, small_cfg(), &mut rng);
        let mut data_rng = Rng::seed_from(2);
        let real = data::image_corpus(32, 4, &mut data_rng);
        let rep = t.train_step(0, &real).unwrap();
        assert!(rep.divergence.is_finite());
        assert!(rep.sinkhorn_iters > 0);
    }

    #[test]
    fn envelope_grads_shapes() {
        let sol = Solution {
            u: vec![1.0, 2.0],
            v: vec![3.0, 4.0, 5.0],
            objective: 0.0,
            iterations: 1,
            marginal_error: 0.0,
            converged: true,
            escalated: false,
            grad_norm: None,
            wall_us: 0,
            simd_arm: "scalar",
        };
        let phi_y = Mat::ones(3, 4);
        let g = envelope_grad_left(1.0, &sol, &phi_y);
        assert_eq!(g.shape(), (2, 4));
        // -eps * u_i * sum_j v_j = -(3+4+5) * u_i.
        assert!((g[(0, 0)] + 12.0).abs() < 1e-5);
        assert!((g[(1, 0)] + 24.0).abs() < 1e-5);
    }

    #[test]
    fn training_reduces_divergence_on_easy_target() {
        // Target: a fixed low-dim blob. A few dozen steps should reduce the
        // Sinkhorn divergence between generated and real.
        let mut rng = Rng::seed_from(3);
        let cfg = GanConfig { steps: 40, batch_size: 48, lr: 5e-3, ..small_cfg() };
        let mut t = GanTrainer::new(4, cfg, &mut rng);
        let mut data_rng = Rng::seed_from(4);
        // Real data: points near (0.8, 0.2, 0.8, 0.2).
        let target = [0.8f32, 0.2, 0.8, 0.2];
        let real = Mat::from_fn(48, 4, |_, j| {
            (target[j] as f64 + 0.05 * data_rng.normal()) as f32
        });
        // Measure progress in *data space*: mean L2 distance of generated
        // samples to the target pattern. (The divergence itself is not a
        // monotone training signal early on — the critic is simultaneously
        // learning to discriminate, which *raises* the measured value.)
        let mut dist_to_target = |t: &mut GanTrainer| -> f64 {
            let g = t.generate(64);
            let mut s = 0.0f64;
            for i in 0..g.rows() {
                let d2: f64 = g
                    .row(i)
                    .iter()
                    .zip(&target)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum();
                s += d2.sqrt();
            }
            s / g.rows() as f64
        };
        let d0 = dist_to_target(&mut t);
        for step in 0..40 {
            t.train_step(step, &real).unwrap();
        }
        let d1 = dist_to_target(&mut t);
        assert!(d1 < d0, "generator should move toward target: start {d0}, end {d1}");
    }

    #[test]
    fn mean_kernel_separates_trained_manifold() {
        // Even *untrained*, k_theta(x,x')-style averages should be finite
        // and positive; the Table-1 bench checks the trained separation.
        let mut rng = Rng::seed_from(5);
        let t = GanTrainer::new(16, small_cfg(), &mut rng);
        let mut data_rng = Rng::seed_from(6);
        let imgs = data::image_corpus(5, 4, &mut data_rng);
        let noise = data::noise_images(5, 4, &mut data_rng);
        let kii = t.mean_kernel(&imgs, &imgs);
        let kin = t.mean_kernel(&imgs, &noise);
        assert!(kii > 0.0 && kin > 0.0);
        assert!(kii.is_finite() && kin.is_finite());
    }
}
