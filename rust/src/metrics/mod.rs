//! Metrics substrate: counters, gauges, wall-clock timers and streaming
//! histograms with quantile estimates. The coordinator exports a registry
//! snapshot; benches use [`Stopwatch`] directly.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Monotonic counter.
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time gauge.
#[derive(Default, Debug)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, v: i64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed streaming histogram (microsecond-scale latencies).
///
/// Buckets are powers of ~1.5 from 1us to ~17min; quantiles are estimated
/// from bucket midpoints, which is plenty for p50/p95/p99 reporting.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const HIST_BUCKETS: usize = 52;
const HIST_BASE: f64 = 1.5;

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    fn bucket_for(us: u64) -> usize {
        if us <= 1 {
            return 0;
        }
        let idx = (us as f64).ln() / HIST_BASE.ln();
        (idx as usize).min(HIST_BUCKETS - 1)
    }

    /// Upper edge of bucket `i` in microseconds.
    fn bucket_edge(i: usize) -> f64 {
        HIST_BASE.powi(i as i32 + 1)
    }

    pub fn observe_us(&self, us: u64) {
        self.buckets[Self::bucket_for(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Approximate quantile in microseconds (q in [0, 1]).
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_edge(i);
            }
        }
        Self::bucket_edge(HIST_BUCKETS - 1)
    }
}

/// Named-metric registry; the coordinator exposes a snapshot of this.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl Registry {
    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms.lock().unwrap().entry(name.to_string()).or_default().clone()
    }

    /// Human-readable snapshot (sorted, stable for logs/tests).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} = {}\n", c.get()));
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge {k} = {}\n", g.get()));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "hist {k}: n={} mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us max={}us\n",
                h.count(),
                h.mean_us(),
                h.quantile_us(0.5),
                h.quantile_us(0.95),
                h.quantile_us(0.99),
                h.max_us()
            ));
        }
        out
    }
}

/// Simple wall-clock stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed_us(&self) -> u64 {
        self.0.elapsed().as_micros() as u64
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn gauge_basics() {
        let g = Gauge::default();
        g.set(7);
        g.add(-3);
        assert_eq!(g.get(), 4);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::default();
        for us in [10u64, 20, 30, 100, 1_000, 10_000, 100_000] {
            h.observe_us(us);
        }
        assert_eq!(h.count(), 7);
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        assert!(p50 <= p95, "p50={p50} p95={p95}");
        assert!(h.max_us() == 100_000);
    }

    #[test]
    fn histogram_quantile_approximates_value() {
        let h = Histogram::default();
        for _ in 0..1000 {
            h.observe_us(500);
        }
        let p50 = h.quantile_us(0.5);
        // Log-bucket estimate: within one bucket ratio (x1.5) of truth.
        assert!((500.0 / 1.5..=500.0 * 1.5 * 1.5).contains(&p50), "p50={p50}");
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.9), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn registry_reuses_named_metrics() {
        let r = Registry::default();
        r.counter("x").inc();
        r.counter("x").inc();
        assert_eq!(r.counter("x").get(), 2);
    }

    #[test]
    fn registry_render_contains_all() {
        let r = Registry::default();
        r.counter("reqs").add(3);
        r.gauge("depth").set(9);
        r.histogram("lat").observe_us(42);
        let s = r.render();
        assert!(s.contains("reqs = 3"));
        assert!(s.contains("depth = 9"));
        assert!(s.contains("hist lat"));
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.elapsed_us() >= 1_000);
    }
}
