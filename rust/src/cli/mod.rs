//! Minimal declarative CLI substrate (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! defaults, required keys and auto-generated `--help`. Used by the main
//! binary and every example/bench.

use std::collections::BTreeMap;
use std::fmt;

/// Declared option.
#[derive(Clone, Debug)]
struct Opt {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
    required: bool,
}

/// Parse error.
#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Declarative argument parser.
pub struct ArgSpec {
    program: &'static str,
    about: &'static str,
    opts: Vec<Opt>,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl ArgSpec {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        ArgSpec { program, about, opts: Vec::new() }
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
            required: false,
        });
        self
    }

    /// `--name <value>`, required.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: false, required: true });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt { name, help, default: None, is_flag: true, required: false });
        self
    }

    /// Render help text.
    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let left = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = match &o.default {
                Some(d) => format!(" [default: {d}]"),
                None if o.required => " [required]".to_string(),
                None => String::new(),
            };
            s.push_str(&format!("{left:<26} {}{def}\n", o.help));
        }
        s.push_str("  --help                     show this help\n");
        s
    }

    /// Parse an argv-style iterator (excluding the program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(CliError(self.help_text()));
            }
            if tok == "--bench" && !self.opts.iter().any(|o| o.name == "bench") {
                // `cargo bench` appends `--bench` to harness=false targets;
                // swallow it so every bench binary works under cargo bench.
                continue;
            }
            if let Some(name) = tok.strip_prefix("--") {
                let (name, inline_val) = match name.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (name.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| {
                        CliError(format!("unknown option --{name}\n\n{}", self.help_text()))
                    })?;
                if opt.is_flag {
                    if inline_val.is_some() {
                        return Err(CliError(format!("--{name} is a flag, it takes no value")));
                    }
                    args.flags.insert(name, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| CliError(format!("--{name} expects a value")))?,
                    };
                    args.values.insert(name, val);
                }
            } else {
                args.positional.push(tok);
            }
        }
        // Defaults + required check.
        for o in &self.opts {
            if o.is_flag {
                args.flags.entry(o.name.to_string()).or_insert(false);
            } else if !args.values.contains_key(o.name) {
                match &o.default {
                    Some(d) => {
                        args.values.insert(o.name.to_string(), d.clone());
                    }
                    None if o.required => {
                        return Err(CliError(format!("missing required --{}", o.name)));
                    }
                    None => {}
                }
            }
        }
        Ok(args)
    }

    /// Parse the process arguments; print help/errors and exit on failure.
    pub fn parse(&self) -> Args {
        match self.parse_from(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("--{name}: expected unsigned integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("--{name}: expected unsigned integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("--{name}: expected float"))
    }

    pub fn get_str(&self, name: &str) -> &str {
        self.get(name).unwrap_or_else(|| panic!("--{name}: missing"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Comma-separated list of floats (e.g. `--eps 0.05,0.1,0.5`).
    pub fn get_f64_list(&self, name: &str) -> Vec<f64> {
        self.get_str(name)
            .split(',')
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad float list")))
            .collect()
    }

    /// Comma-separated list of unsigned integers.
    pub fn get_usize_list(&self, name: &str) -> Vec<usize> {
        self.get_str(name)
            .split(',')
            .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad int list")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("t", "test")
            .opt("n", "100", "samples")
            .opt("eps", "0.5", "regularisation")
            .req("out", "output path")
            .flag("verbose", "chatty")
    }

    fn parse(toks: &[&str]) -> Result<Args, CliError> {
        spec().parse_from(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["--out", "x.csv"]).unwrap();
        assert_eq!(a.get_usize("n"), 100);
        assert_eq!(a.get_f64("eps"), 0.5);
        assert!(!a.get_flag("verbose"));
    }

    #[test]
    fn values_override_defaults() {
        let a = parse(&["--out", "x", "--n", "7", "--eps=0.25", "--verbose"]).unwrap();
        assert_eq!(a.get_usize("n"), 7);
        assert_eq!(a.get_f64("eps"), 0.25);
        assert!(a.get_flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(parse(&["--n", "7"]).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(parse(&["--out", "x", "--nope", "1"]).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(parse(&["--out", "x", "--verbose=yes"]).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = parse(&["--out", "x", "pos1", "pos2"]).unwrap();
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn help_lists_all_options() {
        let h = spec().help_text();
        for name in ["--n", "--eps", "--out", "--verbose", "--help"] {
            assert!(h.contains(name), "help missing {name}");
        }
    }

    #[test]
    fn float_and_int_lists() {
        let s = ArgSpec::new("t", "t").opt("eps", "0.1,0.5", "list").opt("ranks", "1,2,3", "list");
        let a = s.parse_from(Vec::<String>::new()).unwrap();
        assert_eq!(a.get_f64_list("eps"), vec![0.1, 0.5]);
        assert_eq!(a.get_usize_list("ranks"), vec![1, 2, 3]);
    }

    #[test]
    fn value_missing_errors() {
        assert!(parse(&["--out"]).is_err());
    }

    #[test]
    fn cargo_bench_flag_is_swallowed() {
        let a = parse(&["--bench", "--out", "x"]).unwrap();
        assert_eq!(a.get_str("out"), "x");
        assert!(a.positional.is_empty());
    }
}
