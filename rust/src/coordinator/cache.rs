//! Shared feature-map cache: amortise the Lemma-1 anchor draw across
//! requests.
//!
//! Fitting a [`GaussianFeatureMap`] costs an `r x d` Gaussian anchor draw
//! plus per-anchor constants — cheap next to a solve, but it happens on
//! *every* request, and requests are grouped by shared `(dim, eps)`
//! precisely so this setup can be amortised. The cache makes the reuse
//! explicit and cross-batch.
//!
//! ## Keying rule
//!
//! Entries are keyed by `(dim, eps, r)` ([`FeatureKey`]; `eps` compared by
//! exact bit pattern — a "nearby" regularisation is a different kernel).
//! The fitted radius `R` is deliberately **not** part of the key: Lemma 1
//! is an exact expectation identity for any `x, y`, and `R` only enters
//! the paper's *variance* bound (via `q(eps, R, d)` and `psi`). A cached
//! map is therefore reusable for any request whose data radius is at most
//! the radius the map was fitted with — its Theorem-2 concentration
//! guarantee still applies — while data *larger* than the fitted radius
//! would void the guarantee, so that is a miss.
//!
//! On a radius miss the replacement map is fitted with
//! [`RADIUS_HEADROOM`] slack so mild workload drift (clouds growing a few
//! per cent per request) does not defeat the cache.
//!
//! ## Concurrency and metrics
//!
//! The cache is a `Mutex`-guarded LRU shared by every worker via `Arc`;
//! the expensive fit runs *outside* the lock (two workers may race to fit
//! the same key — both results are valid draws, last insert wins). Hits
//! and misses are counted locally and exported through
//! [`crate::metrics::Registry`] as `service.feature_cache.hits` /
//! `service.feature_cache.misses`, which the divergence-service example
//! prints.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::features::GaussianFeatureMap;
use crate::metrics::Registry;
use crate::rng::Rng;

/// Headroom factor applied to the data radius when fitting a map on a
/// cache miss, so slightly larger follow-up clouds still hit.
pub const RADIUS_HEADROOM: f64 = 1.25;

/// Cache key: requests sharing the ground-space dimension, the
/// regularisation and the feature count can share one anchor draw.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct FeatureKey {
    /// Ground-space dimension d.
    pub dim: usize,
    /// Bit pattern of the regularisation epsilon (exact match only).
    pub eps_bits: u64,
    /// Feature count r.
    pub r: usize,
}

impl FeatureKey {
    /// Key for a `(dim, eps, r)` combination.
    pub fn new(dim: usize, eps: f64, r: usize) -> FeatureKey {
        FeatureKey { dim, eps_bits: eps.to_bits(), r }
    }
}

struct CacheEntry {
    map: Arc<GaussianFeatureMap>,
    last_used: u64,
}

#[derive(Default)]
struct CacheInner {
    entries: HashMap<FeatureKey, CacheEntry>,
    /// Monotonic access clock for LRU eviction.
    tick: u64,
    hits: u64,
    misses: u64,
}

/// LRU cache of fitted [`GaussianFeatureMap`]s, shared across workers.
pub struct FeatureCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
}

impl FeatureCache {
    /// A cache holding at most `capacity` maps; `0` disables caching
    /// (every lookup fits a fresh map and counts as a miss).
    pub fn new(capacity: usize) -> FeatureCache {
        FeatureCache { inner: Mutex::new(CacheInner::default()), capacity }
    }

    /// The configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fetch a map usable for data of radius `radius` under
    /// `(dim, eps, r)`, fitting (with [`RADIUS_HEADROOM`]) on a miss.
    /// Counters go to `metrics` when provided.
    pub fn get_or_fit(
        &self,
        dim: usize,
        eps: f64,
        r: usize,
        radius: f64,
        rng: &mut Rng,
        metrics: Option<&Registry>,
    ) -> Arc<GaussianFeatureMap> {
        let radius = radius.max(1e-6);
        let key = FeatureKey::new(dim, eps, r);
        if self.capacity > 0 {
            let hit = {
                let mut guard = self.inner.lock().unwrap();
                let inner = &mut *guard;
                inner.tick += 1;
                let tick = inner.tick;
                match inner.entries.get_mut(&key) {
                    // Usable iff the fitted radius covers this request's
                    // data (see the module docs for why that is the rule).
                    Some(e) if e.map.radius >= radius => {
                        e.last_used = tick;
                        inner.hits += 1;
                        Some(e.map.clone())
                    }
                    _ => None,
                }
            };
            if let Some(map) = hit {
                if let Some(m) = metrics {
                    m.counter("service.feature_cache.hits").inc();
                }
                return map;
            }
        }
        // Miss (or caching disabled): fit outside the lock — the draw is
        // the expensive part and both racers would produce valid maps.
        let fitted = Arc::new(GaussianFeatureMap::new(
            eps,
            (radius * RADIUS_HEADROOM).max(1e-6),
            dim,
            r,
            rng,
        ));
        if let Some(m) = metrics {
            m.counter("service.feature_cache.misses").inc();
        }
        if self.capacity > 0 {
            let mut guard = self.inner.lock().unwrap();
            let inner = &mut *guard;
            inner.misses += 1;
            inner.tick += 1;
            let tick = inner.tick;
            inner.entries.insert(key, CacheEntry { map: fitted.clone(), last_used: tick });
            while inner.entries.len() > self.capacity {
                // Evict the least-recently-used key (the just-inserted
                // entry carries the newest tick, so it is never the one).
                let victim: Option<FeatureKey> = inner
                    .entries
                    .iter()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| *k);
                match victim {
                    Some(k) => inner.entries.remove(&k),
                    None => break,
                };
            }
        } else {
            self.inner.lock().unwrap().misses += 1;
        }
        fitted
    }

    /// Total hits since construction.
    pub fn hits(&self) -> u64 {
        self.inner.lock().unwrap().hits
    }

    /// Total misses since construction.
    pub fn misses(&self) -> u64 {
        self.inner.lock().unwrap().misses
    }

    /// Cached entry count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::seed_from(0xCACE)
    }

    #[test]
    fn second_lookup_same_key_hits() {
        let c = FeatureCache::new(4);
        let mut rng = rng();
        let a = c.get_or_fit(2, 0.5, 64, 3.0, &mut rng, None);
        let b = c.get_or_fit(2, 0.5, 64, 3.0, &mut rng, None);
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        // Same fitted map object (no refit).
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn smaller_radius_hits_larger_misses() {
        let c = FeatureCache::new(4);
        let mut rng = rng();
        let first = c.get_or_fit(2, 0.5, 64, 3.0, &mut rng, None);
        assert!(first.radius >= 3.0, "fitted with headroom");
        // Smaller data fits under the cached radius.
        let _ = c.get_or_fit(2, 0.5, 64, 2.0, &mut rng, None);
        assert_eq!(c.hits(), 1);
        // Much larger data voids the concentration guarantee -> refit.
        let bigger = c.get_or_fit(2, 0.5, 64, 30.0, &mut rng, None);
        assert_eq!(c.misses(), 2);
        assert!(bigger.radius >= 30.0);
        // The replacement now serves the larger radius.
        let again = c.get_or_fit(2, 0.5, 64, 30.0, &mut rng, None);
        assert!(Arc::ptr_eq(&bigger, &again));
        assert_eq!(c.hits(), 2);
    }

    #[test]
    fn distinct_eps_r_dim_are_distinct_entries() {
        let c = FeatureCache::new(8);
        let mut rng = rng();
        let _ = c.get_or_fit(2, 0.5, 64, 3.0, &mut rng, None);
        let _ = c.get_or_fit(2, 1.0, 64, 3.0, &mut rng, None);
        let _ = c.get_or_fit(2, 0.5, 128, 3.0, &mut rng, None);
        let _ = c.get_or_fit(3, 0.5, 64, 3.0, &mut rng, None);
        assert_eq!(c.misses(), 4);
        assert_eq!(c.hits(), 0);
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn lru_eviction_respects_capacity() {
        let c = FeatureCache::new(2);
        let mut rng = rng();
        let _ = c.get_or_fit(2, 0.1, 8, 3.0, &mut rng, None); // A
        let _ = c.get_or_fit(2, 0.2, 8, 3.0, &mut rng, None); // B
        let _ = c.get_or_fit(2, 0.1, 8, 3.0, &mut rng, None); // touch A
        let _ = c.get_or_fit(2, 0.3, 8, 3.0, &mut rng, None); // C evicts B
        assert_eq!(c.len(), 2);
        let _ = c.get_or_fit(2, 0.1, 8, 3.0, &mut rng, None); // A still hot
        assert_eq!(c.hits(), 2);
        let _ = c.get_or_fit(2, 0.2, 8, 3.0, &mut rng, None); // B was evicted
        assert_eq!(c.misses(), 4);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let c = FeatureCache::new(0);
        let mut rng = rng();
        let _ = c.get_or_fit(2, 0.5, 16, 3.0, &mut rng, None);
        let _ = c.get_or_fit(2, 0.5, 16, 3.0, &mut rng, None);
        assert_eq!(c.hits(), 0);
        assert_eq!(c.misses(), 2);
        assert!(c.is_empty());
    }

    #[test]
    fn metrics_counters_exported() {
        let c = FeatureCache::new(2);
        let m = Registry::default();
        let mut rng = rng();
        let _ = c.get_or_fit(2, 0.5, 16, 3.0, &mut rng, Some(&m));
        let _ = c.get_or_fit(2, 0.5, 16, 3.0, &mut rng, Some(&m));
        assert_eq!(m.counter("service.feature_cache.misses").get(), 1);
        assert_eq!(m.counter("service.feature_cache.hits").get(), 1);
    }
}
