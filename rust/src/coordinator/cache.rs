//! Shared feature-map cache: amortise the Lemma-1 anchor draw across
//! requests.
//!
//! Fitting a [`GaussianFeatureMap`] costs an `r x d` Gaussian anchor draw
//! plus per-anchor constants — cheap next to a solve, but it happens on
//! *every* request, and requests are grouped by shared `(dim, eps)`
//! precisely so this setup can be amortised. The cache makes the reuse
//! explicit and cross-batch.
//!
//! ## Keying rule
//!
//! Entries are keyed by `(dim, eps, r)` ([`FeatureKey`]; `eps` compared by
//! exact bit pattern — a "nearby" regularisation is a different kernel).
//! The fitted radius `R` is deliberately **not** part of the key: Lemma 1
//! is an exact expectation identity for any `x, y`, and `R` only enters
//! the paper's *variance* bound (via `q(eps, R, d)` and `psi`). A cached
//! map is therefore reusable for any request whose data radius is at most
//! the radius the map was fitted with — its Theorem-2 concentration
//! guarantee still applies — while data *larger* than the fitted radius
//! would void the guarantee, so that is a miss.
//!
//! On a radius miss the replacement map is fitted with
//! [`RADIUS_HEADROOM`] slack so mild workload drift (clouds growing a few
//! per cent per request) does not defeat the cache.
//!
//! ## Concurrency and metrics
//!
//! The cache is a `Mutex`-guarded LRU shared by every worker via `Arc`;
//! the expensive fit runs *outside* the lock (two workers may race to fit
//! the same key — both results are valid draws, last insert wins). Hits
//! and misses are counted locally and exported through
//! [`crate::metrics::Registry`] as `service.feature_cache.hits` /
//! `service.feature_cache.misses`, which the divergence-service example
//! prints.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::features::GaussianFeatureMap;
use crate::metrics::Registry;
use crate::rng::Rng;

/// Headroom factor applied to the data radius when fitting a map on a
/// cache miss, so slightly larger follow-up clouds still hit.
pub const RADIUS_HEADROOM: f64 = 1.25;

/// Cache key: requests sharing the ground-space dimension, the
/// regularisation and the feature count can share one anchor draw.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct FeatureKey {
    /// Ground-space dimension d.
    pub dim: usize,
    /// Bit pattern of the regularisation epsilon (exact match only).
    pub eps_bits: u64,
    /// Feature count r.
    pub r: usize,
}

impl FeatureKey {
    /// Key for a `(dim, eps, r)` combination.
    pub fn new(dim: usize, eps: f64, r: usize) -> FeatureKey {
        FeatureKey { dim, eps_bits: eps.to_bits(), r }
    }
}

struct CacheEntry {
    map: Arc<GaussianFeatureMap>,
    last_used: u64,
}

#[derive(Default)]
struct CacheInner {
    entries: HashMap<FeatureKey, CacheEntry>,
    /// Monotonic access clock for LRU eviction.
    tick: u64,
    hits: u64,
    misses: u64,
}

/// LRU cache of fitted [`GaussianFeatureMap`]s, shared across workers.
pub struct FeatureCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
}

impl FeatureCache {
    /// A cache holding at most `capacity` maps; `0` disables caching
    /// (every lookup fits a fresh map and counts as a miss).
    pub fn new(capacity: usize) -> FeatureCache {
        FeatureCache { inner: Mutex::new(CacheInner::default()), capacity }
    }

    /// The configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fetch a map usable for data of radius `radius` under
    /// `(dim, eps, r)`, fitting (with [`RADIUS_HEADROOM`]) on a miss.
    /// Counters go to `metrics` when provided.
    pub fn get_or_fit(
        &self,
        dim: usize,
        eps: f64,
        r: usize,
        radius: f64,
        rng: &mut Rng,
        metrics: Option<&Registry>,
    ) -> Arc<GaussianFeatureMap> {
        let radius = radius.max(1e-6);
        let key = FeatureKey::new(dim, eps, r);
        if self.capacity > 0 {
            let hit = {
                let mut guard = self.inner.lock().unwrap();
                let inner = &mut *guard;
                inner.tick += 1;
                let tick = inner.tick;
                match inner.entries.get_mut(&key) {
                    // Usable iff the fitted radius covers this request's
                    // data (see the module docs for why that is the rule).
                    Some(e) if e.map.radius >= radius => {
                        e.last_used = tick;
                        inner.hits += 1;
                        Some(e.map.clone())
                    }
                    _ => None,
                }
            };
            if let Some(map) = hit {
                if let Some(m) = metrics {
                    m.counter("service.feature_cache.hits").inc();
                }
                return map;
            }
        }
        // Miss (or caching disabled): fit outside the lock — the draw is
        // the expensive part and both racers would produce valid maps.
        let fitted = Arc::new(GaussianFeatureMap::new(
            eps,
            (radius * RADIUS_HEADROOM).max(1e-6),
            dim,
            r,
            rng,
        ));
        if let Some(m) = metrics {
            m.counter("service.feature_cache.misses").inc();
        }
        if self.capacity > 0 {
            let mut guard = self.inner.lock().unwrap();
            let inner = &mut *guard;
            inner.misses += 1;
            inner.tick += 1;
            let tick = inner.tick;
            inner.entries.insert(key, CacheEntry { map: fitted.clone(), last_used: tick });
            while inner.entries.len() > self.capacity {
                // Evict the least-recently-used key (the just-inserted
                // entry carries the newest tick, so it is never the one).
                let victim: Option<FeatureKey> = inner
                    .entries
                    .iter()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| *k);
                match victim {
                    Some(k) => inner.entries.remove(&k),
                    None => break,
                };
            }
        } else {
            self.inner.lock().unwrap().misses += 1;
        }
        fitted
    }

    /// Total hits since construction.
    pub fn hits(&self) -> u64 {
        self.inner.lock().unwrap().hits
    }

    /// Total misses since construction.
    pub fn misses(&self) -> u64 {
        self.inner.lock().unwrap().misses
    }

    /// Cached entry count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------
// Nyström landmark cache
// ---------------------------------------------------------------------

/// Landmark cache key: the `(dim, eps, rank, seed)` tuple the ROADMAP
/// names (eps by exact bit pattern, like [`FeatureKey`]), **plus** a
/// fingerprint of the two supports. Unlike the Lemma-1 anchor draw —
/// which is data-independent, so `(dim, eps, r)` suffices — a landmark
/// set is a function of the actual point clouds: reusing indices across
/// different clouds would silently build a different kernel than the
/// seeded selection, so the fingerprint is part of the key and a
/// changed support is a miss.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct LandmarkKey {
    /// Ground-space dimension d.
    pub dim: usize,
    /// Bit pattern of the regularisation epsilon (exact match only).
    pub eps_bits: u64,
    /// Landmark count.
    pub rank: usize,
    /// Selection seed (the plan seed the draw replays from).
    pub seed: u64,
    /// FNV-1a over both supports' point bits (see
    /// [`support_fingerprint`]).
    pub fingerprint: u64,
}

impl LandmarkKey {
    /// Key for a `(dim, eps, rank, seed)` combination over fingerprinted
    /// supports.
    pub fn new(dim: usize, eps: f64, rank: usize, seed: u64, fingerprint: u64) -> LandmarkKey {
        LandmarkKey { dim, eps_bits: eps.to_bits(), rank, seed, fingerprint }
    }
}

/// FNV-1a over the exact f32 bit patterns of both supports (lengths and
/// dim mixed in), so "same clouds" means bitwise-same clouds — the only
/// equality under which a cached landmark set replays the seeded
/// selection exactly.
pub fn support_fingerprint(mu: &crate::data::Measure, nu: &crate::data::Measure) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |v: u64| {
        for byte in v.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    mix(mu.len() as u64);
    mix(nu.len() as u64);
    mix(mu.dim() as u64);
    for m in [mu, nu] {
        for i in 0..m.len() {
            for &x in m.points.row(i) {
                mix(x.to_bits() as u64);
            }
        }
    }
    h
}

struct LandmarkEntry {
    landmarks: Arc<Vec<usize>>,
    last_used: u64,
}

#[derive(Default)]
struct LandmarkInner {
    entries: HashMap<LandmarkKey, LandmarkEntry>,
    tick: u64,
    hits: u64,
    misses: u64,
}

/// LRU cache of selected Nyström landmark index sets, living beside the
/// coordinator's feature-map cache: hot groups skip the O(r·(n+m)·d)
/// adaptive re-selection (the selection, not the factor construction,
/// is what dominates Nyström setup). Hits/misses export as
/// `service.landmark_cache.hits` / `service.landmark_cache.misses`.
///
/// Cached indices rebuild the **bit-identical** kernel: selection is a
/// pure function of `(supports, rank, seed)`, all of which are in the
/// key, and `NystromKernel::from_landmarks` is a pure function of the
/// indices.
pub struct LandmarkCache {
    inner: Mutex<LandmarkInner>,
    capacity: usize,
}

impl LandmarkCache {
    /// A cache holding at most `capacity` landmark sets; `0` disables
    /// caching (every lookup selects afresh and counts as a miss).
    pub fn new(capacity: usize) -> LandmarkCache {
        LandmarkCache { inner: Mutex::new(LandmarkInner::default()), capacity }
    }

    /// The configured capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fetch the landmark set for `key`, running `select` (the seeded
    /// selection) on a miss. Counters go to `metrics` when provided.
    /// The selection runs outside the lock, like the feature cache's
    /// fit: two racers produce identical sets (selection is seeded and
    /// pure), so last-insert-wins is harmless.
    pub fn get_or_select(
        &self,
        key: LandmarkKey,
        metrics: Option<&Registry>,
        select: impl FnOnce() -> Vec<usize>,
    ) -> Arc<Vec<usize>> {
        if self.capacity > 0 {
            let hit = {
                let mut guard = self.inner.lock().unwrap();
                let inner = &mut *guard;
                inner.tick += 1;
                let tick = inner.tick;
                match inner.entries.get_mut(&key) {
                    Some(e) => {
                        e.last_used = tick;
                        inner.hits += 1;
                        Some(e.landmarks.clone())
                    }
                    None => None,
                }
            };
            if let Some(set) = hit {
                if let Some(m) = metrics {
                    m.counter("service.landmark_cache.hits").inc();
                }
                return set;
            }
        }
        let selected = Arc::new(select());
        if let Some(m) = metrics {
            m.counter("service.landmark_cache.misses").inc();
        }
        if self.capacity > 0 {
            let mut guard = self.inner.lock().unwrap();
            let inner = &mut *guard;
            inner.misses += 1;
            inner.tick += 1;
            let tick = inner.tick;
            inner
                .entries
                .insert(key, LandmarkEntry { landmarks: selected.clone(), last_used: tick });
            while inner.entries.len() > self.capacity {
                let victim: Option<LandmarkKey> = inner
                    .entries
                    .iter()
                    .min_by_key(|(_, e)| e.last_used)
                    .map(|(k, _)| *k);
                match victim {
                    Some(k) => inner.entries.remove(&k),
                    None => break,
                };
            }
        } else {
            self.inner.lock().unwrap().misses += 1;
        }
        selected
    }

    /// Total hits since construction.
    pub fn hits(&self) -> u64 {
        self.inner.lock().unwrap().hits
    }

    /// Total misses since construction.
    pub fn misses(&self) -> u64 {
        self.inner.lock().unwrap().misses
    }

    /// Cached entry count.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::seed_from(0xCACE)
    }

    #[test]
    fn second_lookup_same_key_hits() {
        let c = FeatureCache::new(4);
        let mut rng = rng();
        let a = c.get_or_fit(2, 0.5, 64, 3.0, &mut rng, None);
        let b = c.get_or_fit(2, 0.5, 64, 3.0, &mut rng, None);
        assert_eq!(c.hits(), 1);
        assert_eq!(c.misses(), 1);
        // Same fitted map object (no refit).
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn smaller_radius_hits_larger_misses() {
        let c = FeatureCache::new(4);
        let mut rng = rng();
        let first = c.get_or_fit(2, 0.5, 64, 3.0, &mut rng, None);
        assert!(first.radius >= 3.0, "fitted with headroom");
        // Smaller data fits under the cached radius.
        let _ = c.get_or_fit(2, 0.5, 64, 2.0, &mut rng, None);
        assert_eq!(c.hits(), 1);
        // Much larger data voids the concentration guarantee -> refit.
        let bigger = c.get_or_fit(2, 0.5, 64, 30.0, &mut rng, None);
        assert_eq!(c.misses(), 2);
        assert!(bigger.radius >= 30.0);
        // The replacement now serves the larger radius.
        let again = c.get_or_fit(2, 0.5, 64, 30.0, &mut rng, None);
        assert!(Arc::ptr_eq(&bigger, &again));
        assert_eq!(c.hits(), 2);
    }

    #[test]
    fn distinct_eps_r_dim_are_distinct_entries() {
        let c = FeatureCache::new(8);
        let mut rng = rng();
        let _ = c.get_or_fit(2, 0.5, 64, 3.0, &mut rng, None);
        let _ = c.get_or_fit(2, 1.0, 64, 3.0, &mut rng, None);
        let _ = c.get_or_fit(2, 0.5, 128, 3.0, &mut rng, None);
        let _ = c.get_or_fit(3, 0.5, 64, 3.0, &mut rng, None);
        assert_eq!(c.misses(), 4);
        assert_eq!(c.hits(), 0);
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn lru_eviction_respects_capacity() {
        let c = FeatureCache::new(2);
        let mut rng = rng();
        let _ = c.get_or_fit(2, 0.1, 8, 3.0, &mut rng, None); // A
        let _ = c.get_or_fit(2, 0.2, 8, 3.0, &mut rng, None); // B
        let _ = c.get_or_fit(2, 0.1, 8, 3.0, &mut rng, None); // touch A
        let _ = c.get_or_fit(2, 0.3, 8, 3.0, &mut rng, None); // C evicts B
        assert_eq!(c.len(), 2);
        let _ = c.get_or_fit(2, 0.1, 8, 3.0, &mut rng, None); // A still hot
        assert_eq!(c.hits(), 2);
        let _ = c.get_or_fit(2, 0.2, 8, 3.0, &mut rng, None); // B was evicted
        assert_eq!(c.misses(), 4);
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let c = FeatureCache::new(0);
        let mut rng = rng();
        let _ = c.get_or_fit(2, 0.5, 16, 3.0, &mut rng, None);
        let _ = c.get_or_fit(2, 0.5, 16, 3.0, &mut rng, None);
        assert_eq!(c.hits(), 0);
        assert_eq!(c.misses(), 2);
        assert!(c.is_empty());
    }

    #[test]
    fn metrics_counters_exported() {
        let c = FeatureCache::new(2);
        let m = Registry::default();
        let mut rng = rng();
        let _ = c.get_or_fit(2, 0.5, 16, 3.0, &mut rng, Some(&m));
        let _ = c.get_or_fit(2, 0.5, 16, 3.0, &mut rng, Some(&m));
        assert_eq!(m.counter("service.feature_cache.misses").get(), 1);
        assert_eq!(m.counter("service.feature_cache.hits").get(), 1);
    }

    #[test]
    fn landmark_cache_hits_same_key_and_skips_selection() {
        let c = LandmarkCache::new(4);
        let m = Registry::default();
        let key = LandmarkKey::new(2, 0.5, 8, 7, 0xF00D);
        let mut selections = 0;
        let first = c.get_or_select(key, Some(&m), || {
            selections += 1;
            vec![1, 2, 3]
        });
        let second = c.get_or_select(key, Some(&m), || {
            selections += 1;
            vec![9, 9, 9] // must not run
        });
        assert_eq!(selections, 1, "hit must skip the selection closure");
        assert!(Arc::ptr_eq(&first, &second));
        assert_eq!(m.counter("service.landmark_cache.hits").get(), 1);
        assert_eq!(m.counter("service.landmark_cache.misses").get(), 1);
    }

    #[test]
    fn landmark_cache_misses_on_different_support_fingerprint() {
        let c = LandmarkCache::new(4);
        let a = LandmarkKey::new(2, 0.5, 8, 7, 0xAAAA);
        let b = LandmarkKey::new(2, 0.5, 8, 7, 0xBBBB);
        let _ = c.get_or_select(a, None, || vec![1]);
        let _ = c.get_or_select(b, None, || vec![2]);
        assert_eq!(c.misses(), 2);
        assert_eq!(c.hits(), 0);
        assert_eq!(c.len(), 2, "different fingerprints are distinct entries");
    }

    #[test]
    fn landmark_cache_zero_capacity_disables() {
        let c = LandmarkCache::new(0);
        let key = LandmarkKey::new(2, 0.5, 8, 7, 1);
        let _ = c.get_or_select(key, None, || vec![1]);
        let _ = c.get_or_select(key, None, || vec![1]);
        assert_eq!(c.hits(), 0);
        assert_eq!(c.misses(), 2);
        assert!(c.is_empty());
    }

    #[test]
    fn support_fingerprint_tracks_point_bits() {
        use crate::data::Measure;
        use crate::linalg::Mat;
        let m1 = Measure::uniform(Mat::from_vec(2, 2, vec![0.0, 1.0, 2.0, 3.0]));
        let m2 = Measure::uniform(Mat::from_vec(2, 2, vec![0.0, 1.0, 2.0, 3.0]));
        let m3 = Measure::uniform(Mat::from_vec(2, 2, vec![0.0, 1.0, 2.0, 3.5]));
        assert_eq!(support_fingerprint(&m1, &m2), support_fingerprint(&m2, &m1));
        assert_ne!(support_fingerprint(&m1, &m2), support_fingerprint(&m1, &m3));
        // Side order matters (xy vs yx are different selections).
        assert_ne!(support_fingerprint(&m1, &m3), support_fingerprint(&m3, &m1));
    }
}
