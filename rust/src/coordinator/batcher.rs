//! Dynamic batcher: size-or-deadline flush policy, plus the fuse-grouping
//! rule that feeds the batched multi-pair solve engine.
//!
//! Invariants (property-tested in `rust/tests/`):
//! * never drops a request — every received request appears in exactly one
//!   emitted batch;
//! * preserves arrival order within and across batches;
//! * no batch exceeds `max_batch`;
//! * no request waits in the batcher longer than ~`max_delay_us` past the
//!   batch's first arrival (modulo scheduler jitter).
//!
//! [`fuse_groups`] partitions a flushed batch into groups that one
//! [`crate::sinkhorn::solve_batch`]-powered solve can serve: requests
//! fuse only when they agree on the feature-map key (dimension and
//! epsilon — the `(dim, eps, r)` cache key, with `r` fixed per service)
//! **and** share identical support points, which is what lets their
//! weight pairs stack against a single factored kernel. Incompatible
//! requests never fuse; groups are capped at `sinkhorn.max_batch`.
//! Fusion is a throughput optimisation only — batched solves are bitwise
//! identical to sequential ones (`rust/tests/batched_equivalence.rs`).

use std::sync::mpsc::{Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::metrics::Registry;

use super::Request;

/// Flush policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherPolicy {
    pub max_batch: usize,
    pub max_delay_us: u64,
}

/// A group of requests flushed together.
pub struct Batch {
    pub requests: Vec<Request>,
    pub formed_at: Instant,
}

/// Run the batcher loop until the request channel disconnects.
pub fn run(
    rx: Receiver<Request>,
    tx: SyncSender<Batch>,
    policy: BatcherPolicy,
    metrics: Arc<Registry>,
) {
    let max_batch = policy.max_batch.max(1);
    let max_delay = Duration::from_micros(policy.max_delay_us);
    let mut pending: Vec<Request> = Vec::with_capacity(max_batch);
    let mut first_arrival: Option<Instant> = None;

    loop {
        // How long may we still wait before the deadline of the oldest
        // pending request?
        let timeout = match first_arrival {
            Some(t0) => max_delay.saturating_sub(t0.elapsed()),
            None => Duration::from_secs(3600), // idle: block until work arrives
        };
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if pending.is_empty() {
                    first_arrival = Some(Instant::now());
                }
                pending.push(req);
                metrics.gauge("batcher.pending").set(pending.len() as i64);
                if pending.len() >= max_batch {
                    flush(&mut pending, &mut first_arrival, &tx, &metrics);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if !pending.is_empty() {
                    flush(&mut pending, &mut first_arrival, &tx, &metrics);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // Drain what we have, then exit (workers see the batch
                // channel close when we drop tx).
                if !pending.is_empty() {
                    flush(&mut pending, &mut first_arrival, &tx, &metrics);
                }
                return;
            }
        }
    }
}

/// Can two requests ride one fused multi-pair solve?
///
/// They must resolve to the same feature map (same dimension and the same
/// epsilon override, compared by bit pattern like the cache key) and sit
/// on identical support points — a shared support is what makes their
/// weight pairs marginals of the *same* factored kernel. Weights are
/// free to differ; they are exactly the per-pair payload of the batched
/// solve.
fn fusable(a: &Request, b: &Request) -> bool {
    a.epsilon.map(f64::to_bits) == b.epsilon.map(f64::to_bits)
        && a.mu.dim() == b.mu.dim()
        && a.mu.points == b.mu.points
        && a.nu.points == b.nu.points
}

/// Partition a flushed batch into fuse groups of width ≤ `max_width`.
///
/// Requests are first ordered by request ID, then greedily first-fit:
/// each request joins the first not-yet-full group it is [`fusable`]
/// with, else opens a new group. The sort makes the partition a **pure
/// function of the request set** — the same requests always land in the
/// same groups in the same order, no matter how channel scheduling
/// interleaved their arrival. That determinism is what the shard tier's
/// re-scatter leans on: a re-dispatched group re-partitions identically,
/// so retries cannot reshuffle pair order (and request IDs are assigned
/// monotonically at submit, so ID order is submit order anyway).
/// A fused request replies together with its group — ahead of unfusable
/// earlier-group neighbours still queued — so *cross-request* reply
/// order is not strict arrival order (each request has its own reply
/// channel; nothing observes cross-request ordering). With
/// `max_width ≤ 1` every request gets its own group — fusion disabled.
pub fn fuse_groups(mut requests: Vec<Request>, max_width: usize) -> Vec<Vec<Request>> {
    requests.sort_by_key(|r| r.id);
    let cap = max_width.max(1);
    let mut groups: Vec<Vec<Request>> = Vec::new();
    for req in requests {
        match groups.iter_mut().find(|g| g.len() < cap && fusable(&g[0], &req)) {
            Some(group) => group.push(req),
            None => groups.push(vec![req]),
        }
    }
    groups
}

fn flush(
    pending: &mut Vec<Request>,
    first_arrival: &mut Option<Instant>,
    tx: &SyncSender<Batch>,
    metrics: &Registry,
) {
    let batch = Batch { requests: std::mem::take(pending), formed_at: Instant::now() };
    metrics.counter("batcher.flushes").inc();
    metrics.gauge("batcher.pending").set(0);
    *first_arrival = None;
    // If workers are saturated this blocks — that is the backpressure the
    // bounded submit queue propagates to clients.
    let _ = tx.send(batch);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Measure;
    use crate::linalg::Mat;
    use std::sync::mpsc::sync_channel;

    fn mk_request(
        id: u64,
        reply: SyncSender<crate::error::Result<super::super::Response>>,
    ) -> Request {
        Request {
            id,
            mu: Measure::uniform(Mat::ones(2, 2)),
            nu: Measure::uniform(Mat::ones(2, 2)),
            epsilon: None,
            enqueued: Instant::now(),
            reply,
        }
    }

    fn run_batcher_on(ids: &[u64], policy: BatcherPolicy) -> Vec<Vec<u64>> {
        let (req_tx, req_rx) = sync_channel::<Request>(256);
        let (batch_tx, batch_rx) = sync_channel::<Batch>(256);
        let metrics = Arc::new(Registry::default());
        let handle = std::thread::spawn(move || run(req_rx, batch_tx, policy, metrics));
        let (reply_tx, _reply_rx) = sync_channel(256);
        for &id in ids {
            req_tx.send(mk_request(id, reply_tx.clone())).unwrap();
        }
        drop(req_tx);
        handle.join().unwrap();
        batch_rx.iter().map(|b| b.requests.iter().map(|r| r.id).collect()).collect()
    }

    #[test]
    fn never_drops_and_preserves_order() {
        let ids: Vec<u64> = (0..23).collect();
        let batches =
            run_batcher_on(&ids, BatcherPolicy { max_batch: 4, max_delay_us: 10_000 });
        let flat: Vec<u64> = batches.iter().flatten().cloned().collect();
        assert_eq!(flat, ids, "all requests, in order");
    }

    #[test]
    fn respects_max_batch() {
        let ids: Vec<u64> = (0..50).collect();
        let batches = run_batcher_on(&ids, BatcherPolicy { max_batch: 8, max_delay_us: 10_000 });
        assert!(batches.iter().all(|b| b.len() <= 8));
        assert!(batches.iter().any(|b| b.len() == 8), "bursts should fill batches");
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        // One request, huge max_batch, short deadline: must still be
        // delivered promptly (before channel close in this test, the flush
        // comes from the timeout path).
        let (req_tx, req_rx) = sync_channel::<Request>(16);
        let (batch_tx, batch_rx) = sync_channel::<Batch>(16);
        let metrics = Arc::new(Registry::default());
        let handle = std::thread::spawn(move || {
            run(req_rx, batch_tx, BatcherPolicy { max_batch: 1000, max_delay_us: 2_000 }, metrics)
        });
        let (reply_tx, _reply_rx) = sync_channel(1);
        req_tx.send(mk_request(7, reply_tx)).unwrap();
        let batch = batch_rx.recv_timeout(Duration::from_secs(2)).expect("deadline flush");
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(batch.requests[0].id, 7);
        drop(req_tx);
        handle.join().unwrap();
    }

    #[test]
    fn size_flush_is_immediate_even_with_huge_deadline() {
        // Filling max_batch must flush NOW — the deadline (here one
        // minute) is a latency bound for partial batches, not a pacing
        // clock for full ones.
        let (req_tx, req_rx) = sync_channel::<Request>(16);
        let (batch_tx, batch_rx) = sync_channel::<Batch>(16);
        let metrics = Arc::new(Registry::default());
        let handle = std::thread::spawn(move || {
            run(
                req_rx,
                batch_tx,
                BatcherPolicy { max_batch: 4, max_delay_us: 60_000_000 },
                metrics,
            )
        });
        let (reply_tx, _reply_rx) = sync_channel(16);
        for id in 0..4 {
            req_tx.send(mk_request(id, reply_tx.clone())).unwrap();
        }
        let batch = batch_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("size-triggered flush must not wait for the deadline");
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(
            batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        drop(req_tx);
        handle.join().unwrap();
    }

    #[test]
    fn partial_batch_waits_for_deadline_not_forever() {
        // A partial batch (3 of max 100) must be flushed by the deadline
        // alone, while the channel stays open.
        let (req_tx, req_rx) = sync_channel::<Request>(16);
        let (batch_tx, batch_rx) = sync_channel::<Batch>(16);
        let metrics = Arc::new(Registry::default());
        let handle = std::thread::spawn(move || {
            run(
                req_rx,
                batch_tx,
                BatcherPolicy { max_batch: 100, max_delay_us: 5_000 },
                metrics,
            )
        });
        let (reply_tx, _reply_rx) = sync_channel(16);
        for id in 0..3 {
            req_tx.send(mk_request(id, reply_tx.clone())).unwrap();
        }
        let batch = batch_rx.recv_timeout(Duration::from_secs(5)).expect("deadline flush");
        assert_eq!(batch.requests.len(), 3, "partial batch flushed as one unit");
        drop(req_tx);
        handle.join().unwrap();
    }

    #[test]
    fn drains_on_disconnect() {
        let ids: Vec<u64> = (0..3).collect();
        let batches =
            run_batcher_on(&ids, BatcherPolicy { max_batch: 100, max_delay_us: 60_000_000 });
        let flat: Vec<u64> = batches.iter().flatten().cloned().collect();
        assert_eq!(flat, ids, "pending requests must be drained at shutdown");
    }

    fn mk_typed_request(
        id: u64,
        mu: Measure,
        nu: Measure,
        epsilon: Option<f64>,
        reply: SyncSender<crate::error::Result<super::super::Response>>,
    ) -> Request {
        Request { id, mu, nu, epsilon, enqueued: Instant::now(), reply }
    }

    fn group_ids(groups: &[Vec<Request>]) -> Vec<Vec<u64>> {
        groups.iter().map(|g| g.iter().map(|r| r.id).collect()).collect()
    }

    #[test]
    fn fuse_groups_shares_only_compatible_requests() {
        let (reply_tx, _reply_rx) = sync_channel(16);
        let pts_a = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f32);
        let pts_b = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f32 + 10.0);
        let pts_3d = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        let shared = |id, eps| {
            mk_typed_request(
                id,
                Measure::uniform(pts_a.clone()),
                Measure::uniform(pts_b.clone()),
                eps,
                reply_tx.clone(),
            )
        };
        let requests = vec![
            shared(0, None),                 // fuses with 1 and 4
            shared(1, None),
            shared(2, Some(0.25)),           // different eps: never fuses with 0/1
            mk_typed_request(
                3,
                Measure::uniform(pts_3d.clone()),
                Measure::uniform(pts_3d.clone()),
                None,
                reply_tx.clone(),
            ),                               // different dim: its own group
            shared(4, None),
            mk_typed_request(
                5,
                Measure::uniform(pts_b.clone()),
                Measure::uniform(pts_a.clone()),
                None,
                reply_tx.clone(),
            ),                               // same dim+eps but different support: no fuse
        ];
        let groups = fuse_groups(requests, 8);
        assert_eq!(
            group_ids(&groups),
            vec![vec![0, 1, 4], vec![2], vec![3], vec![5]],
            "only same-(dim, eps)+same-support requests share a fused solve"
        );
    }

    #[test]
    fn fuse_groups_is_a_pure_function_of_request_ids() {
        // The shard tier re-scatters orphaned groups, and the retry path
        // is only bitwise-safe if partitioning never depends on channel
        // arrival order. Property: for any request set, fusing a seeded
        // shuffle of it yields exactly the groups of the ID-ordered fuse.
        crate::testing::property("fuse_groups_pure_in_ids", 32, |g| {
            let (reply_tx, _reply_rx) = sync_channel(512);
            // A handful of compatibility classes: two support sets × two
            // epsilon overrides, plus a 3-d odd one out.
            let pts_a = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f32);
            let pts_b = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f32 + 5.0);
            let pts_c = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
            let n = g.usize_in(1, 24) as u64;
            let mut requests: Vec<Request> = (0..n)
                .map(|id| {
                    let (mu_pts, nu_pts) = match g.usize_in(0, 2) {
                        0 => (pts_a.clone(), pts_b.clone()),
                        1 => (pts_b.clone(), pts_a.clone()),
                        _ => (pts_c.clone(), pts_c.clone()),
                    };
                    let eps = if g.usize_in(0, 1) == 0 { None } else { Some(0.25) };
                    mk_typed_request(
                        id,
                        Measure::uniform(mu_pts),
                        Measure::uniform(nu_pts),
                        eps,
                        reply_tx.clone(),
                    )
                })
                .collect();
            let width = g.usize_in(1, 5);
            let clone_all = |reqs: &[Request]| -> Vec<Request> {
                reqs.iter()
                    .map(|r| {
                        mk_typed_request(
                            r.id,
                            r.mu.clone(),
                            r.nu.clone(),
                            r.epsilon,
                            reply_tx.clone(),
                        )
                    })
                    .collect()
            };
            let baseline = group_ids(&fuse_groups(clone_all(&requests), width));
            // Fisher–Yates with the case's seeded rng: an arbitrary
            // arrival interleaving of the same request set.
            for i in (1..requests.len()).rev() {
                let j = g.rng.uniform_usize(i + 1);
                requests.swap(i, j);
            }
            let shuffled = group_ids(&fuse_groups(requests, width));
            assert_eq!(
                shuffled, baseline,
                "fuse partition must not depend on arrival interleaving (width {width})"
            );
        });
    }

    #[test]
    fn fuse_groups_respects_width_cap_and_disables_at_one() {
        let (reply_tx, _reply_rx) = sync_channel(16);
        let pts = Mat::ones(2, 2);
        let reqs = |n: u64| -> Vec<Request> {
            (0..n)
                .map(|id| {
                    mk_typed_request(
                        id,
                        Measure::uniform(pts.clone()),
                        Measure::uniform(pts.clone()),
                        None,
                        reply_tx.clone(),
                    )
                })
                .collect()
        };
        let capped = fuse_groups(reqs(5), 2);
        assert_eq!(group_ids(&capped), vec![vec![0, 1], vec![2, 3], vec![4]]);
        let solo = fuse_groups(reqs(3), 1);
        assert_eq!(group_ids(&solo), vec![vec![0], vec![1], vec![2]]);
    }
}
