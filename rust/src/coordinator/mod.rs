//! The L3 divergence service: a thread-based coordinator that accepts
//! point-cloud pairs and returns their linear-time Sinkhorn divergence.
//!
//! Pipeline:
//!
//! ```text
//!  clients --submit--> [bounded queue] --> batcher --batches--> worker pool
//!      ^                    |  (shed when full)        |  (N threads)
//!      +----- response <----+--------------------------+
//! ```
//!
//! * **Dynamic batching** ([`batcher`]): flush on `max_batch` pending or
//!   when the oldest request has waited `max_delay_us` — the same
//!   size-or-deadline policy a serving stack (vLLM-style) uses. Batching
//!   matters here because requests with the same (dim, eps) *share the
//!   Lemma-1 anchor draw*, amortising feature-map setup across a batch.
//! * **Fused multi-pair solves** ([`batcher::fuse_groups`] +
//!   [`crate::sinkhorn::solve_batch_stabilized`]): within a flushed
//!   batch, requests that share the feature-map key *and* identical
//!   support points are solved as **one** batched solve per transport
//!   problem — their weight pairs stack into column-blocked scaling
//!   matrices and every Sinkhorn iteration streams the shared factors
//!   once for the whole group (O(r·Σn) fused applies). Results are
//!   bitwise identical to solving each request alone, so fusion is
//!   invisible except in throughput. Width is capped by
//!   `sinkhorn.max_batch` (`--max-batch`; `1` disables). Metrics:
//!   `service.batched_solves` counts requests served by fused solves,
//!   `service.batch_width` records solve-group widths.
//! * **Feature-map cache** ([`cache`]): the amortisation is made explicit
//!   and cross-batch — fitted `GaussianFeatureMap`s are cached by
//!   `(dim, eps, r)` and reused whenever the cached radius covers the
//!   request's data; hit/miss counters are exported through the metrics
//!   registry (`service.feature_cache.*`).
//! * **Backpressure**: the submit queue is bounded (`queue_depth`);
//!   overflow sheds with typed [`Error::Overloaded`] instead of queueing
//!   unboundedly (retryable by construction — nothing was attempted).
//! * **Workers** solve each request with the native factored-kernel
//!   Sinkhorn (O(r(n+m)) per iteration); `solver_threads` additionally
//!   parallelises each solve's matvecs and feature evaluation over the
//!   intra-solve pool ([`crate::runtime::pool`]). Each worker creates its
//!   persistent pools **once** and reuses them for every request — with
//!   the channel-fed pool, per-request construction would mean
//!   per-request thread spawning.
//! * **Stabilisation**: requests whose epsilon drives plain Alg. 1 into
//!   non-finite scalings are retried on the matrix-free log-domain
//!   solver (still O(r(n+m)), see [`crate::kernels::LogKernelOp`]) when
//!   `sinkhorn.stabilize` is on; escalations are counted by the
//!   `service.stabilized_solves` metric.
//!
//! * **Sharded serving** (`service.shard_workers > 0`, or a
//!   `service.shard_addrs` roster of `host:port` TCP workers): every
//!   fuse group is delegated through a
//!   [`crate::shard::ShardCoordinator`] — the plan, measures, weight
//!   pairs, and the cache-resolved feature map ship as wire envelopes
//!   to shard workers, and the gathered
//!   [`crate::api::DivergenceReport`]s are bitwise identical to the
//!   in-process fused solve (the map travels with the task precisely so
//!   the worker does not have to refit it). Worker crashes, hangs,
//!   stragglers, and lost messages are absorbed by heartbeat liveness,
//!   bounded retry, hedging, and rejoin, all tuned by
//!   `service.shard.*` config keys ([`crate::config::ShardSettings`]);
//!   [`Service::shutdown`] drains the shard tier gracefully. See
//!   `crate::shard` for the failure ladder and the `service.shard.*`
//!   metrics.
//!
//! * **Streaming sessions** ([`crate::session`]): long-lived *mutating*
//!   transport problems served through the same handle —
//!   [`ServiceHandle::session_create`] / [`ServiceHandle::session_update`]
//!   / [`ServiceHandle::session_query`] /
//!   [`ServiceHandle::session_close`]. The coordinator keeps a bounded
//!   session table (`service.session_capacity`, shed with
//!   [`Error::Overloaded`]); queries warm-start from the session's
//!   cached dual remapped across updates. With a shard tier configured,
//!   the session's factored support stays **resident** on a pinned
//!   shard worker and only the op delta plus the warm dual ship per
//!   query — a residency miss (worker death, version skew, eviction)
//!   surfaces as a typed error the coordinator answers with a full
//!   snapshot retry, so correctness never depends on the residency
//!   cache. Metrics: `service.session.{live,created,closed,updates,
//!   queries,warm_solves,cold_solves,warm_iterations_saved,
//!   sharded_queries,snapshot_retries}`.
//!
//! Everything is std::thread + mpsc (the offline crate set has no tokio);
//! for a compute-bound service this is the right tool anyway.

pub mod batcher;
pub mod cache;

pub use batcher::{Batch, BatcherPolicy};
pub use cache::{support_fingerprint, FeatureCache, FeatureKey, LandmarkCache, LandmarkKey};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::api::{BackendPref, OtProblem, SessionDelta};
use crate::config::ServiceConfig;
use crate::data::Measure;
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::metrics::Registry;
use crate::rng::Rng;
use crate::runtime::pool::Pool;
use crate::session::{
    QueryReport, SessionConfig, SessionOp, SessionStats, StreamingSession, DEFAULT_SESSION_SEED,
};

/// A divergence request: two measures on the same ground space.
pub struct Request {
    pub id: u64,
    pub mu: Measure,
    pub nu: Measure,
    /// Per-request regularisation override (None = service default).
    /// High-dimensional clouds need a larger eps than 2-D ones — squared
    /// distances scale with the dimension — so clients pick their own.
    pub epsilon: Option<f64>,
    pub enqueued: Instant,
    reply: SyncSender<Result<Response>>,
}

/// A completed divergence computation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// The Eq. (2) Sinkhorn divergence estimate.
    pub divergence: f64,
    /// The raw transport objective W(mu, nu).
    pub w_xy: f64,
    /// Total Sinkhorn iterations across the three solves.
    pub iterations: usize,
    /// End-to-end latency in microseconds (enqueue -> solve done).
    pub latency_us: u64,
    /// How many requests shared this request's batch.
    pub batch_size: usize,
}

/// A pending reply the client blocks on.
pub struct Pending {
    rx: Receiver<Result<Response>>,
}

impl Pending {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<Response> {
        self.rx
            .recv()
            .map_err(|_| Error::Service("service shut down before replying".into()))?
    }
}

/// One live streaming session plus the serving-side state that does not
/// belong in [`StreamingSession`] itself: the op log accumulated since
/// the last successful sharded solve, and where (if anywhere) the
/// session's support is resident on the shard tier.
struct SessionEntry {
    session: StreamingSession,
    /// Ops applied locally but not yet replayed on the resident shard
    /// copy. Cleared on every successful sharded solve (the worker is
    /// then at the current version) and whenever residency is dropped.
    pending: Vec<SessionOp>,
    /// `(shard worker index, version resident there)` after a
    /// successful sharded solve; `None` forces the next sharded query
    /// to ship a full snapshot.
    resident: Option<(usize, u64)>,
}

/// The coordinator's bounded table of live sessions. Two-level locking:
/// the outer map lock is held only to look up / insert / remove entry
/// `Arc`s, so a long-running solve on one session never blocks
/// create/update/query traffic on another.
struct SessionTable {
    entries: Mutex<HashMap<u64, Arc<Mutex<SessionEntry>>>>,
    next_id: AtomicU64,
    capacity: usize,
}

impl SessionTable {
    fn new(capacity: usize) -> SessionTable {
        SessionTable {
            entries: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            capacity,
        }
    }
}

/// Client handle; cloneable, cheap.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<Request>,
    next_id: Arc<AtomicU64>,
    metrics: Arc<Registry>,
    sessions: Arc<SessionTable>,
    /// The service's shard tier, when configured — sharded session
    /// queries pin the session's support to one worker through it.
    shard: Option<Arc<crate::shard::ShardCoordinator>>,
    cfg: Arc<ServiceConfig>,
}

impl ServiceHandle {
    /// Submit a divergence request. Errors immediately with
    /// [`Error::Overloaded`] if the queue is full (load shed) or
    /// [`Error::Service`] if the service has shut down.
    pub fn submit(&self, mu: Measure, nu: Measure) -> Result<Pending> {
        self.submit_with(mu, nu, None)
    }

    /// Submit with a per-request regularisation override.
    pub fn submit_with(&self, mu: Measure, nu: Measure, epsilon: Option<f64>) -> Result<Pending> {
        if mu.dim() != nu.dim() {
            return Err(Error::Shape(format!(
                "measures have different dims ({} vs {})",
                mu.dim(),
                nu.dim()
            )));
        }
        if let Some(e) = epsilon {
            if !(e > 0.0 && e.is_finite()) {
                return Err(Error::Config(format!("epsilon override must be positive, got {e}")));
            }
        }
        let (reply_tx, reply_rx) = sync_channel(1);
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            mu,
            nu,
            epsilon,
            enqueued: Instant::now(),
            reply: reply_tx,
        };
        match self.tx.try_send(req) {
            Ok(()) => {
                self.metrics.counter("service.submitted").inc();
                Ok(Pending { rx: reply_rx })
            }
            Err(TrySendError::Full(_)) => {
                self.metrics.counter("service.shed").inc();
                Err(Error::Overloaded("submit queue full (load shed)".into()))
            }
            Err(TrySendError::Disconnected(_)) => {
                Err(Error::Service("service is shut down".into()))
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn divergence(&self, mu: Measure, nu: Measure) -> Result<Response> {
        self.submit(mu, nu)?.wait()
    }

    /// Metrics snapshot.
    pub fn metrics_text(&self) -> String {
        self.metrics.render()
    }

    // ------------------------------------------------------------------
    // Streaming sessions
    // ------------------------------------------------------------------

    /// Open a streaming session on the given supports; returns the
    /// session id for [`ServiceHandle::session_update`] /
    /// [`ServiceHandle::session_query`] / [`ServiceHandle::session_close`].
    /// Sheds with [`Error::Overloaded`] when the table is at
    /// `service.session_capacity`. The session inherits the service's
    /// solver settings (with `epsilon` overridden per session when
    /// given), `num_features` as its rank, and the fixed session seed —
    /// annealing and symmetric-divergence schedules are per-request
    /// conveniences that do not apply to a long-lived cached-dual
    /// session, so they are stripped.
    pub fn session_create(&self, mu: Measure, nu: Measure, epsilon: Option<f64>) -> Result<u64> {
        if mu.dim() != nu.dim() {
            return Err(Error::Shape(format!(
                "measures have different dims ({} vs {})",
                mu.dim(),
                nu.dim()
            )));
        }
        if let Some(e) = epsilon {
            if !(e > 0.0 && e.is_finite()) {
                return Err(Error::Config(format!("epsilon override must be positive, got {e}")));
            }
        }
        let mut sinkhorn = self.cfg.sinkhorn.clone();
        if let Some(e) = epsilon {
            sinkhorn.epsilon = e;
        }
        sinkhorn.anneal = None;
        sinkhorn.symmetric = None;
        let scfg = SessionConfig {
            sinkhorn,
            rank: self.cfg.num_features,
            seed: DEFAULT_SESSION_SEED,
            solver_threads: self.cfg.solver_threads,
        };
        let session = StreamingSession::new(&mu, &nu, scfg)?;
        let mut entries = self.sessions.entries.lock().unwrap();
        if entries.len() >= self.sessions.capacity {
            return Err(Error::Overloaded(format!(
                "session table full ({} live sessions)",
                entries.len()
            )));
        }
        let id = self.sessions.next_id.fetch_add(1, Ordering::Relaxed);
        entries.insert(
            id,
            Arc::new(Mutex::new(SessionEntry { session, pending: Vec::new(), resident: None })),
        );
        self.metrics.counter("service.session.created").inc();
        self.metrics.gauge("service.session.live").add(1);
        Ok(id)
    }

    /// Apply a batch of support edits to a session; returns the new
    /// version. On an op error the batch may be partially applied (the
    /// version still bumps) and the shard-resident copy can no longer be
    /// reached by delta replay, so residency is dropped — the next
    /// sharded query re-snapshots.
    pub fn session_update(&self, id: u64, ops: &[SessionOp]) -> Result<u64> {
        let entry = self.session_entry(id)?;
        let mut e = entry.lock().unwrap();
        match e.session.update(ops) {
            Ok(version) => {
                e.pending.extend_from_slice(ops);
                self.metrics.counter("service.session.updates").add(ops.len() as u64);
                Ok(version)
            }
            Err(err) => {
                e.pending.clear();
                e.resident = None;
                Err(err)
            }
        }
    }

    /// Solve `W_eps` on the session's current support, warm-starting
    /// from the cached dual when it survived the updates since the last
    /// solve. In-process this is exactly [`StreamingSession::query`];
    /// with a shard tier the solve runs on the session's pinned worker
    /// (delta replay against the resident support, snapshot on miss)
    /// and returns bit-identical numbers — both routes go through
    /// [`crate::session::solve_support`].
    pub fn session_query(&self, id: u64) -> Result<QueryReport> {
        let entry = self.session_entry(id)?;
        let mut e = entry.lock().unwrap();
        let saved_before = e.session.stats().iterations_saved;
        let report = match self.shard.clone() {
            None => e.session.query()?,
            Some(shard) => self.session_query_sharded(&shard, &mut e, id)?,
        };
        self.metrics.counter("service.session.queries").inc();
        if report.warm_started {
            self.metrics.counter("service.session.warm_solves").inc();
        } else {
            self.metrics.counter("service.session.cold_solves").inc();
        }
        let saved = e.session.stats().iterations_saved.saturating_sub(saved_before);
        if saved > 0 {
            self.metrics.counter("service.session.warm_iterations_saved").add(saved);
        }
        Ok(report)
    }

    /// Change a session's regularisation: cold restart (map refit from
    /// the session seed over the current support, duals dropped), and
    /// any shard-resident copy is invalidated.
    pub fn session_set_epsilon(&self, id: u64, eps: f64) -> Result<()> {
        let entry = self.session_entry(id)?;
        let mut e = entry.lock().unwrap();
        e.session.set_epsilon(eps)?;
        e.pending.clear();
        e.resident = None;
        Ok(())
    }

    /// Lifetime counters for one session (updates, queries, warm/cold
    /// split, iteration savings).
    pub fn session_stats(&self, id: u64) -> Result<SessionStats> {
        let entry = self.session_entry(id)?;
        let stats = entry.lock().unwrap().session.stats().clone();
        Ok(stats)
    }

    /// Close a session: drop it from the table and tell the shard tier
    /// to evict any resident copy.
    pub fn session_close(&self, id: u64) -> Result<()> {
        let removed = self.sessions.entries.lock().unwrap().remove(&id);
        match removed {
            Some(_) => {
                if let Some(shard) = self.shard.as_deref() {
                    shard.close_session(id);
                }
                self.metrics.counter("service.session.closed").inc();
                self.metrics.gauge("service.session.live").add(-1);
                Ok(())
            }
            None => Err(Error::Service(format!("unknown session {id}"))),
        }
    }

    fn session_entry(&self, id: u64) -> Result<Arc<Mutex<SessionEntry>>> {
        self.sessions
            .entries
            .lock()
            .unwrap()
            .get(&id)
            .cloned()
            .ok_or_else(|| Error::Service(format!("unknown session {id}")))
    }

    /// The sharded leg of [`ServiceHandle::session_query`]. Ships the
    /// cheapest frame that reaches the session's current version: a
    /// delta (pending ops + warm dual, empty placeholder measures) when
    /// a resident copy exists, a full snapshot (supports + the exact
    /// feature map) otherwise. A failed delta — worker death, residency
    /// eviction, version skew — is answered with one snapshot retry
    /// (`service.session.snapshot_retries`); the solve itself is
    /// [`crate::session::solve_support`] on the worker, so results are
    /// bitwise the local path's.
    fn session_query_sharded(
        &self,
        shard: &crate::shard::ShardCoordinator,
        e: &mut SessionEntry,
        id: u64,
    ) -> Result<QueryReport> {
        let version = e.session.version();
        let warm = e.session.warm_dual();
        let (mu, nu) = e.session.state().snapshot();
        let map = e.session.state().map().clone();
        let rank = e.session.config().rank;
        let skcfg = e.session.config().sinkhorn.clone();
        // The worker consults only the plan's solver config, but a plan
        // is always built against real measures — use the snapshot.
        let plan = OtProblem::new(&mu, &nu)
            .config(&skcfg)
            .backend(BackendPref::Factored { rank })
            .with_feature_map(&map)
            .stabilized_factors(true)
            .plan()?;
        let mut solved = None;
        if let Some((widx, resident_version)) = e.resident {
            let delta = SessionDelta {
                session_id: id,
                base_version: resident_version,
                version,
                snapshot: false,
                ops: e.pending.clone(),
                warm_alpha: warm.clone(),
            };
            // Delta frames carry no support data: dim-0 placeholder
            // measures and no map (the resident state owns both).
            let empty_mu = Measure { points: Mat::from_vec(0, 0, Vec::new()), weights: Vec::new() };
            let empty_nu = Measure { points: Mat::from_vec(0, 0, Vec::new()), weights: Vec::new() };
            match shard.solve_session(&plan, &empty_mu, &empty_nu, None, delta, Some(widx)) {
                Ok(out) => solved = Some(out),
                Err(_) => {
                    self.metrics.counter("service.session.snapshot_retries").inc();
                }
            }
        }
        let (out, widx) = match solved {
            Some(s) => s,
            None => {
                let delta = SessionDelta {
                    session_id: id,
                    base_version: version,
                    version,
                    snapshot: true,
                    ops: Vec::new(),
                    warm_alpha: warm,
                };
                shard.solve_session(&plan, &mu, &nu, Some(map.as_ref()), delta, None)?
            }
        };
        e.resident = Some((widx, version));
        e.pending.clear();
        e.session.install_result(out.alpha, out.iterations, out.warm_started);
        self.metrics.counter("service.session.sharded_queries").inc();
        Ok(QueryReport {
            objective: out.objective,
            iterations: out.iterations,
            marginal_error: out.marginal_error,
            converged: out.converged,
            warm_started: out.warm_started,
            escalated: out.escalated,
            n: mu.len(),
            m: nu.len(),
            version,
        })
    }
}

/// The running service: batcher thread + worker pool.
pub struct Service {
    /// The service's own handle clone; dropped at shutdown so the request
    /// channel disconnects once all client handles are gone too.
    handle: Option<ServiceHandle>,
    shutdown: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
    /// Shard tier, when `shard_workers > 0` or a `shard_addrs` roster is
    /// configured. Held so the shard workers outlive the service workers,
    /// get drained gracefully at [`Service::shutdown`], and are joined
    /// when the last `Arc` drops.
    shard: Option<Arc<crate::shard::ShardCoordinator>>,
    /// Budget for the graceful shard drain at shutdown.
    shard_drain: std::time::Duration,
}

impl Service {
    /// Start the service with the given configuration. Fails typed when
    /// a configured shard roster cannot be dialled and handshaken — a
    /// fleet that is wrong at startup (unreachable, version-mismatched)
    /// should fail fast, not limp.
    pub fn start(cfg: ServiceConfig) -> Result<Service> {
        let metrics = Arc::new(Registry::default());
        let (req_tx, req_rx) = sync_channel::<Request>(cfg.batcher.queue_depth);
        let (batch_tx, batch_rx) = sync_channel::<Batch>(cfg.workers * 2);
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let shutdown = Arc::new(AtomicBool::new(false));

        let mut threads = Vec::new();

        // Batcher thread.
        {
            let policy = BatcherPolicy {
                max_batch: cfg.batcher.max_batch,
                max_delay_us: cfg.batcher.max_delay_us,
            };
            let metrics = metrics.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("ls-batcher".into())
                    .spawn(move || batcher::run(req_rx, batch_tx, policy, metrics))
                    .expect("spawn batcher"),
            );
        }

        // Shared feature-map cache (one per service, all workers), and
        // its Nyström sibling: selected landmark index sets keyed by
        // `(dim, eps, rank, seed, support fingerprint)` so hot groups
        // under a `nystrom*` backend skip re-selection
        // (`service.landmark_cache.*`).
        let cache = Arc::new(FeatureCache::new(cfg.cache_capacity));
        let landmarks = Arc::new(LandmarkCache::new(cfg.cache_capacity));

        // Optional shard tier: one coordinator shared by every service
        // worker. A non-empty roster of cross-host TCP workers takes
        // precedence (each entry dialled + version-handshaken up front);
        // otherwise `shard_workers` in-process executors spawn behind it.
        let shard = if !cfg.shard_addrs.is_empty() {
            Some(Arc::new(crate::shard::ShardCoordinator::connect(
                &cfg.shard_addrs,
                cfg.shard.to_shard_config(),
                metrics.clone(),
            )?))
        } else if cfg.shard_workers > 0 {
            Some(Arc::new(crate::shard::ShardCoordinator::in_process(
                cfg.shard_workers,
                cfg.shard.to_shard_config(),
                metrics.clone(),
            )))
        } else {
            None
        };

        // Worker pool.
        for w in 0..cfg.workers.max(1) {
            let rx = batch_rx.clone();
            let metrics = metrics.clone();
            let cfg = cfg.clone();
            let cache = cache.clone();
            let landmarks = landmarks.clone();
            let shard = shard.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("ls-worker-{w}"))
                    .spawn(move || worker_loop(w as u64, rx, cfg, metrics, cache, landmarks, shard))
                    .expect("spawn worker"),
            );
        }

        let handle = ServiceHandle {
            tx: req_tx,
            next_id: Arc::new(AtomicU64::new(0)),
            metrics,
            sessions: Arc::new(SessionTable::new(cfg.session_capacity)),
            shard: shard.clone(),
            cfg: Arc::new(cfg.clone()),
        };
        Ok(Service {
            handle: Some(handle),
            shutdown,
            threads,
            shard,
            shard_drain: std::time::Duration::from_millis(cfg.shard.drain_deadline_ms),
        })
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.as_ref().expect("service not shut down").clone()
    }

    /// Graceful shutdown: close the intake, drain, join all threads.
    ///
    /// The request channel disconnects once the service's own handle AND
    /// every client clone are dropped — callers should drop their handles
    /// before (or concurrently with) this call, or the join blocks until
    /// they do. The batcher drains pending work before exiting, and the
    /// workers exit when the batch channel closes behind it.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        drop(self.handle.take());
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        // With every service worker joined there are no in-flight shard
        // groups left, so a graceful drain (best-effort within the
        // `service.shard.drain_deadline_ms` budget) just tells shard
        // workers to exit cleanly instead of yanking their links.
        if let Some(shard) = self.shard.take() {
            let _ = shard.drain(self.shard_drain);
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }
}

/// Worker: pull batches, solve each request, reply.
fn worker_loop(
    worker_id: u64,
    rx: Arc<Mutex<Receiver<Batch>>>,
    cfg: ServiceConfig,
    metrics: Arc<Registry>,
    cache: Arc<FeatureCache>,
    landmarks: Arc<LandmarkCache>,
    shard: Option<Arc<crate::shard::ShardCoordinator>>,
) {
    let mut rng = Rng::seed_from(0xC0FFEE ^ worker_id);
    // Persistent pools, one pair per worker thread for its whole
    // lifetime: the intra-solve pool row-chunks each request's matvecs
    // and feature evaluation, the solve pool runs the three transport
    // problems concurrently. Constructed once — the channel-fed pool
    // keeps its threads alive across requests.
    let solver_pool = Pool::new(cfg.solver_threads);
    let solve_pool = Pool::new_capped(cfg.sinkhorn.threads, 3);
    loop {
        let batch = {
            let guard = rx.lock().unwrap();
            match guard.recv() {
                Ok(b) => b,
                Err(_) => return, // batcher gone -> shut down
            }
        };
        let bsize = batch.requests.len();
        metrics.histogram("service.batch_size").observe_us(bsize as u64);
        // The anchor draw is amortised through the shared feature-map
        // cache: requests with the same (dim, eps, r) reuse one Lemma-1
        // anchor set, within a batch and across batches/workers alike.
        // Requests that additionally share identical support points fuse
        // onto one batched multi-pair solve (bitwise identical to solving
        // them one by one — see `batcher::fuse_groups`).
        let groups = batcher::fuse_groups(batch.requests, cfg.sinkhorn.max_batch);
        for group in groups {
            // Width histogram: `n`, `mean` and `max` are exact; the
            // quantile estimates are log-bucketed (built for latencies)
            // and overshoot small integers — read the mean/max fields
            // when tuning `sinkhorn.max_batch`.
            metrics.histogram("service.batch_width").observe_us(group.len() as u64);
            let results = if let Some(shard) = shard.as_deref() {
                if group.len() > 1 {
                    metrics.counter("service.batched_solves").add(group.len() as u64);
                }
                solve_group_sharded(shard, &group, &cfg, &mut rng, bsize, &cache, &metrics)
            } else if group.len() == 1 {
                vec![solve_one(
                    &group[0],
                    &cfg,
                    &mut rng,
                    bsize,
                    &cache,
                    &landmarks,
                    &metrics,
                    &solver_pool,
                    &solve_pool,
                )]
            } else {
                metrics.counter("service.batched_solves").add(group.len() as u64);
                solve_group(
                    &group,
                    &cfg,
                    &mut rng,
                    bsize,
                    &cache,
                    &landmarks,
                    &metrics,
                    &solver_pool,
                    &solve_pool,
                )
            };
            for (req, result) in group.iter().zip(results) {
                // Record metrics BEFORE replying: a client that checks the
                // registry right after `wait()` must see its own request.
                metrics.counter("service.completed").inc();
                metrics
                    .histogram("service.latency_us")
                    .observe_us(req.enqueued.elapsed().as_micros() as u64);
                let _ = req.reply.send(result); // client may have gone away
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn solve_one(
    req: &Request,
    cfg: &ServiceConfig,
    rng: &mut Rng,
    batch_size: usize,
    cache: &FeatureCache,
    landmarks: &LandmarkCache,
    metrics: &Registry,
    solver_pool: &Pool,
    solve_pool: &Pool,
) -> Result<Response> {
    let mut skcfg = cfg.sinkhorn.clone();
    if let Some(e) = req.epsilon {
        skcfg.epsilon = e;
    }
    let eps = skcfg.epsilon;
    let backend = BackendPref::parse_flag(&cfg.backend, cfg.num_features)?;
    // One planned divergence = the three concurrent transport solves the
    // worker used to hand-wire: the worker's persistent pools and
    // log-domain escalation per `sinkhorn.stabilize` (absorbed by
    // `.config`). Under the default factored backend the cached feature
    // map is shared across all three kernels with stabilised factors
    // (arbitrary client data must not underflow f32) and execution is
    // bitwise identical to the pre-API worker path; other `--backend`
    // choices skip the map — the dense and Nyström kernels build from
    // the measures themselves (Nyström deterministically from the plan
    // seed).
    let radius = req.mu.radius().max(req.nu.radius());
    let map = matches!(backend, BackendPref::Factored { .. } | BackendPref::Auto)
        .then(|| cache.get_or_fit(req.mu.dim(), eps, cfg.num_features, radius, rng, Some(metrics)));
    let mut problem = OtProblem::new(&req.mu, &req.nu)
        .config(&skcfg)
        .backend(backend)
        .pools(solver_pool.clone(), solve_pool.clone())
        .landmark_cache(landmarks)
        .metrics(metrics);
    if let Some(map) = map.as_ref() {
        problem = problem.with_feature_map(map).stabilized_factors(true);
    }
    let report = problem.divergence()?;
    let stabilized = report.escalations() as u64;
    if stabilized > 0 {
        metrics.counter("service.stabilized_solves").add(stabilized);
    }
    metrics.counter("service.sinkhorn.iterations").add(report.total_iterations() as u64);
    Ok(Response {
        id: req.id,
        divergence: report.divergence,
        w_xy: report.w_xy(),
        iterations: report.iterations(),
        latency_us: req.enqueued.elapsed().as_micros() as u64,
        batch_size,
    })
}

/// Solve a fuse group (≥ 2 requests on identical supports and the same
/// epsilon) as three batched multi-pair solves — one per transport
/// problem, each of width `group.len()` — sharing one kernel triple
/// built from the group's common support. Per request, the result is
/// bitwise identical to [`solve_one`]: the batched solver's
/// sequential-equivalence contract plus the same cached feature map and
/// the same kernel construction (`rust/tests/batched_equivalence.rs`
/// covers the solver; `fused_group_matches_solo_request_bitwise` below
/// covers this end to end).
#[allow(clippy::too_many_arguments)]
fn solve_group(
    group: &[Request],
    cfg: &ServiceConfig,
    rng: &mut Rng,
    batch_size: usize,
    cache: &FeatureCache,
    landmarks: &LandmarkCache,
    metrics: &Registry,
    solver_pool: &Pool,
    solve_pool: &Pool,
) -> Vec<Result<Response>> {
    let rep = &group[0];
    let mut skcfg = cfg.sinkhorn.clone();
    if let Some(e) = rep.epsilon {
        skcfg.epsilon = e;
    }
    let eps = skcfg.epsilon;
    let backend = match BackendPref::parse_flag(&cfg.backend, cfg.num_features) {
        Ok(b) => b,
        Err(e) => {
            let msg = e.to_string();
            return group.iter().map(|_| Err(Error::Config(msg.clone()))).collect();
        }
    };
    // All group members share rep's support, hence also its radius.
    let radius = rep.mu.radius().max(rep.nu.radius());
    let map = matches!(backend, BackendPref::Factored { .. } | BackendPref::Auto)
        .then(|| cache.get_or_fit(rep.mu.dim(), eps, cfg.num_features, radius, rng, Some(metrics)));
    // One planned B-pair divergence = three width-B batched solves on a
    // shared kernel triple, concurrent over the solve pool — the fused
    // path the worker used to hand-wire, bitwise identical per request
    // to `solve_one` (fuse_groups caps B at `sinkhorn.max_batch`, so the
    // plan's fuse width covers the whole group in one chunk).
    let pairs: Vec<(&[f32], &[f32])> =
        group.iter().map(|r| (r.mu.weights.as_slice(), r.nu.weights.as_slice())).collect();
    let mut problem = OtProblem::new(&rep.mu, &rep.nu)
        .config(&skcfg)
        .backend(backend)
        .pools(solver_pool.clone(), solve_pool.clone())
        .landmark_cache(landmarks)
        .metrics(metrics)
        .weight_pairs(&pairs);
    if let Some(map) = map.as_ref() {
        problem = problem.with_feature_map(map).stabilized_factors(true);
    }
    let reports = problem.divergence_all();
    group
        .iter()
        .zip(reports)
        .map(|(req, report)| {
            let report = report?;
            let stabilized = report.escalations() as u64;
            if stabilized > 0 {
                metrics.counter("service.stabilized_solves").add(stabilized);
            }
            metrics
                .counter("service.sinkhorn.iterations")
                .add(report.total_iterations() as u64);
            Ok(Response {
                id: req.id,
                divergence: report.divergence,
                w_xy: report.w_xy(),
                iterations: report.iterations(),
                latency_us: req.enqueued.elapsed().as_micros() as u64,
                batch_size,
            })
        })
        .collect()
}

/// Delegate a fuse group (any width, including 1) through the shard
/// tier. The feature map is resolved from the service cache exactly as
/// the in-process paths do — same RNG stream, same cache key — and ships
/// with the task, so the shard workers solve with the identical anchors
/// and the gathered reports are bitwise the in-process fused solve's
/// (see `crate::shard::coordinator` for the argument and
/// `rust/tests/shard_fault_injection.rs` for the proof under faults).
#[allow(clippy::too_many_arguments)]
fn solve_group_sharded(
    shard: &crate::shard::ShardCoordinator,
    group: &[Request],
    cfg: &ServiceConfig,
    rng: &mut Rng,
    batch_size: usize,
    cache: &FeatureCache,
    metrics: &Registry,
) -> Vec<Result<Response>> {
    let rep = &group[0];
    let mut skcfg = cfg.sinkhorn.clone();
    if let Some(e) = rep.epsilon {
        skcfg.epsilon = e;
    }
    let eps = skcfg.epsilon;
    let backend = match BackendPref::parse_flag(&cfg.backend, cfg.num_features) {
        Ok(b) => b,
        Err(e) => {
            let msg = e.to_string();
            return group.iter().map(|_| Err(Error::Config(msg.clone()))).collect();
        }
    };
    let radius = rep.mu.radius().max(rep.nu.radius());
    // Only factored plans ship the cache-resolved map with the task;
    // a Nyström plan needs no artifact at all — its landmark draw is a
    // pure function of the plan seed, so the shard worker rebuilds the
    // bit-identical kernel from the plan alone.
    let map = matches!(backend, BackendPref::Factored { .. } | BackendPref::Auto)
        .then(|| cache.get_or_fit(rep.mu.dim(), eps, cfg.num_features, radius, rng, Some(metrics)));
    let pairs: Vec<(&[f32], &[f32])> =
        group.iter().map(|r| (r.mu.weights.as_slice(), r.nu.weights.as_slice())).collect();
    let ids: Vec<u64> = group.iter().map(|r| r.id).collect();
    let mut problem = OtProblem::new(&rep.mu, &rep.nu)
        .config(&skcfg)
        .backend(backend)
        .solver_threads(cfg.solver_threads)
        .weight_pairs(&pairs);
    if let Some(map) = map.as_ref() {
        problem = problem.with_feature_map(map).stabilized_factors(true);
    }
    let plan = match problem.plan() {
        Ok(p) => p,
        Err(e) => {
            let msg = e.to_string();
            return group.iter().map(|_| Err(Error::Config(msg.clone()))).collect();
        }
    };
    metrics.counter("service.shard.delegated_groups").inc();
    let reports = shard.solve_group(&plan, &rep.mu, &rep.nu, &pairs, map.as_deref(), &ids);
    group
        .iter()
        .zip(reports)
        .map(|(req, report)| {
            let report = report?;
            let stabilized = report.escalations() as u64;
            if stabilized > 0 {
                metrics.counter("service.stabilized_solves").add(stabilized);
            }
            metrics
                .counter("service.sinkhorn.iterations")
                .add(report.total_iterations() as u64);
            Ok(Response {
                id: req.id,
                divergence: report.divergence,
                w_xy: report.w_xy(),
                iterations: report.iterations(),
                latency_us: req.enqueued.elapsed().as_micros() as u64,
                batch_size,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BatcherConfig, ShardSettings, SinkhornConfig};
    use crate::data;

    fn test_cfg(workers: usize) -> ServiceConfig {
        ServiceConfig {
            workers,
            batcher: BatcherConfig { max_batch: 4, max_delay_us: 200, queue_depth: 64 },
            sinkhorn: SinkhornConfig {
                epsilon: 0.5,
                max_iters: 300,
                tol: 1e-4,
                check_every: 10,
                threads: 1,
                stabilize: true,
                max_batch: 8,
                anneal: None,
                anneal_decay: 0.5,
                symmetric: None,
            },
            num_features: 128,
            solver_threads: 1,
            cache_capacity: 8,
            shard_workers: 0,
            shard_addrs: Vec::new(),
            shard: ShardSettings::default(),
            backend: "factored".to_string(),
            session_capacity: 4,
        }
    }

    fn clouds(seed: u64, n: usize) -> (Measure, Measure) {
        let mut rng = Rng::seed_from(seed);
        data::gaussian_blobs(n, &mut rng)
    }

    #[test]
    fn single_request_roundtrip() {
        let svc = Service::start(test_cfg(2)).unwrap();
        let h = svc.handle();
        let (mu, nu) = clouds(0, 60);
        let resp = h.divergence(mu, nu).unwrap();
        assert!(resp.divergence.is_finite());
        assert!(resp.divergence > 0.0, "separated blobs have positive divergence");
        assert!(resp.iterations > 0);
        drop(h);
        svc.shutdown();
    }

    #[test]
    fn identical_measures_near_zero() {
        let svc = Service::start(test_cfg(1)).unwrap();
        let h = svc.handle();
        let (mu, _) = clouds(1, 40);
        let resp = h.divergence(mu.clone(), mu).unwrap();
        assert!(resp.divergence.abs() < 1e-4, "divergence {}", resp.divergence);
        drop(h);
        svc.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_complete() {
        let svc = Service::start(test_cfg(4)).unwrap();
        let h = svc.handle();
        let mut pendings = Vec::new();
        for i in 0..16 {
            let (mu, nu) = clouds(i, 30);
            pendings.push((i, h.submit(mu, nu).unwrap()));
        }
        for (i, p) in pendings {
            let r = p.wait().unwrap_or_else(|e| panic!("request {i}: {e}"));
            assert!(r.divergence.is_finite());
        }
        let m = h.metrics_text();
        assert!(m.contains("service.completed = 16"), "{m}");
        drop(h);
        svc.shutdown();
    }

    #[test]
    fn dim_mismatch_rejected_at_submit() {
        let svc = Service::start(test_cfg(1)).unwrap();
        let h = svc.handle();
        let (mu, _) = clouds(3, 10);
        let mut rng = Rng::seed_from(4);
        let nu3d = data::gaussian_cloud(10, 3, 0.0, 1.0, &mut rng);
        assert!(matches!(h.submit(mu, nu3d), Err(Error::Shape(_))));
        drop(h);
        svc.shutdown();
    }

    #[test]
    fn queue_overflow_sheds() {
        // 1 worker, tiny queue, slow-ish requests: the tail must shed.
        let cfg = ServiceConfig {
            workers: 1,
            batcher: BatcherConfig { max_batch: 1, max_delay_us: 10, queue_depth: 2 },
            sinkhorn: SinkhornConfig {
                epsilon: 0.5,
                max_iters: 2000,
                tol: 0.0,
                check_every: 100,
                threads: 1,
                stabilize: true,
                max_batch: 8,
                anneal: None,
                anneal_decay: 0.5,
                symmetric: None,
            },
            num_features: 256,
            solver_threads: 1,
            cache_capacity: 8,
            shard_workers: 0,
            shard_addrs: Vec::new(),
            shard: ShardSettings::default(),
            backend: "factored".to_string(),
            session_capacity: 4,
        };
        let svc = Service::start(cfg).unwrap();
        let h = svc.handle();
        let mut accepted = 0;
        let mut shed = 0;
        let mut pendings = Vec::new();
        for i in 0..40 {
            let (mu, nu) = clouds(i, 200);
            match h.submit(mu, nu) {
                Ok(p) => {
                    accepted += 1;
                    pendings.push(p);
                }
                Err(Error::Overloaded(_)) => shed += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(shed > 0, "expected some load shedding (accepted {accepted})");
        for p in pendings {
            let _ = p.wait();
        }
        drop(h);
        svc.shutdown();
    }

    #[test]
    fn feature_cache_hits_across_requests() {
        // Same (dim, eps, r) and same data => first request fits, the
        // rest reuse the cached map; counters are exported via metrics.
        let svc = Service::start(test_cfg(2)).unwrap();
        let h = svc.handle();
        let (mu, nu) = clouds(0, 40);
        for _ in 0..5 {
            h.divergence(mu.clone(), nu.clone()).unwrap();
        }
        let m = h.metrics_text();
        assert!(m.contains("service.feature_cache.misses = 1"), "{m}");
        assert!(m.contains("service.feature_cache.hits = 4"), "{m}");
        assert!(m.contains("service.sinkhorn.iterations = "), "{m}");
        drop(h);
        svc.shutdown();
    }

    #[test]
    fn cache_disabled_still_serves() {
        let mut cfg = test_cfg(1);
        cfg.cache_capacity = 0;
        let svc = Service::start(cfg).unwrap();
        let h = svc.handle();
        let (mu, nu) = clouds(2, 30);
        for _ in 0..3 {
            h.divergence(mu.clone(), nu.clone()).unwrap();
        }
        let m = h.metrics_text();
        assert!(m.contains("service.feature_cache.misses = 3"), "{m}");
        assert!(!m.contains("service.feature_cache.hits"), "{m}");
        drop(h);
        svc.shutdown();
    }

    #[test]
    fn solver_threads_do_not_change_results() {
        // The intra-solve pool is numerically transparent: the same
        // request solved with 1 and 4 solver threads returns the same
        // divergence bit for bit. n = 700 crosses the pooled-matvec and
        // parallel-feature thresholds so threads = 4 really runs the
        // chunked paths (the multi-chunk transpose is covered by
        // rust/tests/parallel_equivalence.rs at n = 1500).
        let solve = |threads: usize| {
            let mut cfg = test_cfg(1);
            cfg.solver_threads = threads;
            cfg.sinkhorn.max_iters = 60;
            let svc = Service::start(cfg).unwrap();
            let h = svc.handle();
            let (mu, nu) = clouds(7, 700);
            let d = h.divergence(mu, nu).unwrap().divergence;
            drop(h);
            svc.shutdown();
            d
        };
        let d1 = solve(1);
        let d4 = solve(4);
        assert_eq!(d1.to_bits(), d4.to_bits(), "{d1} vs {d4}");
    }

    #[test]
    fn tiny_eps_request_still_produces_a_finite_answer() {
        // A per-request epsilon orders of magnitude below the service
        // default. The stabilised factors handle most of the range on
        // their own; if the plain solve ever reports non-finite scalings
        // the worker escalates to the log-domain path
        // (`service.stabilized_solves`). Either way the production
        // guarantee under test is: any positive eps yields a finite
        // divergence, never a NaN and never a panic.
        let mut cfg = test_cfg(1);
        cfg.sinkhorn.max_iters = 500;
        cfg.num_features = 32;
        let svc = Service::start(cfg).unwrap();
        let h = svc.handle();
        for eps in [1e-2, 1e-3] {
            let (mu, nu) = clouds(9, 30);
            let resp = h.submit_with(mu, nu, Some(eps)).unwrap().wait().unwrap();
            assert!(resp.divergence.is_finite(), "eps={eps}: {}", resp.divergence);
        }
        drop(h);
        svc.shutdown();
    }

    #[test]
    fn fused_group_matches_solo_request_bitwise() {
        // The acceptance property of the batched engine at the service
        // level: a request solved inside a fused group returns exactly
        // the bits a solo solve of the same request returns.
        let mut cfg = test_cfg(1);
        // Size-triggered flush at 4 with a generous deadline, so the
        // burst below reliably lands in one batch (and one fuse group —
        // the four requests share their clouds).
        cfg.batcher = BatcherConfig { max_batch: 4, max_delay_us: 500_000, queue_depth: 64 };
        let svc = Service::start(cfg).unwrap();
        let h = svc.handle();
        let (mu, nu) = clouds(11, 50);
        let solo = h.divergence(mu.clone(), nu.clone()).unwrap().divergence;
        let pendings: Vec<_> =
            (0..4).map(|_| h.submit(mu.clone(), nu.clone()).unwrap()).collect();
        for p in pendings {
            let resp = p.wait().unwrap();
            assert_eq!(
                resp.divergence.to_bits(),
                solo.to_bits(),
                "fused {} vs solo {solo}",
                resp.divergence
            );
        }
        let m = h.metrics_text();
        assert!(m.contains("service.batched_solves"), "no fused solve happened:\n{m}");
        assert!(m.contains("service.batch_width"), "{m}");
        drop(h);
        svc.shutdown();
    }

    #[test]
    fn max_batch_one_disables_fusion() {
        let mut cfg = test_cfg(1);
        cfg.sinkhorn.max_batch = 1;
        cfg.batcher = BatcherConfig { max_batch: 4, max_delay_us: 500_000, queue_depth: 64 };
        let svc = Service::start(cfg).unwrap();
        let h = svc.handle();
        let (mu, nu) = clouds(12, 30);
        let pendings: Vec<_> =
            (0..4).map(|_| h.submit(mu.clone(), nu.clone()).unwrap()).collect();
        for p in pendings {
            p.wait().unwrap();
        }
        let m = h.metrics_text();
        assert!(!m.contains("service.batched_solves"), "fusion must be off:\n{m}");
        drop(h);
        svc.shutdown();
    }

    #[test]
    fn sharded_service_matches_in_process_bitwise() {
        // The same requests through an in-process service and through a
        // sharded one (2 shard workers) must answer with identical bits.
        // One service worker on both sides pins which worker's RNG
        // stream fits the cache map, making the two runs comparable.
        let run = |shard_workers: usize| {
            let mut cfg = test_cfg(1);
            cfg.shard_workers = shard_workers;
            // Size-triggered flush so the burst fuses into one group on
            // both sides.
            cfg.batcher = BatcherConfig { max_batch: 4, max_delay_us: 500_000, queue_depth: 64 };
            let svc = Service::start(cfg).unwrap();
            let h = svc.handle();
            let (mu, nu) = clouds(21, 40);
            let solo = h.divergence(mu.clone(), nu.clone()).unwrap();
            let pendings: Vec<_> =
                (0..4).map(|_| h.submit(mu.clone(), nu.clone()).unwrap()).collect();
            let mut out = vec![(solo.divergence, solo.w_xy, solo.iterations)];
            for p in pendings {
                let r = p.wait().unwrap();
                out.push((r.divergence, r.w_xy, r.iterations));
            }
            let m = h.metrics_text();
            drop(h);
            svc.shutdown();
            (out, m)
        };
        let (local, _) = run(0);
        let (sharded, metrics) = run(2);
        for (l, s) in local.iter().zip(&sharded) {
            assert_eq!(l.0.to_bits(), s.0.to_bits(), "divergence {l:?} vs {s:?}");
            assert_eq!(l.1.to_bits(), s.1.to_bits(), "w_xy {l:?} vs {s:?}");
            assert_eq!(l.2, s.2, "iterations {l:?} vs {s:?}");
        }
        assert!(metrics.contains("service.shard.delegated_groups = 2"), "{metrics}");
        assert!(metrics.contains("service.shard.gathered_results"), "{metrics}");
    }

    #[test]
    fn session_lifecycle_create_update_query_close() {
        let svc = Service::start(test_cfg(1)).unwrap();
        let h = svc.handle();
        let (mu, nu) = clouds(30, 50);
        let dim = mu.dim();
        let id = h.session_create(mu, nu, None).unwrap();

        // First query is cold; a repeat on the same support warm-starts.
        let cold = h.session_query(id).unwrap();
        assert!(!cold.warm_started);
        assert!(cold.objective.is_finite());
        let warm = h.session_query(id).unwrap();
        assert!(warm.warm_started);

        // An update bumps the version; the next query still warm-starts
        // (the dual survives a single swap by provenance remap).
        let v = h
            .session_update(
                id,
                &[SessionOp::SwapX { index: 0, point: vec![0.25; dim], weight: 0.01 }],
            )
            .unwrap();
        assert!(v > 0);
        let after = h.session_query(id).unwrap();
        assert!(after.warm_started);
        assert_eq!(after.version, v);

        let stats = h.session_stats(id).unwrap();
        assert_eq!(stats.queries, 3);
        assert_eq!(stats.cold_solves, 1);
        assert_eq!(stats.warm_solves, 2);

        let m = h.metrics_text();
        assert!(m.contains("service.session.live = 1"), "{m}");
        assert!(m.contains("service.session.created = 1"), "{m}");
        assert!(m.contains("service.session.queries = 3"), "{m}");
        assert!(m.contains("service.session.warm_solves = 2"), "{m}");
        assert!(m.contains("service.session.cold_solves = 1"), "{m}");

        h.session_close(id).unwrap();
        assert!(matches!(h.session_query(id), Err(Error::Service(_))));
        assert!(matches!(h.session_close(id), Err(Error::Service(_))));
        let m = h.metrics_text();
        assert!(m.contains("service.session.live = 0"), "{m}");
        assert!(m.contains("service.session.closed = 1"), "{m}");
        drop(h);
        svc.shutdown();
    }

    #[test]
    fn session_table_sheds_at_capacity() {
        let mut cfg = test_cfg(1);
        cfg.session_capacity = 2;
        let svc = Service::start(cfg).unwrap();
        let h = svc.handle();
        let (mu, nu) = clouds(31, 20);
        let a = h.session_create(mu.clone(), nu.clone(), None).unwrap();
        let _b = h.session_create(mu.clone(), nu.clone(), None).unwrap();
        assert!(matches!(
            h.session_create(mu.clone(), nu.clone(), None),
            Err(Error::Overloaded(_))
        ));
        // Closing one frees a slot.
        h.session_close(a).unwrap();
        h.session_create(mu, nu, None).unwrap();
        drop(h);
        svc.shutdown();
    }

    #[test]
    fn sharded_session_query_matches_local_bitwise() {
        // The same session driven through an in-process service and a
        // sharded one (2 shard workers) must answer with identical bits
        // on every query: cold, warm after updates (delta replay on the
        // resident copy), and warm after a second update batch.
        let run = |shard_workers: usize| {
            let mut cfg = test_cfg(1);
            cfg.shard_workers = shard_workers;
            let svc = Service::start(cfg).unwrap();
            let h = svc.handle();
            let (mu, nu) = clouds(32, 40);
            let dim = mu.dim();
            let id = h.session_create(mu, nu, None).unwrap();
            let mut out = Vec::new();
            let q = h.session_query(id).unwrap();
            out.push((q.objective, q.iterations, q.warm_started));
            h.session_update(
                id,
                &[
                    SessionOp::SwapX { index: 1, point: vec![0.5; dim], weight: 0.02 },
                    SessionOp::InsertY { point: vec![-0.5; dim], weight: 0.01 },
                ],
            )
            .unwrap();
            let q = h.session_query(id).unwrap();
            out.push((q.objective, q.iterations, q.warm_started));
            h.session_update(id, &[SessionOp::EvictY { index: 0 }]).unwrap();
            let q = h.session_query(id).unwrap();
            out.push((q.objective, q.iterations, q.warm_started));
            let m = h.metrics_text();
            h.session_close(id).unwrap();
            drop(h);
            svc.shutdown();
            (out, m)
        };
        let (local, _) = run(0);
        let (sharded, metrics) = run(2);
        for (l, s) in local.iter().zip(&sharded) {
            assert_eq!(l.0.to_bits(), s.0.to_bits(), "objective {l:?} vs {s:?}");
            assert_eq!(l.1, s.1, "iterations {l:?} vs {s:?}");
            assert_eq!(l.2, s.2, "warm flag {l:?} vs {s:?}");
        }
        assert!(metrics.contains("service.session.sharded_queries = 3"), "{metrics}");
        // Queries 2 and 3 rode the resident copy — no snapshot retries.
        assert!(!metrics.contains("service.session.snapshot_retries"), "{metrics}");
    }

    #[test]
    fn batching_groups_requests() {
        // Submit a burst, then check the batch-size histogram saw > 1.
        let svc = Service::start(test_cfg(1)).unwrap();
        let h = svc.handle();
        let mut pendings = Vec::new();
        for i in 0..8 {
            let (mu, nu) = clouds(100 + i, 30);
            pendings.push(h.submit(mu, nu).unwrap());
        }
        for p in pendings {
            p.wait().unwrap();
        }
        let m = h.metrics_text();
        assert!(m.contains("service.batch_size"), "{m}");
        drop(h);
        svc.shutdown();
    }
}
