//! Workload generators — the datasets behind every figure in the paper.
//!
//! * [`gaussian_blobs`] — Fig. 1's two 2-D normal clouds.
//! * [`sphere_caps`] — Fig. 2/3's two uniform distributions on S².
//! * [`higgs_like`] — Fig. 5's 28-dim two-class HIGGS substitute: a
//!   synthetic mixture with the dataset's dimensionality and class
//!   structure — the tradeoff figures only need the workload *shape*
//!   (dimension, overlap), not the physics (see README.md §Pointer map
//!   for where each experiment is recorded).
//! * [`image_corpus`] / [`noise_images`] — Table 1 / Fig. 4's CIFAR/noise
//!   substitute: structured synthetic 32×32 grayscale images.
//! * [`corner_histograms`] — Fig. 6's three blurred-corner histograms on a
//!   discretised positive sphere.

use crate::linalg::Mat;
use crate::rng::Rng;

/// A discrete measure: support points (rows of `points`) with weights
/// summing to one.
#[derive(Clone, Debug)]
pub struct Measure {
    pub points: Mat,
    pub weights: Vec<f32>,
}

impl Measure {
    /// Uniform weights over the given points.
    pub fn uniform(points: Mat) -> Self {
        let n = points.rows();
        Measure { points, weights: vec![1.0 / n as f32; n] }
    }

    pub fn len(&self) -> usize {
        self.points.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.points.rows() == 0
    }

    pub fn dim(&self) -> usize {
        self.points.cols()
    }

    /// Largest support-point norm — the Lemma-1 radius R for this measure.
    pub fn radius(&self) -> f64 {
        let mut r2: f64 = 0.0;
        for i in 0..self.len() {
            let n2: f64 = self.points.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum();
            r2 = r2.max(n2);
        }
        r2.sqrt()
    }
}

/// Fig. 1 workload: N((1,1), I2) vs N(0, 0.1*I2), n samples each.
pub fn gaussian_blobs(n: usize, rng: &mut Rng) -> (Measure, Measure) {
    let mu = Mat::from_fn(n, 2, |_, _| rng.normal_scaled(1.0, 1.0) as f32);
    let nu = Mat::from_fn(n, 2, |_, _| rng.normal_scaled(0.0, 0.1f64.sqrt()) as f32);
    (Measure::uniform(mu), Measure::uniform(nu))
}

/// General isotropic Gaussian cloud.
pub fn gaussian_cloud(n: usize, dim: usize, mean: f32, std: f32, rng: &mut Rng) -> Measure {
    let pts = Mat::from_fn(n, dim, |_, _| rng.normal_scaled(mean as f64, std as f64) as f32);
    Measure::uniform(pts)
}

/// Fig. 2/3 workload: two disjoint uniform caps on the unit sphere S².
///
/// The red/blue point sets in the paper are two bands of the sphere; we
/// sample uniformly on S² and keep points by z-coordinate window, which
/// reproduces the same "two separated supports on a manifold" structure.
pub fn sphere_caps(n: usize, rng: &mut Rng) -> (Measure, Measure) {
    let cap = |rng: &mut Rng, zlo: f64, zhi: f64, n: usize| {
        let mut rows = Vec::with_capacity(n);
        while rows.len() < n {
            let p = rng.unit_sphere(3);
            let z = p[2] as f64;
            if z >= zlo && z < zhi {
                rows.push(p);
            }
        }
        Measure::uniform(Mat::from_rows(&rows))
    };
    let a = cap(rng, 0.3, 0.95, n); // northern band
    let b = cap(rng, -0.95, -0.3, n); // southern band
    (a, b)
}

/// Fig. 5 substitute: 28-dim two-class HIGGS-like synthetic data.
///
/// 21 "low-level kinematics": correlated features built from per-class
/// latent factors with log-normal magnitudes (jet pT/energy-like,
/// heavy-tailed, positive) and Gaussian angles; 7 "high-level" features:
/// quadratic combinations of the low-level ones (invariant-mass-like).
/// The signal class shifts the latent means — two overlapping but
/// separable 28-dim clouds, which is all Fig. 5's tradeoff depends on.
pub fn higgs_like(n: usize, signal: bool, rng: &mut Rng) -> Measure {
    let shift = if signal { 0.5 } else { 0.0 };
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        let mut row = Vec::with_capacity(28);
        // 3 latent factors per event.
        let f0 = rng.normal() + shift;
        let f1 = rng.normal() + 0.5 * shift;
        let f2 = rng.normal();
        // 14 magnitude-like features: log-normal with factor loading.
        for k in 0..14 {
            let load = match k % 3 {
                0 => f0,
                1 => f1,
                _ => f2,
            };
            let v = (0.4 * load + 0.3 * rng.normal()).exp();
            row.push(v as f32);
        }
        // 7 angle-like features: Gaussian, weakly loaded.
        for k in 0..7 {
            let load = if k % 2 == 0 { f1 } else { f2 };
            row.push((0.3 * load + rng.normal()) as f32);
        }
        // 7 derived quadratic features (invariant-mass-like).
        for k in 0..7 {
            let a = row[k] as f64;
            let b = row[(k + 7) % 14] as f64;
            let c = row[14 + k % 7] as f64;
            row.push(((a * b).sqrt().max(0.0) + 0.1 * c * c) as f32);
        }
        rows.push(row);
    }
    // NOTE: raw (unstandardised) output. Standardising per class would
    // erase the class-conditional shift; use [`higgs_pair`] to get the two
    // classes standardised with *pooled* statistics, as one would with the
    // real HIGGS table.
    Measure::uniform(Mat::from_rows(&rows))
}

/// Fig. 5 workload: (signal, background) Higgs-like clouds standardised
/// jointly (pooled mean/variance, like preprocessing one HIGGS csv).
pub fn higgs_pair(n: usize, rng: &mut Rng) -> (Measure, Measure) {
    let sig = higgs_like(n, true, rng);
    let bkg = higgs_like(n, false, rng);
    let d = sig.dim();
    // Pool, standardise, split.
    let mut pooled = Mat::zeros(2 * n, d);
    for i in 0..n {
        pooled.row_mut(i).copy_from_slice(sig.points.row(i));
        pooled.row_mut(n + i).copy_from_slice(bkg.points.row(i));
    }
    standardize(&mut pooled);
    let sig_pts = Mat::from_fn(n, d, |i, j| pooled[(i, j)]);
    let bkg_pts = Mat::from_fn(n, d, |i, j| pooled[(n + i, j)]);
    (Measure::uniform(sig_pts), Measure::uniform(bkg_pts))
}

/// Column-standardise in place (zero mean, unit variance per feature) —
/// mirrors the usual preprocessing on HIGGS before computing distances.
pub fn standardize(points: &mut Mat) {
    let (n, d) = points.shape();
    for j in 0..d {
        let mut mean = 0.0f64;
        for i in 0..n {
            mean += points[(i, j)] as f64;
        }
        mean /= n as f64;
        let mut var = 0.0f64;
        for i in 0..n {
            let c = points[(i, j)] as f64 - mean;
            var += c * c;
        }
        var /= n as f64;
        let inv = 1.0 / var.sqrt().max(1e-9);
        for i in 0..n {
            points[(i, j)] = ((points[(i, j)] as f64 - mean) * inv) as f32;
        }
    }
}

/// Table 1 / Fig. 4 substitute: a structured 32×32 grayscale image corpus.
///
/// Each image is a random composition of 2–4 smooth primitives (Gaussian
/// blobs, oriented stripes, gradients) — a low-dimensional "image manifold"
/// that a learned kernel should separate from white noise, which is the
/// property Table 1 measures.
pub fn image_corpus(n: usize, side: usize, rng: &mut Rng) -> Mat {
    let d = side * side;
    let mut out = Mat::zeros(n, d);
    for img in 0..n {
        let n_prims = 2 + rng.uniform_usize(3);
        let row = out.row_mut(img);
        for _ in 0..n_prims {
            let kind = rng.uniform_usize(3);
            match kind {
                0 => {
                    // Gaussian blob.
                    let cx = rng.uniform_in(0.2, 0.8);
                    let cy = rng.uniform_in(0.2, 0.8);
                    let s = rng.uniform_in(0.05, 0.25);
                    let amp = rng.uniform_in(0.3, 1.0);
                    for yy in 0..side {
                        for xx in 0..side {
                            let fx = xx as f64 / side as f64 - cx;
                            let fy = yy as f64 / side as f64 - cy;
                            row[yy * side + xx] +=
                                (amp * (-(fx * fx + fy * fy) / (2.0 * s * s)).exp()) as f32;
                        }
                    }
                }
                1 => {
                    // Oriented sinusoidal stripes.
                    let theta = rng.uniform_in(0.0, std::f64::consts::PI);
                    let freq = rng.uniform_in(2.0, 8.0);
                    let amp = rng.uniform_in(0.1, 0.5);
                    let (c, s) = (theta.cos(), theta.sin());
                    for yy in 0..side {
                        for xx in 0..side {
                            let t = (xx as f64 * c + yy as f64 * s) / side as f64;
                            row[yy * side + xx] +=
                                (amp * (freq * std::f64::consts::TAU * t).sin()) as f32;
                        }
                    }
                }
                _ => {
                    // Linear gradient.
                    let gx = rng.uniform_in(-0.5, 0.5);
                    let gy = rng.uniform_in(-0.5, 0.5);
                    for yy in 0..side {
                        for xx in 0..side {
                            row[yy * side + xx] += (gx * xx as f64 / side as f64
                                + gy * yy as f64 / side as f64)
                                as f32;
                        }
                    }
                }
            }
        }
        // Normalise to [0, 1].
        let mn = row.iter().cloned().fold(f32::INFINITY, f32::min);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let scale = 1.0 / (mx - mn).max(1e-6);
        for v in row.iter_mut() {
            *v = (*v - mn) * scale;
        }
    }
    out
}

/// White-noise images in [0,1], same shape as [`image_corpus`].
pub fn noise_images(n: usize, side: usize, rng: &mut Rng) -> Mat {
    Mat::from_fn(n, side * side, |_, _| rng.uniform() as f32)
}

/// Fig. 6 substrate: the positive octant of S² discretised as a
/// `side x side` grid (spherical coordinates), returned as a `(side², 3)`
/// matrix of unit vectors with strictly positive coordinates.
pub fn positive_sphere_grid(side: usize) -> Mat {
    let mut rows = Vec::with_capacity(side * side);
    for i in 0..side {
        for j in 0..side {
            // theta, phi in the open (0, pi/2) interior so all coords > 0.
            let t = (i as f64 + 0.5) / side as f64 * std::f64::consts::FRAC_PI_2;
            let p = (j as f64 + 0.5) / side as f64 * std::f64::consts::FRAC_PI_2;
            rows.push(vec![
                (t.sin() * p.cos()) as f32,
                (t.sin() * p.sin()) as f32,
                t.cos() as f32,
            ]);
        }
    }
    Mat::from_rows(&rows)
}

/// Fig. 6 inputs: three blurred histograms concentrated near the three
/// "corners" of the positive octant (the x, y and z poles).
pub fn corner_histograms(grid: &Mat, blur: f64) -> [Vec<f32>; 3] {
    let corners: [[f32; 3]; 3] = [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]];
    let mut out: [Vec<f32>; 3] = [vec![], vec![], vec![]];
    for (c, corner) in corners.iter().enumerate() {
        let mut h = Vec::with_capacity(grid.rows());
        for i in 0..grid.rows() {
            let p = grid.row(i);
            let d2: f64 = p
                .iter()
                .zip(corner.iter())
                .map(|(&a, &b)| ((a - b) as f64) * ((a - b) as f64))
                .sum();
            h.push((-d2 / (2.0 * blur * blur)).exp() as f32);
        }
        let z: f64 = h.iter().map(|&x| x as f64).sum();
        for v in &mut h {
            *v = (*v as f64 / z) as f32;
        }
        out[c] = h;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_blobs_shapes_and_weights() {
        let mut rng = Rng::seed_from(0);
        let (a, b) = gaussian_blobs(100, &mut rng);
        assert_eq!(a.len(), 100);
        assert_eq!(a.dim(), 2);
        let s: f32 = a.weights.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        // The two clouds have visibly different means.
        let mean_a: f32 = a.points.data().iter().sum::<f32>() / 200.0;
        let mean_b: f32 = b.points.data().iter().sum::<f32>() / 200.0;
        assert!(mean_a > 0.5 && mean_b.abs() < 0.5);
    }

    #[test]
    fn sphere_caps_on_unit_sphere_and_separated() {
        let mut rng = Rng::seed_from(1);
        let (a, b) = sphere_caps(64, &mut rng);
        for m in [&a, &b] {
            for i in 0..m.len() {
                let n2: f32 = m.points.row(i).iter().map(|x| x * x).sum();
                assert!((n2 - 1.0).abs() < 1e-5);
            }
        }
        // All of a is in the northern band, b southern.
        assert!((0..a.len()).all(|i| a.points[(i, 2)] > 0.0));
        assert!((0..b.len()).all(|i| b.points[(i, 2)] < 0.0));
    }

    #[test]
    fn higgs_pair_shape_and_pooled_standardised() {
        let mut rng = Rng::seed_from(2);
        let (sig, bkg) = higgs_pair(250, &mut rng);
        assert_eq!(sig.dim(), 28);
        assert_eq!(bkg.dim(), 28);
        // Pooled standardisation: every column of the union has ~zero mean
        // and ~unit variance (per-class means may and should differ).
        for j in 0..28 {
            let mut vals: Vec<f64> = sig.points.col_copy(j).iter().map(|&x| x as f64).collect();
            vals.extend(bkg.points.col_copy(j).iter().map(|&x| x as f64));
            let mean: f64 = vals.iter().sum::<f64>() / vals.len() as f64;
            let var: f64 =
                vals.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / vals.len() as f64;
            assert!(mean.abs() < 1e-4, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "col {j} var {var}");
        }
    }

    #[test]
    fn higgs_classes_differ() {
        // The class-conditional shift must survive pooled standardisation:
        // the two class means are separated in feature space.
        let mut rng = Rng::seed_from(3);
        let (sig, bkg) = higgs_pair(600, &mut rng);
        let mean_of = |m: &Measure| -> Vec<f64> {
            (0..m.dim())
                .map(|j| {
                    m.points.col_copy(j).iter().map(|&x| x as f64).sum::<f64>() / m.len() as f64
                })
                .collect()
        };
        let ms = mean_of(&sig);
        let mb = mean_of(&bkg);
        let sep: f64 = ms.iter().zip(&mb).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!(sep > 0.3, "class mean separation {sep} too small");
    }

    #[test]
    fn image_corpus_in_unit_range_and_structured() {
        let mut rng = Rng::seed_from(4);
        let imgs = image_corpus(10, 16, &mut rng);
        assert_eq!(imgs.shape(), (10, 256));
        for &v in imgs.data() {
            assert!((0.0..=1.0).contains(&v));
        }
        // Structured images have strong pixel-to-pixel correlation; noise
        // doesn't. Compare lag-1 autocorrelation.
        let noise = noise_images(10, 16, &mut rng);
        let autocorr = |m: &Mat| {
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for i in 0..m.rows() {
                let r = m.row(i);
                let mean: f64 = r.iter().map(|&x| x as f64).sum::<f64>() / r.len() as f64;
                for w in r.windows(2) {
                    num += (w[0] as f64 - mean) * (w[1] as f64 - mean);
                }
                for &x in r {
                    den += (x as f64 - mean).powi(2);
                }
            }
            num / den
        };
        // Stripes at the highest frequency dent the lag-1 autocorrelation;
        // the separation from white noise is what matters.
        let img_ac = autocorr(&imgs);
        let noise_ac = autocorr(&noise);
        assert!(img_ac > 0.35, "images should be smooth (ac {img_ac})");
        assert!(noise_ac < 0.2, "noise should not be (ac {noise_ac})");
        assert!(img_ac > noise_ac + 0.25, "images vs noise: {img_ac} vs {noise_ac}");
    }

    #[test]
    fn positive_sphere_grid_is_positive_and_unit() {
        let g = positive_sphere_grid(20);
        assert_eq!(g.shape(), (400, 3));
        for i in 0..g.rows() {
            let p = g.row(i);
            assert!(p.iter().all(|&x| x > 0.0), "row {i} not strictly positive");
            let n2: f32 = p.iter().map(|x| x * x).sum();
            assert!((n2 - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn corner_histograms_normalised_and_peaked() {
        let g = positive_sphere_grid(30);
        let hists = corner_histograms(&g, 0.25);
        for h in &hists {
            let s: f64 = h.iter().map(|&x| x as f64).sum();
            assert!((s - 1.0).abs() < 1e-4);
            assert!(h.iter().all(|&x| x >= 0.0));
        }
        // Peak of histogram 2 (z-corner) should be at a grid point with
        // large z coordinate.
        let (argmax, _) = hists[2]
            .iter()
            .enumerate()
            .fold((0, f32::NEG_INFINITY), |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc });
        assert!(g[(argmax, 2)] > 0.9);
    }

    #[test]
    fn measure_radius() {
        let m = Measure::uniform(Mat::from_rows(&[vec![3.0, 4.0], vec![0.0, 1.0]]));
        assert!((m.radius() - 5.0).abs() < 1e-6);
    }
}
