//! Deterministic pseudo-random substrate.
//!
//! The offline crate set has no `rand`, so every stochastic piece of the
//! stack (Lemma-1 anchor draws, workload generators, GAN noise, Nyström
//! column sampling) runs on this module: SplitMix64 seeding into
//! Xoshiro256++, Box–Muller normals, and a few distribution helpers.
//! Everything is reproducible from a single `u64` seed — benches and tests
//! pin seeds so paper-figure regenerations are bit-stable.

/// Xoshiro256++ PRNG seeded via SplitMix64 (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller output.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Deterministic construction from a single seed.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s =
            [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 bits (xoshiro256++ step).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn uniform_usize(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Rejection-free Lemire-style bounded sample.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Standard normal as f32.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// N(mu, sigma^2).
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Uniform point on the unit sphere S^{d-1} (normalised Gaussian).
    pub fn unit_sphere(&mut self, d: usize) -> Vec<f32> {
        loop {
            let v: Vec<f64> = (0..d).map(|_| self.normal()).collect();
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-12 {
                return v.iter().map(|x| (x / norm) as f32).collect();
            }
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut chosen = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.uniform_usize(j + 1);
            if chosen.contains(&t) {
                chosen.push(j);
            } else {
                chosen.push(t);
            }
        }
        chosen
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.uniform_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Rng::seed_from(3);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut rng = Rng::seed_from(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn unit_sphere_has_unit_norm() {
        let mut rng = Rng::seed_from(6);
        for d in [2usize, 3, 28] {
            let v = rng.unit_sphere(d);
            let norm: f32 = v.iter().map(|x| x * x).sum::<f32>();
            assert!((norm - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Rng::seed_from(7);
        for _ in 0..50 {
            let idx = rng.sample_indices(100, 17);
            assert_eq!(idx.len(), 17);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 17, "indices must be distinct");
            assert!(idx.iter().all(|&i| i < 100));
        }
    }

    #[test]
    fn uniform_usize_bounds() {
        let mut rng = Rng::seed_from(8);
        for _ in 0..10_000 {
            assert!(rng.uniform_usize(7) < 7);
        }
    }

    #[test]
    fn fork_streams_are_independent_looking() {
        let mut root = Rng::seed_from(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seed_from(10);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
