//! The shard worker: receives [`TaskEnvelope`]s, solves them through the
//! planned executor, and streams [`ResultEnvelope`]s back.
//!
//! Layout per worker: a **receive loop** (this function's thread) that
//! answers pings immediately and forwards decoded tasks, and a **solver
//! thread** that runs the actual divergence batches. The split is what
//! makes liveness meaningful: a worker deep in a long solve still pongs
//! within one poll interval, so the coordinator's heartbeat timeout
//! fires only for workers that are genuinely gone (crashed, hung, or
//! muted), not merely busy.
//!
//! Determinism: the worker executes the shipped [`crate::api::Plan`]
//! through [`crate::api::OtProblem::divergence_all_planned`] with the
//! shipped feature map (or a `plan.seed` refit when absent). By the
//! PR 3 batch contract each pair's bits are independent of batch width,
//! thread count, and which worker runs it — the foundation of the
//! shard layer's bitwise-identity guarantee.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::api::{
    OtProblem, ResultEnvelope, SessionResultEnvelope, SessionSolveOut, TaskEnvelope,
    PLAN_FORMAT_MAJOR,
};
use crate::error::{Error, Result};
use crate::runtime::wire::kinds;
use crate::runtime::{Pool, WireDoc};
use crate::session::{solve_support, SupportState};

use super::transport::{TcpTransport, Transport};

/// How often the receive loop wakes to poll the transport.
const POLL_INTERVAL: Duration = Duration::from_millis(25);

/// How many streaming sessions a worker keeps resident support state
/// for. Residency is a pure performance cache — a miss surfaces a typed
/// error the coordinator answers with a full-snapshot retry — so the
/// bound only caps memory, never correctness. Eviction (smallest
/// session id first) is deterministic so replicas with identical traffic
/// hold identical state.
const SESSION_RESIDENCY_CAP: usize = 16;

/// What the receive loop hands the solver thread.
enum SolverMsg {
    /// One task envelope plus a scripted straggler delay.
    Task(TaskEnvelope, Option<Duration>),
    /// A streaming session closed: drop its resident state.
    CloseSession(u64),
}

/// Behaviour knobs, used by the fault harness to script worker-level
/// failures (see [`crate::shard::testing::FaultPlan`]). Default = no
/// faults.
#[derive(Clone, Debug, Default)]
pub struct WorkerOptions {
    /// Exit (simulated crash) upon receiving the nth task, 1-based: the
    /// task is accepted, never answered, and the link drops.
    pub exit_on_task: Option<usize>,
    /// From the nth received task on (1-based), keep solving but never
    /// send another frame — results *and* pongs go dark.
    pub mute_on_task: Option<usize>,
    /// Sleep this long before solving the nth task (1-based): a
    /// slow-but-alive straggler. Pongs keep flowing (the receive loop is
    /// unaffected), so this exercises hedging, not liveness.
    pub slow_on_task: Option<(usize, Duration)>,
    /// Plan format major to advertise in the hello handshake instead of
    /// this build's [`PLAN_FORMAT_MAJOR`] — a scripted mixed-version
    /// rejoiner, which the coordinator must refuse typed.
    pub hello_plan_major: Option<u64>,
}

/// Solve one task envelope. Public so tests can run the exact worker
/// computation locally.
pub fn execute_task(worker_id: u64, env: &TaskEnvelope) -> ResultEnvelope {
    let pair_refs: Vec<(&[f32], &[f32])> =
        env.pairs.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
    let mut problem = OtProblem::new(&env.mu, &env.nu).weight_pairs(&pair_refs);
    if let Some(map) = &env.map {
        problem = problem.with_feature_map(map);
    }
    let results = problem.divergence_all_planned(&env.plan);
    ResultEnvelope::new(env.task_id, worker_id, results)
}

/// Solve one streaming-session task against the worker's residency
/// store. Public so tests can run the exact worker computation locally.
///
/// Determinism: the solve runs [`crate::session::solve_support`] — the
/// *same* function the coordinator's local session path calls — on a
/// serial pool (pool width never changes bits; see
/// `rust/tests/streaming_equivalence.rs`), with the warm dual the
/// coordinator shipped. The worker owns no dual state: the solved alpha
/// travels back in the [`SessionResultEnvelope`].
pub fn execute_session(
    worker_id: u64,
    env: &TaskEnvelope,
    resident: &mut HashMap<u64, (SupportState, u64)>,
) -> SessionResultEnvelope {
    SessionResultEnvelope::new(env.task_id, worker_id, session_solve(env, resident))
}

fn session_solve(
    env: &TaskEnvelope,
    resident: &mut HashMap<u64, (SupportState, u64)>,
) -> Result<SessionSolveOut> {
    let delta = env
        .session
        .as_ref()
        .ok_or_else(|| Error::Wire("session solve on a task without a session".into()))?;
    let mut state = if delta.snapshot {
        // Full rebuild: the envelope's measures are the support in the
        // session's deterministic layout; the exact map must ride along
        // (a refit from `plan.seed` would be fit over the *current*
        // snapshot, not the session's original one — different anchors,
        // different bits).
        let map = env
            .map
            .as_ref()
            .ok_or_else(|| Error::Wire("session snapshot task without a feature map".into()))?;
        SupportState::from_measures(Arc::new(map.clone()), &env.mu, &env.nu)?
    } else {
        // Delta replay on the resident copy. Any mismatch — never held,
        // evicted, or a version skew after a lost frame — is a typed
        // miss the coordinator answers with a snapshot retry.
        match resident.remove(&delta.session_id) {
            Some((state, version)) if version == delta.base_version => state,
            Some((_, version)) => {
                return Err(Error::Service(format!(
                    "resident session {} is at version {version}, task expects {}",
                    delta.session_id, delta.base_version
                )))
            }
            None => {
                return Err(Error::Service(format!(
                    "no resident state for session {}",
                    delta.session_id
                )))
            }
        }
    };
    for op in &delta.ops {
        state.apply(op)?;
    }
    let cfg = env.plan.sinkhorn_config();
    let warm = delta.warm_alpha.as_deref();
    let solve = solve_support(&state, &cfg, &Pool::serial(), warm)?;
    if !resident.contains_key(&delta.session_id) && resident.len() >= SESSION_RESIDENCY_CAP {
        if let Some(&evict) = resident.keys().min() {
            resident.remove(&evict);
        }
    }
    resident.insert(delta.session_id, (state, delta.version));
    Ok(SessionSolveOut {
        objective: solve.solution.objective,
        iterations: solve.solution.iterations,
        marginal_error: solve.solution.marginal_error,
        converged: solve.solution.converged,
        escalated: solve.escalated,
        warm_started: warm.is_some(),
        alpha: solve.alpha,
    })
}

/// Run a worker until its link drops (or a scripted crash fires). Blocks
/// the calling thread; spawn it.
pub fn run_worker(worker_id: u64, transport: Arc<dyn Transport>, opts: WorkerOptions) {
    let muted = Arc::new(AtomicBool::new(false));
    let (task_tx, task_rx) = mpsc::channel::<SolverMsg>();
    let solver = {
        let transport = Arc::clone(&transport);
        let muted = Arc::clone(&muted);
        thread::Builder::new()
            .name(format!("ls-shard-solve-{worker_id}"))
            .spawn(move || {
                // Resident session state lives with the solver thread —
                // single-owner, no locking, dropped with the connection.
                let mut resident: HashMap<u64, (SupportState, u64)> = HashMap::new();
                while let Ok(msg) = task_rx.recv() {
                    let (env, delay) = match msg {
                        SolverMsg::Task(env, delay) => (env, delay),
                        SolverMsg::CloseSession(id) => {
                            resident.remove(&id);
                            continue;
                        }
                    };
                    if let Some(delay) = delay {
                        thread::sleep(delay); // scripted straggler
                    }
                    let frame = if env.session.is_some() {
                        execute_session(worker_id, &env, &mut resident).encode()
                    } else {
                        execute_task(worker_id, &env).encode()
                    };
                    if !muted.load(Ordering::SeqCst) && transport.send(&frame).is_err() {
                        break; // link gone: nobody to report to
                    }
                }
            })
            .expect("spawn shard solver thread")
    };

    let mut tasks_seen = 0usize;
    let mut draining = false;
    loop {
        let frame = match transport.recv_timeout(POLL_INTERVAL) {
            Ok(Some(frame)) => frame,
            Ok(None) => continue,
            Err(_) => break, // coordinator gone
        };
        // An undecodable inbound frame is ignored here: the coordinator's
        // task deadline covers lost tasks, and a garbled ping needs no
        // answer.
        let Ok(doc) = WireDoc::decode(&frame) else { continue };
        match doc.kind() {
            kinds::PING => {
                if !muted.load(Ordering::SeqCst) {
                    let mut pong = WireDoc::with_kind(kinds::PONG);
                    pong.set_u64("worker_id", worker_id);
                    if transport.send(&pong.encode()).is_err() {
                        break;
                    }
                }
            }
            kinds::HELLO => {
                // Rejoin handshake: echo the plan format major this build
                // executes (or a scripted impostor version) so the
                // coordinator can refuse mixed-version rejoiners typed.
                if !muted.load(Ordering::SeqCst) {
                    let mut hello = WireDoc::hello(
                        opts.hello_plan_major.unwrap_or(PLAN_FORMAT_MAJOR as u64),
                    );
                    hello.set_u64("worker_id", worker_id);
                    if transport.send(&hello.encode()).is_err() {
                        break;
                    }
                }
            }
            "task" => {
                tasks_seen += 1;
                if opts.exit_on_task == Some(tasks_seen) {
                    return; // scripted crash: transport drops, no join of solver
                }
                if opts.mute_on_task == Some(tasks_seen) {
                    muted.store(true, Ordering::SeqCst);
                }
                let delay = match opts.slow_on_task {
                    Some((nth, delay)) if nth == tasks_seen => Some(delay),
                    _ => None,
                };
                match TaskEnvelope::decode(&frame) {
                    Ok(env) => {
                        if task_tx.send(SolverMsg::Task(env, delay)).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        // The header parsed (we know the task id) but the
                        // envelope is invalid: reject it explicitly so the
                        // coordinator fails the task typed instead of
                        // burning retries on a deterministic failure.
                        if !muted.load(Ordering::SeqCst) {
                            let mut reject = WireDoc::with_kind("reject");
                            if let Ok(id) = doc.get_u64("task_id") {
                                reject.set_u64("task_id", id);
                            }
                            reject.set_str("error", &e.to_string());
                            if transport.send(&reject.encode()).is_err() {
                                break;
                            }
                        }
                    }
                }
            }
            kinds::SESSION_CLOSE => {
                if let Ok(id) = doc.get_u64("session.id") {
                    if task_tx.send(SolverMsg::CloseSession(id)).is_err() {
                        break;
                    }
                }
            }
            kinds::DRAIN => {
                // Graceful drain: stop accepting, finish queued solves
                // (below, via the solver join), then acknowledge.
                draining = true;
                break;
            }
            kinds::SHUTDOWN => break,
            _ => {}
        }
    }
    drop(task_tx);
    let _ = solver.join();
    if draining && !muted.load(Ordering::SeqCst) {
        // Every queued task has now been solved and sent; tell the
        // coordinator this exit was clean (best effort — a dead link at
        // this point just looks like a crash, which drain tolerates).
        let mut ack = WireDoc::with_kind(kinds::DRAIN_ACK);
        ack.set_u64("worker_id", worker_id);
        let _ = transport.send(&ack.encode());
    }
}

/// Serve exactly one coordinator connection on an accepted listener
/// (the cross-host entry point; the `shard-worker` CLI subcommand loops
/// over accepted connections itself so it can serve forever).
pub fn serve_listener(
    listener: std::net::TcpListener,
    worker_id: u64,
    opts: WorkerOptions,
) -> Result<()> {
    let (stream, peer) = listener.accept().map_err(Error::Io)?;
    let transport: Arc<dyn Transport> = Arc::new(TcpTransport::from_stream(stream)?);
    let _ = peer; // observability hooks could log this
    run_worker(worker_id, transport, opts);
    Ok(())
}

/// Serve a bounded sequence of coordinator connections: one
/// [`run_worker`] life per entry in `opts_per_conn`, in order. This is
/// what makes a TCP worker *rejoinable* — after a crash or drain of one
/// connection the listener accepts the coordinator's reconnect and the
/// next life begins (with its own scripted faults, in tests).
pub fn serve_connections(
    listener: std::net::TcpListener,
    worker_id: u64,
    opts_per_conn: Vec<WorkerOptions>,
) -> Result<()> {
    for opts in opts_per_conn {
        let (stream, peer) = listener.accept().map_err(Error::Io)?;
        let transport: Arc<dyn Transport> = Arc::new(TcpTransport::from_stream(stream)?);
        let _ = peer; // observability hooks could log this
        run_worker(worker_id, transport, opts);
    }
    Ok(())
}

/// Spawn a loopback TCP worker on an ephemeral port (test/bench helper).
/// Returns the address to hand to `ShardCoordinator::connect` and the
/// serving thread's handle.
pub fn spawn_tcp_worker(worker_id: u64) -> Result<(std::net::SocketAddr, JoinHandle<()>)> {
    spawn_tcp_worker_with(worker_id, vec![WorkerOptions::default()])
}

/// [`spawn_tcp_worker`] with scripted per-connection options: the worker
/// serves `opts_per_conn.len()` sequential coordinator connections (life
/// N uses `opts_per_conn[N]`), then exits. Lets tests script "crash on
/// first life, clean on rejoin".
pub fn spawn_tcp_worker_with(
    worker_id: u64,
    opts_per_conn: Vec<WorkerOptions>,
) -> Result<(std::net::SocketAddr, JoinHandle<()>)> {
    let listener = super::transport::loopback_listener()?;
    let addr = listener.local_addr().map_err(Error::Io)?;
    let handle = thread::Builder::new()
        .name(format!("ls-shard-tcp-{worker_id}"))
        .spawn(move || {
            let _ = serve_connections(listener, worker_id, opts_per_conn);
        })
        .expect("spawn tcp shard worker");
    Ok((addr, handle))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::rng::Rng;
    use crate::shard::transport::in_proc_pair;

    fn sample_task(task_id: u64) -> TaskEnvelope {
        let mut rng = Rng::seed_from(3);
        let (mu, nu) = data::gaussian_blobs(10, &mut rng);
        let pairs = vec![(mu.weights.clone(), nu.weights.clone())];
        let plan = OtProblem::new(&mu, &nu).epsilon(0.5).rank(8).seed(11).plan().unwrap();
        TaskEnvelope {
            task_id,
            group_id: 0,
            request_ids: vec![1],
            plan,
            mu,
            nu,
            pairs,
            map: None,
            session: None,
        }
    }

    #[test]
    fn worker_answers_pings_and_tasks() {
        let (coord, worker_end) = in_proc_pair();
        let worker_end: Arc<dyn Transport> = Arc::new(worker_end);
        let handle = thread::spawn(move || run_worker(7, worker_end, WorkerOptions::default()));

        let mut ping = WireDoc::with_kind("ping");
        ping.set_u64("worker_id", 7);
        coord.send(&ping.encode()).unwrap();
        let pong = coord.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        let pong = WireDoc::decode(&pong).unwrap();
        assert_eq!(pong.kind(), "pong");
        assert_eq!(pong.get_u64("worker_id").unwrap(), 7);

        let task = sample_task(42);
        coord.send(&task.encode()).unwrap();
        let frame = coord.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        let result = ResultEnvelope::decode(&frame).unwrap();
        assert_eq!(result.task_id, 42);
        assert_eq!(result.worker_id, 7);
        assert_eq!(result.results.len(), 1);
        let local = execute_task(7, &task);
        let (remote, local) =
            (result.results[0].as_ref().unwrap(), local.results[0].as_ref().unwrap());
        assert_eq!(remote.divergence.to_bits(), local.divergence.to_bits());
        assert_eq!(remote.xy.u, local.xy.u);

        drop(coord); // link gone: worker exits
        handle.join().unwrap();
    }

    #[test]
    fn worker_serves_session_snapshot_then_delta_then_close() {
        use crate::api::SessionDelta;
        use crate::features::GaussianFeatureMap;
        use crate::session::SessionOp;

        let mut rng = Rng::seed_from(6);
        let (mu, nu) = data::gaussian_blobs(10, &mut rng);
        let map = GaussianFeatureMap::fit(&mu, &nu, 0.5, 8, &mut Rng::seed_from(11));
        let plan = OtProblem::new(&mu, &nu).epsilon(0.5).rank(8).seed(11).plan().unwrap();
        let session_task = |task_id: u64, delta: SessionDelta| TaskEnvelope {
            task_id,
            group_id: 0,
            request_ids: Vec::new(),
            plan: plan.clone(),
            mu: mu.clone(),
            nu: nu.clone(),
            pairs: Vec::new(),
            map: Some(map.clone()),
            session: Some(delta),
        };
        let snapshot = SessionDelta {
            session_id: 7,
            base_version: 0,
            version: 0,
            snapshot: true,
            ops: Vec::new(),
            warm_alpha: None,
        };

        let (coord, worker_end) = in_proc_pair();
        let worker_end: Arc<dyn Transport> = Arc::new(worker_end);
        let handle = thread::spawn(move || run_worker(3, worker_end, WorkerOptions::default()));

        coord.send(&session_task(1, snapshot.clone()).encode()).unwrap();
        let frame = coord.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        let first = SessionResultEnvelope::decode(&frame).unwrap();
        assert_eq!(first.task_id, 1);
        let first = first.result.unwrap();
        assert!(!first.warm_started, "no dual shipped on first contact");
        assert!(!first.alpha.is_empty());

        // Delta on the resident copy, warm-started from the returned dual.
        let delta = SessionDelta {
            session_id: 7,
            base_version: 0,
            version: 1,
            snapshot: false,
            ops: vec![SessionOp::SwapX {
                index: 0,
                point: mu.points.row(1).to_vec(),
                weight: mu.weights[0],
            }],
            warm_alpha: Some(first.alpha.clone()),
        };
        coord.send(&session_task(2, delta.clone()).encode()).unwrap();
        let frame = coord.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        let second = SessionResultEnvelope::decode(&frame).unwrap().result.unwrap();
        assert!(second.warm_started);
        assert!(second.objective.is_finite());

        // A stale base version is a typed residency miss, not a panic.
        let mut stale = delta;
        stale.base_version = 0; // resident copy is now at version 1
        stale.version = 2;
        coord.send(&session_task(3, stale).encode()).unwrap();
        let frame = coord.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        match SessionResultEnvelope::decode(&frame).unwrap().result {
            Err(Error::Service(msg)) => assert!(msg.contains("version"), "{msg}"),
            other => panic!("expected typed residency miss, got {other:?}"),
        }

        // After a close, even the right base version misses (state gone).
        coord.send(&session_task(4, snapshot).encode()).unwrap();
        let frame = coord.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert!(SessionResultEnvelope::decode(&frame).unwrap().result.is_ok());
        let mut close = WireDoc::with_kind(kinds::SESSION_CLOSE);
        close.set_u64("session.id", 7);
        coord.send(&close.encode()).unwrap();
        let miss = SessionDelta {
            session_id: 7,
            base_version: 0,
            version: 1,
            snapshot: false,
            ops: Vec::new(),
            warm_alpha: None,
        };
        coord.send(&session_task(5, miss).encode()).unwrap();
        let frame = coord.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        match SessionResultEnvelope::decode(&frame).unwrap().result {
            Err(Error::Service(msg)) => assert!(msg.contains("no resident"), "{msg}"),
            other => panic!("expected typed residency miss after close, got {other:?}"),
        }

        drop(coord);
        handle.join().unwrap();
    }

    #[test]
    fn worker_rejects_invalid_task_without_dying() {
        let (coord, worker_end) = in_proc_pair();
        let worker_end: Arc<dyn Transport> = Arc::new(worker_end);
        let handle = thread::spawn(move || run_worker(1, worker_end, WorkerOptions::default()));

        // A "task" frame whose header parses but whose envelope is
        // incomplete: the worker must reject, not panic or go silent.
        let mut bogus = WireDoc::with_kind("task");
        bogus.set_u64("task_id", 99);
        coord.send(&bogus.encode()).unwrap();
        let frame = coord.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        let reject = WireDoc::decode(&frame).unwrap();
        assert_eq!(reject.kind(), "reject");
        assert_eq!(reject.get_u64("task_id").unwrap(), 99);
        assert!(!reject.get_str("error").unwrap().is_empty());

        // Still alive afterwards: a real task completes.
        let task = sample_task(5);
        coord.send(&task.encode()).unwrap();
        let frame = coord.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(ResultEnvelope::decode(&frame).unwrap().task_id, 5);

        drop(coord);
        handle.join().unwrap();
    }

    #[test]
    fn hello_handshake_and_graceful_drain_ack() {
        let (coord, worker_end) = in_proc_pair();
        let worker_end: Arc<dyn Transport> = Arc::new(worker_end);
        let handle = thread::spawn(move || run_worker(4, worker_end, WorkerOptions::default()));

        // Handshake: the worker echoes this build's plan format major.
        coord.send(&WireDoc::hello(PLAN_FORMAT_MAJOR as u64).encode()).unwrap();
        let frame = coord.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        let hello = WireDoc::decode(&frame).unwrap();
        assert_eq!(hello.kind(), kinds::HELLO);
        assert_eq!(hello.get_u64("plan_v").unwrap(), PLAN_FORMAT_MAJOR as u64);
        assert_eq!(hello.get_u64("worker_id").unwrap(), 4);

        // Drain with a task still queued: the result must arrive before
        // the ack — the drain orphans nothing.
        let task = sample_task(9);
        coord.send(&task.encode()).unwrap();
        coord.send(&WireDoc::with_kind(kinds::DRAIN).encode()).unwrap();
        let frame = coord.recv_timeout(Duration::from_secs(30)).unwrap().unwrap();
        assert_eq!(ResultEnvelope::decode(&frame).unwrap().task_id, 9);
        let frame = coord.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
        assert_eq!(WireDoc::decode(&frame).unwrap().kind(), kinds::DRAIN_ACK);
        handle.join().unwrap();
    }

    #[test]
    fn scripted_crash_and_mute_behave() {
        // Crash on first task: the task is never answered and the link
        // drops (send eventually fails / recv errors).
        let (coord, worker_end) = in_proc_pair();
        let worker_end: Arc<dyn Transport> = Arc::new(worker_end);
        let handle = thread::spawn(move || {
            run_worker(0, worker_end, WorkerOptions { exit_on_task: Some(1), ..Default::default() })
        });
        coord.send(&sample_task(1).encode()).unwrap();
        handle.join().unwrap();
        // The solver thread drops its transport handle asynchronously
        // after the crash; poll until the disconnect is visible.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match coord.recv_timeout(Duration::from_millis(20)) {
                Err(_) => break,
                Ok(None) => assert!(std::time::Instant::now() < deadline, "link must drop"),
                Ok(Some(_)) => panic!("crashed worker must not answer"),
            }
        }

        // Mute on first task: the worker stays up (receives, solves) but
        // sends nothing — not the result, not pongs.
        let (coord, worker_end) = in_proc_pair();
        let worker_end: Arc<dyn Transport> = Arc::new(worker_end);
        let handle = thread::spawn(move || {
            run_worker(0, worker_end, WorkerOptions { mute_on_task: Some(1), ..Default::default() })
        });
        coord.send(&sample_task(2).encode()).unwrap();
        let ping = WireDoc::with_kind("ping");
        coord.send(&ping.encode()).unwrap();
        assert!(
            coord.recv_timeout(Duration::from_millis(300)).unwrap().is_none(),
            "muted worker must go dark"
        );
        drop(coord);
        handle.join().unwrap();
    }
}
