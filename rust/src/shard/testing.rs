//! Deterministic fault injection for the shard layer.
//!
//! A [`FaultPlan`] is a reproducible schedule of message- and
//! worker-level failures, keyed by worker index, by the worker's
//! **incarnation** (0 = initial spawn, +1 per rejoin — so a plan can
//! script "crash on first life, clean on rejoin"), and by a
//! per-transport message counter (`nth`, 0-based) — no clocks, no
//! randomness at injection time. The same plan against the same
//! workload replays the same fault sequence, which is what lets
//! `rust/tests/shard_fault_injection.rs` and
//! `rust/tests/shard_chaos_soak.rs` assert *bitwise* agreement with the
//! single-host solve under every survivable fault.
//!
//! Two delivery mechanisms:
//!
//! * **Transport faults** ([`FaultyTransport`]) wrap the *coordinator's*
//!   endpoint of one worker link and perturb frames in flight:
//!   [`Fault::DropSend`] swallows the coordinator's nth outbound frame
//!   (task or ping never arrives), [`Fault::DropRecv`] /
//!   [`Fault::DelayRecv`] / [`Fault::DuplicateRecv`] /
//!   [`Fault::CorruptRecv`] perturb the nth inbound frame (result or
//!   pong), and [`Fault::PartitionSend`] / [`Fault::PartitionRecv`]
//!   black-hole a whole *window* of frames in one direction — a network
//!   partition that later heals.
//! * **Worker faults** are handed to the worker loop as
//!   [`crate::shard::worker::WorkerOptions`]: [`Fault::KillOnTask`] makes
//!   the worker exit the moment its nth task arrives (a crash — the link
//!   drops), [`Fault::MuteOnTask`] makes it keep solving but never send
//!   again (a hang — only the heartbeat timeout can detect it),
//!   [`Fault::SlowOnTask`] makes one solve take an extra `delay`
//!   (a straggler — pongs keep flowing, so hedging covers it, not
//!   liveness), and [`Fault::AdvertiseVersion`] makes the worker's hello
//!   handshake claim a foreign plan format major (a mixed-version
//!   rejoiner the coordinator must refuse typed).
//!
//! [`FaultPlan::random`] derives a schedule from a seed via the crate's
//! own [`Rng`], restricted to survivable message-level faults, for
//! property-style sweeps.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::rng::Rng;

use super::transport::Transport;
use super::worker::WorkerOptions;

/// One injected failure. `nth` counters are 0-based per direction and
/// per transport, except the task-indexed worker faults which are
/// 1-based ("on the nth task received").
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Swallow the coordinator's nth outbound frame.
    DropSend { nth: usize },
    /// Swallow the nth inbound frame.
    DropRecv { nth: usize },
    /// Deliver the nth inbound frame, then deliver a copy again.
    DuplicateRecv { nth: usize },
    /// Hold the nth inbound frame back for `delay` before delivering it
    /// (out-of-order / late gather).
    DelayRecv { nth: usize, delay: Duration },
    /// Garble the nth inbound frame's bytes (decode must fail typed).
    CorruptRecv { nth: usize },
    /// Black-hole `count` outbound frames starting at the `from`th —
    /// one half of a partition window (frames in flight die).
    PartitionSend { from: usize, count: usize },
    /// Black-hole `count` inbound frames starting at the `from`th — the
    /// other half of a partition window.
    PartitionRecv { from: usize, count: usize },
    /// Worker exits (crash) upon receiving its nth task, 1-based.
    KillOnTask { nth: usize },
    /// Worker stops sending (results *and* pongs) from its nth task on,
    /// 1-based, but keeps running — detectable only via heartbeats.
    MuteOnTask { nth: usize },
    /// Worker's nth solve (1-based) takes an extra `delay` — a straggler
    /// that still answers pings.
    SlowOnTask { nth: usize, delay: Duration },
    /// Worker's hello handshake advertises plan format `major` instead
    /// of this build's — a mixed-version rejoiner.
    AdvertiseVersion { major: u64 },
}

impl Fault {
    fn is_transport(&self) -> bool {
        matches!(
            self,
            Fault::DropSend { .. }
                | Fault::DropRecv { .. }
                | Fault::DuplicateRecv { .. }
                | Fault::DelayRecv { .. }
                | Fault::CorruptRecv { .. }
                | Fault::PartitionSend { .. }
                | Fault::PartitionRecv { .. }
        )
    }
}

/// A reproducible schedule of faults, addressed by worker index and
/// incarnation (0 = initial spawn, incremented on every rejoin).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    injections: Vec<(usize, u64, Fault)>,
}

impl FaultPlan {
    /// The empty plan (no faults).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// An empty plan tagged with a seed (for labelling derived plans).
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, injections: Vec::new() }
    }

    /// Add one fault against `worker`'s initial incarnation (builder
    /// style).
    pub fn inject(self, worker: usize, fault: Fault) -> FaultPlan {
        self.inject_at(worker, 0, fault)
    }

    /// Add one fault against a specific incarnation of `worker`:
    /// incarnation 0 is the initial spawn, each successful rejoin
    /// increments it. Lets a plan script flapping workers ("crash on
    /// life 0 *and* life 1, serve cleanly from life 2").
    pub fn inject_at(mut self, worker: usize, incarnation: u64, fault: Fault) -> FaultPlan {
        self.injections.push((worker, incarnation, fault));
        self
    }

    /// Derive a schedule of `count` *survivable* message-level faults
    /// (drops, delays, duplicates — never kills, mutes, or corruption)
    /// from `seed`. Any such plan must leave answers bitwise intact.
    pub fn random(seed: u64, workers: usize, count: usize) -> FaultPlan {
        let mut rng = Rng::seed_from(seed);
        let mut plan = FaultPlan::new(seed);
        for _ in 0..count {
            let worker = rng.uniform_usize(workers.max(1));
            let nth = rng.uniform_usize(3);
            let fault = match rng.uniform_usize(4) {
                0 => Fault::DropSend { nth },
                1 => Fault::DropRecv { nth },
                2 => Fault::DuplicateRecv { nth },
                _ => Fault::DelayRecv {
                    nth,
                    delay: Duration::from_millis(2 + 3 * rng.uniform_usize(8) as u64),
                },
            };
            plan = plan.inject(worker, fault);
        }
        plan
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn is_empty(&self) -> bool {
        self.injections.is_empty()
    }

    /// The 1-based task index at which `worker`'s initial incarnation
    /// crashes, if scheduled.
    pub fn kill_on_task(&self, worker: usize) -> Option<usize> {
        self.worker_options(worker, 0).exit_on_task
    }

    /// The 1-based task index at which `worker`'s initial incarnation
    /// goes mute, if scheduled.
    pub fn mute_on_task(&self, worker: usize) -> Option<usize> {
        self.worker_options(worker, 0).mute_on_task
    }

    /// The [`WorkerOptions`] scripting one incarnation of `worker` —
    /// what the coordinator hands the worker loop at (re)spawn time.
    pub fn worker_options(&self, worker: usize, incarnation: u64) -> WorkerOptions {
        let mut opts = WorkerOptions::default();
        for (w, inc, fault) in &self.injections {
            if *w != worker || *inc != incarnation {
                continue;
            }
            match fault {
                Fault::KillOnTask { nth } => opts.exit_on_task = Some(*nth),
                Fault::MuteOnTask { nth } => opts.mute_on_task = Some(*nth),
                Fault::SlowOnTask { nth, delay } => opts.slow_on_task = Some((*nth, *delay)),
                Fault::AdvertiseVersion { major } => opts.hello_plan_major = Some(*major),
                _ => {}
            }
        }
        opts
    }

    /// Message-level faults against `worker`'s initial link, in
    /// injection order.
    pub fn transport_faults(&self, worker: usize) -> Vec<Fault> {
        self.transport_faults_at(worker, 0)
    }

    /// Message-level faults against one incarnation of `worker`'s link.
    pub fn transport_faults_at(&self, worker: usize, incarnation: u64) -> Vec<Fault> {
        self.injections
            .iter()
            .filter(|(w, inc, f)| *w == worker && *inc == incarnation && f.is_transport())
            .map(|(_, _, f)| f.clone())
            .collect()
    }

    pub fn has_transport_faults(&self, worker: usize) -> bool {
        self.has_transport_faults_at(worker, 0)
    }

    pub fn has_transport_faults_at(&self, worker: usize, incarnation: u64) -> bool {
        !self.transport_faults_at(worker, incarnation).is_empty()
    }
}

/// A [`Transport`] decorator that applies one worker's message-level
/// faults from a [`FaultPlan`]. Wraps the coordinator-side endpoint.
pub struct FaultyTransport<T: Transport> {
    inner: T,
    faults: Vec<Fault>,
    sends: AtomicUsize,
    recvs: AtomicUsize,
    /// Frames held back by `DelayRecv` / queued again by
    /// `DuplicateRecv`: (release time, frame).
    held: Mutex<Vec<(Instant, Vec<u8>)>>,
}

impl<T: Transport> FaultyTransport<T> {
    pub fn new(inner: T, faults: Vec<Fault>) -> FaultyTransport<T> {
        FaultyTransport {
            inner,
            faults,
            sends: AtomicUsize::new(0),
            recvs: AtomicUsize::new(0),
            held: Mutex::new(Vec::new()),
        }
    }

    /// Poison-recovering lock: the held-frame list stays usable even if
    /// a test thread panicked while holding it (the list of delayed
    /// frames is valid at every intermediate state).
    fn held(&self) -> MutexGuard<'_, Vec<(Instant, Vec<u8>)>> {
        self.held.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

impl<T: Transport> Transport for FaultyTransport<T> {
    fn send(&self, frame: &[u8]) -> crate::error::Result<()> {
        let n = self.sends.fetch_add(1, Ordering::SeqCst);
        for fault in &self.faults {
            match fault {
                Fault::DropSend { nth } if *nth == n => {
                    return Ok(()); // swallowed: the peer never sees it
                }
                Fault::PartitionSend { from, count } if n >= *from && n < from + count => {
                    return Ok(()); // inside the partition window
                }
                _ => {}
            }
        }
        self.inner.send(frame)
    }

    fn recv_timeout(&self, timeout: Duration) -> crate::error::Result<Option<Vec<u8>>> {
        // Matured held-back frames are delivered before live ones.
        {
            let mut held = self.held();
            if let Some(pos) = held.iter().position(|(at, _)| *at <= Instant::now()) {
                return Ok(Some(held.remove(pos).1));
            }
        }
        let Some(mut frame) = self.inner.recv_timeout(timeout)? else {
            return Ok(None);
        };
        let n = self.recvs.fetch_add(1, Ordering::SeqCst);
        for fault in &self.faults {
            match fault {
                Fault::DropRecv { nth } if *nth == n => return Ok(None),
                Fault::PartitionRecv { from, count } if n >= *from && n < from + count => {
                    // The frame was read off the link and died in the
                    // partition — unlike a delay, it never arrives.
                    return Ok(None);
                }
                Fault::DuplicateRecv { nth } if *nth == n => {
                    self.held().push((Instant::now(), frame.clone()));
                    return Ok(Some(frame));
                }
                Fault::DelayRecv { nth, delay } if *nth == n => {
                    self.held().push((Instant::now() + *delay, frame));
                    return Ok(None);
                }
                Fault::CorruptRecv { nth } if *nth == n => {
                    // Garble everything past the magic + header-length
                    // prefix so the header JSON fails to parse — decode
                    // must surface a typed wire error, never a panic.
                    let start = 8.min(frame.len().saturating_sub(1));
                    for b in &mut frame[start..] {
                        *b ^= 0xA5;
                    }
                    return Ok(Some(frame));
                }
                _ => {}
            }
        }
        Ok(Some(frame))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::transport::in_proc_pair;

    #[test]
    fn plan_routes_faults_by_worker() {
        let plan = FaultPlan::new(9)
            .inject(0, Fault::KillOnTask { nth: 1 })
            .inject(1, Fault::MuteOnTask { nth: 2 })
            .inject(1, Fault::DropRecv { nth: 0 });
        assert_eq!(plan.kill_on_task(0), Some(1));
        assert_eq!(plan.kill_on_task(1), None);
        assert_eq!(plan.mute_on_task(1), Some(2));
        assert_eq!(plan.transport_faults(0), vec![]);
        assert_eq!(plan.transport_faults(1), vec![Fault::DropRecv { nth: 0 }]);
        assert!(plan.has_transport_faults(1));
        assert!(!plan.has_transport_faults(0));
        assert_eq!(plan.seed(), 9);
    }

    #[test]
    fn plan_scopes_faults_by_incarnation() {
        let plan = FaultPlan::new(4)
            .inject(0, Fault::KillOnTask { nth: 1 })
            .inject_at(0, 1, Fault::KillOnTask { nth: 2 })
            .inject_at(0, 1, Fault::CorruptRecv { nth: 0 })
            .inject_at(0, 2, Fault::AdvertiseVersion { major: 9 })
            .inject_at(0, 2, Fault::SlowOnTask { nth: 1, delay: Duration::from_millis(3) });
        // Life 0: crash on first task, clean link.
        assert_eq!(plan.worker_options(0, 0).exit_on_task, Some(1));
        assert!(!plan.has_transport_faults_at(0, 0));
        // Life 1 (first rejoin): crash on second task, corrupt link.
        assert_eq!(plan.worker_options(0, 1).exit_on_task, Some(2));
        assert_eq!(plan.transport_faults_at(0, 1), vec![Fault::CorruptRecv { nth: 0 }]);
        // Life 2: no crash, but a straggler advertising a foreign version.
        let opts = plan.worker_options(0, 2);
        assert_eq!(opts.exit_on_task, None);
        assert_eq!(opts.hello_plan_major, Some(9));
        assert_eq!(opts.slow_on_task, Some((1, Duration::from_millis(3))));
        // Another worker sees none of it.
        assert_eq!(plan.worker_options(1, 0).exit_on_task, None);
    }

    #[test]
    fn random_plans_are_reproducible_and_survivable() {
        let a = FaultPlan::random(42, 3, 8);
        let b = FaultPlan::random(42, 3, 8);
        assert_eq!(a.injections, b.injections, "same seed, same schedule");
        let c = FaultPlan::random(43, 3, 8);
        assert_ne!(a.injections, c.injections, "different seed, different schedule");
        for w in 0..3 {
            assert_eq!(a.kill_on_task(w), None, "random plans never kill");
            assert_eq!(a.mute_on_task(w), None, "random plans never mute");
            for f in a.transport_faults(w) {
                assert!(!matches!(f, Fault::CorruptRecv { .. }), "random plans never corrupt");
                assert!(
                    !matches!(f, Fault::PartitionSend { .. } | Fault::PartitionRecv { .. }),
                    "random plans never partition"
                );
            }
        }
    }

    #[test]
    fn faulty_transport_drops_duplicates_delays_and_corrupts() {
        let timeout = Duration::from_millis(50);
        // Drop the 0th send: the peer only sees the second frame.
        let (coord, worker) = in_proc_pair();
        let faulty = FaultyTransport::new(coord, vec![Fault::DropSend { nth: 0 }]);
        faulty.send(b"one").unwrap();
        faulty.send(b"two").unwrap();
        assert_eq!(worker.recv_timeout(timeout).unwrap().unwrap(), b"two");
        assert!(worker.recv_timeout(Duration::from_millis(5)).unwrap().is_none());

        // Duplicate the 0th receive: the frame arrives twice.
        let (coord, worker) = in_proc_pair();
        let faulty = FaultyTransport::new(coord, vec![Fault::DuplicateRecv { nth: 0 }]);
        worker.send(b"result").unwrap();
        assert_eq!(faulty.recv_timeout(timeout).unwrap().unwrap(), b"result");
        assert_eq!(faulty.recv_timeout(timeout).unwrap().unwrap(), b"result");

        // Delay the 0th receive: first poll sees nothing, a later poll
        // (after the delay matures) sees the frame.
        let (coord, worker) = in_proc_pair();
        let faulty = FaultyTransport::new(
            coord,
            vec![Fault::DelayRecv { nth: 0, delay: Duration::from_millis(20) }],
        );
        worker.send(b"late").unwrap();
        assert!(faulty.recv_timeout(timeout).unwrap().is_none(), "held back");
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(faulty.recv_timeout(timeout).unwrap().unwrap(), b"late");

        // Corrupt the 0th receive: bytes change, length does not.
        let (coord, worker) = in_proc_pair();
        let faulty = FaultyTransport::new(coord, vec![Fault::CorruptRecv { nth: 0 }]);
        let frame = b"LSW1\x10\x00\x00\x00{\"v\":1}".to_vec();
        worker.send(&frame).unwrap();
        let got = faulty.recv_timeout(timeout).unwrap().unwrap();
        assert_eq!(got.len(), frame.len());
        assert_ne!(got, frame);
        assert_eq!(&got[..8], &frame[..8], "prefix intact, payload garbled");
    }

    #[test]
    fn partition_windows_blackhole_then_heal() {
        let timeout = Duration::from_millis(50);
        // Outbound window [1, 3): frames 1 and 2 die, 0 and 3 arrive.
        let (coord, worker) = in_proc_pair();
        let faulty = FaultyTransport::new(coord, vec![Fault::PartitionSend { from: 1, count: 2 }]);
        for frame in [&b"a"[..], b"b", b"c", b"d"] {
            faulty.send(frame).unwrap();
        }
        assert_eq!(worker.recv_timeout(timeout).unwrap().unwrap(), b"a");
        assert_eq!(worker.recv_timeout(timeout).unwrap().unwrap(), b"d");
        assert!(worker.recv_timeout(Duration::from_millis(5)).unwrap().is_none());

        // Inbound window [0, 2): the first two frames die *in flight*
        // (unlike a delay they never arrive), the third gets through.
        let (coord, worker) = in_proc_pair();
        let faulty = FaultyTransport::new(coord, vec![Fault::PartitionRecv { from: 0, count: 2 }]);
        for frame in [&b"x"[..], b"y", b"z"] {
            worker.send(frame).unwrap();
        }
        assert!(faulty.recv_timeout(timeout).unwrap().is_none());
        assert!(faulty.recv_timeout(timeout).unwrap().is_none());
        assert_eq!(faulty.recv_timeout(timeout).unwrap().unwrap(), b"z");
    }
}
