//! Deterministic fault injection for the shard layer.
//!
//! A [`FaultPlan`] is a reproducible schedule of message- and
//! worker-level failures, keyed by worker index and by a per-transport
//! message counter (`nth`, 0-based) — no clocks, no randomness at
//! injection time. The same plan against the same workload replays the
//! same fault sequence, which is what lets
//! `rust/tests/shard_fault_injection.rs` assert *bitwise* agreement with
//! the single-host solve under every survivable fault.
//!
//! Two delivery mechanisms:
//!
//! * **Transport faults** ([`FaultyTransport`]) wrap the *coordinator's*
//!   endpoint of one worker link and perturb frames in flight:
//!   [`Fault::DropSend`] swallows the coordinator's nth outbound frame
//!   (task or ping never arrives), [`Fault::DropRecv`] /
//!   [`Fault::DelayRecv`] / [`Fault::DuplicateRecv`] /
//!   [`Fault::CorruptRecv`] perturb the nth inbound frame (result or
//!   pong).
//! * **Worker faults** are handed to the worker loop as
//!   [`crate::shard::worker::WorkerOptions`]: [`Fault::KillOnTask`] makes
//!   the worker exit the moment its nth task arrives (a crash — the link
//!   drops), [`Fault::MuteOnTask`] makes it keep solving but never send
//!   again (a hang — only the heartbeat timeout can detect it).
//!
//! [`FaultPlan::random`] derives a schedule from a seed via the crate's
//! own [`Rng`], restricted to survivable message-level faults, for
//! property-style sweeps.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::rng::Rng;

use super::transport::Transport;

/// One injected failure. `nth` counters are 0-based per direction and
/// per transport, except the task-indexed worker faults which are
/// 1-based ("on the nth task received").
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Swallow the coordinator's nth outbound frame.
    DropSend { nth: usize },
    /// Swallow the nth inbound frame.
    DropRecv { nth: usize },
    /// Deliver the nth inbound frame, then deliver a copy again.
    DuplicateRecv { nth: usize },
    /// Hold the nth inbound frame back for `delay` before delivering it
    /// (out-of-order / late gather).
    DelayRecv { nth: usize, delay: Duration },
    /// Garble the nth inbound frame's bytes (decode must fail typed).
    CorruptRecv { nth: usize },
    /// Worker exits (crash) upon receiving its nth task, 1-based.
    KillOnTask { nth: usize },
    /// Worker stops sending (results *and* pongs) from its nth task on,
    /// 1-based, but keeps running — detectable only via heartbeats.
    MuteOnTask { nth: usize },
}

/// A reproducible schedule of faults, addressed by worker index.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    injections: Vec<(usize, Fault)>,
}

impl FaultPlan {
    /// The empty plan (no faults).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// An empty plan tagged with a seed (for labelling derived plans).
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, injections: Vec::new() }
    }

    /// Add one fault against `worker` (builder style).
    pub fn inject(mut self, worker: usize, fault: Fault) -> FaultPlan {
        self.injections.push((worker, fault));
        self
    }

    /// Derive a schedule of `count` *survivable* message-level faults
    /// (drops, delays, duplicates — never kills, mutes, or corruption)
    /// from `seed`. Any such plan must leave answers bitwise intact.
    pub fn random(seed: u64, workers: usize, count: usize) -> FaultPlan {
        let mut rng = Rng::seed_from(seed);
        let mut plan = FaultPlan::new(seed);
        for _ in 0..count {
            let worker = rng.uniform_usize(workers.max(1));
            let nth = rng.uniform_usize(3);
            let fault = match rng.uniform_usize(4) {
                0 => Fault::DropSend { nth },
                1 => Fault::DropRecv { nth },
                2 => Fault::DuplicateRecv { nth },
                _ => Fault::DelayRecv {
                    nth,
                    delay: Duration::from_millis(2 + 3 * rng.uniform_usize(8) as u64),
                },
            };
            plan = plan.inject(worker, fault);
        }
        plan
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn is_empty(&self) -> bool {
        self.injections.is_empty()
    }

    /// The 1-based task index at which `worker` crashes, if scheduled.
    pub fn kill_on_task(&self, worker: usize) -> Option<usize> {
        self.injections.iter().find_map(|(w, f)| match f {
            Fault::KillOnTask { nth } if *w == worker => Some(*nth),
            _ => None,
        })
    }

    /// The 1-based task index at which `worker` goes mute, if scheduled.
    pub fn mute_on_task(&self, worker: usize) -> Option<usize> {
        self.injections.iter().find_map(|(w, f)| match f {
            Fault::MuteOnTask { nth } if *w == worker => Some(*nth),
            _ => None,
        })
    }

    /// Message-level faults against `worker`'s link, in injection order.
    pub fn transport_faults(&self, worker: usize) -> Vec<Fault> {
        self.injections
            .iter()
            .filter(|(w, f)| {
                *w == worker
                    && !matches!(f, Fault::KillOnTask { .. } | Fault::MuteOnTask { .. })
            })
            .map(|(_, f)| f.clone())
            .collect()
    }

    pub fn has_transport_faults(&self, worker: usize) -> bool {
        !self.transport_faults(worker).is_empty()
    }
}

/// A [`Transport`] decorator that applies one worker's message-level
/// faults from a [`FaultPlan`]. Wraps the coordinator-side endpoint.
pub struct FaultyTransport<T: Transport> {
    inner: T,
    faults: Vec<Fault>,
    sends: AtomicUsize,
    recvs: AtomicUsize,
    /// Frames held back by `DelayRecv` / queued again by
    /// `DuplicateRecv`: (release time, frame).
    held: Mutex<Vec<(Instant, Vec<u8>)>>,
}

impl<T: Transport> FaultyTransport<T> {
    pub fn new(inner: T, faults: Vec<Fault>) -> FaultyTransport<T> {
        FaultyTransport {
            inner,
            faults,
            sends: AtomicUsize::new(0),
            recvs: AtomicUsize::new(0),
            held: Mutex::new(Vec::new()),
        }
    }
}

impl<T: Transport> Transport for FaultyTransport<T> {
    fn send(&self, frame: &[u8]) -> crate::error::Result<()> {
        let n = self.sends.fetch_add(1, Ordering::SeqCst);
        for fault in &self.faults {
            if matches!(fault, Fault::DropSend { nth } if *nth == n) {
                return Ok(()); // swallowed: the peer never sees it
            }
        }
        self.inner.send(frame)
    }

    fn recv_timeout(&self, timeout: Duration) -> crate::error::Result<Option<Vec<u8>>> {
        // Matured held-back frames are delivered before live ones.
        {
            let mut held = self.held.lock().unwrap();
            if let Some(pos) = held.iter().position(|(at, _)| *at <= Instant::now()) {
                return Ok(Some(held.remove(pos).1));
            }
        }
        let Some(mut frame) = self.inner.recv_timeout(timeout)? else {
            return Ok(None);
        };
        let n = self.recvs.fetch_add(1, Ordering::SeqCst);
        for fault in &self.faults {
            match fault {
                Fault::DropRecv { nth } if *nth == n => return Ok(None),
                Fault::DuplicateRecv { nth } if *nth == n => {
                    self.held.lock().unwrap().push((Instant::now(), frame.clone()));
                    return Ok(Some(frame));
                }
                Fault::DelayRecv { nth, delay } if *nth == n => {
                    self.held.lock().unwrap().push((Instant::now() + *delay, frame));
                    return Ok(None);
                }
                Fault::CorruptRecv { nth } if *nth == n => {
                    // Garble everything past the magic + header-length
                    // prefix so the header JSON fails to parse — decode
                    // must surface a typed wire error, never a panic.
                    let start = 8.min(frame.len().saturating_sub(1));
                    for b in &mut frame[start..] {
                        *b ^= 0xA5;
                    }
                    return Ok(Some(frame));
                }
                _ => {}
            }
        }
        Ok(Some(frame))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::transport::in_proc_pair;

    #[test]
    fn plan_routes_faults_by_worker() {
        let plan = FaultPlan::new(9)
            .inject(0, Fault::KillOnTask { nth: 1 })
            .inject(1, Fault::MuteOnTask { nth: 2 })
            .inject(1, Fault::DropRecv { nth: 0 });
        assert_eq!(plan.kill_on_task(0), Some(1));
        assert_eq!(plan.kill_on_task(1), None);
        assert_eq!(plan.mute_on_task(1), Some(2));
        assert_eq!(plan.transport_faults(0), vec![]);
        assert_eq!(plan.transport_faults(1), vec![Fault::DropRecv { nth: 0 }]);
        assert!(plan.has_transport_faults(1));
        assert!(!plan.has_transport_faults(0));
        assert_eq!(plan.seed(), 9);
    }

    #[test]
    fn random_plans_are_reproducible_and_survivable() {
        let a = FaultPlan::random(42, 3, 8);
        let b = FaultPlan::random(42, 3, 8);
        assert_eq!(a.injections, b.injections, "same seed, same schedule");
        let c = FaultPlan::random(43, 3, 8);
        assert_ne!(a.injections, c.injections, "different seed, different schedule");
        for w in 0..3 {
            assert_eq!(a.kill_on_task(w), None, "random plans never kill");
            assert_eq!(a.mute_on_task(w), None, "random plans never mute");
            for f in a.transport_faults(w) {
                assert!(!matches!(f, Fault::CorruptRecv { .. }), "random plans never corrupt");
            }
        }
    }

    #[test]
    fn faulty_transport_drops_duplicates_delays_and_corrupts() {
        let timeout = Duration::from_millis(50);
        // Drop the 0th send: the peer only sees the second frame.
        let (coord, worker) = in_proc_pair();
        let faulty = FaultyTransport::new(coord, vec![Fault::DropSend { nth: 0 }]);
        faulty.send(b"one").unwrap();
        faulty.send(b"two").unwrap();
        assert_eq!(worker.recv_timeout(timeout).unwrap().unwrap(), b"two");
        assert!(worker.recv_timeout(Duration::from_millis(5)).unwrap().is_none());

        // Duplicate the 0th receive: the frame arrives twice.
        let (coord, worker) = in_proc_pair();
        let faulty = FaultyTransport::new(coord, vec![Fault::DuplicateRecv { nth: 0 }]);
        worker.send(b"result").unwrap();
        assert_eq!(faulty.recv_timeout(timeout).unwrap().unwrap(), b"result");
        assert_eq!(faulty.recv_timeout(timeout).unwrap().unwrap(), b"result");

        // Delay the 0th receive: first poll sees nothing, a later poll
        // (after the delay matures) sees the frame.
        let (coord, worker) = in_proc_pair();
        let faulty = FaultyTransport::new(
            coord,
            vec![Fault::DelayRecv { nth: 0, delay: Duration::from_millis(20) }],
        );
        worker.send(b"late").unwrap();
        assert!(faulty.recv_timeout(timeout).unwrap().is_none(), "held back");
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(faulty.recv_timeout(timeout).unwrap().unwrap(), b"late");

        // Corrupt the 0th receive: bytes change, length does not.
        let (coord, worker) = in_proc_pair();
        let faulty = FaultyTransport::new(coord, vec![Fault::CorruptRecv { nth: 0 }]);
        let frame = b"LSW1\x10\x00\x00\x00{\"v\":1}".to_vec();
        worker.send(&frame).unwrap();
        let got = faulty.recv_timeout(timeout).unwrap().unwrap();
        assert_eq!(got.len(), frame.len());
        assert_ne!(got, frame);
        assert_eq!(&got[..8], &frame[..8], "prefix intact, payload garbled");
    }
}
