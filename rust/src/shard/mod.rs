//! Cross-host sharded serving: scatter fuse groups over workers, gather
//! bitwise-identical results, survive worker failure.
//!
//! The service's fused batch solve (PR 3) made every pair's result
//! bitwise independent of batch width and neighbours; this layer turns
//! that contract into horizontal scale. A [`ShardCoordinator`]
//! partitions a fuse group's weight pairs into contiguous chunks, ships
//! each as a [`crate::api::TaskEnvelope`] over a [`Transport`], and
//! reassembles the [`crate::api::DivergenceReport`]s — bit for bit the
//! ones a single-host solve produces, under any partition, any worker
//! assignment, and every survivable fault.
//!
//! Layers:
//!
//! * [`transport`] — byte-frame duplex links: in-process channels (the
//!   `--shard-workers` default) and length-prefixed TCP for real
//!   cross-host workers.
//! * [`worker`] — the executor loop: ping-responsive receive thread +
//!   solver thread running [`crate::api::OtProblem::divergence_all_planned`].
//! * [`coordinator`] — scatter/gather, heartbeat liveness, deadlines,
//!   bounded retry with re-scatter, straggler hedging, admission
//!   control, worker rejoin, graceful drain, `service.shard.*` metrics.
//! * [`testing`] — the deterministic fault-injection harness
//!   ([`FaultPlan`], now incarnation-scoped) driving
//!   `rust/tests/shard_fault_injection.rs` and the multi-round chaos
//!   soak in `rust/tests/shard_chaos_soak.rs`.
//!
//! The failure ladder, from mildest to terminal:
//!
//! 1. Straggler (slow but alive) → after `hedge_fraction ×
//!    task_deadline`, an identical copy goes to an idle live worker;
//!    first result wins, the loser dedups by `task_id`. Bitwise
//!    harmless by construction — both copies compute the same bits.
//! 2. Lost or late message → task deadline → re-scatter (bounded by
//!    `max_retries`, linear backoff). Duplicates are deduped by
//!    `task_id`; first result wins.
//! 3. Worker crash (link error) or hang (heartbeat timeout) → worker
//!    marked dead, its tasks re-scattered to survivors (a live hedge
//!    inherits first, without burning a retry).
//! 4. Corrupt frame → that worker's outstanding pairs fail with
//!    [`crate::error::Error::Wire`] (deterministic failures are not
//!    retried).
//! 5. No survivors / retries exhausted →
//!    [`crate::error::Error::Service`]. Always typed, never a panic,
//!    never a wrong answer.
//!
//! And the healing / protection rungs around it:
//!
//! * **Rejoin** — dead slots are re-dialled (TCP roster) or re-spawned
//!   (in-process) after `rejoin_backoff`, gated by a
//!   [`crate::runtime::wire::kinds::HELLO`] handshake that re-verifies
//!   [`crate::api::PLAN_FORMAT_MAJOR`]; a mixed-version rejoiner fails
//!   typed and never receives a task.
//! * **Shed** — groups beyond `max_inflight_groups` fail immediately
//!   with [`crate::error::Error::Overloaded`], before touching a
//!   worker.
//! * **Drain** — [`ShardCoordinator::drain`] stops admissions, lets
//!   in-flight groups finish, then tells workers to exit cleanly: zero
//!   orphaned tasks.

pub mod coordinator;
pub mod testing;
pub mod transport;
pub mod worker;

pub use coordinator::{ShardConfig, ShardCoordinator, METRIC_NAMES};
pub use testing::{Fault, FaultPlan, FaultyTransport};
pub use transport::{in_proc_pair, InProcTransport, TcpTransport, Transport};
pub use worker::{
    execute_task, run_worker, serve_connections, serve_listener, spawn_tcp_worker,
    spawn_tcp_worker_with, WorkerOptions,
};
