//! Cross-host sharded serving: scatter fuse groups over workers, gather
//! bitwise-identical results, survive worker failure.
//!
//! The service's fused batch solve (PR 3) made every pair's result
//! bitwise independent of batch width and neighbours; this layer turns
//! that contract into horizontal scale. A [`ShardCoordinator`]
//! partitions a fuse group's weight pairs into contiguous chunks, ships
//! each as a [`crate::api::TaskEnvelope`] over a [`Transport`], and
//! reassembles the [`crate::api::DivergenceReport`]s — bit for bit the
//! ones a single-host solve produces, under any partition, any worker
//! assignment, and every survivable fault.
//!
//! Layers:
//!
//! * [`transport`] — byte-frame duplex links: in-process channels (the
//!   `--shard-workers` default) and length-prefixed TCP for real
//!   cross-host workers.
//! * [`worker`] — the executor loop: ping-responsive receive thread +
//!   solver thread running [`crate::api::OtProblem::divergence_all_planned`].
//! * [`coordinator`] — scatter/gather, heartbeat liveness, deadlines,
//!   bounded retry with re-scatter, `service.shard.*` metrics.
//! * [`testing`] — the deterministic fault-injection harness
//!   ([`FaultPlan`]) driving `rust/tests/shard_fault_injection.rs`.
//!
//! The failure ladder, from mildest to terminal:
//!
//! 1. Lost or late message → task deadline → re-scatter (bounded by
//!    `max_retries`, linear backoff). Duplicates are deduped by
//!    `task_id`; first result wins.
//! 2. Worker crash (link error) or hang (heartbeat timeout) → worker
//!    marked dead, its tasks re-scattered to survivors.
//! 3. Corrupt frame → that worker's outstanding pairs fail with
//!    [`crate::error::Error::Wire`] (deterministic failures are not
//!    retried).
//! 4. No survivors / retries exhausted →
//!    [`crate::error::Error::Service`]. Always typed, never a panic,
//!    never a wrong answer.

pub mod coordinator;
pub mod testing;
pub mod transport;
pub mod worker;

pub use coordinator::{ShardConfig, ShardCoordinator, METRIC_NAMES};
pub use testing::{Fault, FaultPlan, FaultyTransport};
pub use transport::{in_proc_pair, InProcTransport, TcpTransport, Transport};
pub use worker::{execute_task, run_worker, serve_listener, spawn_tcp_worker, WorkerOptions};
