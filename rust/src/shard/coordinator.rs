//! The shard coordinator: scatter a fuse group over workers, gather the
//! results, and keep the answer correct when workers fail.
//!
//! ## Scatter
//!
//! [`ShardCoordinator::solve_group`] partitions a group's weight pairs
//! into contiguous chunks — one per live worker, near-equal sizes — and
//! ships each chunk as a [`TaskEnvelope`] (plan + measures + pairs +
//! the resolved feature map). The partition is pure bookkeeping: by the
//! batch contract (see `rust/tests/batched_equivalence.rs`) every pair's
//! result is bitwise independent of batch width and neighbours, so *any*
//! split, assignment, or re-assignment yields the same bits as the
//! single-host fused solve.
//!
//! ## Liveness and the failure ladder
//!
//! While tasks are outstanding the coordinator pings every live worker
//! each `heartbeat_interval`; workers pong from their receive loop even
//! mid-solve. A worker is declared dead when its link errors (crash —
//! detected immediately), or when nothing has been heard from it for
//! `heartbeat_timeout` (hang / mute). A task is re-scattered when its
//! worker dies or its `task_deadline` expires, up to `max_retries`
//! further attempts with linear backoff, each to the next live worker
//! round-robin. Identical `task_id`s make re-scatter idempotent: a late
//! original result and a retried result are interchangeable, and
//! whichever lands first wins (the other counts as
//! `service.shard.duplicate_results`).
//!
//! Unsurvivable failures surface as typed errors, never panics:
//! exhausted retries and a fully-dead worker set become
//! [`Error::Service`]; a corrupt result frame fails that worker's
//! outstanding pairs with [`Error::Wire`] (retrying a deterministic
//! decode failure would burn the budget for nothing).
//!
//! Everything is observable under `service.shard.*` — see
//! [`METRIC_NAMES`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::api::{DivergenceReport, Plan, ResultEnvelope, TaskEnvelope};
use crate::data::Measure;
use crate::error::{Error, Result};
use crate::features::GaussianFeatureMap;
use crate::metrics::Registry;
use crate::runtime::WireDoc;

use super::testing::FaultPlan;
use super::transport::{in_proc_pair, TcpTransport, Transport};
use super::worker::{run_worker, WorkerOptions};

/// Every counter the shard layer emits (the histogram
/// `service.shard.task_us` rides along), kept in one place so docs,
/// tests, and dashboards agree.
pub const METRIC_NAMES: &[&str] = &[
    "service.shard.scattered_tasks",
    "service.shard.gathered_results",
    "service.shard.retries",
    "service.shard.rescattered_pairs",
    "service.shard.worker_deaths",
    "service.shard.duplicate_results",
    "service.shard.corrupt_payloads",
    "service.shard.heartbeats",
    "service.shard.delegated_groups",
];

/// Liveness / retry policy.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Ping cadence while tasks are outstanding.
    pub heartbeat_interval: Duration,
    /// Silence longer than this declares a worker dead.
    pub heartbeat_timeout: Duration,
    /// An unanswered task older than this is re-scattered even if its
    /// worker still pongs (covers lost task frames).
    pub task_deadline: Duration,
    /// Re-scatter attempts after the initial send before the task fails
    /// with a typed [`Error::Service`].
    pub max_retries: usize,
    /// Base backoff before a re-scatter; grows linearly with the attempt
    /// number, capped at 500 ms.
    pub retry_backoff: Duration,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            heartbeat_interval: Duration::from_millis(50),
            heartbeat_timeout: Duration::from_secs(1),
            task_deadline: Duration::from_secs(30),
            max_retries: 2,
            retry_backoff: Duration::from_millis(20),
        }
    }
}

struct WorkerSlot {
    id: u64,
    transport: Arc<dyn Transport>,
    alive: bool,
    last_seen: Instant,
    join: Option<JoinHandle<()>>,
}

struct Inner {
    workers: Vec<WorkerSlot>,
    next_group: u64,
}

/// One in-flight scatter unit and its retry bookkeeping.
struct TaskState {
    task_id: u64,
    /// Pair range `start..start + len` of the group this task covers.
    start: usize,
    len: usize,
    /// The encoded envelope, kept verbatim for re-scatter: identical
    /// bytes + identical `task_id` = idempotent retries.
    frame: Vec<u8>,
    worker: usize,
    sent_at: Instant,
    attempts: usize,
    done: bool,
}

/// A transport whose peer is gone; swapped in at shutdown so in-process
/// workers observe a dropped link even if the shutdown frame was lost.
struct ClosedTransport;

impl Transport for ClosedTransport {
    fn send(&self, _frame: &[u8]) -> Result<()> {
        Err(Error::Service("shard transport closed".into()))
    }
    fn recv_timeout(&self, _timeout: Duration) -> Result<Option<Vec<u8>>> {
        Err(Error::Service("shard transport closed".into()))
    }
}

pub struct ShardCoordinator {
    inner: Mutex<Inner>,
    cfg: ShardConfig,
    metrics: Arc<Registry>,
    next_task: AtomicU64,
}

impl ShardCoordinator {
    /// Spawn `n` in-process workers connected over channel transports.
    pub fn in_process(n: usize, cfg: ShardConfig, metrics: Arc<Registry>) -> ShardCoordinator {
        Self::in_process_with_faults(n, cfg, metrics, &FaultPlan::none())
    }

    /// Like [`Self::in_process`], with a scripted fault schedule (the
    /// fault-injection harness entry point).
    pub fn in_process_with_faults(
        n: usize,
        cfg: ShardConfig,
        metrics: Arc<Registry>,
        faults: &FaultPlan,
    ) -> ShardCoordinator {
        let n = n.max(1);
        let mut workers = Vec::with_capacity(n);
        for idx in 0..n {
            let (coord_end, worker_end) = in_proc_pair();
            let opts = WorkerOptions {
                exit_on_task: faults.kill_on_task(idx),
                mute_on_task: faults.mute_on_task(idx),
            };
            let worker_end: Arc<dyn Transport> = Arc::new(worker_end);
            let wid = idx as u64;
            let join = thread::Builder::new()
                .name(format!("ls-shard-worker-{idx}"))
                .spawn(move || run_worker(wid, worker_end, opts))
                .expect("spawn shard worker");
            let transport: Arc<dyn Transport> = if faults.has_transport_faults(idx) {
                Arc::new(super::testing::FaultyTransport::new(
                    coord_end,
                    faults.transport_faults(idx),
                ))
            } else {
                Arc::new(coord_end)
            };
            workers.push(WorkerSlot {
                id: wid,
                transport,
                alive: true,
                last_seen: Instant::now(),
                join: Some(join),
            });
        }
        ShardCoordinator {
            inner: Mutex::new(Inner { workers, next_group: 0 }),
            cfg,
            metrics,
            next_task: AtomicU64::new(0),
        }
    }

    /// Connect to already-listening cross-host workers (see
    /// `shard::worker::serve_listener`).
    pub fn connect(
        addrs: &[String],
        cfg: ShardConfig,
        metrics: Arc<Registry>,
    ) -> Result<ShardCoordinator> {
        if addrs.is_empty() {
            return Err(Error::Config("shard connect: no worker addresses".into()));
        }
        let mut workers = Vec::with_capacity(addrs.len());
        for (idx, addr) in addrs.iter().enumerate() {
            let transport: Arc<dyn Transport> = Arc::new(TcpTransport::connect(addr)?);
            workers.push(WorkerSlot {
                id: idx as u64,
                transport,
                alive: true,
                last_seen: Instant::now(),
                join: None,
            });
        }
        Ok(ShardCoordinator {
            inner: Mutex::new(Inner { workers, next_group: 0 }),
            cfg,
            metrics,
            next_task: AtomicU64::new(0),
        })
    }

    pub fn worker_count(&self) -> usize {
        self.inner.lock().unwrap().workers.len()
    }

    /// Workers not yet declared dead.
    pub fn live_workers(&self) -> usize {
        self.inner.lock().unwrap().workers.iter().filter(|w| w.alive).count()
    }

    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// Solve one fuse group across the worker set. Returns one slot per
    /// pair, index-aligned with `pairs`; survivable faults are absorbed
    /// by retry, unsurvivable ones surface as typed errors in the
    /// affected slots.
    ///
    /// `map` should be the exact feature map the local path would solve
    /// with (service cache maps are not refittable from `plan.seed` —
    /// see [`TaskEnvelope`]); `request_ids`, when index-aligned with
    /// `pairs`, rides along for observability.
    pub fn solve_group(
        &self,
        plan: &Plan,
        mu: &Measure,
        nu: &Measure,
        pairs: &[(&[f32], &[f32])],
        map: Option<&GaussianFeatureMap>,
        request_ids: &[u64],
    ) -> Vec<Result<DivergenceReport>> {
        let b = pairs.len();
        if b == 0 {
            return Vec::new();
        }
        let mut guard = self.inner.lock().unwrap();
        let inner = &mut *guard;
        let group_id = inner.next_group;
        inner.next_group += 1;

        // A fresh group resets staleness: silence *before* this group
        // says nothing about liveness during it.
        let now = Instant::now();
        for w in inner.workers.iter_mut().filter(|w| w.alive) {
            w.last_seen = now;
        }

        let live: Vec<usize> =
            (0..inner.workers.len()).filter(|&i| inner.workers[i].alive).collect();
        let mut out: Vec<Option<Result<DivergenceReport>>> = (0..b).map(|_| None).collect();
        if live.is_empty() {
            return (0..b)
                .map(|_| Err(Error::Service("no live shard workers".into())))
                .collect();
        }

        // Scatter: contiguous near-equal chunks, one per live worker.
        let chunks = live.len().min(b);
        let (base, extra) = (b / chunks, b % chunks);
        let mut tasks: Vec<TaskState> = Vec::with_capacity(chunks);
        let mut start = 0usize;
        for (ci, &widx) in live.iter().take(chunks).enumerate() {
            let len = base + usize::from(ci < extra);
            let task_id = self.next_task.fetch_add(1, Ordering::SeqCst);
            let env = TaskEnvelope {
                task_id,
                group_id,
                request_ids: if request_ids.len() == b {
                    request_ids[start..start + len].to_vec()
                } else {
                    Vec::new()
                },
                plan: plan.clone(),
                mu: mu.clone(),
                nu: nu.clone(),
                pairs: pairs[start..start + len]
                    .iter()
                    .map(|(a, bw)| (a.to_vec(), bw.to_vec()))
                    .collect(),
                map: map.cloned(),
            };
            let frame = env.encode();
            self.metrics.counter("service.shard.scattered_tasks").inc();
            if inner.workers[widx].transport.send(&frame).is_err() {
                // Dead on arrival: the retry ladder below reassigns.
                self.mark_dead(&mut inner.workers[widx]);
            }
            tasks.push(TaskState {
                task_id,
                start,
                len,
                frame,
                worker: widx,
                sent_at: Instant::now(),
                attempts: 0,
                done: false,
            });
            start += len;
        }

        // Gather until every task resolved (result, typed failure, or
        // total worker loss).
        let mut outstanding = tasks.len();
        let mut last_ping = Instant::now();
        'gather: while outstanding > 0 {
            // Drain every live worker's inbox.
            for widx in 0..inner.workers.len() {
                if !inner.workers[widx].alive {
                    continue;
                }
                let transport = Arc::clone(&inner.workers[widx].transport);
                loop {
                    match transport.recv_timeout(Duration::from_millis(1)) {
                        Ok(Some(frame)) => self.handle_frame(
                            &mut inner.workers,
                            widx,
                            &frame,
                            &mut tasks,
                            &mut out,
                            &mut outstanding,
                        ),
                        Ok(None) => break,
                        Err(_) => {
                            self.mark_dead(&mut inner.workers[widx]);
                            break;
                        }
                    }
                }
            }

            // Heartbeats.
            if last_ping.elapsed() >= self.cfg.heartbeat_interval {
                last_ping = Instant::now();
                let mut ping = WireDoc::with_kind("ping");
                ping.set_u64("group_id", group_id);
                let ping = ping.encode();
                for w in inner.workers.iter_mut().filter(|w| w.alive) {
                    self.metrics.counter("service.shard.heartbeats").inc();
                    if w.transport.send(&ping).is_err() {
                        w.alive = false;
                        self.metrics.counter("service.shard.worker_deaths").inc();
                    }
                }
            }

            // Liveness + deadline ladder.
            for ti in 0..tasks.len() {
                if tasks[ti].done {
                    continue;
                }
                let widx = tasks[ti].worker;
                let worker_dead = !inner.workers[widx].alive;
                let stale =
                    inner.workers[widx].last_seen.elapsed() > self.cfg.heartbeat_timeout;
                let expired = tasks[ti].sent_at.elapsed() > self.cfg.task_deadline;
                if !(worker_dead || stale || expired) {
                    continue;
                }
                if stale && !worker_dead {
                    self.mark_dead(&mut inner.workers[widx]);
                }
                tasks[ti].attempts += 1;
                let attempts = tasks[ti].attempts;
                if attempts > self.cfg.max_retries {
                    let task_id = tasks[ti].task_id;
                    fail_task(&mut tasks[ti], &mut out, &mut outstanding, &|| {
                        Error::Service(format!(
                            "shard task {task_id} failed after {attempts} attempts"
                        ))
                    });
                    continue;
                }
                // Next live worker round-robin; the current one only as a
                // last resort (deadline expiry with nowhere else to go).
                let n = inner.workers.len();
                let next = (1..=n)
                    .map(|k| (widx + k) % n)
                    .find(|&c| inner.workers[c].alive);
                let Some(next) = next else {
                    for t in tasks.iter_mut().filter(|t| !t.done) {
                        fail_task(t, &mut out, &mut outstanding, &|| {
                            Error::Service("all shard workers dead".into())
                        });
                    }
                    break 'gather;
                };
                self.metrics.counter("service.shard.retries").inc();
                self.metrics
                    .counter("service.shard.rescattered_pairs")
                    .add(tasks[ti].len as u64);
                let backoff = self
                    .cfg
                    .retry_backoff
                    .saturating_mul(attempts as u32)
                    .min(Duration::from_millis(500));
                thread::sleep(backoff);
                tasks[ti].worker = next;
                tasks[ti].sent_at = Instant::now();
                if inner.workers[next].transport.send(&tasks[ti].frame).is_err() {
                    // Also dead: the next ladder pass moves on again.
                    self.mark_dead(&mut inner.workers[next]);
                }
            }
        }

        // Final sweep: collect whatever is still in flight (late
        // originals after a retry won the race) so duplicates are
        // observed rather than left queued.
        for widx in 0..inner.workers.len() {
            if !inner.workers[widx].alive {
                continue;
            }
            let transport = Arc::clone(&inner.workers[widx].transport);
            while let Ok(Some(frame)) = transport.recv_timeout(Duration::from_millis(2)) {
                self.handle_frame(
                    &mut inner.workers,
                    widx,
                    &frame,
                    &mut tasks,
                    &mut out,
                    &mut outstanding,
                );
            }
        }

        out.into_iter()
            .map(|slot| {
                slot.unwrap_or_else(|| Err(Error::Service("shard gather left a hole".into())))
            })
            .collect()
    }

    fn mark_dead(&self, w: &mut WorkerSlot) {
        if w.alive {
            w.alive = false;
            self.metrics.counter("service.shard.worker_deaths").inc();
        }
    }

    /// Process one inbound frame from `widx`'s link.
    fn handle_frame(
        &self,
        workers: &mut [WorkerSlot],
        widx: usize,
        frame: &[u8],
        tasks: &mut [TaskState],
        out: &mut [Option<Result<DivergenceReport>>],
        outstanding: &mut usize,
    ) {
        let doc = match WireDoc::decode(frame) {
            Ok(doc) => doc,
            Err(e) => {
                self.corrupt_from(workers, widx, tasks, out, outstanding, &e);
                return;
            }
        };
        workers[widx].last_seen = Instant::now();
        match doc.kind() {
            "pong" => {}
            "reject" => {
                // The worker could not even decode the task: a
                // deterministic failure, so fail typed instead of
                // retrying.
                let task_id = doc.get_u64("task_id").ok();
                let msg = doc
                    .get_str("error")
                    .unwrap_or("task rejected by worker")
                    .to_string();
                if let Some(t) =
                    tasks.iter_mut().find(|t| Some(t.task_id) == task_id && !t.done)
                {
                    fail_task(t, out, outstanding, &|| {
                        Error::Wire(format!("worker rejected task: {msg}"))
                    });
                }
            }
            "result" => match ResultEnvelope::decode(frame) {
                Err(e) => self.corrupt_from(workers, widx, tasks, out, outstanding, &e),
                Ok(env) => {
                    let Some(t) = tasks.iter_mut().find(|t| t.task_id == env.task_id) else {
                        // A stale frame from an earlier group.
                        self.metrics.counter("service.shard.duplicate_results").inc();
                        return;
                    };
                    if t.done {
                        self.metrics.counter("service.shard.duplicate_results").inc();
                        return;
                    }
                    if env.results.len() != t.len {
                        let (got, want) = (env.results.len(), t.len);
                        fail_task(t, out, outstanding, &|| {
                            Error::Wire(format!(
                                "result envelope has {got} pairs, task expected {want}"
                            ))
                        });
                        return;
                    }
                    let elapsed = t.sent_at.elapsed();
                    for (off, r) in env.results.into_iter().enumerate() {
                        out[t.start + off] = Some(r);
                    }
                    t.done = true;
                    *outstanding -= 1;
                    self.metrics.counter("service.shard.gathered_results").inc();
                    self.metrics
                        .histogram("service.shard.task_us")
                        .observe_us(elapsed.as_micros() as u64);
                }
            },
            _ => {}
        }
    }

    /// A frame from `widx` failed to decode: unsurvivable for that
    /// worker's outstanding work (retrying a deterministic decode
    /// failure is pointless), and the link is no longer trusted.
    fn corrupt_from(
        &self,
        workers: &mut [WorkerSlot],
        widx: usize,
        tasks: &mut [TaskState],
        out: &mut [Option<Result<DivergenceReport>>],
        outstanding: &mut usize,
        err: &Error,
    ) {
        self.metrics.counter("service.shard.corrupt_payloads").inc();
        let worker_id = workers[widx].id;
        self.mark_dead(&mut workers[widx]);
        let msg = format!("corrupt frame from shard worker {worker_id}: {err}");
        for t in tasks.iter_mut().filter(|t| !t.done && t.worker == widx) {
            fail_task(t, out, outstanding, &|| Error::Wire(msg.clone()));
        }
    }
}

/// Resolve every pair slot of `t` with a fresh instance of the error.
fn fail_task(
    t: &mut TaskState,
    out: &mut [Option<Result<DivergenceReport>>],
    outstanding: &mut usize,
    mk: &dyn Fn() -> Error,
) {
    for slot in &mut out[t.start..t.start + t.len] {
        *slot = Some(Err(mk()));
    }
    t.done = true;
    *outstanding -= 1;
}

impl Drop for ShardCoordinator {
    fn drop(&mut self) {
        let mut inner = self.inner.lock().unwrap();
        let shutdown = WireDoc::with_kind("shutdown").encode();
        for w in inner.workers.iter_mut() {
            let _ = w.transport.send(&shutdown);
            // Drop our endpoint too: a worker that missed the frame
            // (dropped by a fault, or mid-solve) still sees the link
            // close and exits.
            w.transport = Arc::new(ClosedTransport);
        }
        for w in inner.workers.iter_mut() {
            if let Some(join) = w.join.take() {
                let _ = join.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::OtProblem;
    use crate::data;
    use crate::rng::Rng;
    use crate::shard::testing::Fault;

    fn quick_cfg() -> ShardConfig {
        ShardConfig {
            heartbeat_interval: Duration::from_millis(20),
            heartbeat_timeout: Duration::from_millis(400),
            task_deadline: Duration::from_secs(5),
            max_retries: 2,
            retry_backoff: Duration::from_millis(5),
        }
    }

    fn fixture(pairs: usize) -> (Measure, Measure, Vec<(Vec<f32>, Vec<f32>)>, Plan) {
        let mut rng = Rng::seed_from(17);
        let (mu, nu) = data::gaussian_blobs(14, &mut rng);
        let mut weights = Vec::with_capacity(pairs);
        for _ in 0..pairs {
            let mut a = rng.normal_vec(mu.len());
            let mut b = rng.normal_vec(nu.len());
            for w in a.iter_mut().chain(b.iter_mut()) {
                *w = w.abs() + 0.05;
            }
            let (sa, sb) = (a.iter().sum::<f32>(), b.iter().sum::<f32>());
            a.iter_mut().for_each(|w| *w /= sa);
            b.iter_mut().for_each(|w| *w /= sb);
            weights.push((a, b));
        }
        let refs: Vec<(&[f32], &[f32])> =
            weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let plan = OtProblem::new(&mu, &nu)
            .epsilon(0.5)
            .rank(8)
            .seed(23)
            .weight_pairs(&refs)
            .plan()
            .unwrap();
        (mu, nu, weights, plan)
    }

    fn assert_bitwise(shard: &[Result<DivergenceReport>], local: &[Result<DivergenceReport>]) {
        assert_eq!(shard.len(), local.len());
        for (s, l) in shard.iter().zip(local) {
            let (s, l) = (s.as_ref().unwrap(), l.as_ref().unwrap());
            assert_eq!(s.divergence.to_bits(), l.divergence.to_bits());
            assert_eq!(s.xy.objective.to_bits(), l.xy.objective.to_bits());
            assert_eq!(s.xy.u, l.xy.u);
            assert_eq!(s.xx.v, l.xx.v);
            assert_eq!(s.yy.iterations, l.yy.iterations);
        }
    }

    #[test]
    fn sharded_solve_matches_local_bitwise() {
        let (mu, nu, weights, plan) = fixture(5);
        let refs: Vec<(&[f32], &[f32])> =
            weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let local = OtProblem::new(&mu, &nu).weight_pairs(&refs).divergence_all_planned(&plan);

        let metrics = Arc::new(Registry::default());
        let shard = ShardCoordinator::in_process(2, quick_cfg(), metrics.clone());
        let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[1, 2, 3, 4, 5]);
        assert_bitwise(&got, &local);
        assert_eq!(metrics.counter("service.shard.scattered_tasks").get(), 2);
        assert_eq!(metrics.counter("service.shard.gathered_results").get(), 2);
        assert_eq!(metrics.counter("service.shard.retries").get(), 0);
        assert_eq!(shard.live_workers(), 2);
    }

    #[test]
    fn uneven_partitions_and_single_pair_groups_work() {
        let (mu, nu, weights, plan) = fixture(3);
        let refs: Vec<(&[f32], &[f32])> =
            weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let local = OtProblem::new(&mu, &nu).weight_pairs(&refs).divergence_all_planned(&plan);

        let metrics = Arc::new(Registry::default());
        // 4 workers, 3 pairs: only 3 chunks go out, one worker idles.
        let shard = ShardCoordinator::in_process(4, quick_cfg(), metrics);
        let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
        assert_bitwise(&got, &local);

        // A single-pair group lands on one worker.
        let one = &refs[..1];
        let got = shard.solve_group(&plan, &mu, &nu, one, None, &[9]);
        assert_bitwise(&got, &local[..1]);
        assert!(shard.solve_group(&plan, &mu, &nu, &[], None, &[]).is_empty());
    }

    #[test]
    fn all_workers_dead_is_a_typed_error() {
        let (mu, nu, weights, plan) = fixture(4);
        let refs: Vec<(&[f32], &[f32])> =
            weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let metrics = Arc::new(Registry::default());
        // Every worker crashes on its first task: no survivors.
        let faults = FaultPlan::new(1)
            .inject(0, Fault::KillOnTask { nth: 1 })
            .inject(1, Fault::KillOnTask { nth: 1 });
        let shard =
            ShardCoordinator::in_process_with_faults(2, quick_cfg(), metrics.clone(), &faults);
        let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
        assert_eq!(got.len(), refs.len());
        for slot in &got {
            assert!(
                matches!(slot, Err(Error::Service(_))),
                "expected typed service error, got {slot:?}"
            );
        }
        assert_eq!(shard.live_workers(), 0);
        assert_eq!(metrics.counter("service.shard.worker_deaths").get(), 2);
        // A follow-up group fails fast, also typed.
        let again = shard.solve_group(&plan, &mu, &nu, &refs[..1], None, &[]);
        assert!(matches!(&again[0], Err(Error::Service(_))));
    }
}
