//! The shard coordinator: scatter a fuse group over workers, gather the
//! results, and keep the answer correct when workers fail — then heal
//! the fleet and keep serving.
//!
//! ## Scatter
//!
//! [`ShardCoordinator::solve_group`] partitions a group's weight pairs
//! into contiguous chunks — one per live worker, near-equal sizes — and
//! ships each chunk as a [`TaskEnvelope`] (plan + measures + pairs +
//! the resolved feature map). The partition is pure bookkeeping: by the
//! batch contract (see `rust/tests/batched_equivalence.rs`) every pair's
//! result is bitwise independent of batch width and neighbours, so *any*
//! split, assignment, or re-assignment yields the same bits as the
//! single-host fused solve.
//!
//! ## Liveness and the failure ladder
//!
//! While tasks are outstanding the coordinator pings every live worker
//! each `heartbeat_interval`; workers pong from their receive loop even
//! mid-solve. A worker is declared dead when its link errors (crash —
//! detected immediately), or when nothing has been heard from it for
//! `heartbeat_timeout` (hang / mute). A task is re-scattered when its
//! worker dies or its `task_deadline` expires, up to `max_retries`
//! further attempts with linear backoff, each to the next live worker
//! round-robin. Identical `task_id`s make re-scatter idempotent: a late
//! original result and a retried result are interchangeable, and
//! whichever lands first wins (the other counts as
//! `service.shard.duplicate_results`).
//!
//! Unsurvivable failures surface as typed errors, never panics:
//! exhausted retries and a fully-dead worker set become
//! [`Error::Service`]; a corrupt result frame fails that worker's
//! outstanding pairs with [`Error::Wire`] (retrying a deterministic
//! decode failure would burn the budget for nothing).
//!
//! ## Self-healing membership
//!
//! Death is no longer terminal. Every worker slot carries a *respawn*
//! factory — re-dial the roster address for TCP workers, spawn a fresh
//! thread for in-process ones — and the coordinator periodically
//! re-attempts dead slots (at group start and on every heartbeat tick,
//! throttled by `rejoin_backoff`). A rejoin runs the
//! [`crate::runtime::wire::kinds::HELLO`] handshake first: both sides
//! exchange their [`crate::api::PLAN_FORMAT_MAJOR`], and a mismatch
//! fails the rejoin typed (`service.shard.rejoin_failures`) so a
//! mixed-version fleet can never mis-decode a task. A successful rejoin
//! bumps the slot's incarnation, counts `service.shard.rejoins`, and the
//! slot is immediately eligible for new tasks and retries.
//!
//! ## Straggler hedging
//!
//! A slow-but-alive worker (answers pings, sits on a long solve) used to
//! stall its chunk until the task deadline. Now, once a task has been
//! outstanding for `hedge_fraction × task_deadline` and some live worker
//! is idle, the coordinator speculatively re-sends the *identical*
//! frame (same `task_id`, same bytes) to the idle worker
//! (`service.shard.hedged_tasks`). First result wins, the loser dedups —
//! and since both copies compute bitwise-identical answers by the batch
//! contract, hedging can never change a result, only its latency. If the
//! primary dies, a live hedge inherits the task without burning a retry.
//!
//! ## Admission control and graceful drain
//!
//! Concurrent groups are admitted against a bounded in-flight budget
//! (`max_inflight_groups`); beyond it, [`solve_group`] sheds the whole
//! group as typed [`Error::Overloaded`] *before* queueing on the worker
//! set (`service.shard.shed_groups`, gauge
//! `service.shard.inflight_groups`). [`ShardCoordinator::drain`] stops
//! admissions, waits for in-flight groups, then sends every live worker
//! a [`crate::runtime::wire::kinds::DRAIN`] frame; workers finish queued
//! solves, acknowledge, and exit — zero orphaned tasks
//! (`service.shard.drained_workers`).
//!
//! Everything is observable under `service.shard.*` — see
//! [`METRIC_NAMES`].
//!
//! [`solve_group`]: ShardCoordinator::solve_group

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::api::{
    DivergenceReport, Plan, ResultEnvelope, SessionDelta, SessionResultEnvelope, SessionSolveOut,
    TaskEnvelope, PLAN_FORMAT_MAJOR,
};
use crate::data::Measure;
use crate::error::{Error, Result};
use crate::features::GaussianFeatureMap;
use crate::metrics::Registry;
use crate::runtime::wire::kinds;
use crate::runtime::WireDoc;

use super::testing::{FaultPlan, FaultyTransport};
use super::transport::{in_proc_pair, TcpTransport, Transport};
use super::worker::run_worker;

/// Every counter the shard layer emits (the histogram
/// `service.shard.task_us` and the gauge `service.shard.inflight_groups`
/// ride along), kept in one place so docs, tests, and dashboards agree.
pub const METRIC_NAMES: &[&str] = &[
    "service.shard.scattered_tasks",
    "service.shard.gathered_results",
    "service.shard.retries",
    "service.shard.rescattered_pairs",
    "service.shard.worker_deaths",
    "service.shard.duplicate_results",
    "service.shard.corrupt_payloads",
    "service.shard.heartbeats",
    "service.shard.delegated_groups",
    "service.shard.rejoins",
    "service.shard.rejoin_failures",
    "service.shard.hedged_tasks",
    "service.shard.hedge_wins",
    "service.shard.shed_groups",
    "service.shard.drained_workers",
];

/// Liveness / retry / membership policy.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Ping cadence while tasks are outstanding.
    pub heartbeat_interval: Duration,
    /// Silence longer than this declares a worker dead.
    pub heartbeat_timeout: Duration,
    /// An unanswered task older than this is re-scattered even if its
    /// worker still pongs (covers lost task frames).
    pub task_deadline: Duration,
    /// Re-scatter attempts after the initial send before the task fails
    /// with a typed [`Error::Service`].
    pub max_retries: usize,
    /// Base backoff before a re-scatter; grows linearly with the attempt
    /// number, capped at 500 ms.
    pub retry_backoff: Duration,
    /// Fraction of `task_deadline` after which an unanswered task is
    /// speculatively re-sent to an idle live worker (straggler hedging).
    /// `0.0` disables hedging.
    pub hedge_fraction: f64,
    /// Bounded in-flight group budget: groups beyond this shed with
    /// typed [`Error::Overloaded`] instead of queueing.
    pub max_inflight_groups: usize,
    /// Minimum wait between rejoin attempts for a dead worker slot.
    pub rejoin_backoff: Duration,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            heartbeat_interval: Duration::from_millis(50),
            heartbeat_timeout: Duration::from_secs(1),
            task_deadline: Duration::from_secs(30),
            max_retries: 2,
            retry_backoff: Duration::from_millis(20),
            hedge_fraction: 0.5,
            max_inflight_groups: 16,
            rejoin_backoff: Duration::from_millis(250),
        }
    }
}

/// Factory for a fresh incarnation of one worker's link: given the new
/// incarnation number, re-establish a transport (re-dial the roster
/// address, or spawn a fresh in-process thread). The thread handle is
/// `None` for remote workers.
type Respawn = Box<dyn Fn(u64) -> Result<(Arc<dyn Transport>, Option<JoinHandle<()>>)> + Send>;

struct WorkerSlot {
    id: u64,
    transport: Arc<dyn Transport>,
    alive: bool,
    last_seen: Instant,
    /// When the slot was declared dead (throttles rejoin attempts).
    died_at: Option<Instant>,
    /// 0 = initial spawn, +1 per successful rejoin. Keys the fault
    /// plan's incarnation-scoped injections.
    incarnation: u64,
    join: Option<JoinHandle<()>>,
    /// `None` = this slot cannot rejoin (drained, or no factory).
    respawn: Option<Respawn>,
}

struct Inner {
    workers: Vec<WorkerSlot>,
    next_group: u64,
    /// Threads of superseded incarnations, joined at drain/drop.
    graveyard: Vec<JoinHandle<()>>,
}

/// One in-flight scatter unit and its retry bookkeeping.
struct TaskState {
    task_id: u64,
    /// Pair range `start..start + len` of the group this task covers.
    start: usize,
    len: usize,
    /// The encoded envelope, kept verbatim for re-scatter *and* hedging:
    /// identical bytes + identical `task_id` = idempotent copies.
    frame: Vec<u8>,
    worker: usize,
    sent_at: Instant,
    attempts: usize,
    /// Speculative second home, if hedged (and when the copy went out).
    hedge_worker: Option<usize>,
    hedged_at: Option<Instant>,
    done: bool,
}

/// A transport whose peer is gone; swapped in at shutdown/drain so
/// in-process workers observe a dropped link even if the control frame
/// was lost.
struct ClosedTransport;

impl Transport for ClosedTransport {
    fn send(&self, _frame: &[u8]) -> Result<()> {
        Err(Error::Service("shard transport closed".into()))
    }
    fn recv_timeout(&self, _timeout: Duration) -> Result<Option<Vec<u8>>> {
        Err(Error::Service("shard transport closed".into()))
    }
}

/// Decrements the in-flight group count (and gauge) however
/// [`ShardCoordinator::solve_group`] exits.
struct InflightGuard<'a> {
    coordinator: &'a ShardCoordinator,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        let now = self.coordinator.inflight.fetch_sub(1, Ordering::SeqCst) - 1;
        self.coordinator.metrics.gauge("service.shard.inflight_groups").set(now as i64);
    }
}

pub struct ShardCoordinator {
    inner: Mutex<Inner>,
    cfg: ShardConfig,
    metrics: Arc<Registry>,
    next_task: AtomicU64,
    /// Set by [`Self::drain`]: no further groups are admitted and dead
    /// slots stop rejoining.
    draining: AtomicBool,
    /// Groups currently inside (or queued on) [`Self::solve_group`].
    inflight: AtomicUsize,
}

impl ShardCoordinator {
    /// Spawn `n` in-process workers connected over channel transports.
    pub fn in_process(n: usize, cfg: ShardConfig, metrics: Arc<Registry>) -> ShardCoordinator {
        Self::in_process_with_faults(n, cfg, metrics, &FaultPlan::none())
    }

    /// Like [`Self::in_process`], with a scripted fault schedule (the
    /// fault-injection harness entry point). Each worker slot gets a
    /// respawn factory, so a killed worker rejoins as its next
    /// incarnation with that incarnation's scripted faults.
    pub fn in_process_with_faults(
        n: usize,
        cfg: ShardConfig,
        metrics: Arc<Registry>,
        faults: &FaultPlan,
    ) -> ShardCoordinator {
        let n = n.max(1);
        let faults = Arc::new(faults.clone());
        let mut workers = Vec::with_capacity(n);
        for idx in 0..n {
            let faults = Arc::clone(&faults);
            let respawn: Respawn = Box::new(move |inc: u64| {
                let (coord_end, worker_end) = in_proc_pair();
                let opts = faults.worker_options(idx, inc);
                let worker_end: Arc<dyn Transport> = Arc::new(worker_end);
                let wid = idx as u64;
                let join = thread::Builder::new()
                    .name(format!("ls-shard-worker-{idx}-i{inc}"))
                    .spawn(move || run_worker(wid, worker_end, opts))
                    .map_err(|e| Error::Service(format!("spawn shard worker: {e}")))?;
                let transport: Arc<dyn Transport> = if faults.has_transport_faults_at(idx, inc) {
                    Arc::new(FaultyTransport::new(
                        coord_end,
                        faults.transport_faults_at(idx, inc),
                    ))
                } else {
                    Arc::new(coord_end)
                };
                Ok((transport, Some(join)))
            });
            let (transport, join) = respawn(0).expect("spawn shard worker");
            workers.push(WorkerSlot {
                id: idx as u64,
                transport,
                alive: true,
                last_seen: Instant::now(),
                died_at: None,
                incarnation: 0,
                join,
                respawn: Some(respawn),
            });
        }
        ShardCoordinator {
            inner: Mutex::new(Inner { workers, next_group: 0, graveyard: Vec::new() }),
            cfg,
            metrics,
            next_task: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
        }
    }

    /// Connect to already-listening cross-host workers (the roster: see
    /// `shard::worker::serve_listener` and `--shard-worker-file`). Each
    /// address is dialled and handshaken up front — a version-mismatched
    /// or unreachable roster entry fails construction typed — and kept
    /// as the slot's respawn target, so a worker that later dies is
    /// re-dialled and rejoins.
    pub fn connect(
        addrs: &[String],
        cfg: ShardConfig,
        metrics: Arc<Registry>,
    ) -> Result<ShardCoordinator> {
        if addrs.is_empty() {
            return Err(Error::Config("shard connect: no worker addresses".into()));
        }
        let mut workers = Vec::with_capacity(addrs.len());
        for (idx, addr) in addrs.iter().enumerate() {
            let addr = addr.clone();
            let respawn: Respawn = Box::new(move |_inc: u64| {
                let transport: Arc<dyn Transport> = Arc::new(TcpTransport::connect(&addr)?);
                Ok((transport, None))
            });
            let (transport, join) = respawn(0)?;
            handshake(&transport, cfg.heartbeat_timeout)?;
            workers.push(WorkerSlot {
                id: idx as u64,
                transport,
                alive: true,
                last_seen: Instant::now(),
                died_at: None,
                incarnation: 0,
                join,
                respawn: Some(respawn),
            });
        }
        Ok(ShardCoordinator {
            inner: Mutex::new(Inner { workers, next_group: 0, graveyard: Vec::new() }),
            cfg,
            metrics,
            next_task: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
        })
    }

    /// Poison-recovering lock: a panicked thread (a test assertion, a
    /// worker bug) must not cascade into every later `solve_group`
    /// panicking on a poisoned mutex — the coordinator state is valid at
    /// every point a panic can unwind through.
    fn lock_inner(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    pub fn worker_count(&self) -> usize {
        self.lock_inner().workers.len()
    }

    /// Workers not yet declared dead.
    pub fn live_workers(&self) -> usize {
        self.lock_inner().workers.iter().filter(|w| w.alive).count()
    }

    /// Groups currently admitted into [`Self::solve_group`].
    pub fn inflight_groups(&self) -> usize {
        self.inflight.load(Ordering::SeqCst)
    }

    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    /// Attempt to rejoin dead worker slots whose backoff has elapsed
    /// (also runs automatically at group start and on every heartbeat
    /// tick). Returns how many workers rejoined. Public so tests and
    /// maintenance loops can pump membership without traffic.
    pub fn pump_rejoins(&self) -> usize {
        let mut inner = self.lock_inner();
        self.try_rejoins(&mut inner)
    }

    fn try_rejoins(&self, inner: &mut Inner) -> usize {
        if self.draining.load(Ordering::SeqCst) {
            return 0;
        }
        let mut rejoined = 0usize;
        let Inner { workers, graveyard, .. } = inner;
        for w in workers.iter_mut() {
            if w.alive {
                continue;
            }
            let Some(respawn) = w.respawn.as_ref() else { continue };
            if let Some(died_at) = w.died_at {
                if died_at.elapsed() < self.cfg.rejoin_backoff {
                    continue;
                }
            }
            let next_inc = w.incarnation + 1;
            match respawn(next_inc) {
                Err(_) => {
                    // Unreachable (TCP refused, spawn failed): re-arm the
                    // backoff and try again later.
                    w.died_at = Some(Instant::now());
                    self.metrics.counter("service.shard.rejoin_failures").inc();
                }
                Ok((transport, join)) => {
                    match handshake(&transport, self.cfg.heartbeat_timeout) {
                        Ok(()) => {
                            // The superseded life's thread parks in the
                            // graveyard (joined at drain/drop — its link
                            // is long dead, so it has already exited or
                            // will the moment it polls).
                            if let Some(old) = w.join.take() {
                                graveyard.push(old);
                            }
                            w.transport = transport;
                            w.join = join;
                            w.incarnation = next_inc;
                            w.alive = true;
                            w.last_seen = Instant::now();
                            w.died_at = None;
                            self.metrics.counter("service.shard.rejoins").inc();
                            rejoined += 1;
                        }
                        Err(_) => {
                            // Version mismatch or a dead handshake: the
                            // fresh life is unusable. Dropping its
                            // transport closes the link so the spawned
                            // side exits; its thread parks for joining.
                            if let Some(join) = join {
                                graveyard.push(join);
                            }
                            w.died_at = Some(Instant::now());
                            self.metrics.counter("service.shard.rejoin_failures").inc();
                        }
                    }
                }
            }
        }
        rejoined
    }

    /// Stop admitting groups, wait out the in-flight ones, then tell
    /// every live worker to finish and exit cleanly. Returns the number
    /// of workers that acknowledged the drain. Fails typed if in-flight
    /// groups outlast `deadline`. Terminal: after a drain (even a failed
    /// one) the coordinator sheds every new group and never rejoins
    /// workers.
    pub fn drain(&self, deadline: Duration) -> Result<usize> {
        self.draining.store(true, Ordering::SeqCst);
        let until = Instant::now() + deadline;
        // Phase 1: no new groups are admitted now; wait for the ones
        // already inside solve_group to finish or re-home their tasks.
        while self.inflight.load(Ordering::SeqCst) > 0 {
            if Instant::now() >= until {
                return Err(Error::Service(format!(
                    "drain deadline elapsed with {} groups still in flight",
                    self.inflight.load(Ordering::SeqCst)
                )));
            }
            thread::sleep(Duration::from_millis(1));
        }
        let mut inner = self.lock_inner();
        // Phase 2: ask live workers to finish queued solves and exit.
        let drain_frame = WireDoc::with_kind(kinds::DRAIN).encode();
        for w in inner.workers.iter_mut().filter(|w| w.alive) {
            if w.transport.send(&drain_frame).is_err() {
                self.mark_dead(w);
            }
        }
        let mut acked = 0usize;
        for w in inner.workers.iter_mut().filter(|w| w.alive) {
            loop {
                let remaining = until.saturating_duration_since(Instant::now());
                if remaining.is_zero() {
                    break; // no ack in time: treated like a crash below
                }
                match w.transport.recv_timeout(remaining.min(Duration::from_millis(20))) {
                    Ok(Some(frame)) => {
                        let is_ack = WireDoc::decode(&frame)
                            .map(|d| d.kind() == kinds::DRAIN_ACK)
                            .unwrap_or(false);
                        if is_ack {
                            acked += 1;
                            break;
                        }
                        // Stale results/pongs from the final group: skip.
                    }
                    Ok(None) => continue,
                    Err(_) => break, // link already closed — worker left
                }
            }
        }
        // Phase 3: close every link and join what we own. Slots are
        // retired (not "died"): no death metrics, no rejoins.
        let Inner { workers, graveyard, .. } = &mut *inner;
        for w in workers.iter_mut() {
            w.alive = false;
            w.died_at = None;
            w.respawn = None;
            w.transport = Arc::new(ClosedTransport);
            if let Some(join) = w.join.take() {
                graveyard.push(join);
            }
        }
        for join in graveyard.drain(..) {
            let _ = join.join();
        }
        self.metrics.counter("service.shard.drained_workers").add(acked as u64);
        Ok(acked)
    }

    /// Solve one fuse group across the worker set. Returns one slot per
    /// pair, index-aligned with `pairs`; survivable faults are absorbed
    /// by retry and hedging, unsurvivable ones surface as typed errors
    /// in the affected slots, and overload sheds the whole group as
    /// [`Error::Overloaded`] without touching a worker.
    ///
    /// `map` should be the exact feature map the local path would solve
    /// with (service cache maps are not refittable from `plan.seed` —
    /// see [`TaskEnvelope`]); `request_ids`, when index-aligned with
    /// `pairs`, rides along for observability.
    pub fn solve_group(
        &self,
        plan: &Plan,
        mu: &Measure,
        nu: &Measure,
        pairs: &[(&[f32], &[f32])],
        map: Option<&GaussianFeatureMap>,
        request_ids: &[u64],
    ) -> Vec<Result<DivergenceReport>> {
        let b = pairs.len();
        if b == 0 {
            return Vec::new();
        }
        // Admission control, before any lock or worker contact: a
        // draining coordinator refuses, a full budget sheds typed.
        if self.draining.load(Ordering::SeqCst) {
            return (0..b)
                .map(|_| Err(Error::Service("shard coordinator is draining".into())))
                .collect();
        }
        let budget = self.cfg.max_inflight_groups.max(1);
        let admitted = self.inflight.fetch_add(1, Ordering::SeqCst) + 1;
        if admitted > budget {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            self.metrics.counter("service.shard.shed_groups").inc();
            return (0..b)
                .map(|_| {
                    Err(Error::Overloaded(format!(
                        "shard in-flight budget full ({budget} groups)"
                    )))
                })
                .collect();
        }
        if self.draining.load(Ordering::SeqCst) {
            // Lost the race with a concurrent drain(): back out before
            // touching the (now draining) worker set.
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            return (0..b)
                .map(|_| Err(Error::Service("shard coordinator is draining".into())))
                .collect();
        }
        self.metrics.gauge("service.shard.inflight_groups").set(admitted as i64);
        let _inflight = InflightGuard { coordinator: self };

        let mut guard = self.lock_inner();
        let inner = &mut *guard;
        self.try_rejoins(inner);
        let group_id = inner.next_group;
        inner.next_group += 1;

        // A fresh group resets staleness: silence *before* this group
        // says nothing about liveness during it.
        let now = Instant::now();
        for w in inner.workers.iter_mut().filter(|w| w.alive) {
            w.last_seen = now;
        }

        let live: Vec<usize> =
            (0..inner.workers.len()).filter(|&i| inner.workers[i].alive).collect();
        let mut out: Vec<Option<Result<DivergenceReport>>> = (0..b).map(|_| None).collect();
        if live.is_empty() {
            return (0..b)
                .map(|_| Err(Error::Service("no live shard workers".into())))
                .collect();
        }

        // Scatter: contiguous near-equal chunks, one per live worker.
        let chunks = live.len().min(b);
        let (base, extra) = (b / chunks, b % chunks);
        let mut tasks: Vec<TaskState> = Vec::with_capacity(chunks);
        let mut start = 0usize;
        for (ci, &widx) in live.iter().take(chunks).enumerate() {
            let len = base + usize::from(ci < extra);
            let task_id = self.next_task.fetch_add(1, Ordering::SeqCst);
            let env = TaskEnvelope {
                task_id,
                group_id,
                request_ids: if request_ids.len() == b {
                    request_ids[start..start + len].to_vec()
                } else {
                    Vec::new()
                },
                plan: plan.clone(),
                mu: mu.clone(),
                nu: nu.clone(),
                pairs: pairs[start..start + len]
                    .iter()
                    .map(|(a, bw)| (a.to_vec(), bw.to_vec()))
                    .collect(),
                map: map.cloned(),
                session: None,
            };
            let frame = env.encode();
            self.metrics.counter("service.shard.scattered_tasks").inc();
            if inner.workers[widx].transport.send(&frame).is_err() {
                // Dead on arrival: the retry ladder below reassigns.
                self.mark_dead(&mut inner.workers[widx]);
            }
            tasks.push(TaskState {
                task_id,
                start,
                len,
                frame,
                worker: widx,
                sent_at: Instant::now(),
                attempts: 0,
                hedge_worker: None,
                hedged_at: None,
                done: false,
            });
            start += len;
        }

        // Hedge threshold: a fraction of the task deadline (0 = off).
        let hedge_after = (self.cfg.hedge_fraction > 0.0)
            .then(|| self.cfg.task_deadline.mul_f64(self.cfg.hedge_fraction.min(1.0)));

        // Gather until every task resolved (result, typed failure, or
        // total worker loss).
        let mut outstanding = tasks.len();
        let mut last_ping = Instant::now();
        'gather: while outstanding > 0 {
            // Drain every live worker's inbox.
            for widx in 0..inner.workers.len() {
                if !inner.workers[widx].alive {
                    continue;
                }
                let transport = Arc::clone(&inner.workers[widx].transport);
                loop {
                    match transport.recv_timeout(Duration::from_millis(1)) {
                        Ok(Some(frame)) => self.handle_frame(
                            &mut inner.workers,
                            widx,
                            &frame,
                            &mut tasks,
                            &mut out,
                            &mut outstanding,
                        ),
                        Ok(None) => break,
                        Err(_) => {
                            self.mark_dead(&mut inner.workers[widx]);
                            break;
                        }
                    }
                }
            }

            // Heartbeats — and, on the same cadence, rejoin attempts for
            // dead slots whose backoff has elapsed (a mid-group rejoin
            // makes the new incarnation a retry/hedge target right away).
            if last_ping.elapsed() >= self.cfg.heartbeat_interval {
                last_ping = Instant::now();
                self.try_rejoins(inner);
                let mut ping = WireDoc::with_kind(kinds::PING);
                ping.set_u64("group_id", group_id);
                let ping = ping.encode();
                for w in inner.workers.iter_mut().filter(|w| w.alive) {
                    self.metrics.counter("service.shard.heartbeats").inc();
                    if w.transport.send(&ping).is_err() {
                        self.mark_dead(w);
                    }
                }
            }

            // Straggler hedging: an old-enough task whose primary still
            // looks alive gets an identical copy on an idle live worker.
            if let Some(hedge_after) = hedge_after {
                let mut busy = vec![false; inner.workers.len()];
                for t in tasks.iter().filter(|t| !t.done) {
                    busy[t.worker] = true;
                    if let Some(h) = t.hedge_worker {
                        busy[h] = true;
                    }
                }
                for t in tasks.iter_mut() {
                    if t.done || t.hedge_worker.is_some() {
                        continue;
                    }
                    if t.sent_at.elapsed() <= hedge_after {
                        continue;
                    }
                    let Some(idle) = (0..inner.workers.len())
                        .find(|&c| c != t.worker && inner.workers[c].alive && !busy[c])
                    else {
                        continue; // nobody idle: the retry ladder covers it
                    };
                    if inner.workers[idle].transport.send(&t.frame).is_err() {
                        self.mark_dead(&mut inner.workers[idle]);
                        continue;
                    }
                    busy[idle] = true;
                    t.hedge_worker = Some(idle);
                    t.hedged_at = Some(Instant::now());
                    self.metrics.counter("service.shard.hedged_tasks").inc();
                }
            }

            // Liveness + deadline ladder.
            for ti in 0..tasks.len() {
                if tasks[ti].done {
                    continue;
                }
                let widx = tasks[ti].worker;
                let worker_dead = !inner.workers[widx].alive;
                let stale =
                    inner.workers[widx].last_seen.elapsed() > self.cfg.heartbeat_timeout;
                let expired = tasks[ti].sent_at.elapsed() > self.cfg.task_deadline;
                if !(worker_dead || stale || expired) {
                    continue;
                }
                if stale && !worker_dead {
                    self.mark_dead(&mut inner.workers[widx]);
                }
                // A live, unexpired hedge inherits the task before any
                // retry is burned: its identical copy is already running
                // on a healthy worker.
                if let Some(h) = tasks[ti].hedge_worker.take() {
                    let hedged_at = tasks[ti].hedged_at.take().unwrap_or_else(Instant::now);
                    if inner.workers[h].alive
                        && hedged_at.elapsed() <= self.cfg.task_deadline
                    {
                        tasks[ti].worker = h;
                        tasks[ti].sent_at = hedged_at;
                        continue;
                    }
                }
                tasks[ti].attempts += 1;
                let attempts = tasks[ti].attempts;
                if attempts > self.cfg.max_retries {
                    let task_id = tasks[ti].task_id;
                    fail_task(&mut tasks[ti], &mut out, &mut outstanding, &|| {
                        Error::Service(format!(
                            "shard task {task_id} failed after {attempts} attempts"
                        ))
                    });
                    continue;
                }
                // Next live worker round-robin; the current one only as a
                // last resort (deadline expiry with nowhere else to go).
                let n = inner.workers.len();
                let next = (1..=n)
                    .map(|k| (widx + k) % n)
                    .find(|&c| inner.workers[c].alive);
                let Some(next) = next else {
                    for t in tasks.iter_mut().filter(|t| !t.done) {
                        fail_task(t, &mut out, &mut outstanding, &|| {
                            Error::Service("all shard workers dead".into())
                        });
                    }
                    break 'gather;
                };
                self.metrics.counter("service.shard.retries").inc();
                self.metrics
                    .counter("service.shard.rescattered_pairs")
                    .add(tasks[ti].len as u64);
                let backoff = self
                    .cfg
                    .retry_backoff
                    .saturating_mul(attempts as u32)
                    .min(Duration::from_millis(500));
                thread::sleep(backoff);
                tasks[ti].worker = next;
                tasks[ti].sent_at = Instant::now();
                if inner.workers[next].transport.send(&tasks[ti].frame).is_err() {
                    // Also dead: the next ladder pass moves on again.
                    self.mark_dead(&mut inner.workers[next]);
                }
            }
        }

        // Final sweep: collect whatever is still in flight (late
        // originals after a retry or hedge won the race) so duplicates
        // are observed rather than left queued.
        for widx in 0..inner.workers.len() {
            if !inner.workers[widx].alive {
                continue;
            }
            let transport = Arc::clone(&inner.workers[widx].transport);
            while let Ok(Some(frame)) = transport.recv_timeout(Duration::from_millis(2)) {
                self.handle_frame(
                    &mut inner.workers,
                    widx,
                    &frame,
                    &mut tasks,
                    &mut out,
                    &mut outstanding,
                );
            }
        }

        out.into_iter()
            .map(|slot| {
                slot.unwrap_or_else(|| Err(Error::Service("shard gather left a hole".into())))
            })
            .collect()
    }

    /// Solve one streaming-session query on a single worker. Unlike
    /// [`Self::solve_group`] there is no scatter, hedging, or retry
    /// ladder here: a session query is pinned to one worker (its
    /// residency home, `prefer`, when that slot is alive — otherwise the
    /// first live slot), and any failure surfaces typed so the *service*
    /// coordinator — the owner of the session and its duals — can retry
    /// with a full snapshot. Returns the worker slot index that served
    /// the query so the caller can record the new residency home.
    pub fn solve_session(
        &self,
        plan: &Plan,
        mu: &Measure,
        nu: &Measure,
        map: Option<&GaussianFeatureMap>,
        delta: SessionDelta,
        prefer: Option<usize>,
    ) -> Result<(SessionSolveOut, usize)> {
        if self.draining.load(Ordering::SeqCst) {
            return Err(Error::Service("shard coordinator is draining".into()));
        }
        let mut guard = self.lock_inner();
        let inner = &mut *guard;
        self.try_rejoins(inner);
        let widx = prefer
            .filter(|&i| i < inner.workers.len() && inner.workers[i].alive)
            .or_else(|| (0..inner.workers.len()).find(|&i| inner.workers[i].alive))
            .ok_or_else(|| Error::Service("no live shard workers".into()))?;
        let group_id = inner.next_group;
        inner.next_group += 1;
        let task_id = self.next_task.fetch_add(1, Ordering::SeqCst);
        let env = TaskEnvelope {
            task_id,
            group_id,
            request_ids: Vec::new(),
            plan: plan.clone(),
            mu: mu.clone(),
            nu: nu.clone(),
            pairs: Vec::new(),
            map: map.cloned(),
            session: Some(delta),
        };
        self.metrics.counter("service.shard.scattered_tasks").inc();
        let w = &mut inner.workers[widx];
        w.last_seen = Instant::now();
        if w.transport.send(&env.encode()).is_err() {
            self.mark_dead(w);
            return Err(Error::Service(format!("session task send to worker {} failed", w.id)));
        }
        let deadline = Instant::now() + self.cfg.task_deadline;
        let mut last_ping = Instant::now();
        loop {
            let w = &mut inner.workers[widx];
            if Instant::now() >= deadline
                || w.last_seen.elapsed() > self.cfg.heartbeat_timeout
            {
                self.mark_dead(w);
                return Err(Error::Service(format!(
                    "session task {task_id} timed out on worker {}",
                    w.id
                )));
            }
            if last_ping.elapsed() >= self.cfg.heartbeat_interval {
                last_ping = Instant::now();
                self.metrics.counter("service.shard.heartbeats").inc();
                let mut ping = WireDoc::with_kind(kinds::PING);
                ping.set_u64("group_id", group_id);
                if w.transport.send(&ping.encode()).is_err() {
                    self.mark_dead(w);
                    return Err(Error::Service("session worker link lost".into()));
                }
            }
            let frame = match w.transport.recv_timeout(Duration::from_millis(1)) {
                Ok(Some(frame)) => frame,
                Ok(None) => continue,
                Err(_) => {
                    self.mark_dead(w);
                    return Err(Error::Service("session worker link lost".into()));
                }
            };
            let doc = match WireDoc::decode(&frame) {
                Ok(doc) => doc,
                Err(e) => {
                    self.metrics.counter("service.shard.corrupt_payloads").inc();
                    self.mark_dead(w);
                    return Err(Error::Wire(format!("corrupt session frame: {e}")));
                }
            };
            w.last_seen = Instant::now();
            match doc.kind() {
                kinds::PONG => {}
                "reject" => {
                    if doc.get_u64("task_id").ok() == Some(task_id) {
                        let msg =
                            doc.get_str("error").unwrap_or("task rejected by worker").to_string();
                        return Err(Error::Wire(format!("worker rejected session task: {msg}")));
                    }
                }
                kinds::SESSION_RESULT => match SessionResultEnvelope::decode(&frame) {
                    Err(e) => {
                        self.metrics.counter("service.shard.corrupt_payloads").inc();
                        self.mark_dead(w);
                        return Err(e);
                    }
                    Ok(env) if env.task_id == task_id => {
                        self.metrics.counter("service.shard.gathered_results").inc();
                        return env.result.map(|out| (out, widx));
                    }
                    Ok(_) => {
                        // A stale frame from an earlier query.
                        self.metrics.counter("service.shard.duplicate_results").inc();
                    }
                },
                _ => {} // stale results/pongs from earlier groups
            }
        }
    }

    /// Tell every live worker a session closed so its resident support
    /// state can be dropped. Best-effort: a dead or unreachable worker
    /// simply never held (or will naturally evict) the residency.
    pub fn close_session(&self, session_id: u64) {
        let mut inner = self.lock_inner();
        let mut doc = WireDoc::with_kind(kinds::SESSION_CLOSE);
        doc.set_u64("session.id", session_id);
        let frame = doc.encode();
        for w in inner.workers.iter_mut().filter(|w| w.alive) {
            if w.transport.send(&frame).is_err() {
                self.mark_dead(w);
            }
        }
    }

    fn mark_dead(&self, w: &mut WorkerSlot) {
        if w.alive {
            w.alive = false;
            w.died_at = Some(Instant::now());
            self.metrics.counter("service.shard.worker_deaths").inc();
        }
    }

    /// Process one inbound frame from `widx`'s link.
    fn handle_frame(
        &self,
        workers: &mut [WorkerSlot],
        widx: usize,
        frame: &[u8],
        tasks: &mut [TaskState],
        out: &mut [Option<Result<DivergenceReport>>],
        outstanding: &mut usize,
    ) {
        let doc = match WireDoc::decode(frame) {
            Ok(doc) => doc,
            Err(e) => {
                self.corrupt_from(workers, widx, tasks, out, outstanding, &e);
                return;
            }
        };
        workers[widx].last_seen = Instant::now();
        match doc.kind() {
            kinds::PONG => {}
            "reject" => {
                // The worker could not even decode the task: a
                // deterministic failure, so fail typed instead of
                // retrying.
                let task_id = doc.get_u64("task_id").ok();
                let msg = doc
                    .get_str("error")
                    .unwrap_or("task rejected by worker")
                    .to_string();
                if let Some(t) =
                    tasks.iter_mut().find(|t| Some(t.task_id) == task_id && !t.done)
                {
                    fail_task(t, out, outstanding, &|| {
                        Error::Wire(format!("worker rejected task: {msg}"))
                    });
                }
            }
            "result" => match ResultEnvelope::decode(frame) {
                Err(e) => self.corrupt_from(workers, widx, tasks, out, outstanding, &e),
                Ok(env) => {
                    let Some(t) = tasks.iter_mut().find(|t| t.task_id == env.task_id) else {
                        // A stale frame from an earlier group.
                        self.metrics.counter("service.shard.duplicate_results").inc();
                        return;
                    };
                    if t.done {
                        self.metrics.counter("service.shard.duplicate_results").inc();
                        return;
                    }
                    if env.results.len() != t.len {
                        let (got, want) = (env.results.len(), t.len);
                        fail_task(t, out, outstanding, &|| {
                            Error::Wire(format!(
                                "result envelope has {got} pairs, task expected {want}"
                            ))
                        });
                        return;
                    }
                    if t.hedge_worker == Some(widx) {
                        // The speculative copy beat the primary (both
                        // compute identical bits — this is a latency win,
                        // never a different answer).
                        self.metrics.counter("service.shard.hedge_wins").inc();
                    }
                    let elapsed = t.sent_at.elapsed();
                    for (off, r) in env.results.into_iter().enumerate() {
                        out[t.start + off] = Some(r);
                    }
                    t.done = true;
                    *outstanding -= 1;
                    self.metrics.counter("service.shard.gathered_results").inc();
                    self.metrics
                        .histogram("service.shard.task_us")
                        .observe_us(elapsed.as_micros() as u64);
                }
            },
            _ => {}
        }
    }

    /// A frame from `widx` failed to decode: unsurvivable for that
    /// worker's outstanding work (retrying a deterministic decode
    /// failure is pointless), and the link is no longer trusted. Tasks
    /// with a live hedge elsewhere migrate to it instead of failing.
    fn corrupt_from(
        &self,
        workers: &mut [WorkerSlot],
        widx: usize,
        tasks: &mut [TaskState],
        out: &mut [Option<Result<DivergenceReport>>],
        outstanding: &mut usize,
        err: &Error,
    ) {
        self.metrics.counter("service.shard.corrupt_payloads").inc();
        let worker_id = workers[widx].id;
        self.mark_dead(&mut workers[widx]);
        let msg = format!("corrupt frame from shard worker {worker_id}: {err}");
        for t in tasks.iter_mut().filter(|t| !t.done) {
            if t.hedge_worker == Some(widx) {
                // Only the speculative copy is tainted: forget it.
                t.hedge_worker = None;
                t.hedged_at = None;
            }
            if t.worker != widx {
                continue;
            }
            if let Some(h) = t.hedge_worker.take() {
                let hedged_at = t.hedged_at.take().unwrap_or_else(Instant::now);
                if workers[h].alive {
                    t.worker = h;
                    t.sent_at = hedged_at;
                    continue;
                }
            }
            fail_task(t, out, outstanding, &|| Error::Wire(msg.clone()));
        }
    }
}

/// The hello handshake, coordinator side: advertise our plan format
/// major, wait for the worker's, and require exact agreement — a
/// mixed-version rejoiner must fail typed here, before it can ever
/// mis-decode a task.
fn handshake(transport: &Arc<dyn Transport>, timeout: Duration) -> Result<()> {
    transport.send(&WireDoc::hello(PLAN_FORMAT_MAJOR as u64).encode())?;
    let deadline = Instant::now() + timeout;
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(Error::Service("shard handshake timed out".into()));
        }
        let Some(frame) = transport.recv_timeout(remaining.min(Duration::from_millis(20)))?
        else {
            continue;
        };
        let doc = WireDoc::decode(&frame)?;
        if doc.kind() != kinds::HELLO {
            continue; // stale pong/result from a previous life
        }
        let theirs = doc.get_u64("plan_v")?;
        let ours = PLAN_FORMAT_MAJOR as u64;
        if theirs != ours {
            return Err(Error::Wire(format!(
                "worker plan format v{theirs} != coordinator v{ours}; refusing rejoin"
            )));
        }
        return Ok(());
    }
}

/// Resolve every pair slot of `t` with a fresh instance of the error.
fn fail_task(
    t: &mut TaskState,
    out: &mut [Option<Result<DivergenceReport>>],
    outstanding: &mut usize,
    mk: &dyn Fn() -> Error,
) {
    for slot in &mut out[t.start..t.start + t.len] {
        *slot = Some(Err(mk()));
    }
    t.done = true;
    *outstanding -= 1;
}

impl Drop for ShardCoordinator {
    fn drop(&mut self) {
        let mut inner = self.lock_inner();
        let shutdown = WireDoc::with_kind(kinds::SHUTDOWN).encode();
        let Inner { workers, graveyard, .. } = &mut *inner;
        for w in workers.iter_mut() {
            let _ = w.transport.send(&shutdown);
            // Drop our endpoint too: a worker that missed the frame
            // (dropped by a fault, or mid-solve) still sees the link
            // close and exits.
            w.transport = Arc::new(ClosedTransport);
        }
        for w in workers.iter_mut() {
            if let Some(join) = w.join.take() {
                let _ = join.join();
            }
        }
        for join in graveyard.drain(..) {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::OtProblem;
    use crate::data;
    use crate::rng::Rng;
    use crate::shard::testing::Fault;

    fn quick_cfg() -> ShardConfig {
        ShardConfig {
            heartbeat_interval: Duration::from_millis(20),
            heartbeat_timeout: Duration::from_millis(400),
            task_deadline: Duration::from_secs(5),
            max_retries: 2,
            retry_backoff: Duration::from_millis(5),
            // Membership churn off by default in unit tests: rejoins and
            // hedges fire only where a test asks for them.
            hedge_fraction: 0.0,
            max_inflight_groups: 16,
            rejoin_backoff: Duration::from_secs(60),
        }
    }

    fn fixture(pairs: usize) -> (Measure, Measure, Vec<(Vec<f32>, Vec<f32>)>, Plan) {
        let mut rng = Rng::seed_from(17);
        let (mu, nu) = data::gaussian_blobs(14, &mut rng);
        let mut weights = Vec::with_capacity(pairs);
        for _ in 0..pairs {
            let mut a = rng.normal_vec(mu.len());
            let mut b = rng.normal_vec(nu.len());
            for w in a.iter_mut().chain(b.iter_mut()) {
                *w = w.abs() + 0.05;
            }
            let (sa, sb) = (a.iter().sum::<f32>(), b.iter().sum::<f32>());
            a.iter_mut().for_each(|w| *w /= sa);
            b.iter_mut().for_each(|w| *w /= sb);
            weights.push((a, b));
        }
        let refs: Vec<(&[f32], &[f32])> =
            weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let plan = OtProblem::new(&mu, &nu)
            .epsilon(0.5)
            .rank(8)
            .seed(23)
            .weight_pairs(&refs)
            .plan()
            .unwrap();
        (mu, nu, weights, plan)
    }

    fn assert_bitwise(shard: &[Result<DivergenceReport>], local: &[Result<DivergenceReport>]) {
        assert_eq!(shard.len(), local.len());
        for (s, l) in shard.iter().zip(local) {
            let (s, l) = (s.as_ref().unwrap(), l.as_ref().unwrap());
            assert_eq!(s.divergence.to_bits(), l.divergence.to_bits());
            assert_eq!(s.xy.objective.to_bits(), l.xy.objective.to_bits());
            assert_eq!(s.xy.u, l.xy.u);
            assert_eq!(s.xx.v, l.xx.v);
            assert_eq!(s.yy.iterations, l.yy.iterations);
        }
    }

    #[test]
    fn sharded_solve_matches_local_bitwise() {
        let (mu, nu, weights, plan) = fixture(5);
        let refs: Vec<(&[f32], &[f32])> =
            weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let local = OtProblem::new(&mu, &nu).weight_pairs(&refs).divergence_all_planned(&plan);

        let metrics = Arc::new(Registry::default());
        let shard = ShardCoordinator::in_process(2, quick_cfg(), metrics.clone());
        let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[1, 2, 3, 4, 5]);
        assert_bitwise(&got, &local);
        assert_eq!(metrics.counter("service.shard.scattered_tasks").get(), 2);
        assert_eq!(metrics.counter("service.shard.gathered_results").get(), 2);
        assert_eq!(metrics.counter("service.shard.retries").get(), 0);
        assert_eq!(shard.live_workers(), 2);
        assert_eq!(shard.inflight_groups(), 0, "inflight guard released");
    }

    #[test]
    fn uneven_partitions_and_single_pair_groups_work() {
        let (mu, nu, weights, plan) = fixture(3);
        let refs: Vec<(&[f32], &[f32])> =
            weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let local = OtProblem::new(&mu, &nu).weight_pairs(&refs).divergence_all_planned(&plan);

        let metrics = Arc::new(Registry::default());
        // 4 workers, 3 pairs: only 3 chunks go out, one worker idles.
        let shard = ShardCoordinator::in_process(4, quick_cfg(), metrics);
        let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
        assert_bitwise(&got, &local);

        // A single-pair group lands on one worker.
        let one = &refs[..1];
        let got = shard.solve_group(&plan, &mu, &nu, one, None, &[9]);
        assert_bitwise(&got, &local[..1]);
        assert!(shard.solve_group(&plan, &mu, &nu, &[], None, &[]).is_empty());
    }

    #[test]
    fn all_workers_dead_is_a_typed_error() {
        let (mu, nu, weights, plan) = fixture(4);
        let refs: Vec<(&[f32], &[f32])> =
            weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let metrics = Arc::new(Registry::default());
        // Every worker crashes on its first task: no survivors (the
        // quick_cfg rejoin backoff is far beyond the test window, so the
        // fleet stays down).
        let faults = FaultPlan::new(1)
            .inject(0, Fault::KillOnTask { nth: 1 })
            .inject(1, Fault::KillOnTask { nth: 1 });
        let shard =
            ShardCoordinator::in_process_with_faults(2, quick_cfg(), metrics.clone(), &faults);
        let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
        assert_eq!(got.len(), refs.len());
        for slot in &got {
            assert!(
                matches!(slot, Err(Error::Service(_))),
                "expected typed service error, got {slot:?}"
            );
        }
        assert_eq!(shard.live_workers(), 0);
        assert_eq!(metrics.counter("service.shard.worker_deaths").get(), 2);
        // A follow-up group fails fast, also typed.
        let again = shard.solve_group(&plan, &mu, &nu, &refs[..1], None, &[]);
        assert!(matches!(&again[0], Err(Error::Service(_))));
    }

    #[test]
    fn dead_workers_rejoin_and_serve_again() {
        let (mu, nu, weights, plan) = fixture(4);
        let refs: Vec<(&[f32], &[f32])> =
            weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let local = OtProblem::new(&mu, &nu).weight_pairs(&refs).divergence_all_planned(&plan);
        let metrics = Arc::new(Registry::default());
        // Worker 0 crashes on its first task of life 0; life 1 is clean.
        let faults = FaultPlan::new(2).inject(0, Fault::KillOnTask { nth: 1 });
        let mut cfg = quick_cfg();
        cfg.rejoin_backoff = Duration::from_millis(10);
        let shard = ShardCoordinator::in_process_with_faults(2, cfg, metrics.clone(), &faults);

        let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
        assert_bitwise(&got, &local);
        // The survivor covered the crashed worker's chunk; the crashed
        // slot may already have rejoined mid-group (the heartbeat tick
        // pumps membership), so only an upper bound is deterministic.
        assert!(shard.live_workers() >= 1);
        assert_eq!(metrics.counter("service.shard.worker_deaths").get(), 1);

        // After the backoff the fleet heals to full strength.
        std::thread::sleep(Duration::from_millis(15));
        shard.pump_rejoins();
        assert_eq!(shard.live_workers(), 2);
        assert!(metrics.counter("service.shard.rejoins").get() >= 1);

        // The rejoined incarnation serves new tasks, bitwise intact.
        let again = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
        assert_bitwise(&again, &local);
        assert_eq!(shard.live_workers(), 2);
    }

    #[test]
    fn drain_finishes_work_and_refuses_new_groups() {
        let (mu, nu, weights, plan) = fixture(3);
        let refs: Vec<(&[f32], &[f32])> =
            weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let local = OtProblem::new(&mu, &nu).weight_pairs(&refs).divergence_all_planned(&plan);
        let metrics = Arc::new(Registry::default());
        let shard = ShardCoordinator::in_process(2, quick_cfg(), metrics.clone());
        let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
        assert_bitwise(&got, &local);

        let acked = shard.drain(Duration::from_secs(10)).unwrap();
        assert_eq!(acked, 2, "both idle workers acknowledge the drain");
        assert_eq!(metrics.counter("service.shard.drained_workers").get(), 2);
        assert_eq!(shard.live_workers(), 0);
        assert_eq!(metrics.counter("service.shard.worker_deaths").get(), 0, "drain is not death");

        // Drained means drained: new groups are refused typed, and the
        // slots never rejoin.
        let after = shard.solve_group(&plan, &mu, &nu, &refs[..1], None, &[]);
        assert!(matches!(&after[0], Err(Error::Service(_))));
        assert_eq!(shard.pump_rejoins(), 0);
        assert_eq!(shard.live_workers(), 0);
    }
}
