//! Shard transports: byte-frame duplex links between the coordinator and
//! its workers.
//!
//! A [`Transport`] moves opaque frames (encoded [`crate::runtime::wire`]
//! documents) in both directions. Two implementations:
//!
//! * [`InProcTransport`] — an mpsc channel pair, the default for
//!   `--shard-workers` (worker threads in the serving process) and the
//!   substrate the fault-injection harness wraps
//!   ([`crate::shard::testing::FaultyTransport`]).
//! * [`TcpTransport`] — length-prefixed frames over a socket, for
//!   genuinely cross-host workers (`shard::worker::serve_listener`).
//!
//! Both ends use interior locking so a transport can be shared behind an
//! `Arc` between a worker's receive loop and its solver thread. Errors
//! split into two classes the coordinator treats differently: `Ok(None)`
//! is "nothing arrived within the timeout" (normal — keep polling), an
//! `Err` is a dead link (peer gone), which marks the worker dead.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::time::Duration;

use crate::error::{Error, Result};

/// Hard cap on a received frame (1 GiB): a corrupt length prefix must be
/// a typed error, not an absurd allocation.
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// A duplex byte-frame link. See the module docs for the `Ok(None)` /
/// `Err` contract.
pub trait Transport: Send + Sync {
    /// Send one frame. Any error means the link is dead.
    fn send(&self, frame: &[u8]) -> Result<()>;

    /// Receive one frame, waiting at most `timeout`. `Ok(None)` = nothing
    /// arrived; `Err` = the link is dead.
    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Vec<u8>>>;
}

fn disconnected() -> Error {
    Error::Service("shard transport disconnected".into())
}

/// In-process transport endpoint (one side of a channel pair).
pub struct InProcTransport {
    tx: Mutex<Sender<Vec<u8>>>,
    rx: Mutex<Receiver<Vec<u8>>>,
}

/// Create a connected pair of in-process endpoints.
pub fn in_proc_pair() -> (InProcTransport, InProcTransport) {
    let (a_tx, a_rx) = channel();
    let (b_tx, b_rx) = channel();
    (
        InProcTransport { tx: Mutex::new(a_tx), rx: Mutex::new(b_rx) },
        InProcTransport { tx: Mutex::new(b_tx), rx: Mutex::new(a_rx) },
    )
}

impl Transport for InProcTransport {
    fn send(&self, frame: &[u8]) -> Result<()> {
        self.tx.lock().unwrap().send(frame.to_vec()).map_err(|_| disconnected())
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        match self.rx.lock().unwrap().recv_timeout(timeout) {
            Ok(frame) => Ok(Some(frame)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(disconnected()),
        }
    }
}

/// TCP transport: `u32` little-endian length prefix, then the frame.
pub struct TcpTransport {
    reader: Mutex<TcpStream>,
    writer: Mutex<TcpStream>,
}

impl TcpTransport {
    /// Connect to a listening shard worker.
    pub fn connect(addr: &str) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| Error::Service(format!("shard connect {addr}: {e}")))?;
        Self::from_stream(stream)
    }

    /// Wrap an accepted stream (the worker side).
    pub fn from_stream(stream: TcpStream) -> Result<TcpTransport> {
        stream.set_nodelay(true).ok();
        let reader = stream.try_clone()?;
        Ok(TcpTransport { reader: Mutex::new(reader), writer: Mutex::new(stream) })
    }

    /// Read exactly `buf.len()` bytes. When `allow_idle_timeout` and the
    /// timeout fires before the *first* byte, returns `Ok(None)` (idle —
    /// no frame in flight); a timeout mid-buffer keeps reading, because a
    /// peer that started a frame will finish it or close the socket.
    fn read_full(
        stream: &mut TcpStream,
        buf: &mut [u8],
        allow_idle_timeout: bool,
    ) -> Result<Option<()>> {
        let mut filled = 0;
        while filled < buf.len() {
            match stream.read(&mut buf[filled..]) {
                Ok(0) => return Err(disconnected()),
                Ok(k) => filled += k,
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
                {
                    if filled == 0 && allow_idle_timeout {
                        return Ok(None);
                    }
                }
                Err(e) => return Err(Error::Io(e)),
            }
        }
        Ok(Some(()))
    }
}

impl Transport for TcpTransport {
    fn send(&self, frame: &[u8]) -> Result<()> {
        let mut writer = self.writer.lock().unwrap();
        writer.write_all(&(frame.len() as u32).to_le_bytes()).map_err(|_| disconnected())?;
        writer.write_all(frame).map_err(|_| disconnected())?;
        writer.flush().map_err(|_| disconnected())
    }

    fn recv_timeout(&self, timeout: Duration) -> Result<Option<Vec<u8>>> {
        let mut reader = self.reader.lock().unwrap();
        reader
            .set_read_timeout(Some(timeout.max(Duration::from_millis(1))))
            .map_err(Error::Io)?;
        let mut len_buf = [0u8; 4];
        if Self::read_full(&mut reader, &mut len_buf, true)?.is_none() {
            return Ok(None);
        }
        let len = u32::from_le_bytes(len_buf) as usize;
        if len > MAX_FRAME_LEN {
            return Err(Error::Wire(format!("frame length {len} exceeds cap")));
        }
        let mut frame = vec![0u8; len];
        Self::read_full(&mut reader, &mut frame, false)?;
        Ok(Some(frame))
    }
}

/// Bind a loopback listener on an ephemeral port (test/bench helper).
pub fn loopback_listener() -> Result<TcpListener> {
    TcpListener::bind("127.0.0.1:0").map_err(Error::Io)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn in_proc_pair_is_duplex() {
        let (a, b) = in_proc_pair();
        a.send(b"ping").unwrap();
        assert_eq!(b.recv_timeout(Duration::from_millis(100)).unwrap().unwrap(), b"ping");
        b.send(b"pong").unwrap();
        assert_eq!(a.recv_timeout(Duration::from_millis(100)).unwrap().unwrap(), b"pong");
        assert!(a.recv_timeout(Duration::from_millis(5)).unwrap().is_none(), "idle times out");
    }

    #[test]
    fn in_proc_disconnect_is_an_error() {
        let (a, b) = in_proc_pair();
        drop(b);
        assert!(a.send(b"x").is_err());
        assert!(a.recv_timeout(Duration::from_millis(5)).is_err());
    }

    #[test]
    fn tcp_round_trip_and_disconnect() {
        let listener = loopback_listener().unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let t = TcpTransport::from_stream(stream).unwrap();
            let frame = t.recv_timeout(Duration::from_secs(5)).unwrap().unwrap();
            t.send(&frame).unwrap(); // echo
        });
        let client = TcpTransport::connect(&addr.to_string()).unwrap();
        assert!(client.recv_timeout(Duration::from_millis(5)).unwrap().is_none());
        let payload = vec![7u8; 10_000];
        client.send(&payload).unwrap();
        assert_eq!(client.recv_timeout(Duration::from_secs(5)).unwrap().unwrap(), payload);
        server.join().unwrap();
        // Server gone: the next receive must report a dead link.
        assert!(client.recv_timeout(Duration::from_millis(200)).is_err());
    }
}
