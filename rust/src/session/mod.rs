//! Streaming sessions: long-lived transport problems over *mutating*
//! measures, served incrementally.
//!
//! The paper's factored kernel `k(x, y) = ⟨φ(x), φ(y)⟩` makes a Sinkhorn
//! iteration O(r(n+m)) — and a corollary this module exploits is that
//! the feature matrix Φ is **append-only along n for a fixed map**: a
//! mutating measure (sliding-window point cloud, GAN minibatch stream)
//! costs O(r) per inserted/evicted/swapped point instead of a kernel
//! rebuild. Combined with warm-starting duals from the previous solve
//! (the eps-independent `alpha = eps·ln(u/a)` currency the annealing
//! rungs already use), an incremental query converges in a handful of
//! iterations versus hundreds from scratch — the warm-start economics of
//! Cuturi (arXiv:1306.0895) with the iteration-count sensitivity of
//! Altschuler–Weed–Rigollet (arXiv:1705.09634).
//!
//! ## Anatomy
//!
//! * [`SupportState`] — the incrementally-maintained factored support:
//!   raw points, weights, and per-row **log**-feature rows
//!   (`map.log_eval_into`, O(r) per point) for both sides, in flat
//!   `Vec<f32>` buffers with amortised geometric growth. Queries
//!   materialise a [`FactoredKernel::from_log_factors`] from the rows,
//!   so small-eps stabilisation (max-shift + clamped factors + the
//!   log-domain escalation view) keeps working on streamed supports.
//! * [`StreamingSession`] — [`SupportState`] plus the cached row dual
//!   from the last solve and the provenance tracker that remaps it
//!   across updates ([`remap_warm_dual`]).
//! * [`SessionOp`] — the update vocabulary (insert / evict / swap per
//!   side). The same op log drives the local session and a shard
//!   worker's resident Φ replica ([`crate::api::SessionDelta`]).
//!
//! ## Determinism contract
//!
//! The row layout is a **pure function of the update log**: inserts
//! append, evictions `swap_remove` (the last row moves into the hole),
//! swaps overwrite in place — no hashing, no thread-dependent order.
//! Feature rows are evaluated one point at a time, and the solve runs on
//! the thread-count-deterministic pooled kernels, so replaying an update
//! log is bitwise-reproducible at any thread count, on any host
//! (rust/tests/streaming_equivalence.rs pins this per SIMD arm).
//!
//! ## Warm-start contract
//!
//! `query()` warm-starts from the previous solve's row dual, remapped to
//! the current layout: surviving rows keep their dual **bit-exactly**
//! (an explicit identity fast path makes a zero-delta update bitwise
//! invisible), evicted rows are dropped, inserted/swapped rows start at
//! the mean of the surviving duals. The warm start falls back to a cold
//! solve when nothing survives, and an eps change refits the feature map
//! from the session seed and drops the dual entirely (cold restart).

use std::sync::Arc;

use crate::config::SinkhornConfig;
use crate::data::Measure;
use crate::error::{Error, Result};
use crate::features::{FeatureMap, GaussianFeatureMap};
use crate::kernels::FactoredKernel;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::runtime::pool::Pool;
use crate::sinkhorn::{
    sinkhorn_stabilized_warm, sinkhorn_warm, solve_batch_stabilized_warm, WarmSolve,
};

/// Default anchor-draw seed for sessions that don't pin one.
pub const DEFAULT_SESSION_SEED: u64 = 0x5E55;

/// One incremental update to a streaming session's support. Indices are
/// into the side's *current* row layout (see the module docs for the
/// swap-remove layout rule).
#[derive(Clone, Debug, PartialEq)]
pub enum SessionOp {
    /// Append a point to the x side.
    InsertX { point: Vec<f32>, weight: f32 },
    /// Remove x row `index`; the last x row moves into the hole.
    EvictX { index: usize },
    /// Replace x row `index` in place (dual restarts at the mean).
    SwapX { index: usize, point: Vec<f32>, weight: f32 },
    /// Append a point to the y side.
    InsertY { point: Vec<f32>, weight: f32 },
    /// Remove y row `index`; the last y row moves into the hole.
    EvictY { index: usize },
    /// Replace y row `index` in place.
    SwapY { index: usize, point: Vec<f32>, weight: f32 },
}

impl SessionOp {
    /// Compact wire tag (see [`crate::api::SessionDelta`] encoding).
    pub fn tag(&self) -> &'static str {
        match self {
            SessionOp::InsertX { .. } => "ix",
            SessionOp::EvictX { .. } => "ex",
            SessionOp::SwapX { .. } => "sx",
            SessionOp::InsertY { .. } => "iy",
            SessionOp::EvictY { .. } => "ey",
            SessionOp::SwapY { .. } => "sy",
        }
    }
}

/// Configuration for a [`StreamingSession`].
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Solver settings; `sinkhorn.epsilon` is the session's target eps
    /// (changing it later is a cold restart, see
    /// [`StreamingSession::set_epsilon`]).
    pub sinkhorn: SinkhornConfig,
    /// Positive random features r for the session's map.
    pub rank: usize,
    /// Seed for the Lemma-1 anchor draw (map fit and refit).
    pub seed: u64,
    /// Threads for the kernel's pooled applies (`1` = serial, `0` =
    /// auto). Never changes the numbers — the pooled kernels are
    /// deterministic in the thread count.
    pub solver_threads: usize,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            sinkhorn: SinkhornConfig::default(),
            rank: 128,
            seed: DEFAULT_SESSION_SEED,
            solver_threads: 1,
        }
    }
}

/// One side (x or y) of an incrementally-maintained factored support:
/// flat row-major buffers for points, weights, and log-feature rows,
/// all growing/shrinking by whole rows with `Vec`'s amortised geometric
/// reallocation.
pub struct SupportSide {
    dim: usize,
    r: usize,
    points: Vec<f32>,
    weights: Vec<f32>,
    log_phi: Vec<f32>,
}

impl SupportSide {
    fn from_measure(map: &GaussianFeatureMap, m: &Measure) -> SupportSide {
        let (n, dim, r) = (m.len(), m.dim(), map.num_features());
        let mut side = SupportSide {
            dim,
            r,
            points: Vec::with_capacity(n * dim),
            weights: Vec::with_capacity(n),
            log_phi: vec![0.0; n * r],
        };
        for i in 0..n {
            side.points.extend_from_slice(m.points.row(i));
            map.log_eval_into(m.points.row(i), &mut side.log_phi[i * r..(i + 1) * r]);
        }
        side.weights.extend_from_slice(&m.weights);
        side
    }

    /// Current row count.
    pub fn len(&self) -> usize {
        self.weights.len()
    }

    /// True when the side has no rows.
    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    fn check_point(&self, point: &[f32], weight: f32) -> Result<()> {
        if point.len() != self.dim {
            return Err(Error::Shape(format!(
                "session point has dim {} but the support has dim {}",
                point.len(),
                self.dim
            )));
        }
        if !(weight.is_finite() && weight > 0.0) {
            return Err(Error::Config(format!("session weight must be finite and > 0, got {weight}")));
        }
        Ok(())
    }

    /// O(r): append a row (one `log_eval_into` per point).
    fn insert(&mut self, map: &GaussianFeatureMap, point: &[f32], weight: f32) -> Result<()> {
        self.check_point(point, weight)?;
        self.points.extend_from_slice(point);
        self.weights.push(weight);
        let old = self.log_phi.len();
        self.log_phi.resize(old + self.r, 0.0);
        map.log_eval_into(point, &mut self.log_phi[old..]);
        Ok(())
    }

    /// O(r): swap-remove a row (the last row moves into the hole).
    fn evict(&mut self, index: usize) -> Result<()> {
        let n = self.len();
        if index >= n {
            return Err(Error::Shape(format!("evict index {index} out of bounds (n = {n})")));
        }
        let last = n - 1;
        if index != last {
            let (d, r) = (self.dim, self.r);
            self.points.copy_within(last * d..(last + 1) * d, index * d);
            self.log_phi.copy_within(last * r..(last + 1) * r, index * r);
            self.weights[index] = self.weights[last];
        }
        self.points.truncate(last * self.dim);
        self.log_phi.truncate(last * self.r);
        self.weights.truncate(last);
        Ok(())
    }

    /// O(r): overwrite a row in place.
    fn swap(
        &mut self,
        map: &GaussianFeatureMap,
        index: usize,
        point: &[f32],
        weight: f32,
    ) -> Result<()> {
        let n = self.len();
        if index >= n {
            return Err(Error::Shape(format!("swap index {index} out of bounds (n = {n})")));
        }
        self.check_point(point, weight)?;
        let (d, r) = (self.dim, self.r);
        self.points[index * d..(index + 1) * d].copy_from_slice(point);
        self.weights[index] = weight;
        map.log_eval_into(point, &mut self.log_phi[index * r..(index + 1) * r]);
        Ok(())
    }

    /// Snapshot this side as a [`Measure`] in the current row layout.
    pub fn measure(&self) -> Measure {
        Measure {
            points: Mat::from_vec(self.len(), self.dim, self.points.clone()),
            weights: self.weights.clone(),
        }
    }

    fn normalized_weights(&self) -> Result<Vec<f32>> {
        if self.is_empty() {
            return Err(Error::Shape("session support side is empty; insert points first".into()));
        }
        let sum: f64 = self.weights.iter().map(|&w| w as f64).sum();
        if !(sum.is_finite() && sum > 0.0) {
            return Err(Error::Config(format!("session weights sum to {sum}")));
        }
        Ok(self.weights.iter().map(|&w| (w as f64 / sum) as f32).collect())
    }
}

/// Both sides of an incrementally-maintained factored support plus the
/// fixed feature map that defines the rows. Shared by the local
/// [`StreamingSession`] and a shard worker's resident per-session Φ
/// replica — applying the same [`SessionOp`] log to either produces
/// bit-identical rows.
pub struct SupportState {
    map: Arc<GaussianFeatureMap>,
    x: SupportSide,
    y: SupportSide,
}

impl SupportState {
    /// Evaluate both sides' log-feature rows under `map` (O(r·(n+m))).
    pub fn from_measures(
        map: Arc<GaussianFeatureMap>,
        mu: &Measure,
        nu: &Measure,
    ) -> Result<SupportState> {
        if mu.dim() != nu.dim() {
            return Err(Error::Shape(format!(
                "measure dims differ: {} vs {}",
                mu.dim(),
                nu.dim()
            )));
        }
        if mu.len() == 0 || nu.len() == 0 {
            return Err(Error::Shape("streaming sessions need non-empty initial supports".into()));
        }
        let x = SupportSide::from_measure(&map, mu);
        let y = SupportSide::from_measure(&map, nu);
        Ok(SupportState { map, x, y })
    }

    /// Apply one update op (O(r)).
    pub fn apply(&mut self, op: &SessionOp) -> Result<()> {
        let map = self.map.clone();
        match op {
            SessionOp::InsertX { point, weight } => self.x.insert(&map, point, *weight),
            SessionOp::EvictX { index } => self.x.evict(*index),
            SessionOp::SwapX { index, point, weight } => self.x.swap(&map, *index, point, *weight),
            SessionOp::InsertY { point, weight } => self.y.insert(&map, point, *weight),
            SessionOp::EvictY { index } => self.y.evict(*index),
            SessionOp::SwapY { index, point, weight } => self.y.swap(&map, *index, point, *weight),
        }
    }

    /// The x side.
    pub fn x(&self) -> &SupportSide {
        &self.x
    }

    /// The y side.
    pub fn y(&self) -> &SupportSide {
        &self.y
    }

    /// The fixed feature map defining the rows.
    pub fn map(&self) -> &Arc<GaussianFeatureMap> {
        &self.map
    }

    /// Snapshot both sides as measures in the current row layout.
    pub fn snapshot(&self) -> (Measure, Measure) {
        (self.x.measure(), self.y.measure())
    }

    /// Normalised marginals `(a, b)` from the stored weights.
    pub fn marginals(&self) -> Result<(Vec<f32>, Vec<f32>)> {
        Ok((self.x.normalized_weights()?, self.y.normalized_weights()?))
    }

    /// Materialise the factored kernel from the stored log rows
    /// (max-shift + clamp happen inside [`FactoredKernel::from_log_factors`],
    /// so small-eps stabilisation and log-domain escalation keep working).
    pub fn kernel(&self, pool: &Pool) -> FactoredKernel {
        let r = self.x.r;
        let lx = Mat::from_vec(self.x.len(), r, self.x.log_phi.clone());
        let ly = Mat::from_vec(self.y.len(), r, self.y.log_phi.clone());
        FactoredKernel::from_log_factors(lx, ly).with_pool(pool.clone())
    }
}

/// Remap a cached row dual onto the current layout: `slots[i]` names the
/// pre-update row the current row `i` descends from (`None` for
/// inserted/swapped rows, which start at the mean of the survivors).
///
/// The identity permutation takes an explicit fast path that copies the
/// dual verbatim — no mean computation, no per-element arithmetic — so a
/// zero-delta update is **bit-exactly** invisible to the next solve. The
/// general path also copies surviving entries verbatim (`f64` moves, no
/// round-trip through scalings), so the untouched index range stays
/// bit-exact under any permutation.
///
/// Returns `None` when nothing survives (every original row evicted or
/// swapped): the caller must fall back to a cold solve.
pub fn remap_warm_dual(alpha: &[f64], slots: &[Option<usize>]) -> Option<Vec<f64>> {
    if slots.len() == alpha.len() && slots.iter().enumerate().all(|(i, s)| *s == Some(i)) {
        return Some(alpha.to_vec());
    }
    let mut sum = 0.0;
    let mut kept = 0usize;
    for s in slots {
        if let Some(j) = s {
            sum += alpha[*j];
            kept += 1;
        }
    }
    if kept == 0 {
        return None;
    }
    let mean = sum / kept as f64;
    Some(slots.iter().map(|s| match s { Some(j) => alpha[*j], None => mean }).collect())
}

/// Warm-startable single solve over a [`SupportState`] — the one code
/// path shared by the local [`StreamingSession::query`] and a shard
/// worker executing a session task, so the two are bitwise identical by
/// construction. Routes through [`sinkhorn_stabilized_warm`] when
/// `cfg.stabilize` (plain Alg. 1 with log-domain escalation on
/// divergence) and [`sinkhorn_warm`] otherwise.
pub fn solve_support(
    state: &SupportState,
    cfg: &SinkhornConfig,
    pool: &Pool,
    warm: Option<&[f64]>,
) -> Result<WarmSolve> {
    let (a, b) = state.marginals()?;
    let kernel = state.kernel(pool);
    if cfg.stabilize {
        sinkhorn_stabilized_warm(&kernel, &a, &b, cfg, warm)
    } else {
        sinkhorn_warm(&kernel, &a, &b, cfg, warm)
    }
}

/// What one `query()` returned.
#[derive(Clone, Debug)]
pub struct QueryReport {
    /// Entropic OT objective `W_eps(a, b)` on the current support.
    pub objective: f64,
    /// Sinkhorn iterations this solve ran.
    pub iterations: usize,
    /// Final L1 marginal error.
    pub marginal_error: f64,
    /// Whether the stopping tolerance was met within the iteration cap.
    pub converged: bool,
    /// Whether the solve warm-started from a remapped previous dual.
    pub warm_started: bool,
    /// Whether the solve escalated to the log-domain path.
    pub escalated: bool,
    /// Support sizes at solve time.
    pub n: usize,
    /// See `n`.
    pub m: usize,
    /// Session version the solve saw.
    pub version: u64,
}

/// Lifetime counters for one session.
#[derive(Clone, Debug, Default)]
pub struct SessionStats {
    /// Ops applied via `update()`.
    pub updates: u64,
    /// Total `query()`/`query_pairs()` solves.
    pub queries: u64,
    /// Solves that warm-started from a remapped dual.
    pub warm_solves: u64,
    /// Solves that started cold.
    pub cold_solves: u64,
    /// Sum over warm solves of `cold_baseline_iters - iterations`
    /// (floored at 0): the iteration savings attributable to
    /// warm-starting, against the most recent cold solve as baseline.
    pub iterations_saved: u64,
    /// Iteration count of the most recent cold solve.
    pub cold_baseline_iters: u64,
}

/// A long-lived, incrementally-updated transport problem: support state,
/// the cached row dual from the last solve, and the provenance tracker
/// that remaps it across updates. See the module docs for the
/// determinism and warm-start contracts.
pub struct StreamingSession {
    cfg: SessionConfig,
    state: SupportState,
    /// Provenance of each current x row relative to the last solve.
    slots: Vec<Option<usize>>,
    /// Row dual of the last single solve (`WarmSolve::alpha`).
    alpha: Option<Vec<f64>>,
    /// Per-pair row duals of the last `query_pairs` solve.
    pair_alphas: Option<Vec<Vec<f64>>>,
    version: u64,
    stats: SessionStats,
    pool: Pool,
}

impl StreamingSession {
    /// Open a session over initial supports, fitting the feature map
    /// from the session seed (so the same inputs open the bit-identical
    /// session on any host).
    pub fn new(mu: &Measure, nu: &Measure, cfg: SessionConfig) -> Result<StreamingSession> {
        let mut rng = Rng::seed_from(cfg.seed);
        let map = Arc::new(GaussianFeatureMap::fit(mu, nu, cfg.sinkhorn.epsilon, cfg.rank, &mut rng));
        Self::with_map(mu, nu, map, cfg)
    }

    /// Open a session with a pre-fitted map (e.g. shared from the
    /// coordinator's feature cache). The map's eps should match
    /// `cfg.sinkhorn.epsilon`.
    pub fn with_map(
        mu: &Measure,
        nu: &Measure,
        map: Arc<GaussianFeatureMap>,
        cfg: SessionConfig,
    ) -> Result<StreamingSession> {
        let state = SupportState::from_measures(map, mu, nu)?;
        let n = state.x().len();
        let pool = Pool::new(cfg.solver_threads);
        Ok(StreamingSession {
            cfg,
            state,
            slots: (0..n).map(Some).collect(),
            alpha: None,
            pair_alphas: None,
            version: 0,
            stats: SessionStats::default(),
            pool,
        })
    }

    /// The session's configuration.
    pub fn config(&self) -> &SessionConfig {
        &self.cfg
    }

    /// The session's target eps.
    pub fn epsilon(&self) -> f64 {
        self.cfg.sinkhorn.epsilon
    }

    /// Monotonic version, bumped by every `update()` and eps change.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// The support state (sides, map, snapshot).
    pub fn state(&self) -> &SupportState {
        &self.state
    }

    /// Lifetime counters.
    pub fn stats(&self) -> &SessionStats {
        &self.stats
    }

    /// Apply an op batch atomically-ish: ops apply in order, the version
    /// bumps once. An op error (bad index/shape) surfaces immediately
    /// with earlier ops of the batch already applied — the version still
    /// bumps so replicas never silently diverge.
    pub fn update(&mut self, ops: &[SessionOp]) -> Result<u64> {
        let out = self.apply_ops(ops);
        self.version += 1;
        out.map(|()| self.version)
    }

    fn apply_ops(&mut self, ops: &[SessionOp]) -> Result<()> {
        for op in ops {
            self.state.apply(op)?;
            match op {
                SessionOp::InsertX { .. } => self.slots.push(None),
                SessionOp::EvictX { index } => {
                    self.slots.swap_remove(*index);
                }
                SessionOp::SwapX { index, .. } => self.slots[*index] = None,
                SessionOp::InsertY { .. } | SessionOp::EvictY { .. } | SessionOp::SwapY { .. } => {}
            }
            self.stats.updates += 1;
        }
        Ok(())
    }

    /// True when no x-side op has touched the layout since the last
    /// solve (the remap would be the identity).
    fn slots_identity(&self) -> bool {
        self.slots.iter().enumerate().all(|(i, s)| *s == Some(i))
    }

    /// Fold pending layout changes into the cached duals, so both caches
    /// always describe the *current* layout and one provenance tracker
    /// serves them. Bit-exact no-op on the identity.
    fn resync(&mut self) {
        if !self.slots_identity() {
            if let Some(al) = self.alpha.take() {
                self.alpha = remap_warm_dual(&al, &self.slots);
            }
            if let Some(pal) = self.pair_alphas.take() {
                self.pair_alphas = pal
                    .iter()
                    .map(|al| remap_warm_dual(al, &self.slots))
                    .collect::<Option<Vec<_>>>();
            }
        }
        self.slots = (0..self.state.x().len()).map(Some).collect();
    }

    /// The warm dual a query would start from right now (remapped to the
    /// current layout), or `None` for a cold start. Exposed so the
    /// sharded serving path can ship the exact same warm start a local
    /// query would use.
    pub fn warm_dual(&mut self) -> Option<Vec<f64>> {
        self.resync();
        self.alpha.clone()
    }

    /// Record a finished solve (local or returned by a shard worker):
    /// cache the dual, reset provenance to the identity, update stats.
    pub fn install_result(&mut self, alpha: Vec<f64>, iterations: usize, warm: bool) {
        debug_assert_eq!(alpha.len(), self.state.x().len());
        self.alpha = Some(alpha);
        self.slots = (0..self.state.x().len()).map(Some).collect();
        self.stats.queries += 1;
        if warm {
            self.stats.warm_solves += 1;
            self.stats.iterations_saved +=
                self.stats.cold_baseline_iters.saturating_sub(iterations as u64);
        } else {
            self.stats.cold_solves += 1;
            self.stats.cold_baseline_iters = iterations as u64;
        }
    }

    /// Solve `W_eps(a, b)` on the current support, warm-starting from
    /// the remapped previous dual when one survives.
    pub fn query(&mut self) -> Result<QueryReport> {
        let warm = self.warm_dual();
        let ws = solve_support(&self.state, &self.cfg.sinkhorn, &self.pool, warm.as_deref())?;
        let warm_started = warm.is_some();
        let report = QueryReport {
            objective: ws.solution.objective,
            iterations: ws.solution.iterations,
            marginal_error: ws.solution.marginal_error,
            converged: ws.solution.converged,
            warm_started,
            escalated: ws.escalated,
            n: self.state.x().len(),
            m: self.state.y().len(),
            version: self.version,
        };
        self.install_result(ws.alpha, report.iterations, warm_started);
        Ok(report)
    }

    /// Batched variant: solve several weight pairs over the session's
    /// current kernel in one column-blocked batch
    /// ([`solve_batch_stabilized_warm`]), warm-starting every pair from
    /// its cached dual when the previous batch had the same width and
    /// every dual survived the remap. Slices must have the current
    /// side lengths.
    pub fn query_pairs(&mut self, pairs: &[(&[f32], &[f32])]) -> Vec<Result<QueryReport>> {
        if pairs.is_empty() {
            return Vec::new();
        }
        let (n, m) = (self.state.x().len(), self.state.y().len());
        for (a, b) in pairs {
            if a.len() != n || b.len() != m {
                let msg = format!(
                    "query_pairs weight shapes ({}, {}) do not match the support ({n}, {m})",
                    a.len(),
                    b.len()
                );
                return pairs.iter().map(|_| Err(Error::Shape(msg.clone()))).collect();
            }
        }
        self.resync();
        let warms: Option<Vec<Vec<f64>>> = match &self.pair_alphas {
            Some(pal) if pal.len() == pairs.len() => Some(pal.clone()),
            _ => None,
        };
        let warm_started = warms.is_some();
        let kernel = self.state.kernel(&self.pool);
        let outs =
            solve_batch_stabilized_warm(&kernel, pairs, &self.cfg.sinkhorn, warms.as_deref());
        let mut new_alphas: Vec<Vec<f64>> = Vec::with_capacity(pairs.len());
        let mut all_ok = true;
        let reports: Vec<Result<QueryReport>> = outs
            .into_iter()
            .map(|res| match res {
                Ok(ws) => {
                    let report = QueryReport {
                        objective: ws.solution.objective,
                        iterations: ws.solution.iterations,
                        marginal_error: ws.solution.marginal_error,
                        converged: ws.solution.converged,
                        warm_started,
                        escalated: ws.escalated,
                        n,
                        m,
                        version: self.version,
                    };
                    self.stats.queries += 1;
                    if warm_started {
                        self.stats.warm_solves += 1;
                    } else {
                        self.stats.cold_solves += 1;
                    }
                    new_alphas.push(ws.alpha);
                    Ok(report)
                }
                Err(e) => {
                    all_ok = false;
                    Err(e)
                }
            })
            .collect();
        self.pair_alphas = if all_ok { Some(new_alphas) } else { None };
        reports
    }

    /// Change the target eps: refit the feature map from the session
    /// seed over the *current* support, rebuild every log-feature row
    /// (O(r·(n+m))), and drop all cached duals — the next query solves
    /// cold. A no-op when `eps` is bit-identical to the current eps.
    pub fn set_epsilon(&mut self, eps: f64) -> Result<()> {
        if !(eps.is_finite() && eps > 0.0) {
            return Err(Error::Config(format!("session eps must be finite and > 0, got {eps}")));
        }
        if eps.to_bits() == self.cfg.sinkhorn.epsilon.to_bits() {
            return Ok(());
        }
        self.cfg.sinkhorn.epsilon = eps;
        let (mu, nu) = self.state.snapshot();
        let mut rng = Rng::seed_from(self.cfg.seed);
        let map = Arc::new(GaussianFeatureMap::fit(&mu, &nu, eps, self.cfg.rank, &mut rng));
        self.state = SupportState::from_measures(map, &mu, &nu)?;
        self.alpha = None;
        self.pair_alphas = None;
        self.slots = (0..self.state.x().len()).map(Some).collect();
        self.version += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    fn session(n: usize, eps: f64) -> StreamingSession {
        let mut rng = Rng::seed_from(7);
        let (mu, nu) = data::gaussian_blobs(n, &mut rng);
        let cfg = SessionConfig {
            sinkhorn: SinkhornConfig { epsilon: eps, ..SinkhornConfig::default() },
            rank: 32,
            seed: 11,
            solver_threads: 1,
        };
        StreamingSession::new(&mu, &nu, cfg).unwrap()
    }

    fn point(rng: &mut Rng, dim: usize) -> Vec<f32> {
        (0..dim).map(|_| rng.uniform_in(-0.5, 0.5) as f32).collect()
    }

    #[test]
    fn remap_identity_is_bit_exact_passthrough() {
        let alpha = vec![0.1, -0.7, 3.25e-17, f64::from_bits(0x3FF123456789ABCD)];
        let slots: Vec<Option<usize>> = (0..4).map(Some).collect();
        let out = remap_warm_dual(&alpha, &slots).unwrap();
        for (a, b) in alpha.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn remap_preserves_survivors_bitwise_and_means_new_rows() {
        let alpha = vec![1.0, 2.0, 4.0];
        // Row 1 evicted via swap-remove (last row moved into slot 1),
        // then a new row appended.
        let slots = vec![Some(0), Some(2), None];
        let out = remap_warm_dual(&alpha, &slots).unwrap();
        assert_eq!(out[0].to_bits(), 1.0f64.to_bits());
        assert_eq!(out[1].to_bits(), 4.0f64.to_bits());
        assert_eq!(out[2], (1.0 + 4.0) / 2.0);
    }

    #[test]
    fn remap_with_no_survivors_is_cold() {
        assert!(remap_warm_dual(&[1.0, 2.0], &[None, None]).is_none());
        assert!(remap_warm_dual(&[1.0], &[]).is_none());
    }

    #[test]
    fn warm_query_after_single_swap_converges_in_fewer_iters() {
        let mut s = session(300, 0.1);
        let cold = s.query().unwrap();
        assert!(!cold.warm_started);
        assert!(cold.converged);
        let mut rng = Rng::seed_from(99);
        let p = point(&mut rng, 2);
        s.update(&[SessionOp::SwapX { index: 5, point: p, weight: 1.0 }]).unwrap();
        let warm = s.query().unwrap();
        assert!(warm.warm_started);
        assert!(warm.converged);
        assert!(
            warm.iterations <= cold.iterations,
            "warm {} vs cold {}",
            warm.iterations,
            cold.iterations
        );
        assert_eq!(s.stats().warm_solves, 1);
        assert_eq!(s.stats().cold_solves, 1);
    }

    #[test]
    fn update_log_layout_is_deterministic() {
        let mut rng = Rng::seed_from(3);
        let (mu, nu) = data::gaussian_blobs(40, &mut rng);
        let cfg = SessionConfig { rank: 16, ..SessionConfig::default() };
        let run = |cfg: SessionConfig| {
            let mut s = StreamingSession::new(&mu, &nu, cfg).unwrap();
            let mut r2 = Rng::seed_from(17);
            let mut ops = Vec::new();
            for i in 0..10 {
                ops.push(SessionOp::InsertX { point: point(&mut r2, 2), weight: 1.0 });
                ops.push(SessionOp::EvictX { index: i });
                ops.push(SessionOp::SwapY { index: i, point: point(&mut r2, 2), weight: 0.5 });
            }
            s.update(&ops).unwrap();
            let (a, b) = s.state().snapshot();
            (a.points.data().to_vec(), b.points.data().to_vec())
        };
        let one = run(SessionConfig { solver_threads: 1, ..cfg.clone() });
        let four = run(SessionConfig { solver_threads: 4, ..cfg });
        assert_eq!(one.0.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   four.0.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
        assert_eq!(one.1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                   four.1.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn bad_ops_surface_typed_errors() {
        let mut s = session(10, 0.5);
        assert!(matches!(
            s.update(&[SessionOp::EvictX { index: 99 }]),
            Err(Error::Shape(_))
        ));
        assert!(matches!(
            s.update(&[SessionOp::InsertX { point: vec![1.0, 2.0, 3.0], weight: 1.0 }]),
            Err(Error::Shape(_))
        ));
        assert!(matches!(
            s.update(&[SessionOp::InsertX { point: vec![0.0, 0.0], weight: -1.0 }]),
            Err(Error::Config(_))
        ));
    }

    #[test]
    fn query_pairs_warm_starts_second_batch() {
        let mut s = session(60, 0.2);
        let n = s.state().x().len();
        let m = s.state().y().len();
        let a: Vec<f32> = vec![1.0 / n as f32; n];
        let b: Vec<f32> = vec![1.0 / m as f32; m];
        let pairs: Vec<(&[f32], &[f32])> = vec![(&a, &b), (&a, &b)];
        let first = s.query_pairs(&pairs);
        assert!(first.iter().all(|r| r.is_ok()));
        assert!(!first[0].as_ref().unwrap().warm_started);
        let second = s.query_pairs(&pairs);
        assert!(second.iter().all(|r| r.is_ok()));
        assert!(second[0].as_ref().unwrap().warm_started);
    }

    #[test]
    fn eps_change_drops_the_dual() {
        let mut s = session(50, 0.5);
        let _ = s.query().unwrap();
        s.set_epsilon(0.25).unwrap();
        let q = s.query().unwrap();
        assert!(!q.warm_started, "eps change must cold-restart");
    }
}
