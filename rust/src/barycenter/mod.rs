//! Wasserstein barycenters via iterative Bregman projections
//! (Benamou et al. '15), over any [`KernelOp`] — used to reproduce Fig. 6:
//! barycenters on the positive sphere with the cost `c(x,y) = -log x^T y`,
//! whose kernel is *exactly* the rank-3 factored kernel `K = X X^T`
//! (Remark 1 / [`crate::features::SphereLinearMap`]).
//!
//! IBP for N histograms q_1..q_N on a common support with weights w:
//!   repeat:  u_k <- q_k / K v_k ;  p <- prod_k (K^T u_k)^{w_k} (geometric
//!   mean) ;  v_k <- p / K^T u_k.

use crate::error::{Error, Result};
use crate::kernels::KernelOp;

/// Configuration for the IBP barycenter solver.
#[derive(Clone, Debug)]
pub struct BarycenterConfig {
    pub max_iters: usize,
    /// Stop when the max L1 change in the barycenter falls below this.
    pub tol: f64,
}

impl Default for BarycenterConfig {
    fn default() -> Self {
        BarycenterConfig { max_iters: 500, tol: 1e-7 }
    }
}

/// Result of the barycenter computation.
#[derive(Clone, Debug)]
pub struct Barycenter {
    /// The barycenter histogram (sums to 1).
    pub p: Vec<f32>,
    pub iterations: usize,
    pub converged: bool,
}

/// Iterative Bregman projections with equal or custom weights.
///
/// `kernel` must be square (shared support of size n); `hists` are the
/// input histograms q_k (each length n, summing to 1); `weights` are the
/// barycentric weights (default uniform if empty).
pub fn barycenter<K: KernelOp + ?Sized>(
    kernel: &K,
    hists: &[Vec<f32>],
    weights: &[f64],
    cfg: &BarycenterConfig,
) -> Result<Barycenter> {
    let n = kernel.rows();
    if kernel.cols() != n {
        return Err(Error::Shape("barycenter: kernel must be square".into()));
    }
    if hists.is_empty() {
        return Err(Error::Shape("barycenter: need at least one histogram".into()));
    }
    for (k, h) in hists.iter().enumerate() {
        if h.len() != n {
            return Err(Error::Shape(format!("histogram {k} length {} != {n}", h.len())));
        }
    }
    let nk = hists.len();
    let w: Vec<f64> = if weights.is_empty() {
        vec![1.0 / nk as f64; nk]
    } else {
        if weights.len() != nk {
            return Err(Error::Shape("barycenter: weights/histograms mismatch".into()));
        }
        let s: f64 = weights.iter().sum();
        weights.iter().map(|x| x / s).collect()
    };

    let mut u = vec![vec![1.0f32; n]; nk];
    let mut v = vec![vec![1.0f32; n]; nk];
    let mut p = vec![1.0f32 / n as f32; n];
    let mut p_prev = p.clone();
    let mut buf = vec![0.0f32; n];
    let mut log_p = vec![0.0f64; n];

    let mut converged = false;
    let mut iters = 0;
    for it in 0..cfg.max_iters {
        iters = it + 1;
        // u_k <- q_k / (K v_k)
        for k in 0..nk {
            kernel.apply_into(&v[k], &mut buf);
            for i in 0..n {
                u[k][i] = hists[k][i] / buf[i].max(1e-38);
            }
        }
        // p <- geometric mean of K^T u_k with weights w.
        log_p.iter_mut().for_each(|x| *x = 0.0);
        for k in 0..nk {
            kernel.apply_t_into(&u[k], &mut buf);
            for i in 0..n {
                log_p[i] += w[k] * (buf[i].max(1e-38) as f64).ln();
            }
            // Reuse buf for v update below by storing K^T u_k per k — we
            // recompute instead to stay O(n) in memory.
        }
        for i in 0..n {
            p[i] = log_p[i].exp() as f32;
        }
        // Normalise (IBP keeps p near-normalised; enforce exactly).
        let z: f64 = p.iter().map(|&x| x as f64).sum();
        let inv = (1.0 / z) as f32;
        p.iter_mut().for_each(|x| *x *= inv);
        // v_k <- p / (K^T u_k)
        for k in 0..nk {
            kernel.apply_t_into(&u[k], &mut buf);
            for i in 0..n {
                v[k][i] = p[i] / buf[i].max(1e-38);
            }
        }
        if !p.iter().all(|x| x.is_finite()) {
            return Err(Error::SinkhornDiverged {
                iter: it,
                reason: "barycenter produced non-finite mass".into(),
            });
        }
        // Convergence: L1 change in p.
        let diff: f64 =
            p.iter().zip(&p_prev).map(|(&a, &b)| ((a - b) as f64).abs()).sum();
        p_prev.copy_from_slice(&p);
        if diff < cfg.tol && it > 0 {
            converged = true;
            break;
        }
    }

    Ok(Barycenter { p, iterations: iters, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::features::{FeatureMap, SphereLinearMap};
    use crate::kernels::{DenseKernel, FactoredKernel};
    use crate::linalg::Mat;

    /// Factored kernel for the positive sphere: K = X X^T exactly.
    fn sphere_kernel(grid: &Mat) -> FactoredKernel {
        let fm = SphereLinearMap::new(3);
        let phi = fm.feature_matrix(grid);
        FactoredKernel::from_factors(phi.clone(), phi)
    }

    #[test]
    fn barycenter_of_identical_histograms_is_projection_fixed_point() {
        let grid = data::positive_sphere_grid(12);
        let k = sphere_kernel(&grid);
        let h = data::corner_histograms(&grid, 0.3)[0].clone();
        let bc = barycenter(&k, &[h.clone(), h.clone()], &[], &BarycenterConfig::default())
            .unwrap();
        let s: f64 = bc.p.iter().map(|&x| x as f64).sum();
        assert!((s - 1.0).abs() < 1e-4);
        assert!(bc.converged);
    }

    #[test]
    fn barycenter_mass_conservation() {
        let grid = data::positive_sphere_grid(10);
        let k = sphere_kernel(&grid);
        let hs = data::corner_histograms(&grid, 0.25);
        let bc = barycenter(&k, &hs.to_vec(), &[], &BarycenterConfig::default()).unwrap();
        let s: f64 = bc.p.iter().map(|&x| x as f64).sum();
        assert!((s - 1.0).abs() < 1e-4, "mass {s}");
        assert!(bc.p.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn fig6_barycenter_mass_between_corners() {
        // The Fig. 6 observation: the -log x^T y barycenter of three corner
        // histograms concentrates *between* the corners (arccos-geodesic
        // midpoints), i.e. its weighted mean direction is in the interior.
        let grid = data::positive_sphere_grid(20);
        let k = sphere_kernel(&grid);
        let hs = data::corner_histograms(&grid, 0.2);
        let bc = barycenter(&k, &hs.to_vec(), &[], &BarycenterConfig::default()).unwrap();
        // Mean direction of the barycenter mass.
        let mut mean = [0.0f64; 3];
        for i in 0..grid.rows() {
            for c in 0..3 {
                mean[c] += bc.p[i] as f64 * grid[(i, c)] as f64;
            }
        }
        // Interior: all three coordinates well away from 0 (each corner
        // histogram alone would have one coordinate ~1 and others ~small).
        for c in 0..3 {
            assert!(mean[c] > 0.25, "coordinate {c} = {} not interior", mean[c]);
        }
    }

    #[test]
    fn weighted_barycenter_leans_toward_heavier_input() {
        // The theta-phi grid is not equal-area (it oversamples the z pole),
        // so absolute pole dominance is not the right invariant; instead,
        // weighting corner 0 must move the mean direction toward the x-pole
        // *relative to the uniform-weight barycenter*.
        let grid = data::positive_sphere_grid(16);
        let k = sphere_kernel(&grid);
        let hs = data::corner_histograms(&grid, 0.2);
        let mean_dir = |p: &[f32]| -> [f64; 3] {
            let mut m = [0.0f64; 3];
            for i in 0..grid.rows() {
                for c in 0..3 {
                    m[c] += p[i] as f64 * grid[(i, c)] as f64;
                }
            }
            m
        };
        let uni = barycenter(&k, &hs.to_vec(), &[], &BarycenterConfig::default()).unwrap();
        let wtd = barycenter(&k, &hs.to_vec(), &[0.8, 0.1, 0.1], &BarycenterConfig::default())
            .unwrap();
        let mu = mean_dir(&uni.p);
        let mw = mean_dir(&wtd.p);
        assert!(
            mw[0] > mu[0],
            "weighting corner x must raise the x-coordinate: {mu:?} -> {mw:?}"
        );
        assert!(mw[2] < mu[2], "and lower the z-coordinate: {mu:?} -> {mw:?}");
    }

    #[test]
    fn dense_and_factored_kernels_agree() {
        // Same barycenter whether K = XX^T is applied via factors or dense.
        let grid = data::positive_sphere_grid(8);
        let fk = sphere_kernel(&grid);
        let dk = DenseKernel::from_matrix(fk.to_dense(), 1.0);
        let hs = data::corner_histograms(&grid, 0.3);
        let cfg = BarycenterConfig { max_iters: 200, tol: 1e-9 };
        let b1 = barycenter(&fk, &hs.to_vec(), &[], &cfg).unwrap();
        let b2 = barycenter(&dk, &hs.to_vec(), &[], &cfg).unwrap();
        let diff: f64 =
            b1.p.iter().zip(&b2.p).map(|(&a, &b)| ((a - b) as f64).abs()).sum();
        assert!(diff < 1e-4, "L1 diff {diff}");
    }

    #[test]
    fn shape_errors() {
        let grid = data::positive_sphere_grid(5);
        let k = sphere_kernel(&grid);
        assert!(barycenter(&k, &[], &[], &BarycenterConfig::default()).is_err());
        assert!(barycenter(&k, &[vec![0.5; 3]], &[], &BarycenterConfig::default()).is_err());
        let h = vec![1.0 / 25.0; 25];
        assert!(barycenter(&k, &[h], &[0.5, 0.5], &BarycenterConfig::default()).is_err());
    }
}
