//! §3.3/§4: a *learned* positive feature map `phi_theta`.
//!
//! The GAN's adversarial cost is `c_theta(f_gamma(x), f_gamma(y))` where
//! `f_gamma` embeds data into R^e and `phi_theta` maps the embedding to the
//! positive orthant. Here `phi_theta` is a single affine layer followed by
//! a scaled softplus-exp positive nonlinearity:
//!
//!   phi_theta(z)_j = exp(w_j . z + b_j - logsumexp-ish normaliser) / sqrt(r)
//!
//! i.e. exactly the Lemma-1 family with learnable anchors/biases
//! generalised to an arbitrary log-linear form. Strict positivity holds
//! for any theta,
//! so Prop 3.2 differentiability applies and gradients flow through
//! `d phi / d theta` (implemented analytically here — no autodiff crate).

use crate::linalg::Mat;
use crate::rng::Rng;

use super::{FeatureMap, LOG_FLOOR};

/// Learned log-linear positive feature map.
#[derive(Clone, Debug)]
pub struct LearnedFeatureMap {
    /// Weights, (r, e) over embedding dim e.
    pub w: Mat,
    /// Biases, (r,).
    pub b: Vec<f32>,
    /// Fixed scale 1/sqrt(r) keeping kernel magnitudes O(1).
    inv_sqrt_r: f32,
}

impl LearnedFeatureMap {
    /// Random init: rows of `w` ~ N(0, 1/e), b = 0.
    pub fn new(embed_dim: usize, r: usize, rng: &mut Rng) -> Self {
        let std = 1.0 / (embed_dim as f64).sqrt();
        let w = Mat::from_fn(r, embed_dim, |_, _| rng.normal_scaled(0.0, std) as f32);
        LearnedFeatureMap { w, b: vec![0.0; r], inv_sqrt_r: 1.0 / (r as f32).sqrt() }
    }

    /// Number of trainable parameters.
    pub fn num_params(&self) -> usize {
        self.w.rows() * self.w.cols() + self.b.len()
    }

    /// Log-feature (before exp) for gradient computations:
    /// `log phi_j(z) = w_j . z + b_j - log sqrt(r)`.
    pub fn log_feature(&self, z: &[f32], j: usize) -> f32 {
        let dot: f32 = z.iter().zip(self.w.row(j)).map(|(&a, &b)| a * b).sum();
        dot + self.b[j] + self.inv_sqrt_r.ln()
    }

    /// Accumulate the gradient of `sum_i g[i, j] * phi_j(z_i)` w.r.t.
    /// (w, b) into (gw, gb), given precomputed features `phi` (n, r) and
    /// embeddings `z` (n, e).
    ///
    /// d phi_j(z)/d w_j = phi_j(z) * z ;  d phi_j(z)/d b_j = phi_j(z).
    pub fn accumulate_grad(
        &self,
        z: &Mat,
        phi: &Mat,
        upstream: &Mat,
        gw: &mut Mat,
        gb: &mut [f32],
    ) {
        let (n, e) = z.shape();
        let r = self.w.rows();
        assert_eq!(phi.shape(), (n, r));
        assert_eq!(upstream.shape(), (n, r));
        assert_eq!(gw.shape(), (r, e));
        assert_eq!(gb.len(), r);
        for i in 0..n {
            let zi = z.row(i);
            let phii = phi.row(i);
            let upi = upstream.row(i);
            for j in 0..r {
                let coeff = upi[j] * phii[j];
                if coeff == 0.0 {
                    continue;
                }
                gb[j] += coeff;
                let gwr = gw.row_mut(j);
                for (gv, &zv) in gwr.iter_mut().zip(zi) {
                    *gv += coeff * zv;
                }
            }
        }
    }

    /// Gradient of `sum_ij upstream[i,j] phi_j(z_i)` w.r.t. the embeddings
    /// `z` — the piece that backpropagates into `f_gamma` and the
    /// generator. `d phi_j(z)/d z = phi_j(z) * w_j`.
    pub fn backprop_input(&self, z: &Mat, phi: &Mat, upstream: &Mat) -> Mat {
        let (n, e) = z.shape();
        let r = self.w.rows();
        assert_eq!(phi.shape(), (n, r));
        assert_eq!(upstream.shape(), (n, r));
        let mut dz = Mat::zeros(n, e);
        for i in 0..n {
            let phii = phi.row(i);
            let upi = upstream.row(i);
            let dzr = dz.row_mut(i);
            for j in 0..r {
                let coeff = upi[j] * phii[j];
                if coeff == 0.0 {
                    continue;
                }
                let wr = self.w.row(j);
                for (dv, &wv) in dzr.iter_mut().zip(wr) {
                    *dv += coeff * wv;
                }
            }
        }
        dz
    }

    /// Flatten parameters into a vector (for the Adam optimiser).
    pub fn params_flat(&self) -> Vec<f32> {
        let mut p = self.w.data().to_vec();
        p.extend_from_slice(&self.b);
        p
    }

    /// Load parameters from a flat vector.
    pub fn set_params_flat(&mut self, p: &[f32]) {
        let nw = self.w.rows() * self.w.cols();
        assert_eq!(p.len(), nw + self.b.len());
        self.w.data_mut().copy_from_slice(&p[..nw]);
        self.b.copy_from_slice(&p[nw..]);
    }
}

impl FeatureMap for LearnedFeatureMap {
    fn num_features(&self) -> usize {
        self.w.rows()
    }

    fn eval_into(&self, z: &[f32], out: &mut [f32]) {
        let (r, e) = self.w.shape();
        assert_eq!(z.len(), e, "embedding dim mismatch");
        assert_eq!(out.len(), r);
        let level = crate::linalg::simd::active_level();
        for (j, o) in out.iter_mut().enumerate() {
            *o = crate::linalg::simd::dot_f32(level, z, self.w.row(j)) + self.b[j];
        }
        // Clamp the exponent on both sides: positivity below, and an
        // upper guard so a bad adversarial step cannot overflow f32.
        crate::special::vexp::exp_clamped_f32_at(level, out, LOG_FLOOR, 30.0);
        for o in out.iter_mut() {
            *o *= self.inv_sqrt_r;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn learned_features_strictly_positive_any_theta() {
        let mut rng = Rng::seed_from(0);
        let mut fm = LearnedFeatureMap::new(4, 8, &mut rng);
        // Even adversarially large parameters keep positivity.
        let huge: Vec<f32> =
            (0..fm.num_params()).map(|i| if i % 2 == 0 { 50.0 } else { -50.0 }).collect();
        fm.set_params_flat(&huge);
        let mut out = vec![0.0; 8];
        fm.eval_into(&[1.0, -2.0, 3.0, -4.0], &mut out);
        assert!(out.iter().all(|&v| v > 0.0 && v.is_finite()));
    }

    #[test]
    fn params_roundtrip() {
        let mut rng = Rng::seed_from(1);
        let mut fm = LearnedFeatureMap::new(3, 5, &mut rng);
        let p = fm.params_flat();
        assert_eq!(p.len(), fm.num_params());
        let p2: Vec<f32> = p.iter().map(|x| x + 1.0).collect();
        fm.set_params_flat(&p2);
        assert_eq!(fm.params_flat(), p2);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut rng = Rng::seed_from(2);
        let fm = LearnedFeatureMap::new(3, 4, &mut rng);
        let z = Mat::from_fn(5, 3, |_, _| rng.normal_f32());
        let upstream = Mat::from_fn(5, 4, |_, _| rng.normal_f32());
        let phi = fm.feature_matrix(&z);
        let mut gw = Mat::zeros(4, 3);
        let mut gb = vec![0.0; 4];
        fm.accumulate_grad(&z, &phi, &upstream, &mut gw, &mut gb);

        // Objective: L(theta) = sum_ij upstream[i,j] * phi_j(z_i).
        let loss = |fm: &LearnedFeatureMap| -> f64 {
            let phi = fm.feature_matrix(&z);
            let mut s = 0.0f64;
            for i in 0..5 {
                for j in 0..4 {
                    s += (upstream[(i, j)] * phi[(i, j)]) as f64;
                }
            }
            s
        };
        let h = 1e-3;
        let mut fm2 = fm.clone();
        let base_params = fm.params_flat();
        for &idx in &[0usize, 5, 11, 12, 15] {
            let mut p = base_params.clone();
            p[idx] += h;
            fm2.set_params_flat(&p);
            let up = loss(&fm2);
            p[idx] -= 2.0 * h;
            fm2.set_params_flat(&p);
            let dn = loss(&fm2);
            let num = (up - dn) / (2.0 * h as f64);
            let ana = if idx < 12 {
                gw.data()[idx] as f64
            } else {
                gb[idx - 12] as f64
            };
            assert!(
                (num - ana).abs() < 2e-2 * num.abs().max(0.1),
                "param {idx}: fd {num} vs analytic {ana}"
            );
        }
    }
}
