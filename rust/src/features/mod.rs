//! Positive feature maps — the paper's §3 construction.
//!
//! A [`FeatureMap`] sends points `x in R^d` to the *strictly positive*
//! orthant `(R_+^*)^r`, defining a kernel `k(x,y) = <phi(x), phi(y)>` and
//! thereby a cost `c(x,y) = -eps log k(x,y)` (Eq. 7). Implementations:
//!
//! * [`GaussianFeatureMap`] — Lemma 1: random features whose expectation is
//!   the Gaussian/Gibbs kernel of the squared Euclidean cost.
//! * [`ArcCosFeatureMap`] — Lemma 3: perturbed arc-cosine kernels.
//! * [`SphereLinearMap`] — Remark 1: the identity map on the positive
//!   sphere, whose kernel is the plain dot product (used by Fig. 6).
//! * [`LearnedFeatureMap`] — §3.3/§4: an affine embedding followed by an
//!   elementwise positive nonlinearity, trained adversarially in the GAN.

use crate::data::Measure;
use crate::linalg::simd;
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::runtime::pool::Pool;
use crate::special;

mod learned;

pub use learned::LearnedFeatureMap;

/// Rows evaluated per parallel task of [`par_feature_matrix`] /
/// [`par_log_feature_matrix`]: one row costs O(r d), so a few dozen rows
/// per task keeps queue traffic negligible while load-balancing well.
const FEAT_ROWS_PER_TASK: usize = 32;

/// Evaluate `phi` on every row of `points` in parallel over `pool`.
///
/// Rows are independent and each is produced by the same
/// [`FeatureMap::eval_into`] call as the serial
/// [`FeatureMap::feature_matrix`], so the result is bitwise identical to
/// the serial path for every pool size. Serial pools and small inputs
/// fall through to the trait method directly.
pub fn par_feature_matrix<F>(map: &F, points: &Mat, pool: &Pool) -> Mat
where
    F: FeatureMap + Sync + ?Sized,
{
    let n = points.rows();
    let r = map.num_features();
    if pool.threads() <= 1 || n < 2 * FEAT_ROWS_PER_TASK || r == 0 {
        return map.feature_matrix(points);
    }
    let mut out = Mat::zeros(n, r);
    let tasks: Vec<(usize, &mut [f32])> =
        out.data_mut().chunks_mut(FEAT_ROWS_PER_TASK * r).enumerate().collect();
    pool.run_tasks(tasks, |(c, block)| {
        let base = c * FEAT_ROWS_PER_TASK;
        for (i, row) in block.chunks_mut(r).enumerate() {
            map.eval_into(points.row(base + i), row);
        }
    });
    out
}

/// Parallel [`FeatureMap::log_feature_matrix`] — same contract as
/// [`par_feature_matrix`], evaluating unclamped log-features instead.
pub fn par_log_feature_matrix<F>(map: &F, points: &Mat, pool: &Pool) -> Mat
where
    F: FeatureMap + Sync + ?Sized,
{
    let n = points.rows();
    let r = map.num_features();
    if pool.threads() <= 1 || n < 2 * FEAT_ROWS_PER_TASK || r == 0 {
        return map.log_feature_matrix(points);
    }
    let mut out = Mat::zeros(n, r);
    let tasks: Vec<(usize, &mut [f32])> =
        out.data_mut().chunks_mut(FEAT_ROWS_PER_TASK * r).enumerate().collect();
    pool.run_tasks(tasks, |(c, block)| {
        let base = c * FEAT_ROWS_PER_TASK;
        for (i, row) in block.chunks_mut(r).enumerate() {
            map.log_eval_into(points.row(base + i), row);
        }
    });
    out
}

/// Underflow floor shared with the python oracle (`ref.LOG_FLOOR`):
/// exp(-80) ~ 1.8e-35 keeps every feature a normal positive f32.
pub const LOG_FLOOR: f32 = -80.0;

/// Overflow ceiling (`ref.LOG_CEIL`): guards the anchor-norm exponent
/// against f32 overflow at extreme (eps, q).
pub const LOG_CEIL: f32 = 80.0;

/// A map from points to the strictly positive orthant.
pub trait FeatureMap {
    /// Output dimension r (the number of features).
    fn num_features(&self) -> usize;

    /// Write `phi(x)` for a single point into `out` (`out.len() == r`).
    fn eval_into(&self, x: &[f32], out: &mut [f32]);

    /// Write `log phi(x)` — *unclamped* where the implementation can, so
    /// callers may renormalise before exponentiating (the f32-stabilised
    /// factored kernel). Default falls back to `ln(eval)`.
    fn log_eval_into(&self, x: &[f32], out: &mut [f32]) {
        self.eval_into(x, out);
        for v in out.iter_mut() {
            *v = v.ln();
        }
    }

    /// Log-feature matrix (n, r).
    fn log_feature_matrix(&self, points: &Mat) -> Mat {
        let n = points.rows();
        let r = self.num_features();
        let mut out = Mat::zeros(n, r);
        for i in 0..n {
            let row = unsafe {
                std::slice::from_raw_parts_mut(out.data_mut().as_mut_ptr().add(i * r), r)
            };
            self.log_eval_into(points.row(i), row);
        }
        out
    }

    /// Feature matrix `Phi in R_+^{n x r}` for all rows of `points`.
    fn feature_matrix(&self, points: &Mat) -> Mat {
        let n = points.rows();
        let r = self.num_features();
        let mut out = Mat::zeros(n, r);
        for i in 0..n {
            // Split borrow: rows are disjoint.
            let row = unsafe {
                std::slice::from_raw_parts_mut(out.data_mut().as_mut_ptr().add(i * r), r)
            };
            self.eval_into(points.row(i), row);
        }
        out
    }

    /// The induced kernel `k(x, y) = <phi(x), phi(y)>`.
    fn kernel(&self, x: &[f32], y: &[f32]) -> f32 {
        let r = self.num_features();
        let mut px = vec![0.0; r];
        let mut py = vec![0.0; r];
        self.eval_into(x, &mut px);
        self.eval_into(y, &mut py);
        crate::linalg::dot(&px, &py)
    }

    /// The induced cost `c(x, y) = -eps log k(x, y)` (Eq. 7).
    fn cost(&self, x: &[f32], y: &[f32], eps: f64) -> f64 {
        -eps * (self.kernel(x, y) as f64).ln()
    }
}

/// Lemma 1: positive random features for the Gaussian kernel
/// `k(x,y) = exp(-||x-y||^2 / eps)`.
///
/// Anchors `u_1..u_r ~ N(0, (q eps/4) I_d)` with
/// `q = eps^{-1} R^2 / (2 d W0(eps^{-1} R^2/d))`, and
/// `phi_j(x) = (2q)^{d/4} exp(-2/eps ||x-u_j||^2 + ||u_j||^2/(eps q)) / sqrt(r)`.
#[derive(Clone, Debug)]
pub struct GaussianFeatureMap {
    /// Anchor matrix, (r, d).
    pub anchors: Mat,
    pub eps: f64,
    pub q: f64,
    /// Data radius R used to set q (diagnostic).
    pub radius: f64,
    /// Precomputed per-anchor constant:
    /// (d/4) log(2q) + ||u_j||^2/(eps q) - log(r)/2.
    log_const: Vec<f32>,
    /// Precomputed ||u_j||^2 (hot-path term of the expanded square dist).
    anchor_sq: Vec<f32>,
}

impl GaussianFeatureMap {
    /// Draw `r` anchors for data of radius `radius` in dimension `dim`.
    pub fn new(eps: f64, radius: f64, dim: usize, r: usize, rng: &mut Rng) -> Self {
        assert!(r > 0 && dim > 0 && eps > 0.0 && radius > 0.0);
        let q = special::gaussian_q(eps, radius, dim);
        let sigma = (q * eps / 4.0).sqrt();
        let anchors = Mat::from_fn(r, dim, |_, _| rng.normal_scaled(0.0, sigma) as f32);
        Self::with_anchors(anchors, eps, q, radius)
    }

    /// Fit the radius from the data (R = max point norm over both clouds)
    /// then draw anchors.
    pub fn fit(mu: &Measure, nu: &Measure, eps: f64, r: usize, rng: &mut Rng) -> Self {
        assert_eq!(mu.dim(), nu.dim(), "measures must share a ground space");
        let radius = mu.radius().max(nu.radius()).max(1e-6);
        Self::new(eps, radius, mu.dim(), r, rng)
    }

    /// Build from explicit anchors (e.g. shared with the AOT artifacts).
    pub fn with_anchors(anchors: Mat, eps: f64, q: f64, radius: f64) -> Self {
        let (r, d) = anchors.shape();
        let mut log_const = Vec::with_capacity(r);
        let mut anchor_sq = Vec::with_capacity(r);
        let base = (d as f64 / 4.0) * (2.0 * q).ln() - 0.5 * (r as f64).ln();
        for j in 0..r {
            let usq: f64 = anchors.row(j).iter().map(|&v| (v as f64) * (v as f64)).sum();
            anchor_sq.push(usq as f32);
            log_const.push((base + usq / (eps * q)) as f32);
        }
        GaussianFeatureMap { anchors, eps, q, radius, log_const, anchor_sq }
    }

    /// The paper's psi constant `2 (2q)^{d/2}` bounding phi*phi/k —
    /// Theorem 3.1's feature-count driver, exposed for diagnostics.
    pub fn psi(&self) -> f64 {
        2.0 * (2.0 * self.q).powf(self.anchors.cols() as f64 / 2.0)
    }

    /// Gradient of `sum_ij upstream[i,j] * phi_j(x_i)` w.r.t. the point
    /// locations — the `(∂ξ/∂X)^T` piece of Prop 3.2's
    /// `∇_X W = -eps (∂ξ/∂X)^T u (ζ v)^T`, used for Sinkhorn-divergence
    /// gradient flows and generative modelling on raw coordinates.
    ///
    /// For the Lemma-1 features, `∂φ_j(x)/∂x = φ_j(x) · (-4/eps)(x - u_j)`.
    pub fn grad_points(&self, points: &Mat, phi: &Mat, upstream: &Mat) -> Mat {
        let (n, d) = points.shape();
        let r = self.num_features();
        assert_eq!(phi.shape(), (n, r));
        assert_eq!(upstream.shape(), (n, r));
        let coef = (-4.0 / self.eps) as f32;
        let mut out = Mat::zeros(n, d);
        for i in 0..n {
            let xi = points.row(i);
            let phii = phi.row(i);
            let upi = upstream.row(i);
            let orow = out.row_mut(i);
            for j in 0..r {
                let w = upi[j] * phii[j] * coef;
                if w == 0.0 {
                    continue;
                }
                let uj = self.anchors.row(j);
                for ((o, &x), &u) in orow.iter_mut().zip(xi).zip(uj) {
                    *o += w * (x - u);
                }
            }
        }
        out
    }
}

impl FeatureMap for GaussianFeatureMap {
    fn num_features(&self) -> usize {
        self.anchors.rows()
    }

    fn eval_into(&self, x: &[f32], out: &mut [f32]) {
        self.log_eval_into(x, out);
        special::vexp::exp_clamped_f32_at(simd::active_level(), out, LOG_FLOOR, LOG_CEIL);
    }

    fn log_eval_into(&self, x: &[f32], out: &mut [f32]) {
        let (r, d) = self.anchors.shape();
        assert_eq!(x.len(), d, "point dim {} != anchor dim {d}", x.len());
        assert_eq!(out.len(), r);
        let level = simd::active_level();
        let xsq: f32 = x.iter().map(|&v| v * v).sum();
        let inv_eps2 = (2.0 / self.eps) as f32;
        for j in 0..r {
            let urow = self.anchors.row(j);
            // ||x - u||^2 = ||x||^2 - 2 x.u + ||u||^2 (MXU-shaped on L1);
            // the anchor dot is the dispatched SIMD-core kernel.
            let dot = simd::dot_f32(level, x, urow);
            let sq = xsq - 2.0 * dot + self.anchor_sq[j];
            out[j] = self.log_const[j] - inv_eps2 * sq;
        }
    }
}

/// Lemma 3: perturbed arc-cosine features
/// `phi(x,u) = (sigma^{d/2} sqrt(2) max(0, u^T x)^s e^{-||u||^2(1-1/sigma^2)/4},
/// sqrt(kappa))` with anchors `u ~ N(0, sigma^2 I)`.
///
/// The trailing constant feature bounds the kernel below by `kappa > 0`,
/// which is what makes Assumption 2 hold (and Sinkhorn robust).
#[derive(Clone, Debug)]
pub struct ArcCosFeatureMap {
    pub anchors: Mat,
    /// Rectifier exponent s (0 = step kernel, 1 = ReLU/arc-cosine-1).
    pub s: u32,
    /// Positive perturbation kappa.
    pub kappa: f64,
    /// Anchor distribution scale sigma > 1.
    pub sigma: f64,
    scale: Vec<f32>,
}

impl ArcCosFeatureMap {
    pub fn new(dim: usize, r: usize, s: u32, kappa: f64, sigma: f64, rng: &mut Rng) -> Self {
        assert!(sigma > 1.0, "Lemma 3 requires sigma > 1");
        assert!(kappa > 0.0, "kappa must be positive");
        let anchors = Mat::from_fn(r, dim, |_, _| rng.normal_scaled(0.0, sigma) as f32);
        let mut scale = Vec::with_capacity(r);
        let c0 = sigma.powf(dim as f64 / 2.0) * 2.0f64.sqrt() / (r as f64).sqrt();
        for j in 0..r {
            let usq: f64 = anchors.row(j).iter().map(|&v| (v as f64) * (v as f64)).sum();
            scale.push((c0 * (-(usq / 4.0) * (1.0 - 1.0 / (sigma * sigma))).exp()) as f32);
        }
        ArcCosFeatureMap { anchors, s, kappa, sigma, scale }
    }
}

impl FeatureMap for ArcCosFeatureMap {
    fn num_features(&self) -> usize {
        self.anchors.rows() + 1 // +1 for the sqrt(kappa) constant feature
    }

    fn eval_into(&self, x: &[f32], out: &mut [f32]) {
        let (r, d) = self.anchors.shape();
        assert_eq!(x.len(), d);
        assert_eq!(out.len(), r + 1);
        let level = simd::active_level();
        for j in 0..r {
            let dot = simd::dot_f32(level, x, self.anchors.row(j));
            let rect = dot.max(0.0);
            let powed = match self.s {
                0 => {
                    if dot > 0.0 {
                        1.0
                    } else {
                        0.0
                    }
                }
                1 => rect,
                s => rect.powi(s as i32),
            };
            out[j] = powed * self.scale[j];
        }
        out[r] = (self.kappa as f32).sqrt();
    }
}

/// Remark 1: on the positive sphere the cost `c(x,y) = -log x^T y` is
/// *exactly* factorised — the feature map is the identity and `K = X Y^T`
/// with rank d. Fig. 6's barycenters run on this map with r = 3.
#[derive(Clone, Debug)]
pub struct SphereLinearMap {
    pub dim: usize,
}

impl SphereLinearMap {
    pub fn new(dim: usize) -> Self {
        SphereLinearMap { dim }
    }
}

impl FeatureMap for SphereLinearMap {
    fn num_features(&self) -> usize {
        self.dim
    }

    fn eval_into(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.dim);
        debug_assert!(
            x.iter().all(|&v| v > 0.0),
            "SphereLinearMap requires points on the strictly positive sphere"
        );
        out.copy_from_slice(x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    #[test]
    fn gaussian_features_strictly_positive() {
        let mut rng = Rng::seed_from(0);
        let fm = GaussianFeatureMap::new(0.5, 3.0, 2, 64, &mut rng);
        let phi = fm.feature_matrix(&Mat::from_fn(50, 2, |_, _| rng.normal_f32() * 2.0));
        assert!(phi.min_entry() > 0.0, "positivity by construction");
    }

    #[test]
    fn gaussian_kernel_mc_converges() {
        // <phi(x), phi(y)> -> exp(-||x-y||^2/eps) as r grows (Lemma 1).
        let mut rng = Rng::seed_from(1);
        let eps = 1.0;
        let fm = GaussianFeatureMap::new(eps, 2.0, 2, 8000, &mut rng);
        for _ in 0..10 {
            let x = [rng.uniform_in(-1.0, 1.0) as f32, rng.uniform_in(-1.0, 1.0) as f32];
            let y = [rng.uniform_in(-1.0, 1.0) as f32, rng.uniform_in(-1.0, 1.0) as f32];
            let k_theta = fm.kernel(&x, &y) as f64;
            let d2: f64 =
                x.iter().zip(&y).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
            let k_true = (-d2 / eps).exp();
            assert!(
                (k_theta / k_true - 1.0).abs() < 0.2,
                "ratio {} for d2 {d2}",
                k_theta / k_true
            );
        }
    }

    #[test]
    fn gaussian_q_uses_lambert() {
        let mut rng = Rng::seed_from(2);
        let fm = GaussianFeatureMap::new(0.5, 3.0, 2, 8, &mut rng);
        assert!((fm.q - special::gaussian_q(0.5, 3.0, 2)).abs() < 1e-12);
        assert!(fm.psi() > 0.0);
    }

    #[test]
    fn gaussian_fit_radius_covers_data() {
        let mut rng = Rng::seed_from(3);
        let (mu, nu) = data::gaussian_blobs(200, &mut rng);
        let fm = GaussianFeatureMap::fit(&mu, &nu, 0.5, 16, &mut rng);
        assert!(fm.radius >= mu.radius() && fm.radius >= nu.radius());
    }

    #[test]
    fn gaussian_feature_matrix_matches_eval() {
        let mut rng = Rng::seed_from(4);
        let fm = GaussianFeatureMap::new(0.7, 2.0, 3, 10, &mut rng);
        let pts = Mat::from_fn(7, 3, |_, _| rng.normal_f32());
        let phi = fm.feature_matrix(&pts);
        let mut row = vec![0.0; 10];
        for i in 0..7 {
            fm.eval_into(pts.row(i), &mut row);
            for j in 0..10 {
                assert_eq!(phi[(i, j)], row[j]);
            }
        }
    }

    #[test]
    fn arccos_kernel_bounded_below_by_kappa() {
        let mut rng = Rng::seed_from(5);
        let fm = ArcCosFeatureMap::new(3, 100, 1, 0.25, 1.5, &mut rng);
        for _ in 0..20 {
            let x: Vec<f32> = (0..3).map(|_| rng.normal_f32()).collect();
            let y: Vec<f32> = (0..3).map(|_| rng.normal_f32()).collect();
            assert!(fm.kernel(&x, &y) >= 0.25 - 1e-5);
        }
    }

    #[test]
    fn arccos_s0_features_are_binary_scaled() {
        let mut rng = Rng::seed_from(6);
        let fm = ArcCosFeatureMap::new(2, 10, 0, 0.1, 1.2, &mut rng);
        let mut out = vec![0.0; 11];
        fm.eval_into(&[1.0, 0.5], &mut out);
        // Each non-constant feature is 0 or the anchor scale.
        for (j, &v) in out[..10].iter().enumerate() {
            assert!(v == 0.0 || (v - fm.scale[j]).abs() < 1e-6);
        }
    }

    #[test]
    fn sphere_linear_map_is_identity() {
        let fm = SphereLinearMap::new(3);
        let mut out = vec![0.0; 3];
        fm.eval_into(&[0.6, 0.48, 0.64], &mut out);
        assert_eq!(out, vec![0.6, 0.48, 0.64]);
        // Kernel is the dot product.
        let k = fm.kernel(&[0.6, 0.48, 0.64], &[0.1, 0.2, 0.97]);
        assert!((k - (0.06 + 0.096 + 0.6208)).abs() < 1e-5);
    }

    #[test]
    fn cost_is_neg_eps_log_kernel() {
        let fm = SphereLinearMap::new(2);
        let c = fm.cost(&[0.6, 0.8], &[0.8, 0.6], 2.0);
        let k = 0.6f64 * 0.8 + 0.8 * 0.6;
        assert!((c + 2.0 * k.ln()).abs() < 1e-6);
    }
}
