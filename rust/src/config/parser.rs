//! TOML-subset parser: sections, dotted lookup, scalars and flat arrays.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

/// Parse error with line number context.
#[derive(Debug, Clone)]
pub struct ConfigError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ConfigError {}

/// A parsed document: flat map from dotted key path to value.
#[derive(Debug, Default, Clone)]
pub struct ConfigDoc {
    values: BTreeMap<String, Value>,
}

impl ConfigDoc {
    /// Parse a TOML-subset string.
    pub fn parse(text: &str) -> Result<ConfigDoc, ConfigError> {
        let mut doc = ConfigDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            let err = |m: &str| ConfigError { line: lineno + 1, message: m.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name =
                    rest.strip_suffix(']').ok_or_else(|| err("unterminated section header"))?;
                let name = name.trim();
                if name.is_empty()
                    || !name
                        .chars()
                        .all(|c| c.is_alphanumeric() || c == '_' || c == '.' || c == '-')
                {
                    return Err(err("invalid section name"));
                }
                section = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| err("expected `key = value`"))?;
            let key = line[..eq].trim();
            if key.is_empty() || !key.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '-') {
                return Err(err("invalid key"));
            }
            let value_text = line[eq + 1..].trim();
            if value_text.is_empty() {
                return Err(err("missing value"));
            }
            let value = parse_value(value_text).map_err(|m| err(&m))?;
            let path =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            if doc.values.contains_key(&path) {
                return Err(err(&format!("duplicate key `{path}`")));
            }
            doc.values.insert(path, value);
        }
        Ok(doc)
    }

    /// Parse a file.
    pub fn parse_file(path: &str) -> Result<ConfigDoc, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError { line: 0, message: format!("read {path}: {e}") })?;
        ConfigDoc::parse(&text)
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.values.get(path)
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        match self.values.get(path) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_int(&self, path: &str) -> Option<i64> {
        match self.values.get(path) {
            Some(Value::Int(i)) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`n = 7` reads as 7.0).
    pub fn get_float(&self, path: &str) -> Option<f64> {
        match self.values.get(path) {
            Some(Value::Float(x)) => Some(*x),
            Some(Value::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, path: &str) -> Option<bool> {
        match self.values.get(path) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    pub fn get_int_array(&self, path: &str) -> Option<Vec<i64>> {
        match self.values.get(path) {
            Some(Value::Array(xs)) => xs
                .iter()
                .map(|v| match v {
                    Value::Int(i) => Some(*i),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }

    pub fn get_float_array(&self, path: &str) -> Option<Vec<f64>> {
        match self.values.get(path) {
            Some(Value::Array(xs)) => xs
                .iter()
                .map(|v| match v {
                    Value::Float(x) => Some(*x),
                    Value::Int(i) => Some(*i as f64),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }

    /// All keys (dotted), for diagnostics.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a string.
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => escaped = !escaped,
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => escaped = false,
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value, String> {
    let t = text.trim();
    if let Some(rest) = t.strip_prefix('"') {
        return parse_string(rest).map(Value::Str);
    }
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = t.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|s| parse_value(s.trim())).collect();
        return Ok(Value::Array(items?));
    }
    // Number: int if it parses as i64 and has no float markers.
    let looks_float = t.contains('.') || t.contains('e') || t.contains('E');
    if !looks_float {
        if let Ok(i) = t.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(x) = t.parse::<f64>() {
        return Ok(Value::Float(x));
    }
    Err(format!("cannot parse value `{t}`"))
}

fn parse_string(rest: &str) -> Result<String, String> {
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => {
                let trailing: String = chars.collect();
                if !trailing.trim().is_empty() {
                    return Err("trailing characters after string".into());
                }
                return Ok(out);
            }
            '\\' => match chars.next() {
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                other => return Err(format!("bad escape \\{other:?}")),
            },
            c => out.push(c),
        }
    }
    Err("unterminated string".into())
}
