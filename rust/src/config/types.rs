//! Typed configuration structs with documented defaults.

use super::ConfigDoc;

/// Core Sinkhorn solver configuration (Alg. 1 / Alg. 2).
#[derive(Clone, Debug)]
pub struct SinkhornConfig {
    /// Entropic regularisation strength epsilon.
    pub epsilon: f64,
    /// Hard iteration cap.
    pub max_iters: usize,
    /// L1 marginal-error stopping tolerance (Alg. 1's delta).
    pub tol: f64,
    /// Check the stopping criterion every this many iterations (the check
    /// itself costs one kernel apply).
    pub check_every: usize,
    /// Solver-level parallelism: worker threads for the three concurrent
    /// transport problems of a Sinkhorn divergence. `1` = sequential,
    /// `0` = auto-size to the machine. Results are identical for every
    /// value — the parallel kernels are deterministic in the thread
    /// count (see `runtime::pool`), though factored applies above one
    /// transpose chunk (1024 rows) round differently than the pre-pool
    /// releases at any thread count.
    pub threads: usize,
    /// Escalate to the matrix-free log-domain solver when plain Alg. 1
    /// reports non-finite scalings (small-eps over/underflow). Applies to
    /// `sinkhorn_divergence` and the coordinator (which counts
    /// escalations as `service.stabilized_solves`); kernels without a
    /// log-domain view keep their original error. `sinkhorn.stabilize`
    /// in config files, `--stabilize` on the CLI.
    pub stabilize: bool,
    /// Width cap for the batched multi-pair solve engine
    /// (`sinkhorn::solve_batch`): the coordinator fuses at most this many
    /// compatible requests into one column-blocked solve. `1` disables
    /// fusion (every request solves alone). Fusion never changes results
    /// — batched solves are bitwise identical to sequential ones — so
    /// this knob trades per-request latency against throughput only.
    /// `sinkhorn.max_batch` in config files, `--max-batch` on the CLI.
    pub max_batch: usize,
    /// Eps-annealing: run each solve down a geometric eps ladder
    /// ([`EpsSchedule`](crate::sinkhorn::EpsSchedule)), warm-starting the
    /// duals between rungs. `None` (the default) lets the planner decide
    /// — it anneals exactly where the direct solve would pay for
    /// log-domain escalation (small eps relative to the squared support
    /// radius). `Some(true)`/`Some(false)` forces the choice.
    /// `sinkhorn.anneal` in config files, `--anneal auto|on|off` on the
    /// CLI.
    pub anneal: Option<bool>,
    /// Geometric damping factor of the annealing ladder, in (0, 1): each
    /// rung's eps is the previous rung's times this. 0.5 halves eps per
    /// rung (geomloss' default scaling). `sinkhorn.anneal_decay` in
    /// config files, `--anneal-decay` on the CLI.
    pub anneal_decay: f64,
    /// Use the one-dual symmetric fixed-point iteration for the xx/yy
    /// self-solves of a Sinkhorn divergence (half the kernel applies per
    /// self-iteration). `None` (default) lets the planner decide — on
    /// whenever a schedule is on; `Some(true)`/`Some(false)` forces it.
    /// `sinkhorn.symmetric` in config files, `--symmetric auto|on|off`
    /// on the CLI.
    pub symmetric: Option<bool>,
}

impl Default for SinkhornConfig {
    fn default() -> Self {
        SinkhornConfig {
            epsilon: 0.5,
            max_iters: 5000,
            tol: 1e-3,
            check_every: 10,
            threads: 1,
            stabilize: true,
            max_batch: 8,
            anneal: None,
            anneal_decay: 0.5,
            symmetric: None,
        }
    }
}

impl SinkhornConfig {
    pub fn from_doc(doc: &ConfigDoc) -> Self {
        let d = SinkhornConfig::default();
        SinkhornConfig {
            epsilon: doc.get_float("sinkhorn.epsilon").unwrap_or(d.epsilon),
            max_iters: doc.get_int("sinkhorn.max_iters").unwrap_or(d.max_iters as i64) as usize,
            tol: doc.get_float("sinkhorn.tol").unwrap_or(d.tol),
            check_every: doc.get_int("sinkhorn.check_every").unwrap_or(d.check_every as i64)
                as usize,
            threads: doc.get_int("sinkhorn.threads").unwrap_or(d.threads as i64) as usize,
            stabilize: doc.get_bool("sinkhorn.stabilize").unwrap_or(d.stabilize),
            max_batch: doc.get_int("sinkhorn.max_batch").unwrap_or(d.max_batch as i64) as usize,
            // Tri-state: an absent key stays `None` (planner decides).
            anneal: doc.get_bool("sinkhorn.anneal").or(d.anneal),
            anneal_decay: doc.get_float("sinkhorn.anneal_decay").unwrap_or(d.anneal_decay),
            symmetric: doc.get_bool("sinkhorn.symmetric").or(d.symmetric),
        }
    }
}

/// Time–accuracy tradeoff experiment configuration (Figures 1/3/5).
#[derive(Clone, Debug)]
pub struct TradeoffConfig {
    /// Samples per distribution.
    pub n: usize,
    /// Regularisations to sweep.
    pub epsilons: Vec<f64>,
    /// Feature counts / Nyström ranks to sweep.
    pub ranks: Vec<usize>,
    /// Repetitions per (eps, r) cell.
    pub reps: usize,
    /// Seed for the whole sweep.
    pub seed: u64,
}

impl Default for TradeoffConfig {
    fn default() -> Self {
        TradeoffConfig {
            n: 4000,
            epsilons: vec![0.05, 0.1, 0.5, 1.0],
            ranks: vec![100, 300, 600, 1000, 2000],
            reps: 5,
            seed: 0,
        }
    }
}

impl TradeoffConfig {
    pub fn from_doc(doc: &ConfigDoc) -> Self {
        let d = TradeoffConfig::default();
        TradeoffConfig {
            n: doc.get_int("tradeoff.n").unwrap_or(d.n as i64) as usize,
            epsilons: doc.get_float_array("tradeoff.epsilons").unwrap_or(d.epsilons),
            ranks: doc
                .get_int_array("tradeoff.ranks")
                .map(|v| v.into_iter().map(|x| x as usize).collect())
                .unwrap_or(d.ranks),
            reps: doc.get_int("tradeoff.reps").unwrap_or(d.reps as i64) as usize,
            seed: doc.get_int("tradeoff.seed").unwrap_or(d.seed as i64) as u64,
        }
    }
}

/// Dynamic batcher policy for the divergence service.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Flush when this many requests are pending.
    pub max_batch: usize,
    /// Flush when the oldest pending request has waited this long (us).
    pub max_delay_us: u64,
    /// Bounded queue depth; beyond this the service sheds load.
    pub queue_depth: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 16, max_delay_us: 500, queue_depth: 1024 }
    }
}

impl BatcherConfig {
    pub fn from_doc(doc: &ConfigDoc) -> Self {
        let d = BatcherConfig::default();
        BatcherConfig {
            max_batch: doc.get_int("service.batcher.max_batch").unwrap_or(d.max_batch as i64)
                as usize,
            max_delay_us: doc
                .get_int("service.batcher.max_delay_us")
                .unwrap_or(d.max_delay_us as i64) as u64,
            queue_depth: doc.get_int("service.batcher.queue_depth").unwrap_or(d.queue_depth as i64)
                as usize,
        }
    }
}

/// Shard-coordinator policy knobs (liveness, retry, hedging, admission,
/// rejoin), the config-file / CLI view of
/// [`crate::shard::ShardConfig`]. All durations are millisecond
/// integers here; `to_shard_config` converts.
#[derive(Clone, Debug)]
pub struct ShardSettings {
    /// Ping cadence while tasks are outstanding.
    /// `service.shard.heartbeat_interval_ms`, `--shard-heartbeat-ms`.
    pub heartbeat_interval_ms: u64,
    /// Silence longer than this declares a worker dead.
    /// `service.shard.heartbeat_timeout_ms`, `--shard-timeout-ms`.
    pub heartbeat_timeout_ms: u64,
    /// Unanswered tasks older than this are re-scattered.
    /// `service.shard.task_deadline_ms`, `--shard-deadline-ms`.
    pub task_deadline_ms: u64,
    /// Re-scatter attempts before a task fails typed.
    /// `service.shard.max_retries`, `--shard-retries`.
    pub max_retries: usize,
    /// Base linear re-scatter backoff.
    /// `service.shard.retry_backoff_ms`, `--shard-backoff-ms`.
    pub retry_backoff_ms: u64,
    /// Straggler-hedging threshold as a fraction of the task deadline
    /// (`0` disables). `service.shard.hedge_fraction`, `--shard-hedge`.
    pub hedge_fraction: f64,
    /// Bounded in-flight group budget; beyond it groups shed with
    /// `Error::Overloaded`. `service.shard.max_inflight_groups`,
    /// `--shard-max-inflight`.
    pub max_inflight_groups: usize,
    /// Minimum wait between rejoin attempts for a dead worker.
    /// `service.shard.rejoin_backoff_ms`, `--shard-rejoin-ms`.
    pub rejoin_backoff_ms: u64,
    /// Budget for the graceful drain at service shutdown.
    /// `service.shard.drain_deadline_ms`, `--shard-drain-ms`.
    pub drain_deadline_ms: u64,
}

impl Default for ShardSettings {
    fn default() -> Self {
        // Mirrors `crate::shard::ShardConfig::default()` (asserted by
        // the `shard_settings_defaults_match_shard_config` test), plus
        // the service-only drain budget.
        ShardSettings {
            heartbeat_interval_ms: 50,
            heartbeat_timeout_ms: 1_000,
            task_deadline_ms: 30_000,
            max_retries: 2,
            retry_backoff_ms: 20,
            hedge_fraction: 0.5,
            max_inflight_groups: 16,
            rejoin_backoff_ms: 250,
            drain_deadline_ms: 5_000,
        }
    }
}

impl ShardSettings {
    pub fn from_doc(doc: &ConfigDoc) -> Self {
        let d = ShardSettings::default();
        ShardSettings {
            heartbeat_interval_ms: doc
                .get_int("service.shard.heartbeat_interval_ms")
                .unwrap_or(d.heartbeat_interval_ms as i64) as u64,
            heartbeat_timeout_ms: doc
                .get_int("service.shard.heartbeat_timeout_ms")
                .unwrap_or(d.heartbeat_timeout_ms as i64) as u64,
            task_deadline_ms: doc
                .get_int("service.shard.task_deadline_ms")
                .unwrap_or(d.task_deadline_ms as i64) as u64,
            max_retries: doc
                .get_int("service.shard.max_retries")
                .unwrap_or(d.max_retries as i64) as usize,
            retry_backoff_ms: doc
                .get_int("service.shard.retry_backoff_ms")
                .unwrap_or(d.retry_backoff_ms as i64) as u64,
            hedge_fraction: doc
                .get_float("service.shard.hedge_fraction")
                .unwrap_or(d.hedge_fraction),
            max_inflight_groups: doc
                .get_int("service.shard.max_inflight_groups")
                .unwrap_or(d.max_inflight_groups as i64) as usize,
            rejoin_backoff_ms: doc
                .get_int("service.shard.rejoin_backoff_ms")
                .unwrap_or(d.rejoin_backoff_ms as i64) as u64,
            drain_deadline_ms: doc
                .get_int("service.shard.drain_deadline_ms")
                .unwrap_or(d.drain_deadline_ms as i64) as u64,
        }
    }

    /// The coordinator-facing view (everything but the drain budget,
    /// which belongs to service shutdown, not the coordinator).
    pub fn to_shard_config(&self) -> crate::shard::ShardConfig {
        use std::time::Duration;
        crate::shard::ShardConfig {
            heartbeat_interval: Duration::from_millis(self.heartbeat_interval_ms),
            heartbeat_timeout: Duration::from_millis(self.heartbeat_timeout_ms),
            task_deadline: Duration::from_millis(self.task_deadline_ms),
            max_retries: self.max_retries,
            retry_backoff: Duration::from_millis(self.retry_backoff_ms),
            hedge_fraction: self.hedge_fraction,
            max_inflight_groups: self.max_inflight_groups,
            rejoin_backoff: Duration::from_millis(self.rejoin_backoff_ms),
        }
    }
}

/// Divergence service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Worker threads executing Sinkhorn solves.
    pub workers: usize,
    pub batcher: BatcherConfig,
    pub sinkhorn: SinkhornConfig,
    /// Number of random features the service uses per request.
    pub num_features: usize,
    /// Intra-solve parallelism per worker: threads used by each request's
    /// pooled matvecs and feature evaluation (`1` = serial, `0` = auto).
    /// Worker-level and intra-solve parallelism multiply, so keep
    /// `workers * solver_threads` near the core count.
    pub solver_threads: usize,
    /// Capacity (entries) of the shared feature-map cache keyed by
    /// `(dim, eps, r)`; `0` disables caching and re-fits per request.
    pub cache_capacity: usize,
    /// Shard worker count for cross-host-style serving: `0` (default)
    /// solves in-process as before; `> 0` spawns that many shard workers
    /// and delegates every fuse group through the shard coordinator
    /// (scatter / gather / liveness / retry — see `crate::shard`).
    /// Results are bitwise identical either way.
    /// `service.shard_workers` in config files, `--shard-workers` on the
    /// CLI.
    pub shard_workers: usize,
    /// Roster of already-listening cross-host shard workers
    /// (`host:port` each). Non-empty takes precedence over
    /// `shard_workers`: the service dials and handshakes every entry
    /// instead of spawning in-process workers, and dead entries are
    /// periodically re-dialled (rejoin). Comma-separated in
    /// `service.shard_addrs` config keys and the `--shard-addrs` flag;
    /// `--shard-worker-file` loads one `host:port` per line.
    pub shard_addrs: Vec<String>,
    /// Shard liveness / retry / hedging / admission / rejoin policy
    /// (only consulted when sharding is on).
    pub shard: ShardSettings,
    /// Planner backend preference for served solves, in the CLI's
    /// `--backend` syntax (`auto`, `dense`, `factored[:rank]`,
    /// `nystrom[:rank]`, `nystrom-adaptive[:rank]`; a missing rank falls
    /// back to `num_features`). The default `factored` is the pre-PR-8
    /// service behaviour — the positive-feature kernel with
    /// `num_features` features and the shared feature-map cache.
    /// `service.backend` in config files, `--backend` on the CLI.
    pub backend: String,
    /// Maximum number of live streaming sessions the coordinator's
    /// session table will hold; `session_create` sheds with
    /// `Error::Overloaded` beyond this. `service.session_capacity` in
    /// config files, `--session-capacity` on the CLI.
    pub session_capacity: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 4,
            batcher: BatcherConfig::default(),
            sinkhorn: SinkhornConfig::default(),
            num_features: 256,
            solver_threads: 1,
            cache_capacity: 8,
            shard_workers: 0,
            shard_addrs: Vec::new(),
            shard: ShardSettings::default(),
            backend: "factored".to_string(),
            session_capacity: 64,
        }
    }
}

impl ServiceConfig {
    pub fn from_doc(doc: &ConfigDoc) -> Self {
        let d = ServiceConfig::default();
        ServiceConfig {
            workers: doc.get_int("service.workers").unwrap_or(d.workers as i64) as usize,
            batcher: BatcherConfig::from_doc(doc),
            sinkhorn: SinkhornConfig::from_doc(doc),
            num_features: doc.get_int("service.num_features").unwrap_or(d.num_features as i64)
                as usize,
            solver_threads: doc
                .get_int("service.solver_threads")
                .unwrap_or(d.solver_threads as i64) as usize,
            cache_capacity: doc
                .get_int("service.cache_capacity")
                .unwrap_or(d.cache_capacity as i64) as usize,
            shard_workers: doc
                .get_int("service.shard_workers")
                .unwrap_or(d.shard_workers as i64) as usize,
            shard_addrs: doc
                .get_str("service.shard_addrs")
                .map(|s| {
                    s.split(',')
                        .map(str::trim)
                        .filter(|a| !a.is_empty())
                        .map(str::to_string)
                        .collect()
                })
                .unwrap_or(d.shard_addrs),
            shard: ShardSettings::from_doc(doc),
            backend: doc
                .get_str("service.backend")
                .map(str::to_string)
                .unwrap_or(d.backend),
            session_capacity: doc
                .get_int("service.session_capacity")
                .unwrap_or(d.session_capacity as i64) as usize,
        }
    }
}

/// Adversarial-kernel GAN training configuration (paper §4, Eq. 18).
#[derive(Clone, Debug)]
pub struct GanConfig {
    /// Minibatch size s (the paper uses s = 7000 thanks to linearity).
    pub batch_size: usize,
    /// Number of learned random features r (paper: 600).
    pub num_features: usize,
    /// Latent dimension of the generator input.
    pub latent_dim: usize,
    /// Embedding dimension of f_gamma.
    pub embed_dim: usize,
    /// Sinkhorn regularisation (paper: 1.0).
    pub epsilon: f64,
    /// Sinkhorn iterations per divergence evaluation.
    pub sinkhorn_iters: usize,
    /// Critic (cost) steps per generator step (paper's n_c).
    pub critic_steps: usize,
    /// Total generator steps.
    pub steps: usize,
    /// Adam learning rate.
    pub lr: f64,
    pub seed: u64,
}

impl Default for GanConfig {
    fn default() -> Self {
        GanConfig {
            batch_size: 256,
            num_features: 64,
            latent_dim: 16,
            embed_dim: 8,
            epsilon: 1.0,
            sinkhorn_iters: 50,
            critic_steps: 1,
            steps: 300,
            lr: 1e-3,
            seed: 0,
        }
    }
}

impl GanConfig {
    pub fn from_doc(doc: &ConfigDoc) -> Self {
        let d = GanConfig::default();
        GanConfig {
            batch_size: doc.get_int("gan.batch_size").unwrap_or(d.batch_size as i64) as usize,
            num_features: doc.get_int("gan.num_features").unwrap_or(d.num_features as i64) as usize,
            latent_dim: doc.get_int("gan.latent_dim").unwrap_or(d.latent_dim as i64) as usize,
            embed_dim: doc.get_int("gan.embed_dim").unwrap_or(d.embed_dim as i64) as usize,
            epsilon: doc.get_float("gan.epsilon").unwrap_or(d.epsilon),
            sinkhorn_iters: doc.get_int("gan.sinkhorn_iters").unwrap_or(d.sinkhorn_iters as i64)
                as usize,
            critic_steps: doc.get_int("gan.critic_steps").unwrap_or(d.critic_steps as i64) as usize,
            steps: doc.get_int("gan.steps").unwrap_or(d.steps as i64) as usize,
            lr: doc.get_float("gan.lr").unwrap_or(d.lr),
            seed: doc.get_int("gan.seed").unwrap_or(d.seed as i64) as u64,
        }
    }
}
