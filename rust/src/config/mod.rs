//! Configuration substrate: a TOML-subset parser plus the typed configs
//! used by the binary, the service and the GAN trainer.
//!
//! The offline crate set has no `serde`/`toml`, so we parse a pragmatic
//! subset ourselves: `[section]` / `[section.sub]` tables, `key = value`
//! with strings, integers, floats, booleans and flat arrays, `#` comments.
//! That covers every config this project ships.

mod parser;
mod types;

pub use parser::{ConfigDoc, ConfigError, Value};
pub use types::{
    BatcherConfig, GanConfig, ServiceConfig, ShardSettings, SinkhornConfig, TradeoffConfig,
};

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# top-level
name = "fig1"
reps = 5
eps = 0.5
full = false
ranks = [100, 300, 600, 1000, 2000]

[sinkhorn]
max_iters = 5000
tol = 1e-3

[service.batcher]
max_batch = 32
max_delay_us = 500
"#;

    #[test]
    fn parses_scalars() {
        let doc = ConfigDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get_str("name"), Some("fig1"));
        assert_eq!(doc.get_int("reps"), Some(5));
        assert_eq!(doc.get_float("eps"), Some(0.5));
        assert_eq!(doc.get_bool("full"), Some(false));
    }

    #[test]
    fn parses_arrays() {
        let doc = ConfigDoc::parse(SAMPLE).unwrap();
        let ranks = doc.get_int_array("ranks").unwrap();
        assert_eq!(ranks, vec![100, 300, 600, 1000, 2000]);
    }

    #[test]
    fn parses_nested_tables() {
        let doc = ConfigDoc::parse(SAMPLE).unwrap();
        assert_eq!(doc.get_int("sinkhorn.max_iters"), Some(5000));
        assert_eq!(doc.get_float("sinkhorn.tol"), Some(1e-3));
        assert_eq!(doc.get_int("service.batcher.max_batch"), Some(32));
    }

    #[test]
    fn shard_settings_defaults_match_shard_config() {
        // The config-file view and the coordinator's own defaults must
        // not drift apart.
        let d = crate::shard::ShardConfig::default();
        let s = ShardSettings::default().to_shard_config();
        assert_eq!(s.heartbeat_interval, d.heartbeat_interval);
        assert_eq!(s.heartbeat_timeout, d.heartbeat_timeout);
        assert_eq!(s.task_deadline, d.task_deadline);
        assert_eq!(s.max_retries, d.max_retries);
        assert_eq!(s.retry_backoff, d.retry_backoff);
        assert_eq!(s.hedge_fraction, d.hedge_fraction);
        assert_eq!(s.max_inflight_groups, d.max_inflight_groups);
        assert_eq!(s.rejoin_backoff, d.rejoin_backoff);
    }

    #[test]
    fn shard_settings_and_roster_parse_from_doc() {
        let doc = ConfigDoc::parse(
            r#"
[service]
shard_addrs = "10.0.0.1:7000, 10.0.0.2:7000"

[service.shard]
heartbeat_interval_ms = 25
task_deadline_ms = 2000
hedge_fraction = 0.25
max_inflight_groups = 4
rejoin_backoff_ms = 100
"#,
        )
        .unwrap();
        let cfg = ServiceConfig::from_doc(&doc);
        assert_eq!(cfg.shard_addrs, vec!["10.0.0.1:7000", "10.0.0.2:7000"]);
        assert_eq!(cfg.shard.heartbeat_interval_ms, 25);
        assert_eq!(cfg.shard.task_deadline_ms, 2000);
        assert_eq!(cfg.shard.hedge_fraction, 0.25);
        assert_eq!(cfg.shard.max_inflight_groups, 4);
        assert_eq!(cfg.shard.rejoin_backoff_ms, 100);
        // Untouched keys keep their defaults.
        let d = ShardSettings::default();
        assert_eq!(cfg.shard.heartbeat_timeout_ms, d.heartbeat_timeout_ms);
        assert_eq!(cfg.shard.max_retries, d.max_retries);
        assert_eq!(cfg.shard.drain_deadline_ms, d.drain_deadline_ms);
    }

    #[test]
    fn float_forms() {
        let doc = ConfigDoc::parse("a = 1e-3\nb = -2.5\nc = 3.0\nd = 0.5").unwrap();
        assert_eq!(doc.get_float("a"), Some(1e-3));
        assert_eq!(doc.get_float("b"), Some(-2.5));
        assert_eq!(doc.get_float("c"), Some(3.0));
        assert_eq!(doc.get_float("d"), Some(0.5));
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = ConfigDoc::parse("n = 7").unwrap();
        assert_eq!(doc.get_float("n"), Some(7.0));
    }

    #[test]
    fn missing_keys_are_none() {
        let doc = ConfigDoc::parse("a = 1").unwrap();
        assert_eq!(doc.get_int("b"), None);
        assert_eq!(doc.get_str("a"), None, "type-mismatched get returns None");
    }

    #[test]
    fn rejects_garbage() {
        assert!(ConfigDoc::parse("this is not toml").is_err());
        assert!(ConfigDoc::parse("a = ").is_err());
        assert!(ConfigDoc::parse("[unclosed").is_err());
        assert!(ConfigDoc::parse("a = \"unterminated").is_err());
    }

    #[test]
    fn duplicate_key_is_error() {
        assert!(ConfigDoc::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let doc = ConfigDoc::parse("# c\n\na = 1  # trailing\n").unwrap();
        assert_eq!(doc.get_int("a"), Some(1));
    }

    #[test]
    fn string_escapes() {
        let doc = ConfigDoc::parse(r#"s = "a\"b\\c""#).unwrap();
        assert_eq!(doc.get_str("s"), Some("a\"b\\c"));
    }

    #[test]
    fn float_array() {
        let doc = ConfigDoc::parse("xs = [0.1, 1.0, 2.5]").unwrap();
        assert_eq!(doc.get_float_array("xs").unwrap(), vec![0.1, 1.0, 2.5]);
    }

    #[test]
    fn typed_sinkhorn_config_roundtrip() {
        let doc = ConfigDoc::parse(
            "[sinkhorn]\nepsilon = 0.25\nmax_iters = 123\ntol = 1e-4\nstabilize = false\n\
             max_batch = 4",
        )
        .unwrap();
        let cfg = SinkhornConfig::from_doc(&doc);
        assert_eq!(cfg.epsilon, 0.25);
        assert_eq!(cfg.max_iters, 123);
        assert_eq!(cfg.tol, 1e-4);
        assert!(!cfg.stabilize);
        assert_eq!(cfg.max_batch, 4);
    }

    #[test]
    fn stabilize_defaults_on() {
        let doc = ConfigDoc::parse("").unwrap();
        assert!(SinkhornConfig::from_doc(&doc).stabilize);
    }

    #[test]
    fn max_batch_defaults_to_fusion_enabled() {
        let doc = ConfigDoc::parse("").unwrap();
        assert!(SinkhornConfig::from_doc(&doc).max_batch > 1);
    }

    #[test]
    fn typed_defaults_when_absent() {
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = SinkhornConfig::from_doc(&doc);
        assert!(cfg.epsilon > 0.0 && cfg.max_iters > 0);
    }
}
