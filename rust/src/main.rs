//! `linear-sinkhorn` — leader binary.
//!
//! Subcommands:
//!   divergence   compute the Sinkhorn divergence between two generated clouds
//!   tradeoff     run a time–accuracy sweep (RF vs Nys vs Sin) and print a table
//!   barycenter   Fig-6 barycenter on the positive sphere
//!   gan-train    train the adversarial-kernel GAN on the synthetic corpus
//!   serve        start the divergence service and drive it with a workload
//!   shard-worker run a standalone shard worker for `serve --shard-addrs` rosters
//!   runtime      smoke-check the PJRT runtime against the AOT artifacts
//!
//! Every subcommand accepts `--help`.

use linear_sinkhorn::barycenter::{barycenter, BarycenterConfig};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::config::{GanConfig, ServiceConfig};
use linear_sinkhorn::gan::GanTrainer;
use linear_sinkhorn::linalg::softmax_inplace;
use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;
use linear_sinkhorn::runtime::{mat_to_literal, vec_to_literal, Engine, Registry};
use linear_sinkhorn::{coordinator, data, features::FeatureMap, features::SphereLinearMap};

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        eprintln!(
            "usage: linear-sinkhorn \
             <divergence|tradeoff|barycenter|gan-train|serve|shard-worker|runtime> [--help]"
        );
        std::process::exit(2);
    }
    let cmd = args.remove(0);
    let code = match cmd.as_str() {
        "divergence" => cmd_divergence(args),
        "tradeoff" => cmd_tradeoff(args),
        "barycenter" => cmd_barycenter(args),
        "gan-train" => cmd_gan(args),
        "serve" => cmd_serve(args),
        "shard-worker" => cmd_shard_worker(args),
        "runtime" => cmd_runtime(args),
        other => {
            eprintln!("unknown subcommand `{other}`");
            2
        }
    };
    std::process::exit(code);
}

fn parse(spec: ArgSpec, args: Vec<String>) -> linear_sinkhorn::cli::Args {
    match spec.parse_from(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

/// Parse an `on`/`off` CLI value (also accepts true/false and 1/0).
fn parse_on_off(name: &str, value: &str) -> bool {
    match value {
        "on" | "true" | "1" => true,
        "off" | "false" | "0" => false,
        other => {
            eprintln!("--{name}: expected on|off, got `{other}`");
            std::process::exit(2);
        }
    }
}

/// Parse an `auto`/`on`/`off` CLI value; `auto` defers to the planner.
fn parse_auto_on_off(name: &str, value: &str) -> Option<bool> {
    match value {
        "auto" => None,
        other => Some(parse_on_off(name, other)),
    }
}

fn cmd_divergence(argv: Vec<String>) -> i32 {
    let a = parse(
        ArgSpec::new("divergence", "Sinkhorn divergence between two Gaussian clouds")
            .opt("n", "2000", "samples per cloud")
            .opt("eps", "0.5", "entropic regularisation")
            .opt("features", "512", "number of positive random features r")
            .opt("threads", "1", "solver threads (0 = auto-size to the machine)")
            .opt(
                "stabilize",
                "on",
                "escalate to the log-domain solver on small-eps divergence (on/off); \
                 the planner may still pick the log domain outright at tiny eps",
            )
            .opt(
                "anneal",
                "auto",
                "eps-annealing: geometric eps schedule from the support-diameter scale \
                 down to --eps with dual warm starts between rungs (auto/on/off; auto \
                 lets the planner anneal when tiny eps would underflow)",
            )
            .opt("anneal-decay", "0.5", "geometric decay per annealing rung, in (0,1)")
            .opt(
                "symmetric",
                "auto",
                "one-dual symmetric fixed point for the xx/yy self solves \
                 (auto/on/off; auto follows the annealing choice)",
            )
            .opt(
                "backend",
                "factored",
                "kernel backend: auto|dense|factored|nystrom|nystrom-adaptive, each \
                 optionally with a :rank suffix (default rank = --features); auto \
                 runs the planner's flops rule, nystrom-* may lose positivity at \
                 small eps and then fails typed",
            )
            .opt("seed", "0", "RNG seed")
            .flag(
                "explain",
                "print the solver plan (narrated decision + JSON) before executing; \
                 annealed plans carry `schedule` {eps_start, decay} and \
                 `symmetric_self_solves`",
            ),
        argv,
    );
    let (n, eps, r, seed) =
        (a.get_usize("n"), a.get_f64("eps"), a.get_usize("features"), a.get_u64("seed"));
    let backend = match BackendPref::parse_flag(a.get_str("backend"), r) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let stabilize = parse_on_off("stabilize", a.get_str("stabilize"));
    // One --threads budget split across the two parallelism levels: up
    // to 3 concurrent solves, with the remainder row-chunking each
    // solve's matvecs (3-way * kernel pool stays near the budget
    // instead of multiplying to 3*T).
    let threads = {
        let requested = a.get_usize("threads");
        if requested == 0 { linear_sinkhorn::runtime::pool::available_threads() } else { requested }
    };
    let mut rng = Rng::seed_from(seed);
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    // Stabilised factors + automatic domain planning: any eps a user
    // types should produce a number, not a NaN (EXPERIMENTS.md
    // §Stabilisation). `--stabilize off` pins the plain domain so
    // small-eps failures surface as typed errors instead.
    let mut problem = OtProblem::new(&mu, &nu)
        .epsilon(eps)
        .backend(backend)
        .threads(threads.min(3))
        .solver_threads(threads.div_ceil(3))
        .seed(seed);
    if !stabilize {
        problem = problem.domain(DomainChoice::Plain);
    }
    problem = problem.anneal_decay(a.get_f64("anneal-decay"));
    if let Some(on) = parse_auto_on_off("anneal", a.get_str("anneal")) {
        problem = problem.anneal(on);
    }
    if let Some(on) = parse_auto_on_off("symmetric", a.get_str("symmetric")) {
        problem = problem.symmetric_self_solves(on);
    }
    let plan = match problem.plan() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("planning error: {e}");
            return 1;
        }
    };
    if a.get_flag("explain") {
        // The narrated decision record: the flops numbers behind the
        // backend choice, the underflow heuristic, and any demotions.
        match problem.explain() {
            Ok(text) => println!("{text}"),
            Err(e) => {
                eprintln!("planning error: {e}");
                return 1;
            }
        }
        println!("{}", plan.to_json());
    }
    let sw = Stopwatch::start();
    match problem.divergence_planned(&plan) {
        Ok(report) => {
            println!(
                "sinkhorn divergence (n={n}, eps={eps}, r={r}, threads={threads}): {:.6}  \
                 [{:.1} ms, {} iters over {} rung(s), {} escalations, arm {}]",
                report.divergence,
                sw.elapsed_secs() * 1e3,
                report.total_iterations(),
                report.xy.rung_iterations.len().max(1),
                report.escalations(),
                report.simd_arm
            );
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_tradeoff(argv: Vec<String>) -> i32 {
    let a = parse(
        ArgSpec::new("tradeoff", "time–accuracy tradeoff (Fig. 1 workload, one cell)")
            .opt("n", "2000", "samples per cloud")
            .opt("eps", "0.5", "regularisation")
            .opt("ranks", "100,300,600,1000", "feature counts / landmark counts to sweep")
            .opt(
                "backend",
                "factored",
                "estimator to sweep: factored (positive features, the paper's RF), \
                 nystrom, nystrom-adaptive, dense or auto; each rank in --ranks \
                 becomes that backend's rank",
            )
            .opt("seed", "0", "RNG seed"),
        argv,
    );
    let n = a.get_usize("n");
    let eps = a.get_f64("eps");
    let ranks = a.get_usize_list("ranks");
    let seed = a.get_u64("seed");
    let backend_flag = a.get_str("backend").to_string();
    if let Err(e) = BackendPref::parse_flag(&backend_flag, 1) {
        eprintln!("{e}");
        return 2;
    }
    let mut rng = Rng::seed_from(seed);
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);

    // Converged dense ground truth (the paper's tight-tolerance `Sin`,
    // via the canonical `ground_truth` profile).
    let sw = Stopwatch::start();
    let truth = match OtProblem::new(&mu, &nu).epsilon(eps).ground_truth().solve() {
        Ok(sol) => sol.objective,
        Err(e) => {
            eprintln!("ground truth failed: {e}");
            return 1;
        }
    };
    println!("Sin ground truth: {truth:.6} in {:.2}s", sw.elapsed_secs());

    println!("{:>6} {:>12} {:>12} {:>10}", "r", "estimate", "deviation", "time");
    for &r in &ranks {
        let sw = Stopwatch::start();
        // Plain domain, like the fig-bench sweep: a small-eps failure
        // (RF underflow, Nyström broken positivity) should print as
        // `failed`, not silently escalate — that contrast is what the
        // table is for.
        let pref = BackendPref::parse_flag(&backend_flag, r).expect("validated above");
        let res = match pref {
            BackendPref::Factored { rank } => {
                let map = GaussianFeatureMap::fit(&mu, &nu, eps, rank, &mut rng);
                OtProblem::new(&mu, &nu)
                    .epsilon(eps)
                    .rank(rank)
                    .with_feature_map(&map)
                    .stabilized_factors(false)
                    .domain(DomainChoice::Plain)
                    .solve()
            }
            pref => OtProblem::new(&mu, &nu)
                .epsilon(eps)
                .backend(pref)
                .seed(seed)
                .domain(DomainChoice::Plain)
                .solve(),
        };
        match res {
            Ok(sol) => {
                let dev = linear_sinkhorn::sinkhorn::deviation_score(truth, sol.objective);
                println!(
                    "{r:>6} {:>12.6} {:>12.2} {:>9.2}s",
                    sol.objective,
                    dev,
                    sw.elapsed_secs()
                );
            }
            Err(e) => println!("{r:>6} failed: {e}"),
        }
    }
    0
}

fn cmd_barycenter(argv: Vec<String>) -> i32 {
    let a = parse(
        ArgSpec::new("barycenter", "Fig-6 barycenter on the positive sphere")
            .opt("side", "50", "grid side (support = side^2 points)")
            .opt("blur", "0.2", "corner histogram blur")
            .opt("temp", "1000", "softmax sharpening temperature"),
        argv,
    );
    let side = a.get_usize("side");
    let grid = data::positive_sphere_grid(side);
    let hists = data::corner_histograms(&grid, a.get_f64("blur"));
    let fm = SphereLinearMap::new(3);
    let phi = fm.feature_matrix(&grid);
    let kernel = FactoredKernel::from_factors(phi.clone(), phi);
    let sw = Stopwatch::start();
    match barycenter(&kernel, &hists.to_vec(), &[], &BarycenterConfig::default()) {
        Ok(bc) => {
            let mut sharp = bc.p.clone();
            softmax_inplace(&mut sharp, a.get_f64("temp") as f32);
            // Report the mean direction and the sharpened peak.
            let mut mean = [0.0f64; 3];
            for i in 0..grid.rows() {
                for c in 0..3 {
                    mean[c] += bc.p[i] as f64 * grid[(i, c)] as f64;
                }
            }
            let (peak, _) = sharp
                .iter()
                .enumerate()
                .fold((0, f32::NEG_INFINITY), |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc });
            println!(
                "barycenter over {}x{} grid: {} iters ({}), mean direction ({:.3},{:.3},{:.3}), \
                 sharpened peak at ({:.3},{:.3},{:.3})  [{:.2}s]",
                side,
                side,
                bc.iterations,
                if bc.converged { "converged" } else { "max-iters" },
                mean[0],
                mean[1],
                mean[2],
                grid[(peak, 0)],
                grid[(peak, 1)],
                grid[(peak, 2)],
                sw.elapsed_secs()
            );
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_gan(argv: Vec<String>) -> i32 {
    let a = parse(
        ArgSpec::new("gan-train", "adversarial-kernel OT-GAN on the synthetic image corpus")
            .opt("steps", "200", "generator steps")
            .opt("batch", "256", "minibatch size s")
            .opt("features", "64", "learned positive features r")
            .opt("side", "8", "image side (side^2 pixels)")
            .opt("seed", "0", "RNG seed"),
        argv,
    );
    let side = a.get_usize("side");
    let cfg = GanConfig {
        steps: a.get_usize("steps"),
        batch_size: a.get_usize("batch"),
        num_features: a.get_usize("features"),
        seed: a.get_u64("seed"),
        ..Default::default()
    };
    let mut rng = Rng::seed_from(cfg.seed);
    let corpus = data::image_corpus(cfg.batch_size * 4, side, &mut rng);
    let mut trainer = GanTrainer::new(side * side, cfg.clone(), &mut rng);
    let mut batch_rng = Rng::seed_from(cfg.seed ^ 0xBEEF);
    for step in 0..cfg.steps {
        let idx = batch_rng.sample_indices(corpus.rows(), cfg.batch_size);
        let real = linear_sinkhorn::linalg::Mat::from_fn(cfg.batch_size, side * side, |i, j| {
            corpus[(idx[i], j)]
        });
        match trainer.train_step(step, &real) {
            Ok(rep) => {
                if step % 10 == 0 || step + 1 == cfg.steps {
                    println!(
                        "step {:>4}  divergence {:>10.6}  (w_xy {:.4}, sinkhorn iters {})",
                        rep.step, rep.divergence, rep.w_xy, rep.sinkhorn_iters
                    );
                }
            }
            Err(e) => {
                eprintln!("training failed at step {step}: {e}");
                return 1;
            }
        }
    }
    0
}

fn cmd_serve(argv: Vec<String>) -> i32 {
    let a = parse(
        ArgSpec::new("serve", "start the divergence service and drive a workload through it")
            .opt("workers", "4", "worker threads")
            .opt("solver-threads", "1", "intra-solve threads per worker (0 = auto)")
            .opt("cache", "8", "feature-map cache capacity (0 = disabled)")
            .opt("stabilize", "on", "log-domain escalation for small-eps requests (on/off)")
            .opt(
                "anneal",
                "auto",
                "eps-annealing for served solves (auto/on/off; auto = planner decides \
                 per request)",
            )
            .opt("anneal-decay", "0.5", "geometric decay per annealing rung, in (0,1)")
            .opt(
                "symmetric",
                "auto",
                "one-dual symmetric self solves (auto/on/off; auto follows annealing)",
            )
            .opt(
                "max-batch",
                "8",
                "fused multi-pair solve width cap (1 = solve every request alone)",
            )
            .opt(
                "shard-workers",
                "0",
                "delegate fuse groups to this many in-process shard workers over the \
                 wire-format scatter/gather path (0 = solve in-process); results are \
                 bitwise identical either way",
            )
            .opt(
                "shard-addrs",
                "",
                "comma-separated host:port roster of already-listening shard workers \
                 (see `linear-sinkhorn shard-worker`); non-empty takes precedence \
                 over --shard-workers, and dead entries are re-dialled (rejoin)",
            )
            .opt(
                "shard-worker-file",
                "",
                "file with one shard worker host:port per line (blank lines and # \
                 comments skipped), appended to --shard-addrs",
            )
            .opt("shard-heartbeat-ms", "50", "shard heartbeat ping cadence")
            .opt("shard-timeout-ms", "1000", "silence before a shard worker is declared dead")
            .opt("shard-deadline-ms", "30000", "per-task deadline before re-scatter")
            .opt("shard-retries", "2", "re-scatter attempts before a shard task fails typed")
            .opt("shard-backoff-ms", "20", "base linear backoff between re-scatters")
            .opt(
                "shard-hedge",
                "0.5",
                "straggler-hedging threshold as a fraction of the task deadline \
                 (0 = no hedging)",
            )
            .opt(
                "shard-max-inflight",
                "16",
                "in-flight group budget; beyond it groups shed typed (overloaded)",
            )
            .opt("shard-rejoin-ms", "250", "backoff between rejoin attempts for dead workers")
            .opt("shard-drain-ms", "5000", "graceful shard drain budget at shutdown")
            .opt(
                "backend",
                "factored",
                "planner backend for served solves: auto|dense|factored|nystrom|\
                 nystrom-adaptive, optionally with a :rank suffix (default rank = \
                 the service's num_features); factored is the pre-PR-8 behaviour \
                 with the shared feature-map cache",
            )
            .opt("requests", "32", "number of requests to send")
            .opt("n", "500", "samples per cloud per request")
            .opt(
                "sessions",
                "0",
                "also drive this many streaming sessions through the handle's \
                 session API (create / update / warm query / close)",
            )
            .opt(
                "session-updates",
                "4",
                "single-point swap ops applied between session queries",
            )
            .opt("session-queries", "4", "queries per streaming session (first is cold)")
            .opt(
                "session-capacity",
                "64",
                "live-session table bound; creates beyond it shed typed",
            )
            .opt("config", "", "optional TOML config file (replaces ALL service flags)"),
        argv,
    );
    let mut cfg = ServiceConfig {
        workers: a.get_usize("workers"),
        solver_threads: a.get_usize("solver-threads"),
        cache_capacity: a.get_usize("cache"),
        shard_workers: a.get_usize("shard-workers"),
        session_capacity: a.get_usize("session-capacity"),
        ..Default::default()
    };
    cfg.sinkhorn.stabilize = parse_on_off("stabilize", a.get_str("stabilize"));
    cfg.sinkhorn.max_batch = a.get_usize("max-batch");
    cfg.sinkhorn.anneal = parse_auto_on_off("anneal", a.get_str("anneal"));
    cfg.sinkhorn.anneal_decay = a.get_f64("anneal-decay");
    cfg.sinkhorn.symmetric = parse_auto_on_off("symmetric", a.get_str("symmetric"));
    cfg.backend = a.get_str("backend").to_string();
    // Fail malformed backend values at startup, not per request.
    if let Err(e) = BackendPref::parse_flag(&cfg.backend, cfg.num_features) {
        eprintln!("{e}");
        return 2;
    }
    cfg.shard_addrs = a
        .get_str("shard-addrs")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    let roster_path = a.get_str("shard-worker-file");
    if !roster_path.is_empty() {
        match std::fs::read_to_string(roster_path) {
            Ok(text) => {
                for line in text.lines() {
                    let line = line.trim();
                    if line.is_empty() || line.starts_with('#') {
                        continue;
                    }
                    cfg.shard_addrs.push(line.to_string());
                }
            }
            Err(e) => {
                eprintln!("--shard-worker-file {roster_path}: {e}");
                return 2;
            }
        }
    }
    cfg.shard.heartbeat_interval_ms = a.get_usize("shard-heartbeat-ms") as u64;
    cfg.shard.heartbeat_timeout_ms = a.get_usize("shard-timeout-ms") as u64;
    cfg.shard.task_deadline_ms = a.get_usize("shard-deadline-ms") as u64;
    cfg.shard.max_retries = a.get_usize("shard-retries");
    cfg.shard.retry_backoff_ms = a.get_usize("shard-backoff-ms") as u64;
    cfg.shard.hedge_fraction = a.get_f64("shard-hedge");
    cfg.shard.max_inflight_groups = a.get_usize("shard-max-inflight");
    cfg.shard.rejoin_backoff_ms = a.get_usize("shard-rejoin-ms") as u64;
    cfg.shard.drain_deadline_ms = a.get_usize("shard-drain-ms") as u64;
    let cfg_path = a.get_str("config");
    if !cfg_path.is_empty() {
        match linear_sinkhorn::config::ConfigDoc::parse_file(cfg_path) {
            Ok(doc) => {
                cfg = ServiceConfig::from_doc(&doc);
                eprintln!(
                    "note: --config replaces all service flags (--workers/--solver-threads/\
                     --cache/--stabilize/--anneal/--anneal-decay/--symmetric/--max-batch/\
                     --shard-workers/--shard-addrs/--shard-worker-file/--shard-*-ms/\
                     --shard-retries/--shard-hedge/--shard-max-inflight/--backend ignored)"
                );
            }
            Err(e) => {
                eprintln!("config error: {e}");
                return 2;
            }
        }
    }
    let svc = match coordinator::Service::start(cfg) {
        Ok(svc) => svc,
        Err(e) => {
            eprintln!("service start failed: {e}");
            return 1;
        }
    };
    let h = svc.handle();
    let n_req = a.get_usize("requests");
    let n = a.get_usize("n");
    let sw = Stopwatch::start();
    let mut pendings = Vec::new();
    let mut rng = Rng::seed_from(42);
    for _ in 0..n_req {
        let (mu, nu) = data::gaussian_blobs(n, &mut rng);
        match h.submit(mu, nu) {
            Ok(p) => pendings.push(p),
            Err(e) => eprintln!("shed: {e}"),
        }
    }
    let mut ok = 0;
    for p in pendings {
        if let Ok(resp) = p.wait() {
            ok += 1;
            if ok <= 3 {
                println!(
                    "response id={} divergence={:.6} latency={}us batch={}",
                    resp.id, resp.divergence, resp.latency_us, resp.batch_size
                );
            }
        }
    }
    println!(
        "{ok}/{n_req} requests served in {:.2}s ({:.1} req/s)",
        sw.elapsed_secs(),
        ok as f64 / sw.elapsed_secs(),
    );
    // Optional streaming-session workload: long-lived mutating problems
    // with dual warm-starts, alongside the one-shot request traffic.
    let n_sessions = a.get_usize("sessions");
    if n_sessions > 0 {
        let n_updates = a.get_usize("session-updates");
        let n_queries = a.get_usize("session-queries");
        let sw = Stopwatch::start();
        for s in 0..n_sessions {
            let (mu, nu) = data::gaussian_blobs(n, &mut rng);
            let dim = mu.dim();
            let id = match h.session_create(mu, nu, None) {
                Ok(id) => id,
                Err(e) => {
                    eprintln!("session create shed: {e}");
                    continue;
                }
            };
            let mut cold_iters = 0;
            let mut last = None;
            for q in 0..n_queries.max(1) {
                if q > 0 && n_updates > 0 {
                    let ops: Vec<SessionOp> = (0..n_updates)
                        .map(|_| SessionOp::SwapX {
                            index: rng.uniform_usize(n),
                            point: (0..dim).map(|_| rng.normal_f32()).collect(),
                            weight: 1.0 / n as f32,
                        })
                        .collect();
                    if let Err(e) = h.session_update(id, &ops) {
                        eprintln!("session {id} update: {e}");
                    }
                }
                match h.session_query(id) {
                    Ok(rep) => {
                        if q == 0 {
                            cold_iters = rep.iterations;
                        }
                        last = Some(rep);
                    }
                    Err(e) => eprintln!("session {id} query: {e}"),
                }
            }
            if let Some(rep) = last {
                if s < 3 {
                    println!(
                        "session id={id} objective={:.6} iters={} (cold {cold_iters}) \
                         warm={} version={}",
                        rep.objective, rep.iterations, rep.warm_started, rep.version
                    );
                }
            }
            if let Err(e) = h.session_close(id) {
                eprintln!("session {id} close: {e}");
            }
        }
        println!(
            "{n_sessions} sessions x {n_queries} queries in {:.2}s",
            sw.elapsed_secs()
        );
    }
    println!("{}", h.metrics_text());
    drop(h);
    svc.shutdown();
    0
}

fn cmd_shard_worker(argv: Vec<String>) -> i32 {
    let a = parse(
        ArgSpec::new(
            "shard-worker",
            "run a standalone shard worker; point `serve --shard-addrs` (or a \
             --shard-worker-file roster) at its listen address",
        )
        .opt("listen", "127.0.0.1:0", "host:port to listen on (port 0 = ephemeral, printed)")
        .opt("id", "0", "worker id reported in wire frames"),
        argv,
    );
    let listener = match std::net::TcpListener::bind(a.get_str("listen")) {
        Ok(l) => l,
        Err(e) => {
            eprintln!("bind {}: {e}", a.get_str("listen"));
            return 1;
        }
    };
    match listener.local_addr() {
        Ok(addr) => println!("shard worker listening on {addr}"),
        Err(e) => eprintln!("local_addr: {e}"),
    }
    let id = a.get_usize("id") as u64;
    // Serve coordinator connections until killed. One run_worker life per
    // connection: the life ends at shutdown, drain, or link loss, and the
    // next accept is what makes this worker *rejoinable* — a coordinator
    // that declared us dead re-dials the same roster address.
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                eprintln!("coordinator connected from {peer}");
                match linear_sinkhorn::shard::TcpTransport::from_stream(stream) {
                    Ok(t) => linear_sinkhorn::shard::run_worker(
                        id,
                        std::sync::Arc::new(t),
                        linear_sinkhorn::shard::WorkerOptions::default(),
                    ),
                    Err(e) => eprintln!("transport setup failed: {e}"),
                }
                eprintln!("coordinator connection closed; awaiting reconnect");
            }
            Err(e) => {
                eprintln!("accept: {e}");
                return 1;
            }
        }
    }
}

fn cmd_runtime(argv: Vec<String>) -> i32 {
    let a = parse(
        ArgSpec::new("runtime", "smoke-check the PJRT runtime against AOT artifacts")
            .opt("artifacts", "artifacts", "artifact directory"),
        argv,
    );
    let reg = match Registry::load(a.get_str("artifacts")) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    println!("platform: {}", engine.platform());
    for (name, meta) in &reg.entries {
        let sw = Stopwatch::start();
        match engine.load(meta) {
            Ok(exe) => {
                // Drive with constant fill of the right shapes.
                let args: Vec<xla::Literal> = meta
                    .params
                    .iter()
                    .map(|(_, shape)| {
                        let total: usize = shape.iter().product::<usize>().max(1);
                        let fill = vec![0.5f32; total];
                        if shape.len() == 2 {
                            mat_to_literal(&linear_sinkhorn::linalg::Mat::from_vec(
                                shape[0], shape[1], fill,
                            ))
                            .unwrap()
                        } else {
                            vec_to_literal(&fill)
                        }
                    })
                    .collect();
                match exe.run(&args) {
                    Ok(outs) => println!(
                        "  {name}: OK, {} outputs, compile+run {:.2}s",
                        outs.len(),
                        sw.elapsed_secs()
                    ),
                    Err(e) => println!("  {name}: EXEC FAILED: {e}"),
                }
            }
            Err(e) => println!("  {name}: COMPILE FAILED: {e}"),
        }
    }
    0
}
