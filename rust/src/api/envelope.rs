//! Shard envelopes: the [`crate::runtime::wire`] documents that carry a
//! fuse group to a remote worker and its solutions back.
//!
//! * [`TaskEnvelope`] — one scatter unit: the serialised [`Plan`], the
//!   group's shared support measures, the per-request weight pairs, and
//!   (optionally) the exact feature map the coordinator resolved from its
//!   cache. Shipping the map matters for the bitwise contract: a service
//!   cache map is drawn from a *worker's* RNG stream, not from
//!   `plan.seed`, so a remote executor could not refit it — instead the
//!   anchors travel as an f32 column and the worker rebuilds the map with
//!   [`GaussianFeatureMap::with_anchors`], which recomputes the derived
//!   per-anchor constants deterministically from the same bits. Without a
//!   map the worker falls back to the executor's seeded refit
//!   (`Rng::seed_from(plan.seed)`), which is equally deterministic.
//!   Nyström plans need no extra columns at all: the landmark draw
//!   (uniform or farthest-point) is a pure function of `plan.seed`, so
//!   the seed riding the serialised plan *is* the landmark set and the
//!   worker rebuilds the bit-identical kernel. The plan's `v` field is
//!   checked in [`Plan::from_json`] during decode, so a worker handed a
//!   newer-major plan fails with a typed wire error instead of
//!   misinterpreting fields (mixed-version fleets fail loudly).
//! * [`ResultEnvelope`] — the gather unit: per-pair scalar diagnostics as
//!   f64 columns and the three solves' dual scalings as f32 columns, so
//!   the reassembled [`DivergenceReport`]s are bit-for-bit the ones the
//!   worker computed (NaN marginal errors included — scalars travel as
//!   bit patterns, not text). Failed pairs travel as a tagged status
//!   string `error[{tag}]: {message}` and decode back to the matching
//!   [`Error`] variant (`service`/`wire`/`overloaded`/`config`; every
//!   other variant normalises to `config` carrying its Display text).
//!   Untagged `error: {message}` statuses from pre-tag frames still
//!   decode — to [`Error::Config`], the old convention.
//!
//! Envelope identity: results are matched to tasks by `task_id` alone, so
//! a duplicated or re-scattered task yields interchangeable result frames
//! — dedup at the gather site is safe by construction.
//!
//! Streaming sessions ride the same frames as a backward-compatible
//! extension: a task may carry an optional [`SessionDelta`] (session
//! identity, an op log or full-snapshot marker, and the coordinator's
//! remapped warm dual as an f64 column), and the worker answers with a
//! [`SessionResultEnvelope`] (`kind = "session_result"`) carrying the
//! solved x-side dual back so the coordinator — the owner of all dual
//! state — can warm-start the next query. Frames without a session
//! extension are byte-identical to pre-session frames: the extra meta
//! key and columns are only emitted when present.

use crate::data::Measure;
use crate::error::{Error, Result};
use crate::features::GaussianFeatureMap;
use crate::linalg::simd::SimdLevel;
use crate::linalg::Mat;
use crate::runtime::wire::kinds;
use crate::runtime::{Json, WireDoc};
use crate::session::SessionOp;

use super::plan::Plan;
use super::solution::{DivergenceReport, Solution};

/// Scalar diagnostics per pair, packed into one f64 column (see
/// [`ResultEnvelope`]): 3 objectives, 3 marginal errors, 3 iteration
/// counts, 3 converged flags, 3 escalated flags, 3 solve wall clocks,
/// 1 report wall clock.
const SCALARS_PER_PAIR: usize = 19;

/// One scatter unit: a fuse group (or a pair-chunk of one) bound for a
/// shard worker.
#[derive(Clone, Debug)]
pub struct TaskEnvelope {
    /// Gather key — the coordinator dedups result frames on this.
    pub task_id: u64,
    /// The fuse group this chunk came from (observability only).
    pub group_id: u64,
    /// Originating request ids, index-aligned with `pairs` (observability
    /// and re-scatter bookkeeping; empty when callers have no ids).
    pub request_ids: Vec<u64>,
    pub plan: Plan,
    pub mu: Measure,
    pub nu: Measure,
    /// Per-request weight pairs `(a, b)` with `|a| = n`, `|b| = m`.
    pub pairs: Vec<(Vec<f32>, Vec<f32>)>,
    /// The exact feature map to solve with (see the module docs); `None`
    /// lets the worker refit from `plan.seed`.
    pub map: Option<GaussianFeatureMap>,
    /// Streaming-session extension (see the module docs); `None` for
    /// ordinary fuse-group tasks, whose frames stay byte-identical to
    /// pre-session builds.
    pub session: Option<SessionDelta>,
}

/// The session extension of a [`TaskEnvelope`]: everything a worker
/// needs to bring its resident copy of session `session_id` from
/// `base_version` to `version` and solve it.
///
/// Two shapes travel:
///
/// * **snapshot** (`snapshot = true`, `ops` empty): the envelope's
///   `mu`/`nu` are the full support in the session's deterministic
///   column layout and `map` is the session's exact feature map. Sent
///   on first contact with a worker and whenever residency was lost —
///   the unconditional fallback.
/// * **delta** (`snapshot = false`): `ops` replay on the worker's
///   resident state at `base_version`. The envelope's `mu`/`nu` are
///   then empty placeholders — the resident support plus the op log
///   fully determine the post-update state — keeping the frame O(ops),
///   not O(n). The op points' dimension travels in the session meta
///   (`dim`) so decode never leans on the placeholder measures.
///
/// `warm_alpha` is the coordinator's remapped previous dual and always
/// ships when available: the worker never owns dual state, so local and
/// sharded queries warm-start from the same bits by construction.
#[derive(Clone, Debug)]
pub struct SessionDelta {
    pub session_id: u64,
    /// Version the ops apply on top of (ignored for snapshots).
    pub base_version: u64,
    /// Version after applying `ops` — what the worker's residency table
    /// records for the next delta.
    pub version: u64,
    pub snapshot: bool,
    pub ops: Vec<SessionOp>,
    pub warm_alpha: Option<Vec<f64>>,
}

impl TaskEnvelope {
    pub fn encode(&self) -> Vec<u8> {
        let mut doc = WireDoc::with_kind("task");
        doc.set_u64("task_id", self.task_id);
        doc.set_u64("group_id", self.group_id);
        doc.set_json(
            "request_ids",
            Json::Arr(self.request_ids.iter().map(|id| Json::Str(id.to_string())).collect()),
        );
        doc.set_json(
            "plan",
            Json::parse(&self.plan.to_json()).expect("Plan::to_json emits valid json"),
        );
        let (n, dim) = (self.mu.len(), self.mu.dim());
        let m = self.nu.len();
        doc.set_num("n", n as f64);
        doc.set_num("m", m as f64);
        doc.set_num("dim", dim as f64);
        doc.set_num("pairs", self.pairs.len() as f64);
        doc.push_f32("mu.points", self.mu.points.data()).expect("fresh doc");
        doc.push_f32("mu.weights", &self.mu.weights).expect("fresh doc");
        doc.push_f32("nu.points", self.nu.points.data()).expect("fresh doc");
        doc.push_f32("nu.weights", &self.nu.weights).expect("fresh doc");
        for (i, (a, b)) in self.pairs.iter().enumerate() {
            doc.push_f32(&format!("pair{i}.a"), a).expect("unique pair column");
            doc.push_f32(&format!("pair{i}.b"), b).expect("unique pair column");
        }
        if let Some(map) = &self.map {
            let mut obj = std::collections::BTreeMap::new();
            obj.insert("eps".to_string(), Json::Num(map.eps));
            obj.insert("q".to_string(), Json::Num(map.q));
            obj.insert("radius".to_string(), Json::Num(map.radius));
            obj.insert("r".to_string(), Json::Num(map.anchors.rows() as f64));
            doc.set_json("map", Json::Obj(obj));
            doc.push_f32("map.anchors", map.anchors.data()).expect("fresh doc");
        }
        if let Some(session) = &self.session {
            encode_session(&mut doc, session);
        }
        doc.encode()
    }

    pub fn decode(bytes: &[u8]) -> Result<TaskEnvelope> {
        let doc = WireDoc::decode(bytes)?;
        if doc.kind() != "task" {
            return Err(Error::Wire(format!("expected task envelope, got `{}`", doc.kind())));
        }
        let plan_json = doc
            .meta
            .get("plan")
            .ok_or_else(|| Error::Wire("task envelope missing `plan`".into()))?
            .encode();
        let plan =
            Plan::from_json(&plan_json).map_err(|e| Error::Wire(format!("task plan: {e}")))?;
        let n = doc.get_usize("n")?;
        let m = doc.get_usize("m")?;
        let dim = doc.get_usize("dim")?;
        let n_pairs = doc.get_usize("pairs")?;
        let request_ids = match doc.meta.get("request_ids") {
            Some(Json::Arr(items)) => items
                .iter()
                .map(|v| {
                    v.as_str()
                        .and_then(|s| s.parse::<u64>().ok())
                        .ok_or_else(|| Error::Wire("bad request id".into()))
                })
                .collect::<Result<Vec<u64>>>()?,
            _ => return Err(Error::Wire("task envelope missing `request_ids`".into())),
        };
        let mu = decode_measure(&doc, "mu", n, dim)?;
        let nu = decode_measure(&doc, "nu", m, dim)?;
        let mut pairs = Vec::with_capacity(n_pairs);
        for i in 0..n_pairs {
            let a = doc.f32s(&format!("pair{i}.a"))?;
            let b = doc.f32s(&format!("pair{i}.b"))?;
            if a.len() != n || b.len() != m {
                return Err(Error::Wire(format!(
                    "pair {i} weights have lengths ({}, {}), expected ({n}, {m})",
                    a.len(),
                    b.len()
                )));
            }
            pairs.push((a.to_vec(), b.to_vec()));
        }
        let map = match doc.meta.get("map") {
            Some(meta) => {
                let num = |k: &str| -> Result<f64> {
                    meta.get(k)
                        .and_then(Json::as_f64)
                        .ok_or_else(|| Error::Wire(format!("map meta missing `{k}`")))
                };
                let r = meta
                    .get("r")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| Error::Wire("map meta missing `r`".into()))?;
                let data = doc.f32s("map.anchors")?;
                if data.len() != r * dim {
                    return Err(Error::Wire(format!(
                        "map.anchors has {} entries, expected {r}x{dim}",
                        data.len()
                    )));
                }
                let anchors = Mat::from_vec(r, dim, data.to_vec());
                Some(GaussianFeatureMap::with_anchors(
                    anchors,
                    num("eps")?,
                    num("q")?,
                    num("radius")?,
                ))
            }
            None => None,
        };
        let session = match doc.meta.get("session") {
            Some(meta) => Some(decode_session(meta, &doc)?),
            None => None,
        };
        Ok(TaskEnvelope {
            task_id: doc.get_u64("task_id")?,
            group_id: doc.get_u64("group_id")?,
            request_ids,
            plan,
            mu,
            nu,
            pairs,
            map,
            session,
        })
    }
}

/// Serialise a [`SessionDelta`] into the task doc: one `session` meta
/// object (identity, versions, snapshot flag, op-dim, and the op log as
/// compact `tag[:index]` strings) plus up to three optional columns —
/// `session.ops.points` / `session.ops.weights` (the payloads of
/// point-carrying ops, in op order) and `session.warm` (the remapped
/// warm dual, f64 so the warm-start bits survive the hop).
fn encode_session(doc: &mut WireDoc, session: &SessionDelta) {
    let op_dim = session
        .ops
        .iter()
        .find_map(|op| match op {
            SessionOp::InsertX { point, .. }
            | SessionOp::SwapX { point, .. }
            | SessionOp::InsertY { point, .. }
            | SessionOp::SwapY { point, .. } => Some(point.len()),
            _ => None,
        })
        .unwrap_or(0);
    let ops: Vec<Json> = session
        .ops
        .iter()
        .map(|op| {
            Json::Str(match op {
                SessionOp::InsertX { .. } | SessionOp::InsertY { .. } => op.tag().to_string(),
                SessionOp::EvictX { index }
                | SessionOp::SwapX { index, .. }
                | SessionOp::EvictY { index }
                | SessionOp::SwapY { index, .. } => format!("{}:{index}", op.tag()),
            })
        })
        .collect();
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("id".to_string(), Json::Str(session.session_id.to_string()));
    obj.insert("base".to_string(), Json::Str(session.base_version.to_string()));
    obj.insert("version".to_string(), Json::Str(session.version.to_string()));
    obj.insert("snapshot".to_string(), Json::Bool(session.snapshot));
    obj.insert("dim".to_string(), Json::Num(op_dim as f64));
    obj.insert("ops".to_string(), Json::Arr(ops));
    doc.set_json("session", Json::Obj(obj));
    let mut points = Vec::new();
    let mut weights = Vec::new();
    for op in &session.ops {
        match op {
            SessionOp::InsertX { point, weight }
            | SessionOp::SwapX { point, weight, .. }
            | SessionOp::InsertY { point, weight }
            | SessionOp::SwapY { point, weight, .. } => {
                points.extend_from_slice(point);
                weights.push(*weight);
            }
            SessionOp::EvictX { .. } | SessionOp::EvictY { .. } => {}
        }
    }
    if !weights.is_empty() {
        doc.push_f32("session.ops.points", &points).expect("fresh doc");
        doc.push_f32("session.ops.weights", &weights).expect("fresh doc");
    }
    if let Some(alpha) = &session.warm_alpha {
        doc.push_f64("session.warm", alpha).expect("fresh doc");
    }
}

/// Inverse of [`encode_session`]. Strict about payload accounting: the
/// op strings must consume `session.ops.points` / `.weights` exactly, so
/// a truncated or padded frame fails typed instead of replaying a
/// mis-sliced op log into a resident session.
fn decode_session(meta: &Json, doc: &WireDoc) -> Result<SessionDelta> {
    let get_u64 = |k: &str| -> Result<u64> {
        meta.get(k)
            .and_then(Json::as_str)
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| Error::Wire(format!("session meta missing u64 `{k}`")))
    };
    let snapshot = match meta.get("snapshot") {
        Some(Json::Bool(b)) => *b,
        _ => return Err(Error::Wire("session meta missing `snapshot`".into())),
    };
    let dim = meta
        .get("dim")
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Wire("session meta missing `dim`".into()))?;
    let tags = match meta.get("ops") {
        Some(Json::Arr(items)) => items,
        _ => return Err(Error::Wire("session meta missing `ops`".into())),
    };
    let (points, weights) = if doc.has_col("session.ops.weights") {
        (doc.f32s("session.ops.points")?, doc.f32s("session.ops.weights")?)
    } else {
        (&[][..], &[][..])
    };
    let mut at = 0usize;
    let mut take = |what: &str| -> Result<(Vec<f32>, f32)> {
        if (at + 1) * dim > points.len() || at + 1 > weights.len() {
            return Err(Error::Wire(format!("session op `{what}` payload truncated")));
        }
        let point = points[at * dim..(at + 1) * dim].to_vec();
        let weight = weights[at];
        at += 1;
        Ok((point, weight))
    };
    let mut ops = Vec::with_capacity(tags.len());
    for tag in tags {
        let tag =
            tag.as_str().ok_or_else(|| Error::Wire("session op tag must be a string".into()))?;
        let (kind, index) = match tag.split_once(':') {
            Some((kind, idx)) => (
                kind,
                Some(idx.parse::<usize>().map_err(|_| {
                    Error::Wire(format!("session op `{tag}` has a bad index"))
                })?),
            ),
            None => (tag, None),
        };
        let need_index = || index.ok_or_else(|| Error::Wire(format!("op `{kind}` needs an index")));
        ops.push(match kind {
            "ix" => {
                let (point, weight) = take(kind)?;
                SessionOp::InsertX { point, weight }
            }
            "iy" => {
                let (point, weight) = take(kind)?;
                SessionOp::InsertY { point, weight }
            }
            "sx" => {
                let index = need_index()?;
                let (point, weight) = take(kind)?;
                SessionOp::SwapX { index, point, weight }
            }
            "sy" => {
                let index = need_index()?;
                let (point, weight) = take(kind)?;
                SessionOp::SwapY { index, point, weight }
            }
            "ex" => SessionOp::EvictX { index: need_index()? },
            "ey" => SessionOp::EvictY { index: need_index()? },
            other => return Err(Error::Wire(format!("unknown session op `{other}`"))),
        });
    }
    if at != weights.len() || at * dim != points.len() {
        return Err(Error::Wire(format!(
            "session op payload mismatch: {at} ops consumed, {} weights / {} coords shipped",
            weights.len(),
            points.len()
        )));
    }
    let warm_alpha =
        if doc.has_col("session.warm") { Some(doc.f64s("session.warm")?.to_vec()) } else { None };
    Ok(SessionDelta {
        session_id: get_u64("id")?,
        base_version: get_u64("base")?,
        version: get_u64("version")?,
        snapshot,
        ops,
        warm_alpha,
    })
}

/// Status-string form of a per-pair failure: `error[{tag}]: {message}`.
/// The tag picks the [`Error`] variant back at the gather site, so typed
/// failures (a worker shedding under [`Error::Overloaded`], a wire-level
/// refusal) survive the hop instead of flattening to `Config`.
fn encode_status_error(e: &Error) -> String {
    let (tag, msg) = match e {
        Error::Service(s) => ("service", s.clone()),
        Error::Wire(s) => ("wire", s.clone()),
        Error::Overloaded(s) => ("overloaded", s.clone()),
        Error::Config(s) => ("config", s.clone()),
        other => ("config", other.to_string()),
    };
    format!("error[{tag}]: {msg}")
}

/// Inverse of [`encode_status_error`]; untagged `error: …` statuses from
/// pre-tag frames fall back to [`Error::Config`] (the old convention).
fn decode_status_error(status: &str) -> Error {
    if let Some(rest) = status.strip_prefix("error[") {
        if let Some((tag, msg)) = rest.split_once("]: ") {
            let msg = msg.to_string();
            return match tag {
                "service" => Error::Service(msg),
                "wire" => Error::Wire(msg),
                "overloaded" => Error::Overloaded(msg),
                _ => Error::Config(msg),
            };
        }
    }
    Error::Config(status.strip_prefix("error: ").unwrap_or(status).to_string())
}

fn decode_measure(doc: &WireDoc, prefix: &str, rows: usize, dim: usize) -> Result<Measure> {
    let points = doc.f32s(&format!("{prefix}.points"))?;
    let weights = doc.f32s(&format!("{prefix}.weights"))?;
    if points.len() != rows * dim {
        return Err(Error::Wire(format!(
            "{prefix}.points has {} entries, expected {rows}x{dim}",
            points.len()
        )));
    }
    if weights.len() != rows {
        return Err(Error::Wire(format!(
            "{prefix}.weights has {} entries, expected {rows}",
            weights.len()
        )));
    }
    Ok(Measure { points: Mat::from_vec(rows, dim, points.to_vec()), weights: weights.to_vec() })
}

/// The gather unit: one task's per-pair divergence results.
#[derive(Debug)]
pub struct ResultEnvelope {
    pub task_id: u64,
    pub worker_id: u64,
    pub results: Vec<Result<DivergenceReport>>,
}

impl ResultEnvelope {
    pub fn new(task_id: u64, worker_id: u64, results: Vec<Result<DivergenceReport>>) -> Self {
        ResultEnvelope { task_id, worker_id, results }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut doc = WireDoc::with_kind("result");
        doc.set_u64("task_id", self.task_id);
        doc.set_u64("worker_id", self.worker_id);
        doc.set_num("pairs", self.results.len() as f64);
        let arm = self
            .results
            .iter()
            .find_map(|r| r.as_ref().ok().map(|rep| rep.simd_arm))
            .unwrap_or_else(|| crate::linalg::simd::active_level().label());
        doc.set_str("simd_arm", arm);
        let statuses = self
            .results
            .iter()
            .map(|r| match r {
                Ok(_) => Json::Str("ok".to_string()),
                Err(e) => Json::Str(encode_status_error(e)),
            })
            .collect();
        doc.set_json("statuses", Json::Arr(statuses));
        for (i, result) in self.results.iter().enumerate() {
            let Ok(rep) = result else { continue };
            let s = |sol: &Solution| {
                [
                    sol.objective,
                    sol.marginal_error,
                    sol.iterations as f64,
                    sol.converged as u8 as f64,
                    sol.escalated as u8 as f64,
                    sol.wall_us as f64,
                ]
            };
            let (xy, xx, yy) = (s(&rep.xy), s(&rep.xx), s(&rep.yy));
            let mut scalars = Vec::with_capacity(SCALARS_PER_PAIR);
            for j in 0..6 {
                scalars.push(xy[j]);
                scalars.push(xx[j]);
                scalars.push(yy[j]);
            }
            scalars.push(rep.wall_us as f64);
            doc.push_f64(&format!("p{i}.scalars"), &scalars).expect("unique result column");
            doc.push_f32(&format!("p{i}.xy.u"), &rep.xy.u).expect("unique result column");
            doc.push_f32(&format!("p{i}.xy.v"), &rep.xy.v).expect("unique result column");
            doc.push_f32(&format!("p{i}.xx.u"), &rep.xx.u).expect("unique result column");
            doc.push_f32(&format!("p{i}.xx.v"), &rep.xx.v).expect("unique result column");
            doc.push_f32(&format!("p{i}.yy.u"), &rep.yy.u).expect("unique result column");
            doc.push_f32(&format!("p{i}.yy.v"), &rep.yy.v).expect("unique result column");
            // Annealed solves carry their per-rung iteration counts as an
            // optional column per solve; direct solves (empty vec) push
            // nothing, keeping pre-annealing frames byte-compatible.
            for (role, sol) in [("xy", &rep.xy), ("xx", &rep.xx), ("yy", &rep.yy)] {
                if !sol.rung_iterations.is_empty() {
                    let rungs: Vec<f64> =
                        sol.rung_iterations.iter().map(|&x| x as f64).collect();
                    doc.push_f64(&format!("p{i}.{role}.rungs"), &rungs)
                        .expect("unique result column");
                }
            }
        }
        doc.encode()
    }

    pub fn decode(bytes: &[u8]) -> Result<ResultEnvelope> {
        let doc = WireDoc::decode(bytes)?;
        if doc.kind() != "result" {
            return Err(Error::Wire(format!("expected result envelope, got `{}`", doc.kind())));
        }
        let n_pairs = doc.get_usize("pairs")?;
        // The executing arm is re-interned against this process's static
        // labels; an unknown label is a corrupt (or future) frame.
        let arm = match doc.get_str("simd_arm")? {
            s if s == SimdLevel::Scalar.label() => SimdLevel::Scalar.label(),
            s if s == SimdLevel::Avx2Fma.label() => SimdLevel::Avx2Fma.label(),
            other => return Err(Error::Wire(format!("unknown simd arm `{other}`"))),
        };
        let statuses = match doc.meta.get("statuses") {
            Some(Json::Arr(items)) if items.len() == n_pairs => items,
            _ => return Err(Error::Wire("result envelope missing per-pair statuses".into())),
        };
        let mut results = Vec::with_capacity(n_pairs);
        for (i, status) in statuses.iter().enumerate() {
            let status =
                status.as_str().ok_or_else(|| Error::Wire("status must be a string".into()))?;
            if status != "ok" {
                results.push(Err(decode_status_error(status)));
                continue;
            }
            let scalars = doc.f64s(&format!("p{i}.scalars"))?;
            if scalars.len() != SCALARS_PER_PAIR {
                return Err(Error::Wire(format!(
                    "p{i}.scalars has {} entries, expected {SCALARS_PER_PAIR}",
                    scalars.len()
                )));
            }
            let sol = |slot: usize, role: &str| -> Result<Solution> {
                // Absent rungs column = direct solve (pre-annealing frames
                // included), decoding to the same empty vec it encoded.
                let rungs_col = format!("p{i}.{role}.rungs");
                let rung_iterations = if doc.has_col(&rungs_col) {
                    doc.f64s(&rungs_col)?.iter().map(|&x| x as usize).collect()
                } else {
                    Vec::new()
                };
                Ok(Solution {
                    objective: scalars[slot],
                    u: doc.f32s(&format!("p{i}.{role}.u"))?.to_vec(),
                    v: doc.f32s(&format!("p{i}.{role}.v"))?.to_vec(),
                    iterations: scalars[6 + slot] as usize,
                    marginal_error: scalars[3 + slot],
                    converged: scalars[9 + slot] != 0.0,
                    escalated: scalars[12 + slot] != 0.0,
                    // The divergence path never runs Alg. 2, so the dual
                    // gradient norm is always absent (see `Solution`).
                    grad_norm: None,
                    wall_us: scalars[15 + slot] as u64,
                    simd_arm: arm,
                    rung_iterations,
                })
            };
            let xy = sol(0, "xy")?;
            let xx = sol(1, "xx")?;
            let yy = sol(2, "yy")?;
            // `assemble` recomputes the divergence from the shipped f64
            // objectives — the identical arithmetic the worker ran, hence
            // the identical bits.
            results.push(Ok(DivergenceReport::assemble(xy, xx, yy, scalars[18] as u64)));
        }
        Ok(ResultEnvelope {
            task_id: doc.get_u64("task_id")?,
            worker_id: doc.get_u64("worker_id")?,
            results,
        })
    }
}

/// What a worker's streaming-session solve produced: the scalar
/// diagnostics the coordinator folds into its [`crate::session::QueryReport`]
/// plus the solved x-side dual `alpha` — the warm-start currency that
/// travels back to the coordinator, the sole owner of dual state.
#[derive(Clone, Debug)]
pub struct SessionSolveOut {
    pub objective: f64,
    pub iterations: usize,
    pub marginal_error: f64,
    pub converged: bool,
    pub escalated: bool,
    pub warm_started: bool,
    pub alpha: Vec<f64>,
}

/// The gather unit for a streaming-session solve (`kind =
/// "session_result"`): one task, one solve, one dual. Failures travel
/// as the same tagged status strings as [`ResultEnvelope`] pairs, so a
/// worker that lost residency surfaces a typed error the coordinator
/// answers with a snapshot retry.
#[derive(Debug)]
pub struct SessionResultEnvelope {
    pub task_id: u64,
    pub worker_id: u64,
    pub result: Result<SessionSolveOut>,
}

/// Scalar layout of a session result's `scalars` column: objective,
/// iterations, marginal error, converged, escalated, warm-started.
const SESSION_SCALARS: usize = 6;

impl SessionResultEnvelope {
    pub fn new(task_id: u64, worker_id: u64, result: Result<SessionSolveOut>) -> Self {
        SessionResultEnvelope { task_id, worker_id, result }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut doc = WireDoc::with_kind(kinds::SESSION_RESULT);
        doc.set_u64("task_id", self.task_id);
        doc.set_u64("worker_id", self.worker_id);
        match &self.result {
            Ok(out) => {
                doc.set_str("status", "ok");
                let scalars = [
                    out.objective,
                    out.iterations as f64,
                    out.marginal_error,
                    out.converged as u8 as f64,
                    out.escalated as u8 as f64,
                    out.warm_started as u8 as f64,
                ];
                doc.push_f64("scalars", &scalars).expect("fresh doc");
                doc.push_f64("alpha", &out.alpha).expect("fresh doc");
            }
            Err(e) => doc.set_str("status", &encode_status_error(e)),
        }
        doc.encode()
    }

    pub fn decode(bytes: &[u8]) -> Result<SessionResultEnvelope> {
        let doc = WireDoc::decode(bytes)?;
        if doc.kind() != kinds::SESSION_RESULT {
            return Err(Error::Wire(format!(
                "expected session result envelope, got `{}`",
                doc.kind()
            )));
        }
        let status = doc.get_str("status")?;
        let result = if status == "ok" {
            let scalars = doc.f64s("scalars")?;
            if scalars.len() != SESSION_SCALARS {
                return Err(Error::Wire(format!(
                    "session scalars has {} entries, expected {SESSION_SCALARS}",
                    scalars.len()
                )));
            }
            Ok(SessionSolveOut {
                objective: scalars[0],
                iterations: scalars[1] as usize,
                marginal_error: scalars[2],
                converged: scalars[3] != 0.0,
                escalated: scalars[4] != 0.0,
                warm_started: scalars[5] != 0.0,
                alpha: doc.f64s("alpha")?.to_vec(),
            })
        } else {
            Err(decode_status_error(status))
        };
        Ok(SessionResultEnvelope {
            task_id: doc.get_u64("task_id")?,
            worker_id: doc.get_u64("worker_id")?,
            result,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::OtProblem;
    use crate::data;
    use crate::rng::Rng;

    fn sample_task(with_map: bool) -> TaskEnvelope {
        let mut rng = Rng::seed_from(5);
        let (mu, nu) = data::gaussian_blobs(12, &mut rng);
        let pairs: Vec<(Vec<f32>, Vec<f32>)> =
            vec![(mu.weights.clone(), nu.weights.clone()); 2];
        let problem = OtProblem::new(&mu, &nu).epsilon(0.5).rank(8).seed(7);
        let plan = problem.plan().unwrap();
        let map = with_map
            .then(|| GaussianFeatureMap::fit(&mu, &nu, 0.5, 8, &mut Rng::seed_from(7)));
        TaskEnvelope {
            task_id: u64::MAX - 3,
            group_id: 11,
            request_ids: vec![100, 101],
            plan,
            mu,
            nu,
            pairs,
            map,
            session: None,
        }
    }

    fn sample_delta(snapshot: bool, warm: bool) -> SessionDelta {
        SessionDelta {
            session_id: 42,
            base_version: 3,
            version: 5,
            snapshot,
            ops: if snapshot {
                Vec::new()
            } else {
                vec![
                    SessionOp::InsertX { point: vec![0.25, -1.5], weight: 0.125 },
                    SessionOp::EvictX { index: 7 },
                    SessionOp::SwapY { index: 2, point: vec![3.0, 4.0], weight: 0.5 },
                    SessionOp::EvictY { index: 0 },
                ]
            },
            warm_alpha: warm.then(|| vec![0.1, -0.25, f64::from_bits(0x3FF123456789ABCD)]),
        }
    }

    #[test]
    fn task_round_trips_with_and_without_map() {
        for with_map in [false, true] {
            let task = sample_task(with_map);
            let back = TaskEnvelope::decode(&task.encode()).unwrap();
            assert_eq!(back.task_id, task.task_id);
            assert_eq!(back.request_ids, task.request_ids);
            assert_eq!(back.plan, task.plan);
            assert_eq!(back.mu.points, task.mu.points);
            assert_eq!(back.nu.weights, task.nu.weights);
            assert_eq!(back.pairs, task.pairs);
            match (&back.map, &task.map) {
                (None, None) => {}
                (Some(a), Some(b)) => {
                    assert_eq!(a.anchors, b.anchors);
                    assert_eq!(a.eps.to_bits(), b.eps.to_bits());
                    assert_eq!(a.q.to_bits(), b.q.to_bits());
                }
                _ => panic!("map presence must round trip"),
            }
        }
    }

    #[test]
    fn session_extension_round_trips_and_is_absent_when_off() {
        // No session → byte-identical to a pre-session frame (the meta
        // key and columns are simply never emitted).
        let plain = sample_task(false);
        let frame = plain.encode();
        assert!(!String::from_utf8_lossy(&frame[..200.min(frame.len())]).contains("session"));
        for (snapshot, warm) in [(true, true), (false, true), (false, false)] {
            let mut task = sample_task(false);
            task.session = Some(sample_delta(snapshot, warm));
            let back = TaskEnvelope::decode(&task.encode()).unwrap();
            let (a, b) = (back.session.unwrap(), task.session.unwrap());
            assert_eq!(a.session_id, b.session_id);
            assert_eq!(a.base_version, b.base_version);
            assert_eq!(a.version, b.version);
            assert_eq!(a.snapshot, b.snapshot);
            assert_eq!(a.ops.len(), b.ops.len());
            for (x, y) in a.ops.iter().zip(&b.ops) {
                assert_eq!(format!("{x:?}"), format!("{y:?}"));
            }
            match (&a.warm_alpha, &b.warm_alpha) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    // Warm-start currency must survive the hop bit-for-bit.
                    let xb: Vec<u64> = x.iter().map(|v| v.to_bits()).collect();
                    let yb: Vec<u64> = y.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(xb, yb);
                }
                _ => panic!("warm alpha presence must round trip"),
            }
        }
    }

    #[test]
    fn session_decode_rejects_mis_sliced_op_payloads() {
        let mut task = sample_task(false);
        task.session = Some(sample_delta(false, false));
        let mut doc = WireDoc::decode(&task.encode()).unwrap();
        // Append a phantom insert to the op log: it now over-consumes
        // the shipped point/weight payload.
        let mut session = doc.meta.get("session").cloned().unwrap();
        match session {
            Json::Obj(ref mut obj) => match obj.get_mut("ops") {
                Some(Json::Arr(ops)) => ops.push(Json::Str("ix".to_string())),
                other => panic!("session ops must be an array, got {other:?}"),
            },
            other => panic!("session meta must be an object, got {other:?}"),
        }
        doc.set_json("session", session);
        match TaskEnvelope::decode(&doc.encode()) {
            Err(Error::Wire(msg)) => assert!(msg.contains("truncated"), "{msg}"),
            other => panic!("expected typed wire error, got {other:?}"),
        }
    }

    #[test]
    fn session_result_round_trips_ok_and_error() {
        let out = SessionSolveOut {
            objective: 1.25,
            iterations: 37,
            marginal_error: f64::NAN,
            converged: true,
            escalated: false,
            warm_started: true,
            alpha: vec![0.5, -0.5, f64::from_bits(0xBFF0000000000001)],
        };
        let env = SessionResultEnvelope::new(9, 2, Ok(out.clone()));
        let back = SessionResultEnvelope::decode(&env.encode()).unwrap();
        assert_eq!(back.task_id, 9);
        assert_eq!(back.worker_id, 2);
        let got = back.result.unwrap();
        assert_eq!(got.objective.to_bits(), out.objective.to_bits());
        assert_eq!(got.iterations, 37);
        assert!(got.marginal_error.is_nan(), "NaN scalars travel as bit patterns");
        assert!(got.converged && !got.escalated && got.warm_started);
        let ab: Vec<u64> = got.alpha.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u64> = out.alpha.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ab, bb);

        let err = SessionResultEnvelope::new(9, 2, Err(Error::Service("no resident state".into())));
        match SessionResultEnvelope::decode(&err.encode()).unwrap().result {
            Err(Error::Service(msg)) => assert_eq!(msg, "no resident state"),
            other => panic!("typed session failure survives the hop, got {other:?}"),
        }
    }

    #[test]
    fn task_decode_rejects_wrong_kind_and_bad_shapes() {
        let task = sample_task(false);
        let frame = task.encode();
        assert!(matches!(ResultEnvelope::decode(&frame), Err(Error::Wire(_))));
        assert!(matches!(TaskEnvelope::decode(b"LSW1junk"), Err(Error::Wire(_))));
    }

    #[test]
    fn task_decode_rejects_newer_plan_format_major() {
        // A mixed-version shard fleet must fail typed at envelope decode:
        // re-encode the task with its plan's `v` bumped past what this
        // build supports and watch the worker-side decode refuse it.
        let task = sample_task(false);
        let mut doc = WireDoc::decode(&task.encode()).unwrap();
        let mut plan_json = doc.meta.get("plan").unwrap().encode();
        let v = super::super::plan::PLAN_FORMAT_MAJOR;
        let old = format!("\"v\":{v}");
        let new = format!("\"v\":{}", v + 1);
        assert!(plan_json.contains(&old), "{plan_json}");
        plan_json = plan_json.replace(&old, &new);
        doc.set_json("plan", Json::parse(&plan_json).unwrap());
        match TaskEnvelope::decode(&doc.encode()) {
            Err(Error::Wire(msg)) => assert!(msg.contains("newer than this build"), "{msg}"),
            other => panic!("expected typed wire error, got {other:?}"),
        }
    }

    #[test]
    fn result_round_trips_reports_and_errors_bitwise() {
        let task = sample_task(false);
        let pair_refs: Vec<(&[f32], &[f32])> =
            task.pairs.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let mut results = OtProblem::new(&task.mu, &task.nu)
            .config(&task.plan.sinkhorn_config())
            .rank(8)
            .seed(7)
            .weight_pairs(&pair_refs)
            .divergence_all_planned(&task.plan);
        results.push(Err(Error::Service("worker exploded".into())));
        results.push(Err(Error::Overloaded("budget full".into())));
        results.push(Err(Error::SinkhornDiverged { iter: 3, reason: "nan".into() }));
        let env = ResultEnvelope::new(task.task_id, 2, results);
        let back = ResultEnvelope::decode(&env.encode()).unwrap();
        assert_eq!(back.task_id, env.task_id);
        assert_eq!(back.worker_id, 2);
        assert_eq!(back.results.len(), env.results.len());
        for (a, b) in back.results.iter().zip(&env.results) {
            match (a, b) {
                (Ok(x), Ok(y)) => {
                    assert_eq!(x.divergence.to_bits(), y.divergence.to_bits());
                    assert_eq!(x.xy.objective.to_bits(), y.xy.objective.to_bits());
                    assert_eq!(x.xy.u, y.xy.u);
                    assert_eq!(x.yy.v, y.yy.v);
                    assert_eq!(x.xx.iterations, y.xx.iterations);
                    assert_eq!(x.converged(), y.converged());
                    assert_eq!(x.simd_arm, y.simd_arm);
                }
                (Err(Error::Service(msg)), Err(Error::Service(orig))) => {
                    assert_eq!(msg, orig, "typed service error survives the hop");
                }
                (Err(Error::Overloaded(msg)), Err(Error::Overloaded(orig))) => {
                    assert_eq!(msg, orig, "typed overload shed survives the hop");
                }
                (Err(Error::Config(msg)), Err(orig @ Error::SinkhornDiverged { .. })) => {
                    assert_eq!(msg, &orig.to_string(), "unlisted variants normalise to config");
                }
                other => panic!("slot mismatch: {other:?}"),
            }
        }
    }

    #[test]
    fn result_round_trips_annealed_rung_counts() {
        // Annealed reports ship optional per-rung columns; direct
        // reports ship none — both decode to exactly what was encoded.
        let mut rng = Rng::seed_from(9);
        let (mu, nu) = data::gaussian_blobs(12, &mut rng);
        let problem = OtProblem::new(&mu, &nu).epsilon(0.3).rank(8).seed(7).anneal(true);
        let plan = problem.plan().unwrap();
        let report = problem.divergence_planned(&plan).unwrap();
        assert!(report.xy.rung_iterations.len() > 1, "annealed solve has rungs");
        let env = ResultEnvelope::new(1, 1, vec![Ok(report)]);
        let back = ResultEnvelope::decode(&env.encode()).unwrap();
        let (a, b) = match (&back.results[0], &env.results[0]) {
            (Ok(a), Ok(b)) => (a, b),
            other => panic!("slot mismatch: {other:?}"),
        };
        assert_eq!(a.xy.rung_iterations, b.xy.rung_iterations);
        assert_eq!(a.xx.rung_iterations, b.xx.rung_iterations);
        assert_eq!(a.yy.rung_iterations, b.yy.rung_iterations);
        assert_eq!(a.total_iterations(), b.total_iterations());
        assert_eq!(a.divergence.to_bits(), b.divergence.to_bits());
    }
}
