//! The unified solver API: **Problem → Plan → Solution**.
//!
//! Four PRs of subsystem growth left nine free solver functions plus
//! hand-wired kernel construction on the public surface, so every caller
//! re-implemented the paper's core decision — positive-feature factored
//! kernel vs dense Gibbs, and when to escalate to log-domain
//! stabilisation. This module puts that decision behind a typed planner:
//!
//! 1. [`OtProblem`] — a builder describing *what* to solve: measures (or
//!    prebuilt positive factors), eps, rank, weight pairs, thread /
//!    SIMD / determinism preferences, optional shared feature-map cache
//!    and persistent pools.
//! 2. [`Plan`] — an inspectable, serialisable decision record: chosen
//!    [`Backend`] (`Dense | Factored | Nystrom`), [`Domain`]
//!    (`Plain | LogDomain | AutoEscalate`), batch fusion width, pool
//!    widths, `(dim, eps, r)` cache key, and the SIMD dispatch arm.
//!    [`Plan::to_json`] / [`Plan::from_json`] round-trip exactly — the
//!    groundwork for shipping fuse groups to remote workers.
//! 3. [`Solution`] / [`DivergenceReport`] — objective, duals, per-problem
//!    convergence, escalation flags, wall clock, and the dispatch-arm tag
//!    matching the BENCH_*.json `cpu` field.
//!
//! Execution routes through the pre-existing solver layer bitwise
//! unchanged — see `api/execute.rs`'s module docs for the plan →
//! legacy-path table and `rust/tests/api_equivalence.rs` for the proof.
//! The old free
//! functions remain available for reference-level work via
//! [`crate::prelude::legacy`].

pub mod envelope;
mod execute;
mod plan;
mod problem;
mod solution;

pub use envelope::{
    ResultEnvelope, SessionDelta, SessionResultEnvelope, SessionSolveOut, TaskEnvelope,
};
pub use plan::{Backend, Domain, Plan, PLAN_FORMAT_MAJOR};
pub use problem::{BackendPref, DomainChoice, KernelChoice, OtProblem, SimdPreference};
pub use solution::{DivergenceReport, Solution};

/// Feature count the planner assumes when no rank is requested and the
/// backend is auto-chosen (matches the divergence service's default).
pub const DEFAULT_RANK: usize = 256;

/// Planner threshold for skipping the plain f32 attempt entirely: when
/// `R^2 / eps` exceeds this, typical Gibbs values sit so far below the
/// stabilised factors' `exp(LOG_FLOOR)` clamp that row sums flush to
/// zero in f32 and plain Alg. 1 cannot finish — the planner goes
/// straight to the log domain. `2 * |LOG_FLOOR| = 160` nats, the same
/// constant that sizes the factor clamp (a feature *product* spans two
/// factors).
pub const UNDERFLOW_LOG_SPREAD: f64 = 2.0 * (-crate::features::LOG_FLOOR) as f64;
