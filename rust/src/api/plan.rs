//! The [`Plan`]: an inspectable, serialisable record of every decision the
//! planner made for one [`super::OtProblem`].
//!
//! A `Plan` is pure data — no kernels, no pools, no borrowed measures —
//! which is what makes it the unit a coordinator can ship across hosts
//! (ROADMAP: cross-host shard dispatch of fuse groups). Handles that
//! cannot serialise (worker pools, the shared feature-map cache) are
//! represented by their *decisions*: the pool widths and the `(dim, eps,
//! r)` cache key. The executor ([`super::OtProblem::solve_planned`] and
//! friends) re-binds those decisions to live handles at execution time.
//!
//! The JSON encoding ([`Plan::to_json`] / [`Plan::from_json`]) uses the
//! crate's own minimal parser (`runtime/json.rs`, re-exported as
//! [`crate::runtime::Json`]) — no serde in the offline crate set.
//! Round-tripping is exact: floats are written
//! with Rust's shortest-round-trip `Display` and the `u64` seed is
//! carried as a decimal string (JSON numbers are f64 and cannot hold all
//! of `u64`).

use crate::config::SinkhornConfig;
use crate::coordinator::cache::FeatureKey;
use crate::error::{Error, Result};
use crate::runtime::Json;
use crate::sinkhorn::EpsSchedule;

/// Major version of the `Plan` JSON wire format (the `"v"` key).
///
/// Decode policy is strict forward-compatibility: documents carrying the
/// **same** major may contain unknown fields (they are ignored, which is
/// how minor additions like `schedule` shipped), while a **newer** major
/// is rejected as a typed [`Error::Config`] — a mixed-version shard
/// fleet fails loudly at `TaskEnvelope` decode instead of silently
/// garbling semantics it cannot represent. Documents with no `"v"` key
/// predate the field and decode as v1.
pub const PLAN_FORMAT_MAJOR: usize = 1;

/// Kernel backend chosen by the planner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Materialised Gibbs kernel `exp(-C/eps)` — exact, O(nm) per apply
    /// (the paper's `Sin` baseline).
    Dense,
    /// The paper's positive-feature factored kernel `K = Φ_x Φ_y^T` —
    /// O(r(n+m)) per apply, positive by construction (`RF`).
    Factored {
        /// Feature count r.
        rank: usize,
    },
    /// Nyström low-rank arm — O(r(n+m)) but **not** positivity-safe
    /// (`Nys`); auto-planned only in the flat-kernel regime, adaptive
    /// sampling on explicit preference only.
    Nystrom {
        /// Landmark count.
        rank: usize,
        /// Farthest-point (adaptive) landmark selection instead of
        /// uniform sampling (arXiv:1812.05189); both replay from
        /// [`Plan::seed`].
        adaptive: bool,
    },
}

impl Backend {
    /// The rank driving the `(dim, eps, r)` cache key (0 for dense).
    pub fn rank(&self) -> usize {
        match *self {
            Backend::Dense => 0,
            Backend::Factored { rank } | Backend::Nystrom { rank, .. } => rank,
        }
    }

    fn tag(&self) -> &'static str {
        match self {
            Backend::Dense => "dense",
            Backend::Factored { .. } => "factored",
            Backend::Nystrom { .. } => "nystrom",
        }
    }
}

/// Numeric domain of the Sinkhorn iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// Plain Alg. 1 on f32 scalings; diverges loudly (typed error) when
    /// eps is too small for f32.
    Plain,
    /// The matrix-free log-domain iteration on f64 duals — planned
    /// directly when the regularisation is hopeless for f32.
    LogDomain,
    /// Plain first, escalating to the log-domain solver on
    /// [`Error::SinkhornDiverged`] — the production default.
    AutoEscalate,
}

impl Domain {
    fn tag(&self) -> &'static str {
        match self {
            Domain::Plain => "plain",
            Domain::LogDomain => "log_domain",
            Domain::AutoEscalate => "auto_escalate",
        }
    }
}

/// An inspectable, serialisable solver plan. See the module docs.
///
/// Field-by-field this is the union of the decisions that, before this
/// API existed, were scattered across call sites: which kernel backend
/// (`kernels/`), whether to stabilise the factor construction
/// (`FactoredKernel::from_measures_stabilized`), which numeric domain and
/// when to escalate (`sinkhorn::sinkhorn_stabilized`), how wide to fuse
/// batched solves (`coordinator::batcher::fuse_groups`), which pool
/// widths to use (`runtime::pool`), and which SIMD arm the process
/// dispatches (`linalg::simd`).
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    /// Chosen kernel backend.
    pub backend: Backend,
    /// Chosen numeric domain / escalation policy.
    pub domain: Domain,
    /// Stabilised (max-shifted log) factor construction for the factored
    /// backend — lets arbitrary data survive f32 at small eps.
    pub stabilized_factors: bool,
    /// Alg. 2 (accelerated) instead of Alg. 1 — plain domain, B = 1 only.
    pub accelerated: bool,
    /// Number of weight pairs B this plan covers.
    pub pairs: usize,
    /// Fused width per batched solve call (≤ `pairs`, capped by the
    /// problem's `max_batch`).
    pub batch_width: usize,
    /// Solve-level concurrency: the three transport problems of a
    /// divergence (0 = auto-size, capped at 3 by the executor).
    pub threads: usize,
    /// Intra-solve pool width for row-chunked applies and parallel
    /// feature evaluation (0 = auto-size).
    pub solver_threads: usize,
    /// The SIMD dispatch arm recorded at planning time (`"scalar"` /
    /// `"avx2+fma"` — the `cpu` tag of the BENCH_*.json tables). Dispatch
    /// is process-global, so this is a *record*, not a switch: a plan
    /// executed on another host runs that host's arm, and the
    /// [`super::Solution`] reports the arm that actually executed.
    pub simd_arm: String,
    /// `(dim, eps, r)` feature-map cache key when the factored backend is
    /// fitted from measures — the amortisation unit of
    /// [`crate::coordinator::cache::FeatureCache`].
    pub cache_key: Option<FeatureKey>,
    /// Entropic regularisation.
    pub epsilon: f64,
    /// Solver iteration cap.
    pub max_iters: usize,
    /// L1 marginal stopping tolerance.
    pub tol: f64,
    /// Stopping-check cadence.
    pub check_every: usize,
    /// Problem shape (rows of K = size of mu).
    pub n: usize,
    /// Problem shape (cols of K = size of nu).
    pub m: usize,
    /// Seed for the Lemma-1 anchor draw (and the Nyström landmark draw)
    /// when the executor fits a map itself.
    pub seed: u64,
    /// Eps-annealing schedule: geometric rungs from `schedule.eps_start`
    /// down to `epsilon`, each rung warm-starting the next from the row
    /// dual it converged to. `None` = direct solve at the target eps.
    /// The schedule is pure f64 data, so a plan shipped to a shard worker
    /// anneals through bit-identical rungs on every host.
    pub schedule: Option<EpsSchedule>,
    /// Use the one-dual symmetric fixed point `u <- sqrt(u * a/(Ku))` for
    /// the xx/yy self-solves of a divergence instead of full two-sided
    /// solves. Halves the dual state and roughly halves the applies per
    /// iteration on those legs.
    pub symmetric_self_solves: bool,
    /// The solves this plan describes may warm-start from
    /// caller-provided duals (streaming-session queries: the coordinator
    /// ships the session's remapped previous dual alongside the
    /// envelope, and the executor/worker enters through the `*_warm`
    /// solver entry points). Pure metadata for direct solves — the
    /// executor's own routing is unchanged when no warm dual arrives.
    pub warm_start: bool,
}

impl Plan {
    /// The [`SinkhornConfig`] the executor hands to the underlying
    /// solver loops. `stabilize` is exactly `domain == AutoEscalate`, so
    /// the legacy free functions behave bit-for-bit as the plan dictates.
    pub fn sinkhorn_config(&self) -> SinkhornConfig {
        SinkhornConfig {
            epsilon: self.epsilon,
            max_iters: self.max_iters,
            tol: self.tol,
            check_every: self.check_every,
            threads: self.threads,
            stabilize: self.domain == Domain::AutoEscalate,
            max_batch: self.batch_width.max(1),
            // The executor drives annealing and symmetric routing itself;
            // these mirror the plan so a config round-tripped through the
            // free functions stays faithful to what was planned.
            anneal: self.schedule.is_some().then_some(true),
            anneal_decay: self.schedule.map_or(0.5, |s| s.decay),
            symmetric: Some(self.symmetric_self_solves),
        }
    }

    /// One-line human summary (the CLI's `--explain`).
    pub fn summary(&self) -> String {
        let backend = match self.backend {
            Backend::Dense => format!("dense({}x{})", self.n, self.m),
            Backend::Factored { rank } => format!("factored(r={rank} {}x{})", self.n, self.m),
            Backend::Nystrom { rank, adaptive } => format!(
                "nystrom(r={rank}{} {}x{})",
                if adaptive { ",adaptive" } else { "" },
                self.n,
                self.m
            ),
        };
        format!(
            "plan: backend={backend} domain={} stabilized_factors={} pairs={} width={} \
             threads={}/{} simd={} eps={} anneal={} symmetric={} warm_start={} cache_key={}",
            self.domain.tag(),
            self.stabilized_factors,
            self.pairs,
            self.batch_width,
            self.threads,
            self.solver_threads,
            self.simd_arm,
            self.epsilon,
            match self.schedule {
                Some(s) => format!(
                    "geo(start={},decay={},rungs={})",
                    s.eps_start,
                    s.decay,
                    s.rungs(self.epsilon).len()
                ),
                None => "off".into(),
            },
            self.symmetric_self_solves,
            self.warm_start,
            match self.cache_key {
                Some(k) => format!("(d={},eps,r={})", k.dim, k.r),
                None => "-".into(),
            }
        )
    }

    /// Stable JSON encoding. Exact round trip through
    /// [`Plan::from_json`]: floats use shortest-round-trip formatting,
    /// the seed is a decimal string, and the cache key stores only
    /// `(dim, r)` (its eps bits are derived from `epsilon`, which they
    /// equal by construction).
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(320);
        s.push_str(&format!("{{\"v\":{PLAN_FORMAT_MAJOR},\"backend\":\""));
        s.push_str(self.backend.tag());
        s.push('"');
        if self.backend.rank() > 0 {
            s.push_str(&format!(",\"rank\":{}", self.backend.rank()));
        }
        if let Backend::Nystrom { adaptive, .. } = self.backend {
            s.push_str(&format!(",\"adaptive\":{adaptive}"));
        }
        s.push_str(&format!(",\"domain\":\"{}\"", self.domain.tag()));
        s.push_str(&format!(",\"stabilized_factors\":{}", self.stabilized_factors));
        s.push_str(&format!(",\"accelerated\":{}", self.accelerated));
        s.push_str(&format!(",\"pairs\":{}", self.pairs));
        s.push_str(&format!(",\"batch_width\":{}", self.batch_width));
        s.push_str(&format!(",\"threads\":{}", self.threads));
        s.push_str(&format!(",\"solver_threads\":{}", self.solver_threads));
        s.push_str(&format!(",\"simd_arm\":\"{}\"", self.simd_arm));
        if let Some(k) = self.cache_key {
            s.push_str(&format!(",\"cache\":{{\"dim\":{},\"r\":{}}}", k.dim, k.r));
        }
        s.push_str(&format!(",\"epsilon\":{}", self.epsilon));
        s.push_str(&format!(",\"max_iters\":{}", self.max_iters));
        s.push_str(&format!(",\"tol\":{}", self.tol));
        s.push_str(&format!(",\"check_every\":{}", self.check_every));
        s.push_str(&format!(",\"n\":{},\"m\":{}", self.n, self.m));
        s.push_str(&format!(",\"seed\":\"{}\"", self.seed));
        if let Some(sch) = self.schedule {
            s.push_str(&format!(
                ",\"schedule\":{{\"eps_start\":{},\"decay\":{}}}",
                sch.eps_start, sch.decay
            ));
        }
        s.push_str(&format!(",\"symmetric_self_solves\":{}", self.symmetric_self_solves));
        if self.warm_start {
            // Same-major minor addition (like `schedule`): emitted only
            // when set, so pre-session workers see byte-identical plans
            // for every non-session solve.
            s.push_str(",\"warm_start\":true");
        }
        s.push('}');
        s
    }

    /// Decode a plan previously encoded with [`Plan::to_json`].
    pub fn from_json(text: &str) -> Result<Plan> {
        let j = Json::parse(text).map_err(|e| Error::Config(format!("plan json: {e}")))?;
        // Version gate first (see [`PLAN_FORMAT_MAJOR`]): a document from
        // a newer major may carry semantics this build cannot represent,
        // so it must fail typed before any field is interpreted. Absent
        // `"v"` predates the field and decodes as v1.
        if let Some(v) = j.get("v") {
            let v = v.as_usize().ok_or_else(|| {
                Error::Config("plan json: `v` must be a non-negative integer".into())
            })?;
            if v > PLAN_FORMAT_MAJOR {
                return Err(Error::Config(format!(
                    "plan json: format version {v} is newer than this build supports \
                     ({PLAN_FORMAT_MAJOR}); upgrade this worker"
                )));
            }
        }
        let str_field = |name: &str| -> Result<&str> {
            j.get(name)
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Config(format!("plan json: missing string `{name}`")))
        };
        let usize_field = |name: &str| -> Result<usize> {
            j.get(name)
                .and_then(Json::as_usize)
                .ok_or_else(|| Error::Config(format!("plan json: missing integer `{name}`")))
        };
        let f64_field = |name: &str| -> Result<f64> {
            j.get(name)
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::Config(format!("plan json: missing number `{name}`")))
        };
        let bool_field = |name: &str| -> Result<bool> {
            match j.get(name) {
                Some(Json::Bool(b)) => Ok(*b),
                _ => Err(Error::Config(format!("plan json: missing bool `{name}`"))),
            }
        };

        let backend = match str_field("backend")? {
            "dense" => Backend::Dense,
            "factored" => Backend::Factored { rank: usize_field("rank")? },
            "nystrom" => Backend::Nystrom {
                rank: usize_field("rank")?,
                adaptive: matches!(j.get("adaptive"), Some(Json::Bool(true))),
            },
            other => return Err(Error::Config(format!("plan json: unknown backend `{other}`"))),
        };
        if matches!(backend, Backend::Factored { rank: 0 } | Backend::Nystrom { rank: 0, .. }) {
            return Err(Error::Config("plan json: rank must be >= 1".into()));
        }
        let domain = match str_field("domain")? {
            "plain" => Domain::Plain,
            "log_domain" => Domain::LogDomain,
            "auto_escalate" => Domain::AutoEscalate,
            other => return Err(Error::Config(format!("plan json: unknown domain `{other}`"))),
        };
        // Re-assert the planner's invariants: a wire plan is executed
        // without going back through `OtProblem::plan()`, so a corrupted
        // or hand-built document must not reach the kernels (eps <= 0
        // would exponentiate to NaN, not to a typed error).
        let epsilon = f64_field("epsilon")?;
        if !(epsilon > 0.0 && epsilon.is_finite()) {
            return Err(Error::Config(format!(
                "plan json: epsilon must be positive and finite, got {epsilon}"
            )));
        }
        let cache_key = match j.get("cache") {
            Some(c) => {
                let dim = c
                    .get("dim")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| Error::Config("plan json: cache.dim".into()))?;
                let r = c
                    .get("r")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| Error::Config("plan json: cache.r".into()))?;
                Some(FeatureKey::new(dim, epsilon, r))
            }
            None => None,
        };
        let seed = str_field("seed")?
            .parse::<u64>()
            .map_err(|_| Error::Config("plan json: seed must be a decimal u64 string".into()))?;
        // `schedule` and `symmetric_self_solves` entered the format after
        // v1 shipped: absent keys decode to the direct-solve behaviour so
        // plans written by older coordinators still execute.
        let schedule = match j.get("schedule") {
            Some(sch) => {
                let eps_start = sch
                    .get("eps_start")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| Error::Config("plan json: schedule.eps_start".into()))?;
                let decay = sch
                    .get("decay")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| Error::Config("plan json: schedule.decay".into()))?;
                // Re-assert the schedule invariants on the wire path too.
                Some(EpsSchedule::new(eps_start, decay)?)
            }
            None => None,
        };
        let symmetric_self_solves = matches!(j.get("symmetric_self_solves"), Some(Json::Bool(true)));
        // `warm_start` entered with the streaming-session subsystem:
        // absent decodes to false (direct solve), the only behaviour
        // older writers could have meant.
        let warm_start = matches!(j.get("warm_start"), Some(Json::Bool(true)));

        Ok(Plan {
            backend,
            domain,
            stabilized_factors: bool_field("stabilized_factors")?,
            accelerated: bool_field("accelerated")?,
            pairs: usize_field("pairs")?,
            batch_width: usize_field("batch_width")?,
            threads: usize_field("threads")?,
            solver_threads: usize_field("solver_threads")?,
            simd_arm: str_field("simd_arm")?.to_string(),
            cache_key,
            epsilon,
            max_iters: usize_field("max_iters")?,
            tol: f64_field("tol")?,
            check_every: usize_field("check_every")?,
            n: usize_field("n")?,
            m: usize_field("m")?,
            seed,
            schedule,
            symmetric_self_solves,
            warm_start,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(backend: Backend, domain: Domain, cache: bool) -> Plan {
        Plan {
            backend,
            domain,
            stabilized_factors: true,
            accelerated: false,
            pairs: 4,
            batch_width: 4,
            threads: 3,
            solver_threads: 2,
            simd_arm: "avx2+fma".into(),
            cache_key: cache.then(|| FeatureKey::new(2, 0.05, 256)),
            epsilon: 0.05,
            max_iters: 5000,
            tol: 1e-3,
            check_every: 10,
            n: 1000,
            m: 900,
            seed: u64::MAX, // exercise the beyond-f64 seed path
            schedule: None,
            symmetric_self_solves: false,
            warm_start: false,
        }
    }

    #[test]
    fn json_round_trips_every_backend_and_domain() {
        for plan in [
            sample(Backend::Factored { rank: 256 }, Domain::AutoEscalate, true),
            sample(Backend::Dense, Domain::Plain, false),
            sample(Backend::Nystrom { rank: 32, adaptive: false }, Domain::Plain, false),
            sample(Backend::Nystrom { rank: 32, adaptive: true }, Domain::AutoEscalate, false),
            sample(Backend::Factored { rank: 8 }, Domain::LogDomain, true),
        ] {
            let text = plan.to_json();
            let back = Plan::from_json(&text).unwrap_or_else(|e| panic!("{e}\n{text}"));
            assert_eq!(back, plan, "{text}");
        }
    }

    #[test]
    fn json_round_trips_schedule_and_symmetric() {
        let mut plan = sample(Backend::Factored { rank: 64 }, Domain::AutoEscalate, true);
        plan.schedule = Some(EpsSchedule::new(8.0, 0.5).unwrap());
        plan.symmetric_self_solves = true;
        let text = plan.to_json();
        assert!(text.contains("\"schedule\""), "{text}");
        let back = Plan::from_json(&text).unwrap();
        assert_eq!(back, plan, "{text}");
        // Awkward float bits survive the round trip exactly.
        plan.schedule = Some(EpsSchedule::new(0.1f64.powi(2) * 7.0, 1.0 / 3.0).unwrap());
        let back = Plan::from_json(&plan.to_json()).unwrap();
        assert_eq!(
            back.schedule.unwrap().eps_start.to_bits(),
            plan.schedule.unwrap().eps_start.to_bits()
        );
        assert_eq!(
            back.schedule.unwrap().decay.to_bits(),
            plan.schedule.unwrap().decay.to_bits()
        );
    }

    #[test]
    fn from_json_tolerates_pre_schedule_documents() {
        // Plans written before the schedule fields existed must decode to
        // the direct-solve behaviour, not error.
        let plan = sample(Backend::Dense, Domain::Plain, false);
        let text = plan.to_json().replace(",\"symmetric_self_solves\":false", "");
        let back = Plan::from_json(&text).unwrap();
        assert_eq!(back.schedule, None);
        assert!(!back.symmetric_self_solves);
        // But a present-and-invalid schedule is still a typed error.
        let bad = plan
            .to_json()
            .replace(",\"symmetric_self_solves\":false", ",\"schedule\":{\"eps_start\":8.0,\"decay\":1.5}");
        assert!(Plan::from_json(&bad).is_err());
    }

    #[test]
    fn json_round_trip_is_bit_exact_on_awkward_floats() {
        // Shortest-round-trip Display must reproduce the exact bits, not
        // a decimal approximation.
        let mut plan = sample(Backend::Factored { rank: 10 }, Domain::Plain, true);
        plan.epsilon = 0.1f64.powi(3) * 3.0; // a non-terminating binary fraction
        plan.tol = f64::MIN_POSITIVE;
        if let Some(k) = plan.cache_key.as_mut() {
            *k = FeatureKey::new(k.dim, plan.epsilon, k.r);
        }
        let back = Plan::from_json(&plan.to_json()).unwrap();
        assert_eq!(back.epsilon.to_bits(), plan.epsilon.to_bits());
        assert_eq!(back.tol.to_bits(), plan.tol.to_bits());
        assert_eq!(back.cache_key, plan.cache_key, "cache eps bits derive from epsilon");
    }

    #[test]
    fn from_json_rejects_malformed_plans() {
        assert!(Plan::from_json("not json").is_err());
        assert!(Plan::from_json("{}").is_err());
        let plan = sample(Backend::Factored { rank: 2 }, Domain::Plain, false);
        let bad = plan.to_json().replace("\"factored\"", "\"quantum\"");
        assert!(Plan::from_json(&bad).is_err());
        let bad_seed = plan.to_json().replace(&format!("\"{}\"", u64::MAX), "\"-1\"");
        assert!(Plan::from_json(&bad_seed).is_err());
        // Planner invariants hold on the wire too: a corrupted document
        // must fail decoding, not reach the kernels.
        let bad_eps = plan.to_json().replace("\"epsilon\":0.05", "\"epsilon\":0");
        assert!(Plan::from_json(&bad_eps).is_err());
        let bad_rank = plan.to_json().replace("\"rank\":2", "\"rank\":0");
        assert!(Plan::from_json(&bad_rank).is_err());
    }

    #[test]
    fn version_gate_is_strict_forward_compatible() {
        let plan = sample(Backend::Nystrom { rank: 16, adaptive: true }, Domain::Plain, false);
        let text = plan.to_json();
        assert!(text.starts_with(&format!("{{\"v\":{PLAN_FORMAT_MAJOR},")), "{text}");
        // Same-major unknown fields are ignored (how minor additions ship).
        let extended = text.replace(",\"domain\"", ",\"future_hint\":true,\"domain\"");
        assert_eq!(Plan::from_json(&extended).unwrap(), plan);
        // Pre-version documents (no `"v"` key) decode as v1.
        let unversioned = text.replace(&format!("{{\"v\":{PLAN_FORMAT_MAJOR},"), "{");
        assert!(!unversioned.contains("\"v\":"), "{unversioned}");
        assert_eq!(Plan::from_json(&unversioned).unwrap(), plan);
        // A newer major is a typed Config error naming the versions, so a
        // mixed-version shard fleet fails loudly at envelope decode.
        let newer = text.replace(
            &format!("{{\"v\":{PLAN_FORMAT_MAJOR},"),
            &format!("{{\"v\":{},", PLAN_FORMAT_MAJOR + 1),
        );
        match Plan::from_json(&newer) {
            Err(Error::Config(msg)) => {
                assert!(msg.contains("newer than this build"), "{msg}");
            }
            other => panic!("expected typed Config error, got {other:?}"),
        }
        // And a malformed version is not silently accepted.
        let junk = text.replace(
            &format!("{{\"v\":{PLAN_FORMAT_MAJOR},"),
            "{\"v\":\"one\",",
        );
        assert!(Plan::from_json(&junk).is_err());
    }

    #[test]
    fn warm_start_round_trips_and_is_absent_when_off() {
        let mut plan = sample(Backend::Factored { rank: 64 }, Domain::AutoEscalate, true);
        // Off: the key is omitted entirely, so non-session plans are
        // byte-identical to what pre-session coordinators emitted.
        let text = plan.to_json();
        assert!(!text.contains("warm_start"), "{text}");
        assert!(!Plan::from_json(&text).unwrap().warm_start);
        // On: round-trips exactly.
        plan.warm_start = true;
        let text = plan.to_json();
        assert!(text.contains("\"warm_start\":true"), "{text}");
        assert_eq!(Plan::from_json(&text).unwrap(), plan);
        assert!(plan.summary().contains("warm_start=true"), "{}", plan.summary());
    }

    #[test]
    fn nystrom_adaptive_flag_round_trips_and_defaults_off() {
        let plan = sample(Backend::Nystrom { rank: 24, adaptive: true }, Domain::Plain, false);
        let text = plan.to_json();
        assert!(text.contains("\"adaptive\":true"), "{text}");
        assert_eq!(Plan::from_json(&text).unwrap(), plan);
        // Pre-adaptive documents (no `"adaptive"` key) decode as uniform
        // sampling — the only behaviour old writers could have meant.
        let stripped = text.replace(",\"adaptive\":true", "");
        let back = Plan::from_json(&stripped).unwrap();
        assert_eq!(back.backend, Backend::Nystrom { rank: 24, adaptive: false });
    }

    #[test]
    fn sinkhorn_config_mirrors_the_domain() {
        let esc = sample(Backend::Dense, Domain::AutoEscalate, false);
        assert!(esc.sinkhorn_config().stabilize);
        let plain = sample(Backend::Dense, Domain::Plain, false);
        assert!(!plain.sinkhorn_config().stabilize);
        assert_eq!(plain.sinkhorn_config().max_batch, 4);
    }

    #[test]
    fn summary_mentions_the_load_bearing_decisions() {
        let s = sample(Backend::Factored { rank: 256 }, Domain::AutoEscalate, true).summary();
        assert!(s.contains("factored(r=256"), "{s}");
        assert!(s.contains("auto_escalate"), "{s}");
        assert!(s.contains("width=4"), "{s}");
        assert!(s.contains("anneal=off"), "{s}");
        let mut annealed = sample(Backend::Factored { rank: 256 }, Domain::AutoEscalate, true);
        annealed.schedule = Some(EpsSchedule::new(0.8, 0.5).unwrap());
        annealed.symmetric_self_solves = true;
        let s = annealed.summary();
        assert!(s.contains("anneal=geo(start=0.8,decay=0.5,rungs=5)"), "{s}");
        assert!(s.contains("symmetric=true"), "{s}");
        let s = sample(Backend::Nystrom { rank: 32, adaptive: true }, Domain::Plain, false).summary();
        assert!(s.contains("nystrom(r=32,adaptive"), "{s}");
    }
}
