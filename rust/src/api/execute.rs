//! The executor: binds a [`Plan`] to live kernels/pools and routes it
//! through the legacy solver layer **bitwise-unchanged**.
//!
//! ## The equivalence contract
//!
//! Every `Plan` the planner can emit executes through exactly the code
//! path a hand-wired caller of the pre-API free functions would have
//! taken, with identical kernel construction, identical solver entry
//! point, and identical `SinkhornConfig` — so results are **bitwise
//! identical** to the corresponding legacy call:
//!
//! | plan | legacy path |
//! |------|-------------|
//! | `Dense`, `Plain` | `sinkhorn(&DenseKernel::from_measures(..), ..)` |
//! | `Factored`, `Plain` | `sinkhorn(&FactoredKernel::from_measures[_stabilized]_pooled(..), ..)` |
//! | `*`, `AutoEscalate` | `sinkhorn_stabilized(..)` with `cfg.stabilize = true` |
//! | `*`, `LogDomain` | `sinkhorn_log_domain(kernel.as_log_kernel(), ..)` |
//! | B > 1 | `solve_batch[_stabilized|_log_domain](..)` per width-`batch_width` chunk |
//! | divergence | the three-solve `join3` of `sinkhorn_divergence` / the coordinator worker |
//! | `accelerated` | `sinkhorn_accelerated(..)` |
//!
//! When the executor fits a feature map itself, the draw is
//! `GaussianFeatureMap::fit(mu, nu, eps, rank, &mut Rng::seed_from(plan.seed))`
//! — seeded, so the same plan refits the same anchors. The Nyström
//! backend is built the same way:
//! `NystromKernel::from_measures[_adaptive](mu, nu, eps, rank, &mut
//! Rng::seed_from(plan.seed))`, so the landmark draw (uniform or
//! farthest-point) rides the plan seed and a shard worker decoding the
//! plan rebuilds the bit-identical kernel. The property suite in
//! `rust/tests/api_equivalence.rs` asserts the table above bit for bit.

use std::sync::Arc;

use crate::coordinator::cache::{support_fingerprint, FeatureKey, LandmarkKey};
use crate::data::Measure;
use crate::error::{Error, Result};
use crate::features::GaussianFeatureMap;
use crate::kernels::{DenseKernel, FactoredKernel, KernelOp, NystromKernel};
use crate::metrics::Stopwatch;
use crate::rng::Rng;
use crate::runtime::pool::Pool;
use crate::config::SinkhornConfig;
use crate::sinkhorn::{
    sinkhorn, sinkhorn_accelerated, sinkhorn_log_domain, sinkhorn_log_domain_warm,
    sinkhorn_stabilized, sinkhorn_stabilized_warm, sinkhorn_symmetric,
    sinkhorn_symmetric_log, sinkhorn_symmetric_log_warm, sinkhorn_symmetric_stabilized,
    sinkhorn_symmetric_stabilized_warm, sinkhorn_symmetric_warm, sinkhorn_warm,
    solve_batch_log_domain, solve_batch_log_domain_warm, solve_batch_stabilized,
    solve_batch_stabilized_warm, EpsSchedule, SinkhornSolution, WarmSolve,
};

use super::plan::{Backend, Domain, Plan};
use super::problem::{OtProblem, Source};
use super::solution::{DivergenceReport, Solution};
use super::UNDERFLOW_LOG_SPREAD;

fn us(sw: &Stopwatch) -> u64 {
    (sw.elapsed_secs() * 1e6) as u64
}

/// Replicate a whole-batch failure (planning, kernel construction) onto
/// every pair slot, keeping the documented index-alignment of the
/// `*_all` results. `Error` is not `Clone`, and every whole-batch
/// failure is configuration-class, so each slot gets an [`Error::Config`]
/// carrying the original message.
fn err_per_pair<T>(pairs: usize, e: Error) -> Vec<Result<T>> {
    let message = match e {
        Error::Config(msg) => msg,
        other => other.to_string(),
    };
    (0..pairs.max(1)).map(|_| Err(Error::Config(message.clone()))).collect()
}

/// A fitted map, either borrowed from the problem/cache or freshly drawn.
enum MapHandle<'m> {
    Borrowed(&'m GaussianFeatureMap),
    Shared(Arc<GaussianFeatureMap>),
}

impl MapHandle<'_> {
    fn get(&self) -> &GaussianFeatureMap {
        match self {
            MapHandle::Borrowed(m) => m,
            MapHandle::Shared(a) => a,
        }
    }
}

/// The single-problem kernel (divergence builds its own triple).
enum BuiltKernel {
    Dense(DenseKernel),
    Factored(FactoredKernel),
    Nystrom(NystromKernel),
}

impl<'a> OtProblem<'a> {
    // ----------------------------------------------------------------
    // Public execution entry points.
    // ----------------------------------------------------------------

    /// Plan and solve a single transport problem.
    pub fn solve(&self) -> Result<Solution> {
        let plan = self.plan()?;
        self.solve_planned(&plan)
    }

    /// Execute a given plan (e.g. one decoded from
    /// [`Plan::from_json`]) for a single transport problem.
    pub fn solve_planned(&self, plan: &Plan) -> Result<Solution> {
        let pairs = self.effective_pairs()?;
        if pairs.len() != 1 {
            return Err(Error::Config(format!(
                "solve() is single-pair but the problem has {} weight pairs; use solve_all()",
                pairs.len()
            )));
        }
        let (a, b) = pairs[0];
        let solver_pool = self.resolve_solver_pool(plan);
        if let Some(sch) = annealed_schedule(plan)? {
            return self.run_single_annealed(plan, &sch, a, b, &solver_pool);
        }
        match self.build_kernel(plan, &solver_pool)? {
            BuiltKernel::Dense(k) => self.run_single(plan, &k, a, b),
            BuiltKernel::Factored(k) => self.run_single(plan, &k, a, b),
            BuiltKernel::Nystrom(k) => self.run_single(plan, &k, a, b),
        }
    }

    /// Plan and solve all B weight pairs (fused batched execution,
    /// bitwise identical per pair to B separate [`OtProblem::solve`]s).
    pub fn solve_all(&self) -> Vec<Result<Solution>> {
        let plan = match self.plan() {
            Ok(p) => p,
            Err(e) => return err_per_pair(self.pairs.len(), e),
        };
        self.solve_all_planned(&plan)
    }

    /// Execute a given plan for all B weight pairs. The result vector is
    /// index-aligned with the problem's pairs; one pair failing never
    /// poisons its batch-mates, and whole-batch failures (planning,
    /// kernel construction) are replicated onto every slot so the
    /// alignment holds on the error path too.
    pub fn solve_all_planned(&self, plan: &Plan) -> Vec<Result<Solution>> {
        let pairs = match self.effective_pairs() {
            Ok(p) => p,
            Err(e) => return err_per_pair(self.pairs.len(), e),
        };
        let solver_pool = self.resolve_solver_pool(plan);
        match annealed_schedule(plan) {
            Ok(Some(sch)) => return self.run_batch_annealed(plan, &sch, &pairs, &solver_pool),
            Ok(None) => {}
            Err(e) => return err_per_pair(pairs.len(), e),
        }
        let kernel = match self.build_kernel(plan, &solver_pool) {
            Ok(k) => k,
            Err(e) => return err_per_pair(pairs.len(), e),
        };
        match kernel {
            BuiltKernel::Dense(k) => self.run_batch(plan, &k, &pairs),
            BuiltKernel::Factored(k) => self.run_batch(plan, &k, &pairs),
            BuiltKernel::Nystrom(k) => self.run_batch(plan, &k, &pairs),
        }
    }

    /// Plan and compute the Eq. (2) Sinkhorn divergence (three transport
    /// solves, concurrent when the plan's `threads` allows).
    pub fn divergence(&self) -> Result<DivergenceReport> {
        let plan = self.plan()?;
        self.divergence_planned(&plan)
    }

    /// Execute a given plan as a divergence.
    pub fn divergence_planned(&self, plan: &Plan) -> Result<DivergenceReport> {
        let pairs = self.effective_pairs()?;
        if pairs.len() != 1 {
            return Err(Error::Config(format!(
                "divergence() is single-pair but the problem has {} weight pairs; use \
                 divergence_all()",
                pairs.len()
            )));
        }
        if plan.accelerated {
            // Alg. 2 maximises the single-problem dual; there is no
            // accelerated three-solve divergence (legacy had none
            // either). Reject instead of silently running Alg. 1.
            return Err(Error::Config(
                "the accelerated solver (Alg. 2) has no divergence form; use solve_planned()"
                    .into(),
            ));
        }
        let (a, b) = pairs[0];
        let sw = Stopwatch::start();
        if let Some(sch) = annealed_schedule(plan)? {
            return self.run_divergence_annealed(plan, &sch, a, b, &sw);
        }
        self.with_divergence_kernels(plan, |k_xy, k_xx, k_yy| {
            self.run_divergence(plan, k_xy, k_xx, k_yy, a, b, &sw)
        })
    }

    /// Plan and compute divergences for all B weight pairs as **three
    /// width-B fused solves** (the coordinator's fuse-group path);
    /// per pair bitwise identical to B separate
    /// [`OtProblem::divergence`] calls.
    pub fn divergence_all(&self) -> Vec<Result<DivergenceReport>> {
        let plan = match self.plan() {
            Ok(p) => p,
            Err(e) => return err_per_pair(self.pairs.len(), e),
        };
        self.divergence_all_planned(&plan)
    }

    /// Execute a given plan as a batch of divergences. Like
    /// [`OtProblem::solve_all_planned`], whole-batch failures are
    /// replicated onto every pair slot so the result stays
    /// index-aligned.
    pub fn divergence_all_planned(&self, plan: &Plan) -> Vec<Result<DivergenceReport>> {
        let pairs = match self.effective_pairs() {
            Ok(p) => p,
            Err(e) => return err_per_pair(self.pairs.len(), e),
        };
        if plan.accelerated {
            return err_per_pair(
                pairs.len(),
                Error::Config(
                    "the accelerated solver (Alg. 2) has no divergence form; use \
                     solve_planned()"
                        .into(),
                ),
            );
        }
        let sw = Stopwatch::start();
        match annealed_schedule(plan) {
            Ok(Some(sch)) => {
                return match self.run_divergence_batch_annealed(plan, &sch, &pairs, &sw) {
                    Ok(v) => v,
                    Err(e) => err_per_pair(pairs.len(), e),
                }
            }
            Ok(None) => {}
            Err(e) => return err_per_pair(pairs.len(), e),
        }
        match self.with_divergence_kernels(plan, |k_xy, k_xx, k_yy| {
            Ok(self.run_divergence_batch(plan, k_xy, k_xx, k_yy, &pairs, &sw))
        }) {
            Ok(v) => v,
            Err(e) => err_per_pair(pairs.len(), e),
        }
    }

    // ----------------------------------------------------------------
    // Kernel construction (identical to the legacy call sites).
    // ----------------------------------------------------------------

    fn resolve_solver_pool(&self, plan: &Plan) -> Pool {
        match &self.solver_pool {
            Some(p) => p.clone(),
            // `Pool::new(0)` auto-sizes to the machine, matching the
            // knob's documented `0 = auto` convention.
            None => Pool::new(plan.solver_threads),
        }
    }

    fn resolve_solve_pool(&self, plan: &Plan) -> Pool {
        match &self.solve_pool {
            Some(p) => p.clone(),
            None => Pool::new_capped(plan.threads, 3),
        }
    }

    /// Resolve the Lemma-1 feature map: prebuilt > cache > seeded fit.
    fn resolve_map(&self, plan: &Plan, key: FeatureKey) -> Result<MapHandle<'a>> {
        if let Some(m) = self.map {
            return Ok(MapHandle::Borrowed(m));
        }
        let (mu, nu) = self.measures()?;
        let mut rng = Rng::seed_from(plan.seed);
        if let Some(cache) = self.cache {
            let radius = mu.radius().max(nu.radius());
            return Ok(MapHandle::Shared(cache.get_or_fit(
                key.dim,
                plan.epsilon,
                key.r,
                radius,
                &mut rng,
                self.metrics,
            )));
        }
        Ok(MapHandle::Shared(Arc::new(GaussianFeatureMap::fit(
            mu,
            nu,
            plan.epsilon,
            key.r,
            &mut rng,
        ))))
    }

    fn factored_from_measures(
        &self,
        plan: &Plan,
        map: &GaussianFeatureMap,
        mu: &Measure,
        nu: &Measure,
        pool: Pool,
    ) -> FactoredKernel {
        if plan.stabilized_factors {
            FactoredKernel::from_measures_stabilized_pooled(map, mu, nu, pool)
        } else {
            FactoredKernel::from_measures_pooled(map, mu, nu, pool)
        }
    }

    /// Build a Nyström kernel exactly as a shard worker would: the
    /// landmark draw (uniform or farthest-point) replays from
    /// `Rng::seed_from(plan.seed)` at the given eps, applies run on the
    /// solver pool — so the same plan builds the bit-identical kernel on
    /// every host, rung and divergence leg.
    fn nystrom_from_measures(
        &self,
        plan: &Plan,
        mu: &Measure,
        nu: &Measure,
        eps: f64,
        rank: usize,
        adaptive: bool,
        solver_pool: &Pool,
    ) -> NystromKernel {
        // With a shared landmark cache attached, hot groups skip the
        // O(r·(n+m)·d) selection: the cached indices are exactly what
        // the seeded selection would return for these fingerprinted
        // supports, so `from_landmarks` rebuilds the bit-identical
        // kernel (rust/src/coordinator/cache.rs, `LandmarkCache`).
        if let Some(cache) = self.landmarks {
            let key = LandmarkKey::new(
                mu.dim(),
                eps,
                rank,
                plan.seed,
                support_fingerprint(mu, nu),
            );
            let idx = cache.get_or_select(key, self.metrics, || {
                let mut rng = Rng::seed_from(plan.seed);
                if adaptive {
                    NystromKernel::select_landmarks_adaptive(mu, nu, rank, &mut rng)
                } else {
                    NystromKernel::select_landmarks_uniform(mu, nu, rank, &mut rng)
                }
            });
            return NystromKernel::from_landmarks(mu, nu, eps, idx.as_ref().clone(), adaptive)
                .with_pool(solver_pool.clone());
        }
        let mut rng = Rng::seed_from(plan.seed);
        let kernel = if adaptive {
            NystromKernel::from_measures_adaptive(mu, nu, eps, rank, &mut rng)
        } else {
            NystromKernel::from_measures(mu, nu, eps, rank, &mut rng)
        };
        kernel.with_pool(solver_pool.clone())
    }

    fn build_kernel(&self, plan: &Plan, solver_pool: &Pool) -> Result<BuiltKernel> {
        match plan.backend {
            Backend::Dense => {
                let (mu, nu) = self.measures()?;
                Ok(BuiltKernel::Dense(DenseKernel::from_measures(mu, nu, plan.epsilon)))
            }
            Backend::Nystrom { rank, adaptive } => {
                let (mu, nu) = self.measures()?;
                Ok(BuiltKernel::Nystrom(self.nystrom_from_measures(
                    plan,
                    mu,
                    nu,
                    plan.epsilon,
                    rank,
                    adaptive,
                    solver_pool,
                )))
            }
            Backend::Factored { rank } => match self.source {
                Source::Factors { phi_x, phi_y } => Ok(BuiltKernel::Factored(
                    FactoredKernel::from_factors(phi_x.clone(), phi_y.clone())
                        .with_pool(solver_pool.clone()),
                )),
                Source::Measures { mu, nu } => {
                    let key = plan
                        .cache_key
                        .unwrap_or_else(|| FeatureKey::new(mu.dim(), plan.epsilon, rank));
                    let map = self.resolve_map(plan, key)?;
                    Ok(BuiltKernel::Factored(self.factored_from_measures(
                        plan,
                        map.get(),
                        mu,
                        nu,
                        solver_pool.clone(),
                    )))
                }
            },
        }
    }

    /// [`Self::build_kernel`] at a specific annealing rung. The target
    /// rung (`target = true`, eps = `plan.epsilon`) resolves exactly as a
    /// direct solve — prebuilt map, shared cache, recorded cache key.
    /// Intermediate rungs are scaffolding at a *different* eps: they
    /// bypass the problem's map and cache (both are fitted at the target
    /// eps) and draw a fresh seeded map, so the rung kernel is exactly
    /// `GaussianFeatureMap::fit(mu, nu, rung_eps, r, Rng::seed_from(seed))`
    /// on every host that executes the plan.
    fn build_kernel_at(
        &self,
        plan: &Plan,
        solver_pool: &Pool,
        eps: f64,
        target: bool,
    ) -> Result<BuiltKernel> {
        if target {
            return self.build_kernel(plan, solver_pool);
        }
        let (mu, nu) = self.measures().map_err(|_| {
            Error::Config(
                "annealed plans rebuild the kernel per rung and need point-cloud \
                 measures; prebuilt factors are fixed at one eps"
                    .into(),
            )
        })?;
        match plan.backend {
            Backend::Dense => Ok(BuiltKernel::Dense(DenseKernel::from_measures(mu, nu, eps))),
            Backend::Nystrom { rank, adaptive } => {
                // Same seeded landmark draw at the rung's eps on every
                // host; the target rung lands in the kernel's gated
                // signed log view when plain arithmetic gives out.
                Ok(BuiltKernel::Nystrom(self.nystrom_from_measures(
                    plan, mu, nu, eps, rank, adaptive, solver_pool,
                )))
            }
            Backend::Factored { rank } => {
                let mut rng = Rng::seed_from(plan.seed);
                let map = GaussianFeatureMap::fit(mu, nu, eps, rank, &mut rng);
                Ok(BuiltKernel::Factored(self.factored_from_measures(
                    plan,
                    &map,
                    mu,
                    nu,
                    solver_pool.clone(),
                )))
            }
        }
    }

    /// Build the divergence kernel triple (xy, xx, yy) and hand it to
    /// `f`. One feature map serves all three — the same sharing the
    /// legacy CLI and coordinator worker hand-wired.
    fn with_divergence_kernels<T>(
        &self,
        plan: &Plan,
        f: impl FnOnce(
            &(dyn KernelOp + Sync),
            &(dyn KernelOp + Sync),
            &(dyn KernelOp + Sync),
        ) -> Result<T>,
    ) -> Result<T> {
        self.with_divergence_kernels_at(plan, plan.epsilon, true, f)
    }

    /// [`Self::with_divergence_kernels`] at a specific annealing rung;
    /// see [`Self::build_kernel_at`] for the target/intermediate split.
    fn with_divergence_kernels_at<T>(
        &self,
        plan: &Plan,
        eps: f64,
        target: bool,
        f: impl FnOnce(
            &(dyn KernelOp + Sync),
            &(dyn KernelOp + Sync),
            &(dyn KernelOp + Sync),
        ) -> Result<T>,
    ) -> Result<T> {
        let solver_pool = self.resolve_solver_pool(plan);
        match plan.backend {
            Backend::Nystrom { rank, adaptive } => {
                let (mu, nu) = self.measures()?;
                // Each leg replays its own seeded landmark draw over its
                // own union cloud, so all three kernels are deterministic
                // functions of (plan.seed, eps) on every host.
                let k_xy =
                    self.nystrom_from_measures(plan, mu, nu, eps, rank, adaptive, &solver_pool);
                let k_xx =
                    self.nystrom_from_measures(plan, mu, mu, eps, rank, adaptive, &solver_pool);
                let k_yy =
                    self.nystrom_from_measures(plan, nu, nu, eps, rank, adaptive, &solver_pool);
                f(&k_xy, &k_xx, &k_yy)
            }
            Backend::Dense => {
                let (mu, nu) = self.measures()?;
                let k_xy = DenseKernel::from_measures(mu, nu, eps);
                let k_xx = DenseKernel::from_measures(mu, mu, eps);
                let k_yy = DenseKernel::from_measures(nu, nu, eps);
                f(&k_xy, &k_xx, &k_yy)
            }
            Backend::Factored { rank } => match self.source {
                Source::Factors { phi_x, phi_y } => {
                    if !target {
                        return Err(Error::Config(
                            "annealed plans rebuild the kernel per rung and need \
                             point-cloud measures; prebuilt factors are fixed at one eps"
                                .into(),
                        ));
                    }
                    let k_xy = FactoredKernel::from_factors(phi_x.clone(), phi_y.clone())
                        .with_pool(solver_pool.clone());
                    let k_xx = FactoredKernel::from_factors(phi_x.clone(), phi_x.clone())
                        .with_pool(solver_pool.clone());
                    let k_yy = FactoredKernel::from_factors(phi_y.clone(), phi_y.clone())
                        .with_pool(solver_pool);
                    f(&k_xy, &k_xx, &k_yy)
                }
                Source::Measures { mu, nu } => {
                    // One map serves all three kernels of the rung; the
                    // intermediate-rung fit is the same seeded draw on
                    // every host (see `build_kernel_at`).
                    let (map, fresh);
                    let m: &GaussianFeatureMap = if target {
                        let key = plan
                            .cache_key
                            .unwrap_or_else(|| FeatureKey::new(mu.dim(), plan.epsilon, rank));
                        map = self.resolve_map(plan, key)?;
                        map.get()
                    } else {
                        let mut rng = Rng::seed_from(plan.seed);
                        fresh = GaussianFeatureMap::fit(mu, nu, eps, rank, &mut rng);
                        &fresh
                    };
                    let k_xy =
                        self.factored_from_measures(plan, m, mu, nu, solver_pool.clone());
                    let k_xx =
                        self.factored_from_measures(plan, m, mu, mu, solver_pool.clone());
                    let k_yy = self.factored_from_measures(plan, m, nu, nu, solver_pool);
                    f(&k_xy, &k_xx, &k_yy)
                }
            },
        }
    }

    // ----------------------------------------------------------------
    // Solve routing (the bitwise contract lives here).
    // ----------------------------------------------------------------

    fn run_single<K: KernelOp + ?Sized>(
        &self,
        plan: &Plan,
        kernel: &K,
        a: &[f32],
        b: &[f32],
    ) -> Result<Solution> {
        let cfg = plan.sinkhorn_config();
        let sw = Stopwatch::start();
        if plan.accelerated {
            let sol = sinkhorn_accelerated(kernel, a, b, &cfg)?;
            return Ok(Solution::from_accel(sol, us(&sw)));
        }
        match plan.domain {
            Domain::Plain => sinkhorn(kernel, a, b, &cfg)
                .map(|s| Solution::from_sinkhorn(s, false, us(&sw))),
            Domain::AutoEscalate => sinkhorn_stabilized(kernel, a, b, &cfg)
                .map(|(s, esc)| Solution::from_sinkhorn(s, esc, us(&sw))),
            Domain::LogDomain => {
                let log = kernel.as_log_kernel().ok_or_else(|| {
                    Error::Config(format!("kernel {} has no log-domain view", kernel.label()))
                })?;
                sinkhorn_log_domain(log, a, b, &cfg)
                    .map(|s| Solution::from_sinkhorn(s, false, us(&sw)))
            }
        }
    }

    fn run_batch<K: KernelOp + ?Sized>(
        &self,
        plan: &Plan,
        kernel: &K,
        pairs: &[(&[f32], &[f32])],
    ) -> Vec<Result<Solution>> {
        let cfg = plan.sinkhorn_config();
        if plan.accelerated {
            // The planner rejects this combination; guard hand-crafted
            // (deserialised) plans the same way instead of silently
            // running the wrong solver.
            return pairs
                .iter()
                .map(|_| {
                    Err(Error::Config(
                        "accelerated plans are single-pair; use solve_planned()".into(),
                    ))
                })
                .collect();
        }
        let width = plan.batch_width.max(1);
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(width) {
            let sw = Stopwatch::start();
            let results = batch_by_domain(kernel, chunk, &cfg, plan.domain);
            let wall = us(&sw);
            out.extend(
                results
                    .into_iter()
                    .map(|r| r.map(|(s, esc)| Solution::from_sinkhorn(s, esc, wall))),
            );
        }
        out
    }

    #[allow(clippy::too_many_arguments)]
    fn run_divergence<K: KernelOp + Sync + ?Sized>(
        &self,
        plan: &Plan,
        k_xy: &K,
        k_xx: &K,
        k_yy: &K,
        a: &[f32],
        b: &[f32],
        sw: &Stopwatch,
    ) -> Result<DivergenceReport> {
        let cfg = plan.sinkhorn_config();
        let solve_pool = self.resolve_solve_pool(plan);
        // One closure per transport problem, all routed by the planned
        // domain; the log view is taken *inside* the worker so the
        // non-Send trait object never crosses threads.
        let solve_one = |k: &K, a: &[f32], b: &[f32]| -> Result<Solution> {
            let sw = Stopwatch::start();
            match plan.domain {
                Domain::Plain => {
                    sinkhorn(k, a, b, &cfg).map(|s| Solution::from_sinkhorn(s, false, us(&sw)))
                }
                Domain::AutoEscalate => sinkhorn_stabilized(k, a, b, &cfg)
                    .map(|(s, esc)| Solution::from_sinkhorn(s, esc, us(&sw))),
                Domain::LogDomain => {
                    let log = k.as_log_kernel().ok_or_else(|| {
                        Error::Config(format!("kernel {} has no log-domain view", k.label()))
                    })?;
                    sinkhorn_log_domain(log, a, b, &cfg)
                        .map(|s| Solution::from_sinkhorn(s, false, us(&sw)))
                }
            }
        };
        // The self solves take the one-dual symmetric fixed point when
        // the plan asks for it, same domain routing as above.
        let solve_self = |k: &K, w: &[f32]| -> Result<Solution> {
            if !plan.symmetric_self_solves {
                return solve_one(k, w, w);
            }
            let sw = Stopwatch::start();
            match plan.domain {
                Domain::Plain => sinkhorn_symmetric(k, w, &cfg)
                    .map(|s| Solution::from_sinkhorn(s, false, us(&sw))),
                Domain::AutoEscalate => sinkhorn_symmetric_stabilized(k, w, &cfg)
                    .map(|(s, esc)| Solution::from_sinkhorn(s, esc, us(&sw))),
                Domain::LogDomain => {
                    let log = k.as_log_kernel().ok_or_else(|| {
                        Error::Config(format!("kernel {} has no log-domain view", k.label()))
                    })?;
                    sinkhorn_symmetric_log(log, w, &cfg)
                        .map(|s| Solution::from_sinkhorn(s, false, us(&sw)))
                }
            }
        };
        let (r_xy, r_xx, r_yy) = solve_pool.join3(
            || solve_one(k_xy, a, b),
            || solve_self(k_xx, a),
            || solve_self(k_yy, b),
        );
        // Error priority matches the legacy path: xy, then xx, then yy.
        Ok(DivergenceReport::assemble(r_xy?, r_xx?, r_yy?, us(sw)))
    }

    fn run_divergence_batch<K: KernelOp + Sync + ?Sized>(
        &self,
        plan: &Plan,
        k_xy: &K,
        k_xx: &K,
        k_yy: &K,
        pairs: &[(&[f32], &[f32])],
        sw: &Stopwatch,
    ) -> Vec<Result<DivergenceReport>> {
        let cfg = plan.sinkhorn_config();
        let width = plan.batch_width.max(1);
        let solve_pool = self.resolve_solve_pool(plan);
        let xx_pairs: Vec<(&[f32], &[f32])> = pairs.iter().map(|&(a, _)| (a, a)).collect();
        let yy_pairs: Vec<(&[f32], &[f32])> = pairs.iter().map(|&(_, b)| (b, b)).collect();
        let run = |k: &K, prs: &[(&[f32], &[f32])]| -> Vec<Result<(SinkhornSolution, bool)>> {
            let mut out = Vec::with_capacity(prs.len());
            for chunk in prs.chunks(width) {
                out.extend(batch_by_domain(k, chunk, &cfg, plan.domain));
            }
            out
        };
        // Symmetric self solves have no fused batch form (one dual per
        // pair already halves the state); they run pair-at-a-time.
        let run_self = |k: &K, prs: &[(&[f32], &[f32])]| -> Vec<Result<(SinkhornSolution, bool)>> {
            if !plan.symmetric_self_solves {
                return run(k, prs);
            }
            prs.iter()
                .map(|&(w, _)| match plan.domain {
                    Domain::Plain => sinkhorn_symmetric(k, w, &cfg).map(|s| (s, false)),
                    Domain::AutoEscalate => sinkhorn_symmetric_stabilized(k, w, &cfg),
                    Domain::LogDomain => {
                        let log = k.as_log_kernel().ok_or_else(|| {
                            Error::Config(format!(
                                "kernel {} has no log-domain view",
                                k.label()
                            ))
                        })?;
                        sinkhorn_symmetric_log(log, w, &cfg).map(|s| (s, false))
                    }
                })
                .collect()
        };
        let (r_xy, r_xx, r_yy) = solve_pool.join3(
            || run(k_xy, pairs),
            || run_self(k_xx, &xx_pairs),
            || run_self(k_yy, &yy_pairs),
        );
        let wall = us(sw);
        r_xy.into_iter()
            .zip(r_xx)
            .zip(r_yy)
            .map(|((xy, xx), yy)| {
                let (s_xy, e_xy) = xy?;
                let (s_xx, e_xx) = xx?;
                let (s_yy, e_yy) = yy?;
                Ok(DivergenceReport::assemble(
                    Solution::from_sinkhorn(s_xy, e_xy, wall),
                    Solution::from_sinkhorn(s_xx, e_xx, wall),
                    Solution::from_sinkhorn(s_yy, e_yy, wall),
                    wall,
                ))
            })
            .collect()
    }

    // ----------------------------------------------------------------
    // Annealed execution: the eps-schedule rung loop. Each rung rebuilds
    // the kernel at its eps (eps is baked into kernels at construction)
    // and warm-starts the solve from the previous rung's f64 dual.
    // ----------------------------------------------------------------

    fn run_single_annealed(
        &self,
        plan: &Plan,
        sch: &crate::sinkhorn::EpsSchedule,
        a: &[f32],
        b: &[f32],
        solver_pool: &Pool,
    ) -> Result<Solution> {
        let sw = Stopwatch::start();
        let rungs = sch.rungs(plan.epsilon);
        let mut warm: Option<Vec<f64>> = None;
        let mut rung_iters = Vec::with_capacity(rungs.len());
        let mut last: Option<(SinkhornSolution, bool)> = None;
        for (i, &eps) in rungs.iter().enumerate() {
            let target = i + 1 == rungs.len();
            let ws = match self.build_kernel_at(plan, solver_pool, eps, target)? {
                BuiltKernel::Dense(k) => solve_rung(&k, a, b, plan, eps, warm.as_deref())?,
                BuiltKernel::Factored(k) => solve_rung(&k, a, b, plan, eps, warm.as_deref())?,
                BuiltKernel::Nystrom(k) => solve_rung(&k, a, b, plan, eps, warm.as_deref())?,
            };
            rung_iters.push(ws.solution.iterations);
            let WarmSolve { solution, escalated, alpha } = ws;
            warm = Some(alpha);
            last = Some((solution, escalated));
        }
        let (solution, escalated) = last.expect("a schedule always has >= 1 rung");
        let mut sol = Solution::from_sinkhorn(solution, escalated, us(&sw));
        sol.rung_iterations = rung_iters;
        Ok(sol)
    }

    fn run_batch_annealed(
        &self,
        plan: &Plan,
        sch: &crate::sinkhorn::EpsSchedule,
        pairs: &[(&[f32], &[f32])],
        solver_pool: &Pool,
    ) -> Vec<Result<Solution>> {
        let sw = Stopwatch::start();
        let rungs = sch.rungs(plan.epsilon);
        let width = plan.batch_width.max(1);
        let mut out: Vec<Option<Result<Solution>>> = (0..pairs.len()).map(|_| None).collect();
        let mut rung_iters: Vec<Vec<usize>> =
            vec![Vec::with_capacity(rungs.len()); pairs.len()];
        // Pairs whose every rung so far succeeded, with their warm duals;
        // a pair failing a rung takes its error and leaves the chain
        // without poisoning its batch-mates.
        let mut alive: Vec<usize> = (0..pairs.len()).collect();
        let mut warms: Vec<Vec<f64>> = Vec::new();
        for (i, &eps) in rungs.iter().enumerate() {
            if alive.is_empty() {
                break;
            }
            let target = i + 1 == rungs.len();
            let kernel = match self.build_kernel_at(plan, solver_pool, eps, target) {
                Ok(k) => k,
                Err(e) => {
                    let msg = match e {
                        Error::Config(m) => m,
                        other => other.to_string(),
                    };
                    for &p in &alive {
                        out[p] = Some(Err(Error::Config(msg.clone())));
                    }
                    alive.clear();
                    break;
                }
            };
            let sub: Vec<(&[f32], &[f32])> = alive.iter().map(|&p| pairs[p]).collect();
            let warm_opt = if i == 0 { None } else { Some(&warms[..]) };
            let results = match &kernel {
                BuiltKernel::Dense(k) => batch_rung(k, &sub, plan, eps, warm_opt, width),
                BuiltKernel::Factored(k) => batch_rung(k, &sub, plan, eps, warm_opt, width),
                BuiltKernel::Nystrom(k) => batch_rung(k, &sub, plan, eps, warm_opt, width),
            };
            let mut next_alive = Vec::with_capacity(alive.len());
            let mut next_warms = Vec::with_capacity(alive.len());
            for (j, r) in results.into_iter().enumerate() {
                let p = alive[j];
                match r {
                    Ok(ws) => {
                        rung_iters[p].push(ws.solution.iterations);
                        if target {
                            let mut sol =
                                Solution::from_sinkhorn(ws.solution, ws.escalated, us(&sw));
                            sol.rung_iterations = std::mem::take(&mut rung_iters[p]);
                            out[p] = Some(Ok(sol));
                        } else {
                            next_alive.push(p);
                            next_warms.push(ws.alpha);
                        }
                    }
                    Err(e) => out[p] = Some(Err(e)),
                }
            }
            alive = next_alive;
            warms = next_warms;
        }
        out.into_iter()
            .map(|o| o.expect("every pair ends resolved or errored"))
            .collect()
    }

    fn run_divergence_annealed(
        &self,
        plan: &Plan,
        sch: &crate::sinkhorn::EpsSchedule,
        a: &[f32],
        b: &[f32],
        sw: &Stopwatch,
    ) -> Result<DivergenceReport> {
        let rungs = sch.rungs(plan.epsilon);
        let solve_pool = self.resolve_solve_pool(plan);
        let mut w_xy: Option<Vec<f64>> = None;
        let mut w_xx: Option<Vec<f64>> = None;
        let mut w_yy: Option<Vec<f64>> = None;
        let mut it_xy = Vec::with_capacity(rungs.len());
        let mut it_xx = Vec::with_capacity(rungs.len());
        let mut it_yy = Vec::with_capacity(rungs.len());
        let mut fin: Option<(WarmSolve, WarmSolve, WarmSolve)> = None;
        for (i, &eps) in rungs.iter().enumerate() {
            let target = i + 1 == rungs.len();
            let (r_xy, r_xx, r_yy) =
                self.with_divergence_kernels_at(plan, eps, target, |k_xy, k_xx, k_yy| {
                    Ok(solve_pool.join3(
                        || solve_rung(k_xy, a, b, plan, eps, w_xy.as_deref()),
                        || solve_self_rung(k_xx, a, plan, eps, w_xx.as_deref()),
                        || solve_self_rung(k_yy, b, plan, eps, w_yy.as_deref()),
                    ))
                })?;
            // Error priority matches the legacy path: xy, then xx, then yy.
            let (ws_xy, ws_xx, ws_yy) = (r_xy?, r_xx?, r_yy?);
            it_xy.push(ws_xy.solution.iterations);
            it_xx.push(ws_xx.solution.iterations);
            it_yy.push(ws_yy.solution.iterations);
            if target {
                fin = Some((ws_xy, ws_xx, ws_yy));
            } else {
                w_xy = Some(ws_xy.alpha);
                w_xx = Some(ws_xx.alpha);
                w_yy = Some(ws_yy.alpha);
            }
        }
        let (ws_xy, ws_xx, ws_yy) = fin.expect("a schedule always has >= 1 rung");
        let wall = us(sw);
        let mut s_xy = Solution::from_sinkhorn(ws_xy.solution, ws_xy.escalated, wall);
        let mut s_xx = Solution::from_sinkhorn(ws_xx.solution, ws_xx.escalated, wall);
        let mut s_yy = Solution::from_sinkhorn(ws_yy.solution, ws_yy.escalated, wall);
        s_xy.rung_iterations = it_xy;
        s_xx.rung_iterations = it_xx;
        s_yy.rung_iterations = it_yy;
        Ok(DivergenceReport::assemble(s_xy, s_xx, s_yy, wall))
    }

    fn run_divergence_batch_annealed(
        &self,
        plan: &Plan,
        sch: &crate::sinkhorn::EpsSchedule,
        pairs: &[(&[f32], &[f32])],
        sw: &Stopwatch,
    ) -> Result<Vec<Result<DivergenceReport>>> {
        let rungs = sch.rungs(plan.epsilon);
        let width = plan.batch_width.max(1);
        let solve_pool = self.resolve_solve_pool(plan);
        let mut out: Vec<Option<Result<DivergenceReport>>> =
            (0..pairs.len()).map(|_| None).collect();
        let mut iters: Vec<[Vec<usize>; 3]> = (0..pairs.len())
            .map(|_| [Vec::new(), Vec::new(), Vec::new()])
            .collect();
        let mut alive: Vec<usize> = (0..pairs.len()).collect();
        // Per-role warm duals, aligned with `alive`.
        let mut warms: [Vec<Vec<f64>>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for (i, &eps) in rungs.iter().enumerate() {
            if alive.is_empty() {
                break;
            }
            let target = i + 1 == rungs.len();
            let xy_pairs: Vec<(&[f32], &[f32])> =
                alive.iter().map(|&p| pairs[p]).collect();
            let xx_pairs: Vec<(&[f32], &[f32])> =
                alive.iter().map(|&p| (pairs[p].0, pairs[p].0)).collect();
            let yy_pairs: Vec<(&[f32], &[f32])> =
                alive.iter().map(|&p| (pairs[p].1, pairs[p].1)).collect();
            let (w_xy, w_xx, w_yy) = if i == 0 {
                (None, None, None)
            } else {
                (Some(&warms[0][..]), Some(&warms[1][..]), Some(&warms[2][..]))
            };
            let (r_xy, r_xx, r_yy) =
                self.with_divergence_kernels_at(plan, eps, target, |k_xy, k_xx, k_yy| {
                    Ok(solve_pool.join3(
                        || batch_rung(k_xy, &xy_pairs, plan, eps, w_xy, width),
                        || batch_self_rung(k_xx, &xx_pairs, plan, eps, w_xx, width),
                        || batch_self_rung(k_yy, &yy_pairs, plan, eps, w_yy, width),
                    ))
                })?;
            let mut next_alive = Vec::with_capacity(alive.len());
            let mut next_warms: [Vec<Vec<f64>>; 3] = [Vec::new(), Vec::new(), Vec::new()];
            for (j, ((xy, xx), yy)) in
                r_xy.into_iter().zip(r_xx).zip(r_yy).enumerate()
            {
                let p = alive[j];
                // Error priority: xy, then xx, then yy.
                let trio = (|| Ok::<_, Error>((xy?, xx?, yy?)))();
                match trio {
                    Ok((ws_xy, ws_xx, ws_yy)) => {
                        iters[p][0].push(ws_xy.solution.iterations);
                        iters[p][1].push(ws_xx.solution.iterations);
                        iters[p][2].push(ws_yy.solution.iterations);
                        if target {
                            let wall = us(sw);
                            let mut s_xy = Solution::from_sinkhorn(
                                ws_xy.solution,
                                ws_xy.escalated,
                                wall,
                            );
                            let mut s_xx = Solution::from_sinkhorn(
                                ws_xx.solution,
                                ws_xx.escalated,
                                wall,
                            );
                            let mut s_yy = Solution::from_sinkhorn(
                                ws_yy.solution,
                                ws_yy.escalated,
                                wall,
                            );
                            let [i_xy, i_xx, i_yy] = std::mem::take(&mut iters[p]);
                            s_xy.rung_iterations = i_xy;
                            s_xx.rung_iterations = i_xx;
                            s_yy.rung_iterations = i_yy;
                            out[p] =
                                Some(Ok(DivergenceReport::assemble(s_xy, s_xx, s_yy, wall)));
                        } else {
                            next_alive.push(p);
                            next_warms[0].push(ws_xy.alpha);
                            next_warms[1].push(ws_xx.alpha);
                            next_warms[2].push(ws_yy.alpha);
                        }
                    }
                    Err(e) => out[p] = Some(Err(e)),
                }
            }
            alive = next_alive;
            warms = next_warms;
        }
        Ok(out
            .into_iter()
            .map(|o| o.expect("every pair ends resolved or errored"))
            .collect())
    }
}

/// Route one batched chunk by the planned domain. `Plain` and
/// `AutoEscalate` share [`solve_batch_stabilized`] (whose behaviour is
/// gated by `cfg.stabilize`, exactly like the sequential
/// `sinkhorn_stabilized`); `LogDomain` goes straight to the batched
/// log-domain solver through the kernel's log view.
fn batch_by_domain<K: KernelOp + ?Sized>(
    kernel: &K,
    chunk: &[(&[f32], &[f32])],
    cfg: &crate::config::SinkhornConfig,
    domain: Domain,
) -> Vec<Result<(SinkhornSolution, bool)>> {
    match domain {
        Domain::Plain | Domain::AutoEscalate => solve_batch_stabilized(kernel, chunk, cfg),
        Domain::LogDomain => match kernel.as_log_kernel() {
            Some(log) => solve_batch_log_domain(log, chunk, cfg)
                .into_iter()
                .map(|r| r.map(|s| (s, false)))
                .collect(),
            None => chunk
                .iter()
                .map(|_| {
                    Err(Error::Config(format!(
                        "kernel {} has no log-domain view",
                        kernel.label()
                    )))
                })
                .collect(),
        },
    }
}

/// The plan's annealing schedule, if any, validated against the backend.
/// Plans built by [`OtProblem::plan`] never pair a schedule with an
/// incompatible backend, but deserialized plans are arbitrary documents.
fn annealed_schedule(plan: &Plan) -> Result<Option<EpsSchedule>> {
    match plan.schedule {
        None => Ok(None),
        Some(_) if plan.accelerated => Err(Error::Config(
            "plan pairs an eps schedule with the accelerated solver; \
             accelerated plans do not anneal"
                .into(),
        )),
        Some(sch) => Ok(Some(sch)),
    }
}

/// The solve domain for one annealing rung. Early (large-eps) rungs of
/// an `AutoEscalate` plan run plain — that is the entire point of
/// annealing — but once the *remaining* eps drop from the schedule start
/// would overflow `exp`, plain arithmetic is hopeless and the rung goes
/// straight to the log domain instead of burning a failed plain pass.
/// Pure plan-data arithmetic, so every host picks the same domain.
fn rung_domain(plan: &Plan, eps: f64) -> Domain {
    let hopeless = match plan.schedule {
        Some(sch) => sch.eps_start / (4.0 * eps) >= UNDERFLOW_LOG_SPREAD,
        None => false,
    };
    match plan.domain {
        Domain::Plain => Domain::Plain,
        Domain::LogDomain => Domain::LogDomain,
        Domain::AutoEscalate => {
            if hopeless {
                Domain::LogDomain
            } else {
                Domain::AutoEscalate
            }
        }
    }
}

/// The per-rung solver config: the plan's config with the rung's eps
/// patched in and stabilisation tied to the rung's domain.
fn rung_config(plan: &Plan, eps: f64, domain: Domain) -> SinkhornConfig {
    SinkhornConfig {
        epsilon: eps,
        stabilize: domain == Domain::AutoEscalate,
        ..plan.sinkhorn_config()
    }
}

/// One two-sided rung solve, routed by the rung's domain.
fn solve_rung<K: KernelOp + ?Sized>(
    kernel: &K,
    a: &[f32],
    b: &[f32],
    plan: &Plan,
    eps: f64,
    warm: Option<&[f64]>,
) -> Result<WarmSolve> {
    let domain = rung_domain(plan, eps);
    let cfg = rung_config(plan, eps, domain);
    match domain {
        Domain::Plain => sinkhorn_warm(kernel, a, b, &cfg, warm),
        Domain::AutoEscalate => sinkhorn_stabilized_warm(kernel, a, b, &cfg, warm),
        Domain::LogDomain => match kernel.as_log_kernel() {
            Some(log) => sinkhorn_log_domain_warm(log, a, b, &cfg, warm),
            None => Err(Error::Config(format!(
                "kernel {} has no log-domain view",
                kernel.label()
            ))),
        },
    }
}

/// One self-solve rung W(w, w): the symmetric fixed point when the plan
/// asks for it, the plain two-sided solve otherwise.
fn solve_self_rung<K: KernelOp + ?Sized>(
    kernel: &K,
    w: &[f32],
    plan: &Plan,
    eps: f64,
    warm: Option<&[f64]>,
) -> Result<WarmSolve> {
    if !plan.symmetric_self_solves {
        return solve_rung(kernel, w, w, plan, eps, warm);
    }
    let domain = rung_domain(plan, eps);
    let cfg = rung_config(plan, eps, domain);
    match domain {
        Domain::Plain => sinkhorn_symmetric_warm(kernel, w, &cfg, warm),
        Domain::AutoEscalate => sinkhorn_symmetric_stabilized_warm(kernel, w, &cfg, warm),
        Domain::LogDomain => match kernel.as_log_kernel() {
            Some(log) => sinkhorn_symmetric_log_warm(log, w, &cfg, warm),
            None => Err(Error::Config(format!(
                "kernel {} has no log-domain view",
                kernel.label()
            ))),
        },
    }
}

/// One batched rung over `pairs`, chunked by `width` exactly like the
/// direct batched path, with per-pair warm duals index-aligned to
/// `pairs`. `Plain` and `AutoEscalate` share the stabilized batch core
/// (gated by `cfg.stabilize`, so a `Plain` rung never escalates),
/// mirroring [`batch_by_domain`].
fn batch_rung<K: KernelOp + ?Sized>(
    kernel: &K,
    pairs: &[(&[f32], &[f32])],
    plan: &Plan,
    eps: f64,
    warms: Option<&[Vec<f64>]>,
    width: usize,
) -> Vec<Result<WarmSolve>> {
    let domain = rung_domain(plan, eps);
    let cfg = rung_config(plan, eps, domain);
    let mut out = Vec::with_capacity(pairs.len());
    for (ci, chunk) in pairs.chunks(width).enumerate() {
        let warm_chunk = warms.map(|w| &w[ci * width..ci * width + chunk.len()]);
        let results = match domain {
            Domain::Plain | Domain::AutoEscalate => {
                solve_batch_stabilized_warm(kernel, chunk, &cfg, warm_chunk)
            }
            Domain::LogDomain => match kernel.as_log_kernel() {
                Some(log) => solve_batch_log_domain_warm(log, chunk, &cfg, warm_chunk),
                None => chunk
                    .iter()
                    .map(|_| {
                        Err(Error::Config(format!(
                            "kernel {} has no log-domain view",
                            kernel.label()
                        )))
                    })
                    .collect(),
            },
        };
        out.extend(results);
    }
    out
}

/// One batched self-solve rung over `(w, w)` pairs. Symmetric solves
/// have no batched core (one dual vector per pair is already the cheap
/// path), so they run sequentially per pair; the two-sided fallback
/// reuses [`batch_rung`].
fn batch_self_rung<K: KernelOp + ?Sized>(
    kernel: &K,
    pairs: &[(&[f32], &[f32])],
    plan: &Plan,
    eps: f64,
    warms: Option<&[Vec<f64>]>,
    width: usize,
) -> Vec<Result<WarmSolve>> {
    if !plan.symmetric_self_solves {
        return batch_rung(kernel, pairs, plan, eps, warms, width);
    }
    pairs
        .iter()
        .enumerate()
        .map(|(j, &(w, _))| {
            solve_self_rung(kernel, w, plan, eps, warms.map(|x| &x[j][..]))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{BackendPref, DomainChoice};
    use crate::data;

    fn clouds(n: usize) -> (Measure, Measure) {
        let mut rng = Rng::seed_from(3);
        data::gaussian_blobs(n, &mut rng)
    }

    #[test]
    fn solve_and_divergence_roundtrip_through_a_serialised_plan() {
        // The cross-host story in miniature: plan, ship as JSON, decode,
        // execute — identical to executing the original plan.
        let (mu, nu) = clouds(40);
        let problem = OtProblem::new(&mu, &nu).epsilon(0.5).rank(32).seed(9);
        let plan = problem.plan().unwrap();
        let wire = Plan::from_json(&plan.to_json()).unwrap();
        assert_eq!(wire, plan);
        let direct = problem.solve_planned(&plan).unwrap();
        let shipped = problem.solve_planned(&wire).unwrap();
        assert_eq!(direct.objective.to_bits(), shipped.objective.to_bits());
        let d1 = problem.divergence_planned(&plan).unwrap();
        let d2 = problem.divergence_planned(&wire).unwrap();
        assert_eq!(d1.divergence.to_bits(), d2.divergence.to_bits());
    }

    #[test]
    fn solve_rejects_multi_pair_problems() {
        let (mu, nu) = clouds(20);
        let a = vec![0.05f32; 20];
        let pairs: Vec<(&[f32], &[f32])> = vec![(&a[..], &a[..]), (&a[..], &a[..])];
        let p = OtProblem::new(&mu, &nu).rank(8).weight_pairs(&pairs);
        assert!(matches!(p.solve(), Err(Error::Config(_))));
        assert!(matches!(p.divergence(), Err(Error::Config(_))));
        assert_eq!(p.solve_all().len(), 2);
    }

    #[test]
    fn accelerated_divergence_is_a_typed_error() {
        // Alg. 2 has no three-solve divergence form; it must never
        // silently run Alg. 1 instead.
        let (mu, nu) = clouds(20);
        let p = OtProblem::new(&mu, &nu).rank(8).accelerated();
        assert!(p.solve().is_ok());
        assert!(matches!(p.divergence(), Err(Error::Config(_))));
        let w = vec![0.05f32; 20];
        let pairs: Vec<(&[f32], &[f32])> = vec![(&w[..], &w[..]), (&w[..], &w[..])];
        let p2 = OtProblem::new(&mu, &nu).rank(8).weight_pairs(&pairs).accelerated();
        let reports = p2.divergence_all();
        assert_eq!(reports.len(), 2, "errors stay index-aligned with the pairs");
        assert!(reports.iter().all(|r| matches!(r, Err(Error::Config(_)))));
    }

    #[test]
    fn nystrom_executes_solve_and_divergence_end_to_end() {
        // eps = 5.0 with rank ~ n/3 is the regime where Nyström is known
        // accurate and positive (`nystrom_accurate_at_large_eps`). The
        // old `Error::Config` walls are gone: both the single solve and
        // the three-leg divergence run on this backend.
        let (mu, nu) = clouds(30);
        let p = OtProblem::new(&mu, &nu).epsilon(5.0).nystrom(10);
        assert!(p.solve().is_ok());
        let d = p.divergence().unwrap();
        assert!(d.divergence.is_finite());
        // The adaptive arm takes the identical paths.
        let pa = OtProblem::new(&mu, &nu)
            .epsilon(5.0)
            .backend(BackendPref::Nystrom { rank: 10, adaptive: true });
        assert!(pa.solve().is_ok());
        let da = pa.divergence().unwrap();
        assert!(da.divergence.is_finite());
    }

    #[test]
    fn nystrom_annealed_solve_matches_direct_at_the_target_eps() {
        // The annealed driver now refits the Nyström kernel at each
        // rung's eps from the plan seed; staying in the flat regime
        // (eps = 5.0, generous rank) keeps every rung positive.
        let (mu, nu) = clouds(40);
        let base = || OtProblem::new(&mu, &nu).epsilon(5.0).nystrom(16).seed(5);
        let direct = base().anneal(false).solve().unwrap();
        let annealed = base().anneal(true).solve().unwrap();
        assert!(
            annealed.rung_iterations.len() > 1,
            "an annealed nystrom solve records one count per rung"
        );
        let rel = ((annealed.objective - direct.objective) / direct.objective).abs();
        assert!(rel < 1e-2, "annealed {} vs direct {}", annealed.objective, direct.objective);
    }

    #[test]
    fn solution_reports_the_executed_arm_and_wall_clock() {
        let (mu, nu) = clouds(30);
        let sol = OtProblem::new(&mu, &nu).epsilon(0.5).rank(16).solve().unwrap();
        assert_eq!(sol.simd_arm, crate::linalg::simd::active_level().label());
        assert!(sol.objective.is_finite());
        assert!(!sol.escalated);
    }

    #[test]
    fn planned_log_domain_is_not_reported_as_escalation() {
        let (mu, nu) = clouds(25);
        let sol = OtProblem::new(&mu, &nu)
            .epsilon(0.5)
            .rank(16)
            .domain(DomainChoice::LogDomain)
            .solve()
            .unwrap();
        assert!(!sol.escalated);
        assert!(sol.objective.is_finite());
    }

    #[test]
    fn feature_cache_is_honoured_with_metrics() {
        use crate::coordinator::cache::FeatureCache;
        use crate::metrics::Registry;
        let (mu, nu) = clouds(30);
        let cache = FeatureCache::new(4);
        let metrics = Registry::default();
        for _ in 0..3 {
            OtProblem::new(&mu, &nu)
                .epsilon(0.5)
                .rank(16)
                .feature_cache(&cache)
                .metrics(&metrics)
                .solve()
                .unwrap();
        }
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 2);
        assert_eq!(metrics.counter("service.feature_cache.hits").get(), 2);
    }

    #[test]
    fn landmark_cache_is_honoured_and_preserves_the_answer() {
        use crate::coordinator::cache::LandmarkCache;
        use crate::metrics::Registry;
        let (mu, nu) = clouds(40);
        let base = || OtProblem::new(&mu, &nu).epsilon(5.0).nystrom(16).seed(5).anneal(false);
        let uncached = base().solve().unwrap();
        let cache = LandmarkCache::new(4);
        let metrics = Registry::default();
        let mut objectives = Vec::new();
        for _ in 0..3 {
            let sol =
                base().landmark_cache(&cache).metrics(&metrics).solve().unwrap();
            objectives.push(sol.objective);
        }
        assert_eq!(cache.misses(), 1, "one selection, then reuse");
        assert_eq!(cache.hits(), 2);
        assert_eq!(metrics.counter("service.landmark_cache.hits").get(), 2);
        assert_eq!(metrics.counter("service.landmark_cache.misses").get(), 1);
        // Cached landmark indices rebuild the same kernel: bit-identical
        // objectives across cached repeats, and agreement with the
        // seeded uncached path that picks the same indices.
        assert_eq!(objectives[0].to_bits(), objectives[1].to_bits());
        assert_eq!(objectives[1].to_bits(), objectives[2].to_bits());
        assert_eq!(objectives[0].to_bits(), uncached.objective.to_bits());
    }

    #[test]
    fn annealed_solve_matches_direct_at_the_target_eps() {
        // The schedule only changes *how* the target rung is reached;
        // the answer must agree with a direct solve at the same eps.
        let (mu, nu) = clouds(60);
        let base = || OtProblem::new(&mu, &nu).epsilon(0.3).rank(32).seed(5);
        let direct = base().anneal(false).solve().unwrap();
        let annealed = base().anneal(true).solve().unwrap();
        assert!(
            annealed.rung_iterations.len() > 1,
            "an annealed solve records one count per rung"
        );
        assert_eq!(
            *annealed.rung_iterations.last().unwrap(),
            annealed.iterations,
            "`iterations` is the target-rung count"
        );
        assert!(annealed.total_iterations() >= annealed.iterations);
        assert!(direct.rung_iterations.is_empty());
        let rel = ((annealed.objective - direct.objective) / direct.objective).abs();
        assert!(rel < 1e-3, "annealed {} vs direct {}", annealed.objective, direct.objective);
    }

    #[test]
    fn annealed_plan_roundtrips_through_json_bitwise() {
        // The schedule rides the Plan; a worker decoding the document
        // must anneal through bitwise-identical rungs.
        let (mu, nu) = clouds(40);
        let problem = OtProblem::new(&mu, &nu).epsilon(0.3).rank(24).seed(7).anneal(true);
        let plan = problem.plan().unwrap();
        assert!(plan.schedule.is_some());
        let wire = Plan::from_json(&plan.to_json()).unwrap();
        assert_eq!(wire, plan);
        let local = problem.solve_planned(&plan).unwrap();
        let shipped = problem.solve_planned(&wire).unwrap();
        assert_eq!(local.objective.to_bits(), shipped.objective.to_bits());
        assert_eq!(local.rung_iterations, shipped.rung_iterations);
        let d1 = problem.divergence_planned(&plan).unwrap();
        let d2 = problem.divergence_planned(&wire).unwrap();
        assert_eq!(d1.divergence.to_bits(), d2.divergence.to_bits());
    }

    #[test]
    fn annealed_batch_matches_single_annealed_solves() {
        let (mu, nu) = clouds(30);
        let a = vec![1.0f32 / 30.0; 30];
        let pairs: Vec<(&[f32], &[f32])> = vec![(&a[..], &a[..]); 3];
        let p = OtProblem::new(&mu, &nu)
            .epsilon(0.3)
            .rank(16)
            .seed(3)
            .anneal(true)
            .weight_pairs(&pairs);
        let batch = p.solve_all();
        let single = OtProblem::new(&mu, &nu)
            .epsilon(0.3)
            .rank(16)
            .seed(3)
            .anneal(true)
            .weights(&a, &a)
            .solve()
            .unwrap();
        for sol in batch {
            let sol = sol.unwrap();
            assert_eq!(sol.objective.to_bits(), single.objective.to_bits());
            assert_eq!(sol.rung_iterations, single.rung_iterations);
        }
    }

    #[test]
    fn symmetric_self_solves_match_two_sided_divergence() {
        // The one-dual fixed point reaches the same self-transport
        // objective as the full two-sided solve (up to the solver
        // tolerance), so the debiased divergence agrees too.
        let (mu, nu) = clouds(50);
        let base = || OtProblem::new(&mu, &nu).epsilon(0.4).rank(32).seed(11);
        let two_sided = base().symmetric_self_solves(false).divergence().unwrap();
        let symmetric = base().symmetric_self_solves(true).divergence().unwrap();
        assert_eq!(
            two_sided.xy.objective.to_bits(),
            symmetric.xy.objective.to_bits(),
            "the cross solve is untouched by the self-solve strategy"
        );
        let diff = (two_sided.divergence - symmetric.divergence).abs();
        let scale = two_sided.divergence.abs().max(1e-6);
        assert!(
            diff / scale < 5e-2,
            "two-sided {} vs symmetric {}",
            two_sided.divergence,
            symmetric.divergence
        );
    }

    #[test]
    fn annealed_divergence_batch_matches_single() {
        let (mu, nu) = clouds(30);
        let a = vec![1.0f32 / 30.0; 30];
        let pairs: Vec<(&[f32], &[f32])> = vec![(&a[..], &a[..]); 2];
        let p = OtProblem::new(&mu, &nu)
            .epsilon(0.3)
            .rank(16)
            .seed(13)
            .anneal(true)
            .weight_pairs(&pairs);
        let reports = p.divergence_all();
        let single = OtProblem::new(&mu, &nu)
            .epsilon(0.3)
            .rank(16)
            .seed(13)
            .anneal(true)
            .weights(&a, &a)
            .divergence()
            .unwrap();
        assert!(single.xx.rung_iterations.len() > 1);
        for r in reports {
            let r = r.unwrap();
            assert_eq!(r.divergence.to_bits(), single.divergence.to_bits());
            assert_eq!(r.per_solve_iterations(), single.per_solve_iterations());
        }
    }

    #[test]
    fn deserialized_accelerated_plan_with_schedule_is_rejected() {
        // The planner never emits this combination; a hand-crafted wire
        // document must get a typed error, not a silent wrong solve.
        let (mu, nu) = clouds(20);
        let p = OtProblem::new(&mu, &nu).epsilon(0.3).rank(8).anneal(true);
        let mut plan = p.plan().unwrap();
        plan.accelerated = true;
        assert!(matches!(p.solve_planned(&plan), Err(Error::Config(_))));
    }
}
