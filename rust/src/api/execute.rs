//! The executor: binds a [`Plan`] to live kernels/pools and routes it
//! through the legacy solver layer **bitwise-unchanged**.
//!
//! ## The equivalence contract
//!
//! Every `Plan` the planner can emit executes through exactly the code
//! path a hand-wired caller of the pre-API free functions would have
//! taken, with identical kernel construction, identical solver entry
//! point, and identical `SinkhornConfig` — so results are **bitwise
//! identical** to the corresponding legacy call:
//!
//! | plan | legacy path |
//! |------|-------------|
//! | `Dense`, `Plain` | `sinkhorn(&DenseKernel::from_measures(..), ..)` |
//! | `Factored`, `Plain` | `sinkhorn(&FactoredKernel::from_measures[_stabilized]_pooled(..), ..)` |
//! | `*`, `AutoEscalate` | `sinkhorn_stabilized(..)` with `cfg.stabilize = true` |
//! | `*`, `LogDomain` | `sinkhorn_log_domain(kernel.as_log_kernel(), ..)` |
//! | B > 1 | `solve_batch[_stabilized|_log_domain](..)` per width-`batch_width` chunk |
//! | divergence | the three-solve `join3` of `sinkhorn_divergence` / the coordinator worker |
//! | `accelerated` | `sinkhorn_accelerated(..)` |
//!
//! When the executor fits a feature map itself, the draw is
//! `GaussianFeatureMap::fit(mu, nu, eps, rank, &mut Rng::seed_from(plan.seed))`
//! — seeded, so the same plan refits the same anchors. The property
//! suite in `rust/tests/api_equivalence.rs` asserts the table above bit
//! for bit.

use std::sync::Arc;

use crate::coordinator::cache::FeatureKey;
use crate::data::Measure;
use crate::error::{Error, Result};
use crate::features::GaussianFeatureMap;
use crate::kernels::{DenseKernel, FactoredKernel, KernelOp, NystromKernel};
use crate::metrics::Stopwatch;
use crate::rng::Rng;
use crate::runtime::pool::Pool;
use crate::sinkhorn::{
    sinkhorn, sinkhorn_accelerated, sinkhorn_log_domain, sinkhorn_stabilized,
    solve_batch_log_domain, solve_batch_stabilized, SinkhornSolution,
};

use super::plan::{Backend, Domain, Plan};
use super::problem::{OtProblem, Source};
use super::solution::{DivergenceReport, Solution};

fn us(sw: &Stopwatch) -> u64 {
    (sw.elapsed_secs() * 1e6) as u64
}

/// Replicate a whole-batch failure (planning, kernel construction) onto
/// every pair slot, keeping the documented index-alignment of the
/// `*_all` results. `Error` is not `Clone`, and every whole-batch
/// failure is configuration-class, so each slot gets an [`Error::Config`]
/// carrying the original message.
fn err_per_pair<T>(pairs: usize, e: Error) -> Vec<Result<T>> {
    let message = match e {
        Error::Config(msg) => msg,
        other => other.to_string(),
    };
    (0..pairs.max(1)).map(|_| Err(Error::Config(message.clone()))).collect()
}

/// A fitted map, either borrowed from the problem/cache or freshly drawn.
enum MapHandle<'m> {
    Borrowed(&'m GaussianFeatureMap),
    Shared(Arc<GaussianFeatureMap>),
}

impl MapHandle<'_> {
    fn get(&self) -> &GaussianFeatureMap {
        match self {
            MapHandle::Borrowed(m) => m,
            MapHandle::Shared(a) => a,
        }
    }
}

/// The single-problem kernel (divergence builds its own triple).
enum BuiltKernel {
    Dense(DenseKernel),
    Factored(FactoredKernel),
    Nystrom(NystromKernel),
}

impl<'a> OtProblem<'a> {
    // ----------------------------------------------------------------
    // Public execution entry points.
    // ----------------------------------------------------------------

    /// Plan and solve a single transport problem.
    pub fn solve(&self) -> Result<Solution> {
        let plan = self.plan()?;
        self.solve_planned(&plan)
    }

    /// Execute a given plan (e.g. one decoded from
    /// [`Plan::from_json`]) for a single transport problem.
    pub fn solve_planned(&self, plan: &Plan) -> Result<Solution> {
        let pairs = self.effective_pairs()?;
        if pairs.len() != 1 {
            return Err(Error::Config(format!(
                "solve() is single-pair but the problem has {} weight pairs; use solve_all()",
                pairs.len()
            )));
        }
        let (a, b) = pairs[0];
        let solver_pool = self.resolve_solver_pool(plan);
        match self.build_kernel(plan, &solver_pool)? {
            BuiltKernel::Dense(k) => self.run_single(plan, &k, a, b),
            BuiltKernel::Factored(k) => self.run_single(plan, &k, a, b),
            BuiltKernel::Nystrom(k) => self.run_single(plan, &k, a, b),
        }
    }

    /// Plan and solve all B weight pairs (fused batched execution,
    /// bitwise identical per pair to B separate [`OtProblem::solve`]s).
    pub fn solve_all(&self) -> Vec<Result<Solution>> {
        let plan = match self.plan() {
            Ok(p) => p,
            Err(e) => return err_per_pair(self.pairs.len(), e),
        };
        self.solve_all_planned(&plan)
    }

    /// Execute a given plan for all B weight pairs. The result vector is
    /// index-aligned with the problem's pairs; one pair failing never
    /// poisons its batch-mates, and whole-batch failures (planning,
    /// kernel construction) are replicated onto every slot so the
    /// alignment holds on the error path too.
    pub fn solve_all_planned(&self, plan: &Plan) -> Vec<Result<Solution>> {
        let pairs = match self.effective_pairs() {
            Ok(p) => p,
            Err(e) => return err_per_pair(self.pairs.len(), e),
        };
        let solver_pool = self.resolve_solver_pool(plan);
        let kernel = match self.build_kernel(plan, &solver_pool) {
            Ok(k) => k,
            Err(e) => return err_per_pair(pairs.len(), e),
        };
        match kernel {
            BuiltKernel::Dense(k) => self.run_batch(plan, &k, &pairs),
            BuiltKernel::Factored(k) => self.run_batch(plan, &k, &pairs),
            BuiltKernel::Nystrom(k) => self.run_batch(plan, &k, &pairs),
        }
    }

    /// Plan and compute the Eq. (2) Sinkhorn divergence (three transport
    /// solves, concurrent when the plan's `threads` allows).
    pub fn divergence(&self) -> Result<DivergenceReport> {
        let plan = self.plan()?;
        self.divergence_planned(&plan)
    }

    /// Execute a given plan as a divergence.
    pub fn divergence_planned(&self, plan: &Plan) -> Result<DivergenceReport> {
        let pairs = self.effective_pairs()?;
        if pairs.len() != 1 {
            return Err(Error::Config(format!(
                "divergence() is single-pair but the problem has {} weight pairs; use \
                 divergence_all()",
                pairs.len()
            )));
        }
        if plan.accelerated {
            // Alg. 2 maximises the single-problem dual; there is no
            // accelerated three-solve divergence (legacy had none
            // either). Reject instead of silently running Alg. 1.
            return Err(Error::Config(
                "the accelerated solver (Alg. 2) has no divergence form; use solve_planned()"
                    .into(),
            ));
        }
        let (a, b) = pairs[0];
        let sw = Stopwatch::start();
        self.with_divergence_kernels(plan, |k_xy, k_xx, k_yy| {
            self.run_divergence(plan, k_xy, k_xx, k_yy, a, b, &sw)
        })
    }

    /// Plan and compute divergences for all B weight pairs as **three
    /// width-B fused solves** (the coordinator's fuse-group path);
    /// per pair bitwise identical to B separate
    /// [`OtProblem::divergence`] calls.
    pub fn divergence_all(&self) -> Vec<Result<DivergenceReport>> {
        let plan = match self.plan() {
            Ok(p) => p,
            Err(e) => return err_per_pair(self.pairs.len(), e),
        };
        self.divergence_all_planned(&plan)
    }

    /// Execute a given plan as a batch of divergences. Like
    /// [`OtProblem::solve_all_planned`], whole-batch failures are
    /// replicated onto every pair slot so the result stays
    /// index-aligned.
    pub fn divergence_all_planned(&self, plan: &Plan) -> Vec<Result<DivergenceReport>> {
        let pairs = match self.effective_pairs() {
            Ok(p) => p,
            Err(e) => return err_per_pair(self.pairs.len(), e),
        };
        if plan.accelerated {
            return err_per_pair(
                pairs.len(),
                Error::Config(
                    "the accelerated solver (Alg. 2) has no divergence form; use \
                     solve_planned()"
                        .into(),
                ),
            );
        }
        let sw = Stopwatch::start();
        match self.with_divergence_kernels(plan, |k_xy, k_xx, k_yy| {
            Ok(self.run_divergence_batch(plan, k_xy, k_xx, k_yy, &pairs, &sw))
        }) {
            Ok(v) => v,
            Err(e) => err_per_pair(pairs.len(), e),
        }
    }

    // ----------------------------------------------------------------
    // Kernel construction (identical to the legacy call sites).
    // ----------------------------------------------------------------

    fn resolve_solver_pool(&self, plan: &Plan) -> Pool {
        match &self.solver_pool {
            Some(p) => p.clone(),
            // `Pool::new(0)` auto-sizes to the machine, matching the
            // knob's documented `0 = auto` convention.
            None => Pool::new(plan.solver_threads),
        }
    }

    fn resolve_solve_pool(&self, plan: &Plan) -> Pool {
        match &self.solve_pool {
            Some(p) => p.clone(),
            None => Pool::new_capped(plan.threads, 3),
        }
    }

    /// Resolve the Lemma-1 feature map: prebuilt > cache > seeded fit.
    fn resolve_map(&self, plan: &Plan, key: FeatureKey) -> Result<MapHandle<'a>> {
        if let Some(m) = self.map {
            return Ok(MapHandle::Borrowed(m));
        }
        let (mu, nu) = self.measures()?;
        let mut rng = Rng::seed_from(plan.seed);
        if let Some(cache) = self.cache {
            let radius = mu.radius().max(nu.radius());
            return Ok(MapHandle::Shared(cache.get_or_fit(
                key.dim,
                plan.epsilon,
                key.r,
                radius,
                &mut rng,
                self.metrics,
            )));
        }
        Ok(MapHandle::Shared(Arc::new(GaussianFeatureMap::fit(
            mu,
            nu,
            plan.epsilon,
            key.r,
            &mut rng,
        ))))
    }

    fn factored_from_measures(
        &self,
        plan: &Plan,
        map: &GaussianFeatureMap,
        mu: &Measure,
        nu: &Measure,
        pool: Pool,
    ) -> FactoredKernel {
        if plan.stabilized_factors {
            FactoredKernel::from_measures_stabilized_pooled(map, mu, nu, pool)
        } else {
            FactoredKernel::from_measures_pooled(map, mu, nu, pool)
        }
    }

    fn build_kernel(&self, plan: &Plan, solver_pool: &Pool) -> Result<BuiltKernel> {
        match plan.backend {
            Backend::Dense => {
                let (mu, nu) = self.measures()?;
                Ok(BuiltKernel::Dense(DenseKernel::from_measures(mu, nu, plan.epsilon)))
            }
            Backend::Nystrom { rank } => {
                let (mu, nu) = self.measures()?;
                let mut rng = Rng::seed_from(plan.seed);
                Ok(BuiltKernel::Nystrom(NystromKernel::from_measures(
                    mu,
                    nu,
                    plan.epsilon,
                    rank,
                    &mut rng,
                )))
            }
            Backend::Factored { rank } => match self.source {
                Source::Factors { phi_x, phi_y } => Ok(BuiltKernel::Factored(
                    FactoredKernel::from_factors(phi_x.clone(), phi_y.clone())
                        .with_pool(solver_pool.clone()),
                )),
                Source::Measures { mu, nu } => {
                    let key = plan
                        .cache_key
                        .unwrap_or_else(|| FeatureKey::new(mu.dim(), plan.epsilon, rank));
                    let map = self.resolve_map(plan, key)?;
                    Ok(BuiltKernel::Factored(self.factored_from_measures(
                        plan,
                        map.get(),
                        mu,
                        nu,
                        solver_pool.clone(),
                    )))
                }
            },
        }
    }

    /// Build the divergence kernel triple (xy, xx, yy) and hand it to
    /// `f`. One feature map serves all three — the same sharing the
    /// legacy CLI and coordinator worker hand-wired.
    fn with_divergence_kernels<T>(
        &self,
        plan: &Plan,
        f: impl FnOnce(
            &(dyn KernelOp + Sync),
            &(dyn KernelOp + Sync),
            &(dyn KernelOp + Sync),
        ) -> Result<T>,
    ) -> Result<T> {
        let solver_pool = self.resolve_solver_pool(plan);
        match plan.backend {
            Backend::Nystrom { .. } => Err(Error::Config(
                "the nystrom backend supports solve() only (no positivity guarantee, no \
                 debiased divergence in the baseline)"
                    .into(),
            )),
            Backend::Dense => {
                let (mu, nu) = self.measures()?;
                let k_xy = DenseKernel::from_measures(mu, nu, plan.epsilon);
                let k_xx = DenseKernel::from_measures(mu, mu, plan.epsilon);
                let k_yy = DenseKernel::from_measures(nu, nu, plan.epsilon);
                f(&k_xy, &k_xx, &k_yy)
            }
            Backend::Factored { rank } => match self.source {
                Source::Factors { phi_x, phi_y } => {
                    let k_xy = FactoredKernel::from_factors(phi_x.clone(), phi_y.clone())
                        .with_pool(solver_pool.clone());
                    let k_xx = FactoredKernel::from_factors(phi_x.clone(), phi_x.clone())
                        .with_pool(solver_pool.clone());
                    let k_yy = FactoredKernel::from_factors(phi_y.clone(), phi_y.clone())
                        .with_pool(solver_pool);
                    f(&k_xy, &k_xx, &k_yy)
                }
                Source::Measures { mu, nu } => {
                    let key = plan
                        .cache_key
                        .unwrap_or_else(|| FeatureKey::new(mu.dim(), plan.epsilon, rank));
                    let map = self.resolve_map(plan, key)?;
                    let m = map.get();
                    let k_xy =
                        self.factored_from_measures(plan, m, mu, nu, solver_pool.clone());
                    let k_xx =
                        self.factored_from_measures(plan, m, mu, mu, solver_pool.clone());
                    let k_yy = self.factored_from_measures(plan, m, nu, nu, solver_pool);
                    f(&k_xy, &k_xx, &k_yy)
                }
            },
        }
    }

    // ----------------------------------------------------------------
    // Solve routing (the bitwise contract lives here).
    // ----------------------------------------------------------------

    fn run_single<K: KernelOp + ?Sized>(
        &self,
        plan: &Plan,
        kernel: &K,
        a: &[f32],
        b: &[f32],
    ) -> Result<Solution> {
        let cfg = plan.sinkhorn_config();
        let sw = Stopwatch::start();
        if plan.accelerated {
            let sol = sinkhorn_accelerated(kernel, a, b, &cfg)?;
            return Ok(Solution::from_accel(sol, us(&sw)));
        }
        match plan.domain {
            Domain::Plain => sinkhorn(kernel, a, b, &cfg)
                .map(|s| Solution::from_sinkhorn(s, false, us(&sw))),
            Domain::AutoEscalate => sinkhorn_stabilized(kernel, a, b, &cfg)
                .map(|(s, esc)| Solution::from_sinkhorn(s, esc, us(&sw))),
            Domain::LogDomain => {
                let log = kernel.as_log_kernel().ok_or_else(|| {
                    Error::Config(format!("kernel {} has no log-domain view", kernel.label()))
                })?;
                sinkhorn_log_domain(log, a, b, &cfg)
                    .map(|s| Solution::from_sinkhorn(s, false, us(&sw)))
            }
        }
    }

    fn run_batch<K: KernelOp + ?Sized>(
        &self,
        plan: &Plan,
        kernel: &K,
        pairs: &[(&[f32], &[f32])],
    ) -> Vec<Result<Solution>> {
        let cfg = plan.sinkhorn_config();
        if plan.accelerated {
            // The planner rejects this combination; guard hand-crafted
            // (deserialised) plans the same way instead of silently
            // running the wrong solver.
            return pairs
                .iter()
                .map(|_| {
                    Err(Error::Config(
                        "accelerated plans are single-pair; use solve_planned()".into(),
                    ))
                })
                .collect();
        }
        let width = plan.batch_width.max(1);
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(width) {
            let sw = Stopwatch::start();
            let results = batch_by_domain(kernel, chunk, &cfg, plan.domain);
            let wall = us(&sw);
            out.extend(
                results
                    .into_iter()
                    .map(|r| r.map(|(s, esc)| Solution::from_sinkhorn(s, esc, wall))),
            );
        }
        out
    }

    #[allow(clippy::too_many_arguments)]
    fn run_divergence<K: KernelOp + Sync + ?Sized>(
        &self,
        plan: &Plan,
        k_xy: &K,
        k_xx: &K,
        k_yy: &K,
        a: &[f32],
        b: &[f32],
        sw: &Stopwatch,
    ) -> Result<DivergenceReport> {
        let cfg = plan.sinkhorn_config();
        let solve_pool = self.resolve_solve_pool(plan);
        // One closure per transport problem, all routed by the planned
        // domain; the log view is taken *inside* the worker so the
        // non-Send trait object never crosses threads.
        let solve_one = |k: &K, a: &[f32], b: &[f32]| -> Result<Solution> {
            let sw = Stopwatch::start();
            match plan.domain {
                Domain::Plain => {
                    sinkhorn(k, a, b, &cfg).map(|s| Solution::from_sinkhorn(s, false, us(&sw)))
                }
                Domain::AutoEscalate => sinkhorn_stabilized(k, a, b, &cfg)
                    .map(|(s, esc)| Solution::from_sinkhorn(s, esc, us(&sw))),
                Domain::LogDomain => {
                    let log = k.as_log_kernel().ok_or_else(|| {
                        Error::Config(format!("kernel {} has no log-domain view", k.label()))
                    })?;
                    sinkhorn_log_domain(log, a, b, &cfg)
                        .map(|s| Solution::from_sinkhorn(s, false, us(&sw)))
                }
            }
        };
        let (r_xy, r_xx, r_yy) = solve_pool.join3(
            || solve_one(k_xy, a, b),
            || solve_one(k_xx, a, a),
            || solve_one(k_yy, b, b),
        );
        // Error priority matches the legacy path: xy, then xx, then yy.
        Ok(DivergenceReport::assemble(r_xy?, r_xx?, r_yy?, us(sw)))
    }

    fn run_divergence_batch<K: KernelOp + Sync + ?Sized>(
        &self,
        plan: &Plan,
        k_xy: &K,
        k_xx: &K,
        k_yy: &K,
        pairs: &[(&[f32], &[f32])],
        sw: &Stopwatch,
    ) -> Vec<Result<DivergenceReport>> {
        let cfg = plan.sinkhorn_config();
        let width = plan.batch_width.max(1);
        let solve_pool = self.resolve_solve_pool(plan);
        let xx_pairs: Vec<(&[f32], &[f32])> = pairs.iter().map(|&(a, _)| (a, a)).collect();
        let yy_pairs: Vec<(&[f32], &[f32])> = pairs.iter().map(|&(_, b)| (b, b)).collect();
        let run = |k: &K, prs: &[(&[f32], &[f32])]| -> Vec<Result<(SinkhornSolution, bool)>> {
            let mut out = Vec::with_capacity(prs.len());
            for chunk in prs.chunks(width) {
                out.extend(batch_by_domain(k, chunk, &cfg, plan.domain));
            }
            out
        };
        let (r_xy, r_xx, r_yy) = solve_pool.join3(
            || run(k_xy, pairs),
            || run(k_xx, &xx_pairs),
            || run(k_yy, &yy_pairs),
        );
        let wall = us(sw);
        r_xy.into_iter()
            .zip(r_xx)
            .zip(r_yy)
            .map(|((xy, xx), yy)| {
                let (s_xy, e_xy) = xy?;
                let (s_xx, e_xx) = xx?;
                let (s_yy, e_yy) = yy?;
                Ok(DivergenceReport::assemble(
                    Solution::from_sinkhorn(s_xy, e_xy, wall),
                    Solution::from_sinkhorn(s_xx, e_xx, wall),
                    Solution::from_sinkhorn(s_yy, e_yy, wall),
                    wall,
                ))
            })
            .collect()
    }
}

/// Route one batched chunk by the planned domain. `Plain` and
/// `AutoEscalate` share [`solve_batch_stabilized`] (whose behaviour is
/// gated by `cfg.stabilize`, exactly like the sequential
/// `sinkhorn_stabilized`); `LogDomain` goes straight to the batched
/// log-domain solver through the kernel's log view.
fn batch_by_domain<K: KernelOp + ?Sized>(
    kernel: &K,
    chunk: &[(&[f32], &[f32])],
    cfg: &crate::config::SinkhornConfig,
    domain: Domain,
) -> Vec<Result<(SinkhornSolution, bool)>> {
    match domain {
        Domain::Plain | Domain::AutoEscalate => solve_batch_stabilized(kernel, chunk, cfg),
        Domain::LogDomain => match kernel.as_log_kernel() {
            Some(log) => solve_batch_log_domain(log, chunk, cfg)
                .into_iter()
                .map(|r| r.map(|s| (s, false)))
                .collect(),
            None => chunk
                .iter()
                .map(|_| {
                    Err(Error::Config(format!(
                        "kernel {} has no log-domain view",
                        kernel.label()
                    )))
                })
                .collect(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::DomainChoice;
    use crate::data;

    fn clouds(n: usize) -> (Measure, Measure) {
        let mut rng = Rng::seed_from(3);
        data::gaussian_blobs(n, &mut rng)
    }

    #[test]
    fn solve_and_divergence_roundtrip_through_a_serialised_plan() {
        // The cross-host story in miniature: plan, ship as JSON, decode,
        // execute — identical to executing the original plan.
        let (mu, nu) = clouds(40);
        let problem = OtProblem::new(&mu, &nu).epsilon(0.5).rank(32).seed(9);
        let plan = problem.plan().unwrap();
        let wire = Plan::from_json(&plan.to_json()).unwrap();
        assert_eq!(wire, plan);
        let direct = problem.solve_planned(&plan).unwrap();
        let shipped = problem.solve_planned(&wire).unwrap();
        assert_eq!(direct.objective.to_bits(), shipped.objective.to_bits());
        let d1 = problem.divergence_planned(&plan).unwrap();
        let d2 = problem.divergence_planned(&wire).unwrap();
        assert_eq!(d1.divergence.to_bits(), d2.divergence.to_bits());
    }

    #[test]
    fn solve_rejects_multi_pair_problems() {
        let (mu, nu) = clouds(20);
        let a = vec![0.05f32; 20];
        let pairs: Vec<(&[f32], &[f32])> = vec![(&a[..], &a[..]), (&a[..], &a[..])];
        let p = OtProblem::new(&mu, &nu).rank(8).weight_pairs(&pairs);
        assert!(matches!(p.solve(), Err(Error::Config(_))));
        assert!(matches!(p.divergence(), Err(Error::Config(_))));
        assert_eq!(p.solve_all().len(), 2);
    }

    #[test]
    fn accelerated_divergence_is_a_typed_error() {
        // Alg. 2 has no three-solve divergence form; it must never
        // silently run Alg. 1 instead.
        let (mu, nu) = clouds(20);
        let p = OtProblem::new(&mu, &nu).rank(8).accelerated();
        assert!(p.solve().is_ok());
        assert!(matches!(p.divergence(), Err(Error::Config(_))));
        let w = vec![0.05f32; 20];
        let pairs: Vec<(&[f32], &[f32])> = vec![(&w[..], &w[..]), (&w[..], &w[..])];
        let p2 = OtProblem::new(&mu, &nu).rank(8).weight_pairs(&pairs).accelerated();
        let reports = p2.divergence_all();
        assert_eq!(reports.len(), 2, "errors stay index-aligned with the pairs");
        assert!(reports.iter().all(|r| matches!(r, Err(Error::Config(_)))));
    }

    #[test]
    fn nystrom_divergence_is_a_typed_error() {
        // eps = 5.0 with rank ~ n/3 is the regime where Nyström is known
        // accurate and positive (`nystrom_accurate_at_large_eps`).
        let (mu, nu) = clouds(30);
        let p = OtProblem::new(&mu, &nu).epsilon(5.0).nystrom(10);
        assert!(p.solve().is_ok());
        assert!(matches!(p.divergence(), Err(Error::Config(_))));
    }

    #[test]
    fn solution_reports_the_executed_arm_and_wall_clock() {
        let (mu, nu) = clouds(30);
        let sol = OtProblem::new(&mu, &nu).epsilon(0.5).rank(16).solve().unwrap();
        assert_eq!(sol.simd_arm, crate::linalg::simd::active_level().label());
        assert!(sol.objective.is_finite());
        assert!(!sol.escalated);
    }

    #[test]
    fn planned_log_domain_is_not_reported_as_escalation() {
        let (mu, nu) = clouds(25);
        let sol = OtProblem::new(&mu, &nu)
            .epsilon(0.5)
            .rank(16)
            .domain(DomainChoice::LogDomain)
            .solve()
            .unwrap();
        assert!(!sol.escalated);
        assert!(sol.objective.is_finite());
    }

    #[test]
    fn feature_cache_is_honoured_with_metrics() {
        use crate::coordinator::cache::FeatureCache;
        use crate::metrics::Registry;
        let (mu, nu) = clouds(30);
        let cache = FeatureCache::new(4);
        let metrics = Registry::default();
        for _ in 0..3 {
            OtProblem::new(&mu, &nu)
                .epsilon(0.5)
                .rank(16)
                .feature_cache(&cache)
                .metrics(&metrics)
                .solve()
                .unwrap();
        }
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 2);
        assert_eq!(metrics.counter("service.feature_cache.hits").get(), 2);
    }
}
