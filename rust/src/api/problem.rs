//! [`OtProblem`]: the builder describing *what* to solve, and the planner
//! turning it into a [`Plan`] describing *how*.
//!
//! The planner is where the repo's previously scattered heuristics now
//! live, in one auditable place:
//!
//! * **Backend choice** — factored vs dense vs Nyström by per-iteration
//!   flops (`r(n+m)` vs `nm`, the paper's headline complexity contrast).
//!   Auto-selection is conservative about the Nyström arm: uniform
//!   sampling only in the flat-kernel regime (`eps >= R^2`, where the
//!   Gibbs kernel is numerically low-rank and positivity-safe), and
//!   adaptive farthest-point sampling only on explicit preference
//!   ([`BackendPref::Nystrom`]) until the tradeoff bench justifies more.
//! * **f32-underflow escalation** — the production default is
//!   [`Domain::AutoEscalate`] (plain Alg. 1, retry in the log domain on a
//!   typed divergence), but when the regularisation is hopeless for f32 —
//!   the Gibbs values live beyond f32's exponent range even after the
//!   stabilised factor shift — the planner goes straight to
//!   [`Domain::LogDomain`] and skips the doomed plain attempt.
//! * **Fuse width** — B weight pairs on one support fuse into
//!   column-blocked batched solves of width ≤ `max_batch`, exactly the
//!   grouping rule of [`crate::coordinator::batcher::fuse_groups`].
//! * **Cache key** — factored backends fitted from measures record their
//!   `(dim, eps, r)` key, the amortisation unit of the shared
//!   feature-map cache.
//! * **SIMD arm** — recorded from the process-global dispatch
//!   ([`crate::linalg::simd::active_level`]); a preference that the
//!   process cannot honour is a typed planning error, never a silent
//!   fallback.

use crate::config::SinkhornConfig;
use crate::coordinator::cache::{FeatureCache, FeatureKey, LandmarkCache};
use crate::data::Measure;
use crate::error::{Error, Result};
use crate::features::GaussianFeatureMap;
use crate::linalg::simd::{self, SimdLevel};
use crate::linalg::Mat;
use crate::metrics::Registry;
use crate::runtime::pool::Pool;

use super::plan::{Backend, Domain, Plan};
use super::{DEFAULT_RANK, UNDERFLOW_LOG_SPREAD};
use crate::sinkhorn::EpsSchedule;

/// Requested kernel backend — the single backend-preference surface of
/// the builder ([`OtProblem::backend`]). The planner resolves `Auto`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendPref {
    /// Let the planner pick dense / factored / (flat-regime uniform)
    /// Nyström by per-iteration flops.
    Auto,
    /// Force the dense Gibbs baseline.
    Dense,
    /// Force the positive-feature factored kernel with this rank.
    Factored {
        /// Feature count r.
        rank: usize,
    },
    /// Force the Nyström arm with `rank` landmarks; `adaptive` selects
    /// seeded farthest-point sampling (arXiv:1812.05189) instead of
    /// uniform. May lose positivity at small eps — the paper's central
    /// contrast — and that failure surfaces as a typed error (plain
    /// domain) or a gated-off log view (escalation).
    Nystrom {
        /// Landmark count.
        rank: usize,
        /// Adaptive (farthest-point) landmark selection.
        adaptive: bool,
    },
}

impl BackendPref {
    /// Parse a CLI `--backend` value. Accepted forms:
    /// `auto`, `dense`, `factored[:rank]`, `nystrom[:rank]`,
    /// `nystrom-adaptive[:rank]` — a missing `:rank` suffix falls back to
    /// `default_rank` (the CLI's `--features` value), so
    /// `--backend nystrom` and `--backend nystrom:128` both work.
    pub fn parse_flag(value: &str, default_rank: usize) -> Result<BackendPref> {
        let (name, rank) = match value.split_once(':') {
            Some((n, r)) => {
                let rank: usize = r.parse().map_err(|_| {
                    Error::Config(format!("--backend {value}: `{r}` is not a rank"))
                })?;
                (n, rank)
            }
            None => (value, default_rank),
        };
        match name {
            "auto" => Ok(BackendPref::Auto),
            "dense" => Ok(BackendPref::Dense),
            "factored" => Ok(BackendPref::Factored { rank }),
            "nystrom" => Ok(BackendPref::Nystrom { rank, adaptive: false }),
            "nystrom-adaptive" => Ok(BackendPref::Nystrom { rank, adaptive: true }),
            other => Err(Error::Config(format!(
                "--backend {other}: expected auto|dense|factored|nystrom|nystrom-adaptive \
                 (optionally with a :rank suffix)"
            ))),
        }
    }
}

/// Pre-PR-8 name of [`BackendPref`].
///
/// Deprecated alias, kept for one release: prefer
/// [`OtProblem::backend`] with [`BackendPref`].
pub type KernelChoice = BackendPref;

/// Requested numeric domain (the planner resolves `Auto`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DomainChoice {
    /// Let the planner pick (escalate by default, straight log-domain
    /// when eps is hopeless for f32).
    Auto,
    /// Plain Alg. 1 only; small-eps failures stay typed errors.
    Plain,
    /// The matrix-free log-domain solver directly.
    LogDomain,
    /// Plain with automatic log-domain escalation.
    AutoEscalate,
}

/// Requested SIMD arm. Dispatch is process-global
/// (`LINEAR_SINKHORN_SIMD`), so a preference the process cannot honour
/// fails planning with a [`Error::Config`] instead of silently running
/// the other arm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdPreference {
    /// Record whatever the process dispatches.
    Auto,
    /// Require the portable scalar arm.
    Scalar,
    /// Require the AVX2+FMA arm.
    Avx2Fma,
}

/// What the kernel is built from.
pub(crate) enum Source<'a> {
    /// Two point clouds; the executor evaluates a feature map / cost.
    Measures { mu: &'a Measure, nu: &'a Measure },
    /// Prebuilt positive factor matrices (e.g. the GAN's learned
    /// features): `K = phi_x phi_y^T` as given.
    Factors { phi_x: &'a Mat, phi_y: &'a Mat },
}

/// A transport problem (or a batch of them on one shared support),
/// described declaratively. `plan()` turns it into a [`Plan`];
/// `solve()` / `divergence()` / the `*_all` batch forms execute one.
///
/// ```no_run
/// use linear_sinkhorn::prelude::*;
///
/// let mut rng = Rng::seed_from(0);
/// let (mu, nu) = data::gaussian_blobs(1000, &mut rng);
/// let sol = OtProblem::new(&mu, &nu).epsilon(0.5).rank(256).solve()?;
/// println!("ROT ~= {} [{}]", sol.objective, sol.simd_arm);
/// # Ok::<(), linear_sinkhorn::error::Error>(())
/// ```
pub struct OtProblem<'a> {
    pub(crate) source: Source<'a>,
    pub(crate) weights: Option<(&'a [f32], &'a [f32])>,
    pub(crate) pairs: Vec<(&'a [f32], &'a [f32])>,
    pub(crate) epsilon: f64,
    pub(crate) kernel: BackendPref,
    pub(crate) domain: DomainChoice,
    pub(crate) accelerated: bool,
    pub(crate) stabilized: Option<bool>,
    pub(crate) max_iters: usize,
    pub(crate) tol: f64,
    pub(crate) check_every: usize,
    pub(crate) threads: usize,
    pub(crate) solver_threads: usize,
    pub(crate) max_batch: usize,
    pub(crate) seed: u64,
    pub(crate) anneal: Option<bool>,
    pub(crate) anneal_decay: f64,
    pub(crate) symmetric: Option<bool>,
    pub(crate) simd: SimdPreference,
    pub(crate) warm_start: bool,
    pub(crate) map: Option<&'a GaussianFeatureMap>,
    pub(crate) cache: Option<&'a FeatureCache>,
    pub(crate) landmarks: Option<&'a LandmarkCache>,
    pub(crate) metrics: Option<&'a Registry>,
    pub(crate) solver_pool: Option<Pool>,
    pub(crate) solve_pool: Option<Pool>,
}

impl<'a> OtProblem<'a> {
    fn with_source(source: Source<'a>) -> Self {
        let d = SinkhornConfig::default();
        OtProblem {
            source,
            weights: None,
            pairs: Vec::new(),
            epsilon: d.epsilon,
            kernel: BackendPref::Auto,
            domain: DomainChoice::Auto,
            accelerated: false,
            stabilized: None,
            max_iters: d.max_iters,
            tol: d.tol,
            check_every: d.check_every,
            threads: d.threads,
            solver_threads: 1,
            max_batch: d.max_batch,
            seed: 0,
            anneal: d.anneal,
            anneal_decay: d.anneal_decay,
            symmetric: d.symmetric,
            simd: SimdPreference::Auto,
            warm_start: false,
            map: None,
            cache: None,
            landmarks: None,
            metrics: None,
            solver_pool: None,
            solve_pool: None,
        }
    }

    /// A problem between two point-cloud measures (weights default to the
    /// measures' own).
    pub fn new(mu: &'a Measure, nu: &'a Measure) -> Self {
        Self::with_source(Source::Measures { mu, nu })
    }

    /// A problem on a prebuilt factored kernel `K = phi_x phi_y^T`
    /// (strictly positive factor matrices, e.g. learned features).
    /// Requires explicit [`OtProblem::weights`] or
    /// [`OtProblem::weight_pairs`].
    pub fn from_factors(phi_x: &'a Mat, phi_y: &'a Mat) -> Self {
        Self::with_source(Source::Factors { phi_x, phi_y })
    }

    /// Override the marginal weight vectors (lengths n and m).
    pub fn weights(mut self, a: &'a [f32], b: &'a [f32]) -> Self {
        self.weights = Some((a, b));
        self
    }

    /// Solve B problems sharing this support: one `(a, b)` weight pair
    /// per problem. Batched execution fuses them into column-blocked
    /// solves of width ≤ [`OtProblem::max_batch`], bitwise identical per
    /// pair to solving each alone.
    pub fn weight_pairs(mut self, pairs: &[(&'a [f32], &'a [f32])]) -> Self {
        self.pairs = pairs.to_vec();
        self
    }

    /// Entropic regularisation eps (> 0).
    pub fn epsilon(mut self, eps: f64) -> Self {
        self.epsilon = eps;
        self
    }

    /// Set the backend preference explicitly — the unified selection
    /// surface (`Auto` lets the planner run its flops rule; see
    /// [`OtProblem::explain`] for the narrated decision).
    pub fn backend(mut self, pref: BackendPref) -> Self {
        self.kernel = pref;
        self
    }

    /// Use the factored backend with `rank` positive features
    /// (shorthand for `.backend(BackendPref::Factored { rank })`).
    pub fn rank(self, rank: usize) -> Self {
        self.backend(BackendPref::Factored { rank })
    }

    /// Force the dense Gibbs baseline
    /// (shorthand for `.backend(BackendPref::Dense)`).
    pub fn dense(self) -> Self {
        self.backend(BackendPref::Dense)
    }

    /// Force the uniform-sampling Nyström arm with `rank` landmarks.
    ///
    /// Deprecated alias, kept for one release: prefer
    /// `.backend(BackendPref::Nystrom { rank, adaptive })`, which also
    /// exposes adaptive landmark selection.
    pub fn nystrom(self, rank: usize) -> Self {
        self.backend(BackendPref::Nystrom { rank, adaptive: false })
    }

    /// Deprecated alias of [`OtProblem::backend`] (pre-PR-8 name), kept
    /// for one release.
    pub fn kernel(self, choice: KernelChoice) -> Self {
        self.backend(choice)
    }

    /// Set the numeric-domain choice explicitly.
    pub fn domain(mut self, choice: DomainChoice) -> Self {
        self.domain = choice;
        self
    }

    /// Use Alg. 2 (accelerated Sinkhorn) — plain domain, single pair.
    pub fn accelerated(mut self) -> Self {
        self.accelerated = true;
        self
    }

    /// Force stabilised (max-shifted log) factor construction on or off.
    /// Default: on when fitting from measures (arbitrary client data must
    /// not underflow f32), off for prebuilt factors (taken as given).
    pub fn stabilized_factors(mut self, on: bool) -> Self {
        self.stabilized = Some(on);
        self
    }

    /// Solver iteration cap.
    pub fn max_iters(mut self, n: usize) -> Self {
        self.max_iters = n;
        self
    }

    /// L1 marginal stopping tolerance.
    pub fn tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    /// Stopping-check cadence (the check costs one kernel apply).
    pub fn check_every(mut self, n: usize) -> Self {
        self.check_every = n;
        self
    }

    /// Solve-level concurrency (the three divergence problems; 0 = auto).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Intra-solve pool width (row-chunked applies, parallel feature
    /// evaluation; 0 = auto). Never changes results — the pooled kernels
    /// are deterministic in the thread count.
    pub fn solver_threads(mut self, n: usize) -> Self {
        self.solver_threads = n;
        self
    }

    /// Fused-width cap for batched execution (1 = solve each pair alone).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    /// Force eps-annealing on or off. Default (`Auto`): the planner
    /// anneals exactly when the target eps is hopeless for f32 (the
    /// [`UNDERFLOW_LOG_SPREAD`] rule) and nothing else pins the domain —
    /// high-eps rungs converge in a handful of plain-domain iterations
    /// and warm-start the next, so the expensive target rung starts next
    /// to its fixed point. Explicit `anneal(true)` requires a
    /// measure-built, non-accelerated problem (prebuilt factors cannot
    /// be rebuilt at intermediate eps; measure-built backends — factored
    /// and Nyström alike — refit deterministically at each rung).
    pub fn anneal(mut self, on: bool) -> Self {
        self.anneal = Some(on);
        self
    }

    /// Geometric decay factor between annealing rungs (in `(0, 1)`,
    /// default 0.5). Smaller = fewer, steeper rungs.
    pub fn anneal_decay(mut self, decay: f64) -> Self {
        self.anneal_decay = decay;
        self
    }

    /// Force the one-dual symmetric fixed point for the xx/yy self-solves
    /// of a divergence on or off. Default (`Auto`): on exactly when the
    /// plan anneals.
    pub fn symmetric_self_solves(mut self, on: bool) -> Self {
        self.symmetric = Some(on);
        self
    }

    /// Seed for the Lemma-1 anchor draw (and Nyström landmarks) when the
    /// executor fits a map itself. The executor's draw is exactly
    /// `GaussianFeatureMap::fit(mu, nu, eps, r, &mut Rng::seed_from(seed))`,
    /// which is what makes planned solves reproducible and bitwise
    /// comparable to hand-wired legacy calls with the same seeded RNG.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Require a specific SIMD arm (see [`SimdPreference`]).
    pub fn simd(mut self, pref: SimdPreference) -> Self {
        self.simd = pref;
        self
    }

    /// Use a prebuilt Lemma-1 feature map instead of fitting one (shared
    /// anchor draws across problems — the cache's amortisation, made
    /// explicit).
    pub fn with_feature_map(mut self, map: &'a GaussianFeatureMap) -> Self {
        self.map = Some(map);
        self
    }

    /// Resolve the feature map through a shared [`FeatureCache`] (fits on
    /// miss with the cache's radius-headroom rule).
    pub fn feature_cache(mut self, cache: &'a FeatureCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Resolve Nyström landmark sets through a shared [`LandmarkCache`]:
    /// hot groups skip the O(r·(n+m)·d) adaptive re-selection. Cache
    /// hits rebuild the bit-identical kernel (the landmark indices are
    /// what the selection would have produced; a support fingerprint
    /// guards against reusing indices across different clouds).
    pub fn landmark_cache(mut self, cache: &'a LandmarkCache) -> Self {
        self.landmarks = Some(cache);
        self
    }

    /// Mark the plan as warm-startable ([`Plan::warm_start`]): the
    /// serving layer may attach a caller-provided dual (streaming
    /// sessions) and the executor/worker enters through the `*_warm`
    /// solver entry points. Metadata only for direct solves.
    pub fn warm_start(mut self, on: bool) -> Self {
        self.warm_start = on;
        self
    }

    /// Export cache hit/miss counters to this registry.
    pub fn metrics(mut self, metrics: &'a Registry) -> Self {
        self.metrics = Some(metrics);
        self
    }

    /// Reuse persistent pools (e.g. a coordinator worker's) instead of
    /// constructing them per execution: `solver` backs the intra-solve
    /// row-chunked applies, `solve` runs the divergence's three problems
    /// concurrently. Pool size never changes results.
    pub fn pools(mut self, solver: Pool, solve: Pool) -> Self {
        self.solver_pool = Some(solver);
        self.solve_pool = Some(solve);
        self
    }

    /// Configure this problem as a converged-`Sin` ground-truth solve:
    /// the dense backend under the canonical tight-tolerance profile
    /// ([`crate::sinkhorn::ground_truth_config`]) at the problem's
    /// current epsilon. Call after [`OtProblem::epsilon`].
    pub fn ground_truth(self) -> Self {
        let cfg = crate::sinkhorn::ground_truth_config(self.epsilon);
        self.config(&cfg).dense()
    }

    /// Absorb a [`SinkhornConfig`]: epsilon, iteration/tolerance/cadence,
    /// thread and fuse-width knobs, and `stabilize` → domain
    /// (`AutoEscalate` when set, `Plain` otherwise). Call this *before*
    /// more specific overrides.
    pub fn config(mut self, cfg: &SinkhornConfig) -> Self {
        self.epsilon = cfg.epsilon;
        self.max_iters = cfg.max_iters;
        self.tol = cfg.tol;
        self.check_every = cfg.check_every;
        self.threads = cfg.threads;
        self.max_batch = cfg.max_batch;
        self.anneal = cfg.anneal;
        self.anneal_decay = cfg.anneal_decay;
        self.symmetric = cfg.symmetric;
        self.domain =
            if cfg.stabilize { DomainChoice::AutoEscalate } else { DomainChoice::Plain };
        self
    }

    /// Problem shape (n, m).
    pub fn shape(&self) -> (usize, usize) {
        match self.source {
            Source::Measures { mu, nu } => (mu.len(), nu.len()),
            Source::Factors { phi_x, phi_y } => (phi_x.rows(), phi_y.rows()),
        }
    }

    pub(crate) fn measures(&self) -> Result<(&'a Measure, &'a Measure)> {
        match self.source {
            Source::Measures { mu, nu } => Ok((mu, nu)),
            Source::Factors { .. } => Err(Error::Config(
                "this backend needs point-cloud measures, but the problem was built \
                 from_factors"
                    .into(),
            )),
        }
    }

    /// The weight pairs this problem solves (B ≥ 1), index-aligned with
    /// `solve_all`'s results.
    pub(crate) fn effective_pairs(&self) -> Result<Vec<(&'a [f32], &'a [f32])>> {
        if !self.pairs.is_empty() {
            return Ok(self.pairs.clone());
        }
        if let Some((a, b)) = self.weights {
            return Ok(vec![(a, b)]);
        }
        match self.source {
            Source::Measures { mu, nu } => Ok(vec![(&mu.weights[..], &nu.weights[..])]),
            Source::Factors { .. } => Err(Error::Config(
                "from_factors problems need explicit .weights(..) or .weight_pairs(..)".into(),
            )),
        }
    }

    /// Run the planner: resolve every `Auto` into a concrete, serialisable
    /// decision record. Pure — no kernels are built and no RNG is drawn.
    pub fn plan(&self) -> Result<Plan> {
        if !(self.epsilon > 0.0 && self.epsilon.is_finite()) {
            return Err(Error::Config(format!(
                "epsilon must be positive and finite, got {}",
                self.epsilon
            )));
        }
        let (n, m) = self.shape();
        let pairs = self.effective_pairs()?.len();

        // Backend: explicit choice validated, Auto by per-iteration flops.
        let backend = match self.kernel {
            BackendPref::Dense => {
                self.measures()?;
                Backend::Dense
            }
            BackendPref::Factored { rank } => {
                if rank == 0 {
                    return Err(Error::Config("factored backend needs rank >= 1".into()));
                }
                // Prebuilt factors fix the rank; a contradicting request
                // would make the Plan describe a computation the executor
                // cannot perform.
                if let Source::Factors { phi_x, .. } = self.source {
                    if rank != phi_x.cols() {
                        return Err(Error::Config(format!(
                            "requested rank {rank} but the prebuilt factors have rank {}",
                            phi_x.cols()
                        )));
                    }
                }
                Backend::Factored { rank }
            }
            BackendPref::Nystrom { rank, adaptive } => {
                self.measures()?;
                // min(n, m): a divergence builds (mu, mu) and (nu, nu)
                // legs too, so the rank must fit the smaller cloud.
                if !(1..=n.min(m)).contains(&rank) {
                    return Err(Error::Config(format!(
                        "nystrom rank must be in 1..=min(n,m)={}, got {rank}",
                        n.min(m)
                    )));
                }
                Backend::Nystrom { rank, adaptive }
            }
            BackendPref::Auto => match self.source {
                Source::Factors { phi_x, .. } => Backend::Factored { rank: phi_x.cols() },
                Source::Measures { mu, nu } => {
                    // The paper's complexity contrast, as a planning rule:
                    // factored iterations cost O(r(n+m)), dense O(nm), and
                    // the Nyström arm O(r_nys(n+m)) at a quarter of the
                    // feature rank. Auto stays conservative about Nyström:
                    // uniform sampling only in the flat-kernel regime
                    // (eps >= R^2, where exp(-C/eps) is numerically
                    // low-rank and positivity-safe), and the adaptive arm
                    // never — explicit preference only.
                    let radius = mu.radius().max(nu.radius());
                    let nys_rank = (DEFAULT_RANK / 4).clamp(1, m);
                    if self.epsilon >= radius * radius
                        && nys_rank * (n + m) < n * m
                        && nys_rank < DEFAULT_RANK
                    {
                        Backend::Nystrom { rank: nys_rank, adaptive: false }
                    } else if DEFAULT_RANK * (n + m) < n * m {
                        Backend::Factored { rank: DEFAULT_RANK }
                    } else {
                        Backend::Dense
                    }
                }
            },
        };

        let stabilized_factors = match backend {
            Backend::Factored { .. } => match (&self.source, self.stabilized) {
                // Prebuilt factors are taken exactly as given — a plan
                // claiming stabilised construction would be a lie.
                (Source::Factors { .. }, Some(true)) => {
                    return Err(Error::Config(
                        "stabilized_factors(true) only applies when fitting from measures; \
                         prebuilt factors are taken as given"
                            .into(),
                    ))
                }
                (Source::Factors { .. }, _) => false,
                (Source::Measures { .. }, choice) => choice.unwrap_or(true),
            },
            _ => false,
        };

        // Annealing: resolve the tri-state. Auto anneals exactly when the
        // target eps is hopeless for f32 (the same rule that would send
        // the domain straight to log) and nothing else pins the solve —
        // the high-eps rungs are then cheap plain-domain iterations that
        // warm-start the expensive target rung next to its fixed point.
        let anneal_on = match self.anneal {
            Some(on) => {
                if on && self.accelerated {
                    return Err(Error::Config(
                        "the accelerated solver (Alg. 2) has its own momentum schedule; \
                         .anneal(true) does not compose with it"
                            .into(),
                    ));
                }
                if on && matches!(self.source, Source::Factors { .. }) {
                    return Err(Error::Config(
                        "annealing rebuilds the kernel at each rung's eps; prebuilt \
                         factors are fixed at one eps, so .anneal(true) cannot apply"
                            .into(),
                    ));
                }
                on
            }
            None => {
                self.underflow_risk()
                    && self.domain == DomainChoice::Auto
                    && !self.accelerated
                    && matches!(self.source, Source::Measures { .. })
            }
        };
        let schedule = if anneal_on {
            let (mu, nu) = self.measures()?;
            // The support diameter bounds the cost range: at eps ~ 4R^2
            // the Gibbs kernel is nearly flat and Sinkhorn converges in a
            // handful of iterations from cold.
            let radius = mu.radius().max(nu.radius());
            let eps_start = (4.0 * radius * radius).max(self.epsilon);
            Some(EpsSchedule::new(eps_start, self.anneal_decay)?)
        } else {
            None
        };
        let symmetric_self_solves = self.symmetric.unwrap_or(schedule.is_some());

        // Domain: every backend now carries a log-domain view (Nyström's
        // clamped signed view is gated at runtime — escalation onto a
        // distorted kernel fails typed instead of converging wrong), so
        // the domain choice is backend-independent; Auto applies the
        // underflow heuristic.
        let mut domain = match self.domain {
            DomainChoice::Plain => Domain::Plain,
            DomainChoice::LogDomain => Domain::LogDomain,
            DomainChoice::AutoEscalate => Domain::AutoEscalate,
            DomainChoice::Auto => {
                if self.accelerated {
                    // Accelerated runs plainly (Alg. 2 never escalates).
                    Domain::Plain
                } else if self.underflow_risk() {
                    // Annealed solves reach the target rung warm: give the
                    // plain domain a chance and keep log as the escape
                    // hatch. Direct solves skip the doomed plain attempt.
                    if anneal_on {
                        Domain::AutoEscalate
                    } else {
                        Domain::LogDomain
                    }
                } else {
                    Domain::AutoEscalate
                }
            }
        };

        if self.accelerated {
            match domain {
                Domain::Plain => {}
                // Alg. 2 never escalates, exactly as the legacy
                // `sinkhorn_accelerated` ignored `cfg.stabilize` — so an
                // escalation *policy* (e.g. absorbed from a default
                // config) resolves to plain rather than erroring; only
                // an explicit log-domain request is a contradiction.
                Domain::AutoEscalate => domain = Domain::Plain,
                Domain::LogDomain => {
                    return Err(Error::Config(
                        "the accelerated solver (Alg. 2) runs in the plain domain only"
                            .into(),
                    ))
                }
            }
            if pairs > 1 {
                return Err(Error::Config(
                    "the accelerated solver (Alg. 2) is single-pair; drop .weight_pairs()"
                        .into(),
                ));
            }
        }

        // SIMD: dispatch is process-global; a preference the process
        // cannot honour is a planning error (see SimdPreference).
        let active = simd::active_level();
        let arm = match (self.simd, active) {
            (SimdPreference::Auto, lvl) => lvl,
            (SimdPreference::Scalar, SimdLevel::Scalar) => SimdLevel::Scalar,
            (SimdPreference::Scalar, _) => {
                return Err(Error::Config(
                    "scalar arm requested but the process dispatches avx2+fma; set \
                     LINEAR_SINKHORN_SIMD=scalar before the first kernel call"
                        .into(),
                ))
            }
            (SimdPreference::Avx2Fma, SimdLevel::Avx2Fma) => SimdLevel::Avx2Fma,
            (SimdPreference::Avx2Fma, _) => {
                return Err(Error::Config(
                    "avx2+fma arm requested but unavailable (CPU lacks it or \
                     LINEAR_SINKHORN_SIMD pinned scalar)"
                        .into(),
                ))
            }
        };

        let cache_key = match (backend, &self.source) {
            (Backend::Factored { rank }, Source::Measures { mu, .. }) => {
                Some(FeatureKey::new(mu.dim(), self.epsilon, rank))
            }
            _ => None,
        };

        Ok(Plan {
            backend,
            domain,
            stabilized_factors,
            accelerated: self.accelerated,
            pairs,
            batch_width: pairs.min(self.max_batch.max(1)),
            threads: self.threads,
            solver_threads: self.solver_threads,
            simd_arm: arm.label().to_string(),
            cache_key,
            epsilon: self.epsilon,
            max_iters: self.max_iters,
            tol: self.tol,
            check_every: self.check_every,
            n,
            m,
            seed: self.seed,
            schedule,
            symmetric_self_solves,
            warm_start: self.warm_start,
        })
    }

    /// Plan, then narrate *why*: the flops-rule numbers behind the
    /// backend choice and any demotions the planner applied. This is the
    /// CLI's `--explain` output; the first line is [`Plan::summary`].
    pub fn explain(&self) -> Result<String> {
        let plan = self.plan()?;
        let (n, m) = (plan.n, plan.m);
        let mut out = String::with_capacity(640);
        out.push_str(&plan.summary());
        out.push('\n');

        // Backend: either an explicit request (validated, no rule ran)
        // or the per-iteration flops comparison Auto resolved.
        match self.kernel {
            BackendPref::Auto => {
                let dense_flops = n * m;
                let fact_flops = DEFAULT_RANK * (n + m);
                out.push_str(&format!(
                    "backend: auto flops rule per apply — dense {n}x{m} = {dense_flops}, \
                     factored r={DEFAULT_RANK} -> {fact_flops}"
                ));
                if let Source::Measures { mu, nu } = self.source {
                    let radius = mu.radius().max(nu.radius());
                    let nys_rank = (DEFAULT_RANK / 4).clamp(1, m);
                    out.push_str(&format!(
                        ", nystrom r={nys_rank} -> {} (flat-kernel gate eps >= R^2: \
                         eps={} vs R^2={} -> {})",
                        nys_rank * (n + m),
                        self.epsilon,
                        radius * radius,
                        if self.epsilon >= radius * radius { "open" } else { "closed" }
                    ));
                }
                let chosen = match plan.backend {
                    Backend::Dense => "dense".to_string(),
                    Backend::Factored { rank } => format!("factored(r={rank})"),
                    Backend::Nystrom { rank, adaptive } => {
                        format!("nystrom(r={rank}{})", if adaptive { ",adaptive" } else { "" })
                    }
                };
                out.push_str(&format!(" => chose {chosen}\n"));
                if matches!(plan.backend, Backend::Nystrom { .. }) {
                    out.push_str(
                        "backend: adaptive nystrom sampling is never auto-planned \
                         (explicit .backend(BackendPref::Nystrom { adaptive: true, .. }) only)\n",
                    );
                }
            }
            _ => out.push_str(&format!(
                "backend: explicit request {:?} (validated, no auto rule ran)\n",
                self.kernel
            )),
        }

        // Domain: the underflow heuristic with its numbers, plus any
        // demotion (accelerated -> plain).
        if let Source::Measures { mu, nu } = self.source {
            let radius = mu.radius().max(nu.radius());
            let spread = radius * radius / self.epsilon;
            out.push_str(&format!(
                "domain: f32 underflow spread R^2/eps = {spread:.1} vs threshold \
                 {UNDERFLOW_LOG_SPREAD} -> {} risk",
                if self.underflow_risk() { "at" } else { "no" }
            ));
        } else {
            out.push_str("domain: prebuilt factors taken as given (no underflow probe)");
        }
        out.push_str(&format!(
            " => {}\n",
            match plan.domain {
                Domain::Plain => "plain",
                Domain::LogDomain => "log_domain",
                Domain::AutoEscalate => "auto_escalate",
            }
        ));
        if self.accelerated && self.domain != DomainChoice::Plain {
            out.push_str("domain: demoted to plain — the accelerated solver never escalates\n");
        }
        match plan.schedule {
            Some(s) => out.push_str(&format!(
                "anneal: geometric rungs from eps_start={} (4R^2 scale) by decay={} down \
                 to {} ({} rungs), symmetric self-solves {}\n",
                s.eps_start,
                s.decay,
                plan.epsilon,
                s.rungs(plan.epsilon).len(),
                if plan.symmetric_self_solves { "on" } else { "off" }
            )),
            None => out.push_str("anneal: off (direct solve at the target eps)\n"),
        }
        Ok(out)
    }

    /// The planner's straight-to-log-domain rule (see
    /// [`UNDERFLOW_LOG_SPREAD`]). Only measurable for measure-built
    /// problems — prebuilt factors are taken as given and rely on
    /// escalation.
    fn underflow_risk(&self) -> bool {
        match self.source {
            Source::Measures { mu, nu } => {
                let radius = mu.radius().max(nu.radius());
                radius * radius / self.epsilon >= UNDERFLOW_LOG_SPREAD
            }
            Source::Factors { .. } => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::rng::Rng;

    fn clouds(n: usize) -> (Measure, Measure) {
        let mut rng = Rng::seed_from(7);
        data::gaussian_blobs(n, &mut rng)
    }

    #[test]
    fn auto_backend_follows_the_flops_crossover() {
        // Large clouds: r(n+m) << nm -> factored.
        let (mu, nu) = clouds(2000);
        let plan = OtProblem::new(&mu, &nu).plan().unwrap();
        assert_eq!(plan.backend, Backend::Factored { rank: DEFAULT_RANK });
        assert!(plan.cache_key.is_some());
        // Tiny clouds: nm < r(n+m) -> dense wins (and is exact).
        let (mu, nu) = clouds(50);
        let plan = OtProblem::new(&mu, &nu).plan().unwrap();
        assert_eq!(plan.backend, Backend::Dense);
        assert!(plan.cache_key.is_none());
    }

    #[test]
    fn auto_domain_escalates_by_default_and_goes_log_at_tiny_eps() {
        let (mu, nu) = clouds(100);
        let moderate = OtProblem::new(&mu, &nu).epsilon(0.5).rank(32).plan().unwrap();
        assert_eq!(moderate.domain, Domain::AutoEscalate);
        assert_eq!(moderate.schedule, None, "no annealing at comfortable eps");
        assert!(!moderate.symmetric_self_solves);
        // Tiny eps now auto-anneals: the annealed solve arrives at the
        // target rung warm, so the domain stays escalate-on-demand
        // instead of going straight to log.
        let tiny = OtProblem::new(&mu, &nu).epsilon(1e-4).rank(32).plan().unwrap();
        assert!(tiny.schedule.is_some(), "R^2/eps >> {UNDERFLOW_LOG_SPREAD} must anneal");
        assert_eq!(tiny.domain, Domain::AutoEscalate);
        assert!(tiny.symmetric_self_solves, "symmetric follows annealing by default");
        // Annealing off restores the straight-to-log rule.
        let direct =
            OtProblem::new(&mu, &nu).epsilon(1e-4).rank(32).anneal(false).plan().unwrap();
        assert_eq!(direct.schedule, None);
        assert_eq!(direct.domain, Domain::LogDomain);
    }

    #[test]
    fn schedule_starts_at_the_support_diameter_scale() {
        let (mu, nu) = clouds(100);
        let plan = OtProblem::new(&mu, &nu).epsilon(1e-4).rank(32).plan().unwrap();
        let sch = plan.schedule.unwrap();
        let radius = mu.radius().max(nu.radius());
        assert_eq!(sch.eps_start.to_bits(), (4.0 * radius * radius).to_bits());
        let rungs = sch.rungs(plan.epsilon);
        assert_eq!(*rungs.last().unwrap(), 1e-4, "last rung is exactly the target");
        assert!(rungs.len() >= 2);
        // An explicit decay reshapes the ladder.
        let steep = OtProblem::new(&mu, &nu)
            .epsilon(1e-4)
            .rank(32)
            .anneal_decay(0.1)
            .plan()
            .unwrap();
        assert!(steep.schedule.unwrap().rungs(1e-4).len() < rungs.len());
    }

    #[test]
    fn explicit_anneal_requests_validate_against_the_backend() {
        let (mu, nu) = clouds(50);
        // Pinned domains don't auto-anneal...
        let pinned = OtProblem::new(&mu, &nu)
            .epsilon(1e-4)
            .rank(16)
            .domain(DomainChoice::LogDomain)
            .plan()
            .unwrap();
        assert_eq!(pinned.schedule, None);
        // ...but an explicit request composes with them.
        let explicit = OtProblem::new(&mu, &nu)
            .epsilon(0.5)
            .rank(16)
            .anneal(true)
            .symmetric_self_solves(false)
            .plan()
            .unwrap();
        assert!(explicit.schedule.is_some());
        assert!(!explicit.symmetric_self_solves, "explicit symmetric choice wins");
        // Invalid combinations are typed planning errors.
        assert!(OtProblem::new(&mu, &nu).accelerated().anneal(true).plan().is_err());
        let phi = Mat::from_fn(5, 2, |_, _| 1.0);
        let w = vec![0.2f32; 5];
        assert!(OtProblem::from_factors(&phi, &phi)
            .weights(&w, &w)
            .anneal(true)
            .plan()
            .is_err());
        assert!(OtProblem::new(&mu, &nu).anneal(true).anneal_decay(1.5).plan().is_err());
    }

    #[test]
    fn nystrom_plans_across_domains_and_annealing() {
        let (mu, nu) = clouds(60);
        // The old walls are gone: Nyström composes with every domain
        // choice and with annealing (the executor refits the kernel at
        // each rung's eps from the plan seed).
        let annealed = OtProblem::new(&mu, &nu).nystrom(8).anneal(true).plan().unwrap();
        assert!(annealed.schedule.is_some());
        assert_eq!(annealed.backend, Backend::Nystrom { rank: 8, adaptive: false });
        let logged = OtProblem::new(&mu, &nu)
            .backend(BackendPref::Nystrom { rank: 8, adaptive: true })
            .domain(DomainChoice::LogDomain)
            .plan()
            .unwrap();
        assert_eq!(logged.domain, Domain::LogDomain);
        assert_eq!(logged.backend, Backend::Nystrom { rank: 8, adaptive: true });
        // Auto domain treats the arm like any other: escalate-on-demand.
        let auto = OtProblem::new(&mu, &nu).epsilon(0.5).nystrom(8).plan().unwrap();
        assert_eq!(auto.domain, Domain::AutoEscalate);
        // The deprecated aliases still steer the same field.
        let aliased = OtProblem::new(&mu, &nu)
            .kernel(KernelChoice::Nystrom { rank: 8, adaptive: true })
            .plan()
            .unwrap();
        assert_eq!(aliased.backend, Backend::Nystrom { rank: 8, adaptive: true });
    }

    #[test]
    fn auto_backend_picks_uniform_nystrom_only_in_the_flat_regime() {
        let (mu, nu) = clouds(2000);
        let radius = mu.radius().max(nu.radius());
        // Flat kernel (eps >= R^2) on a big cloud: the cheap uniform
        // Nyström arm wins the flops race. Never the adaptive variant.
        let flat = OtProblem::new(&mu, &nu).epsilon(2.0 * radius * radius).plan().unwrap();
        assert_eq!(
            flat.backend,
            Backend::Nystrom { rank: DEFAULT_RANK / 4, adaptive: false },
            "flat regime on large clouds should auto-plan uniform nystrom"
        );
        // Sharp kernel: same clouds, small eps — gate closed, factored.
        let sharp = OtProblem::new(&mu, &nu).epsilon(0.05).plan().unwrap();
        assert_eq!(sharp.backend, Backend::Factored { rank: DEFAULT_RANK });
        // Tiny clouds: dense is cheaper than any low-rank arm even flat.
        let (mu, nu) = clouds(50);
        let radius = mu.radius().max(nu.radius());
        let tiny = OtProblem::new(&mu, &nu).epsilon(2.0 * radius * radius).plan().unwrap();
        assert_eq!(tiny.backend, Backend::Dense);
    }

    #[test]
    fn explain_narrates_the_flops_rule_and_demotions() {
        let (mu, nu) = clouds(2000);
        let text = OtProblem::new(&mu, &nu).epsilon(0.05).explain().unwrap();
        assert!(text.contains("plan: backend=factored"), "{text}");
        assert!(text.contains(&format!("dense 2000x2000 = {}", 2000 * 2000)), "{text}");
        assert!(text.contains("flat-kernel gate"), "{text}");
        assert!(text.contains("closed"), "{text}");
        assert!(text.contains("=> chose factored(r=256)"), "{text}");
        assert!(text.contains("R^2/eps"), "{text}");
        // Explicit requests say so instead of pretending a rule ran.
        let text = OtProblem::new(&mu, &nu)
            .backend(BackendPref::Nystrom { rank: 16, adaptive: true })
            .explain()
            .unwrap();
        assert!(text.contains("explicit request Nystrom"), "{text}");
        // Demotions are called out.
        let (mu, nu) = clouds(40);
        let cfg = SinkhornConfig::default();
        assert!(cfg.stabilize);
        let text =
            OtProblem::new(&mu, &nu).config(&cfg).rank(8).accelerated().explain().unwrap();
        assert!(text.contains("demoted to plain"), "{text}");
        // An annealed plan narrates its ladder.
        let text = OtProblem::new(&mu, &nu).epsilon(1e-4).rank(8).explain().unwrap();
        assert!(text.contains("anneal: geometric rungs"), "{text}");
    }

    #[test]
    fn backend_flag_parses_every_cli_form() {
        assert_eq!(BackendPref::parse_flag("auto", 64).unwrap(), BackendPref::Auto);
        assert_eq!(BackendPref::parse_flag("dense", 64).unwrap(), BackendPref::Dense);
        assert_eq!(
            BackendPref::parse_flag("factored", 64).unwrap(),
            BackendPref::Factored { rank: 64 }
        );
        assert_eq!(
            BackendPref::parse_flag("factored:300", 64).unwrap(),
            BackendPref::Factored { rank: 300 }
        );
        assert_eq!(
            BackendPref::parse_flag("nystrom", 64).unwrap(),
            BackendPref::Nystrom { rank: 64, adaptive: false }
        );
        assert_eq!(
            BackendPref::parse_flag("nystrom-adaptive:32", 64).unwrap(),
            BackendPref::Nystrom { rank: 32, adaptive: true }
        );
        assert!(BackendPref::parse_flag("cholesky", 64).is_err());
        assert!(BackendPref::parse_flag("nystrom:many", 64).is_err());
    }

    #[test]
    fn config_maps_stabilize_to_the_domain_choice() {
        let (mu, nu) = clouds(60);
        let off = SinkhornConfig { stabilize: false, ..SinkhornConfig::default() };
        let plan = OtProblem::new(&mu, &nu).config(&off).rank(16).plan().unwrap();
        assert_eq!(plan.domain, Domain::Plain);
        let on = SinkhornConfig { stabilize: true, ..off };
        let plan = OtProblem::new(&mu, &nu).config(&on).rank(16).plan().unwrap();
        assert_eq!(plan.domain, Domain::AutoEscalate);
    }

    #[test]
    fn batch_width_caps_at_max_batch() {
        let (mu, nu) = clouds(40);
        let a = vec![1.0f32 / 40.0; 40];
        let pairs: Vec<(&[f32], &[f32])> = (0..5).map(|_| (&a[..], &a[..])).collect();
        let plan = OtProblem::new(&mu, &nu)
            .rank(8)
            .weight_pairs(&pairs)
            .max_batch(2)
            .plan()
            .unwrap();
        assert_eq!(plan.pairs, 5);
        assert_eq!(plan.batch_width, 2);
    }

    #[test]
    fn factors_source_requires_weights_and_gets_its_rank_from_the_factors() {
        let phi_x = Mat::from_fn(10, 4, |i, k| 0.1 + (i + k) as f32 * 0.01);
        let phi_y = Mat::from_fn(8, 4, |j, k| 0.2 + (j + k) as f32 * 0.01);
        let missing = OtProblem::from_factors(&phi_x, &phi_y).plan();
        assert!(matches!(missing, Err(Error::Config(_))));
        let w_a = vec![0.1f32; 10];
        let w_b = vec![0.125f32; 8];
        let plan =
            OtProblem::from_factors(&phi_x, &phi_y).weights(&w_a, &w_b).plan().unwrap();
        assert_eq!(plan.backend, Backend::Factored { rank: 4 });
        assert!(!plan.stabilized_factors, "prebuilt factors are taken as given");
        assert!(plan.cache_key.is_none());
    }

    #[test]
    fn invalid_requests_fail_planning_with_typed_errors() {
        let (mu, nu) = clouds(30);
        assert!(OtProblem::new(&mu, &nu).epsilon(0.0).plan().is_err());
        assert!(OtProblem::new(&mu, &nu).rank(0).plan().is_err());
        assert!(OtProblem::new(&mu, &nu).nystrom(1000).plan().is_err());
        assert!(OtProblem::new(&mu, &nu)
            .accelerated()
            .domain(DomainChoice::LogDomain)
            .plan()
            .is_err());
        let phi = Mat::from_fn(5, 2, |_, _| 1.0);
        let w = vec![0.2f32; 5];
        assert!(OtProblem::from_factors(&phi, &phi).weights(&w, &w).dense().plan().is_err());
        // A plan must never describe a computation the executor cannot
        // perform: contradicting the prebuilt factors' rank or claiming
        // stabilised construction for as-given factors both fail.
        assert!(OtProblem::from_factors(&phi, &phi).weights(&w, &w).rank(7).plan().is_err());
        assert!(OtProblem::from_factors(&phi, &phi)
            .weights(&w, &w)
            .stabilized_factors(true)
            .plan()
            .is_err());
    }

    #[test]
    fn ground_truth_profile_is_dense_plain_and_tight() {
        let (mu, nu) = clouds(30);
        let plan = OtProblem::new(&mu, &nu).epsilon(0.7).ground_truth().plan().unwrap();
        assert_eq!(plan.backend, Backend::Dense);
        assert_eq!(plan.domain, Domain::Plain);
        assert_eq!(plan.max_iters, 20_000);
        assert_eq!(plan.tol, 1e-6);
        assert_eq!(plan.epsilon, 0.7);
    }

    #[test]
    fn plan_records_the_active_simd_arm() {
        let (mu, nu) = clouds(30);
        let plan = OtProblem::new(&mu, &nu).rank(8).plan().unwrap();
        assert_eq!(plan.simd_arm, simd::active_level().label());
    }

    #[test]
    fn accelerated_auto_domain_resolves_plain() {
        let (mu, nu) = clouds(30);
        let plan = OtProblem::new(&mu, &nu).rank(8).accelerated().plan().unwrap();
        assert_eq!(plan.domain, Domain::Plain);
        assert!(plan.accelerated);
        // The README migration path: absorbing a default config
        // (stabilize = true) must still plan — Alg. 2 never escalates,
        // so the escalation policy resolves to plain (legacy
        // `sinkhorn_accelerated` ignored `cfg.stabilize` the same way).
        let cfg = SinkhornConfig::default();
        assert!(cfg.stabilize);
        let plan =
            OtProblem::new(&mu, &nu).config(&cfg).rank(8).accelerated().plan().unwrap();
        assert_eq!(plan.domain, Domain::Plain);
    }
}
