//! Result types of the planned API: [`Solution`] for one transport
//! problem, [`DivergenceReport`] for the three-solve Eq. (2) divergence.
//!
//! Both carry the diagnostics the free-function era scattered across
//! tuples and metrics: whether the log-domain escalation fired, wall
//! clock, and the SIMD dispatch-arm tag (the same string the
//! BENCH_*.json tables record as `cpu`, so service telemetry and bench
//! artifacts key on one vocabulary).

use crate::sinkhorn::{AccelSolution, SinkhornSolution};

/// Output of one planned transport solve.
#[derive(Clone, Debug)]
pub struct Solution {
    /// The Eq. (6) objective estimate (log-scale-compensated for
    /// stabilised kernels, exactly like the legacy solvers).
    pub objective: f64,
    /// Row scaling u (length n). For accelerated solves these are
    /// `exp(eta1)` and may saturate f32 at extreme duals.
    pub u: Vec<f32>,
    /// Column scaling v (length m).
    pub v: Vec<f32>,
    /// Iterations executed.
    pub iterations: usize,
    /// Final L1 marginal error (`NaN` for accelerated solves, which stop
    /// on the dual gradient norm instead — see `grad_norm`).
    pub marginal_error: f64,
    /// Whether the stopping criterion was met before the iteration cap.
    pub converged: bool,
    /// Whether this solve took the log-domain escalation path (always
    /// `false` when the plan chose `LogDomain` outright — a planned
    /// domain is not an escalation).
    pub escalated: bool,
    /// Final dual gradient norm — accelerated (Alg. 2) solves only.
    pub grad_norm: Option<f64>,
    /// Wall clock of the solve in microseconds. For fused batched solves
    /// this is the wall clock of the chunk that served this pair.
    pub wall_us: u64,
    /// The SIMD dispatch arm that actually executed ("scalar" /
    /// "avx2+fma"), matching the `cpu` field of BENCH_*.json.
    pub simd_arm: &'static str,
    /// Iterations per annealing rung, outermost (largest eps) first.
    /// Empty for direct (unscheduled) solves; for annealed solves
    /// `iterations` is the *target-rung* count and
    /// `rung_iterations.iter().sum()` is the whole chain.
    pub rung_iterations: Vec<usize>,
}

impl Solution {
    /// Dual potentials `alpha = eps log u`, `beta = eps log v`.
    pub fn duals(&self, eps: f64) -> (Vec<f32>, Vec<f32>) {
        let a = self.u.iter().map(|&x| (eps * (x as f64).ln()) as f32).collect();
        let b = self.v.iter().map(|&x| (eps * (x as f64).ln()) as f32).collect();
        (a, b)
    }

    pub(crate) fn from_sinkhorn(sol: SinkhornSolution, escalated: bool, wall_us: u64) -> Self {
        Solution {
            objective: sol.objective,
            u: sol.u,
            v: sol.v,
            iterations: sol.iterations,
            marginal_error: sol.marginal_error,
            converged: sol.converged,
            escalated,
            grad_norm: None,
            wall_us,
            simd_arm: crate::linalg::simd::active_level().label(),
            rung_iterations: Vec::new(),
        }
    }

    /// Total iterations including any annealing rungs (equals
    /// `iterations` for direct solves).
    pub fn total_iterations(&self) -> usize {
        if self.rung_iterations.is_empty() {
            self.iterations
        } else {
            self.rung_iterations.iter().sum()
        }
    }

    pub(crate) fn from_accel(sol: AccelSolution, wall_us: u64) -> Self {
        Solution {
            objective: sol.objective,
            u: sol.eta1.iter().map(|&e| e.exp() as f32).collect(),
            v: sol.eta2.iter().map(|&e| e.exp() as f32).collect(),
            iterations: sol.iterations,
            marginal_error: f64::NAN,
            converged: sol.converged,
            escalated: false,
            grad_norm: Some(sol.grad_norm),
            wall_us,
            simd_arm: crate::linalg::simd::active_level().label(),
            rung_iterations: Vec::new(),
        }
    }
}

/// The Eq. (2) debiased divergence
/// `W(mu,nu) - (W(mu,mu) + W(nu,nu))/2`, with all three constituent
/// solutions retained (their duals drive the Prop-3.2 envelope gradients
/// of the GAN trainer and the gradient flows).
#[derive(Clone, Debug)]
pub struct DivergenceReport {
    /// The debiased Sinkhorn divergence estimate.
    pub divergence: f64,
    /// The cross solve W(mu, nu).
    pub xy: Solution,
    /// The self solve W(mu, mu).
    pub xx: Solution,
    /// The self solve W(nu, nu).
    pub yy: Solution,
    /// End-to-end wall clock (kernel construction + three solves), us.
    pub wall_us: u64,
    /// The SIMD dispatch arm that executed (see [`Solution::simd_arm`]).
    pub simd_arm: &'static str,
}

impl DivergenceReport {
    pub(crate) fn assemble(xy: Solution, xx: Solution, yy: Solution, wall_us: u64) -> Self {
        DivergenceReport {
            divergence: xy.objective - 0.5 * (xx.objective + yy.objective),
            simd_arm: xy.simd_arm,
            xy,
            xx,
            yy,
            wall_us,
        }
    }

    /// The raw transport objective W(mu, nu).
    pub fn w_xy(&self) -> f64 {
        self.xy.objective
    }

    /// Total Sinkhorn iterations across the three solves (target rungs
    /// only for annealed plans — see [`DivergenceReport::total_iterations`]).
    pub fn iterations(&self) -> usize {
        self.xy.iterations + self.xx.iterations + self.yy.iterations
    }

    /// Total iterations across the three solves *and* all annealing
    /// rungs — the cost metric the iteration-count benches record.
    pub fn total_iterations(&self) -> usize {
        self.xy.total_iterations() + self.xx.total_iterations() + self.yy.total_iterations()
    }

    /// Per-solve iteration counts `[xy, xx, yy]`, rungs included.
    pub fn per_solve_iterations(&self) -> [usize; 3] {
        [
            self.xy.total_iterations(),
            self.xx.total_iterations(),
            self.yy.total_iterations(),
        ]
    }

    /// How many of the three solves escalated to the log domain (the
    /// coordinator exports the sum as `service.stabilized_solves`).
    pub fn escalations(&self) -> usize {
        [&self.xy, &self.xx, &self.yy].iter().filter(|s| s.escalated).count()
    }

    /// Whether all three solves converged.
    pub fn converged(&self) -> bool {
        self.xy.converged && self.xx.converged && self.yy.converged
    }
}
