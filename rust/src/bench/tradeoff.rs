//! Shared runner for the paper's time–accuracy tradeoff experiments
//! (Figures 1, 3 and 5): for each regularisation eps and each feature
//! count / rank r, measure wall-clock and the deviation score
//! `D = 100 (ROT - ROT_hat)/|ROT| + 100` for the three contenders:
//!
//! * `Sin`   — converged dense Sinkhorn (also defines the ground truth),
//! * `RF`    — the paper's positive random features (always runs),
//! * `Nys`   — uniform-landmark Nyström low-rank (recorded as FAILED when
//!             it loses positivity or diverges — the paper's central
//!             contrast),
//! * `Nys+a` — Nyström with adaptive farthest-point landmarks
//!             (arXiv:1812.05189): better-spread landmarks at the same
//!             rank, the same broken-positivity failure mode.
//!
//! [`run_headtohead`] is the focused variant: positive features vs
//! adaptive Nyström vs uniform Nyström at one matched rank, error vs
//! time per eps.

use crate::api::{BackendPref, OtProblem};
use crate::config::SinkhornConfig;
use crate::data::Measure;
use crate::features::GaussianFeatureMap;
use crate::kernels::CostMatrixLogKernel;
use crate::metrics::Stopwatch;
use crate::rng::Rng;
use crate::sinkhorn::{deviation_score, sinkhorn_log_domain, sq_euclidean_cost};

/// One measured cell of the sweep.
#[derive(Clone, Debug)]
pub struct Cell {
    pub method: &'static str,
    pub eps: f64,
    /// Feature count / rank (0 for the dense baseline).
    pub rank: usize,
    /// Mean deviation score over reps (100 = exact); NaN if every rep failed.
    pub deviation: f64,
    /// Mean wall-clock seconds over successful reps.
    pub time_s: f64,
    /// Successful repetitions out of `reps`.
    pub ok: usize,
    pub reps: usize,
    /// Human-readable failure reason when ok == 0.
    pub failure: Option<String>,
}

/// The sweep configuration.
#[derive(Clone, Debug)]
pub struct Sweep {
    pub epsilons: Vec<f64>,
    pub ranks: Vec<usize>,
    pub reps: usize,
    pub solver_tol: f64,
    pub max_iters: usize,
}

impl Default for Sweep {
    fn default() -> Self {
        Sweep {
            epsilons: vec![0.05, 0.1, 0.5, 1.0],
            ranks: vec![100, 300, 600, 1000, 2000],
            reps: 3,
            solver_tol: 1e-4,
            max_iters: 5000,
        }
    }
}

/// Ground truth ROT per eps: converged *f64* dense Sinkhorn.
///
/// f64 exponent range (down to ~1e-308) keeps `exp(-C/eps)` away from
/// underflow for every regularisation in the paper's sweeps, and dense
/// f64 matvecs are orders of magnitude faster than the per-entry
/// logsumexp of the log-domain solver — which remains the fallback when
/// the f64 kernel itself degenerates (rows flushed to zero).
pub fn ground_truth(mu: &Measure, nu: &Measure, eps: f64) -> f64 {
    if let Some(v) = ground_truth_dense_f64(mu, nu, eps, 1e-7, 20_000) {
        return v;
    }
    let cost = sq_euclidean_cost(&mu.points, &nu.points);
    let cfg = SinkhornConfig {
        epsilon: eps,
        max_iters: 10_000,
        tol: 1e-7,
        check_every: 25,
        threads: 1,
        stabilize: false,
        max_batch: 1,
        // Pinned off: this is the exact log-domain reference solve.
        anneal: Some(false),
        anneal_decay: 0.5,
        symmetric: Some(false),
    };
    sinkhorn_log_domain(&CostMatrixLogKernel::new(&cost, eps), &mu.weights, &nu.weights, &cfg)
        .expect("log-domain ground truth cannot diverge")
        .objective
}

/// Alg. 1 on an f64 dense Gibbs kernel; None if the kernel degenerates.
fn ground_truth_dense_f64(
    mu: &Measure,
    nu: &Measure,
    eps: f64,
    tol: f64,
    max_iters: usize,
) -> Option<f64> {
    let (n, m) = (mu.len(), nu.len());
    // Row-major f64 kernel.
    let mut k = vec![0.0f64; n * m];
    for i in 0..n {
        let xi = mu.points.row(i);
        for j in 0..m {
            let yj = nu.points.row(j);
            let d2: f64 =
                xi.iter().zip(yj).map(|(&a, &b)| ((a - b) as f64) * ((a - b) as f64)).sum();
            k[i * m + j] = (-d2 / eps).exp();
        }
    }
    let a: Vec<f64> = mu.weights.iter().map(|&x| x as f64).collect();
    let b: Vec<f64> = nu.weights.iter().map(|&x| x as f64).collect();
    let mut u = vec![1.0f64; n];
    let mut v = vec![1.0f64; m];
    let mut ktu = vec![0.0f64; m];
    let mut kv = vec![0.0f64; n];
    for it in 0..max_iters {
        ktu.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..n {
            let ui = u[i];
            let row = &k[i * m..(i + 1) * m];
            for (t, &kij) in ktu.iter_mut().zip(row) {
                *t += kij * ui;
            }
        }
        for j in 0..m {
            v[j] = b[j] / ktu[j];
        }
        for i in 0..n {
            let row = &k[i * m..(i + 1) * m];
            kv[i] = row.iter().zip(&v).map(|(&kij, &vj)| kij * vj).sum();
            u[i] = a[i] / kv[i];
        }
        if !u.iter().chain(v.iter()).all(|x| x.is_finite() && *x > 0.0) {
            return None; // degenerate: caller falls back to log-domain
        }
        if it % 20 == 0 || it + 1 == max_iters {
            // Marginal error.
            ktu.iter_mut().for_each(|x| *x = 0.0);
            for i in 0..n {
                let ui = u[i];
                let row = &k[i * m..(i + 1) * m];
                for (t, &kij) in ktu.iter_mut().zip(row) {
                    *t += kij * ui;
                }
            }
            let err: f64 = (0..m).map(|j| (v[j] * ktu[j] - b[j]).abs()).sum();
            if err < tol {
                break;
            }
        }
    }
    let obj = eps
        * (a.iter().zip(&u).map(|(&ai, &ui)| ai * ui.ln()).sum::<f64>()
            + b.iter().zip(&v).map(|(&bi, &vi)| bi * vi.ln()).sum::<f64>());
    obj.is_finite().then_some(obj)
}

/// Run the full sweep on a workload generator (fresh clouds per rep draw
/// share the same generator seed, matching the paper's repeated trials).
pub fn run_sweep(
    mu: &Measure,
    nu: &Measure,
    sweep: &Sweep,
    seed: u64,
    progress: impl Fn(&Cell),
) -> Vec<Cell> {
    let mut cells = Vec::new();
    for &eps in &sweep.epsilons {
        let truth = ground_truth(mu, nu, eps);
        let cfg = SinkhornConfig {
            epsilon: eps,
            max_iters: sweep.max_iters,
            tol: sweep.solver_tol,
            check_every: 10,
            threads: 1,
            stabilize: false,
            max_batch: 1,
            anneal: None,
            anneal_decay: 0.5,
            symmetric: None,
        };
        // All three contenders run through the planned API with the
        // domain pinned to Plain (`stabilize: false` in `cfg`): the sweep
        // *wants* small-eps failures recorded as FAILED cells, not
        // silently escalated — that contrast is the figure.

        // --- Sin baseline: converged dense solve (one timing; deviation of
        // its own estimate vs the tight-tolerance truth).
        {
            let sw = Stopwatch::start();
            let cell = match OtProblem::new(mu, nu).config(&cfg).dense().solve() {
                Ok(sol) => Cell {
                    method: "Sin",
                    eps,
                    rank: 0,
                    deviation: deviation_score(truth, sol.objective),
                    time_s: sw.elapsed_secs(),
                    ok: 1,
                    reps: 1,
                    failure: None,
                },
                Err(e) => Cell {
                    method: "Sin",
                    eps,
                    rank: 0,
                    deviation: f64::NAN,
                    time_s: sw.elapsed_secs(),
                    ok: 0,
                    reps: 1,
                    failure: Some(e.to_string()),
                },
            };
            progress(&cell);
            cells.push(cell);
        }

        // --- RF and Nys per rank.
        for &r in &sweep.ranks {
            let mut rf_devs = Vec::new();
            let mut rf_times = Vec::new();
            let mut rf_fail = None;
            let mut an_devs = Vec::new();
            let mut an_times = Vec::new();
            let mut an_fail: Option<String> = None;
            let mut ny_devs = Vec::new();
            let mut ny_times = Vec::new();
            let mut ny_fail: Option<String> = None;
            let mut na_devs = Vec::new();
            let mut na_times = Vec::new();
            let mut na_fail: Option<String> = None;
            for rep in 0..sweep.reps {
                let rep_seed = seed ^ (rep as u64) << 32 ^ r as u64;
                let mut rng = Rng::seed_from(rep_seed);
                // RF.
                let sw = Stopwatch::start();
                let map = GaussianFeatureMap::fit(mu, nu, eps, r, &mut rng);
                // Stabilised factors: at small eps the raw Gibbs scale sits
                // far below f32 range; the log-normalised factors keep RF
                // running exactly where the paper's f64 implementation did.
                let rf = OtProblem::new(mu, nu)
                    .config(&cfg)
                    .rank(r)
                    .with_feature_map(&map)
                    .stabilized_factors(true)
                    .solve();
                match rf {
                    Ok(sol) => {
                        rf_devs.push(deviation_score(truth, sol.objective));
                        rf_times.push(sw.elapsed_secs());
                    }
                    Err(e) => rf_fail = Some(e.to_string()),
                }
                // RF with the eps-annealing schedule: same features, same
                // pinned plain domain, but the solve walks a geometric eps
                // ladder with dual warm starts (intermediate-rung map
                // refits included in the timing — that is the real cost).
                let sw = Stopwatch::start();
                let an = OtProblem::new(mu, nu)
                    .config(&cfg)
                    .rank(r)
                    .seed(rep_seed)
                    .with_feature_map(&map)
                    .stabilized_factors(true)
                    .anneal(true)
                    .solve();
                match an {
                    Ok(sol) => {
                        an_devs.push(deviation_score(truth, sol.objective));
                        an_times.push(sw.elapsed_secs());
                    }
                    Err(e) => an_fail = Some(e.to_string()),
                }
                // Nys: no pre-validation — Sinkhorn itself is the judge.
                // (Its iterates only touch K^T u / K v for the actual
                // scaling vectors; the solver reports SinkhornDiverged when
                // the lost positivity actually bites, which is the paper's
                // observed failure mode.) The landmark draw is seeded per
                // rep through the plan.
                let sw = Stopwatch::start();
                let nys = OtProblem::new(mu, nu)
                    .config(&cfg)
                    .nystrom(r.min(mu.len()))
                    .seed(rep_seed ^ 0x4E59)
                    .solve();
                match nys {
                    Ok(sol) => {
                        ny_devs.push(deviation_score(truth, sol.objective));
                        ny_times.push(sw.elapsed_secs());
                    }
                    Err(e) => ny_fail = Some(e.to_string()),
                }
                // Adaptive Nyström: same rank, farthest-point landmarks
                // (the greedy pass is part of the measured time — spread
                // landmarks are only worth what they cost).
                let sw = Stopwatch::start();
                let nysa = OtProblem::new(mu, nu)
                    .config(&cfg)
                    .backend(BackendPref::Nystrom { rank: r.min(mu.len()), adaptive: true })
                    .seed(rep_seed ^ 0x4E5A)
                    .solve();
                match nysa {
                    Ok(sol) => {
                        na_devs.push(deviation_score(truth, sol.objective));
                        na_times.push(sw.elapsed_secs());
                    }
                    Err(e) => na_fail = Some(e.to_string()),
                }
            }
            let mk = |method: &'static str,
                      devs: &[f64],
                      times: &[f64],
                      fail: Option<String>| Cell {
                method,
                eps,
                rank: r,
                deviation: if devs.is_empty() {
                    f64::NAN
                } else {
                    devs.iter().sum::<f64>() / devs.len() as f64
                },
                time_s: if times.is_empty() {
                    f64::NAN
                } else {
                    times.iter().sum::<f64>() / times.len() as f64
                },
                ok: devs.len(),
                reps: sweep.reps,
                failure: if devs.is_empty() { fail } else { None },
            };
            let rf = mk("RF", &rf_devs, &rf_times, rf_fail);
            progress(&rf);
            cells.push(rf);
            let an = mk("RF+an", &an_devs, &an_times, an_fail);
            progress(&an);
            cells.push(an);
            let ny = mk("Nys", &ny_devs, &ny_times, ny_fail);
            progress(&ny);
            cells.push(ny);
            let na = mk("Nys+a", &na_devs, &na_times, na_fail);
            progress(&na);
            cells.push(na);
        }
    }
    cells
}

/// The PR-8 head-to-head: positive features vs adaptive Nyström vs
/// uniform Nyström at one matched rank, error vs time per eps (the
/// acceptance sweep runs eps ∈ {1e-1, 1e-2, 1e-3}).
///
/// Solves run with log-domain escalation *on* (unlike [`run_sweep`]'s
/// pinned plain domain): at small eps the positive-feature kernel
/// escalates and still answers, while Nyström's clamped signed log view
/// gates itself off exactly where clamping would distort the apply — so
/// its broken-positivity regime lands as a FAILED cell, which is the
/// paper's contrast measured end to end.
pub fn run_headtohead(
    mu: &Measure,
    nu: &Measure,
    epsilons: &[f64],
    rank: usize,
    reps: usize,
    seed: u64,
    progress: impl Fn(&Cell),
) -> Vec<Cell> {
    // Matched rank: the divergence-free solve only needs rank <= m, but
    // keep the cap symmetric so the comparison is honest for any clouds.
    let r = rank.min(mu.len()).min(nu.len());
    let mut cells = Vec::new();
    for &eps in epsilons {
        let truth = ground_truth(mu, nu, eps);
        let cfg = SinkhornConfig {
            epsilon: eps,
            max_iters: 5000,
            tol: 1e-4,
            check_every: 10,
            threads: 1,
            stabilize: true,
            max_batch: 1,
            // Direct solves: annealing would blur the per-backend timing.
            anneal: Some(false),
            anneal_decay: 0.5,
            symmetric: Some(false),
        };
        let mut devs = [Vec::new(), Vec::new(), Vec::new()];
        let mut times = [Vec::new(), Vec::new(), Vec::new()];
        let mut fails: [Option<String>; 3] = [None, None, None];
        for rep in 0..reps {
            let rep_seed = seed ^ ((rep as u64) << 32) ^ r as u64;
            let contenders: [(usize, BackendPref, u64); 3] = [
                (0, BackendPref::Factored { rank: r }, rep_seed),
                (1, BackendPref::Nystrom { rank: r, adaptive: true }, rep_seed ^ 0x4E5A),
                (2, BackendPref::Nystrom { rank: r, adaptive: false }, rep_seed ^ 0x4E59),
            ];
            for (slot, pref, s) in contenders {
                let sw = Stopwatch::start();
                let res = OtProblem::new(mu, nu).config(&cfg).backend(pref).seed(s).solve();
                match res {
                    Ok(sol) => {
                        devs[slot].push(deviation_score(truth, sol.objective));
                        times[slot].push(sw.elapsed_secs());
                    }
                    Err(e) => fails[slot] = Some(e.to_string()),
                }
            }
        }
        for (slot, method) in [(0usize, "RF"), (1, "Nys+a"), (2, "Nys")] {
            let d = &devs[slot];
            let t = &times[slot];
            let cell = Cell {
                method,
                eps,
                rank: r,
                deviation: if d.is_empty() {
                    f64::NAN
                } else {
                    d.iter().sum::<f64>() / d.len() as f64
                },
                time_s: if t.is_empty() {
                    f64::NAN
                } else {
                    t.iter().sum::<f64>() / t.len() as f64
                },
                ok: d.len(),
                reps,
                failure: if d.is_empty() { fails[slot].take() } else { None },
            };
            progress(&cell);
            cells.push(cell);
        }
    }
    cells
}

/// Render cells into a [`super::Table`] matching the figure's series.
pub fn cells_to_table(title: &str, cells: &[Cell]) -> super::Table {
    let mut t = super::Table::new(
        title,
        &["method", "eps", "r", "deviation", "time", "ok/reps", "note"],
    );
    for c in cells {
        t.row(vec![
            c.method.to_string(),
            format!("{}", c.eps),
            if c.rank == 0 { "-".into() } else { c.rank.to_string() },
            if c.deviation.is_nan() { "FAILED".into() } else { format!("{:.2}", c.deviation) },
            if c.time_s.is_nan() { "-".into() } else { super::fmt_secs(c.time_s) },
            format!("{}/{}", c.ok, c.reps),
            c.failure.clone().map(|f| truncate(&f, 48)).unwrap_or_default(),
        ]);
    }
    t
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;

    #[test]
    fn tiny_sweep_produces_expected_shape() {
        let mut rng = Rng::seed_from(0);
        let (mu, nu) = data::gaussian_blobs(80, &mut rng);
        let sweep = Sweep {
            epsilons: vec![0.5],
            ranks: vec![50, 200],
            reps: 1,
            solver_tol: 1e-4,
            max_iters: 2000,
        };
        let cells = run_sweep(&mu, &nu, &sweep, 0, |_| {});
        // 1 Sin + 2 ranks x 4 methods (RF, RF+an, Nys, Nys+a) = 9 cells.
        assert_eq!(cells.len(), 9);
        let sin = &cells[0];
        assert_eq!(sin.method, "Sin");
        assert!((sin.deviation - 100.0).abs() < 1.0, "Sin dev {}", sin.deviation);
        // RF at r=200 on an n=80 problem: psi/sqrt(r) is O(1) here, so only
        // a loose accuracy band is guaranteed (Thm 3.1 needs much larger r
        // for tight bounds); the regression being guarded is the *sign and
        // scale* of the deviation plumbing, not MC tightness.
        let rf = cells.iter().find(|c| c.method == "RF" && c.rank == 200).unwrap();
        assert!(rf.ok == 1);
        assert!((rf.deviation - 100.0).abs() < 50.0, "RF dev {}", rf.deviation);
        // The annealed RF contender solves the same problem through the
        // eps ladder; its deviation plumbing holds to the same band.
        let an = cells.iter().find(|c| c.method == "RF+an" && c.rank == 200).unwrap();
        assert!(an.ok == 1, "annealed RF failed: {:?}", an.failure);
        assert!((an.deviation - 100.0).abs() < 50.0, "RF+an dev {}", an.deviation);
    }

    #[test]
    fn headtohead_emits_three_methods_per_eps() {
        let mut rng = Rng::seed_from(3);
        let (mu, nu) = data::gaussian_blobs(60, &mut rng);
        // One comfortable eps: all three contenders should answer here
        // (small-eps Nyström failures are the bench's business, not this
        // shape test's).
        let cells = run_headtohead(&mu, &nu, &[5.0], 12, 1, 7, |_| {});
        assert_eq!(cells.len(), 3);
        let methods: Vec<&str> = cells.iter().map(|c| c.method).collect();
        assert_eq!(methods, vec!["RF", "Nys+a", "Nys"]);
        for c in &cells {
            assert_eq!(c.rank, 12);
            assert_eq!(c.ok, 1, "{} failed: {:?}", c.method, c.failure);
            assert!(c.time_s.is_finite() && c.time_s >= 0.0);
            assert!(c.deviation.is_finite());
        }
    }

    #[test]
    fn ground_truth_is_finite_at_small_eps() {
        let mut rng = Rng::seed_from(1);
        let (mu, nu) = data::gaussian_blobs(40, &mut rng);
        let t = ground_truth(&mu, &nu, 0.01);
        assert!(t.is_finite());
    }

    #[test]
    fn table_rendering_includes_failures() {
        let cells = vec![Cell {
            method: "Nys",
            eps: 0.05,
            rank: 100,
            deviation: f64::NAN,
            time_s: f64::NAN,
            ok: 0,
            reps: 3,
            failure: Some("kernel approximation is not positive".into()),
        }];
        let t = cells_to_table("t", &cells);
        let md = t.render();
        assert!(md.contains("FAILED"));
        assert!(md.contains("not positive"));
    }
}
