//! Micro-benchmark harness (criterion is not in the offline crate set).
//!
//! Provides warmup + repeated timing with robust statistics, and table
//! emitters (markdown + CSV) used by every `rust/benches/*` target to
//! print the paper's figures as machine-readable series.

pub mod tradeoff;

use std::time::Instant;

/// Robust summary of repeated timings.
#[derive(Clone, Debug)]
pub struct Summary {
    pub reps: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub stddev_s: f64,
}

/// Time `f` with `warmup` unmeasured runs then `reps` measured runs.
pub fn time<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Summary {
    assert!(reps > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    summarize(&samples)
}

/// Summarise raw second-valued samples.
pub fn summarize(samples: &[f64]) -> Summary {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    let mean = sorted.iter().sum::<f64>() / n as f64;
    let median = if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    };
    let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        reps: n,
        mean_s: mean,
        median_s: median,
        min_s: sorted[0],
        max_s: sorted[n - 1],
        stddev_s: var.sqrt(),
    }
}

/// A results table rendered as aligned markdown and optionally CSV.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells);
    }

    /// Render as aligned markdown.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = format!("\n### {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV (headers + rows).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Render as one JSON object (`{"title", "cpu", "headers", "rows"}`)
    /// — the machine-readable form the CI `bench-smoke` job collects into
    /// `BENCH_ci.json` (one object per line, one line per table). The
    /// `cpu` field tags every table with the active SIMD dispatch arm
    /// (`scalar` / `avx2+fma`, [`crate::linalg::simd::active_level`]), so
    /// BENCH_*.json trajectories recorded on different machines — or with
    /// `LINEAR_SINKHORN_SIMD=scalar` forced — stay comparable.
    pub fn to_json(&self) -> String {
        let arr = |items: &[String]| -> String {
            let quoted: Vec<String> =
                items.iter().map(|s| format!("\"{}\"", json_escape(s))).collect();
            format!("[{}]", quoted.join(","))
        };
        let rows: Vec<String> = self.rows.iter().map(|r| arr(r)).collect();
        format!(
            "{{\"title\":\"{}\",\"cpu\":\"{}\",\"headers\":{},\"rows\":[{}]}}",
            json_escape(&self.title),
            json_escape(crate::linalg::simd::active_level().label()),
            arr(&self.headers),
            rows.join(",")
        )
    }

    /// Print markdown to stdout and optionally write CSV next to it.
    ///
    /// When the `BENCH_JSON` environment variable names a file, the table
    /// is additionally appended there in JSON-lines form — how the CI
    /// `bench-smoke` job records every bench table into one artifact
    /// without per-bench plumbing.
    pub fn emit(&self, csv_path: Option<&str>) {
        println!("{}", self.render());
        if let Some(path) = csv_path {
            if let Some(parent) = std::path::Path::new(path).parent() {
                let _ = std::fs::create_dir_all(parent);
            }
            std::fs::write(path, self.to_csv()).expect("write csv");
            println!("(csv written to {path})");
        }
        if let Ok(json_path) = std::env::var("BENCH_JSON") {
            if !json_path.is_empty() {
                self.append_json(&json_path);
                println!("(json appended to {json_path})");
            }
        }
    }

    /// Append this table's [`Table::to_json`] line to `path`.
    pub fn append_json(&self, path: &str) {
        use std::io::Write;
        if let Some(parent) = std::path::Path::new(path).parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .expect("open bench json");
        writeln!(f, "{}", self.to_json()).expect("append bench json");
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars) —
/// table titles and cells are plain ASCII, but stay correct regardless.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Convenience: format seconds adaptively (s / ms / us).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_statistics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.reps, 5);
        assert_eq!(s.median_s, 3.0);
        assert_eq!(s.min_s, 1.0);
        assert_eq!(s.max_s, 100.0);
        assert!((s.mean_s - 22.0).abs() < 1e-12);
        // Median is robust to the outlier, mean is not.
        assert!(s.median_s < s.mean_s);
    }

    #[test]
    fn even_count_median_interpolates() {
        let s = summarize(&[1.0, 3.0]);
        assert_eq!(s.median_s, 2.0);
    }

    #[test]
    fn time_runs_function() {
        let mut count = 0;
        let s = time(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.reps, 5);
        assert!(s.min_s >= 0.0);
    }

    #[test]
    fn table_renders_and_csvs() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "x".into()]);
        let md = t.render();
        assert!(md.contains("### demo"));
        assert!(md.contains("| 1 | x |"));
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n1,x\n");
    }

    #[test]
    #[should_panic]
    fn table_arity_enforced() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn table_json_shape_and_escaping() {
        let mut t = Table::new("q\"t", &["a", "b"]);
        t.row(vec!["1.5x".into(), "path\\x\n".into()]);
        let cpu = crate::linalg::simd::active_level().label();
        assert_eq!(
            t.to_json(),
            format!(
                "{{\"title\":\"q\\\"t\",\"cpu\":\"{cpu}\",\"headers\":[\"a\",\"b\"],\
                 \"rows\":[[\"1.5x\",\"path\\\\x\\n\"]]}}"
            )
        );
    }

    #[test]
    fn append_json_writes_one_line_per_table() {
        let dir = std::env::temp_dir().join("ls_bench_json_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("BENCH_ci.json");
        let path = path.to_str().unwrap();
        let mut t = Table::new("demo", &["a"]);
        t.row(vec!["1".into()]);
        t.append_json(path);
        t.append_json(path);
        let contents = std::fs::read_to_string(path).unwrap();
        assert_eq!(contents.lines().count(), 2);
        assert!(contents.lines().all(|l| l.starts_with("{\"title\":\"demo\"")));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(2.0), "2.00s");
        assert_eq!(fmt_secs(0.002), "2.00ms");
        assert_eq!(fmt_secs(2e-6), "2.0us");
    }
}
